"""CI gate: lint the compiled programs of every shipped spec.

For each ``examples/specs/*.json`` (``plan_*.json`` are PrecisionPlans,
not programs — skipped) this builds the spec's train step and — on 1x1
meshes — its serving decode tick, runs the ``repro.analysis`` rule
registry over the traced jaxpr + compiled HLO, and emits a JSON program
report: explicit wire-launch counts, per-kind/per-dtype HLO collective
census, data-axis-crossing counts, aliased-buffer counts, violations.

The report is diffed against the committed golden
(``benchmarks/baselines/PROGRAMS.json``) with the same direction-aware
``--update`` / ``--override`` flow as ``benchmarks/check_regression.py``
— default tolerance is zero (program shapes are deterministic counts).
Rule violations fail the run regardless of what the baseline says.

Usage (8-device CI job):
    python tools/lint_programs.py --devices 8 --out REPORT_programs.json
Re-baseline after an intentional program change:
    python tools/lint_programs.py --devices 8 --update
Widen one metric (e.g. an XLA upgrade shifting GSPMD counts):
    ... --override 'train:*.collectives.*=0.25'

Exit codes: 0 = clean, 1 = violations or regression, 2 = missing
baseline / bad invocation.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import shutil
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_SPECS = os.path.join(ROOT, "examples", "specs")
DEFAULT_BASELINE = os.path.join(ROOT, "benchmarks", "baselines",
                                "PROGRAMS.json")


def _spec_paths(specs_dir: str):
    return [p for p in sorted(glob.glob(os.path.join(specs_dir, "*.json")))
            if not os.path.basename(p).startswith("plan_")]


def _devices_needed(paths) -> int:
    need = 1
    for p in paths:
        mesh = json.load(open(p)).get("mesh", {})
        need = max(need, mesh.get("pods", 1) * mesh.get("data", 1)
                   * mesh.get("model", 1))
    return need


def _parse_override(s: str):
    if "=" not in s:
        raise argparse.ArgumentTypeError(
            f"--override wants PATTERN=TOL, got {s!r}")
    pattern, _, tol = s.rpartition("=")
    return pattern, float(tol)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="lint compiled programs of shipped specs")
    ap.add_argument("--specs-dir", default=DEFAULT_SPECS)
    ap.add_argument("--out", default="REPORT_programs.json",
                    help="where to write the fresh report JSON")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--update", action="store_true",
                    help="copy the fresh report over the baseline "
                         "instead of diffing (still fails on violations)")
    ap.add_argument("--override", action="append", default=[],
                    type=_parse_override, metavar="PATTERN=TOL",
                    help="relative tolerance for matching metrics "
                         "(fnmatch, last match wins, default 0)")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (0 = max any spec needs)")
    args = ap.parse_args(argv)

    paths = _spec_paths(args.specs_dir)
    if not paths:
        print(f"no specs under {args.specs_dir}", file=sys.stderr)
        return 2

    # force the device count BEFORE jax initializes — same contract as
    # benchmarks/collectives_bench.py --devices
    need = args.devices or _devices_needed(paths)
    if "jax" in sys.modules:
        import jax
        if jax.device_count() < need:
            print(f"jax already initialized with {jax.device_count()} "
                  f"devices, need {need}", file=sys.stderr)
            return 2
    else:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={need}").strip()

    sys.path.insert(0, os.path.join(ROOT, "src"))
    from repro import analysis
    from repro.api import RunSpec

    arts = []
    for p in paths:
        spec = RunSpec.from_json(open(p).read())
        rel = os.path.relpath(p, ROOT)
        print(f"lint_programs: analyzing {rel} "
              f"(mesh {spec.mesh.data}x{spec.mesh.model})")
        arts.extend(analysis.artifacts_for_spec(spec, rel))

    report = analysis.collect(arts)
    with open(args.out, "w") as f:
        f.write(analysis.dumps(report))
    print(f"lint_programs: wrote {args.out} "
          f"({len(report['programs'])} programs)")

    violations = [v for rep in report["programs"].values()
                  for v in rep["violations"]]
    for v in violations:
        print(f"FAIL {v}", file=sys.stderr)

    if args.update:
        os.makedirs(os.path.dirname(args.baseline), exist_ok=True)
        shutil.copyfile(args.out, args.baseline)
        print(f"lint_programs: baseline updated -> {args.baseline}")
        return 1 if violations else 0

    if not os.path.exists(args.baseline):
        print(f"missing baseline {args.baseline} — run with --update to "
              f"create it", file=sys.stderr)
        return 2
    baseline = json.load(open(args.baseline))
    failures, notes = analysis.compare(baseline, report,
                                       overrides=args.override)
    for n in notes:
        print(f"note {n}")
    for f_ in failures:
        print(f"FAIL regression {f_}", file=sys.stderr)
    if failures or violations:
        print("lint_programs: FAILED — fix the program or re-baseline "
              "deliberately with --update / widen with --override "
              "'PATTERN=TOL' (see README 'Static analysis & program "
              "gates')", file=sys.stderr)
        return 1
    print("lint_programs: all programs clean and within baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
