"""CI gate: every shipped spec/plan JSON round-trips byte-exactly.

``examples/specs/*.json`` are the golden run configurations users copy
from; the loaders (``repro.api.RunSpec``, ``repro.core.plan
.PrecisionPlan``) reject unknown fields and emit canonical JSON
(sorted keys, 2-space indent, trailing newline).  This checker pins both
directions: each shipped file must parse with the right loader AND
re-serialize to exactly the bytes on disk — so a schema change that
silently reinterprets or drops a field, or a hand-edit that drifts from
canonical form, fails CI instead of shipping.

File routing: ``plan_*.json`` are bare :class:`PrecisionPlan` width
tables (what ``--plan`` consumes); everything else is a full
:class:`RunSpec` (what ``--spec`` consumes).

Usage (CI lint job):  python tools/check_specs.py
Exit codes: 0 = clean, 1 = violations, 2 = bad invocation.
"""
from __future__ import annotations

import glob
import os
import sys
from typing import List

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SPECS = os.path.join(ROOT, "examples", "specs")
sys.path.insert(0, os.path.join(ROOT, "src"))


def check_file(path: str) -> List[str]:
    from repro.api.spec import RunSpec
    from repro.core.plan import PrecisionPlan

    rel = os.path.relpath(path, ROOT) if path.startswith(ROOT) else path
    is_plan = os.path.basename(path).startswith("plan_")
    loader = PrecisionPlan if is_plan else RunSpec
    with open(path) as f:
        raw = f.read()
    try:
        obj = loader.from_json(raw)
    except Exception as e:
        return [f"{rel}: does not parse as {loader.__name__}: "
                f"{type(e).__name__}: {e}"]
    out = obj.to_json()
    if out != raw:
        return [f"{rel}: not canonical {loader.__name__} JSON — "
                f"round-trip changes the bytes (regenerate with "
                f"`{loader.__name__}.from_file(p).save(p)`)"]
    return []


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        description="spec/plan JSON byte-exact round-trip gate")
    ap.add_argument("--specs-dir", default=SPECS)
    args = ap.parse_args(argv)
    if not os.path.isdir(args.specs_dir):
        print(f"missing {args.specs_dir}", file=sys.stderr)
        return 2
    files = sorted(glob.glob(os.path.join(args.specs_dir, "*.json")))
    if not files:
        print(f"no spec files under {args.specs_dir}", file=sys.stderr)
        return 2
    problems: List[str] = []
    for path in files:
        problems += check_file(path)
    for p in problems:
        print(f"FAIL {p}", file=sys.stderr)
    if problems:
        return 1
    print(f"check_specs: {len(files)} spec/plan files under "
          f"{os.path.relpath(args.specs_dir, ROOT)} round-trip byte-exactly")
    return 0


if __name__ == "__main__":
    sys.exit(main())
