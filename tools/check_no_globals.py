"""CI gate: source-level AST rules over src/repro.

Thin CLI over the ``repro.analysis.ast_rules`` registry (one rule per
invariant, same declarative shape as the compiled-program rules):

* ``no-global`` — no ``global`` statements (the RunSpec/RunContext
  redesign removed hidden trace-time globals; ``dist.scope.Scoped`` is
  the sanctioned mechanism);
* ``module-mutable`` — no module-level mutable-container bindings,
  including tuple-unpack (``a, b = [], {}``) and starred targets;
* ``inexact-bit-arith`` — no ``jnp.exp2``/``log2``/float-pow in the
  bit-exact modules (frexp/ldexp-exact helpers only);
* ``fixed-prngkey`` — no hardcoded ``PRNGKey(0)`` in library code;
* ``deprecated-shim-call`` — no calls to the deprecated ``set_*`` shims.

Allowlist entries are ``path::name`` (one binding) or ``path::*``
(whole file); a ``# lint: allow(<rule>)`` comment suppresses one line.

Usage (CI lint job):  python tools/check_no_globals.py
Exit codes: 0 = clean, 1 = violations, 2 = bad invocation.
"""
from __future__ import annotations

import os
import sys
from typing import List

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))

# path::name entries exempt from the module-level-mutable rule.  Keep
# this list SHORT and justified; the deprecated set_* shims do not need
# entries (they delegate to Scoped defaults, no module globals).
ALLOWLIST = frozenset({
    # import-time lookup tables, never mutated after module import:
    "src/repro/api/context.py::_DTYPES",         # dtype-name resolution
    "src/repro/configs/base.py::ALIASES",        # arch-id registry
    "src/repro/configs/base.py::SHAPES",         # assigned shape grid
    "src/repro/launch/roofline.py::_DTYPE_BYTES",
})


def check_tree(src_root: str, root: str = ROOT,
               allow: frozenset = ALLOWLIST) -> List[str]:
    from repro.analysis import check_source
    problems: List[str] = []
    for dirpath, _, files in os.walk(src_root):
        for fn in sorted(files):
            if not fn.endswith(".py"):
                continue
            path = os.path.join(dirpath, fn)
            rel = os.path.relpath(path, root).replace(os.sep, "/")
            with open(path) as f:
                problems += check_source(rel, f.read(), allow=allow)
    return problems


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        description="source-level AST rules over src/repro")
    ap.add_argument("--src", default=os.path.join(ROOT, "src", "repro"),
                    help="tree to check (paths in messages/allowlist "
                         "stay relative to its grandparent)")
    args = ap.parse_args(argv)
    if not os.path.isdir(args.src):
        print(f"missing {args.src}", file=sys.stderr)
        return 2
    # allowlist keys are relative to the directory holding `src/`
    root = os.path.dirname(os.path.dirname(os.path.abspath(args.src)))
    problems = check_tree(os.path.abspath(args.src), root=root)
    for p in problems:
        print(f"FAIL {p}", file=sys.stderr)
    if problems:
        return 1
    print("check_no_globals: src tree passes all source rules "
          "(no-global, module-mutable, inexact-bit-arith, fixed-prngkey, "
          "deprecated-shim-call)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
