"""CI gate: no new module-level mutable trace-time state in src/repro.

The RunSpec/RunContext redesign removed the hidden trace-time globals
(``set_axes``/``set_compute_dtype`` module state) in favor of the scoped
mechanism in ``repro/dist/scope.py``.  This checker keeps them out:

* any ``global`` statement in ``src/repro`` fails — mutating module
  state from a function is exactly the pattern that made jitted programs
  depend on ambient configuration (use ``dist.scope.Scoped`` instead);
* any module-level binding of a *mutable* container literal
  (``= []``, ``= {}``, ``= set()`` / ``dict()`` / ``list()``) fails —
  module-level caches/registries accumulate cross-run state (bind them
  inside a class or a ``Scoped`` default).

Allowlist entries are ``path::name`` (for assignments) or ``path::*``
(whole file), relative to the repo root.

Usage (CI lint job):  python tools/check_no_globals.py
Exit codes: 0 = clean, 1 = violations, 2 = bad invocation.
"""
from __future__ import annotations

import ast
import os
import sys
from typing import List

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src", "repro")

# path::name entries exempt from the module-level-mutable rule.  Keep
# this list SHORT and justified; the deprecated set_* shims do not need
# entries (they delegate to Scoped defaults, no module globals).
ALLOWLIST = frozenset({
    # import-time lookup tables, never mutated after module import:
    "src/repro/api/context.py::_DTYPES",         # dtype-name resolution
    "src/repro/configs/base.py::ALIASES",        # arch-id registry
    "src/repro/configs/base.py::SHAPES",         # assigned shape grid
    "src/repro/launch/roofline.py::_DTYPE_BYTES",
})

MUTABLE_CALLS = {"dict", "list", "set", "defaultdict", "OrderedDict",
                 "deque", "Counter"}


def _is_mutable_literal(node: ast.AST) -> bool:
    if isinstance(node, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                         ast.DictComp, ast.SetComp)):
        return True
    if isinstance(node, ast.Call):
        fn = node.func
        name = fn.id if isinstance(fn, ast.Name) else (
            fn.attr if isinstance(fn, ast.Attribute) else "")
        return name in MUTABLE_CALLS
    return False


def _targets(node: ast.AST) -> List[str]:
    if isinstance(node, ast.Assign):
        out = []
        for t in node.targets:
            if isinstance(t, ast.Name):
                out.append(t.id)
        return out
    if isinstance(node, ast.AnnAssign) and isinstance(node.target, ast.Name):
        return [node.target.id]
    return []


def check_file(path: str) -> List[str]:
    rel = os.path.relpath(path, ROOT)
    with open(path) as f:
        tree = ast.parse(f.read(), filename=rel)
    problems = []
    if f"{rel}::*" in ALLOWLIST:
        return problems
    # rule 1: no `global` statements anywhere in the module
    for node in ast.walk(tree):
        if isinstance(node, ast.Global):
            problems.append(
                f"{rel}:{node.lineno}: `global {', '.join(node.names)}` — "
                f"module-level mutable trace-time state; use "
                f"repro.dist.scope.Scoped")
    # rule 2: no module-level mutable-container bindings
    for node in tree.body:
        if isinstance(node, (ast.Assign, ast.AnnAssign)):
            value = node.value
            if value is None or not _is_mutable_literal(value):
                continue
            for name in _targets(node):
                if name.startswith("__") and name.endswith("__"):
                    continue  # dunder module attrs (__all__) are constants
                if f"{rel}::{name}" in ALLOWLIST:
                    continue
                problems.append(
                    f"{rel}:{node.lineno}: module-level mutable binding "
                    f"`{name}` — bind it in a class or a Scoped default")
    return problems


def main() -> int:
    if not os.path.isdir(SRC):
        print(f"missing {SRC}", file=sys.stderr)
        return 2
    problems: List[str] = []
    for dirpath, _, files in os.walk(SRC):
        for fn in sorted(files):
            if fn.endswith(".py"):
                problems += check_file(os.path.join(dirpath, fn))
    for p in problems:
        print(f"FAIL {p}", file=sys.stderr)
    if problems:
        return 1
    print("check_no_globals: src/repro is free of module-level mutable "
          "trace-time state")
    return 0


if __name__ == "__main__":
    sys.exit(main())
