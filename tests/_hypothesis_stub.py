"""Minimal, dependency-free stand-in for the `hypothesis` API this test
suite uses, loaded by conftest.py ONLY when the real package is absent
(this container cannot pip-install).  Deterministic: examples are drawn
from a per-test seeded PRNG, boundary values first.

Covers: @given, settings.register_profile/load_profile, and the
strategies floats/integers/lists/data.  Anything else raises loudly.
"""
from __future__ import annotations

import functools
import random
import zlib

import numpy as np

_DEFAULT_MAX_EXAMPLES = 25


class settings:  # noqa: N801 - mirrors hypothesis' class name
    _profiles = {"default": {"max_examples": _DEFAULT_MAX_EXAMPLES}}
    _current = dict(_profiles["default"])

    def __init__(self, **kwargs):
        self._kwargs = kwargs

    def __call__(self, fn):          # @settings(...) decorator form
        fn._stub_settings = self._kwargs
        return fn

    @classmethod
    def register_profile(cls, name, **kwargs):
        cls._profiles[name] = kwargs

    @classmethod
    def load_profile(cls, name):
        cls._current = dict(cls._profiles["default"])
        cls._current.update(cls._profiles.get(name, {}))


class _Strategy:
    def example(self, rng: random.Random):
        raise NotImplementedError

    def edges(self):
        return []


class _Floats(_Strategy):
    def __init__(self, min_value=-1e6, max_value=1e6, allow_nan=False,
                 allow_infinity=False, width=64):
        del allow_nan, allow_infinity  # never generated
        self.lo, self.hi, self.width = float(min_value), float(max_value), width

    def _cast(self, v):
        return float(np.float32(v)) if self.width == 32 else float(v)

    def example(self, rng):
        if rng.random() < 0.1:
            return self._cast(rng.choice(self.edges()))
        return self._cast(rng.uniform(self.lo, self.hi))

    def edges(self):
        es = [self.lo, self.hi]
        if self.lo <= 0.0 <= self.hi:
            es.append(0.0)
        if self.lo <= 1.0 <= self.hi:
            es.append(1.0)
        return [self._cast(e) for e in es]


class _Integers(_Strategy):
    def __init__(self, min_value=0, max_value=100):
        self.lo, self.hi = int(min_value), int(max_value)

    def example(self, rng):
        return rng.randint(self.lo, self.hi)

    def edges(self):
        return sorted({self.lo, self.hi, min(max(0, self.lo), self.hi)})


class _Lists(_Strategy):
    def __init__(self, elements, min_size=0, max_size=10):
        self.el, self.lo, self.hi = elements, int(min_size), int(max_size)

    def example(self, rng):
        n = rng.randint(self.lo, self.hi)
        return [self.el.example(rng) for _ in range(n)]

    def edges(self):
        rng = random.Random(0)
        return [[self.el.example(rng) for _ in range(max(self.lo, 1))]]


class _DataObject:
    def __init__(self, rng):
        self._rng = rng

    def draw(self, strategy, label=None):
        return strategy.example(self._rng)


class _Data(_Strategy):
    def example(self, rng):
        return _DataObject(rng)


class strategies:  # noqa: N801 - accessed as `strategies as st`
    @staticmethod
    def floats(*a, **k):
        return _Floats(*a, **k)

    @staticmethod
    def integers(*a, **k):
        return _Integers(*a, **k)

    @staticmethod
    def lists(*a, **k):
        return _Lists(*a, **k)

    @staticmethod
    def data():
        return _Data()


def given(*strats, **kw_strats):
    if kw_strats:
        raise NotImplementedError("stub @given supports positional "
                                  "strategies only")

    def deco(fn):
        n = settings._current.get("max_examples", _DEFAULT_MAX_EXAMPLES)
        n = getattr(fn, "_stub_settings", {}).get("max_examples", n)

        @functools.wraps(fn)
        def wrapper():
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            # boundary combinations first, then random draws
            edge_lists = [s.edges() or [s.example(rng)] for s in strats]
            n_edge = min(max(len(e) for e in edge_lists), 4)
            for i in range(n):
                if i < n_edge:
                    args = [e[i % len(e)] for e in edge_lists]
                    # data() edges are DataObject-free; redraw those live
                    args = [s.example(rng) if isinstance(s, _Data) else a
                            for s, a in zip(strats, args)]
                else:
                    args = [s.example(rng) for s in strats]
                try:
                    fn(*args)
                except Exception as e:
                    raise AssertionError(
                        f"{fn.__name__} falsified with args={args!r}: "
                        f"{e}") from e
        # hide the original signature from pytest's fixture resolution
        del wrapper.__wrapped__
        return wrapper

    return deco
