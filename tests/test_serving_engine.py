"""Continuous-batching engine correctness.

The engine's contract: a ragged workload (prompts of different lengths,
requests joining and leaving mid-run, fewer slots than requests) produces
token-for-token the same output as running ``generate()`` per request —
in fp and in the int8-packed serving mode.  ``cache_len`` pins the
reference's cache width to the engine's so masked-attention shapes match
exactly (documented tolerance for packed mode: argmax near-ties; on this
grid-exact EVAL path it is empirically exact).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get
from repro.core import hgq
from repro.models import model_for
from repro.serving import Engine, Request, SamplingConfig, generate

KEY = jax.random.PRNGKey(3)


def _ragged_requests(vocab, lens, max_news):
    reqs = []
    for i, (n, mn) in enumerate(zip(lens, max_news)):
        toks = jax.random.randint(jax.random.fold_in(KEY, i), (n,), 0, vocab)
        reqs.append(Request(prompt=[int(t) for t in toks], max_new=mn))
    return reqs


def _match_fraction(M, p, q, cfg, reqs, max_len, packed):
    total, match = 0, 0
    for r in reqs:
        ref = generate(M, p, q, cfg, jnp.asarray([r.prompt], jnp.int32),
                       r.max_new, cache_len=max_len, packed=packed)
        ref = [int(t) for t in np.asarray(ref)[0]]
        assert len(r.out) == len(ref)
        total += len(ref)
        match += sum(a == b for a, b in zip(r.out, ref))
    return match / total


@pytest.mark.parametrize("packed", [False, True])
def test_engine_matches_generate_ragged(packed):
    """6 ragged requests through 3 slots (join/leave mid-run) must equal
    per-request generate() token-for-token."""
    cfg = get("qwen2-0.5b", smoke=True)
    M = model_for(cfg)
    p, q = M.init(KEY, cfg)
    lens = [3, 5, 2, 7, 6, 4]
    max_news = [4, 3, 6, 2, 5, 4]
    reqs = _ragged_requests(cfg.vocab, lens, max_news)
    eng = Engine(M, p, q, cfg, batch_slots=3, max_len=32, prefill_chunk=4,
                 packed=packed)
    eng.run(reqs)
    assert all(r.done for r in reqs)
    frac = _match_fraction(M, p, q, cfg, reqs, 32, packed)
    if packed:
        assert frac >= 0.95, f"packed token match {frac}"
    else:
        assert frac == 1.0, f"fp token match {frac}"


def test_sliding_window_per_slot_cache():
    """Windowed (ring-buffer) per-slot caches: ragged prompts decoding past
    the attention window on a hybrid recurrent+local-attention model."""
    cfg = get("recurrentgemma-2b", smoke=True)   # window = 16
    M = model_for(cfg)
    p, q = M.init(KEY, cfg)
    lens = [3, 21, 9]                            # 21 + 8 decodes past W=16
    max_news = [12, 8, 10]
    reqs = _ragged_requests(cfg.vocab, lens, max_news)
    eng = Engine(M, p, q, cfg, batch_slots=2, max_len=40, prefill_chunk=8)
    eng.run(reqs)
    assert all(r.done for r in reqs)
    frac = _match_fraction(M, p, q, cfg, reqs, 40, packed=False)
    assert frac == 1.0, f"windowed ragged token match {frac}"


def test_packed_vs_fp_decode_closeness():
    """The int8-packed decode path must stay numerically close to fp: the
    EVAL-mode HGQ weights already sit on the 2^-f grid, so packing at the
    per-channel max-f is exact up to the int8 saturation cap."""
    from repro.serving.packed import pack_for_serving, packed_matmul
    cfg = get("qwen2-0.5b", smoke=True)
    M = model_for(cfg)
    p, q = M.init(KEY, cfg)
    pp, qq = pack_for_serving(p, q)
    B, S = 2, 6
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    cache = M.init_cache(cfg, B, 16)
    lg_fp, _ = M.decode_step(p, q, cache, toks, jnp.int32(0), cfg,
                             mode=hgq.EVAL)
    with packed_matmul(True):
        lg_pk, _ = M.decode_step(pp, qq, cache, toks,
                                 jnp.zeros((B,), jnp.int32), cfg,
                                 mode=hgq.EVAL)
    a = np.asarray(lg_fp, np.float32)
    b = np.asarray(lg_pk, np.float32)
    rms = float(np.sqrt(np.mean(a * a)))
    assert float(np.max(np.abs(a - b))) <= 0.05 * max(rms, 1.0)
    assert np.mean(a.argmax(-1) == b.argmax(-1)) > 0.99


def test_engine_sampling_modes():
    """Greedy and temperature/top-k requests coexist in one batch; sampled
    tokens are valid ids and sampled runs differ across seeds."""
    cfg = get("qwen2-0.5b", smoke=True)
    M = model_for(cfg)
    p, q = M.init(KEY, cfg)

    def run(seed):
        reqs = _ragged_requests(cfg.vocab, [4, 3], [8, 8])
        reqs[1].sampling = SamplingConfig(temperature=1.5, top_k=8)
        eng = Engine(M, p, q, cfg, batch_slots=2, max_len=32, seed=seed)
        eng.run(reqs)
        return reqs

    a, b = run(0), run(1)
    for reqs in (a, b):
        assert all(r.done for r in reqs)
        assert all(0 <= t < cfg.vocab for r in reqs for t in r.out)
    # greedy slot is seed-independent, sampled slot is (overwhelmingly) not
    assert a[0].out == b[0].out
    assert a[1].out != b[1].out


def test_engine_recycles_slots_and_eos():
    cfg = get("qwen2-0.5b", smoke=True)
    M = model_for(cfg)
    p, q = M.init(KEY, cfg)
    eng = Engine(M, p, q, cfg, batch_slots=2, max_len=32)
    reqs = _ragged_requests(cfg.vocab, [3, 3, 3, 3, 3], [3, 3, 3, 3, 3])
    eng.run(reqs)
    assert all(r.done and len(r.out) == 3 for r in reqs)
    assert all(r is None for r in eng.slot_req)
    # oversubmission returns False once slots are full
    eng2 = Engine(M, p, q, cfg, batch_slots=1, max_len=32)
    r1 = Request(prompt=[1, 2], max_new=8)
    assert eng2.submit(r1) is True
    assert eng2.submit(Request(prompt=[3], max_new=2)) is False


def test_qmatmul_backend_interpret_default():
    from repro.kernels.qmatmul.ops import default_interpret
    # this suite runs on CPU: the Pallas kernel must select interpret mode
    assert jax.default_backend() == "cpu"
    assert default_interpret() is True
