"""Continuous-batching engine correctness.

The engine's contract: a ragged workload (prompts of different lengths,
requests joining and leaving mid-run, fewer slots than requests) produces
token-for-token the same output as running ``generate()`` per request —
in fp and in the int8-packed serving mode.  ``cache_len`` pins the
reference's cache width to the engine's so masked-attention shapes match
exactly (documented tolerance for packed mode: argmax near-ties; on this
grid-exact EVAL path it is empirically exact).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get
from repro.core import hgq
from repro.models import model_for
from repro.serving import Engine, Request, SamplingConfig, generate

KEY = jax.random.PRNGKey(3)


def _ragged_requests(vocab, lens, max_news):
    reqs = []
    for i, (n, mn) in enumerate(zip(lens, max_news)):
        toks = jax.random.randint(jax.random.fold_in(KEY, i), (n,), 0, vocab)
        reqs.append(Request(prompt=[int(t) for t in toks], max_new=mn))
    return reqs


def _match_fraction(M, p, q, cfg, reqs, max_len, packed):
    total, match = 0, 0
    for r in reqs:
        ref = generate(M, p, q, cfg, jnp.asarray([r.prompt], jnp.int32),
                       r.max_new, cache_len=max_len, packed=packed)
        ref = [int(t) for t in np.asarray(ref)[0]]
        assert len(r.out) == len(ref)
        total += len(ref)
        match += sum(a == b for a, b in zip(r.out, ref))
    return match / total


@pytest.mark.parametrize("packed", [False, True])
def test_engine_matches_generate_ragged(packed):
    """6 ragged requests through 3 slots (join/leave mid-run) must equal
    per-request generate() token-for-token."""
    cfg = get("qwen2-0.5b", smoke=True)
    M = model_for(cfg)
    p, q = M.init(KEY, cfg)
    lens = [3, 5, 2, 7, 6, 4]
    max_news = [4, 3, 6, 2, 5, 4]
    reqs = _ragged_requests(cfg.vocab, lens, max_news)
    eng = Engine(M, p, q, cfg, batch_slots=3, max_len=32, prefill_chunk=4,
                 packed=packed)
    eng.run(reqs)
    assert all(r.done for r in reqs)
    frac = _match_fraction(M, p, q, cfg, reqs, 32, packed)
    if packed:
        assert frac >= 0.95, f"packed token match {frac}"
    else:
        assert frac == 1.0, f"fp token match {frac}"


def test_sliding_window_per_slot_cache():
    """Windowed (ring-buffer) per-slot caches: ragged prompts decoding past
    the attention window on a hybrid recurrent+local-attention model."""
    cfg = get("recurrentgemma-2b", smoke=True)   # window = 16
    M = model_for(cfg)
    p, q = M.init(KEY, cfg)
    lens = [3, 21, 9]                            # 21 + 8 decodes past W=16
    max_news = [12, 8, 10]
    reqs = _ragged_requests(cfg.vocab, lens, max_news)
    eng = Engine(M, p, q, cfg, batch_slots=2, max_len=40, prefill_chunk=8)
    eng.run(reqs)
    assert all(r.done for r in reqs)
    frac = _match_fraction(M, p, q, cfg, reqs, 40, packed=False)
    assert frac == 1.0, f"windowed ragged token match {frac}"


def test_packed_vs_fp_decode_closeness():
    """The int8-packed decode path must stay numerically close to fp: the
    EVAL-mode HGQ weights already sit on the 2^-f grid, so packing at the
    per-channel max-f is exact up to the int8 saturation cap."""
    from repro.serving.packed import pack_for_serving, packed_matmul
    cfg = get("qwen2-0.5b", smoke=True)
    M = model_for(cfg)
    p, q = M.init(KEY, cfg)
    pp, qq = pack_for_serving(p, q)
    B, S = 2, 6
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    cache = M.init_cache(cfg, B, 16)
    lg_fp, _ = M.decode_step(p, q, cache, toks, jnp.int32(0), cfg,
                             mode=hgq.EVAL)
    with packed_matmul(True):
        lg_pk, _ = M.decode_step(pp, qq, cache, toks,
                                 jnp.zeros((B,), jnp.int32), cfg,
                                 mode=hgq.EVAL)
    a = np.asarray(lg_fp, np.float32)
    b = np.asarray(lg_pk, np.float32)
    rms = float(np.sqrt(np.mean(a * a)))
    assert float(np.max(np.abs(a - b))) <= 0.05 * max(rms, 1.0)
    assert np.mean(a.argmax(-1) == b.argmax(-1)) > 0.99


def test_engine_sampling_modes():
    """Greedy and temperature/top-k requests coexist in one batch; sampled
    tokens are valid ids and sampled runs differ across seeds."""
    cfg = get("qwen2-0.5b", smoke=True)
    M = model_for(cfg)
    p, q = M.init(KEY, cfg)

    def run(seed):
        reqs = _ragged_requests(cfg.vocab, [4, 3], [8, 8])
        reqs[1].sampling = SamplingConfig(temperature=1.5, top_k=8)
        eng = Engine(M, p, q, cfg, batch_slots=2, max_len=32, seed=seed)
        eng.run(reqs)
        return reqs

    a, b = run(0), run(1)
    for reqs in (a, b):
        assert all(r.done for r in reqs)
        assert all(0 <= t < cfg.vocab for r in reqs for t in r.out)
    # greedy slot is seed-independent, sampled slot is (overwhelmingly) not
    assert a[0].out == b[0].out
    assert a[1].out != b[1].out


def test_engine_recycles_slots_and_eos():
    cfg = get("qwen2-0.5b", smoke=True)
    M = model_for(cfg)
    p, q = M.init(KEY, cfg)
    eng = Engine(M, p, q, cfg, batch_slots=2, max_len=32)
    reqs = _ragged_requests(cfg.vocab, [3, 3, 3, 3, 3], [3, 3, 3, 3, 3])
    eng.run(reqs)
    assert all(r.done and len(r.out) == 3 for r in reqs)
    assert all(r is None for r in eng.slot_req)
    # oversubmission returns a falsy None once slots are full; admission
    # returns a truthy handle
    eng2 = Engine(M, p, q, cfg, batch_slots=1, max_len=32)
    r1 = Request(prompt=[1, 2], max_new=8)
    assert eng2.submit(r1)
    assert eng2.submit(Request(prompt=[3], max_new=2)) is None


def _token_match(a_reqs, b_reqs):
    total = sum(len(r.out) for r in a_reqs)
    match = sum(x == y for ra, rb in zip(a_reqs, b_reqs)
                for x, y in zip(ra.out, rb.out))
    return match / total


@pytest.mark.parametrize("packed,kv_bits", [(False, 8), (True, 8)])
def test_quantized_kv_close_to_fp_ragged(packed, kv_bits):
    """Quantized-KV decode must track the fp cache on ragged continuous
    batches (chunked prefill at different slot offsets, join/leave):
    identical engines except kv_bits, token agreement stays high and
    output shape/termination identical.  (4-bit numerics are pinned
    teacher-forced in ``test_quantized_kv_logits_close_teacher_forced``
    — trajectory
    matching compounds every argmax flip, which on random smoke weights
    measures divergence, not quantization error.)"""
    cfg = get("qwen2-0.5b", smoke=True)
    M = model_for(cfg)
    p, q = M.init(KEY, cfg)
    lens = [3, 5, 2, 7, 6, 4]
    max_news = [4, 3, 6, 2, 5, 4]

    def serve(bits):
        reqs = _ragged_requests(cfg.vocab, lens, max_news)
        eng = Engine(M, p, q, cfg, batch_slots=3, max_len=32,
                     prefill_chunk=4, packed=packed, kv_bits=bits)
        eng.run(reqs)
        assert all(r.done for r in reqs)
        return reqs

    fp, qz = serve(None), serve(kv_bits)
    frac = _token_match(fp, qz)
    assert frac >= 0.8, f"kv_bits={kv_bits} token match {frac}"


@pytest.mark.parametrize("kv_bits", [8])
def test_quantized_kv_ring_wrap_past_window(kv_bits):
    """The quantized ring buffer must wrap exactly like the fp one:
    windowed model, prompts past the window, decode past it again — the
    newest-wins scatter and tpos masking run on the int8 buffers.
    (Nibble-width wrap numerics are pinned teacher-forced below.)"""
    cfg = get("recurrentgemma-2b", smoke=True)   # window = 16
    M = model_for(cfg)
    p, q = M.init(KEY, cfg)
    lens = [3, 21, 9]                            # 21 + 8 decodes past W=16
    max_news = [12, 8, 10]

    def serve(bits):
        reqs = _ragged_requests(cfg.vocab, lens, max_news)
        eng = Engine(M, p, q, cfg, batch_slots=2, max_len=40,
                     prefill_chunk=8, kv_bits=bits)
        eng.run(reqs)
        assert all(r.done for r in reqs)
        return reqs

    fp, qz = serve(None), serve(kv_bits)
    frac = _token_match(fp, qz)
    # a single argmax flip diverges the rest of that request's stream,
    # and post-wrap the cache is entirely quantized history — 0.6 pins
    # the wrap *mechanism* (far above chance); numerics are pinned
    # teacher-forced below
    assert frac >= 0.6, f"kv_bits={kv_bits} ring-wrap token match {frac}"


@pytest.mark.parametrize("arch,kv_bits,rel_max,agree_min", [
    ("qwen2-0.5b", 8, 0.08, 0.9),
    ("qwen2-0.5b", 4, 0.30, 0.6),
    ("recurrentgemma-2b", 4, 0.25, 0.65),   # decode wraps past window=16
])
def test_quantized_kv_logits_close_teacher_forced(arch, kv_bits, rel_max,
                                                  agree_min):
    """Per-step quantization error of the quantized cache, measured
    teacher-forced: both caches consume the SAME fp-greedy token stream,
    so argmax flips cannot compound into trajectory divergence and the
    comparison isolates cache error.  Logits stay relatively close and
    greedy choices mostly agree — incl. nibble widths, and ring-wrap on
    the windowed arch (prompt 5 + 20 steps > window 16)."""
    cfg = get(arch, smoke=True)
    M = model_for(cfg)
    p, q = M.init(KEY, cfg)
    B, plen, steps, max_len = 2, 5, 20, 32
    toks = jax.random.randint(KEY, (B, plen), 1, cfg.vocab)
    cfp = M.init_cache(cfg, B, max_len)
    cqz = M.init_cache(cfg, B, max_len, kv_bits=kv_bits)
    lf, cfp = M.decode_step(p, q, cfp, toks, jnp.int32(0), cfg,
                            mode=hgq.EVAL)
    lq, cqz = M.decode_step(p, q, cqz, toks, jnp.int32(0), cfg,
                            mode=hgq.EVAL, kv_bits=kv_bits)
    rels, agrees = [], []
    for t in range(steps):
        a = np.asarray(lf[:, -1], np.float32)
        b = np.asarray(lq[:, -1], np.float32)
        rels.append(np.linalg.norm(a - b) / np.linalg.norm(a))
        agrees.append(np.mean(a.argmax(-1) == b.argmax(-1)))
        tok = jnp.asarray(a.argmax(-1)[:, None], jnp.int32)  # fp-greedy
        pos = jnp.int32(plen + t)
        lf, cfp = M.decode_step(p, q, cfp, tok, pos, cfg, mode=hgq.EVAL)
        lq, cqz = M.decode_step(p, q, cqz, tok, pos, cfg, mode=hgq.EVAL,
                                kv_bits=kv_bits)
    rel, agree = float(np.mean(rels)), float(np.mean(agrees))
    assert rel <= rel_max, f"kv_bits={kv_bits} mean rms-rel {rel}"
    assert agree >= agree_min, f"kv_bits={kv_bits} argmax agree {agree}"


def test_handle_surface_equals_run():
    """submit()+tokens(handle) must produce token-for-token what run()
    produces on the same workload — the handle surface is a reader over
    the same engine, not a different scheduler."""
    cfg = get("qwen2-0.5b", smoke=True)
    M = model_for(cfg)
    p, q = M.init(KEY, cfg)
    lens, max_news = [3, 5, 2], [4, 3, 6]
    run_reqs = _ragged_requests(cfg.vocab, lens, max_news)
    Engine(M, p, q, cfg, batch_slots=3, max_len=32).run(run_reqs)
    eng = Engine(M, p, q, cfg, batch_slots=3, max_len=32)
    handles = [eng.submit(r) for r in
               _ragged_requests(cfg.vocab, lens, max_news)]
    assert all(handles)
    for h, r in zip(handles, run_reqs):
        assert list(eng.tokens(h)) == r.out
        assert h.done and h.out == r.out
    # an incremental reader sees the same stream one token at a time
    eng2 = Engine(M, p, q, cfg, batch_slots=3, max_len=32)
    h = eng2.submit(Request(prompt=list(run_reqs[0].prompt), max_new=4))
    it = eng2.tokens(h)
    assert [next(it) for _ in range(4)] == run_reqs[0].out


@pytest.mark.parametrize("kv_bits", [None, 8])
def test_recycled_slot_matches_fresh_engine(kv_bits):
    """Slot-recycling regression: after a long-sequence tenant finishes,
    the recycled slot (including the quantized cache's kf/vf scale
    state) must decode a new request token-for-token like a fresh
    engine — stale grid exponents in the ring would skew the dequant."""
    cfg = get("qwen2-0.5b", smoke=True)
    M = model_for(cfg)
    p, q = M.init(KEY, cfg)
    long_req = _ragged_requests(cfg.vocab, [9], [14])[0]
    probe = _ragged_requests(cfg.vocab, [4], [6])[0]
    eng = Engine(M, p, q, cfg, batch_slots=1, max_len=32,
                 kv_bits=kv_bits)
    eng.run([long_req])
    assert long_req.done and eng.slot_req == [None]
    recycled = Request(prompt=list(probe.prompt), max_new=probe.max_new)
    eng.run([recycled])
    fresh_eng = Engine(M, p, q, cfg, batch_slots=1, max_len=32,
                       kv_bits=kv_bits)
    fresh = Request(prompt=list(probe.prompt), max_new=probe.max_new)
    fresh_eng.run([fresh])
    assert recycled.out == fresh.out


def test_prefix_reuse_token_identical():
    """prefix_reuse must be invisible in outputs: resubmitting the same
    prompt serves from the cached prefill slice, token-for-token."""
    cfg = get("qwen2-0.5b", smoke=True)
    M = model_for(cfg)
    p, q = M.init(KEY, cfg)
    prompt = [int(t) for t in
              jax.random.randint(KEY, (6,), 0, cfg.vocab)]
    eng = Engine(M, p, q, cfg, batch_slots=1, max_len=32,
                 prefix_reuse=True)
    a = Request(prompt=list(prompt), max_new=5)
    b = Request(prompt=list(prompt), max_new=5)
    eng.run([a])
    eng.run([b])
    assert a.out == b.out
    assert tuple(prompt) in eng._prefix_cache


def test_prefix_lru_eviction_and_refresh():
    """Bounded prefix cache under capacity pressure: filling past
    ``_prefix_cap`` evicts the least-recently-used entry, and a cache
    hit refreshes recency so the eviction victim is the true LRU."""
    cfg = get("qwen2-0.5b", smoke=True)
    M = model_for(cfg)
    p, q = M.init(KEY, cfg)
    eng = Engine(M, p, q, cfg, batch_slots=1, max_len=32,
                 prefix_reuse=True)
    eng._prefix_cap = 3
    prompts = [[1 + i, 7, 3 + i] for i in range(4)]
    for pr in prompts[:3]:
        eng.run([Request(prompt=list(pr), max_new=2)])
    assert [list(k) for k in eng._prefix_cache] == prompts[:3]
    # hit prompt 0 -> refreshed to most-recent; prompt 1 becomes LRU
    eng.run([Request(prompt=list(prompts[0]), max_new=2)])
    assert next(iter(eng._prefix_cache)) == tuple(prompts[1])
    # a 4th distinct prompt evicts prompt 1, not the refreshed prompt 0
    eng.run([Request(prompt=list(prompts[3]), max_new=2)])
    assert len(eng._prefix_cache) == 3
    assert tuple(prompts[1]) not in eng._prefix_cache
    assert tuple(prompts[0]) in eng._prefix_cache
    assert tuple(prompts[3]) in eng._prefix_cache


def test_prefix_reuse_across_recycled_slots_matches_cold():
    """A prefix served from the cache into a *recycled* slot must be
    token-for-token what a cold prefill produces — and must actually
    skip the prefill (counted), not just happen to agree."""
    cfg = get("qwen2-0.5b", smoke=True)
    M = model_for(cfg)
    p, q = M.init(KEY, cfg)
    prompt = [int(t) for t in
              jax.random.randint(KEY, (6,), 0, cfg.vocab)]
    other = [int(t) for t in
             jax.random.randint(jax.random.fold_in(KEY, 1), (4,), 0,
                                cfg.vocab)]
    eng = Engine(M, p, q, cfg, batch_slots=1, max_len=32,
                 prefix_reuse=True)
    calls = []
    inner = eng._prefill_prompt

    def counting(*a, **kw):
        calls.append(1)
        return inner(*a, **kw)

    eng._prefill_prompt = counting
    first = Request(prompt=list(prompt), max_new=5)
    eng.run([first])                                  # cold prefill
    eng.run([Request(prompt=list(other), max_new=3)])  # recycle slot 0
    reused = Request(prompt=list(prompt), max_new=5)
    eng.run([reused])                                 # cache hit
    assert len(calls) == 2, "reuse path ran a third prefill"
    assert reused.out == first.out
    cold_eng = Engine(M, p, q, cfg, batch_slots=1, max_len=32)
    cold = Request(prompt=list(prompt), max_new=5)
    cold_eng.run([cold])
    assert reused.out == cold.out


def test_qmatmul_backend_interpret_default():
    from repro.kernels.qmatmul.ops import default_interpret
    # this suite runs on CPU: the Pallas kernel must select interpret mode
    assert jax.default_backend() == "cpu"
    assert default_interpret() is True
