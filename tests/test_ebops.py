"""EBOPs accounting tests (paper Eq. 5, SSec. III.C)."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.ebops import ebops_conv2d, ebops_dyn_matmul, ebops_matmul

settings.register_profile("ci", max_examples=40, deadline=None)
settings.load_profile("ci")

dims = st.integers(min_value=1, max_value=9)


@given(dims, dims, st.data())
def test_ebops_matmul_matches_bruteforce(din, dout, data):
    bx = jnp.asarray(data.draw(st.lists(
        st.floats(0, 16, width=32), min_size=din, max_size=din)), jnp.float32)
    bw = jnp.asarray(data.draw(st.lists(
        st.lists(st.floats(0, 16, width=32), min_size=dout, max_size=dout),
        min_size=din, max_size=din)), jnp.float32)
    want = float(jnp.sum(bx[:, None] * bw))
    got = float(ebops_matmul(bx, bw, din, dout))
    np.testing.assert_allclose(got, want, rtol=1e-5)


@given(dims, dims)
def test_ebops_matmul_broadcast_forms(din, dout):
    """scalar / per-channel / full bit tensors must agree when constant."""
    full = jnp.full((din, dout), 5.0)
    chan = jnp.full((1, dout), 5.0)
    scal = jnp.float32(5.0)
    bx = jnp.full((din,), 3.0)
    want = 15.0 * din * dout
    for bw in (full, chan, scal):
        got = float(ebops_matmul(bx, bw, din, dout))
        np.testing.assert_allclose(got, want, rtol=1e-5)
    # scalar activation bits too
    got = float(ebops_matmul(jnp.float32(3.0), full, din, dout))
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_ebops_conv2d():
    kh, kw, cin, cout = 3, 3, 4, 8
    bw = jnp.full((kh, kw, cin, cout), 4.0)
    bx = jnp.full((cin,), 6.0)
    want = 24.0 * kh * kw * cin * cout
    np.testing.assert_allclose(float(ebops_conv2d(bx, bw, (kh, kw, cin, cout))),
                               want, rtol=1e-6)
    # per-tensor forms
    np.testing.assert_allclose(
        float(ebops_conv2d(jnp.float32(6.0), jnp.float32(4.0),
                           (kh, kw, cin, cout))), want, rtol=1e-6)


def test_ebops_dyn_matmul():
    m, k, n = 4, 6, 5
    ba = jnp.arange(m * k, dtype=jnp.float32).reshape(m, k) % 7
    bb = jnp.arange(k * n, dtype=jnp.float32).reshape(k, n) % 5
    want = float(sum(ba[i, kk] * bb[kk, j]
                     for i in range(m) for kk in range(k) for j in range(n)))
    np.testing.assert_allclose(float(ebops_dyn_matmul(ba, bb, (m, k), (k, n))),
                               want, rtol=1e-5)
    # scalar bits
    np.testing.assert_allclose(
        float(ebops_dyn_matmul(jnp.float32(3), jnp.float32(2), (m, k), (k, n))),
        6.0 * m * k * n, rtol=1e-6)


def test_jet_ebops_magnitude():
    """Full-precision-ish jet model ~EBOPs lands in the plausible range of
    the paper's Table I EBOPs scale (10^2-10^5)."""
    import jax
    from repro.models import JetTagger
    from repro.nn import HGQConfig
    cfg = HGQConfig(weight_gran="per_parameter", act_gran="per_parameter",
                    init_weight_f=2, init_act_f=2)
    p, q = JetTagger.init(jax.random.PRNGKey(0), cfg)
    out, _, aux = JetTagger.forward(p, q,
                                    {"x": jax.random.normal(
                                        jax.random.PRNGKey(1), (32, 16))})
    assert 1e2 < float(aux.ebops) < 1e6
