"""Unit tests for the repro.dist subsystem itself (axes / sharding / perf /
error-feedback compression) — the sharding *rule* tests against fake meshes
live in test_recurrent_sharding.py; this file covers the rest of the
contract."""
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist import EFState, ef_compress, ef_init
from repro.dist.axes import (AxisRegistry, axis_scope, constrain,
                             get_model_size)
from repro.dist.perf import (cast_for_matmul, compute_dtype_scope,
                             get_compute_dtype, pack_params_for_serving,
                             unpack_weight)
from repro.dist.sharding import spec_for_param, shard_tree, stacked_tree


class _FakeMesh:
    axis_names = ("data", "model")
    devices = types.SimpleNamespace(shape=(16, 16))


# ------------------------------- axes --------------------------------------

def test_constrain_identity_on_single_device():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 2, 16))
    for pat in ("b.m.", "b...", "....", ".bm."[:4]):
        y = constrain(x, pat)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
    # and it is jit-traceable as an identity
    y = jax.jit(lambda v: constrain(v, "b.m."))(x)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


def test_constrain_pattern_validation():
    x = jnp.zeros((2, 3))
    with pytest.raises(ValueError):
        constrain(x, "b.m")        # rank mismatch
    with pytest.raises(ValueError):
        constrain(x, "bx")         # unknown axis char


def test_axes_scope_roundtrip():
    """axis_scope binds the registry for the dynamic extent only — and
    nests (inner scope wins, outer restored)."""
    assert get_model_size() == 1
    with axis_scope(AxisRegistry(("pod", "data"), "model", 32, 16)):
        assert get_model_size() == 16
        with axis_scope(AxisRegistry(("data",), "model", 2, 4)):
            assert get_model_size() == 4
        assert get_model_size() == 16
    assert get_model_size() == 1


# ----------------------------- sharding ------------------------------------

class K:
    def __init__(self, key):
        self.key = key


def _spec(path, shape, mode="train"):
    return spec_for_param([K(k) for k in path], shape, _FakeMesh(), mode)


def test_spec_low_rank_replicates():
    assert _spec(("bias", "w"), (4864,)) == P(None)
    assert _spec(("out_f",), ()) == P()


def test_spec_square_tie_prefers_last_axis():
    assert _spec(("kernel", "w"), (1024, 1024)) == P("data", "model")


def test_spec_per_channel_f_leaf():
    # (1, N) fractional-bit tensors: broadcast axis replicates, N -> model
    assert _spec(("kernel", "f"), (1, 4864)) == P(None, "model")


def test_spec_serve_mode_non_divisible():
    assert _spec(("kernel", "w"), (7, 13), mode="serve") == P(None, None)


def test_spec_bad_mode_raises():
    with pytest.raises(ValueError):
        _spec(("kernel", "w"), (8, 8), mode="decode")


def test_spec_from_real_tree_paths():
    """spec_for_param must understand tree_flatten_with_path key types
    (DictKey etc.), not just the fake .key records."""
    tree = {"kernel": {"w": jax.ShapeDtypeStruct((896, 4864), jnp.float32),
                       "f": jax.ShapeDtypeStruct((1, 4864), jnp.float32)},
            "bias": {"w": jax.ShapeDtypeStruct((4864,), jnp.float32)},
            "step": jax.ShapeDtypeStruct((), jnp.int32)}
    flat = dict(jax.tree_util.tree_flatten_with_path(tree)[0])
    specs = {tuple(str(getattr(k, "key", k)) for k in path):
             spec_for_param(path, leaf.shape, _FakeMesh(), "train")
             for path, leaf in flat.items()}
    assert specs[("kernel", "w")] == P("data", "model")
    assert specs[("kernel", "f")] == P(None, "model")
    assert specs[("bias", "w")] == P(None)
    assert specs[("step",)] == P()


def test_shard_tree_on_real_mesh():
    """On the 1x1 host mesh everything replicates (axis size 1 never
    shards) but the NamedSharding tree must build and jit-apply."""
    from jax.sharding import NamedSharding
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tree = {"kernel": {"w": jnp.zeros((8, 16)), "f": jnp.zeros((1, 16))}}
    sh = shard_tree(tree, mesh, "train")
    assert all(isinstance(s, NamedSharding)
               for s in jax.tree.leaves(sh))
    assert sh["kernel"]["w"].spec == P(None, None)
    with mesh:
        out = jax.jit(lambda t: t, in_shardings=(sh,))(tree)
    assert out["kernel"]["w"].shape == (8, 16)


# ------------------------------- perf --------------------------------------

def test_compute_dtype_cast():
    assert get_compute_dtype() is None
    x = jnp.ones((3, 3), jnp.float32)
    ids = jnp.ones((3,), jnp.int32)
    assert cast_for_matmul(x).dtype == jnp.float32
    with compute_dtype_scope(jnp.bfloat16):
        assert cast_for_matmul(x).dtype == jnp.bfloat16
        assert cast_for_matmul(ids).dtype == jnp.int32  # ints untouched
    assert cast_for_matmul(x).dtype == jnp.float32


def test_pack_unpack_roundtrip_on_grid():
    """Weights already on the 2^-f grid survive packing exactly."""
    key = jax.random.PRNGKey(1)
    f = 6.0
    # keep |w| < 127 * 2^-f so the int8 mantissa never saturates
    w = jnp.round(jnp.clip(jax.random.normal(key, (32, 16)) * 0.5,
                           -1.9, 1.9) * 2.0 ** f) / 2.0 ** f
    p = {"kernel": {"w": w, "f": jnp.full((32, 16), f)},
         "bias": {"w": jnp.zeros((16,))}}
    packed = pack_params_for_serving(p)
    assert packed["kernel"]["w_int8"].dtype == jnp.int8
    assert "w" in packed["bias"], "biases must not be packed"
    got = unpack_weight(packed["kernel"])
    np.testing.assert_allclose(np.asarray(got), np.asarray(w), atol=1e-7)


def test_pack_never_saturates_large_weights():
    """Per-parameter f can put >8 bits in one column (regression: the
    column-max grid clipped w=2.0 at f=[2,9] to 127 * 2^-9 = 0.248 — an
    8x silent error on the *large* weight).  The exponent must cap so big
    weights stay exact and only sub-grid small ones floor."""
    w = jnp.array([[2.0], [0.001953125]])          # 2^1 and 2^-9
    f = jnp.array([[2.0], [9.0]])
    packed = pack_params_for_serving({"k": {"w": w, "f": f}})["k"]
    got = unpack_weight(packed)
    step = float(packed["scale"].max())
    assert abs(float(got[0, 0]) - 2.0) <= step / 2, float(got[0, 0])
    assert abs(float(got[1, 0])) <= step            # floored, not exploded
    # homogeneous f with int bits beyond 8 total: w=3.0 at f=6 needs 192
    w2 = jnp.array([[3.0], [-3.0]])
    p2 = pack_params_for_serving({"k": {"w": w2, "f": jnp.full((2, 1), 6.0)}})
    got2 = unpack_weight(p2["k"])
    np.testing.assert_allclose(np.asarray(got2), np.asarray(w2),
                               atol=float(p2["k"]["scale"].max()) / 2)


def test_pack_skips_conv_kernels():
    p = {"kernel": {"w": jnp.zeros((3, 3, 4, 8)), "f": jnp.zeros(())}}
    packed = pack_params_for_serving(p)
    assert "w" in packed["kernel"] and "w_int8" not in packed["kernel"]


def test_pack_is_eval_shape_traceable():
    abs_p = {"kernel": {"w": jax.ShapeDtypeStruct((8, 4), jnp.float32),
                        "f": jax.ShapeDtypeStruct((1, 4), jnp.float32)}}
    out = jax.eval_shape(pack_params_for_serving, abs_p)
    assert out["kernel"]["w_int8"].shape == (8, 4)
    assert out["kernel"]["w_int8"].dtype == jnp.int8


def test_packed_weights_flow_through_get_qw():
    from repro.nn.common import get_qw
    from repro.core import hgq
    w = jnp.round(jax.random.normal(jax.random.PRNGKey(2), (16, 8)) * 4) / 4
    p = {"kernel": {"w": w, "f": jnp.full((16, 8), 2.0)}}
    qt_ref = get_qw(p["kernel"], hgq.EVAL)
    qt_packed = get_qw(pack_params_for_serving(p)["kernel"], hgq.EVAL)
    np.testing.assert_allclose(np.asarray(qt_packed.q), np.asarray(qt_ref.q),
                               atol=1e-6)


# ----------------------- plan-width serving packing ------------------------

def _grid_params(shape=(32, 16), f=4.0):
    """A matmul weight already on the 2^-f grid, small enough that no
    width's channel cap saturates."""
    key = jax.random.PRNGKey(5)
    w = jnp.round(jnp.clip(jax.random.normal(key, shape) * 0.2, -0.9, 0.9)
                  * 2.0 ** f) / 2.0 ** f
    return {"kernel": {"w": w, "f": jnp.full(shape, f)}}


def test_pack_with_plan_nibble_storage_and_roundtrip():
    """A w4 plan layer stores two mantissas per byte along K; the accessor
    recovers full-width int4-range mantissas and dequant stays within half
    a step of the original weights."""
    from repro.core.plan import LayerPlan, PrecisionPlan
    from repro.dist.perf import is_packed, packed_mantissas
    p = _grid_params()
    plan = PrecisionPlan(layers={"kernel": LayerPlan(wire_bits=4,
                                                     pack_bits=4)})
    packed = pack_params_for_serving(p, plan)["kernel"]
    assert "w_nib" in packed and "w_int8" not in packed
    assert packed["w_nib"].shape == (16, 16)       # K halves
    assert is_packed(packed)
    m = packed_mantissas(packed)
    assert m.shape == (32, 16)
    assert int(jnp.max(jnp.abs(m))) <= 7
    got = unpack_weight(packed)
    err = np.abs(np.asarray(got) - np.asarray(p["kernel"]["w"]))
    step = np.asarray(packed["scale"]).reshape(1, -1)
    assert (err <= step / 2 + 1e-7).all()


def test_packed_nbytes_nibble_halves_mantissa_bytes():
    from repro.core.plan import LayerPlan, PrecisionPlan
    from repro.serving.packed import packed_nbytes
    p = _grid_params()
    plan4 = PrecisionPlan(layers={"kernel": LayerPlan(wire_bits=4,
                                                      pack_bits=4)})
    p8 = pack_params_for_serving(p)
    p4 = pack_params_for_serving(p, plan4)
    assert p4["kernel"]["w_nib"].nbytes \
        == p8["kernel"]["w_int8"].nbytes // 2
    # scales and f pass through identically, so the tree totals differ
    # by exactly the halved mantissa payload
    assert packed_nbytes(p8) - packed_nbytes(p4) \
        == p8["kernel"]["w_int8"].nbytes // 2


def test_pack_plan_odd_k_falls_back_to_int8_storage():
    """Odd-K layers keep int8 storage (no pad metadata on disk) but still
    quantize on the narrow grid the plan asked for."""
    from repro.core.plan import LayerPlan, PrecisionPlan
    from repro.dist.perf import packed_mantissas
    p = _grid_params(shape=(7, 4))
    plan = PrecisionPlan(layers={"kernel": LayerPlan(wire_bits=4,
                                                     pack_bits=4)})
    packed = pack_params_for_serving(p, plan)["kernel"]
    assert "w_int8" in packed and "w_nib" not in packed
    assert int(jnp.max(jnp.abs(packed["w_int8"]))) <= 7
    np.testing.assert_array_equal(np.asarray(packed_mantissas(packed)),
                                  np.asarray(packed["w_int8"]))


def test_plan_widths_address_tree_paths():
    """Plan keys are the /-joined tree paths iter_packable yields: a
    d0/kernel entry packs only that layer, siblings stay uniform int8."""
    from repro.core.plan import LayerPlan, PrecisionPlan
    params = {"d0": _grid_params(), "d1": _grid_params()}
    plan = PrecisionPlan(layers={"d0/kernel": LayerPlan(wire_bits=4,
                                                        pack_bits=4)})
    packed = pack_params_for_serving(params, plan)
    assert "w_nib" in packed["d0"]["kernel"]
    assert "w_int8" in packed["d1"]["kernel"]


def test_pack_with_plan_is_eval_shape_traceable():
    from repro.core.plan import LayerPlan, PrecisionPlan
    abs_p = {"kernel": {"w": jax.ShapeDtypeStruct((8, 4), jnp.float32),
                        "f": jax.ShapeDtypeStruct((1, 4), jnp.float32)}}
    plan = PrecisionPlan(layers={"kernel": LayerPlan(wire_bits=4,
                                                     pack_bits=4)})
    out = jax.eval_shape(lambda t: pack_params_for_serving(t, plan), abs_p)
    assert out["kernel"]["w_nib"].shape == (4, 4)
    assert out["kernel"]["w_nib"].dtype == jnp.int8


# --------------------------- error feedback --------------------------------

def test_ef_unsupported_kind_raises():
    grads = {"w": jnp.ones((4,))}
    st = ef_init(grads)
    with pytest.raises(ValueError, match="topk"):
        ef_compress(grads, st, kind="topk")
    with pytest.raises(ValueError):
        ef_compress(grads, st, kind="fp4")


def test_ef_none_is_passthrough():
    grads = {"w": jnp.linspace(-1.0, 1.0, 7)}
    st = ef_init(grads)
    sent, st2 = ef_compress(grads, st, kind="none")
    np.testing.assert_array_equal(np.asarray(sent["w"]),
                                  np.asarray(grads["w"]))
    assert float(jnp.max(jnp.abs(st2.residual["w"]))) == 0.0


def test_ef_bf16_residual_bounded():
    grads = {"w": jnp.linspace(-1e-3, 1e-3, 101)}
    st = ef_init(grads)
    for _ in range(20):
        sent, st = ef_compress(grads, st, kind="bf16")
        # bf16 has ~8 mantissa bits: residual < 2^-8 * max|e|
        assert float(jnp.max(jnp.abs(st.residual["w"]))) < 1e-5


def test_ef_int8_stacked_leaf_per_layer_grid():
    """Regression: a stacked [L, ...] leaf used ONE per-tensor int8 grid,
    so a single outlier layer crushed quantization resolution for all L
    layers.  The grid must be per leading (layer) axis: each layer's
    max-abs error stays within one step of its OWN grid.  Stackedness is
    marked by the tree path (the scan'd ``layers`` container here)."""
    key = jax.random.PRNGKey(3)
    g = jax.random.normal(key, (4, 8, 6)) * 1e-3
    g = g.at[2].mul(1e4)                     # layer 2 is a 10-scale outlier
    grads = {"layers": {"w": g}}
    sent, st = ef_compress(grads, ef_init(grads), kind="int8")
    err = np.abs(np.asarray(sent["layers"]["w"] - g))
    for layer in range(4):
        own_grid = float(jnp.max(jnp.abs(g[layer]))) / 127.0
        assert err[layer].max() <= own_grid, (
            f"layer {layer}: err {err[layer].max():.2e} > grid {own_grid:.2e}")
    # the old per-tensor grid floored every non-outlier layer to zero with
    # error ~= the full value; per-layer grids keep them finite-resolution
    assert err[0].max() < float(jnp.max(jnp.abs(g[0]))) / 64
    # rank <= 2 leaves keep the per-tensor grid
    flat = {"w": jnp.linspace(-1.0, 1.0, 33).reshape(3, 11)}
    s2, _ = ef_compress(flat, ef_init(flat), kind="int8")
    m = np.asarray(s2["w"]) * 127.0
    np.testing.assert_allclose(m, np.round(m), atol=1e-4)


def test_ef_int8_genuine_3d_weight_one_grid():
    """Regression (rank-sniffing bug): a genuinely 3-D weight — e.g. a
    per-head attention tensor NOT under a stacked-layer container — must
    get ONE per-tensor grid, not a silent per-slice grid along axis 0.
    Every sent value lies on the single global max|e|/127 grid."""
    key = jax.random.PRNGKey(4)
    g = jax.random.normal(key, (4, 8, 6))      # [heads, d, d] — one tensor
    g = g.at[2].mul(100.0)                     # head 2 dominates the amax
    grads = {"attn_heads": {"w": g}}
    assert jax.tree.leaves(stacked_tree(grads)) == [False]
    sent, _ = ef_compress(grads, ef_init(grads), kind="int8")
    scale = float(jnp.max(jnp.abs(g))) / 127.0
    m = np.asarray(sent["attn_heads"]["w"]) / scale
    # on one global grid every mantissa is an integer; per-slice grids
    # (the old rank>=3 sniff) would put slices 0/1/3 on much finer grids
    np.testing.assert_allclose(m, np.round(m), atol=1e-3)
    # explicit override: the same tree CAN be marked stacked by metadata
    sent2, _ = ef_compress(grads, ef_init(grads), kind="int8",
                           stacked={"attn_heads": {"w": True}})
    err2 = np.abs(np.asarray(sent2["attn_heads"]["w"] - g))
    own_grid = float(jnp.max(jnp.abs(g[0]))) / 127.0
    assert err2[0].max() <= own_grid


def test_stacked_tree_path_rule():
    """stacked_tree marks exactly the leaves under stacked containers
    (scan'd layer stacks, MoE expert stacks) — param metadata, not rank."""
    tree = {"layers": {"attn": {"wq": {"kernel": {"w": jnp.zeros((2, 4, 4))}}}},
            "units": {"mlp": {"w": jnp.zeros((1, 4, 8))}},
            "head": {"kernel": {"w": jnp.zeros((4, 4))}},
            "attn_heads": {"w": jnp.zeros((4, 4, 4))}}
    marks = stacked_tree(tree)
    assert marks["layers"]["attn"]["wq"]["kernel"]["w"] is True
    assert marks["units"]["mlp"]["w"] is True
    assert marks["head"]["kernel"]["w"] is False
    assert marks["attn_heads"]["w"] is False


def test_ef_state_is_jit_compatible():
    grads = {"w": jnp.linspace(-1.0, 1.0, 33)}
    step = jax.jit(lambda g, s: ef_compress(g, s, kind="int8"))
    sent, st = step(grads, ef_init(grads))
    assert isinstance(st, EFState)
    # sent values lie on the int8 grid of max|e|
    scale = float(jnp.max(jnp.abs(grads["w"]))) / 127.0
    m = np.asarray(sent["w"]) / scale
    np.testing.assert_allclose(m, np.round(m), atol=1e-4)
