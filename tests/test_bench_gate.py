"""benchmarks/check_regression.py — the CI bench-gate.

Pure-python tests: metric extraction from both bench schemas, the
direction-aware tolerance compare, per-metric overrides, the
injected-regression failure path (the acceptance contract: the gate MUST
fail when wire bytes/element rises or smoke tokens/sec drops beyond
tolerance, and MUST pass on an unchanged run), and --update
re-baselining."""
import copy
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                "benchmarks"))
import check_regression as gate  # noqa: E402


COLLECTIVES = {
    "bench": "collectives", "arch": "qwen2-0.5b-smoke", "devices": 8,
    "runs": [
        {"mode": "fp32", "bytes_per_element": 7.0, "step_ms": 30.0,
         "reduction_vs_fp32": 1.0},
        {"mode": "int8-wire", "bytes_per_element": 1.757, "step_ms": 80.0,
         "reduction_vs_fp32": 3.98},
    ],
    "mesh2d": [
        {"mesh": "2x4", "runs": [
            {"mode": "int8-wire", "bytes_on_wire_per_device": 50000.0,
             "tp_replication_bytes": 150000.0,
             "total_bytes_per_element": 4.0, "step_ms": 35.0},
            {"mode": "int8-wire-2d", "bytes_on_wire_per_device": 50500.0,
             "tp_replication_bytes": 0.0,
             "total_bytes_per_element": 1.006, "step_ms": 70.0,
             "reduction_vs_1d": 3.98},
        ]},
    ],
}

SERVING = {
    "bench": "serving", "arch": "qwen2-0.5b-smoke", "hbm_saving_x": 3.7,
    "runs": [
        {"mode": "fp", "decode_tokens_per_sec": 1980.0,
         "mixed_tokens_per_sec": 800.0},
        {"mode": "packed", "decode_tokens_per_sec": 1500.0,
         "mixed_tokens_per_sec": 700.0},
    ],
}


def _write(tmp_path, name, data):
    p = os.path.join(tmp_path, name)
    with open(p, "w") as f:
        json.dump(data, f)
    return p


@pytest.fixture
def gate_env(tmp_path):
    """(fresh_dir, baseline_dir) with both benches baselined."""
    tmp = str(tmp_path)
    base = os.path.join(tmp, "baselines")
    os.makedirs(base)
    _write(base, "BENCH_collectives.json", COLLECTIVES)
    _write(base, "BENCH_serving.json", SERVING)
    return tmp, base


def test_extract_collectives_metrics():
    m = gate.extract_metrics(COLLECTIVES)
    assert m["collectives.int8-wire.bytes_per_element"] == (1.757, "lower")
    assert m["collectives[2x4].int8-wire-2d.total_bytes_per_element"] == \
        (1.006, "lower")
    assert m["collectives[2x4].int8-wire-2d.reduction_vs_1d"] == \
        (3.98, "higher")


def test_extract_serving_metrics():
    m = gate.extract_metrics(SERVING)
    assert m["serving.fp.decode_tokens_per_sec"] == (1980.0, "higher")
    assert m["serving.packed.mixed_tokens_per_sec"] == (700.0, "higher")
    assert m["serving.hbm_saving_x"] == (3.7, "higher")


def test_extract_step_ms_direction_lower():
    m = gate.extract_metrics(COLLECTIVES)
    assert m["collectives.int8-wire.step_ms"] == (80.0, "lower")
    assert m["collectives[2x4].int8-wire-2d.step_ms"] == (70.0, "lower")


def test_extract_mixed_precision_metrics():
    data = copy.deepcopy(COLLECTIVES)
    data["mixed_precision"] = {"plan_summary": {"n_layers": 8},
                               "low_bits": 4, "runs": [
        {"mode": "int8-wire-uniform", "bytes_per_element": 1.757},
        {"mode": "int8-wire-mixed-w4w8", "bytes_per_element": 0.889,
         "step_ms": 40.0, "reduction_vs_uniform": 1.98}]}
    m = gate.extract_metrics(data)
    assert m["collectives[mixed].int8-wire-mixed-w4w8.bytes_per_element"] \
        == (0.889, "lower")
    assert m["collectives[mixed].int8-wire-mixed-w4w8"
             ".reduction_vs_uniform"] == (1.98, "higher")
    assert "collectives[mixed].int8-wire-uniform.reduction_vs_uniform" \
        not in m


def test_gate_step_ms_direction_aware(gate_env):
    """Wall-time gates only on the bad direction: a rise beyond tolerance
    fails, the per-metric override loosens it, a drop always passes."""
    tmp, base = gate_env
    slow = copy.deepcopy(COLLECTIVES)
    slow["runs"][1]["step_ms"] = 200.0            # 80 -> +150%
    fresh = _write(tmp, "BENCH_collectives.json", slow)
    assert gate.main([fresh, "--baseline-dir", base]) == 1
    assert gate.main([fresh, "--baseline-dir", base,
                      "--override", "collectives*step_ms=2.0"]) == 0
    fast = copy.deepcopy(COLLECTIVES)
    fast["runs"][1]["step_ms"] = 10.0
    fresh = _write(tmp, "BENCH_collectives.json", fast)
    assert gate.main([fresh, "--baseline-dir", base]) == 0


def test_gate_fails_on_mixed_reduction_drop(gate_env, capsys):
    tmp, base = gate_env
    with_mixed = copy.deepcopy(COLLECTIVES)
    with_mixed["mixed_precision"] = {"runs": [
        {"mode": "int8-wire-mixed-w4w8", "bytes_per_element": 0.889,
         "reduction_vs_uniform": 1.98}]}
    _write(base, "BENCH_collectives.json", with_mixed)
    bad = copy.deepcopy(with_mixed)
    bad["mixed_precision"]["runs"][0]["reduction_vs_uniform"] = 1.0
    fresh = _write(tmp, "BENCH_collectives.json", bad)
    assert gate.main([fresh, "--baseline-dir", base]) == 1
    assert "reduction_vs_uniform" in capsys.readouterr().err


def test_unknown_bench_contributes_nothing():
    assert gate.extract_metrics({"bench": "mystery", "runs": [{"x": 1}]}) \
        == {}


def test_gate_passes_on_identical_run(gate_env, capsys):
    tmp, base = gate_env
    fresh = _write(tmp, "BENCH_collectives.json", COLLECTIVES)
    assert gate.main([fresh, "--baseline-dir", base]) == 0
    assert "OK" in capsys.readouterr().out


def test_gate_fails_on_injected_byte_regression(gate_env, capsys):
    """The acceptance contract: bytes/element rising >10% must fail."""
    tmp, base = gate_env
    bad = copy.deepcopy(COLLECTIVES)
    bad["runs"][1]["bytes_per_element"] = 2.4          # 1.757 -> +37%
    fresh = _write(tmp, "BENCH_collectives.json", bad)
    assert gate.main([fresh, "--baseline-dir", base]) == 1
    err = capsys.readouterr().err
    assert "bytes_per_element" in err and "rose" in err


def test_gate_fails_on_2d_reduction_drop(gate_env, capsys):
    tmp, base = gate_env
    bad = copy.deepcopy(COLLECTIVES)
    bad["mesh2d"][0]["runs"][1]["reduction_vs_1d"] = 1.2   # 3.98 -> 1.2
    fresh = _write(tmp, "BENCH_collectives.json", bad)
    assert gate.main([fresh, "--baseline-dir", base]) == 1
    assert "reduction_vs_1d" in capsys.readouterr().err


def test_gate_fails_on_tokens_per_sec_drop(gate_env, capsys):
    tmp, base = gate_env
    bad = copy.deepcopy(SERVING)
    bad["runs"][0]["decode_tokens_per_sec"] = 900.0    # 1980 -> -55%
    fresh = _write(tmp, "BENCH_serving.json", bad)
    assert gate.main([fresh, "--baseline-dir", base]) == 1
    assert "dropped" in capsys.readouterr().err


def test_gate_ignores_improvements(gate_env):
    """Direction-aware: fewer bytes / more tokens never fail."""
    tmp, base = gate_env
    good = copy.deepcopy(COLLECTIVES)
    good["runs"][1]["bytes_per_element"] = 0.9
    good["mesh2d"][0]["runs"][1]["reduction_vs_1d"] = 7.0
    fresh = _write(tmp, "BENCH_collectives.json", good)
    assert gate.main([fresh, "--baseline-dir", base]) == 0


def test_gate_within_default_tolerance(gate_env):
    tmp, base = gate_env
    ok = copy.deepcopy(SERVING)
    ok["runs"][0]["decode_tokens_per_sec"] = 1980.0 * 0.95   # -5% < 10%
    fresh = _write(tmp, "BENCH_serving.json", ok)
    assert gate.main([fresh, "--baseline-dir", base]) == 0


def test_per_metric_override_loosens(gate_env):
    """--override PATTERN=TOL: a 40% throughput drop passes at tol 0.5
    but the untouched byte metrics keep the tight default."""
    tmp, base = gate_env
    noisy = copy.deepcopy(SERVING)
    for row in noisy["runs"]:
        row["decode_tokens_per_sec"] *= 0.6
        row["mixed_tokens_per_sec"] *= 0.6
    fresh = _write(tmp, "BENCH_serving.json", noisy)
    assert gate.main([fresh, "--baseline-dir", base]) == 1
    assert gate.main([fresh, "--baseline-dir", base,
                      "--override", "serving.*tokens_per_sec=0.5"]) == 0


def test_override_last_match_wins():
    assert gate.tolerance_for("a.b", 0.1, [("a.*", 0.3), ("a.b", 0.05)]) \
        == 0.05
    assert gate.tolerance_for("zzz", 0.1, [("a.*", 0.3)]) == 0.1


def test_missing_baseline_is_exit_2(gate_env, capsys):
    tmp, base = gate_env
    fresh = _write(tmp, "BENCH_unknown.json", SERVING)
    assert gate.main([fresh, "--baseline-dir", base]) == 2
    assert "no baseline" in capsys.readouterr().err


def test_new_metric_warns_then_strict_fails(gate_env, capsys):
    tmp, base = gate_env
    grown = copy.deepcopy(SERVING)
    grown["runs"].append({"mode": "spec-decode",
                          "decode_tokens_per_sec": 5000.0,
                          "mixed_tokens_per_sec": 2000.0})
    fresh = _write(tmp, "BENCH_serving.json", grown)
    assert gate.main([fresh, "--baseline-dir", base]) == 0
    assert "WARN" in capsys.readouterr().out
    assert gate.main([fresh, "--baseline-dir", base, "--strict"]) == 1


def test_update_rebaselines(gate_env):
    tmp, base = gate_env
    newer = copy.deepcopy(COLLECTIVES)
    newer["runs"][1]["bytes_per_element"] = 1.5
    fresh = _write(tmp, "BENCH_collectives.json", newer)
    assert gate.main([fresh, "--baseline-dir", base, "--update"]) == 0
    # the regression that would have failed is now the baseline
    assert gate.main([fresh, "--baseline-dir", base]) == 0
    with open(os.path.join(base, "BENCH_collectives.json")) as f:
        assert json.load(f)["runs"][1]["bytes_per_element"] == 1.5


def test_bad_override_is_exit_2(gate_env, capsys):
    tmp, base = gate_env
    fresh = _write(tmp, "BENCH_serving.json", SERVING)
    assert gate.main([fresh, "--baseline-dir", base,
                      "--override", "nonsense"]) == 2


# ------------------- step_ratio_vs_fp32 + timing tolerance ------------------

def _with_ratios():
    data = copy.deepcopy(COLLECTIVES)
    data["runs"][1]["step_ratio_vs_fp32"] = 1.15
    data["mesh2d"][0]["runs"][1]["step_ratio_vs_fp32"] = 1.2
    return data


def test_extract_step_ratio_metrics():
    m = gate.extract_metrics(_with_ratios())
    assert m["collectives.int8-wire.step_ratio_vs_fp32"] == (1.15, "lower")
    assert m["collectives[2x4].int8-wire-2d.step_ratio_vs_fp32"] == \
        (1.2, "lower")
    assert "collectives.fp32.step_ratio_vs_fp32" not in m


def test_gate_fails_on_injected_step_ratio_regression(gate_env, capsys):
    """The tentpole wall-clock contract: int8-wire losing ground against
    the fp32 ring (ratio rising beyond the timing tolerance) MUST fail
    CI even if absolute step_ms noise were overridden away."""
    tmp, base = gate_env
    _write(base, "BENCH_collectives.json", _with_ratios())
    bad = _with_ratios()
    bad["runs"][1]["step_ratio_vs_fp32"] = 1.9          # 1.15 -> +65%
    fresh = _write(tmp, "BENCH_collectives.json", bad)
    assert gate.main([fresh, "--baseline-dir", base,
                      "--override", "collectives*step_ms=5.0"]) == 1
    err = capsys.readouterr().err
    assert "step_ratio_vs_fp32" in err and "rose" in err
    bad2d = _with_ratios()
    bad2d["mesh2d"][0]["runs"][1]["step_ratio_vs_fp32"] = 2.4
    fresh = _write(tmp, "BENCH_collectives.json", bad2d)
    assert gate.main([fresh, "--baseline-dir", base,
                      "--override", "collectives*step_ms=5.0"]) == 1


def test_builtin_timing_tolerance_wider_than_default():
    """Timing metrics get the built-in tolerances (25% step_ms, 50%
    step_ratio), so a 20% wall-clock wobble passes where a 20% byte rise
    fails — without any --override."""
    base = gate.extract_metrics(_with_ratios())
    wobble = _with_ratios()
    wobble["runs"][1]["step_ms"] *= 1.2
    wobble["runs"][1]["step_ratio_vs_fp32"] *= 1.2
    fails, _ = gate.compare(base, gate.extract_metrics(wobble), 0.10, [],
                            strict=False)
    assert fails == []
    bytes_up = _with_ratios()
    bytes_up["runs"][1]["bytes_per_element"] *= 1.2
    fails, _ = gate.compare(base, gate.extract_metrics(bytes_up), 0.10,
                            [], strict=False)
    assert len(fails) == 1 and "bytes_per_element" in fails[0]


def test_user_override_beats_builtin_timing_default():
    """--override always wins over the built-in timing tolerance: a user
    can TIGHTEN the step_ms gate below 25%."""
    base = gate.extract_metrics(COLLECTIVES)
    wobble = copy.deepcopy(COLLECTIVES)
    wobble["runs"][1]["step_ms"] *= 1.2
    fails, _ = gate.compare(base, gate.extract_metrics(wobble), 0.10,
                            [("collectives*step_ms", 0.05)], strict=False)
    assert len(fails) == 1 and "step_ms" in fails[0]
