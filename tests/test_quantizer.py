"""Property tests for the HGQ quantizer (paper Eq. 1-15, Algorithm 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.quantizer import (LN2, f_shape_for, grad_scale, group_size,
                                  int_bits_from_range, occupied_bits,
                                  group_occupied_bits, quantize,
                                  quantize_inference, ste_round, train_bits)

settings.register_profile("ci", max_examples=60, deadline=None)
settings.load_profile("ci")

floats = st.floats(min_value=-100.0, max_value=100.0, allow_nan=False,
                   width=32)
fbits = st.integers(min_value=-4, max_value=12)


@given(floats, fbits)
def test_error_bound(x, f):
    """|x - q(x)| <= 2^-f-1 (Eq. 8: quantization error is bounded by half a
    step)."""
    xq = quantize_inference(jnp.float32(x), jnp.float32(f))
    step = 2.0 ** (-f)
    assert abs(float(xq) - x) <= step / 2 + 1e-6 * max(abs(x), 1)


@given(floats, fbits)
def test_idempotent(x, f):
    """q(q(x)) == q(x): quantized values are fixed points."""
    q1 = quantize_inference(jnp.float32(x), jnp.float32(f))
    q2 = quantize_inference(q1, jnp.float32(f))
    assert float(q1) == float(q2)


@given(floats, fbits)
def test_on_grid(x, f):
    """q(x) * 2^f is an integer (fixed-point grid membership)."""
    xq = float(quantize_inference(jnp.float32(x), jnp.float32(f)))
    scaled = xq * (2.0 ** f)
    assert abs(scaled - round(scaled)) < 1e-3


@given(st.lists(floats, min_size=2, max_size=16), fbits)
def test_monotone(xs, f):
    xs = sorted(xs)
    qs = [float(quantize_inference(jnp.float32(x), jnp.float32(f)))
          for x in xs]
    assert all(a <= b + 1e-9 for a, b in zip(qs, qs[1:]))


@given(floats, fbits)
def test_ste_gradient_x(x, f):
    """Straight-through: d q(x)/dx == 1 exactly."""
    g = jax.grad(lambda v: quantize(v, jnp.float32(f)))(jnp.float32(x))
    assert float(g) == pytest.approx(1.0)


@given(floats, fbits)
def test_surrogate_gradient_f(x, f):
    """Eq. 15: d q(x)/df == ln2 * delta with delta = x - q(x)."""
    xf = jnp.float32(x)
    g = jax.grad(lambda ff: quantize(xf, ff))(jnp.float32(f))
    delta = float(xf - quantize_inference(xf, jnp.float32(f)))
    assert float(g) == pytest.approx(LN2 * delta, rel=1e-4, abs=1e-6)


def test_ste_round_matches_paper_convention():
    # [x] = floor(x + 1/2): midpoint rounds UP
    assert float(ste_round(jnp.float32(0.5))) == 1.0
    assert float(ste_round(jnp.float32(-0.5))) == 0.0
    assert float(jax.grad(lambda x: ste_round(x))(jnp.float32(1.3))) == 1.0


def test_grad_scale():
    x = jnp.float32(3.0)
    assert float(grad_scale(x, 0.25)) == pytest.approx(3.0)
    g = jax.grad(lambda v: grad_scale(v, 0.25))(x)
    assert float(g) == pytest.approx(0.25)


# ------------------------- bit accounting ---------------------------------

def test_occupied_bits_known_values():
    # paper SSIII.C: 001xx1000-style counting
    f = jnp.float32(8.0)
    assert float(occupied_bits(jnp.float32(0.5), f)) == 1       # 0.1
    assert float(occupied_bits(jnp.float32(0.140625), jnp.float32(6))) == 4
    assert float(occupied_bits(jnp.float32(0.0), f)) == 0       # pruned
    assert float(occupied_bits(jnp.float32(-0.75), f)) == 2     # 0.11


@given(st.integers(min_value=1, max_value=2**20), st.integers(0, 10))
def test_occupied_bits_vs_python(m, f):
    """Cross-check against python bit twiddling on the integer mantissa."""
    w = m * (2.0 ** -f)
    got = float(occupied_bits(jnp.float32(w), jnp.float32(f)))
    want = m.bit_length() - ((m & -m).bit_length() - 1)
    assert got == want


def test_occupied_bits_no_int32_overflow():
    """round(w * 2^f) exceeds int32 for large f; the mantissa-normalized
    path must keep exact counts (regression: the old int32 cast wrapped
    negative and returned garbage)."""
    # 1.0 at f=40: mantissa 2^40 -> exactly 1 occupied bit
    assert float(occupied_bits(jnp.float32(1.0), jnp.float32(40.0))) == 1
    # 1.5 = 0b11 * 2^-1 -> 2 occupied bits at any f
    assert float(occupied_bits(jnp.float32(1.5), jnp.float32(30.0))) == 2
    # 0.140625 = 0b1001 * 2^-6 -> 4 bits, stable across huge f
    for f in (6.0, 25.0, 31.0, 60.0):
        assert float(occupied_bits(jnp.float32(0.140625),
                                   jnp.float32(f))) == 4
    # group variant: same normalization path
    w = jnp.array([1.0, 1.5, 0.0])
    assert float(group_occupied_bits(w, jnp.float32(40.0), ())) == 2.0
    # beyond float32's exponent range the clamp keeps counts finite/right
    assert float(occupied_bits(jnp.float32(1.0), jnp.float32(128.0))) == 1
    assert float(group_occupied_bits(w, jnp.float32(128.0), ())) == 2.0
    # |w| * 2^f overflowing float32 must not corrupt the count either
    assert float(occupied_bits(jnp.float32(2.0), jnp.float32(127.0))) == 1
    assert float(occupied_bits(jnp.float32(96.0), jnp.float32(125.0))) == 2
    assert float(group_occupied_bits(jnp.array([2.0, 3.0]),
                                     jnp.float32(127.0), ())) == 2.0


def test_int_bits_exact_at_powers_of_two():
    """floor(log2(2^13)) is 12 via jnp.log2 on some backends (one ulp
    low); Eq. 3 must still allocate 14 bits for vmax=8192 (regression)."""
    for k in (13, 15, 26, 27, 30):
        assert float(int_bits_from_range(0.0, float(2 ** k))) == k + 1, k
        assert float(int_bits_from_range(-float(2 ** k), 0.0)) == k, k


def test_group_occupied_bits():
    w = jnp.array([0.5, 0.25, 0.0])
    # msb of 0.5 = -1, lsb of 0.25 = -2 -> 2 bits for the group
    assert float(group_occupied_bits(w, jnp.float32(8.0), ())) == 2.0


@given(st.lists(st.floats(-8, 8, allow_nan=False, width=32), min_size=1,
                max_size=32), st.integers(0, 8))
def test_train_bits_upper_bounds_occupied(ws, f):
    """~EBOPs bits (relu(i'+f)) upper-bound the exact occupied bits up to
    the sign-bit convention (paper SSIII.D.2: f bounds the *fractional* bits
    enclosed by non-zero bits; Eq. 3 counts integer bits in two's
    complement, occupied bits count the magnitude — they differ by at most
    1 at exact negative powers of two, e.g. w = -1, f = 0)."""
    w = jnp.asarray(ws, jnp.float32)
    wq = quantize_inference(w, jnp.float32(f))
    vmin, vmax = jnp.min(wq), jnp.max(wq)
    bt = float(train_bits(jnp.float32(f), vmin, vmax, signed_bit=False))
    occ = float(jnp.max(occupied_bits(wq, jnp.float32(f))))
    assert bt + 1.0 >= occ - 1e-4
    # the paper's exact claim: fractional occupied bits never exceed f
    from repro.core.quantizer import _trailing_zeros
    m = jnp.abs(jnp.round(wq * jnp.exp2(jnp.float32(f)))).astype(jnp.int32)
    frac_occ = jnp.where(m > 0, f - _trailing_zeros(m), 0.0)
    assert float(jnp.max(frac_occ)) <= f + 1e-6


def test_int_bits_from_range():
    assert float(int_bits_from_range(0.0, 3.0)) == 2     # need 2 bits for 3
    assert float(int_bits_from_range(0.0, 4.0)) == 3
    assert float(int_bits_from_range(-1.0, 0.5)) == 0    # ceil(log2 1) = 0
    assert float(int_bits_from_range(0.0, 0.0)) < -100   # dead value


def test_f_shapes_and_group_size():
    assert f_shape_for((4, 8), "per_tensor") == ()
    assert f_shape_for((4, 8), "per_channel") == (1, 8)
    assert f_shape_for((4, 8), "per_parameter") == (4, 8)
    assert group_size((4, 8), (1, 8)) == 4.0
    assert group_size((4, 8), ()) == 32.0
