"""Training-loop integration: loss decreases, HGQ pruning emerges under
high beta, checkpoint/restore is exact, resume replays deterministically,
gradient compression keeps bounded residuals."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hgq
from repro.core.pareto import ParetoFront
from repro.data import DataSpec, make_pipeline
from repro.dist import ef_compress, ef_init
from repro.models import JetTagger
from repro.nn import HGQConfig
from repro.train import (TrainConfig, Trainer, accuracy, checkpoint,
                         softmax_xent)

CFG = HGQConfig(weight_gran="per_parameter", act_gran="per_parameter",
                init_weight_f=2, init_act_f=2)


def _make_trainer(tmp=None, steps=40, beta0=1e-7, beta1=1e-6, grad_tx=None):
    key = jax.random.PRNGKey(0)
    p, q = JetTagger.init(key, CFG)
    fwd = lambda params, qstate, batch, mode: JetTagger.forward(
        params, qstate, batch, mode)
    loss = lambda out, batch: softmax_xent(out, batch["y"])
    pipe = make_pipeline(DataSpec(kind="jet", batch=256))
    tc = TrainConfig(steps=steps, lr=3e-3, beta0=beta0, beta1=beta1,
                     log_every=1000, ckpt_dir=tmp or "")
    return Trainer(fwd, loss, tc, p, q, pipeline=pipe,
                   grad_tx=grad_tx), pipe


def test_loss_decreases_and_accuracy():
    tr, pipe = _make_trainer(steps=60)
    res = tr.run(log=lambda *a: None)
    b = pipe(999)
    out, _, _ = JetTagger.forward(tr.params, tr.qstate, b, mode=hgq.EVAL)
    acc = float(accuracy(out, b["y"]))
    assert acc > 0.9, f"jet accuracy {acc}"
    assert res["metrics"]["loss"] < 1.0


def test_high_beta_prunes_bits():
    """The paper's pruning-from-quantization: crank beta and bitwidths
    collapse toward zero (SSec. III.D.4).  AdamW moves f by ~lr per step
    under any sustained pressure, so pruning needs a few hundred steps."""
    tr, _ = _make_trainer(steps=400, beta0=5e-2, beta1=5e-1)
    res = tr.run(log=lambda *a: None)
    # resource pressure: ~EBOPs collapses (3.5e3 at init -> under 1e3)
    assert res["metrics"]["ebops"] < 1.5e3, res["metrics"]
    # pruned fraction: weights quantized to exactly zero.  (f itself need
    # not go below 0 — once relu(i'+f)=0 the EBOPs gradient vanishes, which
    # is exactly the paper's pruning mechanism.)
    from repro.core.quantizer import quantize_inference
    w = tr.params["d0"]["kernel"]["w"]
    f = tr.params["d0"]["kernel"]["f"]
    wq = quantize_inference(w, f)
    assert float(jnp.mean(wq == 0)) > 0.2


def test_checkpoint_roundtrip_exact(tmp_path):
    tr, _ = _make_trainer(str(tmp_path), steps=12)
    tr.run(steps=10, log=lambda *a: None)
    path = tr.checkpoint(10)
    step, trees = checkpoint.restore(
        str(tmp_path), 10, {"params": tr.params, "qstate": tr.qstate,
                            "opt": tr.opt})
    assert step == 10
    for got, want in zip(jax.tree.leaves(trees["params"]),
                         jax.tree.leaves(tr.params)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_resume_replays_identically(tmp_path):
    """Fault tolerance: a crash at step 10 resumed from the checkpoint must
    land exactly where an uninterrupted run lands (step-indexed data
    pipeline, no iterator state)."""
    d1 = str(tmp_path / "a")
    tr1, _ = _make_trainer(d1, steps=20)
    tr1.run(steps=20, log=lambda *a: None)
    ref = jax.tree.leaves(tr1.params)

    d2 = str(tmp_path / "b")
    tr2, _ = _make_trainer(d2, steps=20)
    tr2.run(steps=10, log=lambda *a: None)
    tr2.checkpoint(10)
    # simulate preemption: rebuild from scratch and resume
    tr3, _ = _make_trainer(d2, steps=20)
    assert tr3.maybe_resume()
    assert tr3.start_step == 10
    tr3.run(steps=20, log=lambda *a: None)
    for got, want in zip(jax.tree.leaves(tr3.params), ref):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-6)


def test_atomic_checkpoint_gc_keeps_pareto(tmp_path):
    tr, _ = _make_trainer(str(tmp_path), steps=10)
    tr.run(steps=5, log=lambda *a: None)
    p1 = tr.checkpoint(1, pareto=True)
    for s in (2, 3, 4, 5):
        tr.checkpoint(s)
    names = sorted(os.listdir(tmp_path))
    assert "step_00000001" in names, "Pareto-pinned checkpoint was GC'd"
    assert len([n for n in names if n.startswith("step_")]) <= 4


def test_pareto_front_invariants():
    pf = ParetoFront("max")
    assert pf.offer(0.9, 100, 1)
    assert pf.offer(0.95, 200, 2)
    assert not pf.offer(0.89, 150, 3)       # dominated (worse acc, more ops)
    assert pf.offer(0.85, 50, 4)
    front = pf.front()
    # no point dominates another
    for m1, e1, _ in front:
        for m2, e2, _ in front:
            assert not (m1 >= m2 and e1 <= e2 and (m1 > m2 or e1 < e2))
    assert pf.best(max_ebops=120).metric == 0.9


def test_auto_checkpoint_resume_replays_identically(tmp_path):
    """The *in-loop* auto-checkpoint (ckpt_every) must label with steps
    APPLIED, not the loop index — the old `step` label re-applied one
    batch on every resume (regression)."""
    import dataclasses
    tr_ref, _ = _make_trainer(None, steps=6)
    tr_ref.run(steps=6, log=lambda *a: None)
    ref = jax.tree.leaves(tr_ref.params)

    tr1, _ = _make_trainer(str(tmp_path), steps=6)
    tr1.tcfg = dataclasses.replace(tr1.tcfg, ckpt_every=2)
    tr1.run(steps=5, log=lambda *a: None)     # auto-ckpt after applying 4
    tr2, _ = _make_trainer(str(tmp_path), steps=6)
    assert tr2.maybe_resume()
    assert tr2.start_step == 5, tr2.start_step
    tr2.run(steps=6, log=lambda *a: None)
    for got, want in zip(jax.tree.leaves(tr2.params), ref):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-6)


def test_trainer_honors_grad_tx():
    """Regression: Trainer jitted make_train_step WITHOUT grad_tx, so
    Trainer-driven runs silently ignored configured gradient compression.
    A coarse compressor must now change the trajectory (and thread a
    nonzero residual), while kind='none' stays bit-exact."""
    tx = lambda g, s: ef_compress(g, s, kind="int8")
    tr_c, _ = _make_trainer(steps=6, grad_tx=tx)
    tr_p, _ = _make_trainer(steps=6)
    tr_c.run(steps=6, log=lambda *a: None)
    tr_p.run(steps=6, log=lambda *a: None)
    assert tr_c.tx_state is not None
    res_max = max(float(jnp.max(jnp.abs(leaf)))
                  for leaf in jax.tree.leaves(tr_c.tx_state.residual))
    assert res_max > 0.0, "residual never updated: grad_tx was ignored"
    diff = max(float(jnp.max(jnp.abs(a - b)))
               for a, b in zip(jax.tree.leaves(tr_c.params),
                               jax.tree.leaves(tr_p.params)))
    assert diff > 0.0, "int8 compression had zero effect: grad_tx ignored"
    none_tx = lambda g, s: ef_compress(g, s, kind="none")
    tr_n, _ = _make_trainer(steps=6, grad_tx=none_tx)
    tr_n.run(steps=6, log=lambda *a: None)
    for got, want in zip(jax.tree.leaves(tr_n.params),
                         jax.tree.leaves(tr_p.params)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_trainer_rejects_orphan_tx_state():
    import pytest
    key = jax.random.PRNGKey(0)
    p, q = JetTagger.init(key, CFG)
    fwd = lambda params, qstate, batch, mode: JetTagger.forward(
        params, qstate, batch, mode)
    loss = lambda out, batch: softmax_xent(out, batch["y"])
    with pytest.raises(ValueError, match="grad_tx"):
        Trainer(fwd, loss, TrainConfig(steps=1), p, q,
                tx_state=ef_init(p))


def test_trainer_saves_and_resumes_ef_residual(tmp_path):
    """Regression: Trainer.checkpoint never wrote the EF residual — a
    resumed compressed run restarted with a zero residual and a biased
    first window.  Save 'ef' whenever compression is on; resume must
    round-trip it exactly and replay like the uninterrupted run."""
    tx = lambda g, s: ef_compress(g, s, kind="int8")
    tr_ref, _ = _make_trainer(steps=12, grad_tx=tx)
    tr_ref.run(steps=12, log=lambda *a: None)
    ref = jax.tree.leaves(tr_ref.params)

    d = str(tmp_path)
    tr1, _ = _make_trainer(d, steps=12, grad_tx=tx)
    tr1.run(steps=6, log=lambda *a: None)
    tr1.checkpoint(6)
    saved_res = [np.asarray(x) for x in jax.tree.leaves(tr1.tx_state.residual)]
    assert checkpoint.has_tree(d, 6, "ef"), "EF residual not checkpointed"

    tr2, _ = _make_trainer(d, steps=12, grad_tx=tx)
    assert tr2.maybe_resume()
    assert tr2.start_step == 6
    for got, want in zip(jax.tree.leaves(tr2.tx_state.residual), saved_res):
        np.testing.assert_array_equal(np.asarray(got), want)
    tr2.run(steps=12, log=lambda *a: None)
    for got, want in zip(jax.tree.leaves(tr2.params), ref):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-6)


def test_gradient_compression_error_feedback():
    grads = {"w": jnp.linspace(-1e-3, 1e-3, 101)}
    st = ef_init(grads)
    total_sent = jnp.zeros_like(grads["w"])
    for _ in range(50):
        sent, st = ef_compress(grads, st, kind="int8")
        total_sent = total_sent + sent["w"]
    # error feedback: average delivered gradient converges to the truth
    np.testing.assert_allclose(total_sent / 50, grads["w"], atol=2e-5)
    # residual stays bounded by one quantization step
    assert float(jnp.max(jnp.abs(st.residual["w"]))) < 1e-4
