"""core.plan — the PrecisionPlan width table and its derivation.

Covers: LayerPlan/PrecisionPlan validation, deepest-prefix entry lookup,
the uniform-int8 no-op property, per-leaf wire-width trees, JSON
round-trips (including a hypothesis property test over random plans),
derivation from trained params (``plan_from_params`` /
``mixed_low_plan``), and the nibble pack/unpack identity the sub-5-bit
paths rely on.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.plan import (LayerPlan, NIBBLE_BITS, PrecisionPlan,
                             iter_packable, layer_occupied_bits,
                             mixed_low_plan, packable_weight,
                             plan_from_params)


# ------------------------------ validation ---------------------------------

def test_layer_plan_width_bounds():
    LayerPlan(wire_bits=4, pack_bits=8)        # bounds are inclusive
    with pytest.raises(ValueError, match="wire_bits"):
        LayerPlan(wire_bits=3)
    with pytest.raises(ValueError, match="pack_bits"):
        LayerPlan(pack_bits=9)
    with pytest.raises(ValueError, match="unknown PrecisionPlan fields"):
        PrecisionPlan.from_dict({"defaults": {}})
    with pytest.raises(ValueError, match="unknown LayerPlan fields"):
        PrecisionPlan.from_dict({"layers": {"x": {"bits": 4}}})


def test_entry_for_deepest_prefix_wins():
    plan = PrecisionPlan(layers={
        "layers": LayerPlan(wire_bits=5, pack_bits=5),
        "layers/mlp/up/kernel": LayerPlan(wire_bits=4, pack_bits=4)})
    assert plan.entry_for("layers/mlp/up/kernel").wire_bits == 4
    # an entry covers the whole subtree under its path...
    assert plan.entry_for("layers/mlp/up/kernel/w").wire_bits == 4
    assert plan.entry_for("layers/attn/wq/kernel").wire_bits == 5
    # ...but not sibling names that merely share a string prefix
    assert plan.entry_for("layers2/x").wire_bits == 8
    assert plan.entry_for("embed/table").wire_bits == 8


def test_is_uniform_int8():
    assert PrecisionPlan().is_uniform_int8
    assert PrecisionPlan(layers={"x": LayerPlan()}).is_uniform_int8
    assert not PrecisionPlan(
        layers={"x": LayerPlan(wire_bits=4)}).is_uniform_int8
    assert not PrecisionPlan(
        default=LayerPlan(pack_bits=4)).is_uniform_int8


def test_wire_bits_tree_matches_structure():
    tree = {"a": {"w": jnp.zeros((4, 4)), "f": jnp.zeros((4, 4))},
            "b": [jnp.zeros(3), jnp.zeros(2)]}
    # a layer-level entry covers its whole subtree (w AND f grads)...
    plan = PrecisionPlan(layers={"a": LayerPlan(wire_bits=4, pack_bits=4)})
    assert plan.wire_bits_tree(tree) == {"a": {"w": 4, "f": 4},
                                         "b": [8, 8]}
    # ...while a leaf-level entry pins just that leaf
    leafy = PrecisionPlan(layers={"a/w": LayerPlan(wire_bits=4,
                                                   pack_bits=4)})
    assert leafy.wire_bits_tree(tree) == {"a": {"w": 4, "f": 8},
                                          "b": [8, 8]}


# ----------------------------- serialization -------------------------------

def test_plan_json_roundtrip_exact():
    plan = PrecisionPlan(
        default=LayerPlan(wire_bits=8, pack_bits=8, scale_exp=2.0),
        layers={"d0/kernel": LayerPlan(wire_bits=4, pack_bits=4,
                                       scale_exp=5.0)})
    assert PrecisionPlan.from_json(plan.to_json()) == plan
    assert PrecisionPlan.from_dict(plan.to_dict()) == plan
    # canonical form is stable
    assert PrecisionPlan.from_json(plan.to_json()).to_json() \
        == plan.to_json()


@settings(max_examples=25)
@given(st.integers(min_value=4, max_value=8),
       st.integers(min_value=4, max_value=8),
       st.integers(min_value=0, max_value=6),
       st.floats(min_value=-8.0, max_value=8.0, width=32))
def test_plan_roundtrip_property(wire, pack, n_layers, exp):
    """from_json(to_json(plan)) == plan for random width tables."""
    layers = {f"l{i}/kernel": LayerPlan(
        wire_bits=wire if i % 2 else 8,
        pack_bits=pack, scale_exp=float(exp) if i % 3 else None)
        for i in range(n_layers)}
    plan = PrecisionPlan(layers=layers)
    p2 = PrecisionPlan.from_json(plan.to_json())
    assert p2 == plan
    assert p2.to_json() == plan.to_json()


# ------------------------------ derivation ---------------------------------

def _toy_params():
    k = jax.random.PRNGKey(0)
    mk = lambda s, kk: jax.random.normal(kk, s, jnp.float32) * 0.1
    k1, k2, k3 = jax.random.split(k, 3)
    return {
        "d0": {"kernel": {"w": mk((16, 8), k1),
                          "f": jnp.full((16, 8), 2.0)},
               "bias": {"w": mk((8,), k2)}},
        "d1": {"kernel": {"w": mk((8, 4), k3),
                          "f": jnp.full((8, 4), 6.0)}},
    }


def test_iter_packable_keys_and_rule():
    keys = [k for k, _ in iter_packable(_toy_params())]
    assert keys == ["d0/kernel", "d1/kernel"]
    assert not packable_weight("bias", jnp.zeros((4, 4)))
    assert not packable_weight("w", jnp.zeros(4))          # rank-1
    assert not packable_weight("kernel", jnp.zeros((3, 3, 3, 3)))  # conv
    assert packable_weight("table", jnp.zeros((16, 8), jnp.bfloat16))


def test_plan_from_params_width_classes():
    """A layer whose occupied bits fit in 4 goes w4; a wide one stays
    int8; unlisted leaves keep the 8-bit default."""
    params = _toy_params()
    # d0: f=2 on |w|~0.1 -> tiny mantissas -> low occupied bits
    occ0 = layer_occupied_bits(params["d0"]["kernel"]["w"],
                               params["d0"]["kernel"]["f"])
    occ1 = layer_occupied_bits(params["d1"]["kernel"]["w"],
                               params["d1"]["kernel"]["f"])
    assert 1 <= occ0 <= 8 and 1 <= occ1 <= 8
    plan = plan_from_params(params, low_bits=4, threshold=occ0)
    e0 = plan.entry_for("d0/kernel")
    assert e0.wire_bits == 4 and e0.pack_bits == 4
    assert e0.scale_exp is not None
    if occ1 > occ0:
        assert plan.entry_for("d1/kernel").wire_bits == 8
    assert plan.entry_for("d0/bias").wire_bits == 8
    with pytest.raises(ValueError, match="low_bits"):
        plan_from_params(params, low_bits=3)


def test_mixed_low_plan_covers_all_packable():
    plan = mixed_low_plan(_toy_params(), low_bits=4)
    assert set(plan.layers) == {"d0/kernel", "d1/kernel"}
    assert all(e.wire_bits == 4 and e.pack_bits == 4
               for e in plan.layers.values())
    assert not plan.is_uniform_int8


# --------------------------- nibble pack/unpack ----------------------------

@pytest.mark.parametrize("n", [6, 7])          # even and odd lengths
@pytest.mark.parametrize("axis", [-1, 0])
def test_nibble_pack_unpack_identity(n, axis):
    """pack∘unpack is the identity on in-range int4 mantissas — the
    property that lets the wire simulators skip packing entirely."""
    from repro.kernels.qmatmul.ops import pack_nibbles, unpack_nibbles
    rng = np.random.default_rng(0)
    qmax = 2 ** (NIBBLE_BITS - 1) - 1
    m = rng.integers(-qmax, qmax + 1, size=(n, 5), dtype=np.int8)
    m = np.swapaxes(m, -1, axis) if axis != -1 else m
    packed = pack_nibbles(jnp.asarray(m), axis=axis)
    assert packed.shape[axis] == (m.shape[axis] + 1) // 2
    out = unpack_nibbles(packed, m.shape[axis], axis=axis)
    np.testing.assert_array_equal(np.asarray(out), m)
