"""core.pareto — front maintenance, budget queries, serialization.

Covers: ``best(max_ebops)`` tie-breaking toward the cheaper point,
``offer`` dominance and equal-point rejection, and the front's JSON
round-trip — including per-point ``PrecisionPlan`` payloads (a
hypothesis property test drives random offer sequences).
"""
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.pareto import ParetoFront
from repro.core.plan import LayerPlan, PrecisionPlan


# ------------------------------ best() -------------------------------------

def test_best_tie_breaks_toward_cheaper_point():
    fr = ParetoFront("max")
    assert fr.best() is None
    assert fr.offer(0.90, 100.0, 1)
    assert fr.offer(0.95, 200.0, 2)
    # equal metric, higher ebops: non-dominated (front keeps it) but
    # best() must pick the cheaper of the metric-tied pair
    fr.points.append(type(fr.points[0])(0.95, 300.0, 3))
    b = fr.best()
    assert (b.metric, b.ebops, b.step) == (0.95, 200.0, 2)
    # budget excludes the winner -> cheaper, worse-metric point
    assert fr.best(max_ebops=150.0).step == 1
    assert fr.best(max_ebops=50.0) is None


def test_best_tie_break_min_metric():
    fr = ParetoFront("min")
    fr.offer(0.10, 100.0, 1)
    fr.points.append(type(fr.points[0])(0.10, 50.0, 2))
    assert fr.best().step == 2            # same loss, cheaper model


# ------------------------------ offer() ------------------------------------

def test_offer_dominance_and_equal_points():
    fr = ParetoFront("max")
    assert fr.offer(0.90, 100.0, 1)
    # exactly equal (metric, ebops): rejected, the incumbent stays
    assert not fr.offer(0.90, 100.0, 2)
    assert [p.step for p in fr.points] == [1]
    # equal metric, strictly cheaper: dominates and replaces
    assert fr.offer(0.90, 80.0, 3)
    assert [p.step for p in fr.points] == [3]
    # equal ebops, strictly better metric: dominates and replaces
    assert fr.offer(0.92, 80.0, 4)
    assert [p.step for p in fr.points] == [4]
    # dominated on both axes: rejected
    assert not fr.offer(0.91, 90.0, 5)
    # incomparable: joins, sorted by ebops
    assert fr.offer(0.80, 10.0, 6)
    assert [p.step for p in fr.points] == [6, 4]


# --------------------------- serialization ---------------------------------

def test_front_json_roundtrip_with_plan_payloads():
    plan = PrecisionPlan(layers={
        "d0/kernel": LayerPlan(wire_bits=4, pack_bits=4, scale_exp=3.0)})
    fr = ParetoFront("max")
    fr.offer(0.90, 100.0, 1, payload=plan)
    fr.offer(0.80, 50.0, 2, payload="ckpt/step2")
    fr.offer(0.70, 20.0, 3, payload={"params": object()})  # not JSON-able
    fr2 = ParetoFront.from_json(fr.to_json())
    assert fr2.sign == fr.sign
    assert [(p.metric, p.ebops, p.step) for p in fr2.points] \
        == [(p.metric, p.ebops, p.step) for p in fr.points]
    by_step = {p.step: p.payload for p in fr2.points}
    assert by_step[1] == plan            # plan payload survives exactly
    assert by_step[2] == "ckpt/step2"    # JSON-native scalar survives
    assert by_step[3] is None            # live snapshot drops to None
    # canonical JSON is stable through the round-trip
    assert fr2.to_json() == fr.to_json()


@settings(max_examples=25)
@given(st.lists(st.floats(min_value=0.0, max_value=1.0, width=32),
                min_size=1, max_size=12),
       st.lists(st.floats(min_value=1.0, max_value=1e6, width=32),
                min_size=1, max_size=12))
def test_front_roundtrip_property(metrics, ebops):
    """Any front built by a random offer sequence round-trips exactly
    (points, order, direction)."""
    fr = ParetoFront("max")
    for i, (m, e) in enumerate(zip(metrics, ebops)):
        fr.offer(m, e, i, payload=PrecisionPlan() if i % 2 else None)
    fr2 = ParetoFront.from_json(fr.to_json())
    assert [(p.metric, p.ebops, p.step, p.payload) for p in fr2.points] \
        == [(p.metric, p.ebops, p.step, p.payload) for p in fr.points]
    # front invariant survives: ebops strictly increasing, metric too
    # (non-dominated set under max-metric)
    es = [p.ebops for p in fr2.points]
    ms = [p.metric for p in fr2.points]
    assert es == sorted(es)
    assert ms == sorted(ms)


def test_front_rejects_bad_direction():
    with pytest.raises(AssertionError):
        ParetoFront("bigger")
