"""repro.analysis — the precision-flow program linter.

Covers: the HLO parsers (collective lines incl. tuple/async results,
brace + iota replica groups, input-output aliases), the jaxpr walker
(explicit collectives with logical axis names through shard_map), the
program rules firing on injected violations (an fp32 wire payload, a
dropped donation, a missing exchange), the direction-aware report diff,
and — on 8 devices — the real 2x4 wire-2d program: exactly the explicit
launches the wire wrote, all of them int8 at gradient size, plus the
row-major mesh-layout assumption ``crosses_data_axis`` is built on.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import pytest

from repro import analysis
from repro.analysis import (SCALAR_MAX, Collective, ExplicitCollective,
                            ProgramArtifacts, Violation)
from repro.analysis.rules import run_rules
from repro.api import RunSpec, build

multidevice = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


# ------------------------------ HLO parsers --------------------------------

def test_parse_collectives_basic_line():
    hlo = ('  %all-reduce.1 = f32[64,128]{1,0} all-reduce(%x), '
           'replica_groups={{0,4},{1,5},{2,6},{3,7}}, to_apply=%add\n')
    (c,) = analysis.parse_collectives(hlo)
    assert c.kind == "all-reduce" and c.dtype == "f32"
    assert c.dims == (64, 128) and c.numel == 64 * 128
    assert c.groups == ((0, 4), (1, 5), (2, 6), (3, 7))


def test_parse_collectives_tuple_and_async():
    hlo = "\n".join([
        "%a2a = (s8[1,8478]{1,0}, s8[1,8478]{1,0}) all-to-all(%p, %q), "
        "replica_groups={{0,1}}, dimensions={0}",
        "%ag = s8[2,512]{1,0} all-gather-start(%g), replica_groups=[2,4]<=[8]",
        "%f = f32[8]{0} fusion(%all-reduce.169), kind=kLoop",  # operand ref
    ])
    cs = analysis.parse_collectives(hlo)
    assert [(c.kind, c.dtype) for c in cs] == [
        ("all-to-all", "s8"), ("all-gather", "s8")]
    # iota without transpose: [2,4]<=[8] -> rows of consecutive ids
    assert cs[1].groups == ((0, 1, 2, 3), (4, 5, 6, 7))


def test_replica_groups_iota_transposed():
    # [4,2]<=[2,4]T(1,0): iota reshaped (2,4), transposed, re-read 4x2 —
    # columns of the row-major 2x4 mesh, i.e. groups that CROSS data
    groups = analysis.parse_replica_groups(
        "replica_groups=[4,2]<=[2,4]T(1,0)")
    assert groups == [[0, 4], [1, 5], [2, 6], [3, 7]]
    c = Collective(kind="all-reduce", dtype="f32", dims=(512,),
                   groups=tuple(tuple(g) for g in groups), line="")
    assert c.crosses_data_axis(model_size=4)
    # rows of the same mesh stay inside one data shard
    rows = Collective(kind="all-gather", dtype="s8", dims=(512,),
                      groups=((0, 1, 2, 3), (4, 5, 6, 7)), line="")
    assert not rows.crosses_data_axis(model_size=4)
    # unknown grouping reads as crossing (conservative)
    unk = Collective(kind="all-reduce", dtype="f32", dims=(512,),
                     groups=None, line="")
    assert unk.crosses_data_axis(model_size=4)


def test_collective_permute_pairs_as_groups():
    groups = analysis.parse_replica_groups(
        "source_target_pairs={{0,4},{4,0}}")
    assert groups == [[0, 4], [4, 0]]


def test_strip_metadata_removes_location_noise():
    a = 'op(%x), metadata={op_name="f/alpha" source_file="a.py"}, calls=%c'
    b = 'op(%x), metadata={op_name="g/beta" source_file="b.py"}, calls=%c'
    assert analysis.strip_metadata(a) == analysis.strip_metadata(b)
    assert "alpha" not in analysis.strip_metadata(a)


def test_input_output_aliases_nested_braces():
    hlo = ("HloModule m, input_output_alias={ {0}: (0, {}, may-alias), "
           "{3}: (7, {}, may-alias) }, entry_computation_layout={()->()}")
    assert analysis.input_output_aliases(hlo) == [(0, 0), (3, 7)]
    assert analysis.input_output_aliases("HloModule bare") == []


# ------------------------------ jaxpr walker -------------------------------

@multidevice
def test_explicit_collectives_through_shard_map():
    """The walker finds a psum written inside a shard_map body, with the
    logical axis name attached (a size-1 axis would be elided at trace
    time, hence the real 2x4 mesh)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((2, 4), ("data", "model"))

    def f(x):
        return jax.lax.psum(x, "data")

    sm = shard_map(f, mesh=mesh, in_specs=P("data"), out_specs=P())
    traced = jax.jit(sm).trace(jnp.zeros((8, 4), jnp.float32))
    (c,) = analysis.explicit_collectives(traced.jaxpr)
    assert c.primitive == "psum" and c.axes == ("data",)
    assert c.dtype == "float32" and c.numel == 4 * 4
    assert c.over("data") and not c.over("model")


# ----------------------- rules on injected violations ----------------------

def _fake_art(explicit=(), hlo="HloModule m", kind="train", mesh=(2, 4),
              meta=None):
    """A ProgramArtifacts with hand-planted collectives — the injection
    point for violation tests (subclassing keeps the rule code on the
    exact production path)."""
    class Injected(ProgramArtifacts):
        def explicit_collectives(self):
            return list(explicit)
    return Injected(
        name="train:injected", kind=kind, spec=RunSpec(),
        spec_path="", mesh_shape=mesh, jaxpr=None, hlo=hlo,
        meta={"wire": True, "wire_payload": "int8",
              "donated_leaves": 0, **(meta or {})})


def _ec(primitive, axes, dtype, dims):
    return ExplicitCollective(primitive=primitive, axes=axes, dtype=dtype,
                              dims=dims)


def test_fp32_wire_payload_is_a_violation():
    """The acceptance-criterion injection: force an fp32 wire path —
    a gradient-sized f32 collective over data must trip wire-dtype."""
    art = _fake_art(explicit=[
        _ec("all_to_all", ("data",), "float32", (2, 8478)),
        _ec("pmax", ("data", "model"), "float32", (49,)),   # scalar: fine
    ])
    names = [v.rule for v in run_rules(art)]
    assert "wire-dtype" in names
    # and the clean int8 version of the same program passes
    ok = _fake_art(explicit=[
        _ec("all_to_all", ("data",), "int8", (2, 8478)),
        _ec("pmax", ("data", "model"), "float32", (49,)),
    ])
    assert [v.rule for v in run_rules(ok)] == []


def test_missing_wire_exchange_is_a_violation():
    art = _fake_art(explicit=[_ec("pmax", ("data", "model"),
                                  "float32", (49,))])
    assert "wire-present" in [v.rule for v in run_rules(art)]


def test_dropped_donation_is_a_violation():
    art = _fake_art(
        explicit=[_ec("all_to_all", ("data",), "int8", (2, 8478))],
        meta={"donated_leaves": 10})   # hlo has no alias header -> 0
    assert "donation" in [v.rule for v in run_rules(art)]


def test_f64_leak_is_a_violation():
    art = _fake_art(
        explicit=[_ec("all_to_all", ("data",), "int8", (2, 8478))],
        hlo="HloModule m\n %x = f64[3]{0} convert(%y)\n")
    assert "no-f64" in [v.rule for v in run_rules(art)]


def test_violation_str_names_rule_and_program():
    v = Violation(rule="wire-dtype", program="train:x", message="boom")
    assert "wire-dtype" in str(v) and "train:x" in str(v)


# --------------------------- report + baseline diff ------------------------

def _report_with(launches, aliased=5, crossing=None):
    return {"report": "programs", "programs": {"train:x": {
        "kind": "train", "spec": "s.json", "mesh": [2, 4],
        "launches": launches, "explicit": {"all_to_all[data]": launches},
        "collectives": {"all-reduce.f32": 3},
        "crossing": crossing or {}, "aliased_buffers": aliased,
        "violations": []}}}


def test_compare_extra_launch_fails():
    base, fresh = _report_with(3), _report_with(4)
    failures, _ = analysis.compare(base, fresh)
    assert any("launches" in f for f in failures)
    # the good direction (fewer launches) is a note, not a failure
    failures, notes = analysis.compare(_report_with(4), _report_with(3))
    assert not failures and any("launches" in n for n in notes)


def test_compare_lost_alias_fails_but_gain_passes():
    failures, _ = analysis.compare(_report_with(3, aliased=5),
                                   _report_with(3, aliased=4))
    assert any("aliased_buffers" in f for f in failures)
    failures, _ = analysis.compare(_report_with(3, aliased=5),
                                   _report_with(3, aliased=9))
    assert not failures


def test_compare_override_widens_tolerance():
    base, fresh = _report_with(3), _report_with(4)
    failures, _ = analysis.compare(
        base, fresh, overrides=[("train:x.*", 0.5)])
    assert not failures
    # last match wins, same as check_regression.py
    failures, _ = analysis.compare(
        base, fresh, overrides=[("train:x.*", 0.5), ("*launches", 0.0)])
    assert any("launches" in f for f in failures)


def test_compare_new_and_missing_metrics_are_notes():
    base, fresh = _report_with(3), _report_with(3)
    fresh["programs"]["train:x"]["crossing"] = {"all-to-all.s8": 1}
    failures, notes = analysis.compare(base, fresh)
    assert not failures and any("new metric" in n for n in notes)


def test_report_json_is_deterministic():
    r = _report_with(3)
    assert analysis.dumps(r) == analysis.dumps(json.loads(analysis.dumps(r)))


# --------------------------- real programs ---------------------------------

def test_host_1x1_programs_clean():
    """The shipped single-host spec builds, lints clean, and donates:
    train params/opt round-trip aliased, the decode cache too."""
    spec = RunSpec.from_json(open("examples/specs/host_1x1.json").read())
    arts = analysis.artifacts_for_spec(spec, "examples/specs/host_1x1.json")
    assert [a.kind for a in arts] == ["train", "decode"]
    for a in arts:
        rep = analysis.program_report(a)
        assert rep["violations"] == [], rep["violations"]
    train, decode = arts
    assert train.aliased_buffers() >= train.meta["donated_leaves"] > 0
    assert decode.aliased_buffers() > 0


@multidevice
def test_wire2d_program_census_and_rules():
    """The real 2x4 int8-wire-2d program: the explicit collectives are
    exactly the wire's launches (scale pmax + payload all_to_all + the
    two all_gathers), every gradient-sized one int8 — and the census
    the ROADMAP's fold-pmax work must move is visible in the report."""
    spec = RunSpec.from_json(
        open("examples/specs/host_2x4_int8wire2d.json").read())
    art = analysis.train_artifacts(spec, "specs/host_2x4_int8wire2d.json")
    rep = analysis.program_report(art)
    assert rep["violations"] == [], rep["violations"]
    assert rep["explicit"] == {"all_gather[data]": 1,
                               "all_gather[model]": 1,
                               "all_to_all[data]": 1,
                               "pmax[data,model]": 1}
    assert rep["launches"] == 4
    for c in art.explicit_collectives():
        if c.numel >= SCALAR_MAX:
            assert c.dtype in ("int8", "uint8"), dataclasses.asdict(c)


@multidevice
def test_mesh_layout_is_row_major():
    """crosses_data_axis assumes jax.make_mesh((D, M)) lays device ids
    out row-major (id = d*M + m) — pin that, since every grouping
    classification in the linter rests on it."""
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    ids = [[d.id for d in row] for row in mesh.devices]
    assert ids == [[0, 1, 2, 3], [4, 5, 6, 7]]


@multidevice
def test_wire2d_hlo_census_matches_committed_baseline():
    """The committed golden PROGRAMS.json stays truthful for the 2x4
    program on these exact package versions: explicit-launch metrics are
    deterministic; if THIS test fails after an intentional program
    change, re-baseline with `tools/lint_programs.py --devices 8
    --update`."""
    base = json.load(open("benchmarks/baselines/PROGRAMS.json"))
    prog = base["programs"]["train:host_2x4_int8wire2d"]
    spec = RunSpec.from_json(
        open("examples/specs/host_2x4_int8wire2d.json").read())
    art = analysis.train_artifacts(spec)
    rep = analysis.program_report(art)
    assert rep["launches"] == prog["launches"]
    assert rep["explicit"] == prog["explicit"]
