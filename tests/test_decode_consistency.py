"""Decode-path correctness: incremental KV-cache/state decoding must
reproduce the full-sequence forward logits (the strongest functional test of
caches, ring buffers, RoPE offsets, and recurrent state threading)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get
from repro.core import hgq
from repro.models import model_for

KEY = jax.random.PRNGKey(3)

# HGQ quantizers make tiny numeric differences between the chunked
# (forward) and cached (decode) paths; disable activation ranges' effect by
# using EVAL mode in both.
# moonshot (MoE) is tested separately: near-tie top-k routing can flip
# between the forward and decode numeric paths, which is inherent to MoE
# (not a cache bug) and produces large logit deltas on flipped tokens.
DECODER_ARCHS = ["llama3.2-3b", "qwen2-0.5b", "recurrentgemma-2b",
                 "rwkv6-1.6b"]


@pytest.mark.parametrize("arch", DECODER_ARCHS)
def test_decode_matches_forward(arch):
    cfg = get(arch, smoke=True)
    M = model_for(cfg)
    p, q = M.init(KEY, cfg)
    B, S = 2, 12
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    batch = {"tokens": toks}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(KEY, (B, cfg.n_patches,
                                                        cfg.d_model))
    full_logits, _, _ = M.forward(p, q, batch, cfg, mode=hgq.EVAL)

    cache = M.init_cache(cfg, B, S + 4)
    got = []
    for t in range(S):
        lg, cache = M.decode_step(p, q, cache, toks[:, t:t + 1],
                                  jnp.int32(t), cfg, mode=hgq.EVAL)
        got.append(lg[:, 0])
    got = jnp.stack(got, axis=1)
    # expected numerics: bf16 KV cache (~1e-3) + probs quantized against
    # chunk-local vs global softmax max (~5e-3) + associative-vs-sequential
    # scan order for the recurrent families (~5e-2); none grows with position
    np.testing.assert_allclose(np.asarray(got), np.asarray(full_logits),
                               rtol=1e-1, atol=1e-1)
    # and the decoded distribution must agree where it matters
    agree = np.mean(np.argmax(np.asarray(got), -1)
                    == np.argmax(np.asarray(full_logits), -1))
    assert agree > 0.95, f"top-1 agreement {agree}"


def test_windowed_ring_buffer_decode():
    """RecurrentGemma local attention: decoding past the window must agree
    with a fresh forward over the same suffix-visible context."""
    cfg = get("recurrentgemma-2b", smoke=True)   # window = 16
    M = model_for(cfg)
    p, q = M.init(KEY, cfg)
    B, S = 1, 24                                  # exceeds the window
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    full_logits, _, _ = M.forward(p, q, {"tokens": toks}, cfg, mode=hgq.EVAL)
    cache = M.init_cache(cfg, B, S)
    got = []
    for t in range(S):
        lg, cache = M.decode_step(p, q, cache, toks[:, t:t + 1],
                                  jnp.int32(t), cfg, mode=hgq.EVAL)
        got.append(lg[:, 0])
    got = jnp.stack(got, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full_logits),
                               rtol=1e-1, atol=1e-1)
    agree = np.mean(np.argmax(np.asarray(got), -1)
                    == np.argmax(np.asarray(full_logits), -1))
    assert agree > 0.95, f"top-1 agreement {agree}"


def test_generate_greedy():
    from repro.serving import generate
    cfg = get("qwen2-0.5b", smoke=True)
    M = model_for(cfg)
    p, q = M.init(KEY, cfg)
    prompt = jax.random.randint(KEY, (2, 5), 0, cfg.vocab)
    out = generate(M, p, q, cfg, prompt, max_new=4)
    assert out.shape == (2, 4)
    assert bool(jnp.all((out >= 0) & (out < cfg.vocab)))


def test_engine_batched_requests():
    from repro.serving import Engine, Request
    cfg = get("qwen2-0.5b", smoke=True)
    M = model_for(cfg)
    p, q = M.init(KEY, cfg)
    eng = Engine(M, p, q, cfg, batch_slots=4, max_len=32)
    reqs = [Request(prompt=[1, 2, 3], max_new=3) for _ in range(6)]
    done = eng.run(list(reqs))
    assert all(r.done for r in done)
    assert all(len(r.out) == 3 for r in done)


def test_moe_decode_routing_stability():
    """MoE decode: logits match except where top-k routing flips on
    near-ties; top-1 agreement must stay high and errors must not grow
    unboundedly with position."""
    cfg = get("moonshot-v1-16b-a3b", smoke=True)
    M = model_for(cfg)
    p, q = M.init(KEY, cfg)
    B, S = 2, 12
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    full_logits, _, _ = M.forward(p, q, {"tokens": toks}, cfg, mode=hgq.EVAL)
    cache = M.init_cache(cfg, B, S + 4)
    got = []
    for t in range(S):
        lg, cache = M.decode_step(p, q, cache, toks[:, t:t + 1],
                                  jnp.int32(t), cfg, mode=hgq.EVAL)
        got.append(lg[:, 0])
    got = np.asarray(jnp.stack(got, axis=1))
    full = np.asarray(full_logits)
    agree = np.mean(np.argmax(got, -1) == np.argmax(full, -1))
    assert agree > 0.6, f"top-1 agreement {agree}"
    # the median error stays at quantizer-noise level — only flipped
    # routings (a minority of (batch, position) pairs) deviate
    med = np.median(np.abs(got - full))
    assert med < 5e-2, f"median err {med}"
