"""repro.api — the declarative RunSpec surface and the RunContext builder.

Covers: exact JSON/CLI round-trips (hypothesis property tests over random
specs), the shipped examples/specs/*.json files, seed threading, the
no-global-leak contract (two contexts with different precision in one
process: neither retraces nor perturbs the other, nothing escapes the
scope), and HLO identity — the spec-built train step lowers to the same
program as the legacy global-state setup (``--spec`` file == classic
flags), on 1x1 here and on the 2x4/4x2 meshes in the multi-device CI job.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.analysis import strip_metadata, train_step_hlo
from repro.api import (CompressionSpec, GRAD_COMPRESSION_KINDS, MeshSpec,
                       PrecisionSpec, RunSpec, ServingSpec, build)

multidevice = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

SPEC_DIR = "examples/specs"


# ----------------------------- round-trips ---------------------------------

def test_default_spec_roundtrip_exact():
    s = RunSpec()
    assert RunSpec.from_json(s.to_json()) == s
    assert RunSpec.from_dict(s.to_dict()) == s


@settings(max_examples=25)
@given(st.integers(min_value=0, max_value=10 ** 6),
       st.integers(min_value=1, max_value=16),
       st.integers(min_value=1, max_value=16),
       st.integers(min_value=0, max_value=len(GRAD_COMPRESSION_KINDS) - 1),
       st.integers(min_value=0, max_value=2),
       st.integers(min_value=1, max_value=4096),
       st.floats(min_value=1e-6, max_value=1.0, width=32))
def test_spec_json_roundtrip_property(seed, d, m, comp_i, dtype_i, steps,
                                      lr):
    """RunSpec.from_json(spec.to_json()) == spec for random specs — every
    field class exercised: ints, floats (exact via JSON repr), None-able
    strings, nested frozen dataclasses."""
    s = RunSpec(
        arch="qwen2-0.5b", seed=seed,
        mesh=MeshSpec.host(d, m),
        precision=PrecisionSpec(
            compute_dtype=[None, "bfloat16", "float32"][dtype_i],
            packed_serving=bool(seed % 2),
            packed_matmul=[None, True, False][dtype_i]),
        compression=CompressionSpec(kind=GRAD_COMPRESSION_KINDS[comp_i]),
        train=dataclasses.replace(RunSpec().train, steps=steps,
                                  lr=float(lr)),
        data=dataclasses.replace(RunSpec().data, batch=d * 2, seed=seed))
    s2 = RunSpec.from_json(s.to_json())
    assert s2 == s
    # and the JSON itself is stable (canonical key order)
    assert s2.to_json() == s.to_json()


def test_spec_rejects_unknown_fields_and_bad_values():
    with pytest.raises(ValueError, match="unknown RunSpec fields"):
        RunSpec.from_dict({"archh": "x"})
    with pytest.raises(ValueError, match="unknown MeshSpec fields"):
        RunSpec.from_dict({"mesh": {"rows": 2}})
    with pytest.raises(ValueError, match="kind"):
        MeshSpec(kind="ring")
    with pytest.raises(ValueError, match="compute_dtype"):
        PrecisionSpec(compute_dtype="fp8")
    with pytest.raises(ValueError, match="CompressionSpec.kind"):
        CompressionSpec(kind="topk")
    with pytest.raises(ValueError, match="contradicts"):
        CompressionSpec(kind="int8-wire-2d", wire_layout="1d")


def test_cli_flags_equal_spec_file():
    """The acceptance contract: `--spec examples/specs/
    host_2x4_int8wire2d.json` parses to the SAME RunSpec value as the
    classic `--mesh 2x4 --grad-compression int8-wire-2d` flags."""
    from_flags = RunSpec.from_args(
        ["--mesh", "2x4", "--grad-compression", "int8-wire-2d"])
    from_file = RunSpec.from_args(
        ["--spec", f"{SPEC_DIR}/host_2x4_int8wire2d.json"])
    assert from_flags == from_file
    # flags override spec-file fields
    over = RunSpec.from_args(
        ["--spec", f"{SPEC_DIR}/host_2x4_int8wire2d.json",
         "--steps", "7", "--seed", "3"])
    assert over.train.steps == 7 and over.seed == 3
    assert over.data.seed == 3
    assert over.mesh == MeshSpec.host(2, 4)


def test_shipped_specs_roundtrip_exact():
    """Every shipped spec/plan file loads with its loader (plan_*.json
    are bare PrecisionPlans, the rest full RunSpecs), round-trips
    exactly, and re-emits byte-identically (the file IS the canonical
    serialization) — the same contract tools/check_specs.py gates."""
    import glob
    import os
    from repro.core.plan import PrecisionPlan
    paths = sorted(glob.glob(f"{SPEC_DIR}/*.json"))
    assert len(paths) >= 4, paths
    n_plans = 0
    for path in paths:
        loader = (PrecisionPlan
                  if os.path.basename(path).startswith("plan_")
                  else RunSpec)
        n_plans += loader is PrecisionPlan
        obj = loader.from_file(path)
        assert loader.from_json(obj.to_json()) == obj, path
        with open(path) as f:
            assert obj.to_json() == f.read(), path
    assert n_plans >= 1    # the golden mixed w4/w8 plan ships


def test_compression_layout_resolution():
    c = CompressionSpec(kind="int8-wire")
    assert c.resolved_wire_layout(1) == "1d"
    assert c.resolved_wire_layout(4) == "2d"       # auto-upgrade under TP
    assert CompressionSpec(kind="int8-wire-2d").resolved_wire_layout(1) \
        == "2d"
    pinned = CompressionSpec(kind="int8-wire", wire_layout="1d")
    assert pinned.resolved_wire_layout(4) == "1d"
    assert pinned.resolved_residual_layout(4) == "1d"


# ------------------------------- seeding -----------------------------------

def test_seed_threads_into_init_and_data():
    ctx0 = build(RunSpec())
    ctx3 = build(RunSpec.from_args(["--seed", "3"]))
    p0, _ = ctx0.init_state()
    p3, _ = ctx3.init_state()
    assert any(not np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(jax.tree.leaves(p0), jax.tree.leaves(p3)))
    b0 = ctx0.make_pipeline()(0)["tokens"]
    b3 = ctx3.make_pipeline()(0)["tokens"]
    assert not np.array_equal(np.asarray(b0), np.asarray(b3))
    # same seed reproduces bit-for-bit
    p0b, _ = build(RunSpec()).init_state()
    for a, b in zip(jax.tree.leaves(p0), jax.tree.leaves(p0b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------- no-global-leak --------------------------------

def test_two_contexts_no_retrace_no_perturbation():
    """Two RunContexts with different precision in one process: each
    jitted function traces ONCE under its own flags, repeated calls hit
    the cache (no retrace), outputs stay bit-identical across
    interleaving, and nothing leaks into the ambient defaults."""
    from repro.dist.perf import cast_for_matmul, get_compute_dtype

    ctx_fp = build(RunSpec())
    ctx_bf = build(RunSpec(precision=PrecisionSpec(
        compute_dtype="bfloat16")))
    traces = {"fp": 0, "bf": 0}

    def make(tag):
        def f(x):
            traces[tag] += 1          # runs at trace time only
            return cast_for_matmul(x).astype(jnp.float32) * 3.0
        return f

    j_fp = jax.jit(ctx_fp.wrap(make("fp")))
    j_bf = jax.jit(ctx_bf.wrap(make("bf")))
    x = jnp.asarray([1.0, 1.0 + 2.0 ** -12, -0.3], jnp.float32)
    y_fp1 = j_fp(x)
    y_bf1 = j_bf(x)
    y_fp2 = j_fp(x)
    y_bf2 = j_bf(x)
    assert traces == {"fp": 1, "bf": 1}, traces
    np.testing.assert_array_equal(np.asarray(y_fp1), np.asarray(y_fp2))
    np.testing.assert_array_equal(np.asarray(y_bf1), np.asarray(y_bf2))
    # the bf16 context really cast (1 + 2^-12 rounds away in bf16) — the
    # fp context really didn't; neither saw the other's dtype
    assert float(y_fp1[1]) != float(y_bf1[1])
    # and nothing escaped the scopes
    assert get_compute_dtype() is None


def test_two_contexts_training_isolated():
    """Full train steps from two specs (fp32 vs bf16 compute) interleave
    in one process without retracing or perturbing each other."""
    spec = dataclasses.replace(
        RunSpec(), train=dataclasses.replace(RunSpec().train, steps=3),
        data=dataclasses.replace(RunSpec().data, batch=2, seq=8))
    ctx_a = build(spec)
    ctx_b = build(dataclasses.replace(
        spec, precision=PrecisionSpec(compute_dtype="bfloat16")))
    sa, sb = ctx_a.init_training(), ctx_b.init_training()
    with ctx_a.mesh:
        ma0 = {k: float(v) for k, v in sa.step(0).items()}
    with ctx_b.mesh:
        mb0 = {k: float(v) for k, v in sb.step(0).items()}
    # re-run step 1 then step 0's batch again on a FRESH setup of A: the
    # interleaved A must match the isolated A bit-for-bit
    with ctx_b.mesh:
        sb.step(1)
    sa_iso = build(spec).init_training()
    with ctx_a.mesh:
        ma1 = sa.step(1)
    with build(spec).mesh:
        sa_iso.step(0)
        ma1_iso = sa_iso.step(1)
    for k in ma1:
        assert float(ma1[k]) == float(ma1_iso[k]), k
    # bf16 compute is a genuinely different program
    assert ma0["loss"] != mb0["loss"]


# ------------------------------ HLO identity -------------------------------
# the stripper and the spec-side lowering are the shared
# repro.analysis parsers — the identity the tests pin here is asserted
# over the SAME artifact the program linter (tools/lint_programs.py)
# gates, not a subtly different re-lowering

_strip_metadata = strip_metadata
_spec_step_hlo = train_step_hlo       # argv list -> compiled HLO text
_spec_hlo_from_spec = train_step_hlo  # RunSpec   -> compiled HLO text


def _legacy_step_hlo(mesh_str, grad_compression):
    """The pre-RunSpec launcher wiring: hand-built shardings + the axis
    registry bound directly (what launch.train did before repro.api,
    with the removed ``set_axes`` global swapped for its scoped
    equivalent — same registry value, same trace)."""
    from repro.configs import get
    from repro.data import DataSpec, make_pipeline
    from repro.dist import EFState, collectives, ef_compress, ef_init
    from repro.dist.axes import AxisRegistry, axis_scope
    from repro.dist.sharding import (batch_sharding, ef_residual_sharding,
                                     replicated, shard_tree)
    from repro.models import model_for
    from repro.optim import adamw_init
    from repro.train import TrainConfig, lm_loss, make_train_step

    cfg = get("qwen2-0.5b", smoke=True)
    M = model_for(cfg)
    d, m = (int(v) for v in mesh_str.split("x"))
    mesh = jax.make_mesh((d, m), ("data", "model"))
    with axis_scope(AxisRegistry(("data",), "model", d, m)):
        params, qstate = M.init(jax.random.PRNGKey(0), cfg)
        opt = adamw_init(params)
        pipe = make_pipeline(DataSpec(kind="lm", batch=4, seq=32,
                                      vocab=cfg.vocab))
        tcfg = TrainConfig(steps=20, lr=1e-3, beta0=1e-9, beta1=1e-7)
        fwd = lambda p, q, b, mode: M.forward(p, q, b, cfg, mode)
        dsize = collectives.data_axis_size(mesh)
        msize = collectives.model_axis_size(mesh)
        wire_kinds = ("int8-wire", "int8-wire-2d")
        wire_layout = ("2d" if (grad_compression == "int8-wire-2d"
                                or msize > 1) else "1d")
        wire = (grad_compression in wire_kinds
                and (dsize > 1 or (wire_layout == "2d" and msize > 1)))
        grad_tx = None
        ef_state = None
        if grad_compression in wire_kinds:
            if wire and wire_layout == "2d":
                ef_state = EFState(residual=collectives.ef_wire2d_init(
                    params, dsize, msize))
            elif wire:
                ef_state = EFState(residual=collectives.ef_wire_init(
                    params, dsize))
            else:
                grad_tx = lambda g, s: ef_compress(g, s, kind="int8")
                ef_state = ef_init(params)
        elif grad_compression != "none":
            grad_tx = lambda g, s: ef_compress(g, s,
                                               kind=grad_compression)
            ef_state = ef_init(params)
        step_fn = make_train_step(
            fwd, lambda out, b: lm_loss(out, b["tokens"]), tcfg,
            grad_tx=grad_tx, reduce="compressed" if wire else "full",
            mesh=mesh if wire else None,
            wire_layout=wire_layout if wire else "auto")
        with mesh:
            in_shardings = (shard_tree(params, mesh, "train"),
                            shard_tree(qstate, mesh, "train"),
                            type(opt)(step=replicated(mesh),
                                      mu=shard_tree(opt.mu, mesh, "train"),
                                      nu=shard_tree(opt.nu, mesh, "train")),
                            {"tokens": batch_sharding(mesh, 4, 2)},
                            replicated(mesh))
            donate = (0, 2)
            args = [params, qstate, opt, pipe(0), jnp.int32(0)]
            if ef_state is not None:
                res_sh = (ef_residual_sharding(
                    ef_state.residual, mesh, layout=wire_layout) if wire
                    else shard_tree(ef_state.residual, mesh, "train"))
                in_shardings += (EFState(residual=res_sh),)
                donate += (5,)
                args.append(ef_state)
            jitted = jax.jit(step_fn, in_shardings=in_shardings,
                             donate_argnums=donate)
            return jitted.lower(*args).compile().as_text()


def test_hlo_identity_1x1():
    """The spec-built step lowers to the same program as the legacy
    global-state wiring (single device, no compression)."""
    legacy = _legacy_step_hlo("1x1", "none")
    fresh = _spec_step_hlo(["--mesh", "1x1"])
    assert _strip_metadata(fresh) == _strip_metadata(legacy)


def test_hlo_identity_1x1_post_reduce_int8():
    legacy = _legacy_step_hlo("1x1", "int8")
    fresh = _spec_step_hlo(["--mesh", "1x1",
                            "--grad-compression", "int8"])
    assert _strip_metadata(fresh) == _strip_metadata(legacy)


@multidevice
@pytest.mark.parametrize("mesh_str", ["2x4", "4x2"])
def test_hlo_identity_wire2d(mesh_str):
    """The acceptance contract: `--spec examples/specs/
    host_2x4_int8wire2d.json` (and its flag twin on both mesh tests)
    lowers to the same compiled step as the legacy global wiring with
    `--mesh DxM --grad-compression int8-wire-2d`."""
    legacy = _legacy_step_hlo(mesh_str, "int8-wire-2d")
    if mesh_str == "2x4":
        argv = ["--spec", f"{SPEC_DIR}/host_2x4_int8wire2d.json"]
    else:
        argv = ["--mesh", mesh_str,
                "--grad-compression", "int8-wire-2d"]
    fresh = _spec_step_hlo(argv)
    assert _strip_metadata(fresh) == _strip_metadata(legacy)


# --------------------------- precision plans -------------------------------

def test_spec_plan_field_roundtrip():
    """A RunSpec with an embedded PrecisionPlan round-trips exactly, and
    a plan-free spec serializes with ``"plan": null``."""
    from repro.core.plan import LayerPlan, PrecisionPlan
    plan = PrecisionPlan(layers={
        "layers/mlp/up/kernel": LayerPlan(wire_bits=4, pack_bits=4)})
    s = RunSpec(plan=plan)
    s2 = RunSpec.from_json(s.to_json())
    assert s2 == s
    assert s2.plan.entry_for("layers/mlp/up/kernel/w").wire_bits == 4
    import json
    assert json.loads(RunSpec().to_json())["plan"] is None


def test_plan_flag_loads_plan_file():
    """``--plan plan.json`` attaches the width table to the spec; the
    shipped golden mixed plan is the fixture."""
    s = RunSpec.from_args(["--plan", f"{SPEC_DIR}/plan_mixed_w4w8.json"])
    assert s.plan is not None and not s.plan.is_uniform_int8
    assert s.plan.entry_for("layers/mlp/down/kernel").wire_bits == 4
    assert s.plan.entry_for("layers/attn/wq/kernel").wire_bits == 8
    assert s.plan.entry_for("embed/table").wire_bits == 8   # default


def test_uniform_plan_resolves_to_none():
    """build() normalizes both a missing plan and an explicit uniform
    int8 plan to None — consumers take the exact legacy trace."""
    from repro.core.plan import LayerPlan, PrecisionPlan
    assert build(RunSpec()).plan is None
    assert build(RunSpec(plan=PrecisionPlan())).plan is None
    mixed = PrecisionPlan(layers={"x": LayerPlan(wire_bits=4)})
    ctx = build(RunSpec(plan=mixed))
    assert ctx.plan is mixed
    assert ctx.plan_summary() == mixed.summary()
    assert build(RunSpec()).plan_summary() is None


def test_hlo_identity_uniform_plan_1x1():
    """Acceptance contract: a spec carrying the explicit uniform-int8
    plan compiles the byte-identical train step to the plan-free spec."""
    base = _spec_step_hlo(["--mesh", "1x1"])
    import json
    import tempfile
    d = json.loads(RunSpec.from_args(["--mesh", "1x1"]).to_json())
    d["plan"] = {"default": {"wire_bits": 8, "pack_bits": 8,
                             "scale_exp": None}, "layers": {}}
    with tempfile.NamedTemporaryFile("w", suffix=".json",
                                     delete=False) as f:
        json.dump(d, f)
    with_plan = _spec_step_hlo(["--spec", f.name])
    assert _strip_metadata(with_plan) == _strip_metadata(base)


@multidevice
def test_hlo_identity_uniform_plan_wire2d():
    """Same contract on the 2x4 int8-wire-2d mesh: the uniform plan must
    not perturb the compiled wire collective by a single instruction."""
    import dataclasses as dc
    from repro.core.plan import PrecisionPlan
    spec = RunSpec.from_file(f"{SPEC_DIR}/host_2x4_int8wire2d.json")
    base = _spec_hlo_from_spec(spec)
    with_plan = _spec_hlo_from_spec(dc.replace(spec,
                                               plan=PrecisionPlan()))
    assert _strip_metadata(with_plan) == _strip_metadata(base)


# --------------------------- serving contexts ------------------------------

def test_engine_snapshot_isolated_from_later_scopes():
    """An Engine built under one context keeps decoding identically even
    while another context with different precision is active — the
    engine's trace-time snapshot, not ambient state, governs it."""
    spec = RunSpec(arch="qwen2-0.5b", serving=ServingSpec(slots=2))
    ctx = build(spec)
    params, qstate = ctx.init_state()
    eng = ctx.make_engine(params, qstate, max_len=32)
    from repro.serving import Request
    r1 = Request(prompt=[3, 1, 4, 1], max_new=5)
    eng.run([r1])
    ctx_bf = build(dataclasses.replace(
        spec, precision=PrecisionSpec(compute_dtype="bfloat16")))
    with ctx_bf.activate():
        r2 = Request(prompt=[3, 1, 4, 1], max_new=5)
        eng.run([r2])          # traces/caches under the engine snapshot
    assert r1.out == r2.out


# ----------------------------- ServingSpec ---------------------------------

def test_serving_spec_roundtrip_and_validation():
    s = RunSpec(serving=ServingSpec(slots=4, kv_cache="plan",
                                    packed=True, prefix_reuse=True))
    assert RunSpec.from_json(s.to_json()) == s
    with pytest.raises(ValueError, match="kv_cache"):
        ServingSpec(kv_cache="int4")
    with pytest.raises(ValueError, match="slots"):
        ServingSpec(slots=0)
    with pytest.raises(ValueError, match="unknown ServingSpec fields"):
        RunSpec.from_dict({"serving": {"slotss": 2}})
    # CLI flags map onto the spec
    s2 = RunSpec.from_args(["--kv-cache", "int8", "--slots", "3"])
    assert s2.serving.kv_cache == "int8" and s2.serving.slots == 3
    # packed=None follows PrecisionSpec.packed_serving
    assert not ServingSpec().resolved_packed(PrecisionSpec())
    assert ServingSpec().resolved_packed(
        PrecisionSpec(packed_serving=True))
    assert not ServingSpec(packed=False).resolved_packed(
        PrecisionSpec(packed_serving=True))


def test_make_engine_removed_kwargs_rejected():
    """The one-release batch_slots/packed/plan kwarg shims are gone:
    make_engine must reject them with a pointer to the spec field, not
    silently pass them through to Engine."""
    ctx = build(RunSpec(arch="qwen2-0.5b", serving=ServingSpec(slots=4)))
    params, qstate = ctx.init_state()
    for kw, field in (("batch_slots", "serving.slots"),
                      ("packed", "serving.packed"),
                      ("plan", "RunSpec.plan")):
        with pytest.raises(TypeError, match=field.replace(".", r"\.")):
            ctx.make_engine(params, qstate, max_len=32, **{kw: 2})
    eng = ctx.make_engine(params, qstate, max_len=32)
    assert eng.slots == 4      # the spec field governs


def test_kv_cache_fp_hlo_identical_to_legacy_engine():
    """Acceptance contract: a spec with ``kv_cache="fp"`` (the default)
    compiles the byte-identical decode program to the pre-ServingSpec
    Engine construction — quantized-KV support must not perturb the fp
    decode path by a single instruction.  A kv-carrying plan under
    ``kv_cache="fp"`` must not either."""
    from repro.core.plan import LayerPlan, PrecisionPlan
    from repro.serving import Engine
    spec = RunSpec(arch="qwen2-0.5b", serving=ServingSpec(slots=2))
    ctx = build(spec)
    params, qstate = ctx.init_state()
    _, fresh = ctx.make_engine(params, qstate, max_len=32).decode_program()
    # the legacy surface: direct Engine kwargs, no serving spec at all
    legacy_eng = Engine(ctx.model, params, qstate, ctx.cfg,
                        batch_slots=2, max_len=32)
    _, legacy = legacy_eng.decode_program()
    assert _strip_metadata(fresh) == _strip_metadata(legacy)
    # a plan carrying narrow KV widths changes nothing while kv_cache=fp
    kv_plan = PrecisionPlan(default=LayerPlan(kv_bits=4))
    ctx2 = build(dataclasses.replace(spec, plan=kv_plan))
    _, fp_planned = ctx2.make_engine(params, qstate,
                                     max_len=32).decode_program()
    assert _strip_metadata(fp_planned) == _strip_metadata(legacy)


def test_kv_cache_plan_resolution():
    """kv_cache mode -> storage width: fp -> None, int8 -> 8, plan ->
    the narrowest kv_bits across entries (uniform wire/pack plans are
    NOT normalized away for KV resolution)."""
    from repro.core.plan import LayerPlan, PrecisionPlan
    from repro.serving import resolve_kv_bits
    assert resolve_kv_bits("fp", None) is None
    assert resolve_kv_bits("int8", None) == 8
    assert resolve_kv_bits("plan", None) == 8
    plan = PrecisionPlan(layers={"layers/attn/wk/kernel":
                                 LayerPlan(kv_bits=4)})
    assert resolve_kv_bits("plan", plan) == 4
    # a kv-only plan is wire/pack-uniform: build() normalizes ctx.plan
    # to None, but make_engine still resolves kv widths from the full one
    ctx = build(RunSpec(arch="qwen2-0.5b", plan=plan,
                        serving=ServingSpec(slots=2, kv_cache="plan")))
    assert ctx.plan is None
    params, qstate = ctx.init_state()
    eng = ctx.make_engine(params, qstate, max_len=32)
    assert eng.kv_bits == 4
