import jax
import pytest

# smoke tests and benches see exactly 1 device — the 512-device flag is set
# ONLY inside repro.launch.dryrun (per the brief).
jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
