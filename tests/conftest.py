import importlib.util
import os
import sys

import jax
import pytest

# smoke tests and benches see exactly 1 device — the 512-device flag is set
# ONLY inside repro.launch.dryrun (per the brief).
jax.config.update("jax_platform_name", "cpu")

# `pip install -e .[test]` brings the real hypothesis; containers without
# network fall back to the vendored stub (same API subset, deterministic).
if importlib.util.find_spec("hypothesis") is None:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_stub
    sys.modules["hypothesis"] = _hypothesis_stub
    sys.modules["hypothesis.strategies"] = _hypothesis_stub.strategies


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
