"""Calibration (Eq. 3) + bit-exact fixed-point emulation ("proxy model")."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import hgq
from repro.core.calibrate import (FixedSpec, assert_no_overflow,
                                  fixed_spec_from_range)
from repro.core.fixedpoint import to_fixed
from repro.core.hgq import ActState
from repro.core.quantizer import quantize_inference

settings.register_profile("ci", max_examples=50, deadline=None)
settings.load_profile("ci")


@given(st.lists(st.floats(-64, 64, allow_nan=False, width=32), min_size=1,
                max_size=64), st.integers(0, 8))
def test_calibrated_spec_never_overflows_calib_data(xs, f):
    """The paper's guarantee: integer bits chosen by Eq. 3 on the calib data
    cover every quantized calib value."""
    x = jnp.asarray(xs, jnp.float32)
    ff = jnp.float32(f)
    st_ = ActState(vmin=jnp.min(x), vmax=jnp.max(x))
    spec = fixed_spec_from_range(st_, ff)
    assert bool(assert_no_overflow(x, spec, ff))


@given(st.lists(st.floats(-64, 64, allow_nan=False, width=32), min_size=1,
                max_size=64), st.integers(0, 8))
def test_fixed_emulation_bit_exact_in_range(xs, f):
    """to_fixed(x) == quantize_inference(x) when the calibrated spec covers
    x — software/firmware correspondence (paper SSec. IV)."""
    x = jnp.asarray(xs, jnp.float32)
    ff = jnp.float32(f)
    spec = fixed_spec_from_range(ActState(jnp.min(x), jnp.max(x)), ff)
    got = to_fixed(x, spec, ff)
    want = quantize_inference(x, ff)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_wraparound_overflow_eq1():
    """Eq. (1): signed fixed<3,3> covers [-4, 3]; 4 wraps to -4."""
    spec = FixedSpec(bits=jnp.float32(3), int_bits=jnp.float32(3),
                     signed=jnp.bool_(True))
    f = jnp.float32(0.0)
    assert float(to_fixed(jnp.float32(3.0), spec, f)) == 3.0
    assert float(to_fixed(jnp.float32(4.0), spec, f)) == -4.0
    assert float(to_fixed(jnp.float32(5.0), spec, f)) == -3.0
    assert float(to_fixed(jnp.float32(-5.0), spec, f)) == 3.0


def test_wraparound_exact_at_ulp_off_widths():
    """b=13 is a width where jnp.exp2 is an ulp off, which corrupted the
    wrap modulus exactly at the +-2^(b-1) boundary (regression)."""
    spec = FixedSpec(bits=jnp.float32(13), int_bits=jnp.float32(13),
                     signed=jnp.bool_(True))
    f = jnp.float32(0.0)
    assert float(to_fixed(jnp.float32(4095.0), spec, f)) == 4095.0
    assert float(to_fixed(jnp.float32(4096.0), spec, f)) == -4096.0
    assert float(to_fixed(jnp.float32(-4097.0), spec, f)) == 4095.0


def test_unsigned_wraparound_eq2():
    spec = FixedSpec(bits=jnp.float32(2), int_bits=jnp.float32(2),
                     signed=jnp.bool_(False))
    f = jnp.float32(0.0)
    assert float(to_fixed(jnp.float32(3.0), spec, f)) == 3.0
    assert float(to_fixed(jnp.float32(4.0), spec, f)) == 0.0


def test_jet_model_proxy_bit_exact():
    """End-to-end proxy-model check on the jet tagger: EVAL-mode forward is
    reproducible and CALIB-mode ranges cover later evaluations."""
    from repro.data import jet_batch
    from repro.models import JetTagger
    from repro.nn import HGQConfig
    cfg = HGQConfig(weight_gran="per_parameter", act_gran="per_parameter",
                    init_weight_f=3, init_act_f=3)
    p, q = JetTagger.init(jax.random.PRNGKey(0), cfg)
    calib = jet_batch(0, 0, 512)
    # calibration pass: exact range accumulation
    _, q_cal, _ = JetTagger.forward(p, q, calib, mode=hgq.CALIB)
    # the same data in EVAL mode must produce values whose quantized outputs
    # fit the calibrated ranges (spot-check the input quantizer)
    spec = fixed_spec_from_range(q_cal["inp"], p["inp_f"])
    assert bool(assert_no_overflow(calib["x"], spec, p["inp_f"]))
    # determinism of the quantized forward
    o1, _, _ = JetTagger.forward(p, q_cal, calib, mode=hgq.EVAL)
    o2, _, _ = JetTagger.forward(p, q_cal, calib, mode=hgq.EVAL)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
