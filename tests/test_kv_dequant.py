"""kernels.kv_dequant — the quantized-KV-cache kernel family.

Pins the Pallas kernels (forced through interpret mode on CPU) against
the jnp reference semantics: elementwise quantize/dequant must be
bit-identical, the nibble pack must round-trip exactly, and the fused
dequant-attention read must match the reference attention on the same
int8 buffers to fp32 tolerance (and exactly with probs quantization,
which snaps both paths to the same 2^-f grid).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.kv_dequant import (kv_attention_decode, kv_dequant,
                                      kv_pack, kv_quantize, kv_unpack,
                                      use_fused_kernel)
from repro.kernels.kv_dequant import ref

KEY = jax.random.PRNGKey(11)


def _rows(shape, scale=3.0, key=KEY):
    return scale * jax.random.normal(key, shape, jnp.float32)


def test_backend_dispatch_cpu():
    # this suite runs on CPU: the jnp reference is the fast path, and
    # the kernel route must be forceable in interpret mode
    assert jax.default_backend() == "cpu"
    assert use_fused_kernel() is False


@pytest.mark.parametrize("bits", [8, 5, 4])
def test_kernel_quantize_bit_identical(bits):
    x = _rows((6, 7, 2, 64))
    q_ref, f_ref = kv_quantize(x, bits, use_kernel=False)
    q_k, f_k = kv_quantize(x, bits, use_kernel=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(q_ref), np.asarray(q_k))
    np.testing.assert_array_equal(np.asarray(f_ref), np.asarray(f_k))
    qmax = 2 ** (bits - 1) - 1
    assert int(np.max(np.abs(np.asarray(q_ref)))) <= qmax


@pytest.mark.parametrize("bits", [8, 4])
def test_kernel_dequant_bit_identical_and_bounded(bits):
    x = _rows((5, 3, 48))
    q, f = kv_quantize(x, bits, use_kernel=False)
    d_ref = kv_dequant(q, f, use_kernel=False)
    d_k = kv_dequant(q, f, use_kernel=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(d_ref), np.asarray(d_k))
    # reconstruction error bounded by half a grid step per row
    step = np.exp2(-np.asarray(f, np.float32))
    err = np.max(np.abs(np.asarray(d_ref) - np.asarray(x)), axis=-1)
    assert np.all(err <= 0.5 * step + 1e-7)


def test_nibble_pack_roundtrip_exact():
    x = _rows((4, 9, 32))
    q, _ = kv_quantize(x, 4, use_kernel=False)
    packed = kv_pack(q)
    assert packed.shape == q.shape[:-1] + (q.shape[-1] // 2,)
    assert packed.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(kv_unpack(packed, 32)),
                                  np.asarray(q))


def _ring(B, W, KV, hd, bits, packed, key):
    k1, k2 = jax.random.split(key)
    km, kf = ref.kv_quantize_ref(_rows((B, W, KV, hd), key=k1), bits)
    vm, vf = ref.kv_quantize_ref(_rows((B, W, KV, hd), key=k2), bits)
    if packed:
        km, vm = kv_pack(km), kv_pack(vm)
    return km, kf, vm, vf


@pytest.mark.parametrize("window,probs_f", [(None, None), (8, None),
                                            (None, 6.0)])
def test_fused_attention_matches_ref(window, probs_f):
    B, S, KV, G, hd, W = 2, 1, 2, 2, 64, 16
    H = KV * G
    qh = _rows((B, S, H, hd), scale=1.0)
    km, kf, vm, vf = _ring(B, W, KV, hd, 8, False, KEY)
    qpos = jnp.asarray([[13], [9]], jnp.int32)
    # ring layout: slot s holds global position qpos - ((qpos - s) % W);
    # a few slots negative = never written
    tpos = jnp.stack([jnp.arange(W) - 2, jnp.arange(W) - 5]).astype(
        jnp.int32)
    pf = None if probs_f is None else jnp.float32(probs_f)
    out_ref = kv_attention_decode(qh, km, kf, vm, vf, qpos, tpos,
                                  window=window, n_kv=KV, probs_f=pf,
                                  use_kernel=False)
    out_k = kv_attention_decode(qh, km, kf, vm, vf, qpos, tpos,
                                window=window, n_kv=KV, probs_f=pf,
                                use_kernel=True, interpret=True)
    a, b = np.asarray(out_ref, np.float32), np.asarray(out_k, np.float32)
    tol = 0.0 if probs_f is not None else 1e-5
    assert float(np.max(np.abs(a - b))) <= tol, np.max(np.abs(a - b))


def test_fused_attention_packed_nibbles():
    B, S, KV, G, hd, W = 1, 1, 2, 2, 64, 16
    qh = _rows((B, S, KV * G, hd), scale=1.0)
    km, kf, vm, vf = _ring(B, W, KV, hd, 4, True, KEY)
    qpos = jnp.asarray([[11]], jnp.int32)
    tpos = jnp.arange(W, dtype=jnp.int32)[None, :] - 4
    out_ref = kv_attention_decode(qh, km, kf, vm, vf, qpos, tpos,
                                  window=None, n_kv=KV, use_kernel=False)
    out_k = kv_attention_decode(qh, km, kf, vm, vf, qpos, tpos,
                                window=None, n_kv=KV, use_kernel=True,
                                interpret=True)
    a, b = np.asarray(out_ref, np.float32), np.asarray(out_k, np.float32)
    assert float(np.max(np.abs(a - b))) <= 1e-5


def test_quantized_cache_container():
    from repro.serving import quantized_cache
    c8 = quantized_cache((3, 2, 16, 2, 64), 8)
    assert c8.k.shape == (3, 2, 16, 2, 64) and c8.k.dtype == jnp.int8
    assert c8.kf.shape == (3, 2, 16, 2) and c8.kf.dtype == jnp.int8
    c4 = quantized_cache((3, 2, 16, 2, 64), 4)
    assert c4.k.shape == (3, 2, 16, 2, 32)   # nibble-packed head dim
    with pytest.raises(ValueError, match="even head dim"):
        quantized_cache((2, 16, 2, 63), 4)


def test_kv_bytes_per_token_formula():
    from repro.serving import kv_bytes_per_token
    # fp: 2 tensors * KV * hd * 2 bytes * layers
    assert kv_bytes_per_token(2, 64, 4, None) == 2 * 2 * 64 * 2 * 4
    # int8: mantissa byte per element + one exponent byte per row
    assert kv_bytes_per_token(2, 64, 4, 8) == 2 * 2 * (64 + 1) * 4
    # nibble: two mantissas per byte
    assert kv_bytes_per_token(2, 64, 4, 4) == 2 * 2 * (32 + 1) * 4
