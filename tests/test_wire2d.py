"""dist.collectives 2D (data x model) sliced wire collective.

Single-device tests drive the collective-free reference
(``simulate_wire_pmean_2d``) plus the slice-layout/bytes/EF-property
contracts — including the hypothesis property that 1D and 2D deliver
identical time-averaged mean gradients on random shapes/meshes.  The
``@multidevice`` tests (CI job with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``) assert the real
``shard_map`` path matches the reference bit-for-bit on 2x4 AND 4x2
meshes, that a pure-TP 1xM mesh takes the sliced path with no data-axis
exchange, that the compressed-2d train step tracks the post-reduce loss
curve with s8-only gradient collectives, and that checkpoint resume of
the sliced residual is exact."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.analysis import SCALAR_MAX, parse_collectives
from repro.dist import EFState, ef_init, ef_compress
from repro.dist.collectives import (data_axis_size, ef_wire2d_init,
                                    ef_wire_init, ef_wire_pmean_2d,
                                    model_axis_size, record_wire_bytes,
                                    simulate_wire_pmean,
                                    simulate_wire_pmean_2d,
                                    tp_replication_bytes, wire2d_leaf_bytes,
                                    wire2d_slice_len, wire_bytes_model)
from repro.dist.sharding import ef_residual_sharding, model_axis_for

multidevice = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


def _stacked(key, n=2):
    """A per-shard tree with a model-shardable matrix, a stacked [L, ...]
    leaf (under the ``layers`` container, which marks it stacked by
    path), a flat (model-replicated) vector, and a scalar."""
    ks = jax.random.split(key, 4)
    return {"w": jax.random.normal(ks[0], (n, 6, 8)),
            "layers": jax.random.normal(ks[1], (n, 3, 8, 6)),
            "vec": jax.random.normal(ks[2], (n, 17)),
            "scalar": jax.random.normal(ks[3], (n,))}


def _init_res(tree, D, M):
    return ef_wire2d_init({k: v[0] for k, v in tree.items()}, D, M)


# ----------------------------- slice layout ---------------------------------

def test_model_axis_rule_matches_param_placement():
    assert model_axis_for((6, 8), 4) == 1       # larger trailing axis
    assert model_axis_for((16, 8), 4) == 0
    assert model_axis_for((3, 8, 6), 2) == 1    # leading L stays stacked
    assert model_axis_for((6, 9), 4) is None    # not divisible
    assert model_axis_for((17,), 4) is None     # rank < 2
    assert model_axis_for((6, 8), 1) is None


def test_wire2d_slice_len_padding():
    # model-shardable: block of 48/4=12, padded to D=2 chunks -> 12
    assert wire2d_slice_len((6, 8), 2, 4) == 12
    # flat: ceil(17/4)=5, padded to D=2 -> 6
    assert wire2d_slice_len((17,), 2, 4) == 6
    # scalar: one element, one slice
    assert wire2d_slice_len((), 2, 4) == 2


def test_wire2d_init_shapes():
    tree = _stacked(jax.random.PRNGKey(0), 2)
    res = _init_res(tree, 2, 4)
    for k, leaf in res.items():
        assert leaf.shape[:2] == (2, 4), k
        assert leaf.shape[2] == wire2d_slice_len(tree[k].shape[1:], 2, 4), k
        assert not np.asarray(leaf).any()


# ------------------------- reference semantics ------------------------------

@pytest.mark.parametrize("D,M", [(2, 4), (4, 2), (1, 8)])
def test_simulate_2d_delivers_near_mean(D, M):
    tree = _stacked(jax.random.PRNGKey(0), D)
    delivered, residual = simulate_wire_pmean_2d(tree, _init_res(tree, D, M),
                                                 M, "int8")
    for k in tree:
        true = np.mean(np.asarray(tree[k]), axis=0)
        grid = np.max(np.abs(np.asarray(tree[k]))) / 127 * 2
        np.testing.assert_allclose(np.asarray(delivered[k]), true,
                                   atol=4 * grid)
        assert residual[k].shape == (D, M,
                                     wire2d_slice_len(tree[k].shape[1:],
                                                      D, M))


def test_simulate_2d_stacked_leaf_per_layer_grids():
    """The per-layer grid survives the model slicing: an outlier layer in
    a stacked [L, ...] leaf must not crush the other layers.  The leaf is
    marked stacked explicitly (the metadata override; a ``layers`` path
    would derive the same)."""
    e = jnp.ones((2, 3, 8, 6)) * 1e-3
    e = e.at[:, 1].mul(1e4)
    delivered, _ = simulate_wire_pmean_2d(
        {"w": e}, ef_wire2d_init({"w": e[0]}, 2, 2), 2, "int8",
        stacked={"w": True})
    err = np.abs(np.asarray(delivered["w"]) - np.mean(np.asarray(e), axis=0))
    for layer in range(3):
        own_grid = float(np.max(np.abs(np.asarray(e[:, layer])))) / 127
        assert err[layer].max() <= 2.5 * own_grid, layer
    assert err[0].max() < 1e-4


def test_simulate_2d_unmarked_3d_leaf_single_grid():
    """Regression (rank-sniffing bug): a rank-3 leaf NOT under a stacked
    container gets ONE quantization grid in the wire path too — the
    delivered mean of a uniform-magnitude tensor with one dominant slice
    lands on the single global grid."""
    e = jnp.ones((2, 3, 8, 6)) * 1e-3
    e = e.at[:, 1].mul(1e4)
    delivered, _ = simulate_wire_pmean_2d(
        {"w": e}, ef_wire2d_init({"w": e[0]}, 2, 2), 2, "int8")
    # one global grid (step ~ amax/127 ~ 0.08): the 1e-3 slices floor to
    # exactly 0 on the first step (their EF residual recovers them over
    # time); the old per-slice grids delivered them at fine resolution
    # immediately, which is the bug for a genuine 3-D tensor
    got = np.asarray(delivered["w"])
    assert np.all(got[0] == 0.0) and np.all(got[2] == 0.0), got
    step = float(np.max(np.abs(np.asarray(e)))) / 127.0
    np.testing.assert_allclose(got[1], 10.0, atol=2 * step)


def test_simulate_2d_bad_kind_raises():
    with pytest.raises(ValueError, match="int8"):
        simulate_wire_pmean_2d({"w": jnp.zeros((2, 4))},
                               {"w": jnp.zeros((2, 2, 2))}, 2, "fp4")


# ------------------------ error-feedback property ---------------------------

def test_ef2d_time_average_unbiased():
    """Over K steps of a constant gradient, the 2D path's time-averaged
    delivered gradient telescopes to the true mean on BOTH axes (the
    phase-1/phase-2 errors stay within each (d, m) slice)."""
    K, D, M = 14, 2, 4
    tree = _stacked(jax.random.PRNGKey(3), D)
    res = _init_res(tree, D, M)
    acc = {k: jnp.zeros(v.shape[1:]) for k, v in tree.items()}
    for _ in range(K):
        d, res = simulate_wire_pmean_2d(tree, res, M, "int8")
        acc = {k: acc[k] + d[k] for k in acc}
    for k in tree:
        true = np.mean(np.asarray(tree[k]), axis=0)
        grid = max(float(np.max(np.abs(np.asarray(tree[k])))), 1e-30) \
            / 127 * 2
        np.testing.assert_allclose(np.asarray(acc[k]) / K, true,
                                   atol=grid + 1e-7)


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=1, max_value=4),
       st.integers(min_value=1, max_value=4),
       st.lists(st.floats(min_value=-1.0, max_value=1.0), min_size=4,
                max_size=24),
       st.integers(min_value=2, max_value=13))
def test_property_1d_2d_same_time_averaged_mean(D, M, vals, rows):
    """On random shapes and DxM meshes, the 1D wire and the 2D sliced
    wire deliver IDENTICAL time-averaged mean gradients — both telescope
    to the true mean within one grid step."""
    K = 10
    base = jnp.asarray(vals, jnp.float32)
    # a [D, rows, len(vals)] matrix leaf: model-shardable iff divisible
    fac = (0.5 + jnp.arange(D, dtype=jnp.float32))[:, None, None]
    gs = fac * jnp.broadcast_to(base, (rows, base.shape[0]))[None]
    tree = {"w": gs}
    true = np.mean(np.asarray(gs), axis=0)
    grid = max(float(jnp.max(jnp.abs(gs))), 1e-30) / 127.0 * 2

    res1 = ef_wire_init({"w": true}, D)
    acc1 = jnp.zeros_like(gs[0])
    for _ in range(K):
        d, res1 = simulate_wire_pmean({"w": gs + res1["w"]}, "int8")
        acc1 = acc1 + d["w"]

    res2 = ef_wire2d_init({"w": gs[0]}, D, M)
    acc2 = jnp.zeros_like(gs[0])
    for _ in range(K):
        d, res2 = simulate_wire_pmean_2d(tree, res2, M, "int8")
        acc2 = acc2 + d["w"]

    tol = grid + 1e-7
    np.testing.assert_allclose(np.asarray(acc1) / K, true, atol=tol)
    np.testing.assert_allclose(np.asarray(acc2) / K, true, atol=tol)
    np.testing.assert_allclose(np.asarray(acc2) / K, np.asarray(acc1) / K,
                               atol=2 * tol)


# ----------------------------- mixed widths ---------------------------------

def test_simulate_2d_mixed_widths():
    """Per-leaf widths through the 2D sliced path: the w4 leaf lands
    within its int4 grid of the true mean, the w8 leaves stay
    bit-identical to the widths-free trace."""
    D, M = 2, 4
    tree = _stacked(jax.random.PRNGKey(8), D)
    widths = {"w": 4, "layers": 4, "vec": 8, "scalar": 8}
    d, r = simulate_wire_pmean_2d(tree, _init_res(tree, D, M), M, "int8",
                                  widths=widths)
    d8, r8 = simulate_wire_pmean_2d(tree, _init_res(tree, D, M), M,
                                    "int8")
    for k in ("w", "layers"):
        true = np.mean(np.asarray(tree[k]), axis=0)
        grid4 = np.max(np.abs(np.asarray(tree[k]))) / 7 * 2
        np.testing.assert_allclose(np.asarray(d[k]), true, atol=4 * grid4)
        assert not np.array_equal(np.asarray(d[k]), np.asarray(d8[k]))
    for k in ("vec", "scalar"):
        np.testing.assert_array_equal(np.asarray(d[k]), np.asarray(d8[k]))
        np.testing.assert_array_equal(np.asarray(r[k]), np.asarray(r8[k]))


def test_ef2d_mixed_time_average_unbiased():
    """EF still telescopes to the true mean when leaves ride different
    widths — the w4 leaf just converges on its coarser grid."""
    K, D, M = 14, 2, 4
    tree = _stacked(jax.random.PRNGKey(9), D)
    widths = {"w": 4, "layers": 8, "vec": 4, "scalar": 8}
    res = _init_res(tree, D, M)
    acc = {k: jnp.zeros(v.shape[1:]) for k, v in tree.items()}
    for _ in range(K):
        d, res = simulate_wire_pmean_2d(tree, res, M, "int8",
                                        widths=widths)
        acc = {k: acc[k] + d[k] for k in acc}
    for k in tree:
        true = np.mean(np.asarray(tree[k]), axis=0)
        qmax = 7.0 if widths[k] <= 4 else 127.0
        grid = max(float(np.max(np.abs(np.asarray(tree[k])))), 1e-30) \
            / qmax * 2
        np.testing.assert_allclose(np.asarray(acc[k]) / K, true,
                                   atol=grid + 1e-7)


# ------------------------------ byte model ----------------------------------

def test_wire2d_bytes_beat_1d_with_tp_replication():
    """The acceptance ratio, analytically: on 2x4 and 4x2 meshes the 2D
    sliced exchange must cut total per-device wire bytes >= 1.9x vs the
    1D path (whose model-replicated shard_map costs an fp32 model-axis
    all_gather per model-sharded gradient leaf on top of its data-axis
    int8 phases)."""
    shape = (512, 1024)
    elems = 512 * 1024
    for (D, M) in [(2, 4), (4, 2)]:
        b2d = wire2d_leaf_bytes(shape, D, M, "int8")
        b1d = (wire_bytes_model(elems, D, "int8", 1)
               + tp_replication_bytes(shape, M))
        assert b1d / b2d >= 1.9, (D, M, b1d, b2d)
    # no model axis -> no replication cost and no model gather
    assert tp_replication_bytes(shape, 1) == 0.0
    assert tp_replication_bytes((17,), 8) == 0.0


# --------------------------- multi-device path ------------------------------

@multidevice
@pytest.mark.parametrize("D,M", [(2, 4), (4, 2)])
def test_wire2d_shard_map_matches_simulate(D, M):
    mesh = jax.make_mesh((D, M), ("data", "model"))
    assert data_axis_size(mesh) == D and model_axis_size(mesh) == M
    tree = _stacked(jax.random.PRNGKey(1), D)
    res = _init_res(tree, D, M)
    with mesh:
        res_p = jax.device_put(res, ef_residual_sharding(res, mesh, "2d"))
        for kind in ("int8", "bf16"):
            d, r = jax.jit(lambda t, rr, k=kind: ef_wire_pmean_2d(
                t, rr, mesh, k))(tree, res_p)
            ds, rs = simulate_wire_pmean_2d(tree, res, M, kind)
            for k in tree:
                np.testing.assert_array_equal(np.asarray(d[k]),
                                              np.asarray(ds[k]))
                np.testing.assert_array_equal(np.asarray(r[k]),
                                              np.asarray(rs[k]))


@multidevice
@pytest.mark.parametrize("D,M", [(2, 4), (4, 2)])
def test_wire2d_shard_map_matches_simulate_mixed_widths(D, M):
    """The acceptance contract for mixed widths: the real 2D shard_map
    collective is bit-for-bit equal to its simulator when leaves ride
    different wire widths."""
    mesh = jax.make_mesh((D, M), ("data", "model"))
    tree = _stacked(jax.random.PRNGKey(10), D)
    widths = {"w": 4, "layers": 4, "vec": 8, "scalar": 8}
    res = _init_res(tree, D, M)
    with mesh:
        res_p = jax.device_put(res, ef_residual_sharding(res, mesh, "2d"))
        d, r = jax.jit(lambda t, rr: ef_wire_pmean_2d(
            t, rr, mesh, "int8", widths=widths))(tree, res_p)
    ds, rs = simulate_wire_pmean_2d(tree, res, M, "int8", widths=widths)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(d[k]), np.asarray(ds[k]))
        np.testing.assert_array_equal(np.asarray(r[k]), np.asarray(rs[k]))


@multidevice
@pytest.mark.parametrize("kind,bits", [("int8", 8), ("int8", 4),
                                       ("bf16", 8)])
def test_wire2d_leaf_bytes_pins_measured_trace(kind, bits):
    """wire2d_leaf_bytes == the recorder's measured per-leaf trace bytes
    at the leaf's ACTUAL wire width — for int8 at w8, nibble-packed w4,
    and bf16 (the satellite contract: the byte model may not drift from
    the traced collectives)."""
    D, M = 2, 4
    mesh = jax.make_mesh((D, M), ("data", "model"))
    full = _stacked(jax.random.PRNGKey(11), D)
    with mesh:
        for name in ("w", "layers", "vec", "scalar"):
            tree = {name: full[name]}
            res = _init_res(tree, D, M)
            res_p = jax.device_put(res,
                                   ef_residual_sharding(res, mesh, "2d"))
            fn = jax.jit(lambda t, rr, n_=name: ef_wire_pmean_2d(
                t, rr, mesh, kind, widths={n_: bits}))
            with record_wire_bytes() as rec:
                fn.lower(tree, res_p)
            stacked = name == "layers"
            want = wire2d_leaf_bytes(full[name].shape[1:], D, M, kind,
                                     stacked=stacked, bits=bits)
            assert rec.total() == want, (name, kind, bits,
                                         rec.records, want)


@multidevice
def test_wire2d_pure_tp_takes_sliced_path_no_data_exchange():
    """--mesh 1xM (pure TP): the sliced path runs — and the trace emits
    NO data-axis exchange (no all_to_all, no data all_gather), only the
    model-axis rematerialization plus the scale pmax."""
    mesh = jax.make_mesh((1, 8), ("data", "model"))
    tree = _stacked(jax.random.PRNGKey(2), 1)
    res = _init_res(tree, 1, 8)
    with mesh:
        res_p = jax.device_put(res, ef_residual_sharding(res, mesh, "2d"))
        fn = jax.jit(lambda t, r: ef_wire_pmean_2d(t, r, mesh, "int8"))
        with record_wire_bytes() as rec:
            fn.lower(tree, res_p)
        d, r = fn(tree, res_p)
    ops = {op for op, _ in rec.records}
    assert not any("all_to_all" in op for op in ops), ops
    assert ops == {"pmax.scale", "all_gather.int8.model"}, ops
    # the delivered mean IS the single shard's quantized gradient
    ds, _ = simulate_wire_pmean_2d(tree, res, 8, "int8")
    for k in tree:
        np.testing.assert_array_equal(np.asarray(d[k]), np.asarray(ds[k]))


@multidevice
def test_wire2d_pure_tp_train_step_selected():
    """make_train_step(reduce='compressed') on a 1xM mesh must take the
    sliced wire path (NOT the single-device post-reduce fallback): the
    step accepts the [1, M, C] residual and trains."""
    from repro.data import DataSpec, make_pipeline
    from repro.models import JetTagger
    from repro.nn import HGQConfig
    from repro.optim import adamw_init
    from repro.train import TrainConfig, make_train_step, softmax_xent

    cfg = HGQConfig(weight_gran="per_parameter", act_gran="per_parameter",
                    init_weight_f=2, init_act_f=2)
    p0, q0 = JetTagger.init(jax.random.PRNGKey(0), cfg)
    fwd = lambda p, q, b, mode: JetTagger.forward(p, q, b, mode)
    loss = lambda out, b: softmax_xent(out, b["y"])
    pipe = make_pipeline(DataSpec(kind="jet", batch=64))
    tc = TrainConfig(steps=4, lr=3e-3)
    mesh = jax.make_mesh((1, 8), ("data", "model"))
    step = make_train_step(fwd, loss, tc, reduce="compressed", mesh=mesh)
    with mesh:
        ec = EFState(residual=ef_wire2d_init(p0, 1, 8))
        p, q, o = p0, q0, adamw_init(p0)
        losses = []
        for s in range(4):
            p, q, o, m, ec = jax.jit(step)(p, q, o, pipe(s), jnp.int32(s),
                                           ec)
            losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    # residual kept the sliced layout end-to-end
    for leaf in jax.tree.leaves(ec.residual):
        assert leaf.shape[:2] == (1, 8)


@multidevice
def test_wire2d_vjp_composes():
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    tree = {"w": jax.random.normal(jax.random.PRNGKey(2), (2, 6, 8))}
    res = ef_wire2d_init({"w": tree["w"][0]}, 2, 4)
    with mesh:
        val, grads = jax.value_and_grad(
            lambda t: jnp.sum(ef_wire_pmean_2d(t, res, mesh,
                                               "int8")[0]["w"]))(tree)
    assert np.isfinite(float(val))
    np.testing.assert_allclose(np.asarray(grads["w"]), 0.5, atol=1e-6)


def _jet_setup():
    from repro.data import DataSpec, make_pipeline
    from repro.models import JetTagger
    from repro.nn import HGQConfig
    from repro.train import softmax_xent

    cfg = HGQConfig(weight_gran="per_parameter", act_gran="per_parameter",
                    init_weight_f=2, init_act_f=2)
    p0, q0 = JetTagger.init(jax.random.PRNGKey(0), cfg)
    fwd = lambda p, q, b, mode: JetTagger.forward(p, q, b, mode)
    loss = lambda out, b: softmax_xent(out, b["y"])
    pipe = make_pipeline(DataSpec(kind="jet", batch=256))
    return p0, q0, fwd, loss, pipe


@multidevice
def test_compressed_2d_step_tracks_post_reduce():
    """reduce='compressed' with the 2D layout on a 2x4 mesh trains to the
    same loss curve as the post-reduce int8 path.  (Unlike the 1D test,
    step 0 is only near-equal: the model-sharded grad in_specs make GSPMD
    genuinely TP-partition the forward, and HGQ's activation quantization
    amplifies fp reassociation to grid-step size.)"""
    from repro.optim import adamw_init
    from repro.train import TrainConfig, make_train_step

    p0, q0, fwd, loss, pipe = _jet_setup()
    tc = TrainConfig(steps=20, lr=3e-3, beta0=1e-7, beta1=1e-6)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    step_c = make_train_step(fwd, loss, tc, reduce="compressed", mesh=mesh,
                             wire_layout="2d")
    step_r = make_train_step(
        fwd, loss, tc, grad_tx=lambda g, s: ef_compress(g, s, kind="int8"))
    with mesh:
        jc, jr = jax.jit(step_c), jax.jit(step_r)
        pc, qc, oc = p0, q0, adamw_init(p0)
        ec = EFState(residual=ef_wire2d_init(p0, 2, 4))
        pr, qr, orr = p0, q0, adamw_init(p0)
        er = ef_init(p0)
        lc, lr_ = [], []
        for s in range(8):
            b = pipe(s)
            pc, qc, oc, mc, ec = jc(pc, qc, oc, b, jnp.int32(s), ec)
            pr, qr, orr, mr, er = jr(pr, qr, orr, b, jnp.int32(s), er)
            lc.append(float(mc["loss"]))
            lr_.append(float(mr["loss"]))
    assert abs(lc[0] - lr_[0]) < 5e-3, (lc[0], lr_[0])
    assert max(abs(a - b) for a, b in zip(lc, lr_)) < 0.05, (lc, lr_)
    assert lc[-1] < lc[0]


@multidevice
def test_compressed_2d_step_hlo_moves_int8():
    """The compiled 2D step must contain s8 gradient collectives and NO
    non-scalar fp32 all-reduce that crosses the DATA axis — fp32
    all-reduces inside a model group are the TP forward's activation
    math, which the model-sharded grad in_specs legitimately enable."""
    from repro.optim import adamw_init
    from repro.train import TrainConfig, make_train_step

    p0, q0, fwd, loss, pipe = _jet_setup()
    tc = TrainConfig(steps=8, lr=3e-3)
    D, M = 2, 4
    mesh = jax.make_mesh((D, M), ("data", "model"))
    step = make_train_step(fwd, loss, tc, reduce="compressed", mesh=mesh)
    with mesh:
        ec = EFState(residual=ef_wire2d_init(p0, D, M))
        hlo = jax.jit(step).lower(p0, q0, adamw_init(p0), pipe(0),
                                  jnp.int32(0), ec).compile().as_text()
    assert "s8[" in hlo and "all-to-all" in hlo

    # shared repro.analysis parser: surviving small f32 all-reduces
    # (loss/gnorm scalars, amax grids) stay under SCALAR_MAX elements
    bad = [c.line[:160] for c in parse_collectives(hlo)
           if c.kind == "all-reduce" and c.dtype == "f32"
           and c.numel >= SCALAR_MAX and c.crosses_data_axis(M)]
    assert not bad, bad


@multidevice
def test_wire2d_resume_exact(tmp_path):
    """Checkpoint the sliced residual mid-run, restore, continue: params
    and residual must match the uninterrupted run bit-for-bit (the
    acceptance contract for 2D checkpoint/resume)."""
    from repro.optim import adamw_init
    from repro.train import TrainConfig, make_train_step
    from repro.train import checkpoint as ckpt_lib

    p0, q0, fwd, loss, pipe = _jet_setup()
    tc = TrainConfig(steps=8, lr=3e-3)
    D, M = 2, 4
    mesh = jax.make_mesh((D, M), ("data", "model"))
    step = jax.jit(make_train_step(fwd, loss, tc, reduce="compressed",
                                   mesh=mesh))
    with mesh:
        # uninterrupted: 5 steps
        pa, qa, oa = p0, q0, adamw_init(p0)
        ea = EFState(residual=ef_wire2d_init(p0, D, M))
        for s in range(5):
            pa, qa, oa, _, ea = step(pa, qa, oa, pipe(s), jnp.int32(s), ea)
        # interrupted at 3: checkpoint, restore into fresh templates, go on
        pb, qb, ob = p0, q0, adamw_init(p0)
        eb = EFState(residual=ef_wire2d_init(p0, D, M))
        for s in range(3):
            pb, qb, ob, _, eb = step(pb, qb, ob, pipe(s), jnp.int32(s), eb)
        ckpt_lib.save(str(tmp_path), 3, {"params": pb, "opt": ob, "ef": eb})
        tmpl = {"params": p0, "opt": adamw_init(p0),
                "ef": EFState(residual=ef_wire2d_init(p0, D, M))}
        start, trees = ckpt_lib.restore(str(tmp_path), 3, tmpl)
        assert start == 3
        pc, oc, ec = trees["params"], trees["opt"], trees["ef"]
        qc = qb
        for s in range(3, 5):
            pc, qc, oc, _, ec = step(pc, qc, oc, pipe(s), jnp.int32(s), ec)
    for got, want in zip(jax.tree.leaves(pc), jax.tree.leaves(pa)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    for got, want in zip(jax.tree.leaves(ec.residual),
                         jax.tree.leaves(ea.residual)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ------------------------- fused bucketed path ------------------------------

@multidevice
@pytest.mark.parametrize("D,M", [(2, 4), (4, 2), (1, 8)])
def test_wire2d_fused_matches_legacy(D, M):
    """The fused bucketed 2D wire (concatenated pmax + pipelined
    per-bucket a2a/gather) is bit-for-bit the legacy per-leaf path and
    the simulator — on both DxM shapes AND the pure-TP 1x8 mesh, with
    mixed widths, at the default and a bucket-per-leaf budget."""
    mesh = jax.make_mesh((D, M), ("data", "model"))
    tree = _stacked(jax.random.PRNGKey(30), D)
    widths = {"w": 4, "layers": 4, "vec": 8, "scalar": 8}
    res = _init_res(tree, D, M)
    ds, rs = simulate_wire_pmean_2d(tree, res, M, "int8", widths=widths)
    with mesh:
        res_p = jax.device_put(res, ef_residual_sharding(res, mesh, "2d"))
        dl, rl = jax.jit(lambda t, rr: ef_wire_pmean_2d(
            t, rr, mesh, "int8", widths=widths, fused=False))(tree, res_p)
        for bb in (None, 1):
            df, rf = jax.jit(lambda t, rr, b=bb: ef_wire_pmean_2d(
                t, rr, mesh, "int8", widths=widths, fused=True,
                bucket_bytes=b))(tree, res_p)
            for k in tree:
                np.testing.assert_array_equal(np.asarray(df[k]),
                                              np.asarray(dl[k]))
                np.testing.assert_array_equal(np.asarray(rf[k]),
                                              np.asarray(rl[k]))
                np.testing.assert_array_equal(np.asarray(df[k]),
                                              np.asarray(ds[k]))
                np.testing.assert_array_equal(np.asarray(rf[k]),
                                              np.asarray(rs[k]))


@multidevice
def test_wire2d_fused_records_same_bytes_as_legacy():
    """Fused and legacy 2D traces emit identical per-leaf wire records
    (bf16 and int8, stacked and flat leaves) — bucketing changes launch
    count, never bytes."""
    D, M = 2, 4
    mesh = jax.make_mesh((D, M), ("data", "model"))
    tree = _stacked(jax.random.PRNGKey(31), D)
    res = _init_res(tree, D, M)
    with mesh:
        res_p = jax.device_put(res, ef_residual_sharding(res, mesh, "2d"))
        for kind in ("int8", "bf16"):
            recs = {}
            for fused in (True, False):
                fn = jax.jit(lambda t, rr, k=kind, f=fused:
                             ef_wire_pmean_2d(t, rr, mesh, k, fused=f))
                with record_wire_bytes() as rec:
                    fn.lower(tree, res_p)
                recs[fused] = sorted(rec.records)
            assert recs[True] == recs[False], (kind, recs)
