"""WKV chunked-vs-sequential equivalence, RG-LRU associative scan
correctness, sharding-rule unit tests, data determinism."""
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.nn.recurrent import _linear_scan, _wkv_chunked, _wkv_sequential

KEY = jax.random.PRNGKey(5)


def test_wkv_chunked_matches_sequential():
    B, S, H, N = 2, 37, 3, 8
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (B, S, H, N))
    k = jax.random.normal(ks[1], (B, S, H, N))
    v = jax.random.normal(ks[2], (B, S, H, N))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, N))) * 0.35 + 0.6
    u = jax.random.normal(ks[4], (H, N)) * 0.1
    s0 = jnp.zeros((B, H, N, N))
    y_c, S_c = _wkv_chunked(r, k, v, w, u, s0, chunk=8)
    y_s, S_s = _wkv_sequential(r, k, v, w, u, s0)
    np.testing.assert_allclose(y_c, y_s, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(S_c, S_s, rtol=2e-4, atol=2e-4)


def test_wkv_state_carry_composes():
    """Running two halves with carried state == one full pass."""
    B, S, H, N = 1, 32, 2, 4
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (B, S, H, N))
    k = jax.random.normal(ks[1], (B, S, H, N))
    v = jax.random.normal(ks[2], (B, S, H, N))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, N))) * 0.3 + 0.65
    u = jnp.zeros((H, N))
    s0 = jnp.zeros((B, H, N, N))
    y_full, S_full = _wkv_sequential(r, k, v, w, u, s0)
    h = S // 2
    y1, S_mid = _wkv_sequential(r[:, :h], k[:, :h], v[:, :h], w[:, :h], u, s0)
    y2, S_end = _wkv_sequential(r[:, h:], k[:, h:], v[:, h:], w[:, h:], u,
                                S_mid)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full,
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(S_end, S_full, rtol=1e-5, atol=1e-5)


def test_linear_scan_matches_loop():
    B, S, D = 2, 19, 7
    a = jax.nn.sigmoid(jax.random.normal(KEY, (B, S, D)))
    b = jax.random.normal(jax.random.PRNGKey(1), (B, S, D))
    h0 = jax.random.normal(jax.random.PRNGKey(2), (B, D))
    got = _linear_scan(a, b, h0)
    h = h0
    want = []
    for t in range(S):
        h = a[:, t] * h + (b[:, t] if t > 0 else b[:, t])
        want.append(h)
    # note: _linear_scan folds h0 into b[0] as a[0]*h0 + b[0]
    want = jnp.stack(want, axis=1)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# ----------------------------- sharding rules ------------------------------

class _FakeMesh:
    axis_names = ("data", "model")
    devices = types.SimpleNamespace(shape=(16, 16))


class _FakePodMesh:
    axis_names = ("pod", "data", "model")
    devices = types.SimpleNamespace(shape=(2, 16, 16))


def _spec(path_keys, shape, mesh=None, mode="train"):
    from repro.dist.sharding import spec_for_param

    class K:
        def __init__(self, key):
            self.key = key
    return spec_for_param([K(k) for k in path_keys], shape,
                          _FakeMesh() if mesh is None else mesh, mode)


def test_weight_spec_fsdp_tp():
    # [d_in, d_out]: larger axis -> model, other -> data
    assert _spec(("kernel", "w"), (896, 4864)) == P("data", "model")
    assert _spec(("kernel", "w"), (4864, 896)) == P("model", "data")


def test_stacked_layer_axis_never_sharded():
    sp = _spec(("layers", "kernel", "w"), (80, 8192, 49152))
    assert sp[0] is None
    assert sp[1:] == ("data", "model")


def test_expert_axis_never_sharded():
    sp = _spec(("layers", "gate", "w"), (48, 64, 2048, 1408))
    assert sp[0] is None and sp[1] is None
    assert sp[2] == "model" and sp[3] == "data"


def test_serve_mode_tp_only():
    sp = _spec(("kernel", "w"), (896, 4864), mode="serve")
    assert sp == P(None, "model")


def test_non_divisible_axes_replicate():
    # 14 heads * 64: 896 % 16 == 0 so it shards; 7 x 13 does not
    assert _spec(("kernel", "w"), (7, 13)) == P(None, None)


def test_pod_mesh_data_axes():
    sp = _spec(("kernel", "w"), (896, 4864), mesh=_FakePodMesh())
    assert sp == P(("pod", "data"), "model")


def test_batch_sharding_divisibility():
    from repro.dist.sharding import batch_spec
    assert batch_spec(_FakeMesh(), 256, 2) == P(("data",), None)
    assert batch_spec(_FakeMesh(), 1, 2) == P(None, None)  # B=1: replicate
    assert batch_spec(_FakePodMesh(), 256, 2) == P(("pod", "data"), None)


# ----------------------------- data pipeline -------------------------------

def test_data_determinism_and_learnability():
    from repro.data import DataSpec, make_pipeline
    pipe = make_pipeline(DataSpec(kind="jet", batch=64, seed=9))
    b1, b2 = pipe(7), pipe(7)
    np.testing.assert_array_equal(np.asarray(b1["x"]), np.asarray(b2["x"]))
    b3 = pipe(8)
    assert not np.array_equal(np.asarray(b1["x"]), np.asarray(b3["x"]))
    pipe_lm = make_pipeline(DataSpec(kind="lm", batch=4, seq=32, vocab=97))
    t = pipe_lm(0)["tokens"]
    assert t.shape == (4, 32) and int(t.max()) < 97


def test_muon_data_is_learnable():
    from repro.data import muon_batch
    b = muon_batch(0, 0, 512)
    # track angle is recoverable from the strip positions: correlation check
    x = np.asarray(b["stations"]).reshape(512, 3, 3, 50)
    # median strip over the 3 layers of station 3 suppresses noise hits
    strip = np.median(x[:, 2].argmax(-1), axis=-1)
    corr = np.corrcoef(strip, np.asarray(b["target"]))[0, 1]
    assert corr > 0.9
