"""Pallas kernel validation: shape/dtype sweeps vs the ref.py oracles
(interpret=True executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantizer import quantize
from repro.kernels import hgq_quantize, pack_weights, qmatmul_any
from repro.kernels.hgq_quantize.ref import hgq_quantize_ref
from repro.kernels.qmatmul.ref import pack_ref, qmatmul_ref

KEY = jax.random.PRNGKey(7)

QUANT_SHAPES = [((64, 256), ()), ((64, 256), (256,)), ((64, 256), (64, 256)),
                ((3, 5, 100), ()), ((3, 5, 100), (100,)), ((7,), (7,)),
                ((33, 130), (130,)), ((1, 128), (1, 128)), ((2, 2, 2, 64), ())]


@pytest.mark.parametrize("shape,fshape", QUANT_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_hgq_quantize_matches_ref(shape, fshape, dtype):
    x = (jax.random.normal(KEY, shape) * 4).astype(dtype)
    f = jax.random.uniform(KEY, fshape, minval=-1, maxval=8) if fshape \
        else jnp.float32(3.7)
    got = hgq_quantize(x, jnp.asarray(f))
    want = hgq_quantize_ref(x, jnp.broadcast_to(jnp.asarray(f), x.shape))
    assert got.dtype == x.dtype
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))


def test_hgq_quantize_grads_match_algorithm1():
    x = jax.random.normal(KEY, (8, 128))
    f = jnp.full((128,), 3.0)
    gx_k = jax.grad(lambda v: jnp.sum(hgq_quantize(v, f)))(x)
    gx_c = jax.grad(lambda v: jnp.sum(quantize(v, f)))(x)
    np.testing.assert_allclose(gx_k, gx_c)
    gf_k = jax.grad(lambda v: jnp.sum(hgq_quantize(x, v)))(f)
    gf_c = jax.grad(lambda v: jnp.sum(quantize(x, v)))(f)
    np.testing.assert_allclose(gf_k, gf_c, rtol=1e-5, atol=1e-6)


MM_SHAPES = [(8, 128, 128), (16, 256, 384), (5, 100, 77), (1, 896, 1024),
             (17, 900, 300), (128, 512, 256)]


@pytest.mark.parametrize("M,K,N", MM_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_qmatmul_matches_ref(M, K, N, dtype):
    x = (jax.random.normal(KEY, (M, K)) * 0.5).astype(dtype)
    w = jax.random.normal(KEY, (K, N)) * 0.1
    f = jax.random.uniform(KEY, (N,), minval=2, maxval=7)
    wi, s = pack_weights(w, f)
    got = qmatmul_any(x, wi, s)
    want = qmatmul_ref(x, wi, s)
    assert got.dtype == x.dtype
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_pack_weights_representable():
    """Packing at the trained bits keeps every quantized weight exact when
    |w| < 2^(7-f) (int8 mantissa range)."""
    w = jax.random.normal(KEY, (64, 32)) * 0.25
    f = jnp.full((32,), 6.0)
    wi, s = pack_weights(w, f)
    wq = wi.astype(jnp.float32) * s[None, :]
    from repro.core.quantizer import quantize_inference
    np.testing.assert_allclose(wq, quantize_inference(w, jnp.float32(6.0)),
                               atol=1e-7)


def test_pack_per_parameter_uses_channel_max():
    w = jnp.ones((4, 2)) * 0.25
    f = jnp.array([[2., 1.], [6., 1.], [2., 1.], [2., 1.]])
    wi, s = pack_weights(w, f)
    assert float(s[0]) == 2.0 ** -6  # max f in channel 0
    assert float(s[1]) == 2.0 ** -1


# --------------------------- sub-8-bit widths ------------------------------

@pytest.mark.parametrize("bits", [4, 5, 8])
def test_pack_ref_clips_to_width_grid(bits):
    """Sub-8-bit grids clip symmetrically to +-(2^(b-1)-1) so nibble
    packing and error feedback never see the asymmetric minimum; int8
    keeps the full (-128, 127) range."""
    w = jnp.linspace(-4.0, 4.0, 64).reshape(32, 2)
    f = jnp.full((2,), 6.0)
    m, s = pack_ref(w, f, bits)
    lo, hi = (-128, 127) if bits == 8 else \
        (-(2 ** (bits - 1) - 1), 2 ** (bits - 1) - 1)
    assert m.dtype == jnp.int8
    assert int(m.min()) == lo and int(m.max()) == hi


@pytest.mark.parametrize("bits", [4, 6, 8])
def test_pack_linear_caps_channel_to_width(bits):
    """pack_linear never saturates at any width: the per-channel grid
    cap shrinks 2^-f until the channel amax fits the b-wide mantissa,
    so dequant error stays within half a step everywhere."""
    from repro.kernels.qmatmul.ops import pack_linear
    w = jax.random.normal(KEY, (32, 16))
    m, s = pack_linear(w, None, bits)
    qmax = 127 if bits == 8 else 2 ** (bits - 1) - 1
    assert int(jnp.max(jnp.abs(m))) <= qmax
    err = jnp.abs(m.astype(jnp.float32) * s[None, :] - w)
    assert float(jnp.max(err - s[None, :] / 2)) <= 1e-6


def test_qmatmul_batched():
    x = jax.random.normal(KEY, (2, 3, 256))
    w = jax.random.normal(KEY, (256, 128)) * 0.1
    wi, s = pack_weights(w, jnp.float32(6.0))
    got = qmatmul_any(x, wi, s)
    want = qmatmul_ref(x.reshape(-1, 256), wi, s).reshape(2, 3, 128)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)
