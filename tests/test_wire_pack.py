"""kernels.wire_pack — the fused quantize-pack family behind the wire.

The contract under test: the Pallas kernels (forced on, interpreted, so
any backend runs them) are BIT-IDENTICAL to the jnp reference, and the
reference is definitionally the collective's legacy elementwise math
(``grid_exponent``/``_exp2i`` grids, saturating round, ``pack_nibbles``
wire format, the exact phase-2 decode expression).  Shapes deliberately
include odd tails that straddle the kernel's lane padding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.quantizer import _exp2i
from repro.kernels import wire_pack
from repro.kernels.qmatmul.ops import (grid_exponent, mantissa_max,
                                       pack_nibbles, unpack_nibbles)
from repro.kernels.wire_pack import ref

KERNEL = dict(use_kernel=True, interpret=True)
REF = dict(use_kernel=False)

# stacked [L, P] rows and flat single-row leaves, with odd / sub-lane /
# multi-tile tails
SHAPES = [(1, 1), (1, 120), (3, 40), (4, 129), (7, 257)]


def _rows(shape, seed=0, scale=1.0):
    r = jax.random.normal(jax.random.PRNGKey(seed), shape,
                          jnp.float32) * scale
    amax = jnp.max(jnp.abs(r), axis=tuple(range(1, r.ndim)))
    return r, amax


# ------------------------ kernel == reference -------------------------------

@pytest.mark.parametrize("bits", [4, 5, 6, 7, 8])
@pytest.mark.parametrize("shape", SHAPES)
def test_quantize_leaf_kernel_matches_ref(bits, shape):
    rows, amax = _rows(shape, seed=bits)
    qk, sk, rk = wire_pack.quantize_leaf(rows, amax, bits, **KERNEL)
    qr, sr, rr = wire_pack.quantize_leaf(rows, amax, bits, **REF)
    np.testing.assert_array_equal(np.asarray(qk), np.asarray(qr))
    np.testing.assert_array_equal(np.asarray(sk), np.asarray(sr))
    np.testing.assert_array_equal(np.asarray(rk), np.asarray(rr))
    assert qk.dtype == jnp.int8 and qk.shape == shape
    assert int(np.max(np.abs(np.asarray(qk)))) <= mantissa_max(bits)


@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("shape", SHAPES)
def test_quantize_chunks_kernel_matches_ref(bits, shape):
    """Per-position-scale variant (the 2D sliced path): scales are real
    2^-f grid steps that vary along the row, not a single broadcast."""
    e, _ = _rows(shape, seed=10 + bits)
    amax = jnp.abs(e) + jnp.float32(1e-3)       # positionwise pseudo-amax
    s = wire_pack.grid_scale(amax.reshape(-1), bits).reshape(e.shape)
    qk, rk = wire_pack.quantize_chunks(e, s, bits, **KERNEL)
    qr, rr = wire_pack.quantize_chunks(e, s, bits, **REF)
    np.testing.assert_array_equal(np.asarray(qk), np.asarray(qr))
    np.testing.assert_array_equal(np.asarray(rk), np.asarray(rr))


@pytest.mark.parametrize("shape", [(1, 1), (1, 7), (2, 8), (3, 129),
                                   (2, 4, 33)])
def test_pack_chunks_matches_pack_nibbles(shape):
    """The kernel's byte stream IS the qmatmul nibble wire format —
    including the odd-tail zero nibble and >2-D leading axes."""
    q = jax.random.randint(jax.random.PRNGKey(3), shape, -7, 8,
                           jnp.int32).astype(jnp.int8)
    pk = wire_pack.pack_chunks(q, **KERNEL)
    pr = wire_pack.pack_chunks(q, **REF)
    want = pack_nibbles(q, axis=-1)
    np.testing.assert_array_equal(np.asarray(pk), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(pr), np.asarray(want))
    # pack -> unpack round-trips in-range int4 mantissas exactly
    back = unpack_nibbles(pk, shape[-1], axis=-1)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(q))


@pytest.mark.parametrize("shift,n", [(0, 1), (1, 2), (2, 3), (2, 4),
                                     (3, 5), (3, 8)])
def test_dequant_sum_kernel_matches_ref(shift, n):
    """Kernel == reference under the SAME jit regime (the collective
    always runs jitted; for non-power-of-two n, XLA's reciprocal-multiply
    folding of /n differs from an eager true divide by 1 ulp, identically
    on both paths)."""
    q = jax.random.randint(jax.random.PRNGKey(4), (3, 37), -127, 128,
                           jnp.int32).astype(jnp.int8)
    s = wire_pack.grid_scale(
        jnp.abs(jax.random.normal(jax.random.PRNGKey(5), (37,))) + 0.1)
    dk = jax.jit(lambda q, s: wire_pack.dequant_sum(
        q, s, shift, n, **KERNEL))(q, s[None, :])
    dr = jax.jit(lambda q, s: wire_pack.dequant_sum(
        q, s, shift, n, **REF))(q, s[None, :])
    want = q.astype(jnp.float32) * (2 ** shift) * s[None, :] / n
    np.testing.assert_array_equal(np.asarray(dk), np.asarray(dr))
    np.testing.assert_allclose(np.asarray(dr), np.asarray(want),
                               rtol=2e-7)


# --------------------- reference == legacy collective math ------------------

@pytest.mark.parametrize("bits", [2, 4, 8])
def test_grid_scale_is_legacy_grid(bits):
    """grid_scale == _exp2i(-grid_exponent): an exact power of two whose
    mantissas never exceed qmax — the one grid definition shared by the
    wire collective, its simulators, and the kernels."""
    amax = jnp.asarray([1e-12, 1e-3, 0.5, 1.0, 127.0, 3e4], jnp.float32)
    s = wire_pack.grid_scale(amax, bits)
    np.testing.assert_array_equal(
        np.asarray(s), np.asarray(_exp2i(-grid_exponent(amax, bits))))
    frac, _ = np.frexp(np.asarray(s))
    assert np.all(frac == 0.5)                  # exact powers of two
    q = np.round(np.asarray(amax) / np.asarray(s))
    assert np.all(q <= mantissa_max(bits))


def test_quantize_leaf_is_legacy_phase1():
    """quantize_leaf reproduces the collective's original inline phase-1
    expression term for term (grid, saturating round, residual)."""
    rows, amax = _rows((3, 41), seed=9, scale=2.3)
    q, s, r = wire_pack.quantize_leaf(rows, amax, 8, **REF)
    scale = _exp2i(-grid_exponent(amax, 8))
    want_q = jnp.clip(jnp.round(rows / scale[:, None]), -127,
                      127).astype(jnp.int8)
    want_r = rows - want_q.astype(jnp.float32) * scale[:, None]
    np.testing.assert_array_equal(np.asarray(q), np.asarray(want_q))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(scale))
    np.testing.assert_array_equal(np.asarray(r), np.asarray(want_r))


def test_use_fused_kernel_backend_dispatch():
    """Off-TPU the jnp reference is the fast path (use_fused_kernel
    False); the kernels stay reachable via the explicit override."""
    assert wire_pack.use_fused_kernel() == (jax.default_backend() == "tpu")


# --------------------------- property tests ---------------------------------

@settings(max_examples=20)
@given(st.lists(st.floats(min_value=-100.0, max_value=100.0), min_size=1,
                max_size=40),
       st.integers(min_value=2, max_value=8))
def test_property_quantize_leaf_kernel_matches_ref(vals, bits):
    rows = jnp.asarray(vals, jnp.float32)[None, :]
    amax = jnp.max(jnp.abs(rows), axis=1)
    qk, sk, rk = wire_pack.quantize_leaf(rows, amax, bits, **KERNEL)
    qr, sr, rr = wire_pack.quantize_leaf(rows, amax, bits, **REF)
    np.testing.assert_array_equal(np.asarray(qk), np.asarray(qr))
    np.testing.assert_array_equal(np.asarray(sk), np.asarray(sr))
    np.testing.assert_array_equal(np.asarray(rk), np.asarray(rr))
    # the decomposition identity EF relies on: q * s + residual == rows
    got = np.asarray(qk, np.float32) * np.asarray(sk)[:, None] \
        + np.asarray(rk)
    np.testing.assert_array_equal(got, np.asarray(rows))


@settings(max_examples=20)
@given(st.integers(min_value=1, max_value=33),
       st.integers(min_value=1, max_value=4))
def test_property_pack_odd_tails(C, R):
    """Any (rows, odd-or-even columns) combination packs byte-identically
    to pack_nibbles and round-trips through unpack_nibbles."""
    q = jax.random.randint(jax.random.PRNGKey(C * 31 + R), (R, C), -7, 8,
                           jnp.int32).astype(jnp.int8)
    pk = wire_pack.pack_chunks(q, **KERNEL)
    np.testing.assert_array_equal(np.asarray(pk),
                                  np.asarray(pack_nibbles(q, axis=-1)))
    np.testing.assert_array_equal(
        np.asarray(unpack_nibbles(pk, C, axis=-1)), np.asarray(q))


def test_ref_module_is_the_dispatch_reference():
    """ops with use_kernel=False is exactly ref.* (no drift between the
    dispatch layer and the reference module)."""
    rows, amax = _rows((2, 19), seed=13)
    for a, b in zip(wire_pack.quantize_leaf(rows, amax, 4, **REF),
                    ref.quantize_leaf_ref(rows, amax, 4)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
