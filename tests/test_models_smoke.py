"""Per-arch smoke tests: every assigned architecture's REDUCED config runs
one forward + one train step + (where applicable) one decode step on CPU,
asserting output shapes and no NaNs (brief: deliverable f)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get
from repro.core import hgq
from repro.models import model_for
from repro.optim import adamw_init, adamw_update
from repro.train import lm_loss

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=16):
    b = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab)}
    if cfg.family == "vlm":
        b["patch_embeds"] = jax.random.normal(KEY, (B, cfg.n_patches,
                                                    cfg.d_model))
    if cfg.family == "audio":
        b["frame_embeds"] = jax.random.normal(KEY, (B, cfg.enc_seq,
                                                    cfg.d_model))
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get(arch, smoke=True)
    M = model_for(cfg)
    p, q = M.init(KEY, cfg)
    batch = _batch(cfg)
    logits, newq, aux = M.forward(p, q, batch, cfg, mode=hgq.TRAIN)
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.vocab)
    assert not bool(jnp.isnan(logits).any()), f"{arch}: NaN logits"
    assert float(aux.ebops) > 0, f"{arch}: EBOPs accounting inactive"

    # one real optimizer step end-to-end
    def loss_fn(params):
        out, nq, aux = M.forward(params, q, batch, cfg, mode=hgq.TRAIN)
        return lm_loss(out, batch["tokens"]) + 1e-9 * aux.ebops

    loss, grads = jax.value_and_grad(loss_fn)(p)
    assert not bool(jnp.isnan(loss))
    opt = adamw_init(p)
    p2, _ = adamw_update(grads, opt, p, lr=1e-3)
    loss2 = loss_fn(p2)
    assert not bool(jnp.isnan(loss2))
    # at least one HGQ bitwidth received a gradient
    f_grads = [g for path, g in
               jax.tree_util.tree_flatten_with_path(grads)[0]
               if any(getattr(k, "key", None) == "f" for k in path)]
    assert f_grads and any(float(jnp.max(jnp.abs(g))) > 0 for g in f_grads), \
        f"{arch}: no gradient reached the trainable bitwidths"


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = get(arch, smoke=True)
    M = model_for(cfg)
    p, q = M.init(KEY, cfg)
    B = 2
    cache = M.init_cache(cfg, B, 32)
    if cfg.family == "audio":
        cache = M.prefill_cross(p, q, cache,
                                jax.random.normal(KEY, (B, cfg.enc_seq,
                                                        cfg.d_model)), cfg)
    tok = jax.random.randint(KEY, (B, 1), 0, cfg.vocab)
    logits, new_cache = M.decode_step(p, q, cache, tok, jnp.int32(0), cfg)
    assert logits.shape == (B, 1, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())
    # a second step at the next position must also be finite
    logits2, _ = M.decode_step(p, q, new_cache, tok, jnp.int32(1), cfg)
    assert not bool(jnp.isnan(logits2).any())
