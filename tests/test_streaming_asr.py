"""Streaming ASR serving: audio-chunk requests in the continuous-batching
Engine.

The tentpole contract: audio streamed chunk-by-chunk through
``StreamingEngine`` — encoder blocks appended incrementally into the
slot's quantized cross-attention cache, decoder joining the shared
ragged decode tick — must reproduce the offline whole-audio
:func:`repro.serving.generate_asr` reference token-for-token, on the fp
AND the quantized-KV cache paths, including while LM requests decode
concurrently in the same jitted step.  Plus the surfaces around it:
``split_audio`` block decomposition, the ``submit_audio`` handle API,
admission validation, the ServingSpec workloads/audio routing through
``RunContext.make_engine``, and the latency accounting the serving
bench gates.
"""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get
from repro.models import model_for
from repro.serving import (AudioRequest, Engine, Request, StreamingEngine,
                           generate_asr, kv_cross_bytes_per_request,
                           split_audio)

KEY = jax.random.PRNGKey(5)
SPEC_PATH = (pathlib.Path(__file__).resolve().parents[1] / "examples" /
             "specs" / "serving_asr_stream.json")


def _whisper():
    cfg = get("whisper-large-v3", smoke=True)
    M = model_for(cfg)
    p, q = M.init(KEY, cfg)
    return cfg, M, p, q


def _frames(cfg, T, seed=9):
    return jax.random.normal(jax.random.fold_in(KEY, seed),
                             (T, cfg.d_model)) * 0.3


def _lm_reqs(vocab, lens, max_news, seed=21):
    k = jax.random.fold_in(KEY, seed)
    return [Request(prompt=[int(t) for t in jax.random.randint(
                jax.random.fold_in(k, i), (n,), 1, vocab)], max_new=mn)
            for i, (n, mn) in enumerate(zip(lens, max_news))]


def test_split_audio_blocks():
    """Full chunk-size blocks then power-of-two tails; chunk=0 is one
    block.  This decomposition is THE shared semantic unit between
    streaming and the offline reference."""
    fr = jnp.zeros((16, 4))
    assert [b.shape[1] for b in split_audio(fr, 5)] == [5, 5, 5, 1]
    assert [b.shape[1] for b in split_audio(fr, 6)] == [6, 6, 4]
    assert [b.shape[1] for b in split_audio(fr, 0)] == [16]
    assert [b.shape[1] for b in split_audio(fr, 16)] == [16]
    blocks = split_audio(fr, 7)
    assert sum(b.shape[1] for b in blocks) == 16
    assert all(b.ndim == 3 for b in blocks)


@pytest.mark.parametrize("kv_bits", [None, 4])
def test_streaming_matches_offline(kv_bits):
    """Chunked audio through the slot scheduler == offline whole-audio
    generate_asr, token-for-token, with a concurrent LM request decoding
    in the same jitted step — fp and quantized-KV caches."""
    cfg, M, p, q = _whisper()
    chunk, prompt, max_new = 5, [1, 2], 6
    frames = _frames(cfg, cfg.enc_seq)
    eng = StreamingEngine(M, p, q, cfg, batch_slots=2, max_len=32,
                          kv_bits=kv_bits, audio_chunk=chunk)
    req = AudioRequest(frames=frames, prompt=list(prompt), max_new=max_new)
    lm = _lm_reqs(cfg.vocab, [3], [5])[0]
    eng.run([req, lm])
    assert req.done and lm.done and len(lm.out) == 5
    ref = generate_asr(M, p, q, cfg, frames, prompt, max_new,
                       chunk=chunk, cache_len=32, kv_bits=kv_bits)
    assert req.out == [int(t) for t in np.asarray(ref)[0]]
    # latency accounting: one entry per delivered chunk, ttft recorded
    assert len(req.t_chunks) == len(split_audio(frames, chunk))
    assert all(t > 0 for t in req.t_chunks)
    assert req.ttft_s is not None and req.ttft_s > 0


def test_lm_traffic_unaffected_by_streaming_engine():
    """An LM request served by StreamingEngine (mem_len == 0 rows read
    exactly zero from the memory buffer) matches the plain Engine."""
    cfg, M, p, q = _whisper()
    a = _lm_reqs(cfg.vocab, [4], [6])[0]
    b = Request(prompt=list(a.prompt), max_new=6)
    Engine(M, p, q, cfg, batch_slots=1, max_len=32).run([a])
    StreamingEngine(M, p, q, cfg, batch_slots=1, max_len=32,
                    audio_chunk=5).run([b])
    assert a.done and b.done and a.out == b.out


@pytest.mark.parametrize("kv_bits", [None, 4])
def test_mixed_workload_slot_churn(kv_bits):
    """More streams + LM requests than slots: every audio stream still
    reproduces its offline reference and every LM request its
    generate() reference, across slot recycling."""
    cfg, M, p, q = _whisper()
    chunk = 5
    auds = [AudioRequest(frames=_frames(cfg, T, seed=30 + i),
                         prompt=[1, 2 + i], max_new=4, chunk=chunk)
            for i, T in enumerate([cfg.enc_seq, 7, 11])]
    lms = _lm_reqs(cfg.vocab, [3, 5], [4, 3])
    reqs = [auds[0], lms[0], auds[1], lms[1], auds[2]]
    eng = StreamingEngine(M, p, q, cfg, batch_slots=2, max_len=32,
                          kv_bits=kv_bits, audio_chunk=chunk)
    eng.run(reqs)
    assert all(r.done for r in reqs)
    for a in auds:
        ref = generate_asr(M, p, q, cfg, a.frames, a.prompt, a.max_new,
                           chunk=chunk, cache_len=32, kv_bits=kv_bits)
        assert a.out == [int(t) for t in np.asarray(ref)[0]]
    # LM rows decode on the engine's (possibly quantized) self-KV cache:
    # the exact reference is a plain Engine at the same kv_bits, which
    # pins the streaming machinery as invisible to LM traffic
    for r in lms:
        ref = Request(prompt=list(r.prompt), max_new=r.max_new)
        Engine(M, p, q, cfg, batch_slots=1, max_len=32,
               kv_bits=kv_bits).run([ref])
        assert r.out == ref.out


def test_submit_audio_handle_tokens():
    """The handle API over streaming: submit_audio returns a truthy
    RequestHandle and tokens(handle) yields the same stream run()
    produces, one token at a time while chunks keep arriving."""
    cfg, M, p, q = _whisper()
    frames = _frames(cfg, cfg.enc_seq)
    ref_req = AudioRequest(frames=frames, prompt=[1, 2], max_new=5,
                           chunk=5)
    StreamingEngine(M, p, q, cfg, batch_slots=1, max_len=32,
                    audio_chunk=5).run([ref_req])
    eng = StreamingEngine(M, p, q, cfg, batch_slots=1, max_len=32,
                          audio_chunk=5)
    h = eng.submit_audio(AudioRequest(frames=frames, prompt=[1, 2],
                                      max_new=5))
    assert h
    assert list(eng.tokens(h)) == ref_req.out
    assert h.done and h.out == ref_req.out


def test_submit_audio_validation_and_admission():
    cfg, M, p, q = _whisper()
    eng = StreamingEngine(M, p, q, cfg, batch_slots=1, max_len=16,
                          audio_chunk=5, max_frames=8)
    ok = AudioRequest(frames=_frames(cfg, 6), prompt=[1], max_new=2)
    with pytest.raises(ValueError, match="frames"):
        eng.submit_audio(AudioRequest(frames=_frames(cfg, 9),
                                      prompt=[1], max_new=2))
    with pytest.raises(ValueError, match="max_new"):
        eng.submit_audio(AudioRequest(frames=_frames(cfg, 6),
                                      prompt=[1] * 10, max_new=8))
    assert eng.submit_audio(ok)
    # slot reserved during streaming: both request types are refused
    assert eng.submit_audio(AudioRequest(frames=_frames(cfg, 6),
                                         prompt=[1], max_new=2)) is None
    assert eng.submit(Request(prompt=[1, 2], max_new=2)) is None
    eng.run([])
    assert ok.done and len(ok.out) == 2


def test_spec_routing_builds_streaming_engine():
    """The golden spec routes through RunContext.make_engine to a
    StreamingEngine carrying the spec's audio chunking and plan KV
    width; the asr data pipeline yields encoder-shaped batches."""
    from repro.api import RunSpec, build
    spec = RunSpec.from_file(str(SPEC_PATH))
    assert spec.serving.workloads == ("lm", "asr")
    ctx = build(spec)
    params, qstate = ctx.init_state()
    eng = ctx.make_engine(params, qstate, max_len=32)
    assert isinstance(eng, StreamingEngine)
    assert eng.audio_chunk == spec.serving.audio.chunk_frames
    assert eng.kv_bits == 4
    batch = ctx.make_pipeline()(0)
    assert batch["frame_embeds"].shape == (
        spec.data.batch, ctx.cfg.enc_seq, ctx.cfg.d_model)
    assert batch["tokens"].shape == (spec.data.batch, spec.data.seq)
    # e2e through the spec-built engine: streamed == offline
    frames = _frames(ctx.cfg, ctx.cfg.enc_seq)
    req = AudioRequest(frames=frames, prompt=[1, 2], max_new=4)
    eng.run([req])
    ref = generate_asr(ctx.model, params, qstate, ctx.cfg, frames,
                       [1, 2], 4, chunk=eng.audio_chunk, cache_len=32,
                       kv_bits=eng.kv_bits)
    assert req.out == [int(t) for t in np.asarray(ref)[0]]


def test_serving_spec_workload_validation():
    from repro.api import AudioSpec, ServingSpec
    assert ServingSpec().workloads == ("lm",)
    asr = ServingSpec(workloads=("lm", "asr"))
    assert asr.audio == AudioSpec()          # auto-filled default
    with pytest.raises(ValueError, match="drawn from"):
        ServingSpec(workloads=("lm", "tts"))
    with pytest.raises(ValueError, match="duplicate|unique"):
        ServingSpec(workloads=("lm", "lm"))
    with pytest.raises(ValueError, match="asr"):
        ServingSpec(audio=AudioSpec(chunk_frames=4))
    with pytest.raises(ValueError, match="chunkz"):
        ServingSpec(workloads=("asr",), audio={"chunkz": 3})


def test_golden_spec_round_trips():
    from repro.api import RunSpec
    spec = RunSpec.from_file(str(SPEC_PATH))
    assert RunSpec.from_dict(json.loads(spec.to_json())) == spec


def test_cross_kv_bytes_model():
    """Cross-attention memory is a per-request static pin (frames rows,
    K+V, all layers): exactly frames x the per-token self-ring row cost,
    scaling with kv_bits incl. nibble packing."""
    from repro.serving import kv_bytes_per_token
    full = kv_cross_bytes_per_request(4, 16, 2, 16, None)
    int8 = kv_cross_bytes_per_request(4, 16, 2, 16, 8)
    nib = kv_cross_bytes_per_request(4, 16, 2, 16, 4)
    assert full > int8 > nib
    # same row model as the self ring, times the static frame count
    for bits, v in ((None, full), (8, int8), (4, nib)):
        assert v == kv_bytes_per_token(4, 16, 2, bits) * 16
    # doubling frames doubles the pin
    assert kv_cross_bytes_per_request(4, 16, 2, 32, 8) == 2 * int8
