"""dist.collectives — the int8-on-the-wire compressed mean all-reduce.

Single-device tests drive the collective-free reference
(``simulate_wire_pmean``) plus the grid/bytes/EF-property contracts; the
``@multidevice`` tests (CI job with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``) assert the real
``shard_map`` path matches the reference bit-for-bit, that the compressed
train step tracks the post-reduce one, and that the compiled HLO moves
int8 — not fp32 — gradient bytes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.analysis import SCALAR_MAX, parse_collectives
from repro.dist import EFState, ef_compress, ef_init
from repro.dist.collectives import (data_axis_size, ef_wire_init,
                                    ef_wire_pmean, fp32_allreduce_bytes,
                                    simulate_wire_pmean, wire_bytes_model)

multidevice = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


def _stacked(key, n=4):
    """A per-shard tree with a stacked [L, ...] leaf (under the
    ``layers`` container, which marks it stacked by path), a flat leaf,
    and a scalar leaf."""
    ks = jax.random.split(key, 3)
    return {"layers": jax.random.normal(ks[0], (n, 3, 8, 5)),
            "vec": jax.random.normal(ks[1], (n, 17)),
            "scalar": jax.random.normal(ks[2], (n,))}


# ------------------------- reference semantics ------------------------------

def test_simulate_delivers_near_mean():
    tree = _stacked(jax.random.PRNGKey(0))
    delivered, residual = simulate_wire_pmean(tree, "int8")
    for k in tree:
        true = np.mean(np.asarray(tree[k]), axis=0)
        grid = np.max(np.abs(np.asarray(tree[k]))) / 127 * 2
        np.testing.assert_allclose(np.asarray(delivered[k]), true,
                                   atol=4 * grid)
        assert residual[k].shape == tree[k].shape


def test_simulate_stacked_leaf_per_layer_grids():
    """One outlier layer in a stacked [L, ...] leaf must not crush the
    other layers' resolution: each layer's one-step quantization error is
    bounded by its OWN grid step, not the outlier's."""
    e = jnp.ones((2, 3, 8, 5)) * 1e-3
    e = e.at[:, 1].mul(1e4)  # layer 1 is a 10.0-scale outlier
    delivered, _ = simulate_wire_pmean({"w": e}, "int8",
                                       stacked={"w": True})
    err = np.abs(np.asarray(delivered["w"]) - np.mean(np.asarray(e), axis=0))
    for layer in range(3):
        own_grid = float(np.max(np.abs(np.asarray(e[:, layer])))) / 127
        assert err[layer].max() <= 2.5 * own_grid, (
            f"layer {layer}: err {err[layer].max()} vs own grid {own_grid}")
    # the old per-tensor grid would have made layer-0 error ~outlier/127
    assert err[0].max() < 1e-4


def test_wire_bad_kind_raises():
    tree = {"w": jnp.zeros((2, 4))}
    with pytest.raises(ValueError, match="int8"):
        simulate_wire_pmean(tree, "fp4")


def test_bytes_model_hits_4x():
    """The acceptance ratio: int8-wire must cut gradient collective bytes
    >= 3x vs a ring fp32 all-reduce at n=8 (analytically it is ~4x; the
    per-layer scale sidecar eats a sliver)."""
    n, elems = 8, 500_000
    int8 = wire_bytes_model(elems, n, "int8", n_scale_rows=64)
    bf16 = wire_bytes_model(elems, n, "bf16", n_scale_rows=64)
    fp32 = fp32_allreduce_bytes(elems, n)
    assert fp32 / int8 >= 3.0, (fp32, int8)
    assert fp32 / bf16 >= 1.9, (fp32, bf16)


# ------------------------ error-feedback property ---------------------------

@settings(max_examples=15)
@given(st.lists(st.floats(min_value=-1.0, max_value=1.0), min_size=4,
                max_size=24),
       st.integers(min_value=8, max_value=20))
def test_ef_time_average_unbiased(vals, K):
    """Over K steps of a constant gradient, the time-averaged delivered
    gradient is within one grid step of the truth — for post-reduce int8
    EF and for the two-phase int8-wire reduce (simulated 4 shards)."""
    g = jnp.asarray(vals, jnp.float32)
    grid = max(float(jnp.max(jnp.abs(g))), 1e-30) / 127.0

    st_ = ef_init({"w": g})
    acc = jnp.zeros_like(g)
    for _ in range(K):
        sent, st_ = ef_compress({"w": g}, st_, kind="int8")
        acc = acc + sent["w"]
    np.testing.assert_allclose(np.asarray(acc / K), np.asarray(g),
                               atol=grid + 1e-7)

    # int8-wire: 4 simulated shards, distinct per-shard gradients whose
    # mean is g (shard i sees g scaled by a fixed factor)
    fac = jnp.asarray([0.4, 0.8, 1.2, 1.6])[:, None]
    gs = fac * g[None, :]
    true_mean = jnp.mean(gs, axis=0)
    wire_grid = max(float(jnp.max(jnp.abs(gs))), 1e-30) / 127.0 * 2
    res = ef_wire_init({"w": true_mean}, 4)
    acc = jnp.zeros_like(g)
    for _ in range(K):
        e = {"w": gs + res["w"]}
        d, res = simulate_wire_pmean(e, "int8")
        acc = acc + d["w"]
    np.testing.assert_allclose(np.asarray(acc / K), np.asarray(true_mean),
                               atol=wire_grid + 1e-7)


def test_compression_none_step_bit_exact():
    """kind='none' must be bit-exact with the uncompressed train step."""
    from repro.data import DataSpec, make_pipeline
    from repro.models import JetTagger
    from repro.nn import HGQConfig
    from repro.optim import adamw_init
    from repro.train import TrainConfig, make_train_step, softmax_xent

    cfg = HGQConfig(weight_gran="per_parameter", act_gran="per_parameter",
                    init_weight_f=2, init_act_f=2)
    p0, q0 = JetTagger.init(jax.random.PRNGKey(0), cfg)
    fwd = lambda p, q, b, mode: JetTagger.forward(p, q, b, mode)
    loss = lambda out, b: softmax_xent(out, b["y"])
    pipe = make_pipeline(DataSpec(kind="jet", batch=64))
    tc = TrainConfig(steps=4, lr=3e-3)

    plain = jax.jit(make_train_step(fwd, loss, tc))
    nones = jax.jit(make_train_step(
        fwd, loss, tc, grad_tx=lambda g, s: ef_compress(g, s, kind="none")))

    pa, qa, oa = p0, q0, adamw_init(p0)
    pb, qb, ob = p0, q0, adamw_init(p0)
    eb = ef_init(p0)
    for s in range(3):
        b = pipe(s)
        pa, qa, oa, _ = plain(pa, qa, oa, b, jnp.int32(s))
        pb, qb, ob, _, eb = nones(pb, qb, ob, b, jnp.int32(s), eb)
    for got, want in zip(jax.tree.leaves(pb), jax.tree.leaves(pa)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_compressed_rejects_grad_tx():
    """grad_tx and reduce='compressed' are mutually exclusive — silently
    replacing a caller's transform would be the same bug class Trainer
    just had fixed."""
    from repro.train import TrainConfig, make_train_step
    with pytest.raises(ValueError, match="mutually exclusive"):
        make_train_step(lambda *a, **k: None, lambda *a: None,
                        TrainConfig(steps=1),
                        grad_tx=lambda g, s: (g, s), reduce="compressed")


def test_compressed_single_device_is_post_reduce_path():
    """On one data shard the wire is a no-op: reduce='compressed' must be
    token-for-token exact with the post-reduce ef_compress(kind='int8')
    step (the acceptance contract for single-device fallback)."""
    from repro.data import DataSpec, make_pipeline
    from repro.models import JetTagger
    from repro.nn import HGQConfig
    from repro.optim import adamw_init
    from repro.train import TrainConfig, make_train_step, softmax_xent

    cfg = HGQConfig(weight_gran="per_parameter", act_gran="per_parameter",
                    init_weight_f=2, init_act_f=2)
    p0, q0 = JetTagger.init(jax.random.PRNGKey(0), cfg)
    fwd = lambda p, q, b, mode: JetTagger.forward(p, q, b, mode)
    loss = lambda out, b: softmax_xent(out, b["y"])
    pipe = make_pipeline(DataSpec(kind="jet", batch=64))
    tc = TrainConfig(steps=4, lr=3e-3)

    wire = jax.jit(make_train_step(fwd, loss, tc, reduce="compressed",
                                   mesh=None))
    post = jax.jit(make_train_step(
        fwd, loss, tc, grad_tx=lambda g, s: ef_compress(g, s, kind="int8")))
    pa, qa, oa, ea = p0, q0, adamw_init(p0), ef_init(p0)
    pb, qb, ob, eb = p0, q0, adamw_init(p0), ef_init(p0)
    for s in range(3):
        b = pipe(s)
        pa, qa, oa, _, ea = wire(pa, qa, oa, b, jnp.int32(s), ea)
        pb, qb, ob, _, eb = post(pb, qb, ob, b, jnp.int32(s), eb)
    for got, want in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    for got, want in zip(jax.tree.leaves(ea.residual),
                         jax.tree.leaves(eb.residual)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ----------------------------- mixed widths ---------------------------------

def test_simulate_mixed_widths_grid_and_error():
    """Per-leaf wire widths: a w4 leaf quantizes on the 2^(4-1)-1 = 7
    grid (coarser error bound), w8 leaves are untouched — byte-for-byte
    equal to the no-widths trace."""
    tree = _stacked(jax.random.PRNGKey(5))
    widths = {"layers": 4, "vec": 8, "scalar": 8}
    d, r = simulate_wire_pmean(tree, "int8", widths=widths)
    d8, r8 = simulate_wire_pmean(tree, "int8")
    true = np.mean(np.asarray(tree["layers"]), axis=0)
    grid4 = np.max(np.abs(np.asarray(tree["layers"]))) / 7 * 2
    np.testing.assert_allclose(np.asarray(d["layers"]), true,
                               atol=4 * grid4)
    # w8 leaves must be bit-identical to the widths-free path
    for k in ("vec", "scalar"):
        np.testing.assert_array_equal(np.asarray(d[k]), np.asarray(d8[k]))
        np.testing.assert_array_equal(np.asarray(r[k]), np.asarray(r8[k]))
    # the w4 leaf genuinely moved to the coarser grid
    assert not np.array_equal(np.asarray(d["layers"]),
                              np.asarray(d8["layers"]))


def test_width_flags_validation():
    from repro.dist.collectives import _width_flags
    tree = {"a": jnp.zeros((2, 3)), "b": jnp.zeros((2,))}
    assert _width_flags(tree, None) == (8, 8)
    assert _width_flags(tree, {"a": 4, "b": 8}) == (4, 8)
    with pytest.raises(ValueError, match="wire width"):
        _width_flags(tree, {"a": 1, "b": 8})


@pytest.mark.parametrize("bits", [4, 5, 8])
@pytest.mark.parametrize("n", [1, 2, 3, 4, 8])
def test_phase2_shift_fits_every_width(bits, n):
    """The phase-2 requantize is width-independent: with shift
    k = ceil(log2 n), |round(sum / 2^k)| <= qmax for ANY payload width
    (2^k >= n bounds the worst-case sum of n in-range mantissas) — so
    phase-2/3 payloads always repack into the leaf's width."""
    from repro.dist.collectives import _phase2_shift
    qmax = 2 ** (bits - 1) - 1
    k = _phase2_shift(n)
    worst = n * qmax
    assert round(worst / 2 ** k) <= qmax, (bits, n, k)
    assert round(-worst / 2 ** k) >= -qmax


def test_bytes_model_nibble_halves_payload():
    """bits<=4 int8-wire chunks count nibble-packed (ceil(C/2)) bytes;
    the scale sidecar is width-independent."""
    n, elems, rows = 8, 500_000, 64
    b8 = wire_bytes_model(elems, n, "int8", rows)
    b4 = wire_bytes_model(elems, n, "int8", rows, bits=4)
    b5 = wire_bytes_model(elems, n, "int8", rows, bits=5)
    scales = wire_bytes_model(0, n, "int8", rows)
    assert b5 == b8                       # only <=4 bits nibble-pack
    np.testing.assert_allclose(b4 - scales, (b8 - scales) / 2, rtol=1e-3)
    # bf16 ignores bits (payload carries its own exponents)
    assert wire_bytes_model(elems, n, "bf16", rows, bits=4) \
        == wire_bytes_model(elems, n, "bf16", rows)


# --------------------------- multi-device path ------------------------------

@multidevice
def test_shard_map_matches_simulate():
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    assert data_axis_size(mesh) == 4
    tree = _stacked(jax.random.PRNGKey(1))
    from repro.dist.sharding import ef_residual_sharding
    with mesh:
        placed = jax.device_put(tree, ef_residual_sharding(tree, mesh))
        for kind in ("int8", "bf16"):
            d, r = jax.jit(
                lambda t, k=kind: ef_wire_pmean(t, mesh, k))(placed)
            ds, rs = simulate_wire_pmean(tree, kind)
            for k in tree:
                np.testing.assert_array_equal(np.asarray(d[k]),
                                              np.asarray(ds[k]))
                np.testing.assert_array_equal(np.asarray(r[k]),
                                              np.asarray(rs[k]))


@multidevice
def test_shard_map_matches_simulate_mixed_widths():
    """Mixed per-leaf widths on the real 1D shard_map path: bit-for-bit
    equal to the simulator (pack∘unpack is the identity on in-range int4
    mantissas, so the packed wire changes no delivered value)."""
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    tree = _stacked(jax.random.PRNGKey(6))
    widths = {"layers": 4, "vec": 8, "scalar": 8}
    from repro.dist.sharding import ef_residual_sharding
    with mesh:
        placed = jax.device_put(tree, ef_residual_sharding(tree, mesh))
        d, r = jax.jit(lambda t: ef_wire_pmean(
            t, mesh, "int8", widths=widths))(placed)
    ds, rs = simulate_wire_pmean(tree, "int8", widths=widths)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(d[k]), np.asarray(ds[k]))
        np.testing.assert_array_equal(np.asarray(r[k]), np.asarray(rs[k]))


@multidevice
def test_wire_1d_bytes_model_pins_measured_trace():
    """wire_bytes_model == the recorder's measured per-leaf totals, for
    int8 at w8 and w4 (nibble chunks) and for bf16 — the byte model and
    the traced collectives must not drift apart."""
    from repro.dist.collectives import record_wire_bytes
    from repro.dist.sharding import ef_residual_sharding
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    n = data_axis_size(mesh)
    cases = [("layers", "int8", 8, 3), ("layers", "int8", 4, 3),
             ("vec", "int8", 4, 1), ("vec", "bf16", 8, 1)]
    full = _stacked(jax.random.PRNGKey(7))
    with mesh:
        for name, kind, bits, rows in cases:
            tree = {name: full[name]}
            placed = jax.device_put(tree,
                                    ef_residual_sharding(tree, mesh))
            fn = jax.jit(lambda t, k=kind, b=bits, n_=name: ef_wire_pmean(
                t, mesh, k, widths={n_: b}))
            with record_wire_bytes() as rec:
                fn.lower(placed)
            want = wire_bytes_model(full[name][0].size, n, kind,
                                    n_scale_rows=rows, bits=bits)
            assert rec.total() == want, (name, kind, bits,
                                         rec.records, want)


@multidevice
def test_wire_vjp_composes():
    """value_and_grad through the collective: the backward is the
    transpose of an uncompressed shard mean (cotangent / n per shard)."""
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    tree = {"w": jax.random.normal(jax.random.PRNGKey(2), (4, 6, 5))}
    with mesh:
        val, grads = jax.value_and_grad(
            lambda t: jnp.sum(ef_wire_pmean(t, mesh, "int8")[0]["w"]))(tree)
    assert np.isfinite(float(val))
    np.testing.assert_allclose(np.asarray(grads["w"]), 0.25, atol=1e-6)


@multidevice
def test_compressed_step_tracks_post_reduce():
    """reduce='compressed' on a 4x2 FSDPxTP mesh trains to the same loss
    curve as the post-reduce int8 path (both carry one-grid-step EF
    noise), starting from an identical first step."""
    from repro.data import DataSpec, make_pipeline
    from repro.dist import collectives
    from repro.models import JetTagger
    from repro.nn import HGQConfig
    from repro.optim import adamw_init
    from repro.train import TrainConfig, make_train_step, softmax_xent

    cfg = HGQConfig(weight_gran="per_parameter", act_gran="per_parameter",
                    init_weight_f=2, init_act_f=2)
    p0, q0 = JetTagger.init(jax.random.PRNGKey(0), cfg)
    fwd = lambda p, q, b, mode: JetTagger.forward(p, q, b, mode)
    loss = lambda out, b: softmax_xent(out, b["y"])
    pipe = make_pipeline(DataSpec(kind="jet", batch=256))
    tc = TrainConfig(steps=20, lr=3e-3, beta0=1e-7, beta1=1e-6)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    n = collectives.data_axis_size(mesh)

    # wire_layout pinned to "1d": this test drives the 1D collective (the
    # 2x4-mesh default would auto-select the 2D sliced path, see
    # tests/test_wire2d.py)
    step_c = make_train_step(fwd, loss, tc, reduce="compressed", mesh=mesh,
                             wire_layout="1d")
    step_r = make_train_step(
        fwd, loss, tc, grad_tx=lambda g, s: ef_compress(g, s, kind="int8"))
    with mesh:
        jc, jr = jax.jit(step_c), jax.jit(step_r)
        pc, qc, oc = p0, q0, adamw_init(p0)
        ec = EFState(residual=ef_wire_init(p0, n))
        pr, qr, orr = p0, q0, adamw_init(p0)
        er = ef_init(p0)
        lc, lr_ = [], []
        for s in range(8):
            b = pipe(s)
            pc, qc, oc, mc, ec = jc(pc, qc, oc, b, jnp.int32(s), ec)
            pr, qr, orr, mr, er = jr(pr, qr, orr, b, jnp.int32(s), er)
            lc.append(float(mc["loss"]))
            lr_.append(float(mr["loss"]))
    # step 0 is pre-update: identical up to slice-mean reassociation
    assert abs(lc[0] - lr_[0]) < 1e-5, (lc[0], lr_[0])
    # both curves descend together within EF (one-grid-step) noise
    assert max(abs(a - b) for a, b in zip(lc, lr_)) < 0.05, (lc, lr_)
    assert lc[-1] < lc[0]


@multidevice
def test_compressed_step_hlo_moves_int8():
    """The compiled compressed step must contain s8 gradient collectives
    and NO non-scalar fp32 all-reduce/all-gather of gradient size — the
    fp32 reduction is gone, not merely post-processed."""
    from repro.data import DataSpec, make_pipeline
    from repro.dist import collectives
    from repro.models import JetTagger
    from repro.nn import HGQConfig
    from repro.optim import adamw_init
    from repro.train import TrainConfig, make_train_step, softmax_xent

    cfg = HGQConfig(weight_gran="per_parameter", act_gran="per_parameter",
                    init_weight_f=2, init_act_f=2)
    p0, q0 = JetTagger.init(jax.random.PRNGKey(0), cfg)
    fwd = lambda p, q, b, mode: JetTagger.forward(p, q, b, mode)
    loss = lambda out, b: softmax_xent(out, b["y"])
    pipe = make_pipeline(DataSpec(kind="jet", batch=256))
    tc = TrainConfig(steps=8, lr=3e-3)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    n = collectives.data_axis_size(mesh)
    step = make_train_step(fwd, loss, tc, reduce="compressed", mesh=mesh,
                           wire_layout="1d")
    with mesh:
        ec = EFState(residual=ef_wire_init(p0, n))
        hlo = jax.jit(step).lower(p0, q0, adamw_init(p0), pipe(0),
                                  jnp.int32(0), ec).compile().as_text()
    assert "s8[" in hlo and "all-to-all" in hlo
    # shared repro.analysis parser: every surviving f32 all-reduce is
    # tiny — loss/gnorm scalars, amax grids, TP feature extremes; a
    # gradient-sized one (smallest JetTagger matmul leaf is 16*64) would
    # mean fp32 crossed the wire
    for c in parse_collectives(hlo):
        if c.kind == "all-reduce" and c.dtype == "f32":
            assert c.numel < SCALAR_MAX, c.line[:160]


# ------------------------- fused bucketed path ------------------------------

@settings(max_examples=20)
@given(st.lists(st.integers(min_value=1, max_value=4000), min_size=0,
                max_size=12),
       st.integers(min_value=1, max_value=5000))
def test_property_bucket_leaves_partition(sizes, bucket_bytes):
    """_bucket_leaves is a true partition: every leaf index exactly once,
    every bucket within the budget unless it holds a single oversized
    leaf, and the result deterministic in the input."""
    from repro.dist.collectives import _bucket_leaves
    buckets = _bucket_leaves(sizes, bucket_bytes)
    flat = [i for b in buckets for i in b]
    assert sorted(flat) == list(range(len(sizes)))
    for b in buckets:
        assert b, "empty bucket"
        if len(b) > 1:
            assert sum(sizes[i] for i in b) <= bucket_bytes, (b, sizes)
    assert buckets == _bucket_leaves(sizes, bucket_bytes)


@multidevice
@pytest.mark.parametrize("kind", ["int8", "bf16"])
def test_fused_matches_legacy_1d(kind):
    """The tentpole bit-exactness contract: the fused bucketed wire (one
    concatenated pmax/all_to_all/all_gather per bucket) delivers the SAME
    bits as the legacy per-leaf path and the simulator."""
    from repro.dist.sharding import ef_residual_sharding
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    tree = _stacked(jax.random.PRNGKey(20))
    with mesh:
        placed = jax.device_put(tree, ef_residual_sharding(tree, mesh))
        df, rf = jax.jit(lambda t: ef_wire_pmean(
            t, mesh, kind, fused=True))(placed)
        dl, rl = jax.jit(lambda t: ef_wire_pmean(
            t, mesh, kind, fused=False))(placed)
    ds, rs = simulate_wire_pmean(tree, kind)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(df[k]), np.asarray(dl[k]))
        np.testing.assert_array_equal(np.asarray(rf[k]), np.asarray(rl[k]))
        np.testing.assert_array_equal(np.asarray(df[k]), np.asarray(ds[k]))
        np.testing.assert_array_equal(np.asarray(rf[k]), np.asarray(rs[k]))


@multidevice
def test_fused_multi_bucket_matches_simulator():
    """A tiny bucket budget forces every leaf into its own pipelined
    bucket (odd chunk tails included) — still bit-for-bit the simulator,
    with mixed per-leaf widths riding the nibble wire."""
    from repro.dist.sharding import ef_residual_sharding
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    tree = _stacked(jax.random.PRNGKey(21))
    widths = {"layers": 4, "vec": 8, "scalar": 8}
    from repro.dist.collectives import _bucket_leaves, _WIRE_BUCKET_BYTES
    assert _WIRE_BUCKET_BYTES >= 1 << 20
    ds, rs = simulate_wire_pmean(tree, "int8", widths=widths)
    with mesh:
        placed = jax.device_put(tree, ef_residual_sharding(tree, mesh))
        for bb in (1, 256):                    # 3 buckets / mixed buckets
            d, r = jax.jit(lambda t, b=bb: ef_wire_pmean(
                t, mesh, "int8", widths=widths, fused=True,
                bucket_bytes=b))(placed)
            for k in tree:
                np.testing.assert_array_equal(np.asarray(d[k]),
                                              np.asarray(ds[k]))
                np.testing.assert_array_equal(np.asarray(r[k]),
                                              np.asarray(rs[k]))


@multidevice
def test_fused_records_same_bytes_as_legacy():
    """The byte recorder sees identical per-leaf wire records from the
    fused and legacy traces (tags and values; order may differ with the
    bucket schedule) — the fusion moves launches, not bytes."""
    from repro.dist.collectives import record_wire_bytes
    from repro.dist.sharding import ef_residual_sharding
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    tree = _stacked(jax.random.PRNGKey(22))
    widths = {"layers": 4, "vec": 8, "scalar": 8}
    with mesh:
        placed = jax.device_put(tree, ef_residual_sharding(tree, mesh))
        recs = {}
        for fused in (True, False):
            fn = jax.jit(lambda t, f=fused: ef_wire_pmean(
                t, mesh, "int8", widths=widths, fused=f))
            with record_wire_bytes() as rec:
                fn.lower(placed)
            recs[fused] = sorted(rec.records)
    assert recs[True] == recs[False], recs
