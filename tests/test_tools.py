"""tools/ CI gates — exit codes, violation fixtures, allowlists.

Covers ``check_no_globals.py`` (the source-rule registry CLI, incl. the
tuple-unpack/starred-target regression), ``check_specs.py`` (round-trip
gate on good/bad/non-canonical fixtures), and ``lint_programs.py`` (the
program-report gate: green on a fresh baseline, nonzero on an injected
baseline drift — the "extra collective launch" acceptance check).
"""
import importlib.util
import json
import os
import sys

import pytest

from repro.analysis import check_source

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _load_tool(name):
    spec = importlib.util.spec_from_file_location(
        name, os.path.join(ROOT, "tools", f"{name}.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# --------------------------- check_no_globals ------------------------------

def test_source_rules_tuple_unpack_regression():
    """The historical escape: tuple-unpack and starred targets slipped
    past the module-mutable rule."""
    probs = check_source("src/repro/x.py", "a, b = [], {}\n")
    assert len(probs) == 2
    assert any("`a`" in p for p in probs) and any("`b`" in p for p in probs)
    # element-wise: only the mutable element is flagged
    probs = check_source("src/repro/x.py", "a, b = [], 3\n")
    assert len(probs) == 1 and "`a`" in probs[0]
    # a starred target always binds a fresh list
    probs = check_source("src/repro/x.py", "a, *rest = (1, 2, 3)\n")
    assert len(probs) == 1 and "`rest`" in probs[0]
    # nested unpack
    probs = check_source("src/repro/x.py", "(a, b), c = ([], 1), {}\n")
    assert any("`a`" in p for p in probs) and any("`c`" in p for p in probs)
    assert not any("`b`" in p for p in probs)


def test_source_rules_global_and_mutable():
    bad = "X = {}\n\ndef f():\n    global X\n    X = {}\n"
    probs = check_source("src/repro/x.py", bad)
    assert any("[no-global]" in p for p in probs)
    assert any("[module-mutable]" in p for p in probs)
    assert check_source("src/repro/x.py", "X = (1, 2)\nY = 3\n") == []
    # dunders and annotations-without-value stay exempt
    assert check_source("src/repro/x.py",
                        "__all__ = []\nz: dict\n") == []


def test_source_rules_allowlist_and_pragma():
    src = "CACHE = {}\n"
    assert check_source("src/repro/x.py", src) != []
    assert check_source("src/repro/x.py", src,
                        allow=frozenset({"src/repro/x.py::CACHE"})) == []
    assert check_source("src/repro/x.py", src,
                        allow=frozenset({"src/repro/x.py::*"})) == []
    assert check_source(
        "src/repro/x.py",
        "CACHE = {}  # lint: allow(module-mutable)\n") == []
    # the pragma names ONE rule; a different rule on the line still fires
    assert check_source(
        "src/repro/x.py",
        "CACHE = {}  # lint: allow(fixed-prngkey)\n") != []


def test_source_rules_inexact_bit_arith_scoped_to_bit_exact_modules():
    src = "import jax.numpy as jnp\ns = jnp.exp2(f)\n"
    probs = check_source("src/repro/core/quantizer.py", src)
    assert len(probs) == 1 and "[inexact-bit-arith]" in probs[0]
    assert check_source("src/repro/kernels/wire_pack/kernel.py", src) != []
    # outside the bit-exact modules jnp.exp2 is fine (e.g. an LR schedule)
    assert check_source("src/repro/train/loop.py", src) == []
    # python-level powers are exact and allowed everywhere
    assert check_source("src/repro/core/quantizer.py",
                        "m = 2.0 ** 24\np = pow(2, 5)\n") == []


def test_source_rules_fixed_prngkey_and_shims():
    probs = check_source(
        "src/repro/a.py", "import jax\nk = jax.random.PRNGKey(0)\n")
    assert len(probs) == 1 and "[fixed-prngkey]" in probs[0]
    # a non-zero literal is a deliberate fixture constant, not the bug
    assert check_source("src/repro/a.py",
                        "k = jax.random.PRNGKey(7)\n") == []
    probs = check_source("src/repro/a.py",
                         "from repro.dist import set_axes\n"
                         "set_axes(('data',), 'model')\n")
    assert len(probs) == 1 and "[deprecated-shim-call]" in probs[0]
    # referencing (importing, defining) the shim is not calling it
    assert check_source("src/repro/a.py",
                        "def set_axes(*a, **k):\n    pass\n") == []


def test_check_no_globals_cli(tmp_path):
    tool = _load_tool("check_no_globals")
    src = tmp_path / "src" / "repro"
    src.mkdir(parents=True)
    (src / "ok.py").write_text("X = (1,)\n")
    assert tool.main(["--src", str(src)]) == 0
    (src / "bad.py").write_text("a, b = [], {}\n")
    assert tool.main(["--src", str(src)]) == 1
    assert tool.main(["--src", str(tmp_path / "nope")]) == 2


def test_check_no_globals_real_tree_is_clean():
    tool = _load_tool("check_no_globals")
    assert tool.main([]) == 0


# ----------------------------- check_specs ---------------------------------

def test_check_specs_cli(tmp_path):
    tool = _load_tool("check_specs")
    # the shipped specs pass
    assert tool.main([]) == 0
    # empty dir is a bad invocation, not a pass
    empty = tmp_path / "none"
    empty.mkdir()
    assert tool.main(["--specs-dir", str(empty)]) == 2
    # unparseable spec fails
    bad = tmp_path / "bad"
    bad.mkdir()
    (bad / "x.json").write_text('{"arch": "no-such-arch"!!}\n')
    assert tool.main(["--specs-dir", str(bad)]) == 1
    # parseable but non-canonical bytes fail too
    from repro.api import RunSpec
    noncanon = tmp_path / "noncanon"
    noncanon.mkdir()
    (noncanon / "y.json").write_text(
        json.dumps(json.loads(RunSpec().to_json())) + "\n")  # no indent
    assert tool.main(["--specs-dir", str(noncanon)]) == 1


# ---------------------------- lint_programs --------------------------------

def test_lint_programs_override_parsing():
    import argparse
    tool = _load_tool("lint_programs")
    assert tool._parse_override("train:*.launches=0.5") == \
        ("train:*.launches", 0.5)
    with pytest.raises(argparse.ArgumentTypeError):
        tool._parse_override("no-equals-sign")


def test_lint_programs_gate_and_injected_drift(tmp_path):
    """End-to-end over the 1x1 spec only (fits any host): --update
    creates the baseline and exits 0, the same programs pass against it,
    and a doctored baseline claiming FEWER launches / MORE aliases makes
    the gate exit 1 — the injected-violation acceptance check."""
    tool = _load_tool("lint_programs")
    specs = tmp_path / "specs"
    specs.mkdir()
    shipped = os.path.join(ROOT, "examples", "specs", "host_1x1.json")
    (specs / "host_1x1.json").write_text(open(shipped).read())
    # plan files are skipped, not parsed as RunSpec
    (specs / "plan_x.json").write_text("{not json}")

    out = tmp_path / "report.json"
    baseline = tmp_path / "PROGRAMS.json"
    common = ["--specs-dir", str(specs), "--out", str(out),
              "--baseline", str(baseline)]

    # no baseline yet -> 2
    assert tool.main(common) == 2
    # create it -> 0, then the identical program passes -> 0
    assert tool.main(common + ["--update"]) == 0
    assert tool.main(common) == 0

    report = json.loads(out.read_text())
    assert set(report["programs"]) == {"train:host_1x1",
                                       "decode:host_1x1"}
    for rep in report["programs"].values():
        assert rep["violations"] == []

    # inject drift: the golden claims an alias the program doesn't have
    # (equivalently: the fresh program dropped a donation) and fewer
    # collective launches than the program performs
    doctored = json.loads(baseline.read_text())
    doctored["programs"]["train:host_1x1"]["aliased_buffers"] += 1
    baseline.write_text(json.dumps(doctored))
    assert tool.main(common) == 1

    # an override widening the drifted metric lets it pass again
    assert tool.main(common + [
        "--override", "train:host_1x1.aliased_buffers=0.5"]) == 0
