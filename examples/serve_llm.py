"""Continuous-batching serving example: ragged per-slot decode + HGQ
int8-packed weights on the decode hot path.

Each serving mode is a declarative ``repro.api.RunSpec`` (fp vs
``precision.packed_serving=True``), and the two engines are built from
two *coexisting* RunContexts in one process — the packed engine's traces
never perturb the fp engine's (no global flags).  Runs a reduced
llama-family model, serves a ragged workload (prompts of different
lengths joining and leaving mid-run) through the single jitted per-slot
decode step in both modes; packed decode projections run on the fused
int8 dequant-matmul Pallas kernel (``kernels/qmatmul``), the TPU serving
win of HGQ (DESIGN.md SS2: decode is HBM-bound; packed weights halve the
streamed bytes).

    PYTHONPATH=src python examples/serve_llm.py
"""
import dataclasses
import time

import jax

from repro.api import PrecisionSpec, RunSpec, build
from repro.serving import Request, SamplingConfig, generate
from repro.serving.packed import pack_tree, packed_nbytes


def make_requests(vocab):
    key = jax.random.PRNGKey(7)
    lens = [3, 9, 2, 7, 12, 5]
    reqs = []
    for i, n in enumerate(lens):
        toks = jax.random.randint(jax.random.fold_in(key, i), (n,), 1, vocab)
        reqs.append(Request(prompt=[int(t) for t in toks], max_new=8))
    # one sampled request in the same batch as the greedy ones
    reqs[-1].sampling = SamplingConfig(temperature=0.8, top_k=16)
    return reqs


def serve(ctx, params, qstate):
    eng = ctx.make_engine(params, qstate, batch_slots=4, max_len=64,
                          prefill_chunk=8)
    reqs = make_requests(ctx.cfg.vocab)
    t0 = time.perf_counter()
    eng.run(reqs)
    dt = time.perf_counter() - t0
    new_tokens = sum(len(r.out) for r in reqs)
    tag = "packed" if eng.packed else "fp"
    print(f"[{tag}] {len(reqs)} requests, {new_tokens} new tokens "
          f"in {dt:.2f}s ({new_tokens / dt:.1f} tok/s incl. compile)")
    return reqs


def main():
    spec = RunSpec(arch="llama3.2-3b")
    packed_spec = dataclasses.replace(
        spec, precision=PrecisionSpec(packed_serving=True))

    # two contexts, two precisions, one process: the fp and packed
    # engines trace under their own spec — nothing global is shared
    ctx, packed_ctx = build(spec), build(packed_spec)
    params, qstate = ctx.init_state()

    # ---- fp engine: ragged continuous batching -----------------------
    reqs = serve(ctx, params, qstate)
    for i, r in enumerate(reqs):
        print(f"  request {i}: prompt[{len(r.prompt)}] -> {r.out}")

    # ---- packed engine: int8 weights on the decode path --------------
    packed_reqs = serve(packed_ctx, params, qstate)
    greedy = [i for i, r in enumerate(reqs) if r.sampling is None]
    agree = sum(reqs[i].out == packed_reqs[i].out for i in greedy)
    print(f"  greedy packed-vs-fp request agreement: {agree}/{len(greedy)}")
    fp_b, q_b = packed_nbytes(params), packed_nbytes(pack_tree(params))
    print(f"  weight bytes {fp_b} -> {q_b} "
          f"({fp_b / q_b:.2f}x HBM saving at decode)")

    # ---- per-request greedy reference (what the tests assert) --------
    import jax.numpy as jnp
    r = reqs[0]
    ref = generate(ctx.model, params, qstate, ctx.cfg,
                   jnp.asarray([r.prompt], jnp.int32), r.max_new,
                   cache_len=64)
    print(f"  engine == generate() for request 0: "
          f"{[int(t) for t in ref[0]] == r.out}")


if __name__ == "__main__":
    main()
