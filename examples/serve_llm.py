"""Batched serving example: continuous-batching engine + HGQ-packed weights.

Runs a reduced llama-family model, serves a batch of requests through the
KV-cache decode path, and shows the packed-weight (int8 + 2^-f scale)
matmul agreeing with the float path — the TPU serving win of HGQ
(DESIGN.md SS2: decode is HBM-bound; packed weights halve the bytes).

    PYTHONPATH=src python examples/serve_llm.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get
from repro.kernels import pack_weights, qmatmul_any
from repro.models import model_for
from repro.serving import Engine, Request


def main():
    cfg = get("llama3.2-3b", smoke=True)
    M = model_for(cfg)
    params, qstate = M.init(jax.random.PRNGKey(0), cfg)

    # ---- continuous-batching engine over the KV-cache decode path ----
    eng = Engine(M, params, qstate, cfg, batch_slots=4, max_len=64)
    reqs = [Request(prompt=[1 + i, 7, 42], max_new=8) for i in range(6)]
    eng.run(reqs)
    for i, r in enumerate(reqs):
        print(f"request {i}: prompt={r.prompt} -> {r.out}")

    # ---- packed-weight serving path (per-channel trained bits) ----
    lm_head = params["embed"]["table"]  # tied embeddings
    w = lm_head["w"].T                  # [d, vocab]
    f = lm_head.get("f")
    f_cols = jnp.broadcast_to(jnp.asarray(f).T, w.shape) if f is not None \
        else jnp.full(w.shape, 6.0)
    w_int, scale = pack_weights(w, jnp.max(f_cols, axis=0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, cfg.d_model))
    y_packed = qmatmul_any(x, w_int, scale)
    y_float = x @ (w_int.astype(jnp.float32) * scale[None, :])
    err = float(jnp.max(jnp.abs(y_packed - y_float)))
    bytes_bf16 = w.size * 2
    bytes_int8 = w_int.size + 4 * scale.size
    print(f"packed lm_head: max|err|={err:.2e}  "
          f"bytes {bytes_bf16} -> {bytes_int8} "
          f"({bytes_bf16 / bytes_int8:.2f}x HBM saving at decode)")


if __name__ == "__main__":
    main()
