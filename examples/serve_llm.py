"""Continuous-batching serving example: ragged per-slot decode, HGQ
int8-packed weights, and the plan-width quantized KV ring buffer.

Each serving mode is a declarative ``repro.api.RunSpec`` — the serving
surface itself is the frozen ``ServingSpec`` part (slots, kv_cache,
packing override) — and the engines are built from *coexisting*
RunContexts in one process: one engine's traces never perturb
another's (no global flags).  Runs a reduced llama-family model and
serves a ragged workload (prompts of different lengths joining and
leaving mid-run) through the single jitted per-slot decode step:

* ``fp``      — bf16 weights, fp KV cache (the exact legacy path);
* ``packed``  — decode projections on the fused int8 dequant-matmul
  Pallas kernel (``kernels/qmatmul``): packed weights halve the
  streamed HBM bytes (DESIGN.md SS2: decode is HBM-bound);
* ``kv_plan`` — KV ring buffer stored at the plan's learned widths
  (``ServingSpec(kv_cache="plan")``, reads through
  ``kernels/kv_dequant``): nibble KV cuts cache bytes ~2.7x, the other
  half of the decode-bandwidth story.

The fp engine is driven through the handle surface —
``submit() -> RequestHandle`` plus the incremental ``tokens(handle)``
reader — the streaming API; ``run()`` is the same engine behind a batch
wrapper, which the other modes use.

    PYTHONPATH=src python examples/serve_llm.py
"""
import dataclasses
import time

import jax

from repro.api import PrecisionSpec, RunSpec, ServingSpec, build
from repro.core.plan import LayerPlan, PrecisionPlan
from repro.serving import (Request, SamplingConfig, generate,
                           kv_bytes_per_token)
from repro.serving.packed import pack_tree, packed_nbytes


def make_requests(vocab):
    key = jax.random.PRNGKey(7)
    lens = [3, 9, 2, 7, 12, 5]
    reqs = []
    for i, n in enumerate(lens):
        toks = jax.random.randint(jax.random.fold_in(key, i), (n,), 1, vocab)
        reqs.append(Request(prompt=[int(t) for t in toks], max_new=8))
    # one sampled request in the same batch as the greedy ones
    reqs[-1].sampling = SamplingConfig(temperature=0.8, top_k=16)
    return reqs


def serve(tag, ctx, params, qstate):
    eng = ctx.make_engine(params, qstate, max_len=64, prefill_chunk=8)
    reqs = make_requests(ctx.cfg.vocab)
    t0 = time.perf_counter()
    eng.run(reqs)
    dt = time.perf_counter() - t0
    new_tokens = sum(len(r.out) for r in reqs)
    print(f"[{tag}] {len(reqs)} requests, {new_tokens} new tokens "
          f"in {dt:.2f}s ({new_tokens / dt:.1f} tok/s incl. compile)")
    return reqs


def serve_streaming(ctx, params, qstate):
    """The handle surface: admit what fits, stream tokens as they land,
    backfill freed slots — same engine ``run()`` wraps."""
    eng = ctx.make_engine(params, qstate, max_len=64, prefill_chunk=8)
    reqs = make_requests(ctx.cfg.vocab)
    pending, handles = list(reqs), []
    t0 = time.perf_counter()
    while pending and (h := eng.submit(pending[0])):
        handles.append(h)
        pending.pop(0)
    while handles:
        h = handles.pop(0)
        for tok in eng.tokens(h):          # incremental reader
            while pending and (h2 := eng.submit(pending[0])):
                handles.append(h2)
                pending.pop(0)
    dt = time.perf_counter() - t0
    new_tokens = sum(len(r.out) for r in reqs)
    print(f"[fp/stream] {len(reqs)} requests, {new_tokens} new tokens "
          f"in {dt:.2f}s ({new_tokens / dt:.1f} tok/s incl. compile)")
    return reqs


def main():
    spec = RunSpec(arch="llama3.2-3b", serving=ServingSpec(slots=4))
    packed_spec = dataclasses.replace(
        spec, precision=PrecisionSpec(packed_serving=True))
    kv_spec = dataclasses.replace(
        spec, plan=PrecisionPlan(default=LayerPlan(kv_bits=4)),
        serving=dataclasses.replace(spec.serving, kv_cache="plan"))

    # three contexts, one process: each engine traces under its own spec
    ctx, packed_ctx, kv_ctx = build(spec), build(packed_spec), build(kv_spec)
    params, qstate = ctx.init_state()

    # ---- fp engine, handle surface: streaming ragged batching --------
    reqs = serve_streaming(ctx, params, qstate)
    for i, r in enumerate(reqs):
        print(f"  request {i}: prompt[{len(r.prompt)}] -> {r.out}")

    # ---- packed engine: int8 weights on the decode path --------------
    packed_reqs = serve("packed", packed_ctx, params, qstate)
    greedy = [i for i, r in enumerate(reqs) if r.sampling is None]
    agree = sum(reqs[i].out == packed_reqs[i].out for i in greedy)
    print(f"  greedy packed-vs-fp request agreement: {agree}/{len(greedy)}")
    fp_b, q_b = packed_nbytes(params), packed_nbytes(pack_tree(params))
    print(f"  weight bytes {fp_b} -> {q_b} "
          f"({fp_b / q_b:.2f}x HBM saving at decode)")

    # ---- quantized-KV engine: nibble ring buffer ---------------------
    kv_reqs = serve("kv_plan", kv_ctx, params, qstate)
    agree = sum(reqs[i].out == kv_reqs[i].out for i in greedy)
    print(f"  greedy kv_plan-vs-fp request agreement: {agree}/{len(greedy)}")
    cfg = ctx.cfg
    fp_kv = kv_bytes_per_token(cfg.n_kv, cfg.hd, cfg.n_layers, None)
    q_kv = kv_bytes_per_token(cfg.n_kv, cfg.hd, cfg.n_layers, 4)
    print(f"  KV bytes/token {fp_kv} -> {q_kv} "
          f"({fp_kv / q_kv:.2f}x decode-bandwidth saving)")

    # ---- per-request greedy reference (what the tests assert) --------
    import jax.numpy as jnp
    r = reqs[0]
    ref = generate(ctx.model, params, qstate, ctx.cfg,
                   jnp.asarray([r.prompt], jnp.int32), r.max_new,
                   cache_len=64)
    print(f"  engine == generate() for request 0: "
          f"{[int(t) for t in ref[0]] == r.out}")


if __name__ == "__main__":
    main()
