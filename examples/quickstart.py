"""Quickstart: HGQ in ~40 lines (the JAX analogue of the paper's Listing 2).

Build a small quantized MLP, train with the Eq.-16 loss (beta ramp), watch
the EBOPs fall while accuracy holds, then calibrate integer bits.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import hgq
from repro.data import DataSpec, make_pipeline
from repro.models import JetTagger
from repro.nn import HGQConfig
from repro.train import TrainConfig, Trainer, accuracy, softmax_xent


def main():
    # per-parameter granularity — every weight gets its own trainable bitwidth
    qcfg = HGQConfig(weight_gran="per_parameter", act_gran="per_parameter",
                     init_weight_f=2.0, init_act_f=2.0)
    params, qstate = JetTagger.init(jax.random.PRNGKey(0), qcfg)

    pipe = make_pipeline(DataSpec(kind="jet", batch=1024))
    fwd = lambda p, q, batch, mode: JetTagger.forward(p, q, batch, mode)
    tcfg = TrainConfig(steps=300, lr=3e-3, beta0=1e-6, beta1=1e-3,
                       gamma=2e-6, log_every=50)
    trainer = Trainer(fwd, lambda out, b: softmax_xent(out, b["y"]), tcfg,
                      params, qstate, pipeline=pipe)
    trainer.run()

    # evaluate + calibrate (exact range pass fixes the integer bits, Eq. 3)
    batch = pipe(10 ** 6)
    logits, qcal, aux = JetTagger.forward(trainer.params, trainer.qstate,
                                          batch, mode=hgq.CALIB)
    print(f"accuracy      : {float(accuracy(logits, batch['y'])):.4f}")
    print(f"~EBOPs        : {float(aux.ebops):.0f}")
    f0 = trainer.params["d0"]["kernel"]["f"]
    print(f"layer-0 bits  : mean={float(jnp.mean(f0)):.2f} "
          f"min={float(jnp.min(f0)):.2f} max={float(jnp.max(f0)):.2f}")


if __name__ == "__main__":
    main()
