"""Streaming ASR serving example: audio-chunk requests beside LM
traffic in the continuous-batching engine.

Builds the checked-in golden spec
``examples/specs/serving_asr_stream.json`` — a Whisper encoder-decoder
with the plan-width quantized KV cache and the ``["lm", "asr"]``
workload mix — and serves a mixed workload through the
``serving.StreamingEngine`` that ``ctx.make_engine`` routes to:

* audio arrives in ``chunk_frames``-sized chunks (one per engine tick:
  the arrival simulation); the encoder runs block-locally at absolute
  frame offsets and appends quantized cross-attention K/V into the
  request's slot slice;
* when the last chunk lands the decoder prompt prefills and the slot
  joins the SAME jitted ragged decode tick the LM requests run in;
* per-request SLO latencies come back on the request: ``ttft_s`` (last
  chunk -> first token) and ``t_chunks`` (per-chunk encode+append wall).

The streamed transcript is checked token-for-token against the offline
whole-audio :func:`repro.serving.generate_asr` reference — same
``split_audio`` block decomposition, so it must match exactly.

    PYTHONPATH=src python examples/serve_asr_stream.py
"""
import pathlib
import time

import jax
import numpy as np

from repro.api import RunSpec, build
from repro.serving import (AudioRequest, Request, generate_asr,
                           kv_bytes_per_token, kv_cross_bytes_per_request)

SPEC = pathlib.Path(__file__).resolve().parent / "specs" / \
    "serving_asr_stream.json"


def make_workload(cfg, n_streams=3, n_lm=3):
    key = jax.random.PRNGKey(7)
    auds = [AudioRequest(
        frames=jax.random.normal(jax.random.fold_in(key, i),
                                 (cfg.enc_seq - 3 * i, cfg.d_model)) * 0.3,
        prompt=[1, 2 + i], max_new=6) for i in range(n_streams)]
    lms = [Request(prompt=[int(t) for t in jax.random.randint(
               jax.random.fold_in(key, 100 + i), (3 + i,), 1, cfg.vocab)],
               max_new=6) for i in range(n_lm)]
    return auds, lms


def main():
    spec = RunSpec.from_file(str(SPEC))
    ctx = build(spec)
    params, qstate = ctx.init_state()
    cfg = ctx.cfg
    eng = ctx.make_engine(params, qstate, max_len=64)
    print(f"[spec] {cfg.name}: workloads={spec.serving.workloads}, "
          f"chunk_frames={spec.serving.audio.chunk_frames}, "
          f"kv_bits={eng.kv_bits}")

    # ---- mixed streaming workload through the shared scheduler -------
    auds, lms = make_workload(cfg)
    t0 = time.perf_counter()
    eng.run(auds + lms)
    dt = time.perf_counter() - t0
    tokens = sum(len(r.out) for r in auds + lms)
    print(f"[mixed] {len(auds)} streams + {len(lms)} LM requests, "
          f"{tokens} tokens in {dt:.2f}s (incl. compile)")
    for i, a in enumerate(auds):
        print(f"  stream {i}: {a.frames.shape[0]} frames in "
              f"{len(a.t_chunks)} chunks, ttft {1e3 * a.ttft_s:.1f}ms, "
              f"chunk p50 {1e3 * sorted(a.t_chunks)[len(a.t_chunks) // 2]:.1f}ms"
              f" -> {a.out}")

    # ---- offline whole-audio reference: must match token-for-token ---
    ok = all(a.out == [int(t) for t in np.asarray(
        generate_asr(ctx.model, params, qstate, cfg, a.frames, a.prompt,
                     a.max_new, chunk=eng.audio_chunk, cache_len=64,
                     kv_bits=eng.kv_bits))[0]] for a in auds)
    print(f"[check] streamed == offline generate_asr for all streams: {ok}")

    # ---- handle surface: incremental transcript reader ---------------
    h = eng.submit_audio(AudioRequest(
        frames=auds[0].frames, prompt=list(auds[0].prompt), max_new=6))
    toks = list(eng.tokens(h))
    print(f"[handle] submit_audio + tokens(h) -> {toks} "
          f"(match run(): {toks == auds[0].out})")

    # ---- the two memory axes of an ASR request -----------------------
    ring = kv_bytes_per_token(cfg.n_kv, cfg.hd, cfg.n_layers, eng.kv_bits)
    cross = kv_cross_bytes_per_request(cfg.n_kv, cfg.hd, cfg.n_layers,
                                       cfg.enc_seq, eng.kv_bits)
    cross_fp = kv_cross_bytes_per_request(cfg.n_kv, cfg.hd, cfg.n_layers,
                                          cfg.enc_seq, None)
    print(f"[memory] self ring {ring} B/token (grows per decode); cross "
          f"memory {cross} B/request static pin ({cross_fp / cross:.2f}x "
          f"below fp)")


if __name__ == "__main__":
    main()
