"""End-to-end LM training driver: any assigned arch (smoke or full config),
HGQ quantization-aware, checkpointed + resumable.

CPU demo (default; a reduced llama-family model, a few hundred steps):
    PYTHONPATH=src python examples/train_lm.py --arch llama3.2-3b --steps 200

On a real pod the same driver runs the full config under the production
mesh (see src/repro/launch/train.py for the pjit wrapper).
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get
from repro.data import DataSpec, make_pipeline
from repro.models import model_for
from repro.train import TrainConfig, Trainer, lm_loss


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--full", action="store_true",
                    help="use the full (pod-scale) config instead of smoke")
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    cfg = get(args.arch, smoke=not args.full)
    M = model_for(cfg)
    print(f"arch={cfg.name} params={cfg.n_params()/1e6:.1f}M "
          f"(active {cfg.n_active_params()/1e6:.1f}M)")
    params, qstate = M.init(jax.random.PRNGKey(0), cfg)

    pipe_raw = make_pipeline(DataSpec(kind="lm", batch=args.batch,
                                      seq=args.seq, vocab=cfg.vocab))

    def pipe(step):
        b = pipe_raw(step)
        if cfg.family == "vlm":
            b["patch_embeds"] = jnp.zeros((args.batch, cfg.n_patches,
                                           cfg.d_model))
        if cfg.family == "audio":
            b["frame_embeds"] = jnp.zeros((args.batch, cfg.enc_seq,
                                           cfg.d_model))
        return b

    fwd = lambda p, q, batch, mode: M.forward(p, q, batch, cfg, mode)
    tcfg = TrainConfig(steps=args.steps, lr=1e-3, beta0=1e-9, beta1=1e-7,
                       log_every=max(args.steps // 10, 1),
                       ckpt_every=max(args.steps // 4, 1),
                       ckpt_dir=args.ckpt_dir)
    tr = Trainer(fwd, lambda out, b: lm_loss(out, b["tokens"]), tcfg,
                 params, qstate, pipeline=pipe)
    if args.ckpt_dir and tr.maybe_resume():
        print(f"resumed from step {tr.start_step}")
    res = tr.run()
    print(f"final loss={res['metrics']['loss']:.4f} "
          f"ebops={res['metrics']['ebops']:.3g} "
          f"wall={res['wall_s']:.1f}s")


if __name__ == "__main__":
    main()
