"""End-to-end LM training driver: any assigned arch (smoke or full config),
HGQ quantization-aware, checkpointed + resumable.

Configuration is one declarative ``repro.api.RunSpec`` (the same surface
``repro.launch.train`` and the benchmarks parse): CLI flags are overrides
on a spec, ``--spec run.json`` loads one whole.

CPU demo (default; a reduced llama-family model, a few hundred steps):
    PYTHONPATH=src python examples/train_lm.py --arch llama3.2-3b --steps 200

On a real pod the same spec drives the full config under the production
mesh (see src/repro/launch/train.py for the pjit wrapper).
"""
import dataclasses

import jax.numpy as jnp

from repro.api import RunSpec, build
from repro.train import Trainer, lm_loss


def main():
    # this example's own defaults — a llama-family arch, a longer run, a
    # denser batch than the launcher.  Explicit flags override them; a
    # --spec file replaces them entirely (never silently rewritten).
    base = RunSpec(
        arch="llama3.2-3b",
        train=dataclasses.replace(RunSpec().train, steps=200,
                                  log_every=20, ckpt_every=50),
        data=dataclasses.replace(RunSpec().data, batch=8, seq=64))
    spec = RunSpec.from_parsed(RunSpec.parser().parse_args(), base=base)

    ctx = build(spec)
    cfg = ctx.cfg
    print(f"arch={cfg.name} params={cfg.n_params()/1e6:.1f}M "
          f"(active {cfg.n_active_params()/1e6:.1f}M)")
    params, qstate = ctx.init_state()
    pipe_raw = ctx.make_pipeline()
    batch = spec.data.batch

    def pipe(step):
        b = pipe_raw(step)
        if cfg.family == "vlm":
            b["patch_embeds"] = jnp.zeros((batch, cfg.n_patches,
                                           cfg.d_model))
        if cfg.family == "audio":
            b["frame_embeds"] = jnp.zeros((batch, cfg.enc_seq,
                                           cfg.d_model))
        return b

    tr = Trainer(ctx.wrap(ctx.forward),
                 lambda out, b: lm_loss(out, b["tokens"]), spec.train,
                 params, qstate, pipeline=pipe)
    if spec.train.ckpt_dir and tr.maybe_resume():
        print(f"resumed from step {tr.start_step}")
    res = tr.run()
    print(f"final loss={res['metrics']['loss']:.4f} "
          f"ebops={res['metrics']['ebops']:.3g} "
          f"wall={res['wall_s']:.1f}s")


if __name__ == "__main__":
    main()
