"""Paper Table-I style experiment: one training run with a beta ramp
recovers the full accuracy/EBOPs Pareto front, then each front member is
calibrated and its exact EBOPs + pruning fraction reported.

    PYTHONPATH=src python examples/pareto_sweep_jet.py

With ``--emit-specs DIR`` every front point also carries the
:class:`repro.core.plan.PrecisionPlan` derived from its params snapshot
(per-layer wire/pack widths), and the sweep emits one ready-to-run
RunSpec+plan JSON per point plus ``front.json`` into DIR:

    PYTHONPATH=src python examples/pareto_sweep_jet.py --emit-specs out/
    PYTHONPATH=src python -m repro.launch.train --spec out/pareto_00_*.json
"""
import argparse

import jax
import jax.numpy as jnp

from repro.core import hgq
from repro.core.plan import plan_from_params
from repro.core.quantizer import quantize_inference
from repro.data import DataSpec, make_pipeline
from repro.models import JetTagger
from repro.nn import HGQConfig
from repro.train import TrainConfig, Trainer, accuracy, softmax_xent


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--emit-specs", default=None, metavar="DIR",
                    help="derive a PrecisionPlan per Pareto point and "
                         "write ready-to-run RunSpec+plan JSONs there")
    args = ap.parse_args()

    qcfg = HGQConfig(weight_gran="per_parameter", act_gran="per_parameter",
                     init_weight_f=2.0, init_act_f=2.0)
    params, qstate = JetTagger.init(jax.random.PRNGKey(0), qcfg)
    pipe = make_pipeline(DataSpec(kind="jet", batch=1024))
    fwd = lambda p, q, batch, mode: JetTagger.forward(p, q, batch, mode)

    def eval_fn(p, q):
        b = pipe(10 ** 6)
        out, _, aux = JetTagger.forward(p, q, b, mode=hgq.EVAL)
        metric, ebops = float(accuracy(out, b["y"])), float(aux.ebops)
        if args.emit_specs:
            # the point's payload: the width table its bit distribution
            # supports right now — checkpointing the *plan*, not the params
            return metric, ebops, plan_from_params(p)
        return metric, ebops

    tcfg = TrainConfig(steps=800, lr=3e-3, beta0=1e-6, beta1=5e-3,
                       log_every=100, eval_every=50)
    tr = Trainer(fwd, lambda o, b: softmax_xent(o, b["y"]), tcfg, params,
                 qstate, pipeline=pipe, eval_fn=eval_fn)
    tr.run()

    print("\nPareto front (one run, beta ramp 1e-6 -> 5e-3):")
    print(f"{'step':>6} {'accuracy':>9} {'~EBOPs':>9} {'pruned %':>9}")
    for acc, ebops, step in sorted(tr.pareto.front(), key=lambda t: -t[1]):
        print(f"{step:6d} {acc:9.4f} {ebops:9.0f}")
    # pruning report on the final model
    pruned, total = 0, 0
    for name in ("d0", "d1", "d2", "d3"):
        w = tr.params[name]["kernel"]["w"]
        f = tr.params[name]["kernel"]["f"]
        wq = quantize_inference(w, f)
        pruned += int(jnp.sum(wq == 0))
        total += w.size
    print(f"\nfinal model: {100 * pruned / total:.1f}% of weights pruned to "
          f"exactly 0 by bitwidth collapse (paper SSec. III.D.4)")

    if args.emit_specs:
        from repro.api import RunSpec, emit_pareto_specs
        paths = emit_pareto_specs(tr.pareto, RunSpec(), args.emit_specs)
        print(f"\nemitted {len(paths)} RunSpec+plan files -> "
              f"{args.emit_specs} (plus front.json)")
        for p in paths:
            print(f"  {p}")


if __name__ == "__main__":
    main()
