"""Aggregate the dry-run JSONs into the EXPERIMENTS.md roofline table."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from .common import emit

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load_cells(mesh: str = "16x16") -> List[Dict]:
    cells = []
    for fp in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        r = json.load(open(fp))
        if r.get("mesh") == mesh or (r.get("status") == "skipped"
                                     and mesh in fp):
            cells.append(r)
    return cells


def bench_roofline() -> List[str]:
    lines = []
    cells = load_cells("16x16")
    if not cells:
        return [emit("roofline.missing", 0.0,
                     "run repro.launch.dryrun first")]
    n_ok = sum(1 for c in cells if c.get("status") == "ok")
    n_skip = sum(1 for c in cells if c.get("status") == "skipped")
    lines.append(emit("roofline.cells", 0.0,
                      f"ok={n_ok};skipped={n_skip}"))
    for c in cells:
        if c.get("status") != "ok":
            continue
        t_dom = max(c["t_compute_s"], c["t_memory_s"], c["t_collective_s"])
        lines.append(emit(
            f"roofline.{c['arch']}.{c['shape']}", t_dom * 1e6,
            f"bottleneck={c['bottleneck']};"
            f"tc={c['t_compute_s']:.3e};tm={c['t_memory_s']:.3e};"
            f"tcoll={c['t_collective_s']:.3e};"
            f"frac={c.get('roofline_fraction', 0):.4f}"))
    return lines
