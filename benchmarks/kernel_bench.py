"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels run in interpret mode (correctness
only; microseconds are meaningless for TPU).  We therefore time the XLA
reference path (what the kernel replaces) for the CSV and report the
kernel's *structural* numbers — VMEM working set per tile and bytes moved —
which is what the TPU perf model consumes (EXPERIMENTS.md SSRoofline)."""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from repro.kernels.hgq_quantize.kernel import DEFAULT_BLOCK_ROWS, LANE
from repro.kernels.hgq_quantize.ref import hgq_quantize_ref
from repro.kernels.qmatmul.kernel import DEFAULT_BK, DEFAULT_BM, DEFAULT_BN
from repro.kernels.qmatmul.ref import pack_ref, qmatmul_ref

from .common import emit, time_call


def bench_kernels() -> List[str]:
    lines = []
    key = jax.random.PRNGKey(0)
    # hgq_quantize: weight-sized and activation-sized operands
    for name, shape in (("weight_4kx4k", (4096, 4096)),
                        ("act_16x4096x896", (16 * 4096, 896))):
        x = jax.random.normal(key, shape)
        f = jnp.full((shape[-1],), 6.0)
        fn = jax.jit(lambda x, f: hgq_quantize_ref(x, f[None, :]))
        us = time_call(fn, x, f)
        vmem_tile = DEFAULT_BLOCK_ROWS * ((shape[-1] + LANE - 1) // LANE
                                          ) * LANE * 4 * 2
        lines.append(emit(f"kernel.hgq_quantize.{name}", us,
                          f"bytes={x.size * 8};vmem_tile_bytes={vmem_tile};"
                          f"xla_ref_timing=True"))
    # qmatmul: decode-like (M small) and prefill-like (M big)
    for name, (M, K, N) in (("decode_8x2048x7168", (8, 2048, 7168)),
                            ("prefill_2048x2048x2048", (2048, 2048, 2048))):
        x = jax.random.normal(key, (M, K), jnp.float32)
        w = jax.random.normal(key, (K, N)) * 0.05
        wi, s = pack_ref(w, jnp.full((N,), 6.0))
        fn = jax.jit(qmatmul_ref)
        us = time_call(fn, x, wi, s)
        flops = 2.0 * M * K * N
        int8_bytes = K * N + 4 * N
        bf16_bytes = 2 * K * N
        vmem = (DEFAULT_BM * DEFAULT_BK * 4 + DEFAULT_BK * DEFAULT_BN
                + DEFAULT_BM * DEFAULT_BN * 4)
        lines.append(emit(
            f"kernel.qmatmul.{name}", us,
            f"flops={flops:.3g};weight_bytes_int8={int8_bytes};"
            f"weight_bytes_bf16={bf16_bytes};"
            f"hbm_saving={bf16_bytes / int8_bytes:.2f}x;"
            f"vmem_tile_bytes={vmem}"))
    return lines
