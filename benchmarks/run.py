"""Benchmark harness: one entry per paper table/figure + kernels + roofline.

Prints ``name,us_per_call,derived`` CSV lines (brief: deliverable d).
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="jet|svhn|muon|fig2|kernels|roofline")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    only = args.only

    from . import kernel_bench, paper_tables, roofline_table
    if only in (None, "kernels"):
        kernel_bench.bench_kernels()
    if only in (None, "roofline"):
        roofline_table.bench_roofline()
    if only in (None, "jet"):
        paper_tables.bench_table1_jet()
    if only in (None, "muon"):
        paper_tables.bench_table3_muon()
    if only in (None, "fig2"):
        paper_tables.bench_fig2_resource_estimation()
    if only in (None, "svhn"):
        paper_tables.bench_table2_svhn()


if __name__ == "__main__":
    main()
