"""Continuous-batching serving benchmark: decode tokens/sec across
weight (fp vs HGQ int8-packed) and KV-cache (fp vs plan-width quantized)
modes.

Serves an identical ragged workload through ``repro.serving.Engine``
once per ``RunSpec`` mode — bf16/fp weights, the HGQ int8-packed tree
(``packed=True``, decode projections on ``kernels.qmatmul.qmatmul_any``),
and the plan-width quantized KV ring buffer
(``ServingSpec(kv_cache="plan")``, decode reads through
``kernels.kv_dequant``) — and reports two numbers per mode (compile
excluded via a warmup run): ``decode_tokens_per_sec``, pure jitted
decode ticks on a saturated batch (prefill untimed — the steady-state
hot-path number), and ``mixed_tokens_per_sec``, a full continuous-
batching run including chunked prefill and slot churn.  KV rows
additionally report ``kv_bytes_per_token`` and the cache-bandwidth
speedup ``decode_kv_speedup_x`` (decode is KV-bound, so stored cache
bytes per token are the structural decode-throughput model — the
number that holds on TPU where wall time on this container does not).
Writes a JSON artifact so CI accumulates the perf trajectory.

A fourth row (``mode="asr_stream"``) serves the shipped
``examples/specs/serving_asr_stream.json`` streaming-ASR spec through
``serving.StreamingEngine``: audio-chunk requests stream beside LM
traffic in the shared slot scheduler, and the row reports the
bounded-latency SLO metrics — ``ttft_ms`` (last chunk -> first token),
``chunk_latency_p50_ms`` / ``chunk_latency_p90_ms`` (per-chunk encode +
append wall), ``mixed_tokens_per_sec`` over the mixed workload, and the
structural ``cross_kv_bytes_per_request`` the quantized cross-attention
memory pins.

    PYTHONPATH=src python benchmarks/serving_bench.py --smoke
    PYTHONPATH=src python benchmarks/serving_bench.py \
        --arch qwen2-0.5b --requests 16 --max-new 32 --out BENCH_serving.json

On this CPU container the Pallas kernels run in interpret/reference
mode, so the packed and quantized-KV *wall times* are not the TPU story
(the structural bytes-moved numbers in the JSON are); on TPU the same
flags compile the kernels.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax


def ragged_requests(vocab: int, n: int, max_new: int, seed: int = 7):
    from repro.serving import Request
    key = jax.random.PRNGKey(seed)
    reqs = []
    for i in range(n):
        plen = 2 + (i * 5) % 13          # ragged prompt lengths 2..14
        toks = jax.random.randint(jax.random.fold_in(key, i), (plen,), 1,
                                  vocab)
        reqs.append(Request(prompt=[int(t) for t in toks], max_new=max_new))
    return reqs


def bench_engine(ctx, params, qstate, *, mode: str, n_requests: int,
                 max_new: int, max_len: int) -> dict:
    from repro.serving import kv_bytes_per_token
    cfg = ctx.cfg
    slots = ctx.spec.serving.slots
    eng = ctx.make_engine(params, qstate, max_len=max_len, prefill_chunk=8)
    # warmup: compile decode/prefill/sample once
    eng.run(ragged_requests(cfg.vocab, slots, 4))
    # decode-only: saturate every slot (prefill + first token untimed),
    # then time nothing but jitted ragged decode ticks
    dec_reqs = ragged_requests(cfg.vocab, slots, max_new, seed=11)
    for r in dec_reqs:
        if eng.submit(r) is None:
            raise RuntimeError("engine rejected a warm decode request")
    t0 = time.perf_counter()
    while any(s is not None for s in eng.slot_req):
        eng.step()
    dt_dec = time.perf_counter() - t0
    dec_tokens = sum(len(r.out) for r in dec_reqs) - len(dec_reqs)
    # mixed: full continuous-batching run (chunked prefill + slot churn)
    reqs = ragged_requests(cfg.vocab, n_requests, max_new)
    t0 = time.perf_counter()
    eng.run(reqs)
    dt = time.perf_counter() - t0
    new_tokens = sum(len(r.out) for r in reqs)
    # attention layers only: griffin/whisper mix in non-KV blocks, but
    # the archs this bench serves are all-attention stacks
    kv_fp = kv_bytes_per_token(cfg.n_kv, cfg.hd, cfg.n_layers, None)
    kv_now = kv_bytes_per_token(cfg.n_kv, cfg.hd, cfg.n_layers,
                                eng.kv_bits)
    return {"mode": mode,
            "spec": ctx.spec.to_dict(),
            "requests": n_requests,
            "kv_bits": eng.kv_bits,
            "kv_bytes_per_token": kv_now,
            # decode is KV-bandwidth-bound: stored cache bytes per token
            # are the structural decode-throughput model (TPU story)
            "decode_kv_speedup_x": round(kv_fp / kv_now, 2),
            "decode_tokens": dec_tokens, "decode_wall_s": round(dt_dec, 4),
            "decode_tokens_per_sec": round(dec_tokens / dt_dec, 2),
            "mixed_tokens": new_tokens, "mixed_wall_s": round(dt, 4),
            "mixed_tokens_per_sec": round(new_tokens / dt, 2)}


def bench_streaming(ctx, params, qstate, *, n_streams: int, n_lm: int,
                    max_new: int, max_len: int) -> dict:
    """Streaming-ASR SLO metrics: chunked audio through the continuous-
    batching slot scheduler with concurrent LM traffic, timed after a
    compile warmup."""
    from repro.serving import (AudioRequest, kv_bytes_per_token,
                               kv_cross_bytes_per_request)
    cfg = ctx.cfg

    def audio_reqs(seed):
        key = jax.random.PRNGKey(seed)
        return [AudioRequest(
            frames=jax.random.normal(
                jax.random.fold_in(key, i),
                (cfg.enc_seq, cfg.d_model)) * 0.3,
            prompt=[1, 2 + i % 7], max_new=max_new)
            for i in range(n_streams)]

    eng = ctx.make_engine(params, qstate, max_len=max_len,
                          prefill_chunk=8)
    # warmup: compile append_cross per block shape + prefill + decode
    eng.run(audio_reqs(3) + ragged_requests(cfg.vocab, n_lm, 4))
    streams = audio_reqs(11)
    reqs = streams + ragged_requests(cfg.vocab, n_lm, max_new, seed=13)
    t0 = time.perf_counter()
    eng.run(reqs)
    dt = time.perf_counter() - t0
    chunks = sorted(t for r in streams for t in r.t_chunks)
    ttfts = [r.ttft_s for r in streams]
    pct = lambda v, p: v[min(len(v) - 1, round(p * (len(v) - 1)))]
    tokens = sum(len(r.out) for r in reqs)
    return {"mode": "asr_stream",
            "spec": ctx.spec.to_dict(),
            "streams": n_streams, "lm_requests": n_lm,
            "chunk_frames": ctx.spec.serving.audio.chunk_frames,
            "chunks_per_stream": len(streams[0].t_chunks),
            "kv_bits": eng.kv_bits,
            "kv_bytes_per_token": kv_bytes_per_token(
                cfg.n_kv, cfg.hd, cfg.n_layers, eng.kv_bits),
            # static per-request cross-attention memory footprint (the
            # admission-control number; see serving/kvcache.py)
            "cross_kv_bytes_per_request": kv_cross_bytes_per_request(
                cfg.n_kv, cfg.hd, cfg.n_layers, cfg.enc_seq, eng.kv_bits),
            # SLO latencies: ttft = last chunk appended -> first token
            # sampled; chunk latency = one encode+quantize+append event
            "ttft_ms": round(1e3 * sum(ttfts) / len(ttfts), 2),
            "chunk_latency_p50_ms": round(1e3 * pct(chunks, 0.5), 2),
            "chunk_latency_p90_ms": round(1e3 * pct(chunks, 0.9), 2),
            "mixed_tokens": tokens, "mixed_wall_s": round(dt, 4),
            "mixed_tokens_per_sec": round(tokens / dt, 2)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--full", action="store_true",
                    help="use the full (published) config, not smoke")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: tiny workload, smoke config")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch-slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="write a jax.profiler trace of the timed serving "
                         "runs to DIR (view with tensorboard or xprof)")
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args()
    if args.smoke:
        args.requests, args.max_new = 6, 6

    import dataclasses

    from repro.api import PrecisionSpec, RunSpec, ServingSpec, build
    from repro.core.plan import LayerPlan, PrecisionPlan
    from repro.serving.packed import pack_tree, packed_nbytes

    # the bench measures exactly the declarative config the launcher and
    # the serving example run: one RunSpec per mode, coexisting contexts
    # (one engine's traces never touch another's).  kv_plan carries a
    # nibble-width KV plan (wire/pack stay uniform int8, so weights and
    # every other trace are the exact fp-row programs).
    base = RunSpec(arch=args.arch, full=args.full,
                   serving=ServingSpec(slots=args.batch_slots))
    kv_plan = PrecisionPlan(default=LayerPlan(kv_bits=4))
    modes = [
        ("fp", base),
        ("packed", dataclasses.replace(
            base, precision=PrecisionSpec(packed_serving=True))),
        ("kv_plan", dataclasses.replace(
            base, plan=kv_plan,
            serving=dataclasses.replace(base.serving, kv_cache="plan"))),
    ]
    ctxs = [(m, build(spec)) for m, spec in modes]
    params, qstate = ctxs[0][1].init_state()

    if args.profile:
        jax.profiler.start_trace(args.profile)
    rows = []
    for mode, ctx in ctxs:
        row = bench_engine(ctx, params, qstate, mode=mode,
                           n_requests=args.requests, max_new=args.max_new,
                           max_len=args.max_len)
        rows.append(row)
        print(f"serving.{row['mode']}: decode "
              f"{row['decode_tokens_per_sec']} tok/s, mixed "
              f"{row['mixed_tokens_per_sec']} tok/s "
              f"({row['mixed_tokens']} tokens / {row['mixed_wall_s']}s), "
              f"kv {row['kv_bytes_per_token']} B/tok "
              f"({row['decode_kv_speedup_x']}x)")
    # streaming ASR: serve the shipped golden spec (whisper enc-dec,
    # quantized cross+self KV, mixed lm+asr admission) — its own context
    # and params, coexisting with the LM contexts above
    asr_path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "..", "examples", "specs",
                            "serving_asr_stream.json")
    spec_asr = RunSpec.from_file(asr_path)
    if args.full:
        spec_asr = dataclasses.replace(spec_asr, full=True)
    ctx_asr = build(spec_asr)
    p_asr, q_asr = ctx_asr.init_state()
    row = bench_streaming(ctx_asr, p_asr, q_asr,
                          n_streams=3 if args.smoke else 4,
                          n_lm=3 if args.smoke else args.requests,
                          max_new=args.max_new, max_len=args.max_len)
    rows.append(row)
    print(f"serving.{row['mode']}: ttft {row['ttft_ms']}ms, chunk p50 "
          f"{row['chunk_latency_p50_ms']}ms p90 "
          f"{row['chunk_latency_p90_ms']}ms, mixed "
          f"{row['mixed_tokens_per_sec']} tok/s, cross-kv "
          f"{row['cross_kv_bytes_per_request']} B/req")

    if args.profile:
        jax.profiler.stop_trace()
        print(f"profiler trace written to {args.profile}")

    fp_b, q_b = packed_nbytes(params), packed_nbytes(pack_tree(params))
    result = {
        "bench": "serving", "arch": ctxs[0][1].cfg.name,
        "backend": jax.default_backend(),
        "batch_slots": args.batch_slots, "max_len": args.max_len,
        "weight_bytes_fp": fp_b, "weight_bytes_packed": q_b,
        "hbm_saving_x": round(fp_b / q_b, 2),
        "runs": rows,
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
