"""Benchmark regression gate: compare fresh BENCH_*.json files against the
committed baselines in ``benchmarks/baselines/`` and fail the CI job when
a guarded metric regresses beyond tolerance.

What is guarded (direction-aware — a metric only fails when it moves the
*bad* way):

* ``collectives``: ``bytes_per_element`` AND ``step_ms`` per mode (both
  lower is better), the ``step_ratio_vs_fp32`` wall-clock ratio of each
  compressed wire against the fp32 ring on the same mesh (lower is
  better — THE "compression wins wall-clock" gate), the 2D-mesh
  ``total_bytes_per_element`` / ``step_ms`` / ``step_ratio_vs_fp32``
  per mode, the ``reduction_vs_1d`` ratio of the 2D sliced exchange
  (higher is better), and the mixed-precision section's
  ``bytes_per_element`` (lower) / ``reduction_vs_uniform`` (higher);
* ``serving``: ``decode_tokens_per_sec`` / ``mixed_tokens_per_sec`` per
  mode (higher is better), the ``hbm_saving_x`` packing ratio, the
  structural KV-cache metrics per mode — ``kv_bytes_per_token`` (lower)
  and the cache-bandwidth decode speedup ``decode_kv_speedup_x``
  (higher; THE quantized-KV win gate) — and the streaming-ASR SLO
  metrics of the ``asr_stream`` row: ``ttft_ms`` /
  ``chunk_latency_p50_ms`` / ``chunk_latency_p90_ms`` (all lower is
  better — the bounded-latency gate) plus the structural
  ``cross_kv_bytes_per_request`` (lower).

Timing metrics get built-in default tolerances instead of the global
``--tolerance``: ``*step_ms*`` at ``TIMING_TOLERANCE`` (25%) and
``*step_ratio*`` at ``RATIO_TOLERANCE`` (50%) — ``step_ms`` is now a
warmup-discarded median (see ``benchmarks/common.time_stats``), stable
enough to gate, but shared runners still jitter more than byte counts
(which are exact), and ratios divide two independently-jittering
medians.  Streaming latencies (``*ttft_ms`` / ``*chunk_latency*``) get
``LATENCY_TOLERANCE`` (50%): unlike step_ms they aggregate only a
handful of single events per run, so the tails jitter hard on shared
runners.  A user ``--override`` always beats the built-in default.

Usage (CI runs exactly this after the smoke benches):

    python benchmarks/check_regression.py BENCH_collectives.json \
        BENCH_serving.json

    # wall-time on shared runners is noisy — per-metric tolerance:
    python benchmarks/check_regression.py BENCH_serving.json \
        --override "serving.*tokens_per_sec=0.5" \
        --override "collectives*step_ms=1.0"

Re-baselining (after an intentional change, run the benches and commit):

    python benchmarks/check_regression.py BENCH_collectives.json --update

Exit codes: 0 = pass, 1 = regression, 2 = bad invocation / missing
baseline.  Metrics present in the baseline but missing from the fresh run
(or vice versa) warn by default and fail under ``--strict`` — a renamed
metric should be an explicit re-baseline, not a silent skip.
"""
from __future__ import annotations

import argparse
import fnmatch
import json
import os
import shutil
import sys
from typing import Dict, List, Tuple

BASELINE_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "baselines")

# wall-clock metrics are gated at these looser built-in tolerances unless
# a user --override matches them (overrides always win, see compare()).
# Ratios get extra headroom: on 1-core CI hosts the fp32 denominator is a
# single collective launch whose latency jitters independently of the
# compressed path's, so the quotient is noisier than either step_ms.
TIMING_TOLERANCE = 0.25
RATIO_TOLERANCE = 0.5
# streaming SLO latencies aggregate a handful of single wall-clock
# events (one ttft per stream, one latency per chunk) — far noisier on
# shared runners than the warmup-discarded step_ms medians
LATENCY_TOLERANCE = 0.5
TIMING_DEFAULTS: List[Tuple[str, float]] = [
    ("*step_ms*", TIMING_TOLERANCE),
    ("*step_ratio*", RATIO_TOLERANCE),
    ("*ttft_ms", LATENCY_TOLERANCE),
    ("*chunk_latency*", LATENCY_TOLERANCE),
]

# metric name -> direction ("lower" = regression when it rises,
# "higher" = regression when it drops)
Metrics = Dict[str, Tuple[float, str]]


def extract_metrics(data: dict) -> Metrics:
    """Flatten one BENCH_*.json into guarded ``name -> (value, direction)``
    entries.  Unknown bench kinds contribute nothing (forward-compatible:
    a new bench gates once a spec is added here)."""
    kind = data.get("bench", "")
    out: Metrics = {}
    if kind == "collectives":
        for row in data.get("runs", []):
            out[f"collectives.{row['mode']}.bytes_per_element"] = (
                float(row["bytes_per_element"]), "lower")
            if "step_ms" in row:
                out[f"collectives.{row['mode']}.step_ms"] = (
                    float(row["step_ms"]), "lower")
            if "step_ratio_vs_fp32" in row:
                out[f"collectives.{row['mode']}.step_ratio_vs_fp32"] = (
                    float(row["step_ratio_vs_fp32"]), "lower")
        for sec in data.get("mesh2d", []):
            for row in sec.get("runs", []):
                name = f"collectives[{sec['mesh']}].{row['mode']}"
                out[f"{name}.total_bytes_per_element"] = (
                    float(row["total_bytes_per_element"]), "lower")
                if "step_ms" in row:
                    out[f"{name}.step_ms"] = (float(row["step_ms"]),
                                              "lower")
                if "step_ratio_vs_fp32" in row:
                    out[f"{name}.step_ratio_vs_fp32"] = (
                        float(row["step_ratio_vs_fp32"]), "lower")
                if "reduction_vs_1d" in row:
                    out[f"{name}.reduction_vs_1d"] = (
                        float(row["reduction_vs_1d"]), "higher")
        for row in data.get("mixed_precision", {}).get("runs", []):
            name = f"collectives[mixed].{row['mode']}"
            out[f"{name}.bytes_per_element"] = (
                float(row["bytes_per_element"]), "lower")
            if "step_ratio_vs_fp32" in row:
                out[f"{name}.step_ratio_vs_fp32"] = (
                    float(row["step_ratio_vs_fp32"]), "lower")
            if "reduction_vs_uniform" in row:
                out[f"{name}.reduction_vs_uniform"] = (
                    float(row["reduction_vs_uniform"]), "higher")
    elif kind == "serving":
        for row in data.get("runs", []):
            # the asr_stream row has no decode-only phase, hence no
            # decode_tokens_per_sec — extract whichever keys are present
            for key in ("decode_tokens_per_sec", "mixed_tokens_per_sec"):
                if key in row:
                    out[f"serving.{row['mode']}.{key}"] = (
                        float(row[key]), "higher")
            # structural KV-cache metrics (exact, not timing): stored
            # bytes per decoded token, the cache-bandwidth decode
            # speedup of the quantized ring buffer over the fp one, and
            # the per-request cross-attention memory pin
            for key in ("kv_bytes_per_token", "cross_kv_bytes_per_request"):
                if key in row:
                    out[f"serving.{row['mode']}.{key}"] = (
                        float(row[key]), "lower")
            if "decode_kv_speedup_x" in row:
                out[f"serving.{row['mode']}.decode_kv_speedup_x"] = (
                    float(row["decode_kv_speedup_x"]), "higher")
            # streaming-ASR bounded-latency SLO gate
            for key in ("ttft_ms", "chunk_latency_p50_ms",
                        "chunk_latency_p90_ms"):
                if key in row:
                    out[f"serving.{row['mode']}.{key}"] = (
                        float(row[key]), "lower")
        if "hbm_saving_x" in data:
            out["serving.hbm_saving_x"] = (float(data["hbm_saving_x"]),
                                           "higher")
    return out


def tolerance_for(name: str, default: float,
                  overrides: List[Tuple[str, float]]) -> float:
    """Last matching ``--override pattern=tol`` wins; else the default."""
    tol = default
    for pattern, value in overrides:
        if fnmatch.fnmatch(name, pattern):
            tol = value
    return tol


def compare(baseline: Metrics, fresh: Metrics, default_tol: float,
            overrides: List[Tuple[str, float]], strict: bool
            ) -> Tuple[List[str], List[str]]:
    """Returns ``(failures, warnings)`` comparing fresh against baseline."""
    failures, warnings = [], []
    for name in sorted(set(baseline) | set(fresh)):
        if name not in fresh:
            warnings.append(f"metric {name} in baseline but not in fresh "
                            f"run (re-baseline with --update?)")
            continue
        if name not in baseline:
            warnings.append(f"metric {name} new (not in baseline); "
                            f"commit a baseline with --update to gate it")
            continue
        base, direction = baseline[name]
        value, _ = fresh[name]
        # built-in timing defaults first, so any user override (later in
        # the list) wins under tolerance_for's last-match-wins rule
        tol = tolerance_for(name, default_tol,
                            list(TIMING_DEFAULTS) + list(overrides))
        if base == 0:
            continue
        if direction == "lower":
            bad = value > base * (1.0 + tol)
            arrow = "rose"
        else:
            bad = value < base * (1.0 - tol)
            arrow = "dropped"
        if bad:
            failures.append(
                f"{name} {arrow} beyond tolerance: baseline {base:g} -> "
                f"{value:g} ({(value / base - 1.0) * 100:+.1f}%, "
                f"tol ±{tol * 100:.0f}%)")
    if strict:
        failures += warnings
        warnings = []
    return failures, warnings


def baseline_path(fresh_path: str, baseline_dir: str) -> str:
    return os.path.join(baseline_dir, os.path.basename(fresh_path))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("fresh", nargs="+",
                    help="fresh BENCH_*.json files to check")
    ap.add_argument("--baseline-dir", default=BASELINE_DIR)
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="default relative tolerance (0.10 = 10%%)")
    ap.add_argument("--override", action="append", default=[],
                    metavar="PATTERN=TOL",
                    help="per-metric tolerance override, fnmatch pattern "
                         "(repeatable; last match wins)")
    ap.add_argument("--update", action="store_true",
                    help="copy the fresh files into the baseline dir "
                         "instead of checking (re-baseline)")
    ap.add_argument("--strict", action="store_true",
                    help="treat added/removed metrics as failures")
    args = ap.parse_args(argv)

    overrides: List[Tuple[str, float]] = []
    for item in args.override:
        if "=" not in item:
            print(f"bad --override {item!r}: expected PATTERN=TOL",
                  file=sys.stderr)
            return 2
        pattern, _, tol = item.rpartition("=")
        try:
            overrides.append((pattern, float(tol)))
        except ValueError:
            print(f"bad --override tolerance {tol!r}", file=sys.stderr)
            return 2

    if args.update:
        os.makedirs(args.baseline_dir, exist_ok=True)
        for path in args.fresh:
            dst = baseline_path(path, args.baseline_dir)
            shutil.copyfile(path, dst)
            print(f"baselined {path} -> {dst}")
        return 0

    rc = 0
    for path in args.fresh:
        base_file = baseline_path(path, args.baseline_dir)
        if not os.path.exists(base_file):
            print(f"no baseline for {os.path.basename(path)} "
                  f"(expected {base_file}); run with --update and commit "
                  f"it", file=sys.stderr)
            rc = max(rc, 2)
            continue
        with open(path) as f:
            fresh = extract_metrics(json.load(f))
        with open(base_file) as f:
            baseline = extract_metrics(json.load(f))
        failures, warnings = compare(baseline, fresh, args.tolerance,
                                     overrides, args.strict)
        tag = os.path.basename(path)
        for w in warnings:
            print(f"WARN [{tag}] {w}")
        if failures:
            for fmsg in failures:
                print(f"FAIL [{tag}] {fmsg}", file=sys.stderr)
            rc = max(rc, 1)
        else:
            print(f"OK   [{tag}] {len(fresh)} metrics within tolerance "
                  f"of baseline")
    return rc


if __name__ == "__main__":
    sys.exit(main())
