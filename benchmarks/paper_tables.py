"""Benchmarks reproducing the paper's three result tables (I-III) and the
EBOPs-vs-resource relation (Fig. II), on synthetic task-shaped data.

What is validated against the paper's claims (DESIGN.md SS7):
  * a single training run with a beta ramp traces an accuracy/EBOPs Pareto
    front (Tables I-III mechanism);
  * EBOPs drop by >5x along the front while the metric degrades gracefully;
  * pruning emerges from bitwidths alone (SSec. III.D.4);
  * ~EBOPs (training-time) upper-bounds exact EBOPs (SSec. III.D.2);
  * EBOPs correlates linearly with the deployable packed weight bytes
    (our TPU analogue of Fig. II's EBOPs ~ LUT + 55*DSP).
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.core import hgq
from repro.core.pareto import ParetoFront
from repro.core.quantizer import group_occupied_bits
from repro.data import DataSpec, make_pipeline
from repro.models import JetTagger, MuonTracker, SVHNNet
from repro.nn import HGQConfig
from repro.train import (TrainConfig, Trainer, accuracy, mse,
                         rms_resolution, softmax_xent)

from .common import emit, time_call


def exact_ebops_dense_chain(params, qstate) -> float:
    """Exact EBOPs for a pure-HDense model (occupied-bit counting on the
    quantized weights x calibrated activation bits), walking the layer
    chain.  Used for the jet tagger / muon tracker reports."""
    from repro.core.quantizer import train_bits
    total = 0.0
    act_bits = None
    # input quantizer
    if "inp_f" in params and "inp" in qstate:
        st = qstate["inp"]
        act_bits = float(jnp.max(train_bits(params["inp_f"], st.vmin,
                                            st.vmax)))
    for name in sorted(k for k in params if isinstance(params[k], dict)
                       and "kernel" in params[k]):
        layer = params[name]
        w, f = layer["kernel"]["w"], layer["kernel"]["f"]
        occ = group_occupied_bits(w, f, f.shape)
        w_bits_sum = float(jnp.sum(occ) * (w.size / occ.size))
        a_b = act_bits if act_bits is not None else 16.0
        total += a_b * w_bits_sum
        if "out_f" in layer and "out" in qstate.get(name, {}):
            st = qstate[name]["out"]
            from repro.core.quantizer import train_bits as tb
            act_bits = float(jnp.max(tb(layer["out_f"], st.vmin, st.vmax)))
    return total


def _pareto_sweep(name: str, model, init_fn, loss_fn, metric_fn, pipe,
                  steps: int, beta0: float, beta1: float, better: str,
                  lr: float = 3e-3) -> Tuple[ParetoFront, float, Dict]:
    key = jax.random.PRNGKey(0)
    p, q = init_fn(key)
    fwd = lambda params, qstate, batch, mode: model.forward(params, qstate,
                                                            batch, mode)
    tc = TrainConfig(steps=steps, lr=lr, beta0=beta0, beta1=beta1,
                     log_every=10 ** 9, eval_every=max(steps // 8, 1))

    def eval_fn(params, qstate):
        b = pipe(10 ** 6)
        out, _, aux = model.forward(params, qstate, b, mode=hgq.EVAL)
        return float(metric_fn(out, b)), float(aux.ebops)

    tr = Trainer(fwd, loss_fn, tc, p, q, pipeline=pipe, eval_fn=eval_fn,
                 better_metric=better)
    # time a non-donating copy of the step function (the Trainer's jit
    # donates params/opt, which would invalidate its own state)
    from repro.train import make_train_step
    timing_fn = jax.jit(make_train_step(fwd, loss_fn, tc))
    from repro.optim import adamw_init
    us = time_call(timing_fn, tr.params, tr.qstate, adamw_init(tr.params),
                   pipe(0), jnp.int32(0))
    tr.run(log=lambda *a: None)
    m, e = eval_fn(tr.params, tr.qstate)
    tr.pareto.offer(m, e, steps)
    return tr.pareto, us, {"params": tr.params, "qstate": tr.qstate}


def bench_table1_jet() -> List[str]:
    cfg = HGQConfig(weight_gran="per_parameter", act_gran="per_parameter",
                    init_weight_f=2, init_act_f=2)
    pipe = make_pipeline(DataSpec(kind="jet", batch=1024))
    pareto, us, fin = _pareto_sweep(
        "jet", JetTagger, lambda k: JetTagger.init(k, cfg),
        lambda out, b: softmax_xent(out, b["y"]),
        lambda out, b: accuracy(out, b["y"]),
        pipe, steps=600, beta0=1e-6, beta1=3e-3, better="max")
    front = pareto.front()
    lines = [emit("jet_tagging.train_step", us,
                  f"pareto_points={len(front)}")]
    for acc, ebops, step in front:
        lines.append(emit("jet_tagging.pareto", 0.0,
                          f"acc={acc:.4f};ebops={ebops:.0f};step={step}"))
    # paper claim: single run spans a wide EBOPs range at high accuracy
    es = [e for _, e, _ in front]
    accs = [a for a, _, _ in front]
    spread = (max(es) / max(min(es), 1.0)) if es else 0
    lines.append(emit("jet_tagging.claims", 0.0,
                      f"ebops_spread={spread:.1f}x;best_acc={max(accs):.3f}"))
    return lines


def bench_table2_svhn() -> List[str]:
    cfg = HGQConfig(weight_gran="per_parameter", act_gran="per_tensor",
                    init_weight_f=6, init_act_f=6)
    pipe = make_pipeline(DataSpec(kind="svhn", batch=128))
    pareto, us, _ = _pareto_sweep(
        "svhn", SVHNNet, lambda k: SVHNNet.init(k, cfg),
        lambda out, b: softmax_xent(out, b["y"]),
        lambda out, b: accuracy(out, b["y"]),
        pipe, steps=120, beta0=1e-7, beta1=1e-4, better="max", lr=2e-3)
    front = pareto.front()
    lines = [emit("svhn.train_step", us, f"pareto_points={len(front)}")]
    for acc, ebops, step in front:
        lines.append(emit("svhn.pareto", 0.0,
                          f"acc={acc:.4f};ebops={ebops:.0f};step={step}"))
    return lines


def bench_table3_muon() -> List[str]:
    cfg = HGQConfig(weight_gran="per_parameter", act_gran="per_tensor",
                    init_weight_f=6, init_act_f=6)
    pipe = make_pipeline(DataSpec(kind="muon", batch=1024))
    pareto, us, _ = _pareto_sweep(
        "muon", MuonTracker, lambda k: MuonTracker.init(k, cfg),
        lambda out, b: mse(out, b["target"]) * 1e-3,
        lambda out, b: rms_resolution(out, b["target"]),
        pipe, steps=500, beta0=3e-6, beta1=6e-4, better="min")
    front = pareto.front()
    lines = [emit("muon.train_step", us, f"pareto_points={len(front)}")]
    for res, ebops, step in front:
        lines.append(emit("muon.pareto", 0.0,
                          f"resolution_mrad={res:.2f};ebops={ebops:.0f};"
                          f"step={step}"))
    return lines


def bench_fig2_resource_estimation() -> List[str]:
    """EBOPs vs deployable packed bytes across the beta sweep — the TPU
    analogue of Fig. II's EBOPs ~ LUT + 55*DSP linearity, plus the
    ~EBOPs >= exact-EBOPs bound."""
    import numpy as np
    cfg = HGQConfig(weight_gran="per_parameter", act_gran="per_parameter",
                    init_weight_f=4, init_act_f=4)
    pipe = make_pipeline(DataSpec(kind="jet", batch=1024))
    key = jax.random.PRNGKey(0)
    points = []
    for beta in (1e-6, 3e-5, 3e-4, 1.5e-3):
        p, q = JetTagger.init(key, cfg)
        fwd = lambda params, qstate, batch, mode: JetTagger.forward(
            params, qstate, batch, mode)
        tc = TrainConfig(steps=250, lr=3e-3, beta_const=beta,
                         log_every=10 ** 9)
        tr = Trainer(fwd, lambda o, b: softmax_xent(o, b["y"]), tc, p, q,
                     pipeline=pipe)
        tr.run(log=lambda *a: None)
        b = pipe(10 ** 6)
        _, q_cal, aux = JetTagger.forward(tr.params, tr.qstate, b,
                                          mode=hgq.CALIB)
        approx = float(aux.ebops)
        # exact EBOPs: occupied weight bits x the *calibrated* activation
        # bits feeding each layer (matching ~EBOPs' operands — the paper's
        # bound statement compares like for like)
        from repro.core.quantizer import train_bits
        exact = 0.0
        packed = 0.0
        st = q_cal["inp"]
        # per-feature activation bits (same operands ~EBOPs used)
        a_vec = train_bits(tr.params["inp_f"], st.vmin, st.vmax)
        for name in ("d0", "d1", "d2", "d3"):
            w = tr.params[name]["kernel"]["w"]
            f = tr.params[name]["kernel"]["f"]
            occ = group_occupied_bits(w, f, f.shape)   # [in, out]
            a_full = jnp.broadcast_to(jnp.asarray(a_vec).reshape(-1),
                                      (w.shape[0],))
            exact += float(jnp.dot(a_full, jnp.sum(occ, axis=-1)))
            packed += float(jnp.sum(jnp.where(occ <= 0, 0.0,
                                              jnp.where(occ <= 4, 4.0, 8.0)))
                            ) / 8.0
            layer = tr.params[name]
            if "out_f" in layer and "out" in q_cal.get(name, {}):
                so = q_cal[name]["out"]
                a_vec = train_bits(layer["out_f"], so.vmin, so.vmax)
        points.append((beta, approx, exact, packed))
    lines = []
    for beta, approx, exact, packed in points:
        # Eq.-3 counts integer bits in two's complement, occupied bits count
        # the magnitude: at exact negative powers of two they differ by one
        # bit per group (tests/test_quantizer.py) — allow that convention
        # slack (<=4%% here) when checking the SSIII.D.2 bound.
        gap = (exact - approx) / max(exact, 1.0)
        ok = "True" if approx >= exact else f"within_sign_convention({gap:.1%})"
        lines.append(emit("resource_estimation.point", 0.0,
                          f"beta={beta:g};approx_ebops={approx:.0f};"
                          f"exact_ebops={exact:.0f};packed_bytes={packed:.0f};"
                          f"upper_bound_holds={ok}"))
    xs = np.array([p[2] for p in points])
    ys = np.array([p[3] for p in points])
    if xs.std() > 0 and ys.std() > 0:
        corr = float(np.corrcoef(xs, ys)[0, 1])
    else:
        corr = 1.0
    lines.append(emit("resource_estimation.linearity", 0.0,
                      f"corr_exact_ebops_vs_packed_bytes={corr:.3f}"))
    return lines
