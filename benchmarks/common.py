"""Shared benchmark utilities: timing + CSV emission."""
from __future__ import annotations

import time
from typing import Callable, Dict

import jax


def time_call(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time per call in microseconds (blocks on jax results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def time_stats(fn: Callable, *args, warmup: int = 2,
               reps: int = 10) -> Dict[str, float]:
    """Gate-worthy wall-clock stats per call, in milliseconds.

    ``warmup`` calls are discarded (the first one compiles), then
    ``reps`` timed calls yield ``{"p50_ms", "p90_ms", "mean_ms"}`` —
    medians, not means, so one scheduler hiccup cannot flip a CI
    regression gate.  Blocks on jax results."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e3)
    ts.sort()
    p90 = ts[min(int(0.9 * (len(ts) - 1) + 0.5), len(ts) - 1)]
    return {"p50_ms": ts[len(ts) // 2], "p90_ms": p90,
            "mean_ms": sum(ts) / len(ts)}


def emit(name: str, us_per_call: float, derived: str) -> str:
    line = f"{name},{us_per_call:.1f},{derived}"
    print(line, flush=True)
    return line
