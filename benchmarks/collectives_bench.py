"""Gradient-collective benchmark: bytes on the wire and step time for the
data-parallel mean-reduce, fp32 (ring all-reduce) vs bf16-wire vs
int8-wire (``repro.dist.collectives`` two-phase exchange), plus the 2D
(data x model) sliced exchange on DxM meshes, plus a mixed-precision
section where every packable matmul layer rides the int4 nibble wire
(``core.plan.mixed_low_plan``) against the uniform int8 wire.

Builds the real gradient-shaped tree of an architecture (every parameter
leaf), stacks it per data shard, and runs each reduction jitted on an
``n``-device host mesh.  Bytes are *measured from the traced collectives*
(``collectives.record_wire_bytes`` records every all_to_all / all_gather /
scale-pmax payload the compressed path actually emits, at its true dtype
and padded shape; the fp32/bf16-on-fp32-ring baselines use the ring
all-reduce model on the same leaves).  Wall time on this CPU container
reflects host collectives plus quantize arithmetic — the bytes column is
the interconnect story; on real inter-pod links the bytes ARE the time.

The 2D section compares, on 2x4 and 4x2 meshes of the same 8 devices:

* ``int8-wire`` (1D): the in-collective bytes PLUS the fp32 model-axis
  all_gather a TP train step pays to rematerialize model-sharded
  gradients before the model-replicated shard_map
  (``collectives.tp_replication_bytes`` per leaf — GSPMD inserts it
  implicitly, so the recorder cannot see it);
* ``int8-wire-2d``: in-collective bytes only — its per-leaf in_specs
  consume model-sharded gradients directly (replication cost 0), the
  data exchange runs on the 1/M slice, and the model-axis
  rematerialization moves int8.

    PYTHONPATH=src python benchmarks/collectives_bench.py --smoke
    PYTHONPATH=src python benchmarks/collectives_bench.py \
        --arch qwen2-0.5b --devices 8 --out BENCH_collectives.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--full", action="store_true",
                    help="use the full (published) config, not smoke")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: smoke config, few timing reps")
    ap.add_argument("--devices", type=int, default=8,
                    help="host data-parallel device count (forced via "
                         "XLA_FLAGS before jax init)")
    ap.add_argument("--reps", type=int, default=10)
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="write a jax.profiler trace of the timed "
                         "reductions to DIR (view with tensorboard or "
                         "xprof)")
    ap.add_argument("--out", default="BENCH_collectives.json")
    args = ap.parse_args()
    if args.smoke:
        args.reps = 9               # p50 of 9 — launch-latency noise on
        #                             1-core hosts swamps a 3-rep median

    flag = f"--xla_force_host_platform_device_count={args.devices}"
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") + " "
                               + flag).strip()
    import jax                      # noqa: E402 — after the device flag
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    import common                   # noqa: E402 — benchmarks/ is sys.path[0]

    from repro.api import (CompressionSpec, MeshSpec, RunSpec, build,
                           build_mesh)
    from repro.dist import collectives
    from repro.dist.sharding import ef_residual_sharding, stacked_tree

    # the bench measures the same declarative config surface the
    # launcher trains: one RunSpec per (mesh, compression) cell
    n = args.devices
    spec_1d = RunSpec(arch=args.arch, full=args.full,
                      mesh=MeshSpec.host(n, 1),
                      compression=CompressionSpec(kind="int8-wire"))
    ctx = build(spec_1d)
    cfg = ctx.cfg
    mesh = ctx.mesh
    params, _ = ctx.init_state()

    leaves = jax.tree.leaves(params)
    stacked_flags = jax.tree.leaves(stacked_tree(params))
    elements = int(sum(x.size for x in leaves))
    scale_rows = int(sum(x.shape[0] if (st and x.ndim >= 3) else 1
                         for x, st in zip(leaves, stacked_flags)))
    stacked = jax.tree.map(
        lambda x: jax.random.normal(
            jax.random.PRNGKey(x.size % 9973),
            (n,) + tuple(x.shape), jnp.float32) * 1e-3, params)

    def time_reduce(fn, tree):
        """Gate-worthy timing: warmup discarded, p50/p90/mean over reps."""
        return common.time_stats(fn, tree, warmup=2, reps=args.reps)

    def fp32_pmean_for(mesh_obj):
        # the ring all-reduce baseline: pmean over the data axis only
        def fp32_pmean(tree):
            spec = jax.tree.map(
                lambda leaf: P(("data",), *([None] * (leaf.ndim - 1))),
                tree)
            return shard_map(
                lambda t: jax.tree.map(
                    lambda x: jax.lax.pmean(x[0], ("data",)), t),
                mesh=mesh_obj, in_specs=(spec,),
                out_specs=jax.tree.map(
                    lambda leaf: P(*([None] * (leaf.ndim - 1))), tree),
                check_rep=False)(tree)
        return fp32_pmean

    if args.profile:
        jax.profiler.start_trace(args.profile)

    rows = []
    with mesh:
        placed = jax.device_put(stacked,
                                ef_residual_sharding(stacked, mesh))
        # fp32 baseline: the ring all-reduce the wire path replaces
        st = time_reduce(jax.jit(fp32_pmean_for(mesh)), placed)
        fp32_ms = st["p50_ms"]
        fp32_bytes = sum(collectives.fp32_allreduce_bytes(x.size, n)
                         for x in leaves)
        rows.append({"mode": "fp32", "bytes_on_wire_per_device": fp32_bytes,
                     "bytes_per_element": round(fp32_bytes / elements, 3),
                     "step_ms": round(st["p50_ms"], 2),
                     "p50_ms": round(st["p50_ms"], 2),
                     "p90_ms": round(st["p90_ms"], 2),
                     "reduction_vs_fp32": 1.0})
        for kind in ("bf16", "int8"):
            fn = jax.jit(lambda t, k=kind:
                         collectives.ef_wire_pmean(t, mesh, k))
            with collectives.record_wire_bytes() as rec:
                fn.lower(placed)                    # trace -> record bytes
            st = time_reduce(fn, placed)
            b = rec.total()
            rows.append({
                "mode": f"{kind}-wire",
                "bytes_on_wire_per_device": b,
                "bytes_per_element": round(b / elements, 3),
                "step_ms": round(st["p50_ms"], 2),
                "p50_ms": round(st["p50_ms"], 2),
                "p90_ms": round(st["p90_ms"], 2),
                "step_ratio_vs_fp32": round(st["p50_ms"] / fp32_ms, 3),
                "reduction_vs_fp32": round(fp32_bytes / b, 2)})

        # ---- mixed-precision section: every packable matmul layer on the
        # int4 nibble wire (a learned PrecisionPlan's maximal mixed plan),
        # everything else (biases, norms, activation f) at int8 — vs the
        # uniform int8 wire above
        from repro.core.plan import mixed_low_plan
        plan = mixed_low_plan(params, low_bits=4)
        widths = plan.wire_bits_tree(placed)
        uniform_b = rows[-1]["bytes_on_wire_per_device"]   # int8-wire
        fnm = jax.jit(lambda t: collectives.ef_wire_pmean(
            t, mesh, "int8", widths=widths))
        with collectives.record_wire_bytes() as recm:
            fnm.lower(placed)
        stm = time_reduce(fnm, placed)
        bm = recm.total()
        mixed = {
            "plan_summary": plan.summary(),
            "low_bits": 4,
            "runs": [{
                "mode": "int8-wire-uniform",
                "bytes_on_wire_per_device": uniform_b,
                "bytes_per_element": round(uniform_b / elements, 3)},
                {"mode": "int8-wire-mixed-w4w8",
                 "bytes_on_wire_per_device": bm,
                 "bytes_per_element": round(bm / elements, 3),
                 "step_ms": round(stm["p50_ms"], 2),
                 "p50_ms": round(stm["p50_ms"], 2),
                 "p90_ms": round(stm["p90_ms"], 2),
                 "step_ratio_vs_fp32": round(stm["p50_ms"] / fp32_ms, 3),
                 "reduction_vs_uniform": round(uniform_b / bm, 2)}],
        }

    # ---- 2D (data x model) section: 1D vs 2D on DxM meshes of n devices
    mesh2d = []
    shapes_2d = [(n // m, m) for m in (4, 2)
                 if m < n and n % m == 0 and n // m >= 1]
    for (D, M) in shapes_2d:
        spec_2d = RunSpec(arch=args.arch, full=args.full,
                          mesh=MeshSpec.host(D, M),
                          compression=CompressionSpec(kind="int8-wire-2d"))
        mesh_dm = build_mesh(spec_2d.mesh)
        stacked_dm = jax.tree.map(
            lambda x, D=D: jax.random.normal(
                jax.random.PRNGKey(x.size % 9973),
                (D,) + tuple(x.shape), jnp.float32) * 1e-3, params)
        res2d = collectives.ef_wire2d_init(params, D, M)
        tp_repl = sum(collectives.tp_replication_bytes(x.shape, M)
                      for x in leaves)
        dm_rows = []
        with mesh_dm:
            placed_dm = jax.device_put(
                stacked_dm, ef_residual_sharding(stacked_dm, mesh_dm))
            res_placed = jax.device_put(
                res2d, ef_residual_sharding(res2d, mesh_dm, layout="2d"))
            # fp32 baseline on THIS mesh: D-device ring all-reduce plus
            # the fp32 model-axis replication a TP step pays either way
            st0 = time_reduce(jax.jit(fp32_pmean_for(mesh_dm)), placed_dm)
            fp32_dm_ms = st0["p50_ms"]
            fp32_b_dm = sum(collectives.fp32_allreduce_bytes(x.size, D)
                            for x in leaves)
            dm_rows.append({
                "mode": "fp32",
                "bytes_on_wire_per_device": fp32_b_dm,
                "tp_replication_bytes": tp_repl,
                "total_bytes_per_element": round(
                    (fp32_b_dm + tp_repl) / elements, 3),
                "step_ms": round(st0["p50_ms"], 2),
                "p50_ms": round(st0["p50_ms"], 2),
                "p90_ms": round(st0["p90_ms"], 2)})
            fn1 = jax.jit(lambda t: collectives.ef_wire_pmean(
                t, mesh_dm, "int8"))
            with collectives.record_wire_bytes() as rec1:
                fn1.lower(placed_dm)
            st1 = time_reduce(fn1, placed_dm)
            total1 = rec1.total() + tp_repl
            dm_rows.append({
                "mode": "int8-wire",
                "bytes_on_wire_per_device": rec1.total(),
                "tp_replication_bytes": tp_repl,
                "total_bytes_per_element": round(total1 / elements, 3),
                "step_ms": round(st1["p50_ms"], 2),
                "p50_ms": round(st1["p50_ms"], 2),
                "p90_ms": round(st1["p90_ms"], 2),
                "step_ratio_vs_fp32": round(
                    st1["p50_ms"] / fp32_dm_ms, 3)})
            fn2 = jax.jit(lambda t, r: collectives.ef_wire_pmean_2d(
                t, r, mesh_dm, "int8"))
            with collectives.record_wire_bytes() as rec2:
                fn2.lower(placed_dm, res_placed)
            st2 = time_reduce(lambda _: fn2(placed_dm, res_placed), None)
            total2 = rec2.total()
            dm_rows.append({
                "mode": "int8-wire-2d",
                "bytes_on_wire_per_device": rec2.total(),
                "tp_replication_bytes": 0.0,
                "total_bytes_per_element": round(total2 / elements, 3),
                "step_ms": round(st2["p50_ms"], 2),
                "p50_ms": round(st2["p50_ms"], 2),
                "p90_ms": round(st2["p90_ms"], 2),
                "step_ratio_vs_fp32": round(
                    st2["p50_ms"] / fp32_dm_ms, 3),
                "reduction_vs_1d": round(total1 / total2, 2)})
        mesh2d.append({"mesh": f"{D}x{M}", "spec": spec_2d.to_dict(),
                       "runs": dm_rows})

    if args.profile:
        jax.profiler.stop_trace()
        print(f"profiler trace written to {args.profile}")

    result = {
        "bench": "collectives", "arch": cfg.name,
        "spec": spec_1d.to_dict(),
        "backend": jax.default_backend(), "devices": n,
        "grad_elements": elements, "scale_rows": scale_rows,
        "bytes_model": {
            k: collectives.wire_bytes_model(elements, n, k, scale_rows)
            for k in collectives.WIRE_KINDS},
        "runs": rows,
        "mixed_precision": mixed,
        "mesh2d": mesh2d,
    }
    for r in rows:
        print(f"collectives.{r['mode']}: "
              f"{r['bytes_per_element']} B/elt on the wire, "
              f"{r['step_ms']} ms/reduce "
              f"({r['reduction_vs_fp32']}x vs fp32)")
    for r in mixed["runs"]:
        extra = (f" ({r['reduction_vs_uniform']}x vs uniform int8)"
                 if "reduction_vs_uniform" in r else "")
        print(f"collectives[mixed].{r['mode']}: "
              f"{r['bytes_per_element']} B/elt on the wire{extra}")
    for sec in mesh2d:
        for r in sec["runs"]:
            extra = (f" ({r['reduction_vs_1d']}x vs 1d)"
                     if "reduction_vs_1d" in r else "")
            print(f"collectives[{sec['mesh']}].{r['mode']}: "
                  f"{r['total_bytes_per_element']} B/elt total "
                  f"(incl. {r['tp_replication_bytes']:.0f} B fp32 TP "
                  f"replication), {r['step_ms']} ms/reduce{extra}")
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"wrote {args.out}")
    int8 = next(r for r in rows if r["mode"] == "int8-wire")
    if int8["reduction_vs_fp32"] < 3.0:
        print("FAIL: int8-wire byte reduction below 3x", file=sys.stderr)
        sys.exit(1)
    rmix = next(r for r in mixed["runs"]
                if r["mode"] == "int8-wire-mixed-w4w8")
    if rmix["bytes_per_element"] >= mixed["runs"][0]["bytes_per_element"]:
        print("FAIL: mixed w4/w8 wire B/elt did not drop below the "
              "uniform int8 wire", file=sys.stderr)
        sys.exit(1)
    for sec in mesh2d:
        r2d = next(r for r in sec["runs"] if r["mode"] == "int8-wire-2d")
        if r2d["reduction_vs_1d"] < 1.9:
            print(f"FAIL: int8-wire-2d byte reduction vs 1D below 1.9x "
                  f"on the {sec['mesh']} mesh", file=sys.stderr)
            sys.exit(1)


if __name__ == "__main__":
    main()
