"""Gradient-collective benchmark: bytes on the wire and step time for the
data-parallel mean-reduce, fp32 (ring all-reduce) vs bf16-wire vs
int8-wire (``repro.dist.collectives`` two-phase exchange).

Builds the real gradient-shaped tree of an architecture (every parameter
leaf), stacks it per data shard, and runs each reduction jitted on an
``n``-device host mesh.  Bytes are *measured from the traced collectives*
(``collectives.record_wire_bytes`` records every all_to_all / all_gather /
scale-pmax payload the compressed path actually emits, at its true dtype
and padded shape; the fp32/bf16-on-fp32-ring baselines use the ring
all-reduce model on the same leaves).  Wall time on this CPU container
reflects host collectives plus quantize arithmetic — the bytes column is
the interconnect story; on real inter-pod links the bytes ARE the time.

    PYTHONPATH=src python benchmarks/collectives_bench.py --smoke
    PYTHONPATH=src python benchmarks/collectives_bench.py \
        --arch qwen2-0.5b --devices 8 --out BENCH_collectives.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--full", action="store_true",
                    help="use the full (published) config, not smoke")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: smoke config, few timing reps")
    ap.add_argument("--devices", type=int, default=8,
                    help="host data-parallel device count (forced via "
                         "XLA_FLAGS before jax init)")
    ap.add_argument("--reps", type=int, default=10)
    ap.add_argument("--out", default="BENCH_collectives.json")
    args = ap.parse_args()
    if args.smoke:
        args.reps = 3

    flag = f"--xla_force_host_platform_device_count={args.devices}"
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") + " "
                               + flag).strip()
    import jax                      # noqa: E402 — after the device flag
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.configs import get
    from repro.dist import collectives
    from repro.dist.sharding import ef_residual_sharding
    from repro.models import model_for

    cfg = get(args.arch, smoke=not args.full)
    M = model_for(cfg)
    params, _ = M.init(jax.random.PRNGKey(0), cfg)
    n = args.devices
    mesh = jax.make_mesh((n, 1), ("data", "model"))

    leaves = jax.tree.leaves(params)
    elements = int(sum(x.size for x in leaves))
    scale_rows = int(sum(x.shape[0] if x.ndim >= 3 else 1 for x in leaves))
    stacked = jax.tree.map(
        lambda x: jax.random.normal(
            jax.random.PRNGKey(x.size % 9973),
            (n,) + tuple(x.shape), jnp.float32) * 1e-3, params)

    def time_reduce(fn, tree):
        out = jax.block_until_ready(fn(tree))       # compile + warm
        t0 = time.perf_counter()
        for _ in range(args.reps):
            out = jax.block_until_ready(fn(tree))
        del out
        return (time.perf_counter() - t0) / args.reps * 1e3

    def fp32_pmean(tree):
        spec = jax.tree.map(
            lambda leaf: P(("data",), *([None] * (leaf.ndim - 1))), tree)
        return shard_map(
            lambda t: jax.tree.map(
                lambda x: jax.lax.pmean(x[0], ("data",)), t),
            mesh=mesh, in_specs=(spec,),
            out_specs=jax.tree.map(
                lambda leaf: P(*([None] * (leaf.ndim - 1))), tree),
            check_rep=False)(tree)

    rows = []
    with mesh:
        placed = jax.device_put(stacked,
                                ef_residual_sharding(stacked, mesh))
        # fp32 baseline: the ring all-reduce the wire path replaces
        ms = time_reduce(jax.jit(fp32_pmean), placed)
        fp32_bytes = sum(collectives.fp32_allreduce_bytes(x.size, n)
                         for x in leaves)
        rows.append({"mode": "fp32", "bytes_on_wire_per_device": fp32_bytes,
                     "bytes_per_element": round(fp32_bytes / elements, 3),
                     "step_ms": round(ms, 2), "reduction_vs_fp32": 1.0})
        for kind in ("bf16", "int8"):
            fn = jax.jit(lambda t, k=kind:
                         collectives.ef_wire_pmean(t, mesh, k))
            with collectives.record_wire_bytes() as rec:
                fn.lower(placed)                    # trace -> record bytes
            ms = time_reduce(fn, placed)
            b = rec.total()
            rows.append({
                "mode": f"{kind}-wire",
                "bytes_on_wire_per_device": b,
                "bytes_per_element": round(b / elements, 3),
                "step_ms": round(ms, 2),
                "reduction_vs_fp32": round(fp32_bytes / b, 2)})

    result = {
        "bench": "collectives", "arch": cfg.name,
        "backend": jax.default_backend(), "devices": n,
        "grad_elements": elements, "scale_rows": scale_rows,
        "bytes_model": {
            k: collectives.wire_bytes_model(elements, n, k, scale_rows)
            for k in collectives.WIRE_KINDS},
        "runs": rows,
    }
    for r in rows:
        print(f"collectives.{r['mode']}: "
              f"{r['bytes_per_element']} B/elt on the wire, "
              f"{r['step_ms']} ms/reduce "
              f"({r['reduction_vs_fp32']}x vs fp32)")
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"wrote {args.out}")
    int8 = next(r for r in rows if r["mode"] == "int8-wire")
    if int8["reduction_vs_fp32"] < 3.0:
        print("FAIL: int8-wire byte reduction below 3x", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
