"""Streaming ASR serving: audio-chunk requests in the continuous-batching
Engine, with bounded-latency accounting.

The second request type the Engine admits: audio arrives in chunks (the
conv/mel frontend is a stub, so "audio" is precomputed frame embeddings
``[T, d_model]``), the Whisper encoder runs incrementally per chunk —
block-local self-attention at absolute frame offsets
(``WhisperModel.append_cross``) — and the cross-attention K/V rows are
appended into the request's slot slice under the same quantized-cache
machinery the self-attention ring uses.  When the last chunk lands, the
decoder prompt prefills into that slice, the slice splices into the
batch cache, and the request joins the ordinary ragged decode tick —
ASR and LM slots decode together in ONE jitted step (LM rows carry
``mem_len == 0`` and read exactly zero from the memory buffer).

Request lifecycle (see README "Serving > Streaming ASR"):

    submit_audio -> [slot reserved] -> chunk 0..N appended (one per
    engine tick: the per-chunk *arrival simulation*) -> decoder prompt
    prefill -> splice -> shared ragged decode -> done

Latency accounting, filled per request:

* ``t_chunks`` — wall seconds per appended chunk (encode + quantize +
  append, blocked until ready): the bounded per-event latency HGQ-style
  streaming workloads care about;
* ``ttft_s`` — last chunk appended -> first decoded token sampled
  (decoder prompt prefill + first sample): time-to-first-token.

:func:`generate_asr` is the offline (whole-audio) greedy reference the
streaming path is tested token-for-token against: it encodes with the
SAME chunk decomposition (:func:`split_audio` is the shared semantic
unit), then decodes the prompt in one block — so chunked streaming
through the slot scheduler must reproduce it exactly, on fp and
quantized-KV caches alike.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from .engine import Engine, RequestHandle, SamplingConfig


@dataclasses.dataclass
class AudioRequest:
    """One streaming transcription request.

    ``frames`` is ``[T, d_model]`` (or ``[1, T, d_model]``) precomputed
    frame embeddings; ``chunk`` is the arrival granularity in frames
    (``0`` = the whole audio arrives at once); ``prompt`` is the decoder
    prompt (BOS/task tokens).  ``t_chunks``/``ttft_s`` are filled by the
    engine as the request streams (see module docstring)."""
    frames: Any
    prompt: List[int]
    max_new: int
    chunk: int = 0
    sampling: Optional[SamplingConfig] = None
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    t_chunks: List[float] = dataclasses.field(default_factory=list)
    ttft_s: Optional[float] = None


def split_audio(frames: jax.Array, chunk: int) -> List[jax.Array]:
    """The shared chunk decomposition: full ``chunk``-frame blocks, then
    power-of-two tail blocks (bounds compile count at O(log chunk), the
    same policy as the Engine's pad-free prompt prefill).  Streaming and
    the offline reference both encode exactly these blocks, so their
    block-local encoder outputs are bit-identical."""
    if frames.ndim == 2:
        frames = frames[None]
    T = frames.shape[1]
    C = chunk if chunk > 0 else T
    blocks = []
    start = 0
    while start < T:
        n = C if T - start >= C else 1 << ((T - start).bit_length() - 1)
        blocks.append(frames[:, start:start + n])
        start += n
    return blocks


@dataclasses.dataclass
class _AudioState:
    """Engine-side state of one in-flight audio stream: the held
    single-slot cache slice the chunks append into, and the blocks not
    yet 'arrived'."""
    req: AudioRequest
    cs: Any
    blocks: List[jax.Array]


class StreamingEngine(Engine):
    """Engine extension admitting :class:`AudioRequest` alongside LM
    :class:`~repro.serving.Request` traffic.

    A submitted audio request reserves a slot immediately (so admission
    order is fair against LM traffic) but does NOT join the decode batch
    until its audio is complete: each engine tick 'delivers' one pending
    chunk per streaming slot (arrival simulation) and appends it to the
    slot's held cache slice via the jitted ``append_cross``.  On the
    last chunk the decoder prompt prefills into that slice, the slice
    splices into the batch cache, and the slot decodes in the same
    jitted ragged step as every LM slot."""

    def __init__(self, *args, audio_chunk: int = 0,
                 max_frames: Optional[int] = None, **kwargs):
        super().__init__(*args, **kwargs)
        self.audio_chunk = audio_chunk
        self.max_frames = (self.cfg.enc_seq if not max_frames
                           else min(max_frames, self.cfg.enc_seq))
        self._audio: Dict[int, _AudioState] = {}
        model, cfg, kv_bits = self.model, self.cfg, self.kv_bits

        def append(p, q, cs, fr):
            return model.append_cross(p, q, cs, fr, cfg, kv_bits=kv_bits)

        # like _prefill, the first cs may be the shared _fresh_slot —
        # never donate it
        self._append_cross = jax.jit(append)

    # ------------------------------------------------------------------
    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.slot_req):
            if r is None and i not in self._audio:
                return i
        return None

    def submit(self, req):
        """Admit either request type (``run`` and mixed workloads feed
        through here)."""
        if isinstance(req, AudioRequest):
            return self.submit_audio(req)
        return super().submit(req)

    def submit_audio(self, req: AudioRequest) -> Optional[RequestHandle]:
        """Reserve a slot for one audio stream.  Chunks are appended on
        subsequent ``step()`` ticks (one per tick); the handle's
        ``tokens()`` reader starts yielding once decoding begins.
        Returns None when no slot is free."""
        slot = self._free_slot()
        if slot is None:
            return None
        frames = jnp.asarray(req.frames)
        if frames.ndim == 2:
            frames = frames[None]
        T = frames.shape[1]
        plen = len(req.prompt)
        if T < 1 or T > self.max_frames:
            raise ValueError(f"need 1 <= frames <= {self.max_frames} "
                             f"(got {T})")
        if plen < 1 or req.max_new < 1 or plen + req.max_new > self.max_len:
            raise ValueError(
                f"need prompt >= 1 ({plen}), max_new >= 1 ({req.max_new}), "
                f"and prompt + max_new <= max_len ({self.max_len})")
        blocks = split_audio(frames, req.chunk or self.audio_chunk)
        self._audio[slot] = _AudioState(req=req, cs=self._fresh_slot,
                                        blocks=blocks)
        return RequestHandle(req)

    # ------------------------------------------------------------------
    def _finish_audio(self, slot: int, st: _AudioState) -> None:
        """Audio complete: decoder-prompt chunked prefill into the held
        slice, splice into the batch cache, sample the first token — the
        slot joins the shared ragged decode tick.  TTFT is the wall time
        of exactly this transition."""
        req = st.req
        t0 = time.perf_counter()
        cs, last_logits = self._prefill_prompt(req.prompt, cs=st.cs)
        self.caches = self._write_slot(self.caches, cs, jnp.int32(slot))
        sc = self._sampling(req)
        first = self._run(
            self._sample1, last_logits, self._split_key(),
            jnp.asarray([sc.temperature], jnp.float32),
            jnp.asarray([sc.top_k], jnp.int32), sc.temperature > 0)
        tok = int(first[0])
        req.ttft_s = time.perf_counter() - t0
        del self._audio[slot]
        self.slot_req[slot] = req
        self.slot_pos[slot] = len(req.prompt)
        self._next_tok[slot] = tok
        self._record(slot, tok)

    def step(self) -> None:
        """One engine tick: deliver one pending chunk per streaming slot
        (finishing streams whose audio completed), then the ordinary
        jitted ragged decode step over every active slot."""
        for slot, st in list(self._audio.items()):
            t0 = time.perf_counter()
            st.cs = self._run(self._append_cross, self.p, self.q, st.cs,
                              st.blocks.pop(0))
            jax.block_until_ready(st.cs.mem_len)
            st.req.t_chunks.append(time.perf_counter() - t0)
            if not st.blocks:
                self._finish_audio(slot, st)
        super().step()

    def run(self, requests) -> list:
        """Serve a mixed ASR + LM workload to completion."""
        pending = list(requests)
        while pending or self._audio \
                or any(r is not None for r in self.slot_req):
            while pending and self._free_slot() is not None:
                self.submit(pending.pop(0))
            self.step()
        return requests


# ----------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _asr_decode_fn(model, cfg: ModelConfig, kv_bits: Optional[int]):
    if kv_bits is None:
        return jax.jit(lambda p, q, c, t, pos:
                       model.decode_step(p, q, c, t, pos, cfg))
    return jax.jit(lambda p, q, c, t, pos:
                   model.decode_step(p, q, c, t, pos, cfg,
                                     kv_bits=kv_bits))


@functools.lru_cache(maxsize=None)
def _asr_append_fn(model, cfg: ModelConfig, kv_bits: Optional[int]):
    return jax.jit(lambda p, q, c, fr:
                   model.append_cross(p, q, c, fr, cfg, kv_bits=kv_bits))


def generate_asr(model, params, qstate, cfg: ModelConfig, frames,
                 prompt: List[int], max_new: int, *, chunk: int = 0,
                 cache_len: Optional[int] = None,
                 kv_bits: Optional[int] = None) -> jax.Array:
    """Offline (whole-audio) greedy ASR reference: encode the audio in
    the same block decomposition streaming uses (:func:`split_audio`),
    prefill the decoder prompt in one block, decode greedily.  Returns
    ``[1, max_new]`` token ids — what the streaming path must reproduce
    token-for-token."""
    frames = jnp.asarray(frames)
    if frames.ndim == 2:
        frames = frames[None]
    plen = len(prompt)
    caches = model.init_cache(cfg, 1, cache_len or (plen + max_new),
                              ring_slack=plen, kv_bits=kv_bits)
    append = _asr_append_fn(model, cfg, kv_bits)
    for blk in split_audio(frames, chunk):
        caches = append(params, qstate, caches, blk)
    decode = _asr_decode_fn(model, cfg, kv_bits)
    tok = jnp.asarray([prompt], jnp.int32)
    logits, caches = decode(params, qstate, caches, tok, jnp.int32(0))
    pos = plen
    last = jnp.argmax(logits[:, -1:], axis=-1)
    outs = [last]
    for _ in range(max_new - 1):
        logits, caches = decode(params, qstate, caches, last,
                                jnp.int32(pos))
        last = jnp.argmax(logits[:, -1:], axis=-1)
        outs.append(last)
        pos += 1
    return jnp.concatenate(outs, axis=1)
