"""Plan-width quantized KV cache: construction and width resolution.

Decode is KV-cache-bandwidth-bound (BENCH_serving.json: packed weights
move mixed throughput 10.8x and decode not at all), so the ring buffer
is the hottest serving buffer the precision plan can still shrink.  This
module is the serving-side glue around :class:`nn.attention.QKVCache`:

* :func:`quantized_cache` builds the zeroed container for a model cache
  stack — int8 mantissas on per-row 2^-f grids (nibble-packed two per
  byte at ``kv_bits <= 4``) plus the ring-indexed int8 grid-exponent
  buffers that ride alongside through the Engine's slot scheduler,
  checkpoint-free;
* :func:`resolve_kv_bits` maps a ``ServingSpec.kv_cache`` mode to the
  storage width — ``"fp"`` -> None (the exact legacy cache and HLO),
  ``"int8"`` -> 8, ``"plan"`` -> the narrowest ``kv_bits`` the
  :class:`core.plan.PrecisionPlan` resolves (the scan-stacked layers
  share one homogeneous cache, so the narrowest entry is the one that
  can hold every layer's calibrated range);
* :func:`kv_bytes_per_token` is the byte-width formula the README and
  bench meta report.

Quantize-at-write and the fused dequant-attention read live in
``nn/attention.py`` / ``kernels/kv_dequant``; this module never touches
tensors larger than the empty cache it allocates.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from ..core.plan import NIBBLE_BITS, PrecisionPlan
from ..nn.attention import QKVCache

KV_CACHE_MODES = ("fp", "int8", "plan")


def quantized_cache(shape: Tuple[int, ...], kv_bits: int) -> QKVCache:
    """Zeroed quantized cache for a ``[..., W, KV, hd]`` stack (any
    leading layer/batch dims).  Mantissas store ``hd`` int8 bytes per
    row, or ``hd // 2`` nibble-packed at ``kv_bits <= NIBBLE_BITS``;
    exponents drop the head dim.  Zero mantissas under zero exponents
    decode to 0.0, and never-written slots are masked by ``tpos`` anyway,
    so the empty cache is exact."""
    hd = shape[-1]
    if kv_bits <= NIBBLE_BITS:
        if hd % 2:
            raise ValueError(f"nibble-packed kv cache needs even head dim, "
                             f"got {hd}")
        hd = hd // 2
    m_shape = shape[:-1] + (hd,)
    return QKVCache(k=jnp.zeros(m_shape, jnp.int8),
                    v=jnp.zeros(m_shape, jnp.int8),
                    kf=jnp.zeros(shape[:-1], jnp.int8),
                    vf=jnp.zeros(shape[:-1], jnp.int8))


def resolve_kv_bits(kv_cache: str,
                    plan: Optional[PrecisionPlan]) -> Optional[int]:
    """``ServingSpec.kv_cache`` mode -> mantissa storage width (None =
    keep the legacy fp cache)."""
    if kv_cache not in KV_CACHE_MODES:
        raise ValueError(f"kv_cache must be one of {KV_CACHE_MODES}, "
                         f"got {kv_cache!r}")
    if kv_cache == "fp":
        return None
    if kv_cache == "int8" or plan is None:
        return 8
    entries = [plan.default, *plan.layers.values()]
    return min(e.kv_bits for e in entries)


def _kv_row_bytes(n_kv: int, hd: int, kv_bits: Optional[int]) -> int:
    """Stored bytes of one K+V cache row (one token or one memory frame,
    one layer): ``2 * KV * (hd / pack + 1)`` quantized (mantissas plus
    one grid-exponent byte), ``2 * KV * hd * 2`` fp (bf16)."""
    if kv_bits is None:
        return 2 * n_kv * hd * 2
    return 2 * n_kv * ((hd // 2 if kv_bits <= NIBBLE_BITS else hd) + 1)


def kv_bytes_per_token(n_kv: int, hd: int, n_layers: int,
                       kv_bits: Optional[int]) -> int:
    """Stored **self-attention ring** bytes per decoded token across a
    model's attention layers — the per-token marginal cache cost.

    Encoder-decoder models additionally hold a cross-attention memory
    cache, but that one is written once per request and never grows with
    decoded tokens: it is a *per-request static* cost, accounted
    separately by :func:`kv_cross_bytes_per_request` (folding it in here
    would overstate the per-token bandwidth an ASR decode actually
    moves... and understate the admission footprint)."""
    return _kv_row_bytes(n_kv, hd, kv_bits) * n_layers


def kv_cross_bytes_per_request(n_kv: int, hd: int, n_layers: int,
                               frames: int,
                               kv_bits: Optional[int]) -> int:
    """Stored **cross-attention memory** bytes one encoder-decoder
    request pins for its lifetime: ``frames`` K+V rows per decoder
    layer, written once as the audio streams in (quantized on the same
    2^-f grids as the self ring when ``kv_bits`` is set).  Static per
    request — decoded tokens read it every tick but never grow it."""
    return _kv_row_bytes(n_kv, hd, kv_bits) * n_layers * frames
