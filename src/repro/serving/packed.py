"""HGQ quantized-packed serving weights: the decode-time weight format.

Converts a trained ``(params, qstate)`` tree into the serving tree — every
matmul kernel ``{'w', 'f'}`` becomes ``{'w_int8', 'scale', 'f'}`` (or
``{'w_nib', ...}``, two int4 mantissas per byte, for sub-5-bit
PrecisionPlan layers) with a per-output-channel ``2^-f`` scale — via
:func:`repro.kernels.qmatmul.pack_weights`, i.e. exactly the representation
the fused dequant-matmul Pallas kernel consumes.  Under
:func:`repro.dist.perf.packed_matmul` (the ``Engine(packed=True)`` flag)
the dense decode projections and the tied lm head run on
``kernels.qmatmul.qmatmul_any`` so the bytes streamed from HBM per decoded
token are the packed ones — the memory-roofline win the HGQ bitwidths buy
at serving time (DESIGN.md SS2).

Per-layer widths come from a ``core.plan.PrecisionPlan`` (``plan=None`` is
uniform int8, byte-identical to the pre-plan format).  The per-channel
fractional bits are capped so the largest weight in the channel still fits
the layer-width mantissa (saturating the big weights corrupts the matmul
far worse than flooring the small ones); with HGQ disabled (``f`` absent)
the cap itself is the scale — a power-of-two amax fit.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax

from ..dist.perf import (
    pack_params_for_serving,
    packed_matmul,
    packed_mantissas,
    unpack_weight,
)
from ..kernels.qmatmul.ops import channel_bits, pack_linear

__all__ = [
    "channel_bits",
    "pack_for_serving",
    "pack_linear",
    "pack_params_for_serving",
    "pack_tree",
    "packed_mantissas",
    "packed_matmul",
    "packed_nbytes",
    "unpack_weight",
]


def pack_tree(params: Any, plan=None) -> Any:
    """Rewrite every packable matmul weight in a params tree to the
    quantized + per-channel-scale serving form at its ``plan`` pack width
    (uniform int8 when ``plan`` is ``None``); structure-preserving
    elsewhere.  One shared walker + leaf packer
    (``dist.perf.pack_params_for_serving`` over
    ``kernels.qmatmul.pack_linear``) serves both this module and the
    dry-run's abstract packing."""
    return pack_params_for_serving(params, plan)


def pack_for_serving(params: Any, qstate: Any,
                     plan=None) -> Tuple[Any, Any]:
    """Trained ``(params, qstate)`` -> the serving tree.  qstate (activation
    ranges) passes through unchanged: inference activation quantizers only
    read the trained ``f`` leaves, which packing preserves."""
    return pack_tree(params, plan), qstate


def packed_nbytes(params: Any) -> int:
    """Total bytes of the weight leaves as stored (int8 mantissas count 1,
    nibble-packed pairs half that, fp scales 4, everything else its own
    itemsize)."""
    leaves = jax.tree_util.tree_leaves(params)
    return sum(x.size * x.dtype.itemsize for x in leaves
               if hasattr(x, "dtype"))
