"""Batched serving engine: prefill + KV-cache greedy/temperature decode.

The decode step is a single jitted function (the same one the dry-run lowers
for the ``decode_*`` / ``long_*`` cells); the engine adds continuous
batching at the host level: requests join at slot granularity, finished
slots are recycled.  Weights can be served from the HGQ-packed int
representation via ``repro.kernels.qmatmul`` (see serving/packed.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from ..core import hgq
from ..models.config import ModelConfig


@dataclasses.dataclass
class Request:
    prompt: List[int]
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, model, params, qstate, cfg: ModelConfig, *,
                 batch_slots: int = 8, max_len: int = 512,
                 eos_id: Optional[int] = None):
        self.model = model
        self.p = params
        self.q = qstate
        self.cfg = cfg
        self.slots = batch_slots
        self.max_len = max_len
        self.eos = eos_id
        self.caches = model.init_cache(cfg, batch_slots, max_len)
        self._decode = jax.jit(
            lambda p, q, c, t, pos: model.decode_step(p, q, c, t, pos, cfg))
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.slot_pos = [0] * batch_slots

    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.slot_req):
            if r is None:
                return i
        return None

    def submit(self, req: Request) -> bool:
        slot = self._free_slot()
        if slot is None:
            return False
        self.slot_req[slot] = req
        self.slot_pos[slot] = 0
        # prefill token-by-token through the decode path (slot-local; a
        # production deployment uses the chunked-prefill forward instead)
        return True

    def step(self) -> None:
        """One engine tick: advance every active slot by one token."""
        tokens = []
        for i, r in enumerate(self.slot_req):
            if r is None:
                tokens.append(0)
            elif self.slot_pos[i] < len(r.prompt):
                tokens.append(r.prompt[self.slot_pos[i]])
            else:
                tokens.append(r.out[-1] if r.out else r.prompt[-1])
        tok = jnp.asarray(tokens, jnp.int32)[:, None]
        # all slots share cache_pos per slot — engine uses the max; slots are
        # aligned because recycling resets to 0 only when all drain (simple
        # variant; production uses per-slot position tensors)
        pos = jnp.int32(max(self.slot_pos))
        logits, self.caches = self._decode(self.p, self.q, self.caches, tok,
                                           pos)
        nxt = jnp.argmax(logits[:, -1], axis=-1)
        for i, r in enumerate(self.slot_req):
            if r is None:
                continue
            self.slot_pos[i] += 1
            if self.slot_pos[i] >= len(r.prompt):
                t = int(nxt[i])
                r.out.append(t)
                if (self.eos is not None and t == self.eos) or \
                        len(r.out) >= r.max_new:
                    r.done = True
                    self.slot_req[i] = None

    def run(self, requests: List[Request]) -> List[Request]:
        pending = list(requests)
        active = True
        while pending or any(r is not None for r in self.slot_req):
            while pending and self._free_slot() is not None:
                self.submit(pending.pop(0))
            self.step()
        return requests


def generate(model, params, qstate, cfg: ModelConfig, prompt: jax.Array,
             max_new: int) -> jax.Array:
    """Single-batch greedy generation (examples / tests)."""
    B, S = prompt.shape
    caches = model.init_cache(cfg, B, S + max_new)
    decode = jax.jit(lambda p, q, c, t, pos:
                     model.decode_step(p, q, c, t, pos, cfg))
    toks = prompt
    pos = 0
    # prefill through decode path, chunk of the whole prompt at once
    logits, caches = decode(params, qstate, caches, prompt, jnp.int32(0))
    pos = S
    last = jnp.argmax(logits[:, -1:], axis=-1)
    outs = [last]
    for _ in range(max_new - 1):
        logits, caches = decode(params, qstate, caches, last, jnp.int32(pos))
        last = jnp.argmax(logits[:, -1:], axis=-1)
        outs.append(last)
        pos += 1
    return jnp.concatenate(outs, axis=1)
