"""Continuous-batching serving engine: ragged per-slot decode over one
jitted step, chunked prefill, HGQ int8-packed decode weights.

Architecture (one ``Engine`` = one model replica):

* **Slots.** The KV/state cache holds ``batch_slots`` independent rows.  A
  request occupies one slot from admission to completion; finished slots
  are recycled immediately (continuous batching — requests join and leave
  mid-run, no barrier).
* **Per-slot positions.** Every decode tick runs ONE jitted
  ``model.decode_step`` over the whole batch with a position *vector*
  ``cache_pos [B]`` — RoPE phases, ring-buffer writes, and causal/window
  masks are all per-batch-row (``nn.attention``), so slots with different
  prompt lengths decode correctly together.
* **Chunked prefill.** ``submit`` runs the prompt through the same stack
  forward in fixed-size chunks against a fresh single-slot cache slice,
  then splices the slice into the batch cache at the slot's offset —
  no token-by-token prefill, and one compile per chunk shape.
* **Sampling.** Greedy / temperature / top-k, per request, fused into the
  jitted step (Gumbel-max over rank-filtered logits).
* **Packed weights.** ``packed=True`` converts params to the HGQ int8 +
  per-channel 2^-f serving tree (``serving/packed.py``) and routes decode
  projections onto the fused dequant-matmul ``kernels.qmatmul.qmatmul_any``.
* **Quantized KV.** ``kv_bits=b`` stores the ring buffer as int8
  mantissas on per-row 2^-f grids (nibble-packed at b <= 4) with the
  grid exponents riding alongside through the slot scheduler; decode
  reads through the fused dequant-attention kernel
  (``kernels.kv_dequant``).  ``None`` keeps the legacy fp cache,
  byte-identical HLO.
* **Handles.** ``submit(req)`` returns a :class:`RequestHandle`;
  ``tokens(handle)`` reads its output incrementally while ticking the
  engine; ``run(requests)`` is the thin serve-to-completion wrapper.

``generate`` remains the single-batch greedy reference the engine is
tested token-for-token against.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..dist.axes import axis_scope, get_axes
from ..dist.perf import (compute_dtype_scope, get_compute_dtype,
                         packed_matmul)
from ..models.config import ModelConfig
from ..nn.attention import NEG_INF

# cache donation below is a TPU/GPU aliasing win; CPU ignores it (noisily)
warnings.filterwarnings("ignore",
                        message="Some donated buffers were not usable")


@dataclasses.dataclass
class SamplingConfig:
    temperature: float = 0.0      # <= 0: greedy
    top_k: int = 0                # 0: no top-k filter


GREEDY = SamplingConfig()


@dataclasses.dataclass
class Request:
    prompt: List[int]
    max_new: int
    sampling: Optional[SamplingConfig] = None
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class RequestHandle:
    """Admission receipt for one submitted request: what ``submit``
    returns and ``Engine.tokens`` reads from.  Truthy (so legacy
    ``if eng.submit(req):`` call sites keep working — a full engine
    returns ``None``); carries the request plus the incremental-read
    cursor."""
    request: Request
    _cursor: int = 0

    @property
    def done(self) -> bool:
        return self.request.done

    @property
    def out(self) -> List[int]:
        return self.request.out


def _sample(logits: jax.Array, key: jax.Array, temp: jax.Array,
            topk: jax.Array, enable: bool = True) -> jax.Array:
    """Per-row sampling: logits [B, V]; temp [B] (<=0 greedy); topk [B]
    (0 = off).  ``enable`` is static: an all-greedy tick compiles to a bare
    argmax — no vocab sort, no gumbel draw on the decode hot path."""
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if not enable:
        return greedy
    V = logits.shape[-1]
    # top-k via the per-row k-th value threshold (one sort; threshold ties
    # all pass, the standard top-k-filter convention)
    k = jnp.clip(jnp.where(topk > 0, topk, V), 1, V)
    srt = jnp.sort(logits, axis=-1)[:, ::-1]
    thresh = jnp.take_along_axis(srt, (k - 1)[:, None], axis=-1)
    filt = jnp.where(logits >= thresh, logits, NEG_INF)
    t = jnp.maximum(temp, 1e-6)[:, None]
    g = jax.random.gumbel(key, logits.shape)
    sampled = jnp.argmax(filt / t + g, axis=-1).astype(jnp.int32)
    return jnp.where(temp > 0, sampled, greedy)


class Engine:
    """Continuous-batching engine over a model's KV-cache decode path."""

    def __init__(self, model, params, qstate, cfg: ModelConfig, *,
                 batch_slots: int = 8, max_len: int = 512,
                 eos_id: Optional[int] = None, packed: bool = False,
                 plan=None, prefill_chunk: int = 16, seed: int = 0,
                 kv_bits: Optional[int] = None,
                 ring_slack: Optional[int] = None,
                 prefix_reuse: bool = False):
        self.model = model
        self.cfg = cfg
        self.packed = packed
        self.plan = plan       # PrecisionPlan: per-layer pack widths
        # kv_bits: plan-width quantized KV ring storage (serving/kvcache);
        # None keeps the exact legacy fp cache and its byte-identical HLO
        self.kv_bits = kv_bits
        # snapshot the trace-time configuration in scope at construction
        # (a RunContext's activate(), or the process defaults): every
        # trace this engine owns re-binds exactly this snapshot, so
        # engines built under different contexts — two precisions, two
        # meshes, one process — never read each other's flags
        self._axes = get_axes()
        self._compute_dtype = get_compute_dtype()
        if packed:
            from .packed import pack_for_serving
            params, qstate = pack_for_serving(params, qstate, plan)
        self.p = params
        self.q = qstate
        self.slots = batch_slots
        self.max_len = max_len
        self.eos = eos_id
        W = min(max_len, cfg.window) if cfg.window else max_len
        self.prefill_chunk = max(1, min(prefill_chunk, W))
        # ring_slack: a windowed ring buffer gets prefill_chunk extra slots
        # so writing a whole chunk never evicts history still inside the
        # chunk's oldest query window — chunked prefill stays exact.  An
        # explicit ring_slack only widens that floor (shrinking below the
        # chunk would break prefill exactness).
        self.ring_slack = (self.prefill_chunk if not ring_slack
                           else max(ring_slack, self.prefill_chunk))
        self.caches = model.init_cache(cfg, batch_slots, max_len,
                                       ring_slack=self.ring_slack,
                                       kv_bits=kv_bits)
        self.prefix_reuse = prefix_reuse
        # prompt tuple -> (prefilled slot slice, last-position logits);
        # bounded LRU so long-lived engines don't hoard cache slices
        self._prefix_cache: "dict" = {}
        self._prefix_cap = 32
        # a zeroed single-slot cache slice: prefill always starts from a
        # clean slot (also resets recurrent state left by the previous
        # occupant — KV junk is masked by positions, recurrent state isn't)
        self._fresh_slot = jax.tree_util.tree_map(
            lambda a: jnp.zeros(a.shape[:1] + (1,) + a.shape[2:], a.dtype),
            self.caches)
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.slot_pos = np.zeros(batch_slots, np.int32)   # cache fill level
        self._next_tok = np.zeros(batch_slots, np.int32)  # next decode input
        self._key = jax.random.PRNGKey(seed)
        self._build()

    # ------------------------------------------------------------------
    def _build(self) -> None:
        model, cfg, kv_bits = self.model, self.cfg, self.kv_bits

        def decode(p, q, c, tok, pos, key, temp, topk, enable):
            if kv_bits is None:
                logits, c = model.decode_step(p, q, c, tok, pos, cfg)
            else:
                logits, c = model.decode_step(p, q, c, tok, pos, cfg,
                                              kv_bits=kv_bits)
            return _sample(logits[:, -1], key, temp, topk, enable), c

        def prefill(p, q, cs, tok, pos):
            if kv_bits is None:
                return model.decode_step(p, q, cs, tok, pos, cfg)
            return model.decode_step(p, q, cs, tok, pos, cfg,
                                     kv_bits=kv_bits)

        # donate the cache through the per-token tick and the slot splice so
        # XLA aliases it in place instead of copying the full KV/state tree
        # every decoded token (self.caches is reassigned from the result).
        # _prefill must NOT donate: its first cs is the reused _fresh_slot.
        self._decode = jax.jit(decode, static_argnums=(8,),
                               donate_argnums=(2,))
        self._prefill = jax.jit(prefill)
        self._write_slot = jax.jit(
            lambda c, cs, s: jax.tree_util.tree_map(
                lambda a, u: jax.lax.dynamic_update_slice_in_dim(
                    a, u, s, axis=1), c, cs),
            donate_argnums=(0,))
        self._sample1 = jax.jit(_sample, static_argnums=(4,))

    def _run(self, fn, *args):
        """Call a jitted function under this engine's trace-time snapshot
        (axis registry + compute dtype captured at construction) plus its
        packed-matmul routing (all read at trace time; jit caches per
        engine tree)."""
        with axis_scope(self._axes), \
                compute_dtype_scope(self._compute_dtype), \
                packed_matmul(self.packed):
            return fn(*args)

    def decode_program(self):
        """(jaxpr, compiled HLO text) of the engine's decode tick, traced
        on representative full-batch args — the program ``step()`` runs.
        This is what ``repro.analysis`` lints: packed-weight dtypes,
        cache donation and upcasts are judged on this artifact."""
        B = self.slots
        tok = jnp.zeros((B, 1), jnp.int32)
        pos = jnp.zeros((B,), jnp.int32)
        temp = jnp.zeros((B,), jnp.float32)
        topk = jnp.zeros((B,), jnp.int32)
        traced = self._run(self._decode.trace, self.p, self.q, self.caches,
                           tok, pos, self._key, temp, topk, True)
        return traced.jaxpr, traced.lower().compile().as_text()

    # ------------------------------------------------------------------
    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.slot_req):
            if r is None:
                return i
        return None

    def _sampling(self, req: Request) -> SamplingConfig:
        return req.sampling or GREEDY

    def _split_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def _prefill_prompt(self, prompt: List[int], cs=None):
        """Chunked prefill of one prompt into a single-slot slice at
        offset 0: (slice, last-position logits).  ``cs`` starts from a
        caller-held slice instead of the fresh one (streaming ASR: the
        decoder prompt prefills into the slice whose encoder memory was
        already streamed in)."""
        plen = len(prompt)
        C = self.prefill_chunk
        if cs is None:
            cs = self._fresh_slot
        last_logits = None
        start = 0
        # pad-free chunking: full chunks, then power-of-two tail chunks.
        # Padding would be masked fine by the per-position attention masks,
        # but it would advance recurrent (RG-LRU/RWKV) state — so chunks are
        # exact and compile count stays O(log C), not O(prompt lengths).
        while start < plen:
            n = C if plen - start >= C else \
                1 << ((plen - start).bit_length() - 1)
            tok = jnp.asarray([prompt[start:start + n]], jnp.int32)
            logits, cs = self._run(self._prefill, self.p, self.q, cs, tok,
                                   jnp.int32(start))
            start += n
            if start >= plen:
                last_logits = logits[:, -1]
        return cs, last_logits

    def submit(self, req: Request) -> Optional[RequestHandle]:
        """Admit one request: chunked prefill into a fresh slot slice at
        offset 0, splice it into the batch cache, sample the first token.
        Returns a truthy :class:`RequestHandle`, or None when no slot is
        free."""
        slot = self._free_slot()
        if slot is None:
            return None
        plen = len(req.prompt)
        if plen < 1 or req.max_new < 1 or \
                plen + req.max_new > self.max_len:
            raise ValueError(
                f"need prompt >= 1 ({plen}), max_new >= 1 ({req.max_new}), "
                f"and prompt + max_new <= max_len ({self.max_len})")
        key = tuple(req.prompt) if self.prefix_reuse else None
        if key is not None and key in self._prefix_cache:
            # exact-prompt reuse: the cached slice is an immutable jax
            # value (prefill never donates), so splicing it again is safe
            cs, last_logits = self._prefix_cache.pop(key)
            self._prefix_cache[key] = (cs, last_logits)   # LRU refresh
        else:
            cs, last_logits = self._prefill_prompt(req.prompt)
            if key is not None:
                self._prefix_cache[key] = (cs, last_logits)
                while len(self._prefix_cache) > self._prefix_cap:
                    self._prefix_cache.pop(next(iter(self._prefix_cache)))
        self.caches = self._write_slot(self.caches, cs, jnp.int32(slot))
        sc = self._sampling(req)
        first = self._run(
            self._sample1, last_logits, self._split_key(),
            jnp.asarray([sc.temperature], jnp.float32),
            jnp.asarray([sc.top_k], jnp.int32), sc.temperature > 0)
        self.slot_req[slot] = req
        self.slot_pos[slot] = plen
        self._next_tok[slot] = int(first[0])
        self._record(slot, int(first[0]))
        return RequestHandle(req)

    def _record(self, slot: int, token: int) -> None:
        """Append a sampled token; finish + recycle the slot on EOS/len."""
        req = self.slot_req[slot]
        req.out.append(token)
        if (self.eos is not None and token == self.eos) or \
                len(req.out) >= req.max_new:
            req.done = True
            self.slot_req[slot] = None

    def step(self) -> None:
        """One engine tick: a single jitted ragged decode step advancing
        every active slot by one token (inactive slots ride along masked
        by their own positions)."""
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return
        tok = jnp.asarray(self._next_tok, jnp.int32)[:, None]
        pos = jnp.asarray(self.slot_pos, jnp.int32)
        temp = jnp.asarray(
            [self._sampling(r).temperature if r else 0.0
             for r in self.slot_req], jnp.float32)
        topk = jnp.asarray(
            [self._sampling(r).top_k if r else 0 for r in self.slot_req],
            jnp.int32)
        enable = any(self._sampling(self.slot_req[i]).temperature > 0
                     for i in active)
        nxt, self.caches = self._run(self._decode, self.p, self.q,
                                     self.caches, tok, pos,
                                     self._split_key(), temp, topk, enable)
        nxt = np.asarray(nxt)
        for i in active:
            self.slot_pos[i] += 1
            self._next_tok[i] = nxt[i]
            self._record(i, int(nxt[i]))

    def tokens(self, handle: RequestHandle):
        """Incremental token reader for one admitted request: yields each
        sampled token as it lands, ticking the engine (``step()``) when
        the request has produced nothing new yet.  Other active slots
        advance on the same ticks — interleaving readers IS continuous
        batching."""
        req = handle.request
        while True:
            while handle._cursor < len(req.out):
                tok = req.out[handle._cursor]
                handle._cursor += 1
                yield tok
            if req.done:
                return
            self.step()

    def run(self, requests: List[Request]) -> List[Request]:
        """Serve a workload to completion with continuous batching: the
        thin batch wrapper over ``submit``/``step``."""
        pending = list(requests)
        while pending or any(r is not None for r in self.slot_req):
            while pending and self._free_slot() is not None:
                self.submit(pending.pop(0))
            self.step()
        return requests


@functools.lru_cache(maxsize=None)
def _generate_decode_fn(model, cfg: ModelConfig):
    """One jitted decode_step per (model, cfg): repeated generate() calls
    (the per-request test reference) reuse the compiled [B, 1] decode
    instead of re-tracing a fresh lambda each call."""
    return jax.jit(lambda p, q, c, t, pos:
                   model.decode_step(p, q, c, t, pos, cfg))


def generate(model, params, qstate, cfg: ModelConfig, prompt: jax.Array,
             max_new: int, *, cache_len: Optional[int] = None,
             packed: bool = False, plan=None) -> jax.Array:
    """Single-batch greedy generation — the per-request reference the
    engine is tested against.  ``cache_len`` pins the cache width (so
    engine/reference runs share identical masked-attention shapes);
    ``packed=True`` serves from the quantized-packed tree like the
    engine (``plan`` selects per-layer pack widths, ``None`` = int8)."""
    B, S = prompt.shape
    if packed:
        from .packed import pack_for_serving
        params, qstate = pack_for_serving(params, qstate, plan)
    if cache_len is not None and cfg.window is None \
            and cache_len < S + max_new:
        # a windowed ring wraps; a full cache does not — writes past
        # cache_len would be silently dropped and outputs quietly wrong
        raise ValueError(f"cache_len ({cache_len}) < prompt + max_new "
                         f"({S + max_new}) on an unwindowed model")
    # ring_slack=S: the whole-prompt prefill writes S tokens in one chunk
    caches = model.init_cache(cfg, B, cache_len or (S + max_new),
                              ring_slack=S)
    decode = _generate_decode_fn(model, cfg)

    def call(*args):
        with packed_matmul(packed):
            return decode(*args)

    logits, caches = call(params, qstate, caches, prompt, jnp.int32(0))
    pos = S
    last = jnp.argmax(logits[:, -1:], axis=-1)
    outs = [last]
    for _ in range(max_new - 1):
        logits, caches = call(params, qstate, caches, last, jnp.int32(pos))
        last = jnp.argmax(logits[:, -1:], axis=-1)
        outs.append(last)
        pos += 1
    return jnp.concatenate(outs, axis=1)
