from .engine import (Engine, Request, RequestHandle, SamplingConfig,
                     generate)
from .kvcache import (KV_CACHE_MODES, kv_bytes_per_token,
                      kv_cross_bytes_per_request, quantized_cache,
                      resolve_kv_bits)
from .packed import pack_for_serving, pack_tree
from .streaming import (AudioRequest, StreamingEngine, generate_asr,
                        split_audio)
