from .engine import Engine, Request, SamplingConfig, generate
from .packed import pack_for_serving, pack_tree
