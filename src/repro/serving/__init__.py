from .engine import Engine, Request, generate
