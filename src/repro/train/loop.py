"""Training loop: Eq.-16 loss, joint weight+bitwidth optimization, Pareto
checkpointing, fault-tolerant resume.

``make_train_step`` builds the pure step function (pjit-able — the launcher
wraps it with shardings); :class:`Trainer` is the host-side driver with
checkpoint/restart and the paper's beta-ramp Pareto sweep.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core import hgq
from ..core.pareto import ParetoFront
from ..core.schedule import Schedule, constant, log_ramp
from ..optim import AdamWState, adamw_init, adamw_update, clip_by_global_norm
from . import checkpoint as ckpt_lib

Forward = Callable[..., Tuple[jax.Array, Any, Any]]
LossFn = Callable[[jax.Array, Dict[str, jax.Array]], jax.Array]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 1000
    lr: float = 1e-3
    weight_decay: float = 0.0
    clip_norm: float = 1.0
    beta0: float = 1e-6          # Eq. 16 resource coefficient (ramped)
    beta1: float = 1e-4
    gamma: float = 2e-6          # Eq. 16 L1 coefficient (paper: fixed 2e-6)
    beta_const: Optional[float] = None  # HGQ-c* variant: fixed beta
    log_every: int = 50
    eval_every: int = 100
    ckpt_every: int = 200
    ckpt_dir: str = ""
    keep_ckpts: int = 3


def make_train_step(forward: Forward, loss_fn: LossFn, tcfg: TrainConfig,
                    lr_sched: Optional[Schedule] = None,
                    grad_tx: Optional[Callable] = None):
    """Build the pure train step.

    With ``grad_tx`` (e.g. ``dist.ef_compress`` partial application: a
    ``(grads, state) -> (grads, state)`` transform applied after clipping),
    the step takes and returns one extra ``tx_state`` argument so the
    error-feedback residual threads through pjit.
    """
    beta_sched = (constant(tcfg.beta_const) if tcfg.beta_const is not None
                  else log_ramp(tcfg.beta0, tcfg.beta1, tcfg.steps))
    lr_sched = lr_sched or constant(tcfg.lr)

    def _step(params, qstate, opt: AdamWState, batch, step, tx_state):
        beta = beta_sched(step)
        lr = lr_sched(step)

        def loss(params_):
            out, newq, aux = forward(params_, qstate, batch, mode=hgq.TRAIN)
            base = loss_fn(out, batch)
            total = base + beta * aux.ebops + tcfg.gamma * aux.l1
            return total, (newq, aux.ebops, base)

        (total, (newq, ebops, base)), grads = jax.value_and_grad(
            loss, has_aux=True)(params)
        grads, gnorm = clip_by_global_norm(grads, tcfg.clip_norm)
        if grad_tx is not None:
            grads, tx_state = grad_tx(grads, tx_state)
        params, opt = adamw_update(grads, opt, params, lr=lr,
                                   weight_decay=tcfg.weight_decay)
        metrics = {"loss": base, "total": total, "ebops": ebops,
                   "gnorm": gnorm, "beta": beta}
        return params, newq, opt, metrics, tx_state

    if grad_tx is None:
        def step_fn(params, qstate, opt: AdamWState, batch, step):
            return _step(params, qstate, opt, batch, step, None)[:4]
        return step_fn

    def step_fn_tx(params, qstate, opt: AdamWState, batch, step, tx_state):
        return _step(params, qstate, opt, batch, step, tx_state)
    return step_fn_tx


class Trainer:
    """Host-side driver: jit, checkpoints, resume, Pareto tracking."""

    def __init__(self, forward: Forward, loss_fn: LossFn, tcfg: TrainConfig,
                 params, qstate, *,
                 eval_fn: Optional[Callable] = None,
                 pipeline: Optional[Callable[[int], Dict]] = None,
                 better_metric: str = "max"):
        self.tcfg = tcfg
        self.forward = forward
        self.pipeline = pipeline
        self.eval_fn = eval_fn
        self.params = params
        self.qstate = qstate
        self.opt = adamw_init(params)
        self.start_step = 0
        self.pareto = ParetoFront(better_metric)
        self.step_fn = jax.jit(make_train_step(forward, loss_fn, tcfg),
                               donate_argnums=(0, 2))
        self.history = []

    # -------------------------- fault tolerance --------------------------
    def maybe_resume(self) -> bool:
        if not self.tcfg.ckpt_dir:
            return False
        last = ckpt_lib.latest_step(self.tcfg.ckpt_dir)
        if last is None:
            return False
        _, trees = ckpt_lib.restore(
            self.tcfg.ckpt_dir, last,
            {"params": self.params, "qstate": self.qstate, "opt": self.opt})
        self.params = trees["params"]
        self.qstate = trees["qstate"]
        self.opt = trees["opt"]
        self.start_step = last
        return True

    def checkpoint(self, step: int, pareto: bool = False) -> Optional[str]:
        if not self.tcfg.ckpt_dir:
            return None
        path = ckpt_lib.save(self.tcfg.ckpt_dir, step,
                             {"params": self.params, "qstate": self.qstate,
                              "opt": self.opt},
                             keep=self.tcfg.keep_ckpts)
        if pareto:
            ckpt_lib.mark_pareto(path)
        return path

    # ------------------------------- run ---------------------------------
    def run(self, steps: Optional[int] = None, log=print) -> Dict[str, Any]:
        tcfg = self.tcfg
        steps = steps or tcfg.steps
        t0 = time.time()
        m = {}
        for step in range(self.start_step, steps):
            batch = self.pipeline(step)
            self.params, self.qstate, self.opt, m = self.step_fn(
                self.params, self.qstate, self.opt, batch,
                jnp.int32(step))
            if step % tcfg.log_every == 0:
                mm = {k: float(v) for k, v in m.items()}
                log(f"step {step}: loss={mm['loss']:.4f} "
                    f"ebops={mm['ebops']:.3g} beta={mm['beta']:.2g}")
                self.history.append({"step": step, **mm})
            # checkpoint labels are "steps applied" (= next step to run):
            # after the step_fn above, that is step + 1 — labelling with
            # `step` would double-apply one batch on resume.  The Pareto
            # front records the same label so front entries map to their
            # pinned checkpoint directories.
            saved_pareto = False
            if self.eval_fn and step and step % tcfg.eval_every == 0:
                metric, ebops = self.eval_fn(self.params, self.qstate)
                if self.pareto.offer(metric, ebops, step + 1):
                    path = self.checkpoint(step + 1, pareto=True)
                    saved_pareto = True
            if (tcfg.ckpt_dir and step and step % tcfg.ckpt_every == 0
                    and not saved_pareto):  # don't clobber the PARETO pin
                self.checkpoint(step + 1)
        return {"metrics": {k: float(v) for k, v in m.items()},
                "wall_s": time.time() - t0,
                "pareto": self.pareto.front()}
