"""Training loop: Eq.-16 loss, joint weight+bitwidth optimization, Pareto
checkpointing, fault-tolerant resume.

``make_train_step`` builds the pure step function (pjit-able — the launcher
wraps it with shardings); :class:`Trainer` is the host-side driver with
checkpoint/restart and the paper's beta-ramp Pareto sweep.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core import hgq
from ..core.pareto import ParetoFront
from ..core.schedule import Schedule, constant, log_ramp
from ..dist import collectives
from ..optim import AdamWState, adamw_init, adamw_update, clip_by_global_norm
from . import checkpoint as ckpt_lib

Forward = Callable[..., Tuple[jax.Array, Any, Any]]
LossFn = Callable[[jax.Array, Dict[str, jax.Array]], jax.Array]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 1000
    lr: float = 1e-3
    weight_decay: float = 0.0
    clip_norm: float = 1.0
    beta0: float = 1e-6          # Eq. 16 resource coefficient (ramped)
    beta1: float = 1e-4
    gamma: float = 2e-6          # Eq. 16 L1 coefficient (paper: fixed 2e-6)
    beta_const: Optional[float] = None  # HGQ-c* variant: fixed beta
    log_every: int = 50
    eval_every: int = 100
    ckpt_every: int = 200
    ckpt_dir: str = ""
    keep_ckpts: int = 3


def _merge_sliced_qstate(newqs):
    """Reconcile the per-slice activation-range states a vmapped forward
    returns ([n_slices, ...] leaves) back into one qstate: extremes merge
    with min/max over the slice axis — identical to what the unsliced
    forward would have observed on the full batch."""
    def merge(node):
        if isinstance(node, hgq.ActState):
            return hgq.ActState(vmin=jnp.min(node.vmin, axis=0),
                                vmax=jnp.max(node.vmax, axis=0))
        return jnp.mean(node, axis=0)
    return jax.tree.map(merge, newqs,
                        is_leaf=lambda x: isinstance(x, hgq.ActState))


def make_train_step(forward: Forward, loss_fn: LossFn, tcfg: TrainConfig,
                    lr_sched: Optional[Schedule] = None,
                    grad_tx: Optional[Callable] = None,
                    reduce: str = "full", mesh=None,
                    wire_kind: str = "int8", wire_layout: str = "auto",
                    wire_widths: Optional[Any] = None,
                    wire_fused: bool = True):
    """Build the pure train step.

    With ``grad_tx`` (e.g. ``dist.ef_compress`` partial application: a
    ``(grads, state) -> (grads, state)`` transform applied after clipping),
    the step takes and returns one extra ``tx_state`` argument so the
    error-feedback residual threads through pjit.

    ``reduce="compressed"`` moves the compression *into* the data-parallel
    reduction: per-shard gradients come from a vmap over ``n_data`` batch
    slices (sharded on the slice axis, so no fp32 gradient collective is
    ever emitted) and are mean-reduced by the int8-on-the-wire collective
    under ``mesh``.  ``wire_layout`` picks the exchange topology:

    * ``"1d"`` — ``collectives.ef_wire_pmean``: two-phase exchange over
      the data axes only; every model (TP) shard reduces the full
      gradient.  ``tx_state`` carries a leading ``[n_data]`` residual
      (``collectives.ef_wire_init``; shard with
      ``sharding.ef_residual_sharding``).
    * ``"2d"`` — ``collectives.ef_wire_pmean_2d``: each (data, model)
      device reduces only its 1/(D*M) slice, and one int8 all_gather over
      ``model`` rematerializes the full gradient.  ``tx_state`` carries
      the sliced ``[n_data, n_model, C]`` residual
      (``collectives.ef_wire2d_init``; shard with
      ``sharding.ef_residual_sharding(..., layout="2d")``).
    * ``"auto"`` — ``"2d"`` when ``mesh`` has a model axis of size > 1,
      else ``"1d"``.

    ``wire_widths`` (a ``core.plan.PrecisionPlan``) selects per-leaf wire
    widths for the compressed reduction — its ``wire_bits_tree`` over the
    gradient tree feeds the collective's ``widths`` argument.  ``None``
    (or a uniform-int8 plan, which callers normalize to ``None``) traces
    the exact legacy int8 wire.  ``wire_fused`` (default) selects the
    fused/pipelined wire fast path (``CompressionSpec.fused``) — the
    delivered values are bit-for-bit the per-leaf trace either way.

    Global-norm clipping applies to the *delivered* mean gradient
    (post-reduce compression clips before — the true pre-reduce global
    norm is unknowable without the very fp32 reduce this path removes).
    With ``mesh=None``, or a single device, the compressed path
    degenerates to the post-reduce ``ef_compress(kind=wire_kind)``
    transform, bit-for-bit.
    """
    if reduce not in ("full", "compressed"):
        raise ValueError(f"reduce must be 'full' or 'compressed', "
                         f"got {reduce!r}")
    if wire_layout not in ("auto", "1d", "2d"):
        raise ValueError(f"wire_layout must be 'auto', '1d' or '2d', "
                         f"got {wire_layout!r}")
    beta_sched = (constant(tcfg.beta_const) if tcfg.beta_const is not None
                  else log_ramp(tcfg.beta0, tcfg.beta1, tcfg.steps))
    lr_sched = lr_sched or constant(tcfg.lr)

    if reduce == "compressed":
        if grad_tx is not None:
            raise ValueError(
                "grad_tx and reduce='compressed' are mutually exclusive: "
                "the compressed reduction IS the gradient transform "
                "(wire_kind selects its quantization)")
        n_data = collectives.data_axis_size(mesh) if mesh is not None else 1
        n_model = (collectives.model_axis_size(mesh)
                   if mesh is not None else 1)
        if wire_layout == "auto":
            wire_layout = "2d" if n_model > 1 else "1d"
        if n_data <= 1 and not (wire_layout == "2d" and n_model > 1):
            # single device: the wire is a no-op — the current post-reduce
            # error-feedback path IS the compressed path, exactly
            from ..dist import ef_compress
            grad_tx = lambda g, s: ef_compress(g, s, kind=wire_kind)
        else:
            return _make_compressed_step(forward, loss_fn, tcfg, beta_sched,
                                         lr_sched, mesh, wire_kind, n_data,
                                         wire_layout, wire_widths,
                                         wire_fused)

    def _step(params, qstate, opt: AdamWState, batch, step, tx_state):
        beta = beta_sched(step)
        lr = lr_sched(step)

        def loss(params_):
            out, newq, aux = forward(params_, qstate, batch, mode=hgq.TRAIN)
            base = loss_fn(out, batch)
            total = base + beta * aux.ebops + tcfg.gamma * aux.l1
            return total, (newq, aux.ebops, base)

        (total, (newq, ebops, base)), grads = jax.value_and_grad(
            loss, has_aux=True)(params)
        grads, gnorm = clip_by_global_norm(grads, tcfg.clip_norm)
        if grad_tx is not None:
            grads, tx_state = grad_tx(grads, tx_state)
        params, opt = adamw_update(grads, opt, params, lr=lr,
                                   weight_decay=tcfg.weight_decay)
        metrics = {"loss": base, "total": total, "ebops": ebops,
                   "gnorm": gnorm, "beta": beta}
        return params, newq, opt, metrics, tx_state

    if grad_tx is None:
        def step_fn(params, qstate, opt: AdamWState, batch, step):
            return _step(params, qstate, opt, batch, step, None)[:4]
        return step_fn

    def step_fn_tx(params, qstate, opt: AdamWState, batch, step, tx_state):
        return _step(params, qstate, opt, batch, step, tx_state)
    return step_fn_tx


def _make_compressed_step(forward: Forward, loss_fn: LossFn,
                          tcfg: TrainConfig, beta_sched, lr_sched,
                          mesh, wire_kind: str, n_data: int,
                          wire_layout: str = "1d",
                          wire_widths: Optional[Any] = None,
                          wire_fused: bool = True):
    """The int8-on-the-wire train step (see ``make_train_step`` docstring).

    Per-shard gradients are materialized with a leading ``[n_data]`` axis
    (vmap of ``value_and_grad`` over equal batch slices, sharded over the
    data axes — the backward never sums across slices, so XLA emits no
    gradient all-reduce at all); the wire collective
    (``collectives.ef_wire_pmean`` / ``ef_wire_pmean_2d`` per
    ``wire_layout``) is then the only gradient communication in the
    program.
    """
    def step_fn_wire(params, qstate, opt: AdamWState, batch, step, tx_state):
        beta = beta_sched(step)
        lr = lr_sched(step)

        def loss_slice(params_, batch_slice):
            out, newq, aux = forward(params_, qstate, batch_slice,
                                     mode=hgq.TRAIN)
            base = loss_fn(out, batch_slice)
            total = base + beta * aux.ebops + tcfg.gamma * aux.l1
            return total, (newq, aux.ebops, base)

        def slice_leaf(b):
            if b.shape[0] % n_data:
                raise ValueError(
                    f"compressed reduce needs the batch axis ({b.shape[0]}) "
                    f"divisible by the {n_data} data shards")
            return b.reshape((n_data, b.shape[0] // n_data) + b.shape[1:])

        sliced = jax.tree.map(slice_leaf, batch)
        (totals, (newqs, ebops_s, bases)), grads = jax.vmap(
            jax.value_and_grad(loss_slice, has_aux=True),
            in_axes=(None, 0))(params, sliced)
        newq = _merge_sliced_qstate(newqs)
        # per-leaf wire widths from the PrecisionPlan (static ints keyed
        # by the grads tree paths; None = uniform int8, the legacy trace)
        widths = (None if wire_widths is None
                  else wire_widths.wire_bits_tree(grads))
        if wire_layout == "2d":
            # the residual lives in the sliced [n_data, n_model, C] layout,
            # so the grad+residual add happens on the slice, inside the
            # collective — gradients go in raw
            delivered, residual = collectives.ef_wire_pmean_2d(
                grads, tx_state.residual, mesh, wire_kind, widths=widths,
                fused=wire_fused)
        else:
            err = jax.tree.map(jnp.add, grads, tx_state.residual)
            delivered, residual = collectives.ef_wire_pmean(
                err, mesh, wire_kind, widths=widths, fused=wire_fused)
        delivered, gnorm = clip_by_global_norm(delivered, tcfg.clip_norm)
        params, opt = adamw_update(delivered, opt, params, lr=lr,
                                   weight_decay=tcfg.weight_decay)
        metrics = {"loss": jnp.mean(bases), "total": jnp.mean(totals),
                   "ebops": jnp.mean(ebops_s), "gnorm": gnorm, "beta": beta}
        return params, newq, opt, metrics, type(tx_state)(residual=residual)

    return step_fn_wire


class Trainer:
    """Host-side driver: jit, checkpoints, resume, Pareto tracking."""

    def __init__(self, forward: Forward, loss_fn: LossFn, tcfg: TrainConfig,
                 params, qstate, *,
                 eval_fn: Optional[Callable] = None,
                 pipeline: Optional[Callable[[int], Dict]] = None,
                 better_metric: str = "max",
                 grad_tx: Optional[Callable] = None,
                 tx_state: Optional[Any] = None):
        self.tcfg = tcfg
        self.forward = forward
        self.pipeline = pipeline
        self.eval_fn = eval_fn
        self.params = params
        self.qstate = qstate
        self.opt = adamw_init(params)
        self.start_step = 0
        self.pareto = ParetoFront(better_metric)
        # grad_tx must reach the jitted step — building the step without it
        # silently dropped any configured gradient compression (regression)
        self.grad_tx = grad_tx
        if grad_tx is not None:
            if tx_state is None:
                from ..dist import ef_init
                tx_state = ef_init(params)
            # the residual threads step-to-step like the optimizer state
            self.step_fn = jax.jit(
                make_train_step(forward, loss_fn, tcfg, grad_tx=grad_tx),
                donate_argnums=(0, 2, 5))
        else:
            if tx_state is not None:
                raise ValueError("tx_state given but no grad_tx transform; "
                                 "gradient compression would be silently "
                                 "ignored")
            self.step_fn = jax.jit(make_train_step(forward, loss_fn, tcfg),
                                   donate_argnums=(0, 2))
        self.tx_state = tx_state
        self.history = []

    # -------------------------- fault tolerance --------------------------
    def maybe_resume(self) -> bool:
        if not self.tcfg.ckpt_dir:
            return False
        last = ckpt_lib.latest_step(self.tcfg.ckpt_dir)
        if last is None:
            return False
        tmpl = {"params": self.params, "qstate": self.qstate, "opt": self.opt}
        # EF residual resumes rather than resetting — a zero residual would
        # bias the first post-resume window (only when the checkpoint has
        # one: a run may turn compression on mid-stream)
        if self.tx_state is not None and ckpt_lib.has_tree(
                self.tcfg.ckpt_dir, last, "ef"):
            tmpl["ef"] = self.tx_state
        _, trees = ckpt_lib.restore(self.tcfg.ckpt_dir, last, tmpl)
        self.params = trees["params"]
        self.qstate = trees["qstate"]
        self.opt = trees["opt"]
        self.tx_state = trees.get("ef", self.tx_state)
        self.start_step = last
        return True

    def checkpoint(self, step: int, pareto: bool = False) -> Optional[str]:
        if not self.tcfg.ckpt_dir:
            return None
        trees = {"params": self.params, "qstate": self.qstate,
                 "opt": self.opt}
        if self.tx_state is not None:
            trees["ef"] = self.tx_state
        path = ckpt_lib.save(self.tcfg.ckpt_dir, step, trees,
                             keep=self.tcfg.keep_ckpts)
        if pareto:
            ckpt_lib.mark_pareto(path)
        return path

    # ------------------------------- run ---------------------------------
    def run(self, steps: Optional[int] = None, log=print) -> Dict[str, Any]:
        tcfg = self.tcfg
        steps = steps or tcfg.steps
        t0 = time.time()
        m = {}
        for step in range(self.start_step, steps):
            batch = self.pipeline(step)
            if self.grad_tx is not None:
                (self.params, self.qstate, self.opt, m,
                 self.tx_state) = self.step_fn(
                    self.params, self.qstate, self.opt, batch,
                    jnp.int32(step), self.tx_state)
            else:
                self.params, self.qstate, self.opt, m = self.step_fn(
                    self.params, self.qstate, self.opt, batch,
                    jnp.int32(step))
            if step % tcfg.log_every == 0:
                mm = {k: float(v) for k, v in m.items()}
                log(f"step {step}: loss={mm['loss']:.4f} "
                    f"ebops={mm['ebops']:.3g} beta={mm['beta']:.2g}")
                self.history.append({"step": step, **mm})
            # checkpoint labels are "steps applied" (= next step to run):
            # after the step_fn above, that is step + 1 — labelling with
            # `step` would double-apply one batch on resume.  The Pareto
            # front records the same label so front entries map to their
            # pinned checkpoint directories.
            saved_pareto = False
            if self.eval_fn and step and step % tcfg.eval_every == 0:
                out = self.eval_fn(self.params, self.qstate)
                # eval_fn returns (metric, ebops) or (metric, ebops,
                # payload) — e.g. a core.plan.PrecisionPlan snapshot, so
                # every front point carries its deployable width table
                metric, ebops = out[0], out[1]
                payload = out[2] if len(out) > 2 else None
                if self.pareto.offer(metric, ebops, step + 1, payload):
                    path = self.checkpoint(step + 1, pareto=True)
                    saved_pareto = True
            if (tcfg.ckpt_dir and step and step % tcfg.ckpt_every == 0
                    and not saved_pareto):  # don't clobber the PARETO pin
                self.checkpoint(step + 1)
        return {"metrics": {k: float(v) for k, v in m.items()},
                "wall_s": time.time() - t0,
                "pareto": self.pareto.front()}
