"""Task losses (fp32 compute) + the Eq.-16 total."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Cross entropy in vocab-shard-friendly form: the gold logit is picked
    with an iota mask + reduce (elementwise, partial-summable per shard)
    instead of ``take_along_axis``, which would all-gather a [B,S,V] fp32
    tensor when logits are sharded over vocab (40 GB/device at qwen scale)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    gold = jnp.sum(jnp.where(vocab_iota == labels[..., None], logits, 0.0),
                   axis=-1)
    return jnp.mean(logz - gold)


def lm_loss(logits: jax.Array, tokens: jax.Array) -> jax.Array:
    """Next-token cross entropy: predict tokens[:, 1:] from logits[:, :-1]."""
    return softmax_xent(logits[:, :-1], tokens[:, 1:])


def mse(pred: jax.Array, target: jax.Array) -> jax.Array:
    return jnp.mean(jnp.square(pred.astype(jnp.float32)
                               - target.astype(jnp.float32)))


def accuracy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    return jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))


def rms_resolution(pred: jax.Array, target: jax.Array,
                   outlier_mrad: float = 30.0) -> jax.Array:
    """Paper SSec. V.D: RMS of reconstruction error, excluding |err| > 30 mrad."""
    err = pred.astype(jnp.float32) - target.astype(jnp.float32)
    keep = jnp.abs(err) <= outlier_mrad
    n = jnp.maximum(jnp.sum(keep), 1)
    return jnp.sqrt(jnp.sum(jnp.where(keep, err * err, 0.0)) / n)
