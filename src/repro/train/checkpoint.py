"""Fault-tolerant checkpointing.

* Atomic: write to ``<dir>/tmp.<step>`` then ``os.replace`` — a crash
  mid-write never corrupts the latest checkpoint.
* Self-describing: flattened key->array npz + a JSON sidecar with step,
  config name, and tree structure; restore works into any mesh (arrays are
  saved unsharded logical tensors and re-sharded by the caller's
  in_shardings — elastic rescale on restart).
* Resumable data: pipelines are (seed, step)-pure (repro.data), so restoring
  ``step`` alone replays the stream exactly.
* Retention: keeps the last N checkpoints plus every Pareto-front member.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save(ckpt_dir: str, step: int, trees: Dict[str, Any],
         meta: Optional[Dict[str, Any]] = None, keep: int = 3) -> str:
    """trees: e.g. {'params': ..., 'qstate': ..., 'opt': ...}."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f".tmp.{step}")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    treedefs = {}
    for name, tree in trees.items():
        flat = _flatten(tree)
        np.savez(os.path.join(tmp, f"{name}.npz"), **flat)
        treedefs[name] = jax.tree_util.tree_structure(tree).__repr__()
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, "trees": list(trees), **(meta or {})}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)                      # atomic publish
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep] if keep else []:
        pareto_marker = os.path.join(ckpt_dir, d, "PARETO")
        if not os.path.exists(pareto_marker):
            shutil.rmtree(os.path.join(ckpt_dir, d))


def mark_pareto(path: str) -> None:
    """Pin a checkpoint (Pareto-front member) against GC."""
    open(os.path.join(path, "PARETO"), "w").close()


def has_tree(ckpt_dir: str, step: int, name: str) -> bool:
    """Whether checkpoint ``step`` stored a tree under ``name`` — callers
    with optional trees (e.g. the EF residual) probe before templating so
    layout knowledge stays in this module."""
    return os.path.exists(os.path.join(ckpt_dir, f"step_{step:08d}",
                                       f"{name}.npz"))


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, templates: Dict[str, Any]
            ) -> Tuple[int, Dict[str, Any]]:
    """Restore trees shaped like ``templates`` (same structure; arrays are
    loaded by flattened key so minor structural reorder is tolerated)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    out = {}
    for name, template in templates.items():
        data = np.load(os.path.join(path, f"{name}.npz"))
        paths, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for kp, leaf in paths:
            key = "/".join(_path_str(p) for p in kp)
            arr = data[key]
            assert arr.shape == tuple(leaf.shape), \
                f"{name}/{key}: ckpt {arr.shape} vs template {leaf.shape}"
            leaves.append(arr.astype(leaf.dtype))
        out[name] = jax.tree_util.tree_unflatten(treedef, leaves)
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    return meta["step"], out
