from .losses import softmax_xent, lm_loss, mse, accuracy, rms_resolution
from .loop import TrainConfig, Trainer, make_train_step
from . import checkpoint
