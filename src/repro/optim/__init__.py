from .optimizers import (AdamWState, adamw_init, adamw_update, clip_by_global_norm,
                         sgd_update, lion_init, lion_update, LionState)
