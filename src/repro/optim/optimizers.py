"""Pure-JAX optimizers (no optax in this environment).

AdamW is the default for both network weights and HGQ bitwidths; the paper's
released library trains both jointly with one optimizer, and the surrogate
bitwidth gradients (Alg. 1) are already scaled to be commensurate with the
weight gradients.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(jnp.zeros_like, params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.zeros_like, params))


def adamw_update(grads, state: AdamWState, params, *, lr,
                 b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
                 weight_decay: float = 0.0) -> Tuple[Any, AdamWState]:
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        dp = mh / (jnp.sqrt(vh) + eps)
        if weight_decay:
            dp = dp + weight_decay * p.astype(jnp.float32)
        return (p - lr * dp.astype(p.dtype)).astype(p.dtype), m, v

    flat_g, tdef = jax.tree.flatten(grads)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_v = tdef.flatten_up_to(state.nu)
    flat_p = tdef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v,
                                                 flat_p)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v)


class LionState(NamedTuple):
    step: jax.Array
    mu: Any


def lion_init(params) -> LionState:
    return LionState(step=jnp.zeros((), jnp.int32),
                     mu=jax.tree.map(jnp.zeros_like, params))


def lion_update(grads, state: LionState, params, *, lr, b1: float = 0.9,
                b2: float = 0.99, weight_decay: float = 0.0):
    """Lion: sign-momentum optimizer — 1/2 the optimizer memory of AdamW, a
    distributed-training win at 100B+ scale (state bytes halve the
    checkpoint + the FSDP all-gather volume)."""
    step = state.step + 1

    def upd(g, m, p):
        g = g.astype(jnp.float32)
        u = jnp.sign(b1 * m + (1 - b1) * g)
        if weight_decay:
            u = u + weight_decay * p.astype(jnp.float32)
        m_new = b2 * m + (1 - b2) * g
        return (p - lr * u.astype(p.dtype)).astype(p.dtype), m_new

    flat_g, tdef = jax.tree.flatten(grads)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_p = tdef.flatten_up_to(params)
    out = [upd(g, m, p) for g, m, p in zip(flat_g, flat_m, flat_p)]
    return tdef.unflatten([o[0] for o in out]), \
        LionState(step=step, mu=tdef.unflatten([o[1] for o in out]))


def sgd_update(grads, params, *, lr):
    return jax.tree.map(lambda p, g: (p - lr * g).astype(p.dtype), params,
                        grads)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn
