"""Serving/throughput knobs: compute-dtype casting and HGQ int8 packing.

Compute dtype: the launchers opt a run into bf16 compute with
:func:`set_compute_dtype`; layers call :func:`cast_for_matmul` on matmul
operands so fp32-master FSDP gathers and TP partial-sum all-reduces move
bf16 bytes (half the collective volume).  Default (``None``) is a no-op.

Packing: :func:`pack_params_for_serving` rewrites every matmul weight dict
``{'w', 'f'}`` into ``{'w_int8', 'scale', 'f'}`` — int8 mantissas plus a
per-output-channel ``2^-f`` scale, the deployable representation the HGQ
paper's heterogeneous-bitwidth training produces.  ``nn.common.get_qw``
dequantizes at use (``unpack_weight``) and XLA fuses the dequant into the
consuming matmul, mirroring ``kernels/qmatmul``.  Halves decode HBM
traffic vs bf16.  The transform is shape-preserving and traceable, so the
dry-run can ``jax.eval_shape`` it over abstract params.

Both knobs are read at *trace* time: set the compute dtype (and the axis
registry in :mod:`repro.dist.axes`) before jitting — a jitted executable
keeps whatever was set when it traced, and later ``set_compute_dtype``
calls do not retrace it.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..core.quantizer import _exp2i, floor_log2

_COMPUTE_DTYPE: Optional[Any] = None


def set_compute_dtype(dtype) -> None:
    """Set (or clear, with ``None``) the matmul compute dtype."""
    global _COMPUTE_DTYPE
    _COMPUTE_DTYPE = dtype


def get_compute_dtype():
    return _COMPUTE_DTYPE


def cast_for_matmul(x: jax.Array) -> jax.Array:
    """Cast a floating matmul operand to the compute dtype, if one is set."""
    if _COMPUTE_DTYPE is None:
        return x
    if not jnp.issubdtype(x.dtype, jnp.floating):
        return x
    return x.astype(_COMPUTE_DTYPE)


# ---------------------------------------------------------------------------
# HGQ int8 serving-weight packing
# ---------------------------------------------------------------------------

def _packable(name: str, w) -> bool:
    if not hasattr(w, "ndim") or w.ndim < 2:
        return False          # biases, norm gains, scalars
    if not jnp.issubdtype(w.dtype, jnp.floating):
        return False
    if name == "bias":
        return False          # stacked biases are [L, d] but not matmuls
    if name == "kernel" and w.ndim >= 4:
        return False          # conv kernels: HConv2D reads 'w' directly
    return True


def _pack_one(p: Dict[str, Any]) -> Dict[str, Any]:
    w = jnp.asarray(p["w"])
    f = p.get("f")
    w32 = w.astype(jnp.float32)
    amax = jnp.max(jnp.abs(w32), axis=-2, keepdims=True)
    if f is not None:
        # per-output-channel grid from the trained fractional bits: reduce
        # over the contraction axis (-2) only, so stacked-layer / expert
        # leading axes keep their own scales.  With per-parameter f the
        # column max can exceed what 8 bits hold (int_bits + frac_bits > 8),
        # so cap fi at the largest exponent whose mantissa fits in +-127:
        # saturating the big weights corrupts the matmul far worse than
        # flooring the small ones.
        fi = jnp.floor(jnp.broadcast_to(
            jnp.asarray(f, jnp.float32), w.shape) + 0.5)
        fi = jnp.max(fi, axis=-2, keepdims=True)
        fi_cap = floor_log2(127.0 / jnp.maximum(amax, 1e-12))
        fi = jnp.minimum(fi, fi_cap)
        # the cap divides two floats, so it can still be one too high at
        # the boundary; back off where the mantissa would saturate
        fi = jnp.where(jnp.floor(amax * _exp2i(fi) + 0.5) > 127.0,
                       fi - 1.0, fi)
        scale = _exp2i(-fi)
    else:
        scale = jnp.maximum(amax, 1e-12) / 127.0
    m = jnp.clip(jnp.floor(w32 / scale + 0.5), -128, 127)
    out = {"w_int8": m.astype(jnp.int8), "scale": scale.astype(jnp.float32)}
    if f is not None:
        out["f"] = f
    return out


def pack_params_for_serving(params: Any) -> Any:
    """Rewrite matmul weights to int8 + per-channel scale (see module doc).

    Structure-preserving everywhere else; safe to call on abstract
    (``ShapeDtypeStruct``) trees under ``jax.eval_shape``.
    """
    def walk(obj, name=""):
        if isinstance(obj, dict):
            if "w" in obj and _packable(name, obj["w"]):
                return _pack_one(obj)
            return {k: walk(v, k) for k, v in obj.items()}
        return obj
    return walk(params)


def unpack_weight(p: Dict[str, Any]) -> jax.Array:
    """Dequantize a packed weight dict; fuses into the consuming matmul."""
    w = p["w_int8"].astype(jnp.float32) * p["scale"].astype(jnp.float32)
    if _COMPUTE_DTYPE is not None:
        w = w.astype(_COMPUTE_DTYPE)
    return w
