"""Serving/throughput knobs: compute-dtype casting and HGQ int8 packing.

Compute dtype: the launchers opt a run into bf16 compute with
:func:`set_compute_dtype`; layers call :func:`cast_for_matmul` on matmul
operands so fp32-master FSDP gathers and TP partial-sum all-reduces move
bf16 bytes (half the collective volume).  Default (``None``) is a no-op.

Packing: :func:`pack_params_for_serving` rewrites every matmul weight dict
``{'w', 'f'}`` into ``{'w_int8', 'scale', 'f'}`` — int8 mantissas plus a
per-output-channel ``2^-f`` scale, the deployable representation the HGQ
paper's heterogeneous-bitwidth training produces.  ``nn.common.get_qw``
dequantizes at use (``unpack_weight``) and XLA fuses the dequant into the
consuming matmul, mirroring ``kernels/qmatmul``.  Halves decode HBM
traffic vs bf16.  The transform is shape-preserving and traceable, so the
dry-run can ``jax.eval_shape`` it over abstract params.

Both knobs are read at *trace* time: set the compute dtype (and the axis
registry in :mod:`repro.dist.axes`) before jitting — a jitted executable
keeps whatever was set when it traced, and later ``set_compute_dtype``
calls do not retrace it.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

_COMPUTE_DTYPE: Optional[Any] = None


def set_compute_dtype(dtype) -> None:
    """Set (or clear, with ``None``) the matmul compute dtype."""
    global _COMPUTE_DTYPE
    _COMPUTE_DTYPE = dtype


def get_compute_dtype():
    return _COMPUTE_DTYPE


def cast_for_matmul(x: jax.Array) -> jax.Array:
    """Cast a floating matmul operand to the compute dtype, if one is set."""
    if _COMPUTE_DTYPE is None:
        return x
    if not jnp.issubdtype(x.dtype, jnp.floating):
        return x
    return x.astype(_COMPUTE_DTYPE)


# ---------------------------------------------------------------------------
# packed-matmul routing (serving/packed.py)
# ---------------------------------------------------------------------------

_PACKED_MATMUL = False


def set_packed_matmul(on: bool) -> None:
    """Route dense projections over int8-packed kernels onto the Pallas
    ``kernels.qmatmul.qmatmul_any`` path (read at trace time, like the
    compute dtype).  Off: packed kernels dequantize and use ``jnp.matmul``
    (XLA fuses the dequant)."""
    global _PACKED_MATMUL
    _PACKED_MATMUL = bool(on)


def get_packed_matmul() -> bool:
    return _PACKED_MATMUL


class packed_matmul:
    """Context manager: trace/run the enclosed computation with the packed
    qmatmul routing set to ``on`` (restores the previous value on exit)."""

    def __init__(self, on: bool = True):
        self.on = on
        self.prev = None

    def __enter__(self):
        self.prev = _PACKED_MATMUL
        set_packed_matmul(self.on)
        return self

    def __exit__(self, *exc):
        set_packed_matmul(self.prev)
        return False


# ---------------------------------------------------------------------------
# HGQ int8 serving-weight packing
# ---------------------------------------------------------------------------

def _packable(name: str, w) -> bool:
    if not hasattr(w, "ndim") or w.ndim < 2:
        return False          # biases, norm gains, scalars
    if not jnp.issubdtype(w.dtype, jnp.floating):
        return False
    if name == "bias":
        return False          # stacked biases are [L, d] but not matmuls
    if name == "kernel" and w.ndim >= 4:
        return False          # conv kernels: HConv2D reads 'w' directly
    return True


def _pack_one(p: Dict[str, Any]) -> Dict[str, Any]:
    """One matmul-weight dict {'w', 'f'?} -> {'w_int8', 'scale', 'f'?}.

    The per-output-channel power-of-two grid (2^-f at the trained bits,
    capped so the channel amax fits +-127; with no 'f' the cap alone) and
    the int8 mantissas come from the single shared leaf packer
    ``kernels.qmatmul.pack_linear`` — the same representation the fused
    dequant-matmul kernel consumes.  Scale keeps a broadcastable
    ``[..., 1, N]`` shape for ``unpack_weight``."""
    from ..kernels.qmatmul.ops import pack_linear
    m, scale = pack_linear(p["w"], p.get("f"))
    out = {"w_int8": m, "scale": scale[..., None, :].astype(jnp.float32)}
    if p.get("f") is not None:
        out["f"] = p["f"]
    return out


def pack_params_for_serving(params: Any) -> Any:
    """Rewrite matmul weights to int8 + per-channel scale (see module doc).

    Structure-preserving everywhere else (including list-of-layer nodes,
    e.g. Griffin remainder blocks); safe to call on abstract
    (``ShapeDtypeStruct``) trees under ``jax.eval_shape``.
    """
    def walk(obj, name=""):
        if isinstance(obj, dict):
            if "w" in obj and _packable(name, obj["w"]):
                return _pack_one(obj)
            return {k: walk(v, k) for k, v in obj.items()}
        if isinstance(obj, list):
            return [walk(v, name) for v in obj]
        return obj
    return walk(params)


def unpack_weight(p: Dict[str, Any]) -> jax.Array:
    """Dequantize a packed weight dict; fuses into the consuming matmul."""
    w = p["w_int8"].astype(jnp.float32) * p["scale"].astype(jnp.float32)
    if _COMPUTE_DTYPE is not None:
        w = w.astype(_COMPUTE_DTYPE)
    return w
