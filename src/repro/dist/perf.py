"""Serving/throughput knobs: compute-dtype casting and HGQ int8 packing.

Compute dtype: layers call :func:`cast_for_matmul` on matmul operands so
fp32-master FSDP gathers and TP partial-sum all-reduces move bf16 bytes
(half the collective volume) when a run opts in.  The dtype a trace sees
is *scoped*: ``repro.api.RunContext`` activates its ``PrecisionSpec``
around every trace (:func:`compute_dtype_scope`), so two contexts with
different precisions coexist in one process.  The unscoped default is
``None`` (no cast).

Packing: :func:`pack_params_for_serving` rewrites every matmul weight dict
``{'w', 'f'}`` into ``{'w_int8', 'scale', 'f'}`` — int8 mantissas plus a
per-output-channel ``2^-f`` scale, the deployable representation the HGQ
paper's heterogeneous-bitwidth training produces.  ``nn.common.get_qw``
dequantizes at use (``unpack_weight``) and XLA fuses the dequant into the
consuming matmul, mirroring ``kernels/qmatmul``.  Halves decode HBM
traffic vs bf16.  The transform is shape-preserving and traceable, so the
dry-run can ``jax.eval_shape`` it over abstract params.

All knobs here are read at *trace* time: a jitted executable keeps
whatever was in scope when it traced, and later scope changes do not
retrace it.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from .scope import Scoped

_COMPUTE: Scoped[Optional[Any]] = Scoped("repro.dist.compute_dtype", None)
_PACKED: Scoped[bool] = Scoped("repro.dist.packed_matmul", False)


def compute_dtype_scope(dtype):
    """Context manager: trace the enclosed computation with ``dtype`` as
    the matmul compute dtype (``None`` = no cast); restores on exit."""
    return _COMPUTE.scope(dtype)


def reset_precision() -> None:
    """Back to the no-cast / unpacked defaults (tests)."""
    _COMPUTE.reset_default()
    _PACKED.reset_default()


def get_compute_dtype():
    return _COMPUTE.get()


def cast_for_matmul(x: jax.Array) -> jax.Array:
    """Cast a floating matmul operand to the compute dtype, if one is set."""
    dtype = _COMPUTE.get()
    if dtype is None:
        return x
    if not jnp.issubdtype(x.dtype, jnp.floating):
        return x
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# packed-matmul routing (serving/packed.py)
# ---------------------------------------------------------------------------

def get_packed_matmul() -> bool:
    return _PACKED.get()


class packed_matmul:
    """Context manager: trace/run the enclosed computation with the packed
    qmatmul routing set to ``on`` (restores the previous value on exit).

    On: dense projections over int8-packed kernels route onto the Pallas
    ``kernels.qmatmul.qmatmul_any`` path (read at trace time, like the
    compute dtype).  Off: packed kernels dequantize and use ``jnp.matmul``
    (XLA fuses the dequant)."""

    def __init__(self, on: bool = True):
        self.on = bool(on)
        self._cm = None

    def __enter__(self):
        self._cm = _PACKED.scope(self.on)
        self._cm.__enter__()
        return self

    def __exit__(self, *exc):
        cm, self._cm = self._cm, None
        return cm.__exit__(*exc)


# ---------------------------------------------------------------------------
# HGQ int8 serving-weight packing
# ---------------------------------------------------------------------------

def _packable(name: str, w) -> bool:
    if not hasattr(w, "ndim") or w.ndim < 2:
        return False          # biases, norm gains, scalars
    if not jnp.issubdtype(w.dtype, jnp.floating):
        return False
    if name == "bias":
        return False          # stacked biases are [L, d] but not matmuls
    if name == "kernel" and w.ndim >= 4:
        return False          # conv kernels: HConv2D reads 'w' directly
    return True


def _pack_one(p: Dict[str, Any], bits: int = 8) -> Dict[str, Any]:
    """One matmul-weight dict {'w', 'f'?} -> {'w_int8', 'scale', 'f'?}
    (or {'w_nib', ...} for sub-5-bit plan layers).

    The per-output-channel power-of-two grid (2^-f at the trained bits,
    capped so the channel amax fits the ``bits``-wide mantissa; with no
    'f' the cap alone) and the mantissas come from the single shared leaf
    packer ``kernels.qmatmul.pack_linear`` — the same representation the
    fused dequant-matmul kernel consumes.  Scale keeps a broadcastable
    ``[..., 1, N]`` shape for ``unpack_weight``.  ``bits <= 4`` with an
    even K axis nibble-packs two mantissas per stored byte along K
    (``w_nib [..., K/2, N]``; K recovers as ``2 * w_nib.shape[-2]``, no
    side metadata); odd-K layers keep int8 storage on the narrow grid."""
    from ..core.plan import NIBBLE_BITS
    from ..kernels.qmatmul.ops import pack_linear, pack_nibbles
    m, scale = pack_linear(p["w"], p.get("f"), bits)
    out: Dict[str, Any] = {
        "scale": scale[..., None, :].astype(jnp.float32)}
    if bits <= NIBBLE_BITS and m.shape[-2] % 2 == 0:
        out["w_nib"] = pack_nibbles(m, axis=-2)
    else:
        out["w_int8"] = m
    if p.get("f") is not None:
        out["f"] = p["f"]
    return out


def pack_params_for_serving(params: Any, plan=None) -> Any:
    """Rewrite matmul weights to quantized mantissas + per-channel scale
    (see module doc), at each layer's ``plan`` pack width (uniform int8
    when ``plan`` is ``None``).

    Structure-preserving everywhere else (including list-of-layer nodes,
    e.g. Griffin remainder blocks); safe to call on abstract
    (``ShapeDtypeStruct``) trees under ``jax.eval_shape``.  Layer keys
    are the ``/``-joined tree paths ``core.plan.iter_packable`` yields,
    so a plan derived from this params tree addresses exactly these
    weights.
    """
    def walk(obj, name="", prefix=()):
        if isinstance(obj, dict):
            if "w" in obj and _packable(name, obj["w"]):
                bits = 8 if plan is None else \
                    plan.entry_for("/".join(prefix)).pack_bits
                return _pack_one(obj, bits)
            return {k: walk(v, k, prefix + (str(k),))
                    for k, v in obj.items()}
        if isinstance(obj, list):
            return [walk(v, name, prefix + (str(i),))
                    for i, v in enumerate(obj)]
        return obj
    return walk(params)


def packed_mantissas(p: Dict[str, Any]) -> jax.Array:
    """Full-width int8 mantissas ``[..., K, N]`` of a packed weight dict,
    whichever storage it uses (``w_int8`` as-is; ``w_nib`` sign-extend
    unpacked along K).  The one accessor packed-kernel call sites route
    through."""
    if "w_nib" in p:
        from ..kernels.qmatmul.ops import unpack_nibbles
        nib = p["w_nib"]
        return unpack_nibbles(nib, 2 * nib.shape[-2], axis=-2)
    return p["w_int8"]


def is_packed(p: Any) -> bool:
    """True for a serving-packed weight dict (either storage format)."""
    return isinstance(p, dict) and ("w_int8" in p or "w_nib" in p)


def unpack_weight(p: Dict[str, Any]) -> jax.Array:
    """Dequantize a packed weight dict; fuses into the consuming matmul."""
    w = (packed_mantissas(p).astype(jnp.float32)
         * p["scale"].astype(jnp.float32))
    dtype = _COMPUTE.get()
    if dtype is not None:
        w = w.astype(dtype)
    return w
