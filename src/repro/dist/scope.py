"""Per-trace dynamic scope: the replacement for module-level mutable
trace-time state.

Trace-time knobs (the logical-axis registry, the matmul compute dtype, the
packed-kernel routing flag, the wire-bytes recorder) used to live in plain
module globals mutated by ``set_*`` functions.  That made every jitted
program depend on hidden ambient state: two configurations could not
coexist in one process, and the config a trace actually saw was whatever
the last caller left behind.

:class:`Scoped` keeps one *immutable default* plus a
``contextvars``-backed stack of overrides:

* ``get()`` returns the innermost active override, else the default —
  this is what ``constrain`` / ``cast_for_matmul`` read at trace time;
* ``scope(value)`` is a re-entrant context manager pushing an override
  for the dynamic extent of a trace — how :class:`repro.api.RunContext`
  activates its configuration, and how two contexts with different
  precision/axes coexist in one process without touching each other;
* ``set_default(value)`` / ``reset_default()`` rebind the process
  default — the escape hatch the (now removed) one-release ``set_*``
  deprecation shims delegated to; kept for tests that need to restore
  the pristine default.

``ContextVar`` (rather than a bare global) makes overrides task- and
thread-local, and ``tools/check_no_globals.py`` gates the repo so no new
``global``-statement trace state appears outside this mechanism.
"""
from __future__ import annotations

import contextlib
from contextvars import ContextVar
from typing import Generic, Iterator, Tuple, TypeVar

T = TypeVar("T")


class Scoped(Generic[T]):
    """One trace-time knob: an immutable default + a scoped override stack."""

    def __init__(self, name: str, default: T):
        self._var: ContextVar[Tuple[T, ...]] = ContextVar(name, default=())
        self._initial = default
        # one-element list, not a module global: rebound only through
        # set_default (the deprecated-shim delegation point)
        self._default = [default]

    def get(self) -> T:
        stack = self._var.get()
        return stack[-1] if stack else self._default[0]

    def set_default(self, value: T) -> None:
        """Rebind the process-wide default (deprecated shims only)."""
        self._default[0] = value

    def reset_default(self) -> None:
        """Back to the construction-time default (tests)."""
        self._default[0] = self._initial

    @contextlib.contextmanager
    def scope(self, value: T) -> Iterator[T]:
        """Push ``value`` for the dynamic extent of the block (re-entrant)."""
        token = self._var.set(self._var.get() + (value,))
        try:
            yield value
        finally:
            self._var.reset(token)
