"""``repro.dist`` — distribution & deployment utilities.

Six small modules, one convention:

* :mod:`repro.dist.scope` — the per-trace dynamic scope every trace-time
  knob lives in (no module-level mutable state; how
  ``repro.api.RunContext`` activates a configuration).
* :mod:`repro.dist.axes` — logical-axis registry + pattern-string
  activation sharding (``constrain(x, "b.m.")``); identity on 1 device.
* :mod:`repro.dist.sharding` — parameter/batch/cache placement rules
  (FSDP x TP heuristics) used by the launchers and the dry-run.
* :mod:`repro.dist.perf` — compute-dtype casting and HGQ int8
  serving-weight packing.
* :mod:`repro.dist.collectives` — the int8-on-the-wire compressed mean
  all-reduce (shard_map two-phase exchange, error feedback on both
  phases) that replaces the fp32 gradient collective.
* this module — post-reduce int8 error-feedback gradient compression
  (bounds update noise; the wire bytes story lives in ``collectives``).

Error feedback (1-bit-Adam lineage): each step compresses
``grad + residual`` and carries the quantization error forward, so the
*time-averaged* delivered gradient is unbiased and the residual stays
bounded by one quantization step.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .axes import (AxisRegistry, axis_scope, constrain,  # noqa: F401
                   get_model_size, registry_for_mesh)
from .collectives import (WIRE_KINDS, ef_wire2d_init,  # noqa: F401
                          ef_wire_init, ef_wire_pmean, ef_wire_pmean_2d,
                          model_axis_size, simulate_wire_pmean,
                          simulate_wire_pmean_2d)
from .perf import (cast_for_matmul, compute_dtype_scope,  # noqa: F401
                   get_compute_dtype, pack_params_for_serving,
                   unpack_weight)
from .sharding import (batch_sharding, batch_spec, cache_sharding,  # noqa: F401
                       ef_residual_sharding, is_stacked_path, replicated,
                       shard_tree, spec_for_param, stacked_tree)

EF_KINDS = ("none", "bf16", "int8")


class EFState(NamedTuple):
    """Per-leaf quantization residual carried across steps."""
    residual: Any


def ef_init(grads: Any) -> EFState:
    return EFState(residual=jax.tree.map(jnp.zeros_like, grads))


def _compress_leaf(e: jax.Array, kind: str, stacked: bool = False
                   ) -> jax.Array:
    if kind == "bf16":
        return e.astype(jnp.bfloat16).astype(e.dtype)
    # int8: symmetric grid, max|e| -> 127.  Stacked [L, ...] leaves (the
    # lax.scan layer / MoE expert axis, marked by ``stacked`` — derived
    # from the tree path by ``sharding.stacked_tree``, NOT sniffed from
    # rank) get one grid per layer: a single outlier layer must not crush
    # quantization resolution for all L (a per-tensor grid made every
    # other layer's step L-outlier-sized).  A genuinely 3-D weight (e.g. a
    # per-head attention tensor) is one tensor and keeps one grid.
    if stacked and e.ndim >= 3:
        axes = tuple(range(1, e.ndim))
        amax = jnp.max(jnp.abs(e), axis=axes, keepdims=True)
    else:
        amax = jnp.max(jnp.abs(e))
    scale = jnp.maximum(amax, 1e-30) / 127.0
    return jnp.round(e / scale) * scale


def ef_compress(grads: Any, state: EFState, *, kind: str = "int8",
                stacked: Any = None) -> Tuple[Any, EFState]:
    """Compress ``grads`` with error feedback.

    Returns ``(sent, new_state)`` where ``sent`` is what goes over the
    wire (same dtype/shape as ``grads``; apply it to the optimizer) and
    ``new_state`` carries ``(grad + residual) - sent`` to the next step.

    ``stacked`` is an optional matching tree of bools marking leaves whose
    leading axis is a stacked-layer axis (per-layer int8 grids).  Default:
    derived from the tree paths (``sharding.stacked_tree`` — the scan'd
    ``layers``/``units``/expert containers).
    """
    if kind not in EF_KINDS:
        raise ValueError(
            f"unsupported gradient compression kind {kind!r}; "
            f"supported: {EF_KINDS}")
    if kind == "none":
        return grads, state
    if stacked is None:
        stacked = stacked_tree(grads)
    err = jax.tree.map(jnp.add, grads, state.residual)
    sent = jax.tree.map(lambda e, s: _compress_leaf(e, kind, s), err,
                        stacked)
    residual = jax.tree.map(jnp.subtract, err, sent)
    return sent, EFState(residual=residual)
