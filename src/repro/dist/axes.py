"""Logical-axis registry and pattern-string activation sharding.

The ``nn``/``models`` layers annotate activations with one character per
array axis:

    'b'  — the batch-like axis: sharded over the data-parallel mesh axes
           (``("data",)`` or ``("pod", "data")`` on the multi-pod mesh)
    'm'  — a model-parallel axis (heads, hidden features): sharded over
           the tensor-parallel ``model`` mesh axis
    '.'  — replicated / unconstrained

e.g. ``constrain(x, "b.m.")`` on a ``[B, S, H, hd]`` tensor shards batch
over data and heads over model.  The launchers register the concrete mesh
axes via :func:`set_axes`; until then (and always on a single device) every
``constrain`` is an identity, so library code is importable and testable
with no mesh at all.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class AxisRegistry:
    data_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    data_size: int = 1
    model_size: int = 1


_REGISTRY = AxisRegistry()


def set_axes(data_axes: Tuple[str, ...], model_axis: str, *,
             data_size: int, model_size: int) -> None:
    """Register the logical mesh axes used by ``constrain`` patterns.

    Called by the launchers after building the mesh; axis *sizes* are
    needed so non-divisible dimensions degrade to replication instead of
    failing GSPMD propagation.
    """
    global _REGISTRY
    _REGISTRY = AxisRegistry(tuple(data_axes), model_axis,
                             int(data_size), int(model_size))


def reset_axes() -> None:
    """Back to the single-device identity state (tests)."""
    global _REGISTRY
    _REGISTRY = AxisRegistry()


def get_axes() -> AxisRegistry:
    return _REGISTRY


def get_model_size() -> int:
    """Tensor-parallel degree currently registered (1 = no TP)."""
    return _REGISTRY.model_size


def get_data_size() -> int:
    return _REGISTRY.data_size


def _spec_for(pattern: str, shape: Tuple[int, ...]) -> P:
    reg = _REGISTRY
    entries = []
    for ch, dim in zip(pattern, shape):
        if ch == "b":
            ok = reg.data_size > 1 and dim % reg.data_size == 0
            entries.append(tuple(reg.data_axes) if ok else None)
        elif ch == "m":
            ok = reg.model_size > 1 and dim % reg.model_size == 0
            entries.append(reg.model_axis if ok else None)
        elif ch == ".":
            entries.append(None)
        else:
            raise ValueError(f"bad axis char {ch!r} in pattern {pattern!r}")
    return P(*entries)


def constrain(x: jax.Array, pattern: str) -> jax.Array:
    """Apply a pattern-string sharding constraint; identity on 1 device.

    ``pattern`` has one character per axis of ``x`` (see module docstring).
    """
    if len(pattern) != x.ndim:
        raise ValueError(f"pattern {pattern!r} has {len(pattern)} axes, "
                         f"array has {x.ndim} ({x.shape})")
    bad = set(pattern) - set("bm.")
    if bad:
        raise ValueError(f"bad axis chars {sorted(bad)!r} in {pattern!r}")
    reg = _REGISTRY
    if reg.data_size * reg.model_size <= 1:
        return x
    return jax.lax.with_sharding_constraint(x, _spec_for(pattern, x.shape))
