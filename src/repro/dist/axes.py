"""Logical-axis registry and pattern-string activation sharding.

The ``nn``/``models`` layers annotate activations with one character per
array axis:

    'b'  — the batch-like axis: sharded over the data-parallel mesh axes
           (``("data",)`` or ``("pod", "data")`` on the multi-pod mesh)
    'm'  — a model-parallel axis (heads, hidden features): sharded over
           the tensor-parallel ``model`` mesh axis
    '.'  — replicated / unconstrained

e.g. ``constrain(x, "b.m.")`` on a ``[B, S, H, hd]`` tensor shards batch
over data and heads over model.  The registry a ``constrain`` call reads
is *scoped*, not global: a :class:`repro.api.RunContext` activates its
:class:`AxisRegistry` (built from the run's ``MeshSpec``) around every
trace via :func:`axis_scope`, so two contexts with different meshes
coexist in one process.  Outside any scope the immutable default applies
(single-device identity), so library code is importable and testable with
no mesh at all.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
from jax.sharding import PartitionSpec as P

from .scope import Scoped


@dataclasses.dataclass(frozen=True)
class AxisRegistry:
    data_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    data_size: int = 1
    model_size: int = 1


_AXES: Scoped[AxisRegistry] = Scoped("repro.dist.axes", AxisRegistry())


def axis_scope(registry: AxisRegistry):
    """Context manager: trace the enclosed computation under ``registry``
    (re-entrant; restores the previous registry on exit).  This is how
    ``repro.api.RunContext`` binds a mesh's logical axes with no global
    state."""
    return _AXES.scope(registry)


def reset_axes() -> None:
    """Back to the single-device identity default (tests)."""
    _AXES.reset_default()


def get_axes() -> AxisRegistry:
    return _AXES.get()


def get_model_size() -> int:
    """Tensor-parallel degree currently in scope (1 = no TP)."""
    return _AXES.get().model_size


def get_data_size() -> int:
    return _AXES.get().data_size


def registry_for_mesh(mesh) -> AxisRegistry:
    """The :class:`AxisRegistry` describing a concrete mesh (pod is outer
    data parallelism; the axis whitelist lives in ``sharding``)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    daxes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dsize = 1
    for a in daxes:
        dsize *= sizes[a]
    return AxisRegistry(daxes or ("data",), "model", dsize,
                        int(sizes.get("model", 1)))


def _spec_for(pattern: str, shape: Tuple[int, ...]) -> P:
    reg = _AXES.get()
    entries = []
    for ch, dim in zip(pattern, shape):
        if ch == "b":
            ok = reg.data_size > 1 and dim % reg.data_size == 0
            entries.append(tuple(reg.data_axes) if ok else None)
        elif ch == "m":
            ok = reg.model_size > 1 and dim % reg.model_size == 0
            entries.append(reg.model_axis if ok else None)
        elif ch == ".":
            entries.append(None)
        else:
            raise ValueError(f"bad axis char {ch!r} in pattern {pattern!r}")
    return P(*entries)


def constrain(x: jax.Array, pattern: str) -> jax.Array:
    """Apply a pattern-string sharding constraint; identity on 1 device.

    ``pattern`` has one character per axis of ``x`` (see module docstring).
    """
    if len(pattern) != x.ndim:
        raise ValueError(f"pattern {pattern!r} has {len(pattern)} axes, "
                         f"array has {x.ndim} ({x.shape})")
    bad = set(pattern) - set("bm.")
    if bad:
        raise ValueError(f"bad axis chars {sorted(bad)!r} in {pattern!r}")
    reg = _AXES.get()
    if reg.data_size * reg.model_size <= 1:
        return x
    return jax.lax.with_sharding_constraint(x, _spec_for(pattern, x.shape))
