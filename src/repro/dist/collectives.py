"""Compressed data-parallel gradient collectives: the reduction itself
moves int8 (or bf16) bytes, not fp32.

``dist.ef_compress`` quantizes the *synchronized* gradient — it bounds
update noise but every fp32 byte still crosses the wire first.  This
module compresses **inside** the reduction, DeepSpeed/1-bit-Adam style,
with error feedback on both phases:

phase 1 (reduce-scatter as ``all_to_all``)
    Each data shard quantizes its local ``grad + residual`` to int8
    mantissas on a per-layer power-of-two grid ``2^-f`` (the exponent comes
    from :func:`repro.kernels.qmatmul.ops.grid_exponent`, the same grid
    logic the serving weight packer uses; the leaf amax is ``pmax``-shared
    so every shard quantizes on the same grid).  The int8 chunks are
    exchanged with ``lax.all_to_all`` and summed as int32 — exact, since
    ``n * 127`` fits comfortably.

phase 2 (``all_gather``)
    The chunk owner re-quantizes the int32 chunk sum back to int8 by a
    static right-shift of ``ceil(log2 n)`` bits and gathers the int8 sums;
    the shift remainder (phase-2 error) is scattered into the owner's
    residual, so the time-averaged delivered mean gradient telescopes to
    the true mean exactly like single-phase error feedback (see
    ``tests/test_collectives.py``).

Per-device bytes on the wire per gradient element: ``2 * (n-1)/n`` at 1
byte (int8) vs ``2 * (n-1)/n`` at 4 bytes for a ring fp32 all-reduce — a
4x reduction, independent of ``n`` (bf16-wire: 2x).  The per-leaf scale
exponents add one ``pmax`` float per layer, which the byte accounting
includes.

The public entry :func:`ef_wire_pmean` runs under ``shard_map`` over the
mesh's data axes (``model`` stays unmapped: every tensor-parallel shard
carries the replicated gradient, exactly as in the uncompressed step) and
is wrapped in ``jax.custom_vjp`` — the forward is the compressed mean
all-reduce, the backward passes cotangents through like the transpose of
``pmean`` — so it composes under ``jax.value_and_grad`` even though the
quantization ops themselves have no useful derivative.

``simulate_wire_pmean`` is the collective-free reference: identical
per-shard math on a stacked ``[n, ...]`` tree, used by single-device
tests and by the property tests; the 8-device CI job checks the
``shard_map`` path agrees with it bit-for-bit.

Two execution strategies share this math (``fused=True`` default):

fused / pipelined (the wall-clock fast path)
    One amax ``pmax`` for the whole tree, quantize/pack/decode routed
    through the ``kernels.wire_pack`` fused kernels, and the leaves
    exchanged in size-bucketed column-concatenated buffers — bucket k+1
    compresses while bucket k is in ``all_to_all`` (double-buffered
    program order), collapsing ~3 collectives *per leaf* into ~3 per
    bucket.  Bit-for-bit the per-leaf path: ``pmax`` is elementwise, so
    pmax(concat) == concat(pmax); the collectives act on axis 0, so
    column concatenation commutes with them; decode and residual math
    never change.

per-leaf (``fused=False``)
    The original one-collective-set-per-leaf trace, kept as the
    executable reference the fused path is tested against.

:func:`ef_wire_pmean_2d` (below) is the 2D generalization: the exchange
is additionally sliced over the tensor-parallel ``model`` axis, so each
(data, model) device reduces only its 1/(D*M) slice and the model-axis
replication moves int8 instead of fp32 — see the section comment above
it for the full layout.
"""
from __future__ import annotations

from functools import partial
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from ..core.plan import NIBBLE_BITS
from .scope import Scoped

WIRE_KINDS = ("int8", "bf16")

# fused-path bucket budget: wire payload bytes per pipelined exchange
# buffer.  Big enough that a smoke-scale tree rides one buffer (minimum
# launch count), small enough that real models get >= 2 buckets and the
# compress/exchange overlap; tests force tiny budgets to exercise the
# multi-bucket pipeline.
_WIRE_BUCKET_BYTES = 1 << 20

# trace-time recorder for bytes-on-wire accounting (collectives_bench):
# shapes are static, so appending (op, per-device bytes) while tracing
# measures exactly what the compiled collectives move.  Scoped, not a
# module global — see dist.scope.
_BYTES_TRACE: Scoped[Optional[List[Tuple[str, float]]]] = Scoped(
    "repro.dist.wire_bytes", None)


class record_wire_bytes:
    """Context manager: collect (op, per-device payload bytes) tuples for
    every collective issued while tracing inside the block."""

    def __init__(self):
        self.records: List[Tuple[str, float]] = []
        self._cm = None

    def __enter__(self):
        self._cm = _BYTES_TRACE.scope(self.records)
        self._cm.__enter__()
        return self

    def __exit__(self, *exc):
        cm, self._cm = self._cm, None
        return cm.__exit__(*exc)

    def total(self) -> float:
        return sum(b for _, b in self.records)


def _record(op: str, nbytes: float) -> None:
    records = _BYTES_TRACE.get()
    if records is not None:
        records.append((op, float(nbytes)))


def _ring_allreduce_bytes(nbytes: float, n: int) -> float:
    return 2.0 * (n - 1) / n * nbytes


def data_axis_names(mesh) -> Tuple[str, ...]:
    """The data-parallel axis names of ``mesh`` (pod is outer DP; the axis
    whitelist lives once, in ``sharding``)."""
    from .sharding import _data_axes
    return _data_axes(mesh)


def data_axis_size(mesh) -> int:
    from .sharding import _data_size
    return _data_size(mesh)


# ---------------------------------------------------------------------------
# per-shard quantization (pure; shared by the shard_map body, the simulator,
# and the tests)
# ---------------------------------------------------------------------------

def _stacked_flags(tree: Any, stacked: Any) -> Tuple[bool, ...]:
    """Per-leaf stacked-layer flags in ``jax.tree.flatten`` order.

    ``stacked`` is an optional matching tree of bools; ``None`` derives
    the flags from the tree paths (``sharding.stacked_tree`` — the same
    explicit rule ``dist.ef_compress`` uses, replacing the old rank
    sniff)."""
    from .sharding import stacked_tree
    marks = stacked_tree(tree) if stacked is None else stacked
    return tuple(bool(m) for m in jax.tree.leaves(marks))


def _width_flags(tree: Any, widths: Any) -> Tuple[int, ...]:
    """Per-leaf wire widths (static python ints) in ``jax.tree.flatten``
    order.  ``widths`` is an optional matching tree of ints — what
    ``core.plan.PrecisionPlan.wire_bits_tree`` produces; ``None`` means
    uniform int8, the exact legacy trace."""
    if widths is None:
        return tuple(8 for _ in jax.tree.leaves(tree))
    vals = tuple(int(w) for w in jax.tree.leaves(widths))
    for w in vals:
        if not 2 <= w <= 8:
            raise ValueError(f"wire width must be in [2, 8], got {w!r}")
    return vals


def _nibble_wire(kind: str, bits: int) -> bool:
    """True when this leaf's payload rides nibble-packed int4 bytes.
    Static (python bool), so bits == 8 traces the identical legacy graph."""
    return kind == "int8" and bits <= NIBBLE_BITS


def _layer_rows(e: jax.Array, stacked: bool) -> jax.Array:
    """Flatten a leaf to [L, P] rows — one quantization grid per leading
    (stacked-layer) axis entry for stacked rank >= 3 leaves, one per
    tensor otherwise (same stacked-leaf rule as ``dist._compress_leaf``;
    ``stacked`` comes from the tree path, not the rank)."""
    L = e.shape[0] if (stacked and e.ndim >= 3) else 1
    return jnp.asarray(e, jnp.float32).reshape(L, -1)


def _phase1_quantize(e: jax.Array, amax_rows: jax.Array, kind: str,
                     stacked: bool, bits: int = 8
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Quantize one leaf for the wire.

    Returns ``(payload_rows, scale_rows, residual)``: the wire payload as
    [L, P] (``bits``-wide mantissas in int8 storage, or bf16 values with a
    dummy unit scale), the per-row grid step, and the local quantization
    error ``e - dequant``.  ``amax_rows`` is the *global* per-row amax
    (``pmax`` over shards), so every shard lands on the same grid and
    int32 chunk sums are exact.  ``bits`` comes from the leaf's
    PrecisionPlan entry (8 = legacy int8 grid; <= 4 rides nibble-packed
    bytes on the wire) and is ignored for bf16.
    """
    rows = _layer_rows(e, stacked)
    if kind == "bf16":
        payload = rows.astype(jnp.bfloat16)
        deq = payload.astype(jnp.float32)
        scale = jnp.ones((rows.shape[0],), jnp.float32)
        residual = (jnp.asarray(e, jnp.float32)
                    - deq.astype(jnp.float32).reshape(e.shape))
        return payload, scale, residual
    from ..kernels import wire_pack
    payload, scale, res_rows = wire_pack.quantize_leaf(rows, amax_rows,
                                                       bits)
    return payload, scale, res_rows.reshape(e.shape)


def _phase2_requantize(chunk_sum: jax.Array, n: int, kind: str
                       ) -> Tuple[jax.Array, jax.Array]:
    """Requantize a chunk of summed phase-1 payloads for the all_gather.

    int8: the int32 mantissa sum (|sum| <= n*127) shifts right by
    ``k = ceil(log2 n)`` so it fits int8 again; the remainder (in mantissa
    units) is the phase-2 error the chunk owner keeps.  bf16: round the
    fp32 sum to bf16, keep the rounding error.
    """
    if kind == "bf16":
        payload = chunk_sum.astype(jnp.bfloat16)
        return payload, chunk_sum - payload.astype(jnp.float32)
    k = _phase2_shift(n)
    m2 = jnp.round(chunk_sum.astype(jnp.float32) / (2 ** k)).astype(jnp.int32)
    err = (chunk_sum - m2 * (2 ** k)).astype(jnp.float32)
    return m2.astype(jnp.int8), err


def _phase2_shift(n: int) -> int:
    """The decode side multiplies by exactly this power of two — keep the
    encode/decode shift one definition.

    Width-independent by construction: with ``k = ceil(log2 n)`` the
    requantized sum satisfies ``|round(sum / 2^k)| <= round(n * qmax /
    2^k) <= qmax`` for ANY phase-1 grid width (``2^k >= n``), so mixed
    int4/int8 leaves share this one shift and phase-2 payloads always fit
    back into their phase-1 width (tests/test_collectives.py pins this
    for w=4)."""
    return max((n - 1).bit_length(), 0)


# ---------------------------------------------------------------------------
# the shard_map body (one leaf at a time)
# ---------------------------------------------------------------------------

def _wire_leaf(e: jax.Array, axes: Tuple[str, ...], n: int, kind: str,
               stacked: bool, bits: int = 8
               ) -> Tuple[jax.Array, jax.Array]:
    """Compressed mean-reduce of one per-shard leaf inside shard_map.

    ``e`` is this shard's ``grad + residual`` (leading shard axis of size 1
    already squeezed).  Returns ``(delivered_mean, new_residual)``.
    ``bits`` is the leaf's plan wire width; <= 4 nibble-packs the payload
    around each collective (chunk length, scales, and residual layout are
    untouched — only the bytes on the wire halve).
    """
    dtype = e.dtype
    rows = _layer_rows(e, stacked)
    L, Pn = rows.shape
    amax = None
    if kind != "bf16":     # bf16 payloads carry their own exponents
        amax = jax.lax.pmax(jnp.max(jnp.abs(rows), axis=1), axes)
        _record("pmax.scale", _ring_allreduce_bytes(L * 4, n))
    payload, scale, residual = _phase1_quantize(e, amax, kind, stacked,
                                                bits)

    flat = payload.reshape(-1)
    T = flat.shape[0]
    C = -(-T // n)
    flat = jnp.pad(flat, (0, n * C - T))
    # per-position grid steps, padded the same way (bf16 rows share scale 1)
    s_flat = jnp.pad(jnp.broadcast_to(scale[:, None], (L, Pn)).reshape(-1),
                     (0, n * C - T), constant_values=1.0)

    nib = _nibble_wire(kind, bits)
    wtag = "int4" if nib else kind

    # phase 1: reduce-scatter as all_to_all of the compressed chunks
    # (nibble wires pack two mantissas per byte around the collective;
    # each chunk packs independently so nibbles never straddle chunks)
    if nib:
        from ..kernels.qmatmul.ops import pack_nibbles, unpack_nibbles
        pk = pack_nibbles(flat.reshape(n, C), axis=-1)
        _record(f"all_to_all.{wtag}",
                (n - 1) / n * (n * pk.shape[-1]) * pk.dtype.itemsize)
        ex = unpack_nibbles(
            jax.lax.all_to_all(pk, axes, 0, 0, tiled=False), C, axis=-1)
    else:
        _record(f"all_to_all.{wtag}",
                (n - 1) / n * (n * C) * flat.dtype.itemsize)
        ex = jax.lax.all_to_all(flat.reshape(n, C), axes, 0, 0, tiled=False)
    chunk_sum = jnp.sum(ex.astype(jnp.float32 if kind == "bf16"
                                  else jnp.int32), axis=0)

    # phase 2: requantize the sum, gather, decode once (the shift keeps
    # phase-2 mantissas inside the phase-1 width — see _phase2_shift)
    q2, err2 = _phase2_requantize(chunk_sum, n, kind)
    if nib:
        q2p = pack_nibbles(q2, axis=-1)
        _record(f"all_gather.{wtag}",
                (n - 1) * q2p.shape[0] * q2p.dtype.itemsize)
        full = unpack_nibbles(
            jax.lax.all_gather(q2p, axes, axis=0, tiled=False),
            C, axis=-1).reshape(-1)
    else:
        _record(f"all_gather.{wtag}", (n - 1) * C * q2.dtype.itemsize)
        full = jax.lax.all_gather(q2, axes, axis=0, tiled=False).reshape(-1)
    if kind == "bf16":
        delivered_flat = full.astype(jnp.float32) / n
        err2_val = err2  # value domain; carried in full so delivery /n
        #                  next step recovers exactly what was withheld
    else:
        delivered_flat = (full.astype(jnp.float32) * (2 ** _phase2_shift(n))
                          * s_flat / n)
        err2_val = err2  # mantissa units; scaled to values below
    delivered = delivered_flat[:T].reshape(e.shape).astype(dtype)

    # error feedback for phase 2: the owner of chunk i carries the shift
    # remainder forward — next step it is re-quantized and delivered,
    # so the time-averaged delivered mean telescopes exactly
    idx = jnp.int32(0)
    for ax in axes:
        idx = idx * jax.lax.psum(1, ax) + jax.lax.axis_index(ax)
    if kind != "bf16":
        own_scale = jax.lax.dynamic_slice(s_flat, (idx * C,), (C,))
        err2_val = err2_val * own_scale
    scatter = jax.lax.dynamic_update_slice(
        jnp.zeros((n * C,), jnp.float32), err2_val, (idx * C,))[:T]
    new_residual = (residual + scatter.reshape(e.shape)).astype(dtype)
    return delivered, new_residual


# ---------------------------------------------------------------------------
# fused / pipelined tree-level exchange
# ---------------------------------------------------------------------------

def _bucket_leaves(byte_sizes, bucket_bytes: int):
    """Greedy size-bucketed partition of leaf indices, largest first:
    each bucket's wire payload stays under ``bucket_bytes`` (a single
    oversized leaf gets its own bucket).  Deterministic in the leaf
    order, so the fused trace is stable across runs."""
    order = sorted(range(len(byte_sizes)),
                   key=lambda i: (-byte_sizes[i], i))
    buckets, cur, acc = [], [], 0.0
    for i in order:
        if cur and acc + byte_sizes[i] > bucket_bytes:
            buckets.append(cur)
            cur, acc = [], 0.0
        cur.append(i)
        acc += byte_sizes[i]
    if cur:
        buckets.append(cur)
    return buckets


def _pipelined_collective(buckets, build, collective):
    """Double-buffered bucket pipeline: bucket k's collective is issued
    BEFORE bucket k+1's payload is built, so program order lets an async
    backend overlap compression with the bytes in flight (and even a
    synchronous backend pays ~#buckets collective launches instead of
    one per leaf)."""
    if not buckets:
        return []
    outs = [None] * len(buckets)
    pending = build(0)
    for b in range(len(buckets)):
        inflight = collective(pending)
        if b + 1 < len(buckets):
            pending = build(b + 1)
        outs[b] = inflight
    return outs


def _split_cols(buf, idxs, cols, axis):
    """Undo a column concatenation: static per-leaf slices of ``buf``."""
    out = {}
    off = 0
    for i in idxs:
        out[i] = jax.lax.slice_in_dim(buf, off, off + cols[i], axis=axis)
        off += cols[i]
    return out


def _wire_tree_fused(flat: List[jax.Array], axes: Tuple[str, ...], n: int,
                     kind: str, flags: Tuple[bool, ...],
                     widths: Tuple[int, ...], bucket_bytes: int
                     ) -> List[Tuple[jax.Array, jax.Array]]:
    """Fused/pipelined twin of mapping :func:`_wire_leaf` over a tree.

    One amax ``pmax`` covers every leaf (pmax is elementwise, so the
    pmax of the concatenated amax rows equals the concatenation of the
    per-leaf pmaxes), quantize/pack/decode run through the
    ``kernels.wire_pack`` fused kernels, and both exchange phases move
    size-bucketed buffers of column-concatenated leaf chunks — the
    collectives act on axis 0, so splitting columns after the exchange
    reproduces every per-leaf result exactly.  Byte records keep the
    per-leaf legacy tags and values: their totals ARE the fused
    buffers' bytes (tests pin both the equality with the per-leaf path
    and the recorded totals).
    """
    from ..kernels import wire_pack as wp
    from ..kernels.qmatmul.ops import unpack_nibbles
    N = len(flat)
    f32 = [jnp.asarray(e, jnp.float32) for e in flat]
    rows = [_layer_rows(e, st) for e, st in zip(f32, flags)]
    dims = []
    for r in rows:
        L, Pn = r.shape
        T = L * Pn
        dims.append((L, Pn, T, -(-T // n)))
    nibs = [_nibble_wire(kind, b) for b in widths]
    # nibble leaves pre-pad their chunk columns to EVEN with a zero
    # mantissa on scale 1 — the very zero nibble pack_nibbles would add —
    # so packing the column-concatenated bucket equals concatenating the
    # per-leaf packs (no pair straddles a leaf boundary)
    ceven = [(-(-C // 2) * 2 if nib else C)
             for (_, _, _, C), nib in zip(dims, nibs)]
    cols = [(ce // 2 if nib else ce) for ce, nib in zip(ceven, nibs)]
    item = 2 if kind == "bf16" else 1
    # width-homogeneous buckets: one saturating clip bound (and one
    # nibble flag) per bucket, so each bucket quantizes, requantizes and
    # decodes in a SINGLE fused elementwise chain over its concatenated
    # buffer — per-leaf work shrinks to pad/reshape/slice
    classes: dict = {}
    for i in range(N):
        classes.setdefault(widths[i] if kind != "bf16" else 0,
                           []).append(i)
    buckets = []
    for key in sorted(classes):
        idxs = classes[key]
        for b in _bucket_leaves([n * cols[i] * item for i in idxs],
                                bucket_bytes):
            buckets.append([idxs[j] for j in b])

    amaxes: List[Optional[jax.Array]] = [None] * N
    if kind != "bf16":
        gmax = jax.lax.pmax(
            jnp.concatenate([jnp.max(jnp.abs(r), axis=1) for r in rows]),
            axes)
        off = 0
        for i, (L, _, _, _) in enumerate(dims):
            amaxes[i] = jax.lax.slice_in_dim(gmax, off, off + L)
            off += L
            _record("pmax.scale", _ring_allreduce_bytes(L * 4, n))

    def chunked(i):
        """One leaf's (values, scales) in padded chunk layout [n, ceven]
        — positionwise identical to the rows layout, chunk row d = the
        slice shard d will own."""
        L, Pn, T, C = dims[i]
        e = jnp.pad(rows[i].reshape(-1), (0, n * C - T)).reshape(n, C)
        if ceven[i] != C:
            e = jnp.pad(e, ((0, 0), (0, ceven[i] - C)))
        if kind == "bf16":
            return e, None
        s = jnp.pad(
            jnp.broadcast_to(wp.grid_scale(amaxes[i], widths[i])[:, None],
                             (L, Pn)).reshape(-1),
            (0, n * C - T), constant_values=1.0).reshape(n, C)
        if ceven[i] != C:
            s = jnp.pad(s, ((0, 0), (0, ceven[i] - C)),
                        constant_values=1.0)
        return e, s

    bstate: List[Any] = [None] * len(buckets)

    def compress(b):
        idxs = buckets[b]
        pieces = [chunked(i) for i in idxs]
        E = jnp.concatenate([p[0] for p in pieces], axis=1)
        if kind == "bf16":
            payload = E.astype(jnp.bfloat16)
            S, R = None, E - payload.astype(jnp.float32)
        else:
            S = jnp.concatenate([p[1] for p in pieces], axis=1)
            payload, R = wp.quantize_chunks(E, S, widths[idxs[0]])
        bstate[b] = (S, R)
        for i in idxs:
            _record(f"all_to_all.{'int4' if nibs[i] else kind}",
                    (n - 1) / n * (n * cols[i]) * item)
        if nibs[idxs[0]]:
            payload = wp.pack_chunks(payload)
        return payload

    a2a = _pipelined_collective(
        buckets, compress,
        lambda x: jax.lax.all_to_all(x, axes, 0, 0, tiled=False))

    err2c: List[Any] = [None] * len(buckets)

    def requant(b):
        idxs = buckets[b]
        x = a2a[b]
        if nibs[idxs[0]]:
            x = unpack_nibbles(x, sum(ceven[i] for i in idxs), axis=-1)
        chunk_sum = jnp.sum(x.astype(jnp.float32 if kind == "bf16"
                                     else jnp.int32), axis=0)
        q2, err2c[b] = _phase2_requantize(chunk_sum, n, kind)
        if nibs[idxs[0]]:
            q2 = wp.pack_chunks(q2)
        for i in idxs:
            _record(f"all_gather.{'int4' if nibs[i] else kind}",
                    (n - 1) * cols[i] * q2.dtype.itemsize)
        return q2

    gath = _pipelined_collective(
        buckets, requant,
        lambda x: jax.lax.all_gather(x, axes, axis=0, tiled=False))

    idx = jnp.int32(0)
    for ax in axes:
        idx = idx * jax.lax.psum(1, ax) + jax.lax.axis_index(ax)

    out: List[Any] = [None] * N
    for b, idxs in enumerate(buckets):
        f = gath[b]
        if nibs[idxs[0]]:
            f = unpack_nibbles(f, sum(ceven[i] for i in idxs), axis=-1)
        S, R = bstate[b]
        if kind == "bf16":
            dcat = f.astype(jnp.float32) / n
            ecat = err2c[b]
        else:
            dcat = wp.dequant_sum(f, S, _phase2_shift(n), n)
            ecat = err2c[b] * jax.lax.dynamic_slice_in_dim(
                S, idx, 1, axis=0)[0]
        off = 0
        for i in idxs:
            _, _, T, C = dims[i]
            e = flat[i]
            ce = ceven[i]
            d = jax.lax.slice_in_dim(dcat, off, off + ce, axis=1)[:, :C]
            delivered = d.reshape(-1)[:T].reshape(e.shape).astype(e.dtype)
            residual = jax.lax.slice_in_dim(
                R, off, off + ce, axis=1)[:, :C].reshape(-1)[:T] \
                .reshape(e.shape)
            ev = jax.lax.slice_in_dim(ecat, off, off + ce, axis=0)[:C]
            scatter = jax.lax.dynamic_update_slice(
                jnp.zeros((n * C,), jnp.float32), ev, (idx * C,))[:T]
            out[i] = (delivered,
                      (residual + scatter.reshape(e.shape)).astype(e.dtype))
            off += ce
    return out


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def ef_wire_init(grads: Any, n_data: int) -> Any:
    """Zero per-shard residual tree: each leaf gains a leading ``[n_data]``
    shard axis (sharded over the data axes by
    ``sharding.ef_residual_sharding``)."""
    return jax.tree.map(
        lambda g: jnp.zeros((n_data,) + tuple(g.shape), g.dtype), grads)


def _check_kind(kind: str) -> None:
    if kind not in WIRE_KINDS:
        raise ValueError(f"unsupported wire compression kind {kind!r}; "
                         f"supported: {WIRE_KINDS}")


def _wire_pmean_impl(e_stacked: Any, mesh, kind: str,
                     flags: Tuple[bool, ...],
                     widths: Tuple[int, ...], fused: bool = True,
                     bucket_bytes: int = _WIRE_BUCKET_BYTES
                     ) -> Tuple[Any, Any]:
    axes = data_axis_names(mesh)
    n = data_axis_size(mesh)

    def body(tree):
        flat, treedef = jax.tree.flatten(tree)
        squeezed = [leaf[0] for leaf in flat]
        if fused:
            pairs = _wire_tree_fused(squeezed, axes, n, kind, flags,
                                     widths, bucket_bytes)
        else:
            pairs = [_wire_leaf(leaf, axes, n, kind, st, b)
                     for leaf, st, b in zip(squeezed, flags, widths)]
        delivered = jax.tree.unflatten(treedef, [d for d, _ in pairs])
        residual = jax.tree.unflatten(treedef, [r[None] for _, r in pairs])
        return delivered, residual

    stack_spec = jax.tree.map(
        lambda leaf: P(axes, *([None] * (leaf.ndim - 1))), e_stacked)
    plain_spec = jax.tree.map(
        lambda leaf: P(*([None] * (leaf.ndim - 1))), e_stacked)
    return shard_map(body, mesh=mesh, in_specs=(stack_spec,),
                     out_specs=(plain_spec, stack_spec),
                     check_rep=False)(e_stacked)


@partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4, 5, 6))
def _ef_wire_pmean_cv(e_stacked: Any, mesh, kind: str,
                      flags: Tuple[bool, ...],
                      widths: Tuple[int, ...], fused: bool,
                      bucket_bytes: int) -> Tuple[Any, Any]:
    return _wire_pmean_impl(e_stacked, mesh, kind, flags, widths, fused,
                            bucket_bytes)


def _ef_wire_fwd(e_stacked, mesh, kind, flags, widths, fused,
                 bucket_bytes):
    return _ef_wire_pmean_cv(e_stacked, mesh, kind, flags, widths, fused,
                             bucket_bytes), None


def _ef_wire_bwd(mesh, kind, flags, widths, fused, bucket_bytes, _res,
                 cts):
    ct_delivered, _ct_residual = cts
    n = data_axis_size(mesh)
    ct_e = jax.tree.map(
        lambda ct: jnp.broadcast_to(ct[None] / n, (n,) + tuple(ct.shape)),
        ct_delivered)
    return (ct_e,)


_ef_wire_pmean_cv.defvjp(_ef_wire_fwd, _ef_wire_bwd)


def ef_wire_pmean(e_stacked: Any, mesh, kind: str = "int8",
                  stacked: Any = None, widths: Any = None,
                  fused: bool = True,
                  bucket_bytes: Optional[int] = None) -> Tuple[Any, Any]:
    """Compressed mean all-reduce with error feedback, inside the wire.

    ``e_stacked`` is a pytree whose leaves carry a leading ``[n_data]``
    shard axis holding each data shard's ``local_grad + residual``
    (sharded over the data axes).  Returns ``(delivered, new_residual)``:
    the int8/bf16-wire mean gradient, replicated, plus the per-shard
    residual to thread into the next step.

    ``stacked`` optionally marks stacked-layer leaves (a matching bool
    tree) for per-layer quantization grids; default derives it from the
    tree paths, like ``dist.ef_compress``.  ``widths`` optionally carries
    per-leaf wire widths (a matching int tree, e.g. from
    ``core.plan.PrecisionPlan.wire_bits_tree``); ``None`` is uniform int8
    — the exact legacy trace.  Widths <= 4 ride nibble-packed int4 bytes.

    ``fused`` (default) runs the pipelined tree-level exchange —
    bit-for-bit the per-leaf trace, with quantize/pack fused into the
    ``kernels.wire_pack`` kernels and the leaves bucketed so compression
    of bucket k+1 overlaps bucket k's collective; ``fused=False`` keeps
    the original one-collective-set-per-leaf reference.  ``bucket_bytes``
    overrides the pipeline bucket budget (mainly for tests).

    The custom VJP passes the ``delivered`` cotangent through as the
    transpose of an uncompressed shard mean, so the backward of a loss
    containing this collective is unchanged and ``jax.value_and_grad``
    composes; residual cotangents are dropped (state, not value).
    """
    _check_kind(kind)
    bb = _WIRE_BUCKET_BYTES if bucket_bytes is None else int(bucket_bytes)
    return _ef_wire_pmean_cv(e_stacked, mesh, kind,
                             _stacked_flags(e_stacked, stacked),
                             _width_flags(e_stacked, widths),
                             bool(fused), bb)


# ---------------------------------------------------------------------------
# 2D (data x model) sliced wire collective
# ---------------------------------------------------------------------------
#
# The 1D collective above replicates over the model axis: every TP shard
# exchanges and reduces the FULL gradient (and, under TP, first pays an
# fp32 all_gather over `model` to rematerialize it, since gradients of
# model-sharded parameters arrive model-sharded).  The 2D path slices the
# exchange over `model` too:
#
#   * gradients ENTER model-sharded (per-leaf in_specs reuse the exact
#     `sharding.model_axis_for` placement rule, so no model-axis gather is
#     emitted at all); leaves that do not shard over `model` are flat-chunk
#     sliced by model index instead — either way device (d, m) quantizes
#     only its 1/M slice;
#   * the two-phase int8 all_to_all + all_gather reduce runs over the data
#     axes on that slice only (1/M the bytes), with the same globally
#     pmax-shared per-row 2^-f grids — the pmax now spans BOTH axes;
#   * one int8 all_gather over `model` rematerializes each TP shard's full
#     delivered gradient (int8 sums decode once, after the gather), so the
#     model-axis replication that used to move fp32 now moves int8;
#   * error-feedback residuals live in the sliced layout: a stacked
#     [n_data, n_model, C] flat tree (`ef_wire2d_init`), sharded so device
#     (d, m) keeps exactly its own slice (`sharding.ef_residual_sharding`
#     with layout="2d").  Both phase errors stay within the slice, so the
#     time-averaged delivered mean telescopes exactly as in 1D.
#
# Per-device payload bytes per gradient element (D data x M model):
#   1D:  (M-1)/M * 4 (fp32 model ag)  +  2 (D-1)/D * 1   (int8 data phases)
#   2D:  2 (D-1)/(D*M) * 1            +  (M-1)/M * 1     (int8 model ag)
# e.g. on a 2x4 mesh: 4.0 B/elt -> 1.0 B/elt.


def _wire2d_model_axes(mesh) -> Tuple[str, ...]:
    return ("model",) if "model" in mesh.axis_names else ()


def model_axis_size(mesh) -> int:
    """Size of the mesh's tensor-parallel ``model`` axis (1 if absent)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return int(sizes.get("model", 1))


def _prod(shape) -> int:
    out = 1
    for s in shape:
        out *= int(s)
    return out


def wire2d_slice_len(shape, n_data: int, n_model: int) -> int:
    """Padded flat slice length ``C`` each ``(data, model)`` device owns
    for a leaf of ``shape``: the model block (when the leaf shards over
    ``model`` per :func:`repro.dist.sharding.model_axis_for`) or the
    ceil-div flat slice, padded up to a multiple of ``n_data`` so the data
    all_to_all chunks evenly."""
    from .sharding import model_axis_for
    T = _prod(shape)
    if model_axis_for(shape, n_model) is not None:
        Tb = T // n_model
    else:
        Tb = -(-T // n_model)
    return n_data * (-(-Tb // n_data))


def ef_wire2d_init(grads: Any, n_data: int, n_model: int) -> Any:
    """Zero residual tree in the 2D sliced layout: each leaf becomes a
    flat ``[n_data, n_model, C]`` stack (``C`` from
    :func:`wire2d_slice_len`) addressable by ``(data, model)`` index —
    shard with ``sharding.ef_residual_sharding(..., layout='2d')``.  A
    mesh rescale changes ``C`` (or the leading axes), so a checkpointed
    residual from another mesh fails template restore loudly — callers
    warn and restart it at zero."""
    return jax.tree.map(
        lambda g: jnp.zeros(
            (n_data, n_model,
             wire2d_slice_len(g.shape, n_data, n_model)), g.dtype), grads)


def _wire2d_rows(shape, stacked: bool) -> Tuple[int, int]:
    """(L, row_len) of a leaf: one quantization row per leading
    (stacked-layer) axis entry for stacked rank >= 3 leaves, one per
    tensor otherwise — the same rule as :func:`_layer_rows`."""
    L = int(shape[0]) if (stacked and len(shape) >= 3) else 1
    return L, _prod(shape) // max(L, 1)


def _wire2d_leaf(g: jax.Array, r: jax.Array, S: Tuple[int, ...],
                 k: Optional[int], daxes: Tuple[str, ...], maxes:
                 Tuple[str, ...], D: int, M: int, kind: str, stacked: bool,
                 bits: int = 8) -> Tuple[jax.Array, jax.Array]:
    """Sliced compressed mean-reduce of one leaf inside shard_map.

    ``g`` is this device's gradient block (data axis squeezed; the model
    block when ``k`` names the model-sharded tensor axis, else the full
    leaf), ``r`` its ``[C]`` flat residual slice.  Returns
    ``(delivered_full, new_residual_slice)``.  ``bits`` is the leaf's
    plan wire width; <= 4 nibble-packs every payload (all three
    collectives) while slice/residual layouts stay unchanged.
    """
    dtype = g.dtype
    axes2d = tuple(daxes) + tuple(maxes)
    g32 = jnp.asarray(g, jnp.float32)
    L, Prow_full = _wire2d_rows(S, stacked)
    if k is not None:
        B = g.shape                      # model block; block rows keep L
        Tb = g32.size
        C = -(-Tb // D)
        Cp = D * C
        Prow = Tb // L
        sl = jnp.pad(g32.reshape(-1), (0, Cp - Tb))
        row_of = jnp.minimum(jnp.arange(Cp) // Prow, L - 1)
    else:
        T = g32.size                     # full leaf; slice by model index
        Tb = -(-T // M)
        C = -(-Tb // D)
        Cp = D * C
        flat_full = jnp.pad(g32.reshape(-1), (0, M * Cp - T))
        midx = (jax.lax.axis_index(maxes[0]) if maxes else jnp.int32(0))
        sl = jax.lax.dynamic_slice(flat_full, (midx * Cp,), (Cp,))
        pos = midx * Cp + jnp.arange(Cp)
        row_of = jnp.minimum(pos // Prow_full, L - 1)
    e = sl + jnp.asarray(r, jnp.float32)

    if kind == "bf16":
        s_sl = jnp.ones((Cp,), jnp.float32)
        payload = e.astype(jnp.bfloat16)
        deq = payload.astype(jnp.float32)
    else:
        # per-row amax of |grad + residual| over every (data, model)
        # slice: the 2D pmax makes the 2^-f grid global, so int32 chunk
        # sums stay exact and every device decodes on the same scales
        local_amax = jnp.zeros((L,), jnp.float32).at[row_of].max(jnp.abs(e))
        amax = jax.lax.pmax(local_amax, axes2d)
        _record("pmax.scale", _ring_allreduce_bytes(L * 4, D * M))
        from ..core.quantizer import _exp2i
        from ..kernels.qmatmul.ops import grid_exponent
        scale = _exp2i(-grid_exponent(amax, bits))      # [L]
        s_sl = scale[row_of]
        qmax = 2 ** (bits - 1) - 1
        payload = jnp.clip(jnp.round(e / s_sl), -qmax,
                           qmax).astype(jnp.int8)
        deq = payload.astype(jnp.float32) * s_sl
    res1 = e - deq

    nib = _nibble_wire(kind, bits)
    wtag = "int4" if nib else kind
    if nib:
        from ..kernels.qmatmul.ops import pack_nibbles, unpack_nibbles

    # phase 1: reduce-scatter the slice over data as all_to_all
    acc_t = jnp.float32 if kind == "bf16" else jnp.int32
    if D > 1:
        if nib:
            pk = pack_nibbles(payload.reshape(D, C), axis=-1)
            _record(f"all_to_all.{wtag}",
                    (D - 1) / D * (D * pk.shape[-1]) * pk.dtype.itemsize)
            ex = unpack_nibbles(
                jax.lax.all_to_all(pk, daxes, 0, 0, tiled=False),
                C, axis=-1)
        else:
            _record(f"all_to_all.{wtag}",
                    (D - 1) / D * Cp * payload.dtype.itemsize)
            ex = jax.lax.all_to_all(payload.reshape(D, C), daxes, 0, 0,
                                    tiled=False)
        chunk_sum = jnp.sum(ex.astype(acc_t), axis=0)
    else:
        chunk_sum = payload.astype(acc_t)

    # phase 2: requantize the owned chunk, gather the slice over data
    q2, err2 = _phase2_requantize(chunk_sum, D, kind)
    if D > 1:
        if nib:
            q2p = pack_nibbles(q2, axis=-1)
            _record(f"all_gather.{wtag}",
                    (D - 1) * q2p.shape[0] * q2p.dtype.itemsize)
            sl_q = unpack_nibbles(
                jax.lax.all_gather(q2p, daxes, axis=0, tiled=False),
                C, axis=-1).reshape(Cp)
        else:
            _record(f"all_gather.{wtag}", (D - 1) * C * q2.dtype.itemsize)
            sl_q = jax.lax.all_gather(q2, daxes, axis=0, tiled=False
                                      ).reshape(Cp)
    else:
        sl_q = q2.reshape(Cp)

    # phase 3: rematerialize over model — the quantized sums cross the
    # model axis, not fp32; decode once after the gather
    if maxes and M > 1:
        if nib:
            slp = pack_nibbles(sl_q, axis=-1)
            _record(f"all_gather.{wtag}.model",
                    (M - 1) * slp.shape[0] * slp.dtype.itemsize)
            gath = unpack_nibbles(
                jax.lax.all_gather(slp, maxes, axis=0, tiled=False),
                Cp, axis=-1)
        else:
            _record(f"all_gather.{wtag}.model",
                    (M - 1) * Cp * sl_q.dtype.itemsize)
            gath = jax.lax.all_gather(sl_q, maxes, axis=0, tiled=False)
    else:
        gath = sl_q[None]

    shift = 2 ** _phase2_shift(D)
    if k is not None:
        if kind == "bf16":
            dec = gath.astype(jnp.float32) / D
        else:
            dec = gath.astype(jnp.float32) * shift * s_sl[None] / D
        blocks = dec[:, :Tb].reshape((gath.shape[0],) + tuple(B))
        delivered = jnp.concatenate(
            [blocks[m] for m in range(blocks.shape[0])], axis=k)
    else:
        flat_q = gath.reshape(-1)                       # [M * Cp]
        if kind == "bf16":
            dec = flat_q.astype(jnp.float32) / D
        else:
            row_full = jnp.minimum(jnp.arange(flat_q.shape[0]) // Prow_full,
                                   L - 1)
            dec = flat_q.astype(jnp.float32) * shift * scale[row_full] / D
        delivered = dec[:_prod(S)].reshape(S)

    # phase-2 error feedback: the chunk owner keeps the shift remainder
    # inside its own slice, exactly like the 1D path
    didx = jnp.int32(0)
    for ax in daxes:
        didx = didx * jax.lax.psum(1, ax) + jax.lax.axis_index(ax)
    if kind != "bf16":
        err2_val = err2 * jax.lax.dynamic_slice(s_sl, (didx * C,), (C,))
    else:
        err2_val = err2
    new_r = res1 + jax.lax.dynamic_update_slice(
        jnp.zeros((Cp,), jnp.float32), err2_val, (didx * C,))
    return delivered.astype(dtype), new_r.astype(r.dtype)


def _wire2d_tree_fused(gflat: List[jax.Array], rflat: List[jax.Array],
                       shapes, ks, daxes: Tuple[str, ...],
                       maxes: Tuple[str, ...], D: int, M: int, kind: str,
                       flags: Tuple[bool, ...], widths: Tuple[int, ...],
                       bucket_bytes: int
                       ) -> List[Tuple[jax.Array, jax.Array]]:
    """Fused/pipelined twin of mapping :func:`_wire2d_leaf` over a tree:
    one 2D amax ``pmax`` for every leaf, wire_pack kernels for the
    elementwise stages, and all three exchanges (data all_to_all, data
    all_gather, model all_gather) pipelined over size-bucketed
    column-concatenated buffers.  Bit-for-bit the per-leaf path, by the
    same commutation arguments as :func:`_wire_tree_fused`; byte records
    keep the per-leaf legacy tags and values, including the pure-TP op
    set (no data-exchange records when D == 1)."""
    from ..kernels import wire_pack as wp
    from ..kernels.qmatmul.ops import unpack_nibbles
    axes2d = tuple(daxes) + tuple(maxes)
    N = len(gflat)
    midx = (jax.lax.axis_index(maxes[0]) if maxes else jnp.int32(0))

    info = []
    for g, r, S, k, st in zip(gflat, rflat, shapes, ks, flags):
        g32 = jnp.asarray(g, jnp.float32)
        L, Prow_full = _wire2d_rows(S, st)
        if k is not None:
            Tb = g32.size
            C = -(-Tb // D)
            Cp = D * C
            sl = jnp.pad(g32.reshape(-1), (0, Cp - Tb))
            row_of = jnp.minimum(jnp.arange(Cp) // (Tb // L), L - 1)
        else:
            T = g32.size
            Tb = -(-T // M)
            C = -(-Tb // D)
            Cp = D * C
            flat_full = jnp.pad(g32.reshape(-1), (0, M * Cp - T))
            sl = jax.lax.dynamic_slice(flat_full, (midx * Cp,), (Cp,))
            pos = midx * Cp + jnp.arange(Cp)
            row_of = jnp.minimum(pos // Prow_full, L - 1)
        info.append(dict(e=sl + jnp.asarray(r, jnp.float32), row_of=row_of,
                         L=L, Prow_full=Prow_full, C=C, Cp=Cp, Tb=Tb,
                         B=tuple(g.shape)))

    scales: List[Optional[jax.Array]] = [None] * N
    if kind != "bf16":
        gmax = jax.lax.pmax(jnp.concatenate(
            [jnp.zeros((inf["L"],), jnp.float32).at[inf["row_of"]].max(
                jnp.abs(inf["e"])) for inf in info]), axes2d)
        off = 0
        for i, inf in enumerate(info):
            L = inf["L"]
            amax = jax.lax.slice_in_dim(gmax, off, off + L)
            off += L
            _record("pmax.scale", _ring_allreduce_bytes(L * 4, D * M))
            scales[i] = wp.grid_scale(amax, widths[i])

    nibs = [_nibble_wire(kind, w) for w in widths]
    item = 2 if kind == "bf16" else 1
    cols = [(-(-inf["C"] // 2) if nib else inf["C"])
            for inf, nib in zip(info, nibs)]
    buckets = _bucket_leaves([D * c * item for c in cols], bucket_bytes)

    state: List[Any] = [None] * N
    acc_t = jnp.float32 if kind == "bf16" else jnp.int32

    def compress(i):
        """Quantize leaf i's slice -> payload [D, C] in the wire dtype."""
        inf = info[i]
        C = inf["C"]
        if kind == "bf16":
            s_sl = jnp.ones((inf["Cp"],), jnp.float32)
            payload = inf["e"].astype(jnp.bfloat16)
            res1 = inf["e"] - payload.astype(jnp.float32)
            payload = payload.reshape(D, C)
        else:
            s_sl = scales[i][inf["row_of"]]
            payload, res = wp.quantize_chunks(
                inf["e"].reshape(D, C), s_sl.reshape(D, C), widths[i])
            res1 = res.reshape(-1)
        state[i] = (s_sl, res1)
        return payload

    err2s: List[Any] = [None] * N
    slq: List[Any] = [None] * N
    if D > 1:
        def build1(b):
            parts = []
            for i in buckets[b]:
                p = compress(i)
                wtag = "int4" if nibs[i] else kind
                if nibs[i]:
                    p = wp.pack_chunks(p)
                    _record(f"all_to_all.{wtag}", (D - 1) / D
                            * (D * p.shape[-1]) * p.dtype.itemsize)
                else:
                    _record(f"all_to_all.{wtag}",
                            (D - 1) / D * info[i]["Cp"] * p.dtype.itemsize)
                parts.append(p)
            return jnp.concatenate(parts, axis=1)

        a2a = _pipelined_collective(
            buckets, build1,
            lambda x: jax.lax.all_to_all(x, daxes, 0, 0, tiled=False))
        ex: dict = {}
        for b, bucket in enumerate(buckets):
            ex.update(_split_cols(a2a[b], bucket, cols, axis=1))

        def build2(b):
            parts = []
            for i in buckets[b]:
                C = info[i]["C"]
                x = ex[i]
                if nibs[i]:
                    x = unpack_nibbles(x, C, axis=-1)
                q2, err2s[i] = _phase2_requantize(
                    jnp.sum(x.astype(acc_t), axis=0), D, kind)
                wtag = "int4" if nibs[i] else kind
                if nibs[i]:
                    q2 = wp.pack_chunks(q2)
                _record(f"all_gather.{wtag}",
                        (D - 1) * q2.shape[0] * q2.dtype.itemsize)
                parts.append(q2)
            return jnp.concatenate(parts)

        gath2 = _pipelined_collective(
            buckets, build2,
            lambda x: jax.lax.all_gather(x, daxes, axis=0, tiled=False))
        for b, bucket in enumerate(buckets):
            got = _split_cols(gath2[b], bucket, cols, axis=1)
            for i in bucket:
                f = got[i]
                if nibs[i]:
                    f = unpack_nibbles(f, info[i]["C"], axis=-1)
                slq[i] = f.reshape(info[i]["Cp"])
    else:
        for i in range(N):
            payload = compress(i)
            q2, err2s[i] = _phase2_requantize(
                payload.reshape(-1).astype(acc_t), D, kind)
            slq[i] = q2.reshape(info[i]["Cp"])

    gth: List[Any] = [None] * N
    if maxes and M > 1:
        mcols = [(-(-inf["Cp"] // 2) if nib else inf["Cp"])
                 for inf, nib in zip(info, nibs)]

        def build3(b):
            parts = []
            for i in buckets[b]:
                mg = slq[i]
                wtag = "int4" if nibs[i] else kind
                if nibs[i]:
                    mg = wp.pack_chunks(mg)
                _record(f"all_gather.{wtag}.model",
                        (M - 1) * mg.shape[0] * mg.dtype.itemsize)
                parts.append(mg)
            return jnp.concatenate(parts)

        gath3 = _pipelined_collective(
            buckets, build3,
            lambda x: jax.lax.all_gather(x, maxes, axis=0, tiled=False))
        for b, bucket in enumerate(buckets):
            got = _split_cols(gath3[b], bucket, mcols, axis=1)
            for i in bucket:
                f = got[i]
                if nibs[i]:
                    f = unpack_nibbles(f, info[i]["Cp"], axis=-1)
                gth[i] = f
    else:
        for i in range(N):
            gth[i] = slq[i][None]

    didx = jnp.int32(0)
    for ax in daxes:
        didx = didx * jax.lax.psum(1, ax) + jax.lax.axis_index(ax)

    out = []
    shift_k = _phase2_shift(D)
    for i, (g, r, S, k) in enumerate(zip(gflat, rflat, shapes, ks)):
        inf = info[i]
        s_sl, res1 = state[i]
        gath = gth[i]
        C, Cp = inf["C"], inf["Cp"]
        if k is not None:
            if kind == "bf16":
                dec = gath.astype(jnp.float32) / D
            else:
                dec = wp.dequant_sum(gath, s_sl[None], shift_k, D)
            blocks = dec[:, :inf["Tb"]].reshape(
                (gath.shape[0],) + inf["B"])
            delivered = jnp.concatenate(
                [blocks[m] for m in range(blocks.shape[0])], axis=k)
        else:
            flat_q = gath.reshape(-1)
            if kind == "bf16":
                dec = flat_q.astype(jnp.float32) / D
            else:
                row_full = jnp.minimum(
                    jnp.arange(flat_q.shape[0]) // inf["Prow_full"],
                    inf["L"] - 1)
                dec = wp.dequant_sum(flat_q, scales[i][row_full],
                                     shift_k, D)
            delivered = dec[:_prod(S)].reshape(S)
        if kind != "bf16":
            err2_val = err2s[i] * jax.lax.dynamic_slice(
                s_sl, (didx * C,), (C,))
        else:
            err2_val = err2s[i]
        new_r = res1 + jax.lax.dynamic_update_slice(
            jnp.zeros((Cp,), jnp.float32), err2_val, (didx * C,))
        out.append((delivered.astype(g.dtype), new_r.astype(r.dtype)))
    return out


def _wire2d_specs(grads_stacked: Any, mesh):
    """(grad in_specs, residual spec tree, delivered out_specs) for the 2D
    collective: gradients enter stacked ``[n_data]`` over the data axes
    AND model-sharded on their natural tensor axis, residuals in the
    ``[n_data, n_model, C]`` sliced layout, delivered replicated."""
    from .sharding import model_axis_for
    daxes = data_axis_names(mesh)
    maxes = _wire2d_model_axes(mesh)
    M = model_axis_size(mesh)
    d_entry = daxes if len(daxes) > 1 else daxes[0]

    def gspec(leaf):
        entries: list = [None] * leaf.ndim
        entries[0] = d_entry
        k = model_axis_for(leaf.shape[1:], M)
        if k is not None and maxes:
            entries[k + 1] = "model"
        return P(*entries)

    gin = jax.tree.map(gspec, grads_stacked)
    rspec = jax.tree.map(
        lambda leaf: P(d_entry, "model" if maxes else None, None),
        grads_stacked)
    dout = jax.tree.map(lambda leaf: P(*([None] * (leaf.ndim - 1))),
                        grads_stacked)
    return gin, rspec, dout


def _wire2d_impl(grads_stacked: Any, residual: Any, mesh, kind: str,
                 flags: Tuple[bool, ...],
                 widths: Tuple[int, ...], fused: bool = True,
                 bucket_bytes: int = _WIRE_BUCKET_BYTES
                 ) -> Tuple[Any, Any]:
    from .sharding import model_axis_for
    daxes = data_axis_names(mesh)
    maxes = _wire2d_model_axes(mesh)
    D = data_axis_size(mesh)
    M = model_axis_size(mesh)
    shapes = [tuple(leaf.shape[1:])
              for leaf in jax.tree.leaves(grads_stacked)]
    ks = [model_axis_for(S, M) for S in shapes]

    def body(gtree, rtree):
        gflat, treedef = jax.tree.flatten(gtree)
        rflat, _ = jax.tree.flatten(rtree)
        if fused:
            pairs = _wire2d_tree_fused(
                [g[0] for g in gflat], [r[0, 0] for r in rflat], shapes,
                ks, daxes, maxes, D, M, kind, flags, widths, bucket_bytes)
        else:
            pairs = [
                _wire2d_leaf(g[0], r[0, 0], S, kk, daxes, maxes, D, M,
                             kind, st, b)
                for g, r, S, kk, st, b in zip(gflat, rflat, shapes, ks,
                                              flags, widths)]
        delivered = jax.tree.unflatten(treedef, [d for d, _ in pairs])
        new_res = jax.tree.unflatten(treedef,
                                     [nr[None, None] for _, nr in pairs])
        return delivered, new_res

    gin, rspec, dout = _wire2d_specs(grads_stacked, mesh)
    return shard_map(body, mesh=mesh, in_specs=(gin, rspec),
                     out_specs=(dout, rspec), check_rep=False)(
                         grads_stacked, residual)


@partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5, 6, 7))
def _wire2d_cv(grads_stacked: Any, residual: Any, mesh, kind: str,
               flags: Tuple[bool, ...],
               widths: Tuple[int, ...], fused: bool,
               bucket_bytes: int) -> Tuple[Any, Any]:
    return _wire2d_impl(grads_stacked, residual, mesh, kind, flags,
                        widths, fused, bucket_bytes)


def _wire2d_fwd(grads_stacked, residual, mesh, kind, flags, widths, fused,
                bucket_bytes):
    return _wire2d_cv(grads_stacked, residual, mesh, kind, flags,
                      widths, fused, bucket_bytes), None


def _wire2d_bwd(mesh, kind, flags, widths, fused, bucket_bytes, _res,
                cts):
    ct_delivered, ct_residual = cts
    n = data_axis_size(mesh)
    ct_g = jax.tree.map(
        lambda ct: jnp.broadcast_to(ct[None] / n, (n,) + tuple(ct.shape)),
        ct_delivered)
    ct_r = jax.tree.map(jnp.zeros_like, ct_residual)
    return (ct_g, ct_r)


_wire2d_cv.defvjp(_wire2d_fwd, _wire2d_bwd)


def ef_wire_pmean_2d(grads_stacked: Any, residual: Any, mesh,
                     kind: str = "int8", stacked: Any = None,
                     widths: Any = None, fused: bool = True,
                     bucket_bytes: Optional[int] = None
                     ) -> Tuple[Any, Any]:
    """2D-sliced compressed mean all-reduce with error feedback.

    ``grads_stacked`` is a pytree whose leaves carry a leading
    ``[n_data]`` shard axis (each data shard's local gradient — NOT
    pre-added with the residual: the add happens on the slice, inside the
    collective); ``residual`` the matching ``[n_data, n_model, C]`` tree
    from :func:`ef_wire2d_init`.  Returns ``(delivered, new_residual)``:
    the int8/bf16-wire mean gradient, replicated, plus the sliced residual
    for the next step.  ``stacked`` optionally marks stacked-layer leaves
    (default: derived from the tree paths, like ``dist.ef_compress``).
    ``widths`` optionally carries per-leaf wire widths (matching int
    tree); ``None`` is uniform int8 — the exact legacy trace.

    ``fused``/``bucket_bytes`` select the pipelined tree-level exchange
    exactly as in :func:`ef_wire_pmean` (default on; bit-for-bit the
    per-leaf trace).

    The custom VJP passes the ``delivered`` cotangent through as the
    transpose of an uncompressed shard mean (``ct / n_data`` per shard);
    residual cotangents are dropped (state, not value).
    """
    _check_kind(kind)
    bb = _WIRE_BUCKET_BYTES if bucket_bytes is None else int(bucket_bytes)
    return _wire2d_cv(grads_stacked, residual, mesh, kind,
                      _stacked_flags(grads_stacked, stacked),
                      _width_flags(grads_stacked, widths),
                      bool(fused), bb)


def simulate_wire_pmean_2d(grads_stacked: Any, residual: Any, n_model: int,
                           kind: str = "int8", stacked: Any = None,
                           widths: Any = None) -> Tuple[Any, Any]:
    """Collective-free reference of :func:`ef_wire_pmean_2d` on a stacked
    ``[n_data, ...]`` gradient tree plus its ``[n_data, n_model, C]``
    residual: same slicing, same grids, same chunking, same two-phase
    errors — usable on one device.  The 8-device CI job asserts the
    shard_map path matches this bit-for-bit on 2x4 and 4x2 meshes (mixed
    widths included: nibble pack/unpack is the identity on in-range
    mantissas, so the simulator never needs to model the packing)."""
    _check_kind(kind)
    from .sharding import model_axis_for
    flags = _stacked_flags(grads_stacked, stacked)
    wflags = _width_flags(grads_stacked, widths)

    def leaf(es, res, stk, bits):
        D = es.shape[0]
        M = n_model
        S = tuple(es.shape[1:])
        dtype = es.dtype
        T = _prod(S)
        L, Prow_full = _wire2d_rows(S, stk)
        k = model_axis_for(S, M)
        Cp = res.shape[-1]
        C = Cp // D
        shift = 2 ** _phase2_shift(D)

        # per-(d, m) flat slices + row ids (identical to the shard_map body)
        slices = [[None] * M for _ in range(D)]
        rows = [None] * M
        for d in range(D):
            g32 = jnp.asarray(es[d], jnp.float32).reshape(-1)
            for m in range(M):
                if k is not None:
                    Bk = S[k] // M
                    blk = jax.lax.slice_in_dim(
                        jnp.asarray(es[d], jnp.float32), m * Bk,
                        (m + 1) * Bk, axis=k)
                    Tb = blk.size
                    slices[d][m] = jnp.pad(blk.reshape(-1), (0, Cp - Tb))
                    rows[m] = jnp.minimum(
                        jnp.arange(Cp) // (Tb // L), L - 1)
                else:
                    flat = jnp.pad(g32, (0, M * Cp - T))
                    slices[d][m] = jax.lax.dynamic_slice(
                        flat, (m * Cp,), (Cp,))
                    pos = m * Cp + jnp.arange(Cp)
                    rows[m] = jnp.minimum(pos // Prow_full, L - 1)
        es_sl = [[slices[d][m] + jnp.asarray(res[d, m], jnp.float32)
                  for m in range(M)] for d in range(D)]

        if kind != "bf16":
            local = [jnp.zeros((L,), jnp.float32).at[rows[m]].max(
                jnp.abs(es_sl[d][m])) for d in range(D) for m in range(M)]
            amax = jnp.max(jnp.stack(local), axis=0)
            from ..core.quantizer import _exp2i
            from ..kernels.qmatmul.ops import grid_exponent
            scale = _exp2i(-grid_exponent(amax, bits))
            qmax = 2 ** (bits - 1) - 1

        delivered_slices = [None] * M
        new_res = [[None] * M for _ in range(D)]
        for m in range(M):
            if kind == "bf16":
                s_sl = jnp.ones((Cp,), jnp.float32)
                payloads = [es_sl[d][m].astype(jnp.bfloat16)
                            for d in range(D)]
                deqs = [p.astype(jnp.float32) for p in payloads]
            else:
                s_sl = scale[rows[m]]
                payloads = [jnp.clip(jnp.round(es_sl[d][m] / s_sl), -qmax,
                                     qmax).astype(jnp.int8)
                            for d in range(D)]
                deqs = [p.astype(jnp.float32) * s_sl for p in payloads]
            res1 = [es_sl[d][m] - deqs[d] for d in range(D)]
            acc_t = jnp.float32 if kind == "bf16" else jnp.int32
            stacked = jnp.stack([p.reshape(D, C) for p in payloads])
            sums = jnp.sum(stacked.astype(acc_t), axis=0)     # [D, C]
            q2, err2 = _phase2_requantize(sums, D, kind)
            sl_q = q2.reshape(Cp)
            if kind == "bf16":
                delivered_slices[m] = sl_q.astype(jnp.float32) / D
            else:
                delivered_slices[m] = (sl_q.astype(jnp.float32) * shift
                                       * s_sl / D)
            for d in range(D):
                if kind != "bf16":
                    err_val = err2[d] * jax.lax.dynamic_slice(
                        s_sl, (d * C,), (C,))
                else:
                    err_val = err2[d]
                new_res[d][m] = (res1[d] + jax.lax.dynamic_update_slice(
                    jnp.zeros((Cp,), jnp.float32), err_val, (d * C,))
                ).astype(res.dtype)

        if k is not None:
            Bk = S[k] // M
            B = S[:k] + (Bk,) + S[k + 1:]
            Tb = _prod(B)
            blocks = [delivered_slices[m][:Tb].reshape(B) for m in range(M)]
            delivered = jnp.concatenate(blocks, axis=k)
        else:
            delivered = jnp.concatenate(delivered_slices)[:T].reshape(S)
        nr = jnp.stack([jnp.stack([new_res[d][m] for m in range(M)])
                        for d in range(D)])
        return delivered.astype(dtype), nr

    gflat, treedef = jax.tree.flatten(grads_stacked)
    rflat, _ = jax.tree.flatten(residual)
    pairs = [leaf(g, r, st, b)
             for g, r, st, b in zip(gflat, rflat, flags, wflags)]
    return (jax.tree.unflatten(treedef, [d for d, _ in pairs]),
            jax.tree.unflatten(treedef, [r for _, r in pairs]))


def wire2d_leaf_bytes(shape, n_data: int, n_model: int, kind: str,
                      stacked: bool = False, bits: int = 8) -> float:
    """Analytic per-device wire bytes of one 2D-sliced mean-reduce of a
    leaf (matches :class:`record_wire_bytes` on the traced ops, at the
    leaf's ACTUAL wire width): data all_to_all + all_gather on the 1/M
    slice, the quantized model-axis all_gather, and the per-row scale
    pmax over all D*M devices.  ``stacked`` marks a stacked-layer leaf
    (per-layer scale rows); ``bits`` <= 4 counts nibble-packed chunk
    bytes.  tests/test_wire2d.py pins this against measured trace bytes
    per leaf for int8, bf16, and mixed widths."""
    _check_kind(kind)
    item = 1 if kind == "int8" else 2
    Cp = wire2d_slice_len(shape, n_data, n_model)
    C = Cp // n_data
    if _nibble_wire(kind, bits):
        chunk_b, slice_b = float(-(-C // 2)), float(-(-Cp // 2))
    else:
        chunk_b, slice_b = C * item, Cp * item
    a2a = (n_data - 1) * chunk_b if n_data > 1 else 0.0
    ag = (n_data - 1) * chunk_b if n_data > 1 else 0.0
    ag_model = (n_model - 1) * slice_b if n_model > 1 else 0.0
    L, _ = _wire2d_rows(shape, stacked)
    scales = (_ring_allreduce_bytes(L * 4, n_data * n_model)
              if kind == "int8" else 0.0)
    return a2a + ag + ag_model + scales


def tp_replication_bytes(shape, n_model: int) -> float:
    """Per-device fp32 bytes the 1D wire path pays to rematerialize a
    model-sharded gradient leaf before its model-replicated shard_map (an
    all_gather over ``model`` GSPMD inserts implicitly): zero when the
    leaf does not shard over ``model`` — and zero for the 2D path, whose
    in_specs consume the sharded gradient directly."""
    from .sharding import model_axis_for
    if n_model <= 1 or model_axis_for(shape, n_model) is None:
        return 0.0
    return (n_model - 1) * (_prod(shape) / n_model) * 4.0


def simulate_wire_pmean(e_stacked: Any, kind: str = "int8",
                        stacked: Any = None,
                        widths: Any = None) -> Tuple[Any, Any]:
    """Collective-free reference of :func:`ef_wire_pmean` on a stacked
    ``[n, ...]`` tree: same grids, same chunking, same two-phase errors —
    usable on one device (tests, notebooks).  The 8-device CI job asserts
    the shard_map path matches this bit-for-bit (mixed widths included —
    nibble pack/unpack is the identity on in-range mantissas, so the
    simulator never models the packing).  ``stacked`` optionally marks
    stacked-layer leaves (default: derived from the tree paths);
    ``widths`` optionally carries per-leaf wire widths."""
    _check_kind(kind)
    flags = _stacked_flags(e_stacked, stacked)
    wflags = _width_flags(e_stacked, widths)

    def leaf(es, stk, bits):
        n = es.shape[0]
        dtype = es.dtype
        shape = es.shape[1:]
        rows0 = _layer_rows(es[0], stk)
        L, Pn = rows0.shape
        amax = jnp.max(jnp.abs(jnp.asarray(es, jnp.float32)
                               .reshape(n, L, -1)), axis=(0, 2))
        payloads, residuals, scale = [], [], None
        for i in range(n):
            p, scale, r = _phase1_quantize(es[i], amax, kind, stk, bits)
            payloads.append(p.reshape(-1))
            residuals.append(r)
        T = payloads[0].shape[0]
        C = -(-T // n)
        pad = n * C - T
        stacked = jnp.stack([jnp.pad(p, (0, pad)) for p in payloads])
        s_flat = jnp.pad(jnp.broadcast_to(scale[:, None], (L, Pn))
                         .reshape(-1), (0, pad), constant_values=1.0)
        sums = jnp.sum(stacked.astype(jnp.float32 if kind == "bf16"
                                      else jnp.int32), axis=0)
        q2, err2 = _phase2_requantize(sums.reshape(n, C), n, kind)
        q2 = q2.reshape(-1)
        if kind == "bf16":
            delivered_flat = q2.astype(jnp.float32) / n
            err2_val = err2
        else:
            delivered_flat = (q2.astype(jnp.float32)
                              * (2 ** _phase2_shift(n)) * s_flat / n)
            err2_val = err2 * s_flat.reshape(n, C)
        delivered = delivered_flat[:T].reshape(shape).astype(dtype)
        scatter = jnp.zeros((n, n * C), jnp.float32)
        for i in range(n):
            scatter = scatter.at[i, i * C:(i + 1) * C].set(err2_val[i])
        new_res = jnp.stack([
            (residuals[i] + scatter[i, :T].reshape(shape)).astype(dtype)
            for i in range(n)])
        return delivered, new_res

    flat, treedef = jax.tree.flatten(e_stacked)
    pairs = [leaf(x, st, b) for x, st, b in zip(flat, flags, wflags)]
    return (jax.tree.unflatten(treedef, [d for d, _ in pairs]),
            jax.tree.unflatten(treedef, [r for _, r in pairs]))


def wire_bytes_model(n_elements: int, n: int, kind: str,
                     n_scale_rows: int = 1, bits: int = 8) -> float:
    """Analytic per-device bytes-on-wire of one compressed mean-reduce
    (matches what :class:`record_wire_bytes` measures on the traced ops):
    all_to_all + all_gather of 1-byte (int8) / 2-byte (bf16) / half-byte
    (nibble-packed, ``bits <= 4``) payloads plus the per-row fp32 scale
    pmax."""
    _check_kind(kind)
    item = 1 if kind == "int8" else 2
    C = -(-n_elements // n)
    chunk_b = float(-(-C // 2)) if _nibble_wire(kind, bits) else C * item
    a2a = (n - 1) / n * (n * chunk_b)
    ag = (n - 1) * chunk_b
    # bf16 payloads carry their own exponents — no scale pmax on that path
    scales = (_ring_allreduce_bytes(n_scale_rows * 4, n)
              if kind == "int8" else 0.0)
    return a2a + ag + scales


def fp32_allreduce_bytes(n_elements: int, n: int) -> float:
    """Per-device bytes of the ring fp32 all-reduce the wire path replaces."""
    return _ring_allreduce_bytes(n_elements * 4, n)
