"""Compressed data-parallel gradient collectives: the reduction itself
moves int8 (or bf16) bytes, not fp32.

``dist.ef_compress`` quantizes the *synchronized* gradient — it bounds
update noise but every fp32 byte still crosses the wire first.  This
module compresses **inside** the reduction, DeepSpeed/1-bit-Adam style,
with error feedback on both phases:

phase 1 (reduce-scatter as ``all_to_all``)
    Each data shard quantizes its local ``grad + residual`` to int8
    mantissas on a per-layer power-of-two grid ``2^-f`` (the exponent comes
    from :func:`repro.kernels.qmatmul.ops.grid_exponent`, the same grid
    logic the serving weight packer uses; the leaf amax is ``pmax``-shared
    so every shard quantizes on the same grid).  The int8 chunks are
    exchanged with ``lax.all_to_all`` and summed as int32 — exact, since
    ``n * 127`` fits comfortably.

phase 2 (``all_gather``)
    The chunk owner re-quantizes the int32 chunk sum back to int8 by a
    static right-shift of ``ceil(log2 n)`` bits and gathers the int8 sums;
    the shift remainder (phase-2 error) is scattered into the owner's
    residual, so the time-averaged delivered mean gradient telescopes to
    the true mean exactly like single-phase error feedback (see
    ``tests/test_collectives.py``).

Per-device bytes on the wire per gradient element: ``2 * (n-1)/n`` at 1
byte (int8) vs ``2 * (n-1)/n`` at 4 bytes for a ring fp32 all-reduce — a
4x reduction, independent of ``n`` (bf16-wire: 2x).  The per-leaf scale
exponents add one ``pmax`` float per layer, which the byte accounting
includes.

The public entry :func:`ef_wire_pmean` runs under ``shard_map`` over the
mesh's data axes (``model`` stays unmapped: every tensor-parallel shard
carries the replicated gradient, exactly as in the uncompressed step) and
is wrapped in ``jax.custom_vjp`` — the forward is the compressed mean
all-reduce, the backward passes cotangents through like the transpose of
``pmean`` — so it composes under ``jax.value_and_grad`` even though the
quantization ops themselves have no useful derivative.

``simulate_wire_pmean`` is the collective-free reference: identical
per-shard math on a stacked ``[n, ...]`` tree, used by single-device
tests and by the property tests; the 8-device CI job checks the
``shard_map`` path agrees with it bit-for-bit.
"""
from __future__ import annotations

from functools import partial
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

WIRE_KINDS = ("int8", "bf16")

# trace-time recorder for bytes-on-wire accounting (collectives_bench):
# shapes are static, so appending (op, per-device bytes) while tracing
# measures exactly what the compiled collectives move.
_BYTES_TRACE: Optional[List[Tuple[str, float]]] = None


class record_wire_bytes:
    """Context manager: collect (op, per-device payload bytes) tuples for
    every collective issued while tracing inside the block."""

    def __init__(self):
        self.records: List[Tuple[str, float]] = []

    def __enter__(self):
        global _BYTES_TRACE
        self._prev = _BYTES_TRACE
        _BYTES_TRACE = self.records
        return self

    def __exit__(self, *exc):
        global _BYTES_TRACE
        _BYTES_TRACE = self._prev
        return False

    def total(self) -> float:
        return sum(b for _, b in self.records)


def _record(op: str, nbytes: float) -> None:
    if _BYTES_TRACE is not None:
        _BYTES_TRACE.append((op, float(nbytes)))


def _ring_allreduce_bytes(nbytes: float, n: int) -> float:
    return 2.0 * (n - 1) / n * nbytes


def data_axis_names(mesh) -> Tuple[str, ...]:
    """The data-parallel axis names of ``mesh`` (pod is outer DP; the axis
    whitelist lives once, in ``sharding``)."""
    from .sharding import _data_axes
    return _data_axes(mesh)


def data_axis_size(mesh) -> int:
    from .sharding import _data_size
    return _data_size(mesh)


# ---------------------------------------------------------------------------
# per-shard quantization (pure; shared by the shard_map body, the simulator,
# and the tests)
# ---------------------------------------------------------------------------

def _layer_rows(e: jax.Array) -> jax.Array:
    """Flatten a leaf to [L, P] rows — one quantization grid per leading
    (stacked-layer) axis entry for rank >= 3 leaves, one per tensor
    otherwise (same stacked-leaf rule as ``dist._compress_leaf``)."""
    L = e.shape[0] if e.ndim >= 3 else 1
    return jnp.asarray(e, jnp.float32).reshape(L, -1)


def _phase1_quantize(e: jax.Array, amax_rows: jax.Array, kind: str
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Quantize one leaf for the wire.

    Returns ``(payload_rows, scale_rows, residual)``: the wire payload as
    [L, P] (int8 mantissas, or bf16 values with a dummy unit scale), the
    per-row grid step, and the local quantization error ``e - dequant``.
    ``amax_rows`` is the *global* per-row amax (``pmax`` over shards), so
    every shard lands on the same grid and int32 chunk sums are exact.
    """
    rows = _layer_rows(e)
    if kind == "bf16":
        payload = rows.astype(jnp.bfloat16)
        deq = payload.astype(jnp.float32)
        scale = jnp.ones((rows.shape[0],), jnp.float32)
    else:
        from ..kernels.qmatmul.ops import grid_exponent
        from ..core.quantizer import _exp2i
        f = grid_exponent(amax_rows)
        scale = _exp2i(-f)
        payload = jnp.clip(jnp.round(rows / scale[:, None]),
                           -127, 127).astype(jnp.int8)
        deq = payload.astype(jnp.float32) * scale[:, None]
    residual = (jnp.asarray(e, jnp.float32)
                - deq.astype(jnp.float32).reshape(e.shape))
    return payload, scale, residual


def _phase2_requantize(chunk_sum: jax.Array, n: int, kind: str
                       ) -> Tuple[jax.Array, jax.Array]:
    """Requantize a chunk of summed phase-1 payloads for the all_gather.

    int8: the int32 mantissa sum (|sum| <= n*127) shifts right by
    ``k = ceil(log2 n)`` so it fits int8 again; the remainder (in mantissa
    units) is the phase-2 error the chunk owner keeps.  bf16: round the
    fp32 sum to bf16, keep the rounding error.
    """
    if kind == "bf16":
        payload = chunk_sum.astype(jnp.bfloat16)
        return payload, chunk_sum - payload.astype(jnp.float32)
    k = _phase2_shift(n)
    m2 = jnp.round(chunk_sum.astype(jnp.float32) / (2 ** k)).astype(jnp.int32)
    err = (chunk_sum - m2 * (2 ** k)).astype(jnp.float32)
    return m2.astype(jnp.int8), err


def _phase2_shift(n: int) -> int:
    """The decode side multiplies by exactly this power of two — keep the
    encode/decode shift one definition."""
    return max((n - 1).bit_length(), 0)


# ---------------------------------------------------------------------------
# the shard_map body (one leaf at a time)
# ---------------------------------------------------------------------------

def _wire_leaf(e: jax.Array, axes: Tuple[str, ...], n: int, kind: str
               ) -> Tuple[jax.Array, jax.Array]:
    """Compressed mean-reduce of one per-shard leaf inside shard_map.

    ``e`` is this shard's ``grad + residual`` (leading shard axis of size 1
    already squeezed).  Returns ``(delivered_mean, new_residual)``.
    """
    dtype = e.dtype
    rows = _layer_rows(e)
    L, Pn = rows.shape
    amax = None
    if kind != "bf16":     # bf16 payloads carry their own exponents
        amax = jax.lax.pmax(jnp.max(jnp.abs(rows), axis=1), axes)
        _record("pmax.scale", _ring_allreduce_bytes(L * 4, n))
    payload, scale, residual = _phase1_quantize(e, amax, kind)

    flat = payload.reshape(-1)
    T = flat.shape[0]
    C = -(-T // n)
    flat = jnp.pad(flat, (0, n * C - T))
    # per-position grid steps, padded the same way (bf16 rows share scale 1)
    s_flat = jnp.pad(jnp.broadcast_to(scale[:, None], (L, Pn)).reshape(-1),
                     (0, n * C - T), constant_values=1.0)

    # phase 1: reduce-scatter as all_to_all of the compressed chunks
    _record(f"all_to_all.{kind}",
            (n - 1) / n * (n * C) * flat.dtype.itemsize)
    ex = jax.lax.all_to_all(flat.reshape(n, C), axes, 0, 0, tiled=False)
    chunk_sum = jnp.sum(ex.astype(jnp.float32 if kind == "bf16"
                                  else jnp.int32), axis=0)

    # phase 2: requantize the sum, gather, decode once
    q2, err2 = _phase2_requantize(chunk_sum, n, kind)
    _record(f"all_gather.{kind}", (n - 1) * C * q2.dtype.itemsize)
    full = jax.lax.all_gather(q2, axes, axis=0, tiled=False).reshape(-1)
    if kind == "bf16":
        delivered_flat = full.astype(jnp.float32) / n
        err2_val = err2  # value domain; carried in full so delivery /n
        #                  next step recovers exactly what was withheld
    else:
        delivered_flat = (full.astype(jnp.float32) * (2 ** _phase2_shift(n))
                          * s_flat / n)
        err2_val = err2  # mantissa units; scaled to values below
    delivered = delivered_flat[:T].reshape(e.shape).astype(dtype)

    # error feedback for phase 2: the owner of chunk i carries the shift
    # remainder forward — next step it is re-quantized and delivered,
    # so the time-averaged delivered mean telescopes exactly
    idx = jnp.int32(0)
    for ax in axes:
        idx = idx * jax.lax.psum(1, ax) + jax.lax.axis_index(ax)
    if kind != "bf16":
        own_scale = jax.lax.dynamic_slice(s_flat, (idx * C,), (C,))
        err2_val = err2_val * own_scale
    scatter = jax.lax.dynamic_update_slice(
        jnp.zeros((n * C,), jnp.float32), err2_val, (idx * C,))[:T]
    new_residual = (residual + scatter.reshape(e.shape)).astype(dtype)
    return delivered, new_residual


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def ef_wire_init(grads: Any, n_data: int) -> Any:
    """Zero per-shard residual tree: each leaf gains a leading ``[n_data]``
    shard axis (sharded over the data axes by
    ``sharding.ef_residual_sharding``)."""
    return jax.tree.map(
        lambda g: jnp.zeros((n_data,) + tuple(g.shape), g.dtype), grads)


def _check_kind(kind: str) -> None:
    if kind not in WIRE_KINDS:
        raise ValueError(f"unsupported wire compression kind {kind!r}; "
                         f"supported: {WIRE_KINDS}")


def _wire_pmean_impl(e_stacked: Any, mesh, kind: str) -> Tuple[Any, Any]:
    axes = data_axis_names(mesh)
    n = data_axis_size(mesh)

    def body(tree):
        flat, treedef = jax.tree.flatten(tree)
        pairs = [_wire_leaf(leaf[0], axes, n, kind) for leaf in flat]
        delivered = jax.tree.unflatten(treedef, [d for d, _ in pairs])
        residual = jax.tree.unflatten(treedef, [r[None] for _, r in pairs])
        return delivered, residual

    stack_spec = jax.tree.map(
        lambda leaf: P(axes, *([None] * (leaf.ndim - 1))), e_stacked)
    plain_spec = jax.tree.map(
        lambda leaf: P(*([None] * (leaf.ndim - 1))), e_stacked)
    return shard_map(body, mesh=mesh, in_specs=(stack_spec,),
                     out_specs=(plain_spec, stack_spec),
                     check_rep=False)(e_stacked)


@partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def ef_wire_pmean(e_stacked: Any, mesh, kind: str = "int8"
                  ) -> Tuple[Any, Any]:
    """Compressed mean all-reduce with error feedback, inside the wire.

    ``e_stacked`` is a pytree whose leaves carry a leading ``[n_data]``
    shard axis holding each data shard's ``local_grad + residual``
    (sharded over the data axes).  Returns ``(delivered, new_residual)``:
    the int8/bf16-wire mean gradient, replicated, plus the per-shard
    residual to thread into the next step.

    The custom VJP passes the ``delivered`` cotangent through as the
    transpose of an uncompressed shard mean, so the backward of a loss
    containing this collective is unchanged and ``jax.value_and_grad``
    composes; residual cotangents are dropped (state, not value).
    """
    _check_kind(kind)
    return _wire_pmean_impl(e_stacked, mesh, kind)


def _ef_wire_fwd(e_stacked, mesh, kind):
    return ef_wire_pmean(e_stacked, mesh, kind), None


def _ef_wire_bwd(mesh, kind, _res, cts):
    ct_delivered, _ct_residual = cts
    n = data_axis_size(mesh)
    ct_e = jax.tree.map(
        lambda ct: jnp.broadcast_to(ct[None] / n, (n,) + tuple(ct.shape)),
        ct_delivered)
    return (ct_e,)


ef_wire_pmean.defvjp(_ef_wire_fwd, _ef_wire_bwd)


def simulate_wire_pmean(e_stacked: Any, kind: str = "int8"
                        ) -> Tuple[Any, Any]:
    """Collective-free reference of :func:`ef_wire_pmean` on a stacked
    ``[n, ...]`` tree: same grids, same chunking, same two-phase errors —
    usable on one device (tests, notebooks).  The 8-device CI job asserts
    the shard_map path matches this bit-for-bit."""
    _check_kind(kind)

    def leaf(es):
        n = es.shape[0]
        dtype = es.dtype
        shape = es.shape[1:]
        rows0 = _layer_rows(es[0])
        L, Pn = rows0.shape
        amax = jnp.max(jnp.abs(jnp.asarray(es, jnp.float32)
                               .reshape(n, L, -1)), axis=(0, 2))
        payloads, residuals, scale = [], [], None
        for i in range(n):
            p, scale, r = _phase1_quantize(es[i], amax, kind)
            payloads.append(p.reshape(-1))
            residuals.append(r)
        T = payloads[0].shape[0]
        C = -(-T // n)
        pad = n * C - T
        stacked = jnp.stack([jnp.pad(p, (0, pad)) for p in payloads])
        s_flat = jnp.pad(jnp.broadcast_to(scale[:, None], (L, Pn))
                         .reshape(-1), (0, pad), constant_values=1.0)
        sums = jnp.sum(stacked.astype(jnp.float32 if kind == "bf16"
                                      else jnp.int32), axis=0)
        q2, err2 = _phase2_requantize(sums.reshape(n, C), n, kind)
        q2 = q2.reshape(-1)
        if kind == "bf16":
            delivered_flat = q2.astype(jnp.float32) / n
            err2_val = err2
        else:
            delivered_flat = (q2.astype(jnp.float32)
                              * (2 ** _phase2_shift(n)) * s_flat / n)
            err2_val = err2 * s_flat.reshape(n, C)
        delivered = delivered_flat[:T].reshape(shape).astype(dtype)
        scatter = jnp.zeros((n, n * C), jnp.float32)
        for i in range(n):
            scatter = scatter.at[i, i * C:(i + 1) * C].set(err2_val[i])
        new_res = jnp.stack([
            (residuals[i] + scatter[i, :T].reshape(shape)).astype(dtype)
            for i in range(n)])
        return delivered, new_res

    flat, treedef = jax.tree.flatten(e_stacked)
    pairs = [leaf(x) for x in flat]
    return (jax.tree.unflatten(treedef, [d for d, _ in pairs]),
            jax.tree.unflatten(treedef, [r for _, r in pairs]))


def wire_bytes_model(n_elements: int, n: int, kind: str,
                     n_scale_rows: int = 1) -> float:
    """Analytic per-device bytes-on-wire of one compressed mean-reduce
    (matches what :class:`record_wire_bytes` measures on the traced ops):
    all_to_all + all_gather of 1-byte (int8) / 2-byte (bf16) payloads plus
    the per-row fp32 scale pmax."""
    _check_kind(kind)
    item = 1 if kind == "int8" else 2
    C = -(-n_elements // n)
    a2a = (n - 1) / n * (n * C) * item
    ag = (n - 1) * C * item
    # bf16 payloads carry their own exponents — no scale pmax on that path
    scales = (_ring_allreduce_bytes(n_scale_rows * 4, n)
              if kind == "int8" else 0.0)
    return a2a + ag + scales


def fp32_allreduce_bytes(n_elements: int, n: int) -> float:
    """Per-device bytes of the ring fp32 all-reduce the wire path replaces."""
    return _ring_allreduce_bytes(n_elements * 4, n)
