"""Parameter/batch/cache placement rules (FSDP x TP).

One heuristic, applied uniformly to every parameter leaf by
:func:`spec_for_param`:

* only the trailing two axes of a weight are sharding candidates — any
  leading axes (the ``lax.scan`` stacked-layer axis, MoE expert axes, conv
  spatial dims) are iterated per step or routed per token, so sharding them
  would put a collective inside the scan body;
* the *larger* trailing axis goes to the tensor-parallel ``model`` axis
  (bigger shards amortize the TP all-reduce), the other to the
  data-parallel axes (FSDP);  in ``mode="serve"`` there is no gradient
  all-reduce to overlap, so only the TP shard is kept;
* an axis that does not divide the mesh axis size replicates instead.

Data-parallel axes are ``("data",)`` on the single-pod mesh and
``("pod", "data")`` on the multi-pod mesh — ``pod`` is outer data
parallelism, so batch and FSDP shards span both.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_DATA_AXIS_NAMES = ("pod", "data")


def _mesh_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _data_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in _DATA_AXIS_NAMES)


def _data_size(mesh) -> int:
    sizes = _mesh_sizes(mesh)
    n = 1
    for a in _data_axes(mesh):
        n *= sizes[a]
    return n


def _key_name(k: Any) -> str:
    for attr in ("key", "name"):
        v = getattr(k, attr, None)
        if v is not None:
            return str(v)
    return str(k)


# tree containers whose children carry a leading stacked-layer axis: the
# vmap-initialized / lax.scan'd layer stacks and the MoE expert stacks.
# Stackedness used to be sniffed from rank (ndim >= 3), which silently
# treated any genuinely 3-D weight (e.g. a per-head attention tensor) as
# a layer stack — per-slice quantization grids where one per-tensor grid
# was meant.  The tree path is the ground truth: a leaf is stacked iff it
# lives under one of these containers.
STACKED_CONTAINERS = frozenset({
    "layers", "units", "blocks", "dec_layers", "enc_layers",
    "down", "gate", "up",  # HMoE per-expert [E, ...] weight stacks (moe.py)
})


def is_stacked_path(path: Sequence[Any]) -> bool:
    """True when a tree path passes through a stacked-layer container —
    i.e. the leaf's leading axis is a scan'd layer (or expert) axis, not a
    tensor dimension.  ``path`` is a ``tree_flatten_with_path`` key path."""
    return any(_key_name(k) in STACKED_CONTAINERS for k in path)


def stacked_tree(tree: Any) -> Any:
    """Map :func:`is_stacked_path` over a pytree: a matching tree of bools
    marking which leaves carry a leading stacked-layer axis.  The explicit
    per-leaf metadata ``dist.ef_compress`` and the wire collectives use to
    pick per-layer vs per-tensor quantization grids."""
    return jax.tree_util.tree_map_with_path(
        lambda path, _: is_stacked_path(path), tree)


def model_axis_for(shape: Sequence[int], model_size: int) -> Optional[int]:
    """The (absolute) tensor axis the ``model`` mesh axis shards, or
    ``None`` when the leaf replicates over ``model``.

    One rule, shared by :func:`spec_for_param`, the 2D wire collective's
    gradient in_specs, and its collective-free simulator — the larger of
    the two trailing axes (axis -1 wins ties), only when it divides the
    mesh's model size.  Rank < 2 leaves always replicate.
    """
    shape = tuple(shape)
    if len(shape) < 2 or model_size <= 1:
        return None
    model_pos = len(shape) - 1 if shape[-1] >= shape[-2] else len(shape) - 2
    return model_pos if shape[model_pos] % model_size == 0 else None


def spec_for_param(path: Sequence[Any], shape: Sequence[int], mesh,
                   mode: str = "train") -> P:
    """Placement spec for one parameter leaf.

    ``path`` is a ``tree_flatten_with_path``-style key path (anything with a
    ``.key``/``.name`` attribute, or stringifiable); ``shape`` the leaf
    shape; ``mode`` is ``"train"`` (FSDP x TP) or ``"serve"`` (TP only).
    """
    if mode not in ("train", "serve"):
        raise ValueError(f"mode must be 'train' or 'serve', got {mode!r}")
    shape = tuple(shape)
    if len(shape) < 2:
        return P(*([None] * len(shape)))
    sizes = _mesh_sizes(mesh)
    model_size = sizes.get("model", 1)
    daxes = _data_axes(mesh)
    dsize = _data_size(mesh)
    entries: list = [None] * len(shape)
    # larger trailing axis -> model; axis -1 wins ties; the other -> data
    model_pos = len(shape) - 1 if shape[-1] >= shape[-2] else len(shape) - 2
    data_pos = len(shape) - 2 if model_pos == len(shape) - 1 \
        else len(shape) - 1
    if model_axis_for(shape, model_size) is not None:
        entries[model_pos] = "model"
    if mode == "train" and dsize > 1 and shape[data_pos] % dsize == 0:
        entries[data_pos] = daxes if len(daxes) > 1 else daxes[0]
    return P(*entries)


def batch_spec(mesh, batch: int, ndim: int) -> P:
    """Leading-axis data sharding for a batch of ``ndim`` dims; replicate
    when the global batch does not fill every data shard."""
    daxes = _data_axes(mesh)
    dsize = _data_size(mesh)
    entries: list = [None] * ndim
    if dsize > 1 and batch > 1 and batch % dsize == 0:
        entries[0] = daxes
    return P(*entries)


def batch_sharding(mesh, batch: int, ndim: int) -> NamedSharding:
    return NamedSharding(mesh, batch_spec(mesh, batch, ndim))


def cache_sharding(mesh, shape: Sequence[int], *, batch_axis: int = 1,
                   seq_axis: Optional[int] = None,
                   head_axis: Optional[int] = None) -> NamedSharding:
    """Decode-cache placement: batch over data, heads (or, failing that,
    the sequence/window axis) over model.  Axis 0 is the stacked-layer
    axis and always replicates."""
    shape = tuple(shape)
    sizes = _mesh_sizes(mesh)
    model_size = sizes.get("model", 1)
    daxes = _data_axes(mesh)
    dsize = _data_size(mesh)
    entries: list = [None] * len(shape)
    if dsize > 1 and shape[batch_axis] % dsize == 0:
        entries[batch_axis] = daxes if len(daxes) > 1 else daxes[0]
    if model_size > 1:
        for ax in (head_axis, seq_axis):
            if ax is not None and shape[ax] % model_size == 0:
                entries[ax] = "model"
                break
    return NamedSharding(mesh, P(*entries))


def ef_residual_sharding(tree: Any, mesh, layout: str = "1d") -> Any:
    """Placement for the int8-wire error-feedback residual.

    ``layout="1d"`` (``collectives.ef_wire_init``): every leaf carries a
    leading ``[n_data]`` shard axis (one residual per data shard), sharded
    over the data axes exactly like the per-shard gradients it corrects —
    each device keeps only its own residual slice; trailing axes replicate
    (the collective body is manual over data only).

    ``layout="2d"`` (``collectives.ef_wire2d_init``): every leaf is the
    flat ``[n_data, n_model, C]`` slice stack of the 2D-sliced wire
    collective — axis 0 shards over the data axes, axis 1 over ``model``,
    so device ``(d, m)`` holds exactly its own ``[1, 1, C]`` residual
    slice and nothing is replicated anywhere.
    """
    if layout not in ("1d", "2d"):
        raise ValueError(f"layout must be '1d' or '2d', got {layout!r}")
    daxes = _data_axes(mesh)
    entries = daxes if len(daxes) > 1 else (daxes[0] if daxes else None)
    if layout == "2d":
        model = "model" if "model" in mesh.axis_names else None
        return jax.tree.map(
            lambda leaf: NamedSharding(mesh, P(entries, model, None)), tree)

    def spec(leaf):
        return NamedSharding(mesh, P(entries, *([None] * (leaf.ndim - 1))))
    return jax.tree.map(spec, tree)


def replicated(mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def shard_tree(tree: Any, mesh, mode: str = "train") -> Any:
    """Map :func:`spec_for_param` over every leaf of a parameter pytree.

    Leaves only need a ``.shape`` — concrete arrays and
    ``ShapeDtypeStruct``s both work (the dry-run shards abstract trees).
    """
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, spec_for_param(path, leaf.shape, mesh, mode)), tree)
