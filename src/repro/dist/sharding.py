"""Parameter/batch/cache placement rules (FSDP x TP).

One heuristic, applied uniformly to every parameter leaf by
:func:`spec_for_param`:

* only the trailing two axes of a weight are sharding candidates — any
  leading axes (the ``lax.scan`` stacked-layer axis, MoE expert axes, conv
  spatial dims) are iterated per step or routed per token, so sharding them
  would put a collective inside the scan body;
* the *larger* trailing axis goes to the tensor-parallel ``model`` axis
  (bigger shards amortize the TP all-reduce), the other to the
  data-parallel axes (FSDP);  in ``mode="serve"`` there is no gradient
  all-reduce to overlap, so only the TP shard is kept;
* an axis that does not divide the mesh axis size replicates instead.

Data-parallel axes are ``("data",)`` on the single-pod mesh and
``("pod", "data")`` on the multi-pod mesh — ``pod`` is outer data
parallelism, so batch and FSDP shards span both.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_DATA_AXIS_NAMES = ("pod", "data")


def _mesh_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _data_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in _DATA_AXIS_NAMES)


def _data_size(mesh) -> int:
    sizes = _mesh_sizes(mesh)
    n = 1
    for a in _data_axes(mesh):
        n *= sizes[a]
    return n


def _key_name(k: Any) -> str:
    for attr in ("key", "name"):
        v = getattr(k, attr, None)
        if v is not None:
            return str(v)
    return str(k)


def spec_for_param(path: Sequence[Any], shape: Sequence[int], mesh,
                   mode: str = "train") -> P:
    """Placement spec for one parameter leaf.

    ``path`` is a ``tree_flatten_with_path``-style key path (anything with a
    ``.key``/``.name`` attribute, or stringifiable); ``shape`` the leaf
    shape; ``mode`` is ``"train"`` (FSDP x TP) or ``"serve"`` (TP only).
    """
    if mode not in ("train", "serve"):
        raise ValueError(f"mode must be 'train' or 'serve', got {mode!r}")
    shape = tuple(shape)
    if len(shape) < 2:
        return P(*([None] * len(shape)))
    sizes = _mesh_sizes(mesh)
    model_size = sizes.get("model", 1)
    daxes = _data_axes(mesh)
    dsize = _data_size(mesh)
    entries: list = [None] * len(shape)
    d0, d1 = shape[-2], shape[-1]
    # larger trailing axis -> model; axis -1 wins ties; the other -> data
    model_pos = len(shape) - 1 if d1 >= d0 else len(shape) - 2
    data_pos = len(shape) - 2 if model_pos == len(shape) - 1 \
        else len(shape) - 1
    if model_size > 1 and shape[model_pos] % model_size == 0:
        entries[model_pos] = "model"
    if mode == "train" and dsize > 1 and shape[data_pos] % dsize == 0:
        entries[data_pos] = daxes if len(daxes) > 1 else daxes[0]
    return P(*entries)


def batch_spec(mesh, batch: int, ndim: int) -> P:
    """Leading-axis data sharding for a batch of ``ndim`` dims; replicate
    when the global batch does not fill every data shard."""
    daxes = _data_axes(mesh)
    dsize = _data_size(mesh)
    entries: list = [None] * ndim
    if dsize > 1 and batch > 1 and batch % dsize == 0:
        entries[0] = daxes
    return P(*entries)


def batch_sharding(mesh, batch: int, ndim: int) -> NamedSharding:
    return NamedSharding(mesh, batch_spec(mesh, batch, ndim))


def cache_sharding(mesh, shape: Sequence[int], *, batch_axis: int = 1,
                   seq_axis: Optional[int] = None,
                   head_axis: Optional[int] = None) -> NamedSharding:
    """Decode-cache placement: batch over data, heads (or, failing that,
    the sequence/window axis) over model.  Axis 0 is the stacked-layer
    axis and always replicates."""
    shape = tuple(shape)
    sizes = _mesh_sizes(mesh)
    model_size = sizes.get("model", 1)
    daxes = _data_axes(mesh)
    dsize = _data_size(mesh)
    entries: list = [None] * len(shape)
    if dsize > 1 and shape[batch_axis] % dsize == 0:
        entries[batch_axis] = daxes if len(daxes) > 1 else daxes[0]
    if model_size > 1:
        for ax in (head_axis, seq_axis):
            if ax is not None and shape[ax] % model_size == 0:
                entries[ax] = "model"
                break
    return NamedSharding(mesh, P(*entries))


def ef_residual_sharding(tree: Any, mesh) -> Any:
    """Placement for the int8-wire error-feedback residual: every leaf
    carries a leading ``[n_data]`` shard axis (one residual per data
    shard, see ``collectives.ef_wire_init``), sharded over the data axes
    exactly like the per-shard gradients it corrects — each device keeps
    only its own residual slice.  Trailing axes replicate (the collective
    body is manual over data only)."""
    daxes = _data_axes(mesh)
    entries = daxes if len(daxes) > 1 else (daxes[0] if daxes else None)

    def spec(leaf):
        return NamedSharding(mesh, P(entries, *([None] * (leaf.ndim - 1))))
    return jax.tree.map(spec, tree)


def replicated(mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def shard_tree(tree: Any, mesh, mode: str = "train") -> Any:
    """Map :func:`spec_for_param` over every leaf of a parameter pytree.

    Leaves only need a ``.shape`` — concrete arrays and
    ``ShapeDtypeStruct``s both work (the dry-run shards abstract trees).
    """
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, spec_for_param(path, leaf.shape, mesh, mode)), tree)
