"""Reference math of the fused wire quantize-pack family.

Pure jnp, and *definitionally* the semantics of the compressed-wire
collective's elementwise stages: ``quantize_leaf_ref`` is the exact
int8 branch of ``dist.collectives._phase1_quantize`` (per-row 2^-f grid
from :func:`repro.kernels.qmatmul.ops.grid_exponent`, saturating
round-to-nearest-even, phase-1 residual), ``dequant_sum_ref`` the exact
phase-2 decode expression, ``pack_chunks_ref`` the exact nibble wire
format.  Off-TPU this IS the fast path — XLA fuses the chain — while
``kernel.py`` is the single-VMEM-pass Pallas realization;
tests/test_wire_pack.py asserts the two bit-identical.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ...core.quantizer import _exp2i
from ..qmatmul.ops import grid_exponent, mantissa_max, pack_nibbles


def grid_scale(amax: jax.Array, bits: int = 8) -> jax.Array:
    """Per-row wire grid step ``2^-f``: ``_exp2i(-grid_exponent(amax))``
    — the one scale definition phase 1 quantizes on and phase 2 decodes
    with (exact power of two, so divide == multiply-by-inverse)."""
    return _exp2i(-grid_exponent(amax, bits))


def quantize_leaf_ref(rows: jax.Array, amax: jax.Array, bits: int = 8
                      ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """[L, P] fp32 rows + per-row global amax -> (int8 mantissas [L, P],
    scale [L], fp32 residual [L, P]).  ``residual = rows - dequant`` is
    the phase-1 error the caller feeds back next step."""
    scale = grid_scale(amax, bits)
    qmax = mantissa_max(bits)
    q = jnp.clip(jnp.round(rows / scale[:, None]), -qmax,
                 qmax).astype(jnp.int8)
    residual = rows - q.astype(jnp.float32) * scale[:, None]
    return q, scale, residual


def quantize_chunks_ref(e: jax.Array, s: jax.Array, bits: int = 8
                        ) -> Tuple[jax.Array, jax.Array]:
    """Per-position-scale variant (the 2D sliced path, where a flat
    slice crosses stacked-layer row boundaries): ``e`` and ``s`` share a
    shape -> (int8 mantissas, fp32 residual)."""
    qmax = mantissa_max(bits)
    q = jnp.clip(jnp.round(e / s), -qmax, qmax).astype(jnp.int8)
    return q, e - q.astype(jnp.float32) * s


def pack_chunks_ref(q: jax.Array) -> jax.Array:
    """Nibble-pack int4-range mantissas two per byte along the last axis
    (the sub-5-bit wire format; odd lengths pad one zero nibble)."""
    return pack_nibbles(q, axis=-1)


def dequant_sum_ref(q: jax.Array, s: jax.Array, shift: int,
                    n: int) -> jax.Array:
    """Phase-2 decode: gathered requantized mantissa sums -> the fp32
    delivered mean contribution ``q * 2^shift * s / n`` (``shift`` is
    ``_phase2_shift(n)``; evaluation order matches the collective's
    original inline expression exactly)."""
    return q.astype(jnp.float32) * (2 ** shift) * s / n
