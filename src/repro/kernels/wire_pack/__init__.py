"""Fused wire compression kernels for the compressed gradient collective.

One kernel family covers the hot elementwise stages of the int8-on-the-
wire exchange (``dist.collectives``): per-row 2^-f grid-exponent
computation + saturating quantize (+ the phase-1 residual) in one pass,
nibble packing of chunk payloads, and the phase-2 dequant-accumulate.
``ops`` selects the compiled Pallas kernel on TPU and the bit-identical
jnp reference elsewhere (tests/test_wire_pack.py pins both equal in
interpret mode).
"""
from .ops import (dequant_sum, grid_scale, pack_chunks, quantize_chunks,
                  quantize_leaf, use_fused_kernel)

__all__ = ["dequant_sum", "grid_scale", "pack_chunks", "quantize_chunks",
           "quantize_leaf", "use_fused_kernel"]
