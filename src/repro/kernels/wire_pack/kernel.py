"""Pallas TPU kernels: fused wire compression for the gradient collective.

Phase 1 of the int8-on-the-wire exchange is three elementwise sweeps in
the reference path — grid-exponent from the pmax'd amax, saturating
quantize, nibble pack — plus a fourth to materialize the error-feedback
residual.  Each kernel here fuses its stage into one VMEM pass over
(block_rows, lanes) tiles:

  * ``wire_quantize_rows``  — amax -> 2^-f grid -> round/clip -> int8
    mantissas AND the fp32 residual, per stacked-layer row, one pass
  * ``wire_quantize_sflat`` — same with a per-position scale (the 2D
    sliced path, where one device's slice crosses layer-row boundaries)
  * ``wire_pack_rows``      — two int4 mantissas per byte (wire format
    of sub-5-bit plan widths), lifted from ``qmatmul.pack_nibbles``
  * ``wire_dequant_rows``   — phase-2 decode ``q * 2^shift * s / n``

The grid math reuses ``hgq_quantize``'s exact exponent-field exp2
(integer shifts, never an ulp off) with the bitcast twin of
``core.quantizer.floor_log2``; the mantissa range comes from
``qmatmul.mantissa_max``.  ``ref.py`` holds the jnp reference these are
asserted bit-identical to (tests/test_wire_pack.py, interpret mode);
``ops.py`` picks the backend and handles padding/alignment.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..hgq_quantize.kernel import DEFAULT_BLOCK_ROWS, LANE, _exact_exp2


def _floor_log2_pos(x):
    """floor(log2 x) for positive *normal* fp32 via exponent-field
    extraction — bit-identical to ``core.quantizer.floor_log2`` (frexp)
    on that domain, and integer ops only, so it lowers in-kernel.  The
    grid ratio qmax/max(amax, 1e-12) is normal for every finite amax a
    gradient can produce."""
    ex = (jax.lax.bitcast_convert_type(x, jnp.int32) >> 23) & 0xFF
    return ex.astype(jnp.float32) - 127.0


def _grid_scale_math(amax, qmax):
    """amax -> the 2^-f wire grid step; the exact math of
    ``qmatmul.grid_exponent`` + ``_exp2i(-f)``: cap f so amax fits in
    +-qmax mantissas, backing off one where rounding would still
    saturate."""
    fcap = _floor_log2_pos(qmax / jnp.maximum(amax, 1e-12))
    f = jnp.where(jnp.floor(amax * _exact_exp2(fcap) + 0.5) > qmax,
                  fcap - 1.0, fcap)
    return _exact_exp2(-f)


def _quantize_rows_kernel(x_ref, a_ref, q_ref, s_ref, r_ref, *, qmax):
    s = _grid_scale_math(a_ref[...], qmax)        # [br, 1]
    x = x_ref[...]
    q = jnp.clip(jnp.round(x / s), -qmax, qmax)   # integral fp32
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = s
    r_ref[...] = x - q * s


def _quantize_sflat_kernel(x_ref, s_ref, q_ref, r_ref, *, qmax):
    s = s_ref[...]                                # same tile shape as x
    x = x_ref[...]
    q = jnp.clip(jnp.round(x / s), -qmax, qmax)
    q_ref[...] = q.astype(jnp.int8)
    r_ref[...] = x - q * s


def _pack_kernel(q_ref, o_ref):
    q = q_ref[...]
    br, c = q.shape
    pairs = q.reshape(br, c // 2, 2)
    o_ref[...] = jnp.bitwise_or(
        jnp.bitwise_and(pairs[..., 0], jnp.int8(0x0F)),
        jnp.left_shift(pairs[..., 1], 4)).astype(jnp.int8)


def _dequant_kernel(q_ref, s_ref, o_ref, *, mul, n):
    o_ref[...] = q_ref[...].astype(jnp.float32) * mul * s_ref[...] / n


@functools.partial(jax.jit, static_argnames=("bits", "block_rows",
                                             "interpret"))
def wire_quantize_rows(rows: jax.Array, amax: jax.Array, *, bits: int = 8,
                       block_rows: int = DEFAULT_BLOCK_ROWS,
                       interpret: bool = True):
    """[L, P] fp32 rows + [L] amax -> (int8 [L, P], scale [L],
    residual fp32 [L, P]); P must be lane-aligned (ops.py pads)."""
    from ..qmatmul.ops import mantissa_max
    L, P = rows.shape
    assert P % LANE == 0, f"cols {P} must be lane-aligned"
    br = min(block_rows, L)
    grid = (pl.cdiv(L, br),)
    kern = functools.partial(_quantize_rows_kernel,
                             qmax=float(mantissa_max(bits)))
    tile = pl.BlockSpec((br, P), lambda i: (i, 0))
    col = pl.BlockSpec((br, 1), lambda i: (i, 0))
    q, s, r = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[tile, col],
        out_specs=[tile, col, tile],
        out_shape=[jax.ShapeDtypeStruct((L, P), jnp.int8),
                   jax.ShapeDtypeStruct((L, 1), jnp.float32),
                   jax.ShapeDtypeStruct((L, P), jnp.float32)],
        interpret=interpret,
    )(rows.astype(jnp.float32), amax.reshape(L, 1).astype(jnp.float32))
    return q, s[:, 0], r


@functools.partial(jax.jit, static_argnames=("bits", "block_rows",
                                             "interpret"))
def wire_quantize_sflat(x: jax.Array, s: jax.Array, *, bits: int = 8,
                        block_rows: int = DEFAULT_BLOCK_ROWS,
                        interpret: bool = True):
    """[R, C] fp32 + per-position [R, C] scale -> (int8, residual)."""
    from ..qmatmul.ops import mantissa_max
    R, C = x.shape
    assert C % LANE == 0, f"cols {C} must be lane-aligned"
    br = min(block_rows, R)
    grid = (pl.cdiv(R, br),)
    kern = functools.partial(_quantize_sflat_kernel,
                             qmax=float(mantissa_max(bits)))
    tile = pl.BlockSpec((br, C), lambda i: (i, 0))
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[tile, tile],
        out_specs=[tile, tile],
        out_shape=[jax.ShapeDtypeStruct((R, C), jnp.int8),
                   jax.ShapeDtypeStruct((R, C), jnp.float32)],
        interpret=interpret,
    )(x.astype(jnp.float32), s.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def wire_pack_rows(q: jax.Array, *, block_rows: int = DEFAULT_BLOCK_ROWS,
                   interpret: bool = True) -> jax.Array:
    """[R, C] int4-range mantissas -> [R, C // 2] packed bytes; C must be
    2*lane-aligned so the packed tile stays lane-aligned."""
    R, C = q.shape
    assert C % (2 * LANE) == 0, f"cols {C} must be 2*lane-aligned"
    br = min(block_rows, R)
    grid = (pl.cdiv(R, br),)
    return pl.pallas_call(
        _pack_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((br, C), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, C // 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, C // 2), jnp.int8),
        interpret=interpret,
    )(q.astype(jnp.int8))


@functools.partial(jax.jit, static_argnames=("shift", "n", "block_rows",
                                             "interpret"))
def wire_dequant_rows(q: jax.Array, s: jax.Array, *, shift: int, n: int,
                      block_rows: int = DEFAULT_BLOCK_ROWS,
                      interpret: bool = True) -> jax.Array:
    """[R, C] mantissa sums + [R, C] scale -> fp32 ``q * 2^shift * s / n``
    (the phase-2 delivered-mean decode) in one pass."""
    R, C = q.shape
    assert C % LANE == 0, f"cols {C} must be lane-aligned"
    br = min(block_rows, R)
    grid = (pl.cdiv(R, br),)
    kern = functools.partial(_dequant_kernel, mul=float(2 ** shift), n=n)
    tile = pl.BlockSpec((br, C), lambda i: (i, 0))
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[tile, tile],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((R, C), jnp.float32),
        interpret=interpret,
    )(q, s.astype(jnp.float32))
