"""Backend dispatch + shape handling for the fused wire kernels.

Same contract as ``qmatmul.ops``: on TPU the compiled Pallas kernel is
the fast path; elsewhere the jnp reference is — XLA already fuses the
elementwise chain on CPU/GPU, where interpret-mode Pallas would only
add overhead.  ``use_kernel``/``interpret`` overrides exist so tests
can force the kernel route (interpreted) and pin it bit-identical to
the reference on any backend.

All entry points accept arbitrary shapes; lane alignment (and even-
column alignment for nibble packing) is handled here by zero/one
padding that provably round-trips: padded positions quantize to 0
mantissas under scale 1 and are sliced off before return.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import kernel, ref
from .kernel import LANE
from .ref import grid_scale

__all__ = ["dequant_sum", "grid_scale", "pack_chunks", "quantize_chunks",
           "quantize_leaf", "use_fused_kernel"]


def use_fused_kernel() -> bool:
    """True when the compiled Pallas fast path should run (TPU); the
    reference jnp path IS the fast path elsewhere."""
    return jax.default_backend() == "tpu"


def _resolve(use_kernel: Optional[bool], interpret: Optional[bool]):
    if use_kernel is None:
        use_kernel = use_fused_kernel()
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return use_kernel, interpret


def _pad_cols(x: jax.Array, mult: int, value: float) -> jax.Array:
    pad = (-x.shape[-1]) % mult
    if not pad:
        return x
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)],
                   constant_values=value)


def quantize_leaf(rows: jax.Array, amax: jax.Array, bits: int = 8, *,
                  use_kernel: Optional[bool] = None,
                  interpret: Optional[bool] = None
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused phase-1 for one leaf in stacked-row layout: [L, P] fp32 +
    per-row pmax'd amax [L] -> (int8 mantissas, 2^-f scale [L], fp32
    error-feedback residual) — grid exponent, saturating quantize and
    residual in a single pass."""
    use_kernel, interpret = _resolve(use_kernel, interpret)
    if not use_kernel:
        return ref.quantize_leaf_ref(rows, amax, bits)
    L, P = rows.shape
    q, s, r = kernel.wire_quantize_rows(
        _pad_cols(jnp.asarray(rows, jnp.float32), LANE, 0.0), amax,
        bits=bits, interpret=interpret)
    return q[:, :P], s, r[:, :P]


def quantize_chunks(e: jax.Array, s: jax.Array, bits: int = 8, *,
                    use_kernel: Optional[bool] = None,
                    interpret: Optional[bool] = None
                    ) -> Tuple[jax.Array, jax.Array]:
    """Per-position-scale phase-1 (2D sliced path): [R, C] fp32 + [R, C]
    scale -> (int8 mantissas, fp32 residual)."""
    use_kernel, interpret = _resolve(use_kernel, interpret)
    if not use_kernel:
        return ref.quantize_chunks_ref(e, s, bits)
    R, C = e.shape
    q, r = kernel.wire_quantize_sflat(
        _pad_cols(jnp.asarray(e, jnp.float32), LANE, 0.0),
        _pad_cols(jnp.asarray(s, jnp.float32), LANE, 1.0),
        bits=bits, interpret=interpret)
    return q[:, :C], r[:, :C]


def pack_chunks(q: jax.Array, *, use_kernel: Optional[bool] = None,
                interpret: Optional[bool] = None) -> jax.Array:
    """Nibble-pack int4-range mantissas along the last axis, two per
    byte (odd lengths pad one zero nibble) — the sub-5-bit wire format,
    byte-identical to ``qmatmul.pack_nibbles``."""
    use_kernel, interpret = _resolve(use_kernel, interpret)
    if not use_kernel:
        return ref.pack_chunks_ref(q)
    lead, C = q.shape[:-1], q.shape[-1]
    q2 = _pad_cols(jnp.asarray(q, jnp.int8).reshape((-1, C)), 2 * LANE, 0)
    packed = kernel.wire_pack_rows(q2, interpret=interpret)
    return packed[:, :(C + 1) // 2].reshape(lead + ((C + 1) // 2,))


def dequant_sum(q: jax.Array, s: jax.Array, shift: int, n: int, *,
                use_kernel: Optional[bool] = None,
                interpret: Optional[bool] = None) -> jax.Array:
    """Fused phase-2 decode: requantized mantissa sums -> the fp32
    delivered mean contribution ``q * 2^shift * s / n``.  ``s``
    broadcasts against ``q`` (the 2D path decodes [M, C] blocks against
    a [C] slice scale)."""
    use_kernel, interpret = _resolve(use_kernel, interpret)
    if not use_kernel:
        return ref.dequant_sum_ref(q, s, shift, n)
    sb = jnp.broadcast_to(jnp.asarray(s, jnp.float32), q.shape)
    shape = q.shape
    C = shape[-1] if q.ndim else 1
    q2 = _pad_cols(q.reshape((-1, C)), LANE, 0)
    s2 = _pad_cols(sb.reshape((-1, C)), LANE, 1.0)
    out = kernel.wire_dequant_rows(q2, s2, shift=shift, n=n,
                                   interpret=interpret)
    return out[:, :C].reshape(shape)
