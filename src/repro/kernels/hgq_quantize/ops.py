"""jit'd public wrapper for the HGQ quantizer kernel.

Handles arbitrary input shapes (reshape + lane padding), dispatches the
right broadcast layout, and attaches the Algorithm-1 backward pass
(straight-through in x, ``+ln2 * delta`` surrogate in f) via
``jax.custom_vjp`` so the kernel body stays forward-only.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from .kernel import LANE, hgq_quantize_2d
from .ref import hgq_quantize_ref

LN2 = 0.6931471805599453


def _pad_cols(a: jax.Array) -> jax.Array:
    cols = a.shape[-1]
    pad = (-cols) % LANE
    if pad:
        a = jnp.pad(a, [(0, 0)] * (a.ndim - 1) + [(0, pad)])
    return a


def _to_2d(x: jax.Array):
    """Reshape any-rank x to [rows, cols] with lane-aligned cols."""
    if x.ndim == 0:
        return x.reshape(1, 1), x.shape
    lead = math.prod(x.shape[:-1]) if x.ndim > 1 else 1
    return x.reshape(lead, x.shape[-1]), x.shape


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def hgq_quantize(x: jax.Array, f: jax.Array, epsilon: float = 0.5,
                 interpret: bool = True) -> jax.Array:
    """Differentiable HGQ quantizer (Alg. 1) backed by the Pallas kernel.

    f: scalar (per_tensor), [x.shape[-1]] (per_channel) or x.shape
    (per_parameter).
    """
    return _forward(x, f, epsilon, interpret)


def _forward(x, f, epsilon, interpret):
    x2, orig_shape = _to_2d(x)
    cols = x2.shape[-1]
    x2p = _pad_cols(x2)
    if f.ndim == 0:
        f_arg = f
    elif f.shape == (x.shape[-1],):
        f_arg = _pad_cols(f.reshape(1, -1))[0]
    elif f.shape == x.shape:
        f_arg = _pad_cols(f.reshape(x2.shape))
    else:
        # general broadcast group shapes fall back to the reference path
        return hgq_quantize_ref(x, jnp.broadcast_to(f, x.shape))
    out = hgq_quantize_2d(x2p, f_arg, epsilon=epsilon, interpret=interpret)
    return out[..., :cols].reshape(orig_shape)


def _fwd(x, f, epsilon, interpret):
    xq = _forward(x, f, epsilon, interpret)
    delta = (x.astype(jnp.float32) - xq.astype(jnp.float32))
    fi = jnp.floor(f.astype(jnp.float32) + 0.5)
    return xq, (delta, fi, f.shape)


def _bwd(epsilon, interpret, res, g):
    delta, fi, f_shape = res
    g32 = g.astype(jnp.float32)
    # d xq / dx = 1 (STE)
    dx = g
    # d xq / df = +ln2 * delta  (Eq. 15; see core/quantizer.py)
    df_full = g32 * LN2 * delta
    # sum over broadcast axes down to f's shape
    if f_shape == ():
        df = jnp.sum(df_full)
    elif len(f_shape) == 1:
        df = jnp.sum(df_full.reshape(-1, df_full.shape[-1]), axis=0)
    else:
        df = df_full.reshape(f_shape)
    return dx, df.astype(jnp.float32)


hgq_quantize.defvjp(_fwd, _bwd)
