"""Pallas TPU kernel: HGQ quantizer forward (Eq. 4).

This op runs over every weight and activation on every training step — the
framework's hottest elementwise op.  The kernel fuses the (round f ->
exp2 -> scale -> floor -> unscale) chain into one VMEM pass, tiled
(block_rows, 128)-aligned for the VPU lanes.

Three broadcast layouts cover the granularity spectrum:
  * per_tensor    — f is a scalar in SMEM
  * per_channel   — f is a [cols] row, broadcast across rows
  * per_parameter — f has x's shape, streamed tile-by-tile beside x

The backward pass (STE in x, ln2*delta surrogate in f, Alg. 1) is attached
in ops.py via jax.custom_vjp — the kernel computes the forward only.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 256
LANE = 128  # TPU VPU lane width; last-dim tiles must be multiples


def _exact_exp2(fi):
    """2^fi by exponent-field construction — exact where XLA's exp2 can be
    an ulp off (fi=13, 15, 26, ...), and integer-shift only, so it lowers
    inside the kernel body.  fi must be integer-valued; clamped to the
    float32 normal range."""
    biased = jnp.clip(fi, -126.0, 127.0).astype(jnp.int32) + 127
    return jax.lax.bitcast_convert_type(biased << 23, jnp.float32)


def _quantize_math(x, fi, epsilon):
    scale = _exact_exp2(fi)
    return jnp.floor(x.astype(jnp.float32) * scale + epsilon) / scale


def _kernel_per_tensor(x_ref, f_ref, o_ref, *, epsilon):
    fi = jnp.floor(f_ref[0] + 0.5)
    o_ref[...] = _quantize_math(x_ref[...], fi, epsilon).astype(o_ref.dtype)


def _kernel_per_channel(x_ref, f_ref, o_ref, *, epsilon):
    fi = jnp.floor(f_ref[...] + 0.5)          # [1, block_cols]
    o_ref[...] = _quantize_math(x_ref[...], fi, epsilon).astype(o_ref.dtype)


def _kernel_per_param(x_ref, f_ref, o_ref, *, epsilon):
    fi = jnp.floor(f_ref[...] + 0.5)          # same tile shape as x
    o_ref[...] = _quantize_math(x_ref[...], fi, epsilon).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("epsilon", "block_rows",
                                             "interpret"))
def hgq_quantize_2d(x: jax.Array, f: jax.Array, *, epsilon: float = 0.5,
                    block_rows: int = DEFAULT_BLOCK_ROWS,
                    interpret: bool = True) -> jax.Array:
    """Quantize a 2-D array [rows, cols].  f: scalar, [cols], or x.shape.

    cols is padded to the 128-lane boundary by the caller (ops.py handles
    arbitrary shapes by reshaping/padding).
    """
    rows, cols = x.shape
    assert cols % LANE == 0, f"cols {cols} must be lane-aligned"
    br = min(block_rows, rows)
    grid = (pl.cdiv(rows, br),)
    x_spec = pl.BlockSpec((br, cols), lambda i: (i, 0))
    if f.ndim == 0:
        kern = functools.partial(_kernel_per_tensor, epsilon=epsilon)
        f_arg = f.reshape(1).astype(jnp.float32)
        f_spec = pl.BlockSpec((1,), lambda i: (0,))
    elif f.ndim == 1:
        kern = functools.partial(_kernel_per_channel, epsilon=epsilon)
        f_arg = f.reshape(1, cols).astype(jnp.float32)
        f_spec = pl.BlockSpec((1, cols), lambda i: (0, 0))
    else:
        kern = functools.partial(_kernel_per_param, epsilon=epsilon)
        f_arg = f.astype(jnp.float32)
        f_spec = pl.BlockSpec((br, cols), lambda i: (i, 0))
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[x_spec, f_spec],
        out_specs=pl.BlockSpec((br, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), x.dtype),
        interpret=interpret,
    )(x, f_arg)
