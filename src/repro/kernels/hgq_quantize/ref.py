"""Pure-jnp oracle for the HGQ quantizer forward (Eq. 4)."""
from __future__ import annotations

import jax.numpy as jnp

from ...core.quantizer import _exp2i


def hgq_quantize_ref(x: jnp.ndarray, f: jnp.ndarray,
                     epsilon: float = 0.5) -> jnp.ndarray:
    """round(x * 2^f) * 2^-f with f rounded via floor(f + 0.5), f broadcast
    against x.  Math in fp32, result cast back to x.dtype.  _exp2i, not
    jnp.exp2: the grid scale must be the exact power of two the core
    quantizer/calibration uses."""
    x32 = x.astype(jnp.float32)
    fi = jnp.floor(f.astype(jnp.float32) + 0.5)
    scale = _exp2i(fi)
    return (jnp.floor(x32 * scale + epsilon) / scale).astype(x.dtype)
