"""Pallas TPU kernels (validated on CPU via interpret=True).

* hgq_quantize — Algorithm-1 quantizer forward (hottest elementwise op)
* qmatmul      — packed int8 x fp fused dequant-matmul (serving path)
"""
from .hgq_quantize.ops import hgq_quantize
from .qmatmul.ops import pack_weights, qmatmul_any
