"""Pure-jnp oracle for the packed-weight dequant matmul."""
from __future__ import annotations

import jax.numpy as jnp

from ...core.quantizer import _exp2i


def qmatmul_ref(x: jnp.ndarray, w_int: jnp.ndarray,
                scale: jnp.ndarray) -> jnp.ndarray:
    """x [M, K] fp; w_int [K, N] int8; scale [N] fp (= 2^-f per channel).

    Dequantize-then-matmul in fp32: x @ (w_int * scale)."""
    w = w_int.astype(jnp.float32) * scale.astype(jnp.float32)[None, :]
    return jnp.dot(x.astype(jnp.float32), w).astype(x.dtype)


def pack_ref(w: jnp.ndarray, f: jnp.ndarray, bits: int = 8):
    """Quantize fp weights [K, N] to ``bits``-wide mantissas (int8 storage)
    + per-channel scale from the HGQ fractional bits f [N] (scale = 2^-f).
    Sub-8-bit grids clip symmetrically to +-(2^(b-1)-1) so nibble packing
    and error feedback never see the asymmetric minimum."""
    fi = jnp.floor(f.astype(jnp.float32) + 0.5)
    scale = _exp2i(-fi)
    lo, hi = (-128, 127) if bits == 8 else \
        (-(2 ** (bits - 1) - 1), 2 ** (bits - 1) - 1)
    m = jnp.clip(jnp.floor(w.astype(jnp.float32) / scale[None, :] + 0.5),
                 lo, hi).astype(jnp.int8)
    return m, scale
