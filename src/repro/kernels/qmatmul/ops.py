"""Public wrapper for the packed dequant-matmul: padding, batching, packing.

``pack_weights`` converts an HGQ-trained (w, f) pair into the serving
representation (int8 + per-channel 2^-f scale).  ``qmatmul_any`` handles
leading batch dims and non-aligned shapes.  ``packed_bytes`` is the TPU
serving cost model: the per-channel trained bits map channels into
{0, 4, 8} storage classes (0 = pruned — HGQ pruning carries straight
through to serving).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .kernel import qmatmul
from .ref import pack_ref


def default_interpret() -> bool:
    """Pallas execution mode for the current backend: compiled on TPU,
    interpreted elsewhere (the kernel uses TPU VMEM scratch semantics)."""
    return jax.default_backend() != "tpu"


def mantissa_max(bits: int = 8) -> int:
    """Largest symmetric mantissa a ``bits``-wide signed grid carries
    (127 for int8, 7 for int4 — -2^(b-1) is excluded so chunk sums and
    error feedback stay symmetric)."""
    if not 2 <= bits <= 8:
        raise ValueError(f"grid width must be in [2, 8], got {bits!r}")
    return 2 ** (bits - 1) - 1


def grid_exponent(amax: jax.Array, bits: int = 8) -> jax.Array:
    """Largest fractional-bit exponent ``f`` whose power-of-two ``bits``-
    wide grid ``2^-f`` fits magnitudes up to ``amax`` into +-(2^(b-1)-1)
    mantissas (127 for the int8 default).  The raw cap divides two floats,
    so it can be one too high at the boundary; back off where the mantissa
    would still saturate.  Shared by :func:`channel_bits` (weight packing)
    and the quantized-wire gradient collective (``dist.collectives``)."""
    from ...core.quantizer import _exp2i, floor_log2
    qmax = float(mantissa_max(bits))
    amax = jnp.asarray(amax, jnp.float32)
    fcap = floor_log2(qmax / jnp.maximum(amax, 1e-12))
    return jnp.where(jnp.floor(amax * _exp2i(fcap) + 0.5) > qmax,
                     fcap - 1.0, fcap)


def channel_bits(w: jax.Array, f: Optional[jax.Array],
                 bits: int = 8) -> jax.Array:
    """Per-output-channel fractional bits for ``bits``-wide packing of
    ``w [..., K, N]``: the channel max of the trained ``f`` (every weight
    in the channel stays exactly representable), capped so the channel
    amax fits +-(2^(b-1)-1) — saturating the big weights corrupts the
    matmul far worse than flooring the small ones.  With ``f=None`` the
    cap itself is the (power-of-two) scale.  Shared by serving/packed.py
    and dist.perf packing."""
    w32 = jnp.asarray(w, jnp.float32)
    amax = jnp.max(jnp.abs(w32), axis=-2)
    fgrid = grid_exponent(amax, bits)
    if f is None:
        return fgrid
    fi = jnp.max(jnp.floor(jnp.broadcast_to(
        jnp.asarray(f, jnp.float32), w32.shape) + 0.5), axis=-2)
    # trained bits below the cap never saturate (amax * 2^fi <= qmax/2), so
    # min(trained, capped-grid) preserves the old cap-then-back-off result
    return jnp.minimum(fi, fgrid)


def pack_weights(w: jax.Array, f: jax.Array, bits: int = 8
                 ) -> Tuple[jax.Array, jax.Array]:
    """[K, N] fp weights + fractional bits (scalar | [N] | [K, N]) ->
    (int8-stored mantissas clipped to the ``bits``-wide grid, [N] scale).
    Per-parameter f packs at the per-channel max so every weight in the
    channel is exactly representable."""
    f = jnp.asarray(f, jnp.float32)
    if f.ndim == 0:
        fcol = jnp.full((w.shape[1],), f)
    elif f.ndim == 1:
        fcol = jnp.broadcast_to(f, (w.shape[1],))
    else:
        fcol = jnp.max(jnp.broadcast_to(f, w.shape), axis=0)
    return pack_ref(w, fcol, bits)


def pack_linear(w: jax.Array, f: Optional[jax.Array] = None,
                bits: int = 8) -> Tuple[jax.Array, jax.Array]:
    """``w [..., K, N]`` (leading stacked-layer/expert axes allowed) ->
    ``(mantissas [..., K, N], scale [..., N])``: :func:`pack_weights` at
    the capped per-channel bits of :func:`channel_bits` on a ``bits``-wide
    grid.  The single leaf packer behind serving/packed.py and dist.perf
    packing."""
    w32 = jnp.asarray(w, jnp.float32)
    fi = channel_bits(w32, f, bits)
    if w32.ndim == 2:
        return pack_weights(w32, fi, bits)
    lead = w32.shape[:-2]
    m, scale = jax.vmap(lambda wi, fii: pack_weights(wi, fii, bits))(
        w32.reshape((-1,) + w32.shape[-2:]),
        fi.reshape((-1, fi.shape[-1])))
    return m.reshape(w32.shape), scale.reshape(lead + (w32.shape[-1],))


def pack_nibbles(m: jax.Array, axis: int = -1) -> jax.Array:
    """Pack int4-range mantissas two per int8 byte along ``axis`` (odd
    lengths pad one zero nibble).  The storage/wire format of sub-5-bit
    plan layers: halves serving HBM bytes and collective payloads."""
    m = jnp.moveaxis(jnp.asarray(m, jnp.int8), axis, -1)
    if m.shape[-1] % 2:
        m = jnp.pad(m, [(0, 0)] * (m.ndim - 1) + [(0, 1)])
    lo, hi = m[..., 0::2], m[..., 1::2]
    packed = jnp.bitwise_or(jnp.bitwise_and(lo, jnp.int8(0x0F)),
                            jnp.left_shift(hi, 4)).astype(jnp.int8)
    return jnp.moveaxis(packed, -1, axis)


def unpack_nibbles(packed: jax.Array, orig: int,
                   axis: int = -1) -> jax.Array:
    """Inverse of :func:`pack_nibbles`: int8 bytes -> ``orig`` sign-
    extended int4-range mantissas along ``axis`` (arithmetic shifts, so
    negative nibbles come back exact)."""
    p = jnp.moveaxis(jnp.asarray(packed, jnp.int8), axis, -1)
    lo = jnp.right_shift(jnp.left_shift(p, 4), 4)
    hi = jnp.right_shift(p, 4)
    m = jnp.stack([lo, hi], axis=-1).reshape(
        p.shape[:-1] + (2 * p.shape[-1],))[..., :orig]
    return jnp.moveaxis(m, -1, axis)


def qmatmul_any(x: jax.Array, w_int: jax.Array, scale: jax.Array, *,
                interpret: Optional[bool] = None, bm: int = 128,
                bn: int = 128, bk: int = 512) -> jax.Array:
    """x [..., K] @ packed w [K, N]: flattens leading dims and pads to the
    (8, 128) tile grid.  ``interpret=None`` selects per backend
    (:func:`default_interpret`); pass a bool to override."""
    if interpret is None:
        interpret = default_interpret()
    K, N = w_int.shape
    lead = x.shape[:-1]
    M = math.prod(lead) if lead else 1
    x2 = x.reshape(M, K)

    def _round_up(v, base):
        return -(-v // base) * base

    # every dim must be an exact multiple of its tile (partial blocks read
    # out-of-bounds in the k-accumulation grid)
    bm_ = min(bm, _round_up(M, 8))
    bk_ = min(bk, _round_up(K, 128))
    bn_ = min(bn, _round_up(N, 128))
    M3, K3, N3 = _round_up(M, bm_), _round_up(K, bk_), _round_up(N, bn_)
    if M3 > M or K3 > K:
        x2 = jnp.pad(x2, ((0, M3 - M), (0, K3 - K)))
    w2, s2 = w_int, scale
    if K3 > K or N3 > N:
        w2 = jnp.pad(w_int, ((0, K3 - K), (0, N3 - N)))
        s2 = jnp.pad(scale, (0, N3 - N))
    out = qmatmul(x2, w2, s2, bm=bm_, bn=bn_, bk=bk_, interpret=interpret)
    return out[:M, :N].reshape(*lead, N)


def packed_bytes(w: jax.Array, f: jax.Array, vmin, vmax) -> float:
    """Serving weight bytes under {0,4,8}-bit storage classes chosen from the
    calibrated per-channel bitwidths b = max(i' + f, 0).  This is the
    memory-roofline win HGQ buys on TPU decode (DESIGN.md SS2)."""
    from ...core.quantizer import int_bits_from_range
    b = jnp.maximum(int_bits_from_range(vmin, vmax)
                    + jnp.floor(jnp.asarray(f, jnp.float32) + 0.5), 0.0)
    cls = jnp.where(b <= 0, 0.0, jnp.where(b <= 4, 4.0, 8.0))
    n_per_channel = w.shape[0] if w.ndim == 2 else 1
    return float(jnp.sum(cls) / 8.0 * n_per_channel)
