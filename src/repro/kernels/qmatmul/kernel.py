"""Pallas TPU kernel: fused dequant x matmul for HGQ-packed weights.

Serving-path kernel (DESIGN.md SS2): weights live in HBM as int8 + per-output-
channel power-of-two scale (2^-f with f the trained HGQ bits).  Decode is
HBM-bandwidth-bound, so halving (bf16 -> int8) or quartering (-> int4x2,
future) the streamed weight bytes moves the memory roofline term directly.

Tiling: grid (M/bm, N/bn, K/bk), fp32 accumulator scratch in VMEM; the
per-channel scale multiplies once on the final k step (valid because the
scale is constant along K).  MXU-aligned defaults (128, 128, 512).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BM, DEFAULT_BN, DEFAULT_BK = 128, 128, 512


def _qmatmul_kernel(x_ref, w_ref, s_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32),
                            w_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        o_ref[...] = (acc_ref[...] * s_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def qmatmul(x: jax.Array, w_int: jax.Array, scale: jax.Array, *,
            bm: int = DEFAULT_BM, bn: int = DEFAULT_BN, bk: int = DEFAULT_BK,
            interpret: bool = True) -> jax.Array:
    """x [M, K] fp; w_int [K, N] int8; scale [N].  Returns [M, N] in x.dtype.

    M, K, N are padded to tile boundaries by ops.py.
    """
    M, K = x.shape
    K2, N = w_int.shape
    assert K == K2 and scale.shape == (N,)
    bm = min(bm, M)
    bn = min(bn, N)
    bk = min(bk, K)
    grid = (pl.cdiv(M, bm), pl.cdiv(N, bn), pl.cdiv(K, bk))
    return pl.pallas_call(
        functools.partial(_qmatmul_kernel, k_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w_int, scale.reshape(1, N))
