"""Quantized-KV-cache kernels for the serving decode read.

One kernel family covers the hot stages of the plan-width KV cache
(``serving/kvcache.py``): per-row 2^-f grid-exponent computation +
saturating quantize at the ring-buffer write, the plain ``q * 2^-f``
decode, and the fused dequant-attention read that streams int8/nibble
cache bytes from HBM and dequantizes in VMEM.  ``ops`` selects the
compiled Pallas kernel on TPU and the jnp reference elsewhere
(tests/test_kv_dequant.py pins the elementwise kernels bit-identical in
interpret mode and the fused read numerically tight).
"""
from .ops import (kv_attention_decode, kv_dequant, kv_pack, kv_quantize,
                  kv_unpack, use_fused_kernel)

__all__ = ["kv_attention_decode", "kv_dequant", "kv_pack", "kv_quantize",
           "kv_unpack", "use_fused_kernel"]
