"""Backend dispatch + shape handling for the quantized-KV-cache kernels.

Same contract as ``wire_pack.ops``: on TPU the compiled Pallas kernels
are the fast path; elsewhere the jnp reference is — XLA fuses the
dequant into the attention einsums on CPU/GPU, where interpret-mode
Pallas would only add overhead.  ``use_kernel``/``interpret`` overrides
exist so tests can force the kernel route (interpreted) and pin it
against the reference on any backend.

Entry points accept the cache-native layouts of ``serving/kvcache.py``
(``[B, W, KV, hd]`` mantissas, ``[B, W, KV]`` exponents); lane alignment
is handled here by zero padding that provably round-trips — padded head
columns quantize to 0 mantissas and contribute nothing to either dot
product, padded ring slots carry mask 0.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import kernel, ref
from .kernel import LANE

__all__ = ["kv_attention_decode", "kv_dequant", "kv_pack", "kv_quantize",
           "kv_unpack", "use_fused_kernel"]


def use_fused_kernel() -> bool:
    """True when the compiled Pallas fast path should run (TPU); the
    reference jnp path IS the fast path elsewhere."""
    return jax.default_backend() == "tpu"


def _resolve(use_kernel: Optional[bool], interpret: Optional[bool]):
    if use_kernel is None:
        use_kernel = use_fused_kernel()
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return use_kernel, interpret


def _pad_last(x: jax.Array, mult: int, value=0) -> jax.Array:
    pad = (-x.shape[-1]) % mult
    if not pad:
        return x
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)],
                   constant_values=value)


def kv_quantize(x: jax.Array, bits: int = 8, *,
                use_kernel: Optional[bool] = None,
                interpret: Optional[bool] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """``[..., hd]`` fp k/v rows -> (int8 mantissas ``[..., hd]``, int8
    grid exponents ``[...]``): amax over the head dim, capped 2^-f grid,
    saturating round — the cache-store quantizer."""
    use_kernel, interpret = _resolve(use_kernel, interpret)
    if not use_kernel:
        return ref.kv_quantize_ref(x, bits)
    lead, hd = x.shape[:-1], x.shape[-1]
    rows = _pad_last(jnp.asarray(x, jnp.float32).reshape(-1, hd), LANE)
    q, f = kernel.kv_quantize_rows(rows, bits=bits, interpret=interpret)
    return q[:, :hd].reshape(lead + (hd,)), f.reshape(lead)


def kv_dequant(q: jax.Array, f: jax.Array, *,
               use_kernel: Optional[bool] = None,
               interpret: Optional[bool] = None) -> jax.Array:
    """(int8 mantissas ``[..., hd]``, int8 exponents ``[...]``) -> fp32
    ``q * 2^-f``."""
    use_kernel, interpret = _resolve(use_kernel, interpret)
    if not use_kernel:
        return ref.kv_dequant_ref(q, f)
    lead, hd = q.shape[:-1], q.shape[-1]
    q2 = _pad_last(jnp.asarray(q, jnp.int8).reshape(-1, hd), LANE)
    out = kernel.kv_dequant_rows(q2, f.reshape(-1), interpret=interpret)
    return out[:, :hd].reshape(lead + (hd,))


def kv_pack(q: jax.Array) -> jax.Array:
    """Nibble-pack int4-range mantissas two per stored byte along the
    head dim (``kv_bits <= 4`` format).  The written rows are tiny next
    to the full-cache read, so the pack stays jnp on every backend."""
    return ref.kv_pack_ref(q)


def kv_unpack(packed: jax.Array, hd: int) -> jax.Array:
    """Inverse of :func:`kv_pack` (plain readers; the fused attention
    read unpacks in VMEM instead)."""
    return ref.kv_unpack_ref(packed, hd)


def kv_attention_decode(qh: jax.Array, km: jax.Array, kf: jax.Array,
                        vm: jax.Array, vf: jax.Array, qpos: jax.Array,
                        tpos: jax.Array, *, window: Optional[int],
                        n_kv: int, probs_f: Optional[jax.Array] = None,
                        use_kernel: Optional[bool] = None,
                        interpret: Optional[bool] = None) -> jax.Array:
    """Decode attention over the quantized ring cache, dequant fused.

    ``qh`` [B, S, H, hd] roped queries; ``km``/``vm`` [B, W, KV, hdm]
    int8 mantissas (hdm = hd, or hd // 2 nibble-packed); ``kf``/``vf``
    [B, W, KV] int8 exponents; ``qpos`` [B, S] global query positions;
    ``tpos`` [B, W] global position per ring slot (negative = empty).
    Returns [B, S, H, hd] in ``qh.dtype`` — same contract as
    ``nn.attention._decode_attention`` on a dequantized cache.
    """
    use_kernel, interpret = _resolve(use_kernel, interpret)
    B, S, H, hd = qh.shape
    KV = n_kv
    G = H // KV
    qg = qh.reshape(B, S, KV, G, hd)
    if not use_kernel:
        out = ref.kv_attention_ref(qg, km, kf, vm, vf, qpos, tpos,
                                   window=window, probs_f=probs_f)
        return out.reshape(B, S, H, hd)
    W = km.shape[1]
    packed = km.shape[-1] != hd
    # one (b, kv-head) grid cell per call; query rows repeat G-fold so
    # the mask/qpos land row-aligned with the grouped heads
    qg2 = qg.transpose(0, 2, 1, 3, 4).reshape(B, KV, S * G, hd)
    km2 = km.transpose(0, 2, 1, 3)                    # [B, KV, W, hdm]
    vm2 = vm.transpose(0, 2, 1, 3)
    kf2 = kf.transpose(0, 2, 1)[:, :, None, :]        # [B, KV, 1, W]
    vf2 = vf.transpose(0, 2, 1)[:, :, None, :]
    mask = (tpos[:, None, :] <= qpos[:, :, None]) & (tpos[:, None, :] >= 0)
    if window is not None:
        mask &= (qpos[:, :, None] - tpos[:, None, :]) < window
    mask = jnp.repeat(mask.astype(jnp.int8), G, axis=1)  # [B, SG, W]
    if packed:
        hdm = (-(-km.shape[-1] // LANE)) * LANE
        km2, vm2 = _pad_last(km2, LANE), _pad_last(vm2, LANE)
        qg2 = _pad_last(qg2.astype(jnp.float32), 2 * hdm)
    else:
        km2, vm2 = _pad_last(km2, LANE), _pad_last(vm2, LANE)
        qg2 = _pad_last(qg2.astype(jnp.float32), LANE)
    # ring-slot axis: padded slots carry mask 0 and contribute nothing
    Wp = (-(-W // LANE)) * LANE
    if Wp != W:
        km2 = jnp.pad(km2, ((0, 0), (0, 0), (0, Wp - W), (0, 0)))
        vm2 = jnp.pad(vm2, ((0, 0), (0, 0), (0, Wp - W), (0, 0)))
        kf2, vf2 = _pad_last(kf2, LANE), _pad_last(vf2, LANE)
        mask = _pad_last(mask, LANE)
    pf = (jnp.zeros((), jnp.float32) if probs_f is None
          else jnp.asarray(probs_f, jnp.float32))
    out = kernel.kv_attention_rows(
        qg2, km2, kf2, vm2, vf2, mask, pf, scale=float(hd) ** -0.5,
        packed=packed, use_pf=probs_f is not None, interpret=interpret)
    out = out[..., :hd].reshape(B, KV, S, G, hd)
    return out.transpose(0, 2, 1, 3, 4).reshape(B, S, H, hd).astype(qh.dtype)
