"""Pallas TPU kernels: quantized-KV-cache store and fused attention read.

Decode is KV-cache-bandwidth-bound: the ring buffer is read in full every
tick while only one row per slot is written.  Storing mantissas on the
per-row 2^-f grid (``kv_bits`` from the precision plan) and dequantizing
*inside* the attention read means HBM streams int8/nibble bytes instead
of fp — the read kernel touches each cache byte exactly once:

  * ``kv_quantize_rows``    — amax over the head dim -> capped 2^-f grid
    -> round/clip -> int8 mantissas AND the int8 grid exponent, one pass
  * ``kv_dequant_rows``     — ``q * 2^-f`` decode (tests / plain readers)
  * ``kv_attention_rows``   — the fused decode read: scores against int8
    mantissas with the k exponents folded into the score columns, online
    mask/softmax, probs requantization, and the v exponents folded into
    the prob rows — the cache never exists dequantized in HBM.  Nibble-
    packed (``kv_bits <= 4``) caches unpack in VMEM.

The exponent application rides the last (slot) axis of the score matrix,
so both dequants are row-vector broadcasts — no transposed per-column
scales anywhere.  Grid math reuses ``hgq_quantize``'s exact exponent-
field exp2 and the bitcast ``floor_log2`` twin from ``wire_pack``;
``ref.py`` holds the jnp reference (tests/test_kv_dequant.py pins the
elementwise kernels bit-identical in interpret mode, the fused read
numerically tight); ``ops.py`` picks the backend and handles padding.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..hgq_quantize.kernel import DEFAULT_BLOCK_ROWS, LANE, _exact_exp2
from ..wire_pack.kernel import _floor_log2_pos

NEG_INF = -1e30


def _grid_exponent_math(amax, qmax):
    """amax -> the capped grid exponent f of ``qmatmul.grid_exponent``:
    largest f with amax * 2^f inside +-qmax, backing off one where
    rounding would still saturate."""
    fcap = _floor_log2_pos(qmax / jnp.maximum(amax, 1e-12))
    return jnp.where(jnp.floor(amax * _exact_exp2(fcap) + 0.5) > qmax,
                     fcap - 1.0, fcap)


def _unpack_math(packed, hd):
    """[W, hd // 2] nibble bytes -> [W, hd] sign-extended int8 mantissas
    (arithmetic shifts, the exact ``qmatmul.unpack_nibbles`` math)."""
    lo = jax.lax.shift_right_arithmetic(
        jax.lax.shift_left(packed, jnp.int8(4)), jnp.int8(4))
    hi = jax.lax.shift_right_arithmetic(packed, jnp.int8(4))
    return jnp.stack([lo, hi], axis=-1).reshape(packed.shape[0], hd)


def _kv_quantize_kernel(x_ref, q_ref, f_ref, *, qmax):
    x = x_ref[...]                                  # [br, hd] fp32
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    f = _grid_exponent_math(amax, qmax)             # [br, 1]
    q = jnp.clip(jnp.round(x * _exact_exp2(f)), -qmax, qmax)
    q_ref[...] = q.astype(jnp.int8)
    f_ref[...] = f.astype(jnp.int8)


def _kv_dequant_kernel(q_ref, f_ref, o_ref):
    o_ref[...] = q_ref[...].astype(jnp.float32) \
        * _exact_exp2(-f_ref[...].astype(jnp.float32))


def _kv_attention_kernel(q_ref, km_ref, kf_ref, vm_ref, vf_ref, mask_ref,
                         pf_ref, o_ref, *, scale, packed, hd, use_pf):
    qc = q_ref[0, 0]                                # [SG, hd] fp32
    km = km_ref[0, 0]                               # [W, hdm] int8
    vm = vm_ref[0, 0]
    if packed:
        km = _unpack_math(km, hd)
        vm = _unpack_math(vm, hd)
    kf = kf_ref[0, 0, 0].astype(jnp.float32)        # [W]
    vf = vf_ref[0, 0, 0].astype(jnp.float32)
    maskb = mask_ref[0] != 0                        # [SG, W]
    # k dequant folds into the score's slot axis: a [1, W] row broadcast
    s = jax.lax.dot_general(
        qc, km.astype(jnp.float32), (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)         # [SG, W]
    s = s * (_exact_exp2(-kf)[None, :] * scale)
    s = jnp.where(maskb, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    pt = jnp.exp(s - m)
    pt = jnp.where(maskb, pt, 0.0)
    if use_pf:
        # quantize_inference on the probs grid: floor(p * 2^f + 0.5) * 2^-f
        pf = _exact_exp2(jnp.floor(pf_ref[0, 0] + 0.5))
        pt = jnp.floor(pt * pf + 0.5) / pf
    l = jnp.sum(pt, axis=-1, keepdims=True)
    pv = (pt / jnp.maximum(l, 1e-20)) * _exact_exp2(-vf)[None, :]
    o_ref[0, 0] = jnp.dot(pv, vm.astype(jnp.float32),
                          preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bits", "block_rows",
                                             "interpret"))
def kv_quantize_rows(rows: jax.Array, *, bits: int = 8,
                     block_rows: int = DEFAULT_BLOCK_ROWS,
                     interpret: bool = True):
    """[R, hd] fp32 rows -> (int8 mantissas [R, hd], int8 grid exponents
    [R]); hd must be lane-aligned (ops.py pads with zeros, which never
    move a row's amax)."""
    from ..qmatmul.ops import mantissa_max
    R, P = rows.shape
    assert P % LANE == 0, f"cols {P} must be lane-aligned"
    br = min(block_rows, R)
    grid = (pl.cdiv(R, br),)
    kern = functools.partial(_kv_quantize_kernel,
                             qmax=float(mantissa_max(bits)))
    tile = pl.BlockSpec((br, P), lambda i: (i, 0))
    col = pl.BlockSpec((br, 1), lambda i: (i, 0))
    q, f = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[tile],
        out_specs=[tile, col],
        out_shape=[jax.ShapeDtypeStruct((R, P), jnp.int8),
                   jax.ShapeDtypeStruct((R, 1), jnp.int8)],
        interpret=interpret,
    )(rows.astype(jnp.float32))
    return q, f[:, 0]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def kv_dequant_rows(q: jax.Array, f: jax.Array, *,
                    block_rows: int = DEFAULT_BLOCK_ROWS,
                    interpret: bool = True) -> jax.Array:
    """[R, hd] int8 mantissas + [R] int8 exponents -> fp32 ``q * 2^-f``."""
    R, P = q.shape
    assert P % LANE == 0, f"cols {P} must be lane-aligned"
    br = min(block_rows, R)
    grid = (pl.cdiv(R, br),)
    tile = pl.BlockSpec((br, P), lambda i: (i, 0))
    col = pl.BlockSpec((br, 1), lambda i: (i, 0))
    return pl.pallas_call(
        _kv_dequant_kernel,
        grid=grid,
        in_specs=[tile, col],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((R, P), jnp.float32),
        interpret=interpret,
    )(q, f.reshape(R, 1))


@functools.partial(jax.jit, static_argnames=("scale", "packed", "use_pf",
                                             "interpret"))
def kv_attention_rows(qg: jax.Array, km: jax.Array, kf: jax.Array,
                      vm: jax.Array, vf: jax.Array, mask: jax.Array,
                      pf: jax.Array, *, scale: float, packed: bool,
                      use_pf: bool, interpret: bool = True):
    """Fused dequant-attention decode read, one (batch row, kv head) per
    grid cell.

    ``qg`` [B, KV, SG, hd] fp32 (SG = query rows x grouped heads, each
    query row repeated G times); ``km``/``vm`` [B, KV, W, hdm] int8
    mantissas (hdm = hd, or hd // 2 nibble-packed); ``kf``/``vf``
    [B, KV, 1, W] int8 slot exponents; ``mask`` [B, SG, W] int8
    (0 = slot invisible to that query row); ``pf`` [1, 1] fp32 probs
    grid exponent (read iff ``use_pf``).  W and hd lane-aligned
    (ops.py pads; padded slots carry mask 0).  Returns [B, KV, SG, hd]
    fp32 attention outputs.
    """
    B, KV, SG, HD = qg.shape
    W = km.shape[2]
    assert HD % LANE == 0 and W % LANE == 0, (HD, W)
    hdm = km.shape[3]
    kern = functools.partial(_kv_attention_kernel, scale=scale,
                             packed=packed, hd=HD, use_pf=use_pf)
    q_spec = pl.BlockSpec((1, 1, SG, HD), lambda b, k: (b, k, 0, 0))
    m_spec = pl.BlockSpec((1, 1, W, hdm), lambda b, k: (b, k, 0, 0))
    f_spec = pl.BlockSpec((1, 1, 1, W), lambda b, k: (b, k, 0, 0))
    mask_spec = pl.BlockSpec((1, SG, W), lambda b, k: (b, 0, 0))
    pf_spec = pl.BlockSpec((1, 1), lambda b, k: (0, 0))
    return pl.pallas_call(
        kern,
        grid=(B, KV),
        in_specs=[q_spec, m_spec, f_spec, m_spec, f_spec, mask_spec,
                  pf_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, SG, HD), jnp.float32),
        interpret=interpret,
    )(qg.astype(jnp.float32), km, kf, vm, vf, mask,
      pf.reshape(1, 1).astype(jnp.float32))
