"""Reference math of the quantized-KV-cache family.

Pure jnp, and *definitionally* the semantics of the serving quantized KV
cache: ``kv_quantize_ref`` is the per-row (token x kv-head) 2^-f grid
store — amax over the head dim picks the capped grid exponent of
``kernels.qmatmul.ops.grid_exponent``, mantissas saturate at
``mantissa_max(bits)`` — and ``kv_attention_ref`` is the decode
attention read over dequantized mantissas, the exact expression of
``nn.attention._decode_attention`` with the dequant fused in front.
Off-TPU this IS the fast path — XLA fuses dequant into the attention
einsums — while ``kernel.py`` is the single-VMEM-pass Pallas
realization; tests/test_kv_dequant.py pins the elementwise kernels
bit-identical and the fused attention read numerically tight against
these.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ...core.quantizer import _exp2i, quantize_inference
from ..qmatmul.ops import (grid_exponent, mantissa_max, pack_nibbles,
                           unpack_nibbles)

NEG_INF = -1e30


def kv_grid_exponent(rows: jax.Array, bits: int) -> jax.Array:
    """Per-row grid exponent ``f`` for ``[..., hd]`` k/v rows: amax over
    the head dim -> the capped 2^-f grid of ``qmatmul.grid_exponent``."""
    amax = jnp.max(jnp.abs(rows.astype(jnp.float32)), axis=-1)
    return grid_exponent(amax, bits)


def kv_quantize_ref(rows: jax.Array, bits: int
                    ) -> Tuple[jax.Array, jax.Array]:
    """``[..., hd]`` fp rows -> (int8 mantissas ``[..., hd]``, int8 grid
    exponents ``[...]``).  ``x ~ mantissa * 2^-f`` with the per-row f
    chosen so amax fits in +-``mantissa_max(bits)``."""
    f = kv_grid_exponent(rows, bits)
    qmax = mantissa_max(bits)
    q = jnp.clip(jnp.round(rows.astype(jnp.float32) * _exp2i(f)[..., None]),
                 -qmax, qmax).astype(jnp.int8)
    return q, f.astype(jnp.int8)


def kv_dequant_ref(q: jax.Array, f: jax.Array) -> jax.Array:
    """(int8 mantissas ``[..., hd]``, int8 exponents ``[...]``) -> fp32
    ``q * 2^-f`` — the one decode expression every reader shares."""
    return q.astype(jnp.float32) * _exp2i(-f.astype(jnp.float32))[..., None]


def kv_pack_ref(q: jax.Array) -> jax.Array:
    """Nibble-pack int4-range mantissas two per stored byte along the
    head dim (``kv_bits <= 4`` storage format; hd is even by RoPE)."""
    return pack_nibbles(q, axis=-1)


def kv_unpack_ref(packed: jax.Array, hd: int) -> jax.Array:
    """Inverse of :func:`kv_pack_ref`: ``[..., hd // 2]`` bytes ->
    ``[..., hd]`` sign-extended int8 mantissas."""
    return unpack_nibbles(packed, hd, axis=-1)


def kv_attention_ref(qg: jax.Array, km: jax.Array, kf: jax.Array,
                     vm: jax.Array, vf: jax.Array, qpos: jax.Array,
                     tpos: jax.Array, *, window: Optional[int],
                     probs_f: Optional[jax.Array] = None) -> jax.Array:
    """Decode attention over a quantized ring cache, dequant fused.

    ``qg`` [B, S, KV, G, hd] fp queries; ``km``/``vm`` [B, W, KV, hd]
    int8 mantissas (or [B, W, KV, hd//2] nibble-packed when ``hd`` does
    not match); ``kf``/``vf`` [B, W, KV] int8 grid exponents; ``qpos``
    [B, S] global query positions; ``tpos`` [B, W] global position per
    cache slot (negative = never written).  Math is expression-for-
    expression ``nn.attention._decode_attention`` on the dequantized
    cache, so the fp and quantized paths differ only by the storage
    grid.
    """
    B, S, KV, G, hd = qg.shape
    if km.shape[-1] != hd:
        km = kv_unpack_ref(km, hd)
        vm = kv_unpack_ref(vm, hd)
    k_all = kv_dequant_ref(km, kf)                # [B, W, KV, hd] fp32
    v_all = kv_dequant_ref(vm, vf)
    scale = hd ** -0.5
    s = jnp.einsum("bskgh,btkh->bkgst", qg.astype(jnp.float32), k_all,
                   preferred_element_type=jnp.float32) * scale
    mask = (tpos[:, None, :] <= qpos[:, :, None]) & (tpos[:, None, :] >= 0)
    if window is not None:
        mask &= (qpos[:, :, None] - tpos[:, None, :]) < window
    mask = mask[:, None, None]                    # [B, 1, 1, S, T]
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    pt = jnp.exp(s - m)
    pt = jnp.where(mask, pt, 0.0)
    if probs_f is not None:
        pt = quantize_inference(pt, probs_f)
    l = jnp.sum(pt, axis=-1, keepdims=True)
    o = jnp.einsum("bkgst,btkh->bskgh", pt / jnp.maximum(l, 1e-20), v_all,
                   preferred_element_type=jnp.float32)
    return o.astype(qg.dtype)
