"""HGQ glue: quantized tensors, activation-range state, aux accumulation.

Every quantized layer in ``repro.nn`` speaks this protocol:

* Weights carry a trainable fractional-bit tensor ``f`` next to the weight
  (params subtree ``{'w': ..., 'hgq_f': ...}``).
* Activations carry a trainable ``f`` plus a *non-trainable* running range
  state ``(vmin, vmax)`` (the "realized min/max within the epoch" of
  SSec. III.D.2), threaded functionally through the forward pass.
* Each multiplicative op contributes its ~EBOPs term; each quantizer its L1
  term (Eq. 16).  These accumulate in an :class:`Aux` value returned beside
  the layer output — scan-over-layers simply sums the carried Aux.

Modes:
  TRAIN  — quantize with surrogate gradients, update ranges with slow-decay
           running extremes.
  CALIB  — exact range accumulation (no decay) for Eq.-3 calibration.
  EVAL   — quantize, frozen ranges.
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from . import ebops as ebops_lib
from .quantizer import (grad_scale, quantize, quantize_inference, sg,
                        train_bits)

TRAIN, CALIB, EVAL = "train", "calib", "eval"

# decay used by the running extremes in TRAIN mode: old extremes shrink
# toward zero slowly so stale outliers fade (approximates per-epoch min/max)
RANGE_DECAY = 0.999


class QTensor(NamedTuple):
    """A value plus its differentiable bitwidth estimate (or None if the
    value is unquantized — bits None disables EBOPs accounting downstream)."""
    q: jax.Array
    bits: Optional[jax.Array]  # broadcastable to q's *feature* dims


class ActState(NamedTuple):
    vmin: jax.Array
    vmax: jax.Array


@dataclasses.dataclass
class Aux:
    """Per-forward accumulator (a plain pytree-able triple)."""
    ebops: jax.Array
    l1: jax.Array

    @staticmethod
    def zero() -> "Aux":
        return Aux(jnp.float32(0.0), jnp.float32(0.0))

    def add(self, ebops=None, l1=None) -> None:
        if ebops is not None:
            self.ebops = self.ebops + ebops
        if l1 is not None:
            self.l1 = self.l1 + l1

    def merge(self, other: "Aux") -> None:
        self.ebops = self.ebops + other.ebops
        self.l1 = self.l1 + other.l1

    def as_tuple(self) -> Tuple[jax.Array, jax.Array]:
        return (self.ebops, self.l1)


def init_act_state(f_sh) -> ActState:
    return ActState(vmin=jnp.zeros(f_sh, jnp.float32),
                    vmax=jnp.zeros(f_sh, jnp.float32))


def _feature_extremes(x: jax.Array, f_sh) -> Tuple[jax.Array, jax.Array]:
    """Reduce x over batch/broadcast axes down to the f shape."""
    f_sh = tuple(f_sh)
    x32 = sg(jnp.asarray(x, jnp.float32))
    nd = x32.ndim
    padded = (1,) * (nd - len(f_sh)) + f_sh
    axes = tuple(i for i in range(nd) if padded[i] == 1)
    vmin = jnp.min(x32, axis=axes, keepdims=True).reshape(f_sh)
    vmax = jnp.max(x32, axis=axes, keepdims=True).reshape(f_sh)
    return vmin, vmax


def observe(x: jax.Array, state: ActState, mode: str) -> ActState:
    """Update the running activation extremes."""
    vmin_b, vmax_b = _feature_extremes(x, state.vmin.shape)
    if mode == CALIB:
        return ActState(jnp.minimum(state.vmin, vmin_b),
                        jnp.maximum(state.vmax, vmax_b))
    if mode == TRAIN:
        return ActState(jnp.minimum(state.vmin * RANGE_DECAY, vmin_b),
                        jnp.maximum(state.vmax * RANGE_DECAY, vmax_b))
    return state


# ---------------------------------------------------------------------------
# Weight / activation quantizer application
# ---------------------------------------------------------------------------

def quant_weight(w: jax.Array, f: Optional[jax.Array],
                 mode: str = TRAIN) -> QTensor:
    """Quantize a weight; bits via Eq.-3 on the per-group weight extremes.

    The regularizer gradient on f is normalized by 1/sqrt(||g||)
    (SSec. III.D.3) — applied on the *bits* path only, so the loss-path
    surrogate gradient (through ``quantize``) is untouched.
    """
    if f is None:
        return QTensor(w, None)
    wq = quantize(w, f) if mode == TRAIN else quantize_inference(w, f)
    vmin, vmax = _feature_extremes(w, f.shape)
    gsize = _gsize(w.shape, f.shape)
    f_reg = grad_scale(f, 1.0 / math.sqrt(gsize))
    bits = train_bits(f_reg, vmin, vmax, signed_bit=False)
    return QTensor(wq, bits)


def quant_act(x: jax.Array, f: Optional[jax.Array], state: Optional[ActState],
              mode: str, aux: Aux, gamma_l1: bool = True
              ) -> Tuple[QTensor, Optional[ActState]]:
    """Quantize an activation; update range state; add L1 bit regularizer."""
    if f is None:
        return QTensor(x, None), state
    xq = quantize(x, f) if mode == TRAIN else quantize_inference(x, f)
    new_state = observe(x, state, mode) if state is not None else None
    if new_state is not None:
        bits = train_bits(grad_scale(f, 1.0 / math.sqrt(_gsize(x.shape, f.shape))),
                          new_state.vmin, new_state.vmax, signed_bit=True)
    else:
        bits = jax.nn.relu(f) + 1.0
    if gamma_l1:
        aux.add(l1=ebops_lib.l1_bits(jax.nn.relu(f)))
    return QTensor(xq, bits), new_state


def _gsize(value_shape, f_sh) -> float:
    n_val = math.prod(value_shape) if value_shape else 1
    n_f = math.prod(f_sh) if f_sh else 1
    # activations: group size counts feature multiplicity, not batch
    return max(float(n_val) / float(n_f), 1.0)


def matmul_ebops(aux: Aux, x_bits, w_bits, in_dim: int, out_dim: int) -> None:
    """Record ~EBOPs of a dense matmul if both operands are quantized."""
    if x_bits is None or w_bits is None:
        return
    aux.add(ebops=ebops_lib.ebops_matmul(x_bits, w_bits, in_dim, out_dim))


def dyn_matmul_ebops(aux: Aux, a_bits, b_bits, a_shape, b_shape) -> None:
    if a_bits is None or b_bits is None:
        return
    aux.add(ebops=ebops_lib.ebops_dyn_matmul(a_bits, b_bits, a_shape, b_shape))
