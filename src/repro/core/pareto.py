"""EBOPs-vs-metric Pareto-front checkpoint tracker (paper SSec. V).

The paper recovers the whole accuracy/resource trade-off curve from a single
training run by checkpointing every epoch that lands on the running Pareto
front of (validation metric, EBOPs).  This module implements that tracker.

``better_metric``: 'max' (accuracy) or 'min' (resolution / loss).
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Tuple


@dataclasses.dataclass
class ParetoPoint:
    metric: float
    ebops: float
    step: int
    payload: Any = None  # e.g. a checkpoint path or params snapshot


class ParetoFront:
    def __init__(self, better_metric: str = "max"):
        assert better_metric in ("max", "min")
        self.sign = 1.0 if better_metric == "max" else -1.0
        self.points: List[ParetoPoint] = []

    def _dominates(self, a: ParetoPoint, b: ParetoPoint) -> bool:
        """a dominates b: no worse on both axes, strictly better on one."""
        am, bm = self.sign * a.metric, self.sign * b.metric
        return (am >= bm and a.ebops <= b.ebops
                and (am > bm or a.ebops < b.ebops))

    def offer(self, metric: float, ebops: float, step: int,
              payload: Any = None) -> bool:
        """Insert if non-dominated; prune anything the new point dominates.
        Returns True iff the point joined the front (=> checkpoint it)."""
        cand = ParetoPoint(float(metric), float(ebops), int(step), payload)
        for p in self.points:
            if self._dominates(p, cand) or (p.metric == cand.metric
                                            and p.ebops == cand.ebops):
                return False
        self.points = [p for p in self.points if not self._dominates(cand, p)]
        self.points.append(cand)
        self.points.sort(key=lambda p: p.ebops)
        return True

    def front(self) -> List[Tuple[float, float, int]]:
        return [(p.metric, p.ebops, p.step) for p in self.points]

    def best(self, max_ebops: Optional[float] = None) -> Optional[ParetoPoint]:
        elig = [p for p in self.points
                if max_ebops is None or p.ebops <= max_ebops]
        if not elig:
            return None
        return max(elig, key=lambda p: self.sign * p.metric)
