"""EBOPs-vs-metric Pareto-front checkpoint tracker (paper SSec. V).

The paper recovers the whole accuracy/resource trade-off curve from a single
training run by checkpointing every epoch that lands on the running Pareto
front of (validation metric, EBOPs).  This module implements that tracker.

``better_metric``: 'max' (accuracy) or 'min' (resolution / loss).

Fronts serialize to JSON (``to_json``/``from_json``) so a sweep's
accuracy/EBOPs curve — including per-point ``core.plan.PrecisionPlan``
payloads — survives the run that produced it; ``api.spec`` turns such a
front into ready-to-run RunSpec+plan files.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, List, Optional, Tuple


@dataclasses.dataclass
class ParetoPoint:
    metric: float
    ebops: float
    step: int
    payload: Any = None  # e.g. a checkpoint path or params snapshot


class ParetoFront:
    def __init__(self, better_metric: str = "max"):
        assert better_metric in ("max", "min")
        self.sign = 1.0 if better_metric == "max" else -1.0
        self.points: List[ParetoPoint] = []

    def _dominates(self, a: ParetoPoint, b: ParetoPoint) -> bool:
        """a dominates b: no worse on both axes, strictly better on one."""
        am, bm = self.sign * a.metric, self.sign * b.metric
        return (am >= bm and a.ebops <= b.ebops
                and (am > bm or a.ebops < b.ebops))

    def offer(self, metric: float, ebops: float, step: int,
              payload: Any = None) -> bool:
        """Insert if non-dominated; prune anything the new point dominates.
        Returns True iff the point joined the front (=> checkpoint it)."""
        cand = ParetoPoint(float(metric), float(ebops), int(step), payload)
        for p in self.points:
            if self._dominates(p, cand) or (p.metric == cand.metric
                                            and p.ebops == cand.ebops):
                return False
        self.points = [p for p in self.points if not self._dominates(cand, p)]
        self.points.append(cand)
        self.points.sort(key=lambda p: p.ebops)
        return True

    def front(self) -> List[Tuple[float, float, int]]:
        return [(p.metric, p.ebops, p.step) for p in self.points]

    def best(self, max_ebops: Optional[float] = None) -> Optional[ParetoPoint]:
        """Best-metric point within the EBOPs budget; metric ties break
        toward the cheaper (lower-EBOPs) point — the front is the set of
        equally-accurate models, so under a resource metric the cheapest
        one is the right checkpoint to deploy."""
        elig = [p for p in self.points
                if max_ebops is None or p.ebops <= max_ebops]
        if not elig:
            return None
        return max(elig, key=lambda p: (self.sign * p.metric, -p.ebops))

    # --------------------------- serialization ---------------------------

    def to_dict(self) -> dict:
        """JSON view.  Payloads serialize when they are a
        ``core.plan.PrecisionPlan`` (the sweep's per-point width tables)
        or already JSON-native; anything else drops to ``None`` (a live
        params snapshot is not a checkpointable artifact)."""
        from .plan import PrecisionPlan

        def payload(p: Any) -> Any:
            if isinstance(p, PrecisionPlan):
                return {"plan": p.to_dict()}
            if p is None or isinstance(p, (str, int, float, bool)):
                return p
            return None

        return {
            "better_metric": "max" if self.sign > 0 else "min",
            "points": [{"metric": p.metric, "ebops": p.ebops,
                        "step": p.step, "payload": payload(p.payload)}
                       for p in self.points],
        }

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True) + "\n"

    @classmethod
    def from_dict(cls, d: dict) -> "ParetoFront":
        from .plan import PrecisionPlan
        front = cls(d["better_metric"])
        for row in d["points"]:
            pay = row.get("payload")
            if isinstance(pay, dict) and set(pay) == {"plan"}:
                pay = PrecisionPlan.from_dict(pay["plan"])
            front.points.append(ParetoPoint(
                float(row["metric"]), float(row["ebops"]),
                int(row["step"]), pay))
        front.points.sort(key=lambda p: p.ebops)
        return front

    @classmethod
    def from_json(cls, s: str) -> "ParetoFront":
        return cls.from_dict(json.loads(s))
