"""Post-training calibration (paper SSec. III.A, Eq. 3).

After QAT, integer bitwidths are fixed by running a calibration dataset
through the network in CALIB mode (exact running extremes), then

    i' = max( floor(log2 |vmax_q|) + 1,  ceil(log2 |vmin_q|) )
    i  = i' + 1  (signed)   |   i' (unsigned)

Optionally pad the computed range by ``margin_bits`` powers of two for
outlier safety.  The result is a :class:`FixedSpec` per quantizer — total
bits ``b`` and integer bits ``i`` — consumed by the bit-exact fixed-point
emulation (``repro.core.fixedpoint``) and by the exact-EBOPs reporter.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .hgq import ActState
from .quantizer import (_exp2i, ceil_log2, floor_log2,
                        quantize_inference)


class FixedSpec(NamedTuple):
    """A concrete fixed-point type fixed<b, i> (AMD HLS convention: the sign
    bit, when present, is part of the integer bits)."""
    bits: jax.Array      # total bitwidth b  (>= 0; 0 == pruned / constant 0)
    int_bits: jax.Array  # integer bits i (incl. sign bit if signed)
    signed: jax.Array    # bool


def int_bits_exact(vmin: jax.Array, vmax: jax.Array,
                   f: jax.Array, margin_bits: float = 0.0):
    """Eq. (3) on *quantized* extremes, in exact numpy-friendly form."""
    fi = jnp.floor(jnp.asarray(f, jnp.float32) + 0.5)
    vmin_q = quantize_inference(jnp.asarray(vmin, jnp.float32), fi)
    vmax_q = quantize_inference(jnp.asarray(vmax, jnp.float32), fi)
    if margin_bits:
        vmin_q = vmin_q * (2.0 ** margin_bits)
        vmax_q = vmax_q * (2.0 ** margin_bits)
    # frexp-exact log2: jnp.log2 is an ulp low at e.g. 2^13 on some
    # backends, which would allocate one integer bit too few and saturate
    # the largest calibration value at deployment
    hi = jnp.where(vmax_q > 0,
                   floor_log2(jnp.maximum(jnp.abs(vmax_q), 2.0 ** -126))
                   + 1.0, -127.0)
    lo = jnp.where(vmin_q < 0,
                   ceil_log2(jnp.maximum(jnp.abs(vmin_q), 2.0 ** -126)),
                   -127.0)
    return jnp.maximum(hi, lo)


def fixed_spec_from_range(state: ActState, f: jax.Array,
                          margin_bits: float = 0.0) -> FixedSpec:
    """Build the deployable fixed-point type for one quantizer."""
    fi = jnp.floor(jnp.asarray(f, jnp.float32) + 0.5)
    ip = int_bits_exact(state.vmin, state.vmax, fi, margin_bits)
    signed = state.vmin < 0
    i = jnp.where(signed, ip + 1.0, ip)
    b = jnp.maximum(i + fi, 0.0)
    # a value whose range collapsed to {0} needs no bits at all
    dead = (state.vmax <= 0) & (state.vmin >= 0)
    b = jnp.where(dead, 0.0, b)
    return FixedSpec(bits=b, int_bits=jnp.where(dead, 0.0, i), signed=signed)


def fixed_spec_for_weights(w: jax.Array, f: jax.Array,
                           f_sh=None) -> FixedSpec:
    """Weights are constants — their range is known exactly post-training."""
    f_sh = f.shape if f_sh is None else f_sh
    from .hgq import _feature_extremes
    vmin, vmax = _feature_extremes(w, f_sh)
    return fixed_spec_from_range(ActState(vmin, vmax), f)


def assert_no_overflow(x: jax.Array, spec: FixedSpec, f: jax.Array) -> jax.Array:
    """True iff every element of x (quantized at f) is representable by spec.

    Used by tests to verify the calibration guarantee: running the calib
    data through a calibrated model never overflows.
    """
    fi = jnp.floor(jnp.asarray(f, jnp.float32) + 0.5)
    xq = quantize_inference(jnp.asarray(x, jnp.float32), fi)
    frac = fi
    top = (_exp2i(spec.int_bits - spec.signed.astype(jnp.float32))
           - _exp2i(-frac))
    bot = jnp.where(spec.signed,
                    -_exp2i(spec.int_bits - 1.0), 0.0)
    top = jnp.where(spec.bits > 0, top, 0.0)
    bot = jnp.where(spec.bits > 0, bot, 0.0)
    return jnp.all((xq <= top + 1e-9) & (xq >= bot - 1e-9))
