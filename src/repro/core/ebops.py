"""EBOPs — Effective Bit Operations (paper SSec. III.C, Eq. 5).

EBOPs = sum over multiplications of b_i * b_j, where constants (weights)
count their *occupied* bits and variable operands their declared bitwidth.
Accumulations inside a dot-product chain are folded into the multiplication
count, so a dense layer contributes  sum_ij b_x[i] * b_w[i,j].

Two flavours:

* ``ebops_*``   — differentiable ~EBOPs used as the training regularizer:
                  bits = relu(i' + f) from running min/max (upper-bounds the
                  exact EBOPs; paper SSec. III.D.2).
* ``exact_*``   — post-training EBOPs with occupied-bit counting on the
                  quantized weights (used for reporting / Pareto fronts).

All reductions are *separable*:  sum_ij b_x[i] b_w[ij] = <b_x, sum_j b_w>,
so no [in, out] bit tensor is ever materialized — O(N) instead of O(N^2)
memory, which is what makes per-parameter granularity affordable at
LLM scale on TPU (DESIGN.md SS2).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp


def _bsum(bits: jax.Array, full_shape: Sequence[int], axes) -> jax.Array:
    """Sum ``bits`` (broadcastable to full_shape) over ``axes`` of full_shape,
    without materializing the broadcast: multiply by the broadcast multiplicity
    instead."""
    bits = jnp.asarray(bits, jnp.float32)
    full_shape = tuple(full_shape)
    if bits.ndim == 0:
        bits = bits.reshape((1,) * len(full_shape))
    assert bits.ndim == len(full_shape), (bits.shape, full_shape)
    mult = 1.0
    reduce_axes = []
    for ax in axes:
        if bits.shape[ax] == 1 and full_shape[ax] != 1:
            mult *= full_shape[ax]
        else:
            reduce_axes.append(ax)
    out = jnp.sum(bits, axis=tuple(reduce_axes), keepdims=True) if reduce_axes else bits
    return out * mult


def ebops_matmul(bx: jax.Array, bw: jax.Array,
                 in_dim: int, out_dim: int) -> jax.Array:
    """~EBOPs of ``x @ w`` with x:[..., in], w:[in, out].

    ``bx`` broadcastable to [in]; ``bw`` broadcastable to [in, out].
    Returns scalar  sum_ij bx[i] * bw[i, j].
    """
    bx = jnp.asarray(bx, jnp.float32).reshape(-1)  # [in] or [1]
    bw = jnp.asarray(bw, jnp.float32)
    if bw.ndim == 0:
        bw = bw.reshape(1, 1)
    assert bw.ndim == 2, bw.shape
    row = _bsum(bw, (bw.shape[0], out_dim), axes=(1,)).reshape(-1)  # [in] or [1]
    if bx.shape[0] == 1 and row.shape[0] == 1:
        return (bx[0] * row[0]) * in_dim
    if bx.shape[0] == 1:
        return bx[0] * jnp.sum(row)
    if row.shape[0] == 1:
        return row[0] * jnp.sum(bx)
    return jnp.dot(bx, row)


def ebops_conv2d(bx: jax.Array, bw: jax.Array, w_shape: Sequence[int]) -> jax.Array:
    """~EBOPs of a conv2d with kernel [kh, kw, cin, cout].

    Stream-IO counting (paper SSec. V.A / V.C): the physical multipliers are the
    kh*kw*cin*cout kernel weights, applied through a buffer — each counted
    once.  ``bx`` broadcastable to [cin] (activation bits per input channel),
    ``bw`` broadcastable to w_shape.
    """
    kh, kw, cin, cout = w_shape
    bw = jnp.asarray(bw, jnp.float32)
    if bw.ndim == 0:
        bw = bw.reshape(1, 1, 1, 1)
    per_cin = _bsum(bw, (kh, kw, cin, cout), axes=(0, 1, 3)).reshape(-1)  # [cin]|[1]
    bx = jnp.asarray(bx, jnp.float32).reshape(-1)
    if bx.shape[0] == 1 and per_cin.shape[0] == 1:
        return bx[0] * per_cin[0] * cin
    if bx.shape[0] == 1:
        return bx[0] * jnp.sum(per_cin)
    if per_cin.shape[0] == 1:
        return per_cin[0] * jnp.sum(bx)
    return jnp.dot(bx, per_cin)


def ebops_dyn_matmul(ba: jax.Array, bb: jax.Array,
                     a_shape: Sequence[int], b_shape: Sequence[int]) -> jax.Array:
    """~EBOPs of a variable x variable matmul  A[m,k] @ B[k,n]  (e.g. Q.K^T).

    sum_{m,k,n} ba[m,k] * bb[k,n]  =  sum_k (sum_m ba)[k] * (sum_n bb)[k].
    ``ba``/``bb`` broadcastable to a_shape/b_shape (leading batch dims allowed
    and summed).
    """
    m, k = a_shape[-2], a_shape[-1]
    k2, n = b_shape[-2], b_shape[-1]
    assert k == k2, (a_shape, b_shape)
    ba = jnp.asarray(ba, jnp.float32)
    bb = jnp.asarray(bb, jnp.float32)
    ba = ba.reshape((1, 1) if ba.ndim == 0 else ba.shape[-2:])
    bb = bb.reshape((1, 1) if bb.ndim == 0 else bb.shape[-2:])
    a_k = _bsum(ba, (m, k), axes=(0,)).reshape(-1)  # [k] or [1]
    b_k = _bsum(bb, (k, n), axes=(1,)).reshape(-1)
    if a_k.shape[0] == 1 and b_k.shape[0] == 1:
        return a_k[0] * b_k[0] * k
    if a_k.shape[0] == 1:
        return a_k[0] * jnp.sum(b_k)
    if b_k.shape[0] == 1:
        return b_k[0] * jnp.sum(a_k)
    return jnp.dot(a_k, b_k)


def l1_bits(*bit_tensors: jax.Array) -> jax.Array:
    """L1 regularizer on bitwidths (Eq. 16, gamma term) — keeps bits of values
    not feeding any multiplier (last-layer outputs, non-linearity inputs)
    from growing without bound."""
    tot = jnp.float32(0.0)
    for b in bit_tensors:
        tot = tot + jnp.sum(jnp.asarray(b, jnp.float32))
    return tot


def loss_with_resource(base_loss: jax.Array, ebops: jax.Array,
                       l1: jax.Array, beta: jax.Array,
                       gamma: jax.Array) -> jax.Array:
    """Eq. (16):  L = L_base + beta * ~EBOPs + gamma * L1_norm."""
    return base_loss + beta * ebops + gamma * l1


def useful_model_flops_dense(n_params: int, n_tokens: int) -> float:
    """MODEL_FLOPS = 6 * N * D (dense) — for the roofline 'useful compute'
    ratio (brief SSRoofline)."""
    return 6.0 * float(n_params) * float(n_tokens)
