"""Bit-exact fixed-point emulation — the "proxy model" of paper SSec. IV.

Emulates AMD Vivado/Vitis HLS ``fixed<b, i>`` arithmetic, including the
cyclic wrap-around overflow of Eq. (1)/(2), using scaled integers held in
float64 (exact for b <= 52).  This reproduces the paper's guarantee of exact
software/firmware correspondence: when no overflow occurs, the proxy output
equals the QAT-time quantized forward bit for bit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .calibrate import FixedSpec
from .quantizer import _exp2i


def to_fixed(x: jax.Array, spec: FixedSpec, f: jax.Array,
             epsilon: float = 0.5) -> jax.Array:
    """Quantize to fixed<b, i> with Eq. (1)/(2) wrap-around overflow.

    ``f`` is the fractional bitwidth (b - i).  Works elementwise with
    broadcasting; returns float32 values lying exactly on the fixed grid.
    """
    x64 = jnp.asarray(x, jnp.float64) if jax.config.jax_enable_x64 \
        else jnp.asarray(x, jnp.float32)
    fi = jnp.floor(jnp.asarray(f, jnp.float32) + 0.5).astype(x64.dtype)
    b = jnp.asarray(spec.bits, x64.dtype)
    signed = jnp.asarray(spec.signed)
    # exact powers of two: an ulp-off exp2(b) makes the wrap modulus
    # wrong exactly at the +-2^(b-1) boundary (and at b=13, 15, 26, ...)
    m = jnp.floor(x64 * _exp2i(fi) + epsilon)  # [x * 2^f]
    two_b = _exp2i(b)
    half = _exp2i(b - 1.0)
    m_signed = jnp.mod(m + half, two_b) - half          # Eq. (1)
    m_unsigned = jnp.mod(m, two_b)                      # Eq. (2)
    m_wrapped = jnp.where(signed, m_signed, m_unsigned)
    m_wrapped = jnp.where(b > 0, m_wrapped, 0.0)
    return (m_wrapped * _exp2i(-fi)).astype(jnp.float32)


def representable(x: jax.Array, spec: FixedSpec, f: jax.Array) -> jax.Array:
    """Elementwise: is x exactly representable (no wrap) in fixed<b, i>?"""
    y = to_fixed(x, spec, f)
    return jnp.abs(y - jnp.asarray(x, jnp.float32)) < _exp2i(
        -jnp.floor(jnp.asarray(f, jnp.float32) + 0.5) - 1.0)
