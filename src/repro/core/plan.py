"""PrecisionPlan: the learned per-layer width table the whole stack reads.

HGQ trains a fractional bit-width ``f`` per weight (core/hgq.py); EBOPs
(core/ebops.py) turn those bits into the resource axis of the Pareto
front (core/pareto.py).  This module closes the loop for the distributed
and serving layers: a :class:`PrecisionPlan` is a frozen, JSON-exact
per-layer table of

* ``wire_bits``  — payload width of the in-reduction gradient collective
  (``dist.collectives``; 4..8, sub-5-bit leaves ride nibble-packed
  int4 chunks);
* ``pack_bits``  — storage width of the serving weight pack
  (``serving/packed.py`` / ``dist.perf``; <= 4 nibble-packs two
  mantissas per byte);
* ``kv_bits``    — storage width of the serving KV cache rows for the
  layer's attention block (``serving/kvcache.py``; <= 4 nibble-packs two
  mantissas per stored byte, dequantized inside the fused attention
  read of ``kernels/kv_dequant``);
* ``scale_exp``  — the layer's calibrated grid exponent (2^-f), recorded
  for reporting (dry-run cells, plan summaries) — consumers recompute
  their own exact grids.

``plan_from_params`` derives a plan from a trained params tree: per layer,
the occupied mantissa bits under the capped per-channel grid of
``kernels.qmatmul.channel_bits`` decide the width class.  The everywhere-
default plan (``PrecisionPlan()``) is uniform int8 — byte-identical to
the pre-plan behavior, which is what lets ``RunSpec.plan=None`` stay
HLO-exact (tests/test_plan.py).

Like ``api/spec.py`` this module is importable without jax: derivation
helpers import jax lazily, so the plan dataclasses stay pure config.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Iterator, List, Optional, Tuple

MIN_BITS, MAX_BITS = 4, 8
NIBBLE_BITS = 4     # widths <= this pack two mantissas per stored byte


def _check(cond: bool, msg: str) -> None:
    if not cond:
        raise ValueError(msg)


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    """Widths of one layer (a params-tree prefix, e.g. ``d0/kernel``)."""
    wire_bits: int = 8
    pack_bits: int = 8
    scale_exp: Optional[float] = None
    kv_bits: int = 8

    def __post_init__(self):
        for name in ("wire_bits", "pack_bits", "kv_bits"):
            v = getattr(self, name)
            _check(MIN_BITS <= v <= MAX_BITS,
                   f"LayerPlan.{name} must be in "
                   f"[{MIN_BITS}, {MAX_BITS}], got {v!r}")


@dataclasses.dataclass(frozen=True)
class PrecisionPlan:
    """Frozen per-layer width table; ``default`` covers unlisted leaves.

    ``layers`` keys are ``/``-joined params-tree paths (the same keys
    :func:`iter_packable` yields); an entry applies to every leaf at or
    under its path, deepest match winning.  ``PrecisionPlan()`` is the
    uniform-int8 plan — exactly the pre-plan behavior."""
    default: LayerPlan = dataclasses.field(default_factory=LayerPlan)
    layers: Dict[str, LayerPlan] = dataclasses.field(default_factory=dict)

    # ------------------------------ lookup -----------------------------

    def entry_for(self, key: str) -> LayerPlan:
        """The deepest ``layers`` entry whose path is ``key`` or a
        ``/``-prefix of it; ``default`` otherwise."""
        best, best_len = self.default, -1
        for k, entry in self.layers.items():
            if (key == k or key.startswith(k + "/")) and len(k) > best_len:
                best, best_len = entry, len(k)
        return best

    @property
    def is_uniform_int8(self) -> bool:
        """True when every leaf resolves to 8-bit wire and pack — the
        plan is a no-op and consumers take the exact legacy code path."""
        entries = [self.default, *self.layers.values()]
        return all(e.wire_bits == 8 and e.pack_bits == 8 for e in entries)

    def wire_bits_tree(self, tree: Any) -> Any:
        """Matching tree of per-leaf wire widths (plain ints) for a
        params/grads pytree — what ``dist.collectives`` consumes."""
        import jax
        return jax.tree_util.tree_map_with_path(
            lambda path, _: self.entry_for(path_key(path)).wire_bits, tree)

    def kv_bits_for(self, key: str) -> int:
        """KV-cache storage width for an attention layer path (deepest
        ``layers`` match, like :meth:`entry_for`) — what the serving
        quantized KV cache (``serving/kvcache.py``) resolves per model."""
        return self.entry_for(key).kv_bits

    def summary(self) -> Dict[str, Any]:
        """Reporting view (dry-run cells, bench JSONs): the default plus
        every non-default layer's widths."""
        return {
            "default": {"wire_bits": self.default.wire_bits,
                        "pack_bits": self.default.pack_bits,
                        "kv_bits": self.default.kv_bits},
            "layers": {k: {"wire_bits": e.wire_bits,
                           "pack_bits": e.pack_bits,
                           "kv_bits": e.kv_bits}
                       for k, e in sorted(self.layers.items())},
        }

    # --------------------------- serialization -------------------------

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True) + "\n"

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "PrecisionPlan":
        d = dict(d)
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        _check(not unknown, f"unknown PrecisionPlan fields: "
                            f"{sorted(unknown)}")
        entry_known = {f.name for f in dataclasses.fields(LayerPlan)}

        def entry(e: Dict[str, Any]) -> LayerPlan:
            bad = set(e) - entry_known
            _check(not bad, f"unknown LayerPlan fields: {sorted(bad)}")
            return LayerPlan(**e)

        if isinstance(d.get("default"), dict):
            d["default"] = entry(d["default"])
        if isinstance(d.get("layers"), dict):
            d["layers"] = {k: entry(v) for k, v in d["layers"].items()}
        return cls(**d)

    @classmethod
    def from_json(cls, s: str) -> "PrecisionPlan":
        return cls.from_dict(json.loads(s))

    @classmethod
    def from_file(cls, path: str) -> "PrecisionPlan":
        with open(path) as f:
            return cls.from_json(f.read())

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())


def path_key(path) -> str:
    """``tree_flatten_with_path`` key path -> the ``/``-joined plan key
    (``d0/kernel/w``); list indices stringify to their position."""
    from ..dist.sharding import _key_name
    return "/".join(_key_name(k) for k in path)


def packable_weight(name: str, w) -> bool:
    """The one packable-matmul-weight rule, shared with the serving
    walker (``dist.perf``): rank >= 2 floating weights that are not
    biases and not conv kernels."""
    if not hasattr(w, "ndim") or w.ndim < 2:
        return False          # biases, norm gains, scalars
    import jax.numpy as jnp   # lazy: keeps the plan dataclasses jax-free
    if not hasattr(w, "dtype") or not jnp.issubdtype(w.dtype, jnp.floating):
        return False
    if name == "bias":
        return False          # stacked biases are [L, d] but not matmuls
    if name == "kernel" and w.ndim >= 4:
        return False          # conv kernels: HConv2D reads 'w' directly
    return True


def iter_packable(params: Any) -> Iterator[Tuple[str, Dict[str, Any]]]:
    """Yield ``(plan_key, weight_dict)`` for every packable matmul weight
    dict ``{'w', 'f'?}`` in a params tree, in walk order.  The keys are
    exactly the paths :meth:`PrecisionPlan.entry_for` matches against."""
    def walk(obj, prefix: Tuple[str, ...]):
        if isinstance(obj, dict):
            name = prefix[-1] if prefix else ""
            if "w" in obj and packable_weight(name, obj["w"]):
                yield "/".join(prefix), obj
                return
            for k, v in obj.items():
                yield from walk(v, prefix + (str(k),))
        elif isinstance(obj, list):
            for i, v in enumerate(obj):
                yield from walk(v, prefix + (str(i),))
    yield from walk(params, ())


# ---------------------------------------------------------------------------
# derivation from a trained model
# ---------------------------------------------------------------------------

def layer_occupied_bits(w, f=None) -> int:
    """Mantissa bits one layer actually occupies on the capped per-channel
    grid of ``kernels.qmatmul.channel_bits``: the widest channel's
    ``|mantissa|`` plus the sign bit.  An int in [1, 8]."""
    import jax.numpy as jnp
    from ..kernels.qmatmul.ops import channel_bits
    from .quantizer import _exp2i
    w32 = jnp.asarray(w, jnp.float32)
    fi = channel_bits(w32, None if f is None else jnp.asarray(f))
    amax = jnp.max(jnp.abs(w32), axis=-2)
    # _exp2i, not jnp.exp2: the occupied-bits count must round on the
    # exact power-of-two grid the kernel quantizes on
    m = int(jnp.max(jnp.floor(amax * _exp2i(fi) + 0.5)))
    return max(int(m).bit_length() + 1, 1)


def plan_from_params(params: Any, *, low_bits: int = 4,
                     threshold: Optional[int] = None) -> PrecisionPlan:
    """Derive a :class:`PrecisionPlan` from a trained params tree.

    Per packable layer: the occupied bits under the trained (HGQ ``f``)
    grid decide the width class — at or below ``threshold`` (default
    ``low_bits``) the layer gets ``low_bits`` wire AND pack width,
    everything else stays int8.  ``scale_exp`` records the layer's max
    per-channel grid exponent for reporting.  Unlisted leaves (biases,
    norms, activation ``f``) keep the 8-bit default."""
    import jax.numpy as jnp
    from ..kernels.qmatmul.ops import channel_bits
    _check(MIN_BITS <= low_bits <= MAX_BITS,
           f"low_bits must be in [{MIN_BITS}, {MAX_BITS}], got {low_bits!r}")
    thr = low_bits if threshold is None else threshold
    layers: Dict[str, LayerPlan] = {}
    for key, p in iter_packable(params):
        w = jnp.asarray(p["w"], jnp.float32)
        f = p.get("f")
        b = layer_occupied_bits(w, f)
        fi = channel_bits(w, None if f is None else jnp.asarray(f))
        exp = float(jnp.max(fi))
        bits = low_bits if b <= thr else 8
        layers[key] = LayerPlan(wire_bits=bits, pack_bits=bits,
                                scale_exp=exp)
    return PrecisionPlan(layers=layers)


def mixed_low_plan(params: Any, low_bits: int = 4) -> PrecisionPlan:
    """Every packable matmul layer at ``low_bits``, everything else at the
    8-bit default — the maximal mixed plan a params tree supports (used
    by the mixed-precision bench section and the golden example plan)."""
    layers = {key: LayerPlan(wire_bits=low_bits, pack_bits=low_bits)
              for key, _ in iter_packable(params)}
    return PrecisionPlan(layers=layers)


def sweep_plans(front, payload_plan=lambda p: p
                ) -> List[Tuple[float, float, int, Optional[PrecisionPlan]]]:
    """Flatten a ``core.pareto.ParetoFront`` into
    ``(metric, ebops, step, plan)`` rows, extracting each point's plan
    payload (``payload_plan`` maps a payload to its plan, identity by
    default; non-plan payloads yield ``None``)."""
    rows = []
    for p in front.points:
        plan = payload_plan(p.payload)
        rows.append((p.metric, p.ebops, p.step,
                     plan if isinstance(plan, PrecisionPlan) else None))
    return rows
