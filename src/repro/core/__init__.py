"""HGQ core: trainable-bitwidth quantization (the paper's contribution)."""
from .quantizer import (LN2, QuantizerSpec, f_shape_for, grad_scale,
                        group_size, group_occupied_bits, int_bits_from_range,
                        occupied_bits, quantize, quantize_inference, sg,
                        ste_round, train_bits)
from .ebops import (ebops_conv2d, ebops_dyn_matmul, ebops_matmul, l1_bits,
                    loss_with_resource)
from .hgq import (Aux, ActState, QTensor, TRAIN, CALIB, EVAL, init_act_state,
                  matmul_ebops, dyn_matmul_ebops, observe, quant_act,
                  quant_weight)
from .calibrate import (FixedSpec, assert_no_overflow, fixed_spec_for_weights,
                        fixed_spec_from_range, int_bits_exact)
from .fixedpoint import representable, to_fixed
from .pareto import ParetoFront, ParetoPoint
from .schedule import constant, linear_warmup_cosine, log_ramp
