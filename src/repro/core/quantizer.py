"""HGQ fixed-point quantizer with gradient-trainable fractional bitwidths.

Implements Algorithm 1 of the paper:

    f   <- ste(f_fp)                          # STE on the (float) bitwidth
    x_q <- sg(round(x * 2^f) * 2^-f)          # Eq. (4) quantization
    d   <- sg(x - x_q)                        # quantization error delta_f
    d   <- sg(d + ln2 * f * d) - ln2 * f * d  # surrogate grad  d(delta)/df = -ln2*delta
    x_q <- x - d                              # STE in x, surrogate grad in f

so that  d(x_q)/dx = 1  (straight-through) and  d(x_q)/df_fp = +ln2 * delta
(Eq. (15)).  Integer bits are *not* tracked during training (Eq. 4); they are
fixed post-hoc by calibration (see `repro.core.calibrate`).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

LN2 = 0.6931471805599453

sg = jax.lax.stop_gradient


def ste_round(x: jax.Array, epsilon: float = 0.5) -> jax.Array:
    """Round-to-integer with a straight-through gradient (QKeras convention).

    ``[x] = floor(x + eps)`` with midpoint round-up at eps=1/2 (Eq. 1 footnote).
    """
    return x + sg(jnp.floor(x + epsilon) - x)


def grad_scale(x: jax.Array, scale) -> jax.Array:
    """Identity in the forward pass; multiplies the gradient by ``scale``.

    Used for the 1/sqrt(||g||) normalization of the regularizer gradient on
    shared bitwidths (paper SSec. III.D.3).
    """
    return x * scale + sg(x * (1.0 - scale))


def quantize(x: jax.Array, f: jax.Array, epsilon: float = 0.5) -> jax.Array:
    """HGQ Algorithm-1 quantizer. Differentiable in ``x`` (STE) and ``f``.

    ``f`` broadcasts against ``x`` (per-tensor scalar, per-channel, or full
    per-parameter shape).  Math is done in float32 regardless of x dtype so
    the fixed-point grid is exact, and cast back at the end.
    """
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    fi = ste_round(f.astype(jnp.float32))
    # _exp2i, not jnp.exp2: XLA's exp2 is an ulp off at e.g. fi=13, which
    # would put "quantized" values slightly off the fixed-point grid.  The
    # grid scale sits inside sg(), so the int cast never blocks gradients.
    scale = _exp2i(sg(fi))
    xq = sg(jnp.floor(x32 * scale + epsilon) / scale)
    delta = sg(x32 - xq)
    delta = sg(delta + LN2 * fi * delta) - LN2 * fi * delta
    return (x32 - delta).astype(dtype)


def quantize_inference(x: jax.Array, f: jax.Array, epsilon: float = 0.5) -> jax.Array:
    """Pure (non-differentiable) Eq.-(4) quantization: round(x*2^f)*2^-f."""
    x32 = x.astype(jnp.float32)
    fi = jnp.floor(f.astype(jnp.float32) + 0.5)
    scale = _exp2i(fi)
    return (jnp.floor(x32 * scale + epsilon) / scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# Granularity / parameter groups
# ---------------------------------------------------------------------------

Granularity = str  # 'per_tensor' | 'per_channel' | 'per_parameter'

_GRANULARITIES = ("per_tensor", "per_channel", "per_parameter")


def f_shape_for(shape: Sequence[int], granularity: Granularity,
                channel_axis: int = -1) -> Tuple[int, ...]:
    """Shape of the trainable fractional-bit tensor for a value of ``shape``.

    per_tensor    -> ()            one shared bitwidth
    per_channel   -> broadcastable along ``channel_axis`` only
    per_parameter -> same shape as the value (maximum granularity)
    """
    if granularity not in _GRANULARITIES:
        raise ValueError(f"unknown granularity {granularity!r}")
    shape = tuple(shape)
    if granularity == "per_tensor" or not shape:
        return ()
    if granularity == "per_parameter":
        return shape
    ax = channel_axis % len(shape)
    return tuple(d if i == ax else 1 for i, d in enumerate(shape))


def group_size(value_shape: Sequence[int], f_sh: Sequence[int]) -> float:
    """Number of parameters sharing one bitwidth, ``||g||`` in the paper."""
    import math
    n_val = math.prod(value_shape) if value_shape else 1
    n_f = math.prod(f_sh) if f_sh else 1
    return float(n_val) / float(n_f)


@dataclasses.dataclass(frozen=True)
class QuantizerSpec:
    """Static configuration of one HGQ quantizer."""
    granularity: Granularity = "per_parameter"
    init_frac_bits: float = 2.0
    channel_axis: int = -1
    trainable: bool = True
    # extra margin (in powers of two) added during calibration for outliers
    calib_margin_bits: float = 0.0

    def init_f(self, value_shape: Sequence[int]) -> jax.Array:
        return jnp.full(f_shape_for(value_shape, self.granularity,
                                    self.channel_axis),
                        self.init_frac_bits, dtype=jnp.float32)


# ---------------------------------------------------------------------------
# Integer-bit estimation (Eq. 3) — used by the ~EBOPs regularizer and by
# post-training calibration.
# ---------------------------------------------------------------------------

_NEG_LARGE = -127.0  # "no integer bits needed" sentinel (value is ~0)


def int_bits_from_range(vmin: jax.Array, vmax: jax.Array) -> jax.Array:
    """Eq. (3): integer bits i' (sign bit excluded) needed to cover [vmin, vmax].

    i' = max( floor(log2|vmax|) + 1, ceil(log2|vmin|) )

    Zero-range values get a large negative i' so that relu(i' + f) == 0 and
    the parameter contributes nothing to ~EBOPs (it is effectively pruned).
    """
    vmin = sg(jnp.asarray(vmin, jnp.float32))
    vmax = sg(jnp.asarray(vmax, jnp.float32))
    hi = jnp.where(vmax > 0, floor_log2(jnp.maximum(vmax, 1e-30)) + 1.0,
                   _NEG_LARGE)
    lo = jnp.where(vmin < 0, ceil_log2(jnp.maximum(-vmin, 1e-30)),
                   _NEG_LARGE)
    return jnp.maximum(hi, lo)


def train_bits(f: jax.Array, vmin: jax.Array, vmax: jax.Array,
               signed_bit: bool = True) -> jax.Array:
    """Differentiable (in f) bitwidth estimate ``max(i' + f, 0)`` used by ~EBOPs.

    ``signed_bit`` adds one bit when the observed range goes negative
    (variable operands carry their sign bit on-chip).
    """
    ip = int_bits_from_range(vmin, vmax)
    bits = jax.nn.relu(ip + f)
    if signed_bit:
        bits = bits + sg((jnp.asarray(vmin) < 0).astype(jnp.float32)) * (bits > 0)
    return bits


# ---------------------------------------------------------------------------
# Exact occupied-bit counting (EBOPs, SSec. III.C) — post-training, on
# quantized constants.  "bits enclosed by the most and least significant
# non-zero bits": e.g. 001xx1000 counts 4 bits.
# ---------------------------------------------------------------------------

def _exp2i(f: jax.Array) -> jax.Array:
    """Exact 2^f for integer-valued float f, clamped to float32's normal
    exponent range [-126, 127] (XLA's exp2 approximation is an ulp off at
    e.g. f=13, 15, 26..., which corrupts grids, moduli, and mantissa
    counting; ldexp overflows to inf past 127, so we saturate instead —
    bit counts are shift-invariant, so the clamp never changes them for
    representable inputs)."""
    # clip the float BEFORE the int cast: float->int32 conversion of an
    # out-of-range value (diverged f, inf) is implementation-defined and
    # can wrap negative, inverting the grid direction
    fi = jnp.clip(jnp.asarray(f, jnp.float32), -126.0, 127.0)
    return jnp.ldexp(jnp.float32(1.0), fi.astype(jnp.int32))


def floor_log2(x: jax.Array) -> jax.Array:
    """Exact floor(log2 x) for x > 0 via frexp (jnp.log2(2^13) is one ulp
    low on some backends, e.g. floor(log2(8192)) == 12)."""
    _, ex = jnp.frexp(jnp.asarray(x, jnp.float32))
    return ex.astype(jnp.float32) - 1.0


def ceil_log2(x: jax.Array) -> jax.Array:
    """Exact ceil(log2 x) for x > 0 via frexp."""
    man, ex = jnp.frexp(jnp.asarray(x, jnp.float32))
    ex = ex.astype(jnp.float32)
    return jnp.where(man == 0.5, ex - 1.0, ex)


def _mantissa24(m_float: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Exact 24-bit integer mantissa of a non-negative float32.

    Returns ``(m24, ex)`` with ``m_float == m24 * 2^(ex - 24)`` exactly and
    ``m24`` an int32 in ``[2^23, 2^24)`` (0 when the input is 0).  Uses
    ``frexp`` — unlike ``floor(log2(x))``, exact for every representable
    magnitude, so ``round(w * 2^f)`` never overflows int32 no matter how
    large ``f`` is (the old direct int32 cast wrapped negative at f >~ 22
    on unit-scale weights).
    """
    mf = jnp.asarray(m_float, jnp.float32)
    man, ex = jnp.frexp(mf)  # mf = man * 2^ex, man in [0.5, 1)
    m24 = jnp.round(man * jnp.float32(2.0 ** 24)).astype(jnp.int32)
    return m24, ex.astype(jnp.float32)


def occupied_bits(w: jax.Array, f: jax.Array) -> jax.Array:
    """Exact per-element occupied bits of quantized constants ``w``.

    Represent |w_q| = m * 2^-f with integer m; occupied bits =
    floor(log2 m) - trailing_zeros(m) + 1, and 0 when m == 0.  Computed on
    the normalized 24-bit mantissa: the count is shift-invariant, so it
    reduces to ``24 - trailing_zeros(m24)``.
    """
    f = jnp.floor(jnp.asarray(f, jnp.float32) + 0.5)
    mf = jnp.abs(jnp.round(jnp.asarray(w, jnp.float32)
                           * _exp2i(_f_effective(f, w))))
    m24, _ = _mantissa24(mf)
    return jnp.where(m24 > 0, 24.0 - _trailing_zeros(m24), 0.0)


def _f_effective(fi: jax.Array, w: jax.Array) -> jax.Array:
    """Cap fi so |w| * 2^fi stays < 2^25: once the scaled value clears
    float32's 24 mantissa bits, rounding is the identity and the occupied
    span is shift-invariant — so the cap never changes a count, while an
    uncapped fi can push w * 2^fi to inf (frexp(inf) -> garbage)."""
    w32 = jnp.asarray(w, jnp.float32)
    _, ex_w = jnp.frexp(jnp.abs(w32))  # |w| = man * 2^ex_w, man in [0.5, 1)
    return jnp.minimum(fi, 25.0 - ex_w.astype(jnp.float32))


def _trailing_zeros(m: jax.Array) -> jax.Array:
    """Trailing zero count of non-negative int32 (0 -> 0); frexp-exact."""
    m = m.astype(jnp.uint32)
    lowbit = jnp.bitwise_and(m, (~m + jnp.uint32(1)))  # isolate lowest set bit
    _, ex = jnp.frexp(lowbit.astype(jnp.float32))      # lowbit = 2^(ex-1)
    return jnp.where(m > 0, ex.astype(jnp.float32) - 1.0, 0.0)


def group_occupied_bits(w: jax.Array, f: jax.Array,
                        f_sh: Sequence[int]) -> jax.Array:
    """Occupied bits when a *group* of weights shares one multiplier.

    The group bitwidth spans the most-significant non-zero bit to the
    least-significant non-zero bit across the whole group (paper SSec. III.C).
    Reduction axes are those where f is broadcast (size 1 or missing).
    """
    f = jnp.broadcast_to(jnp.asarray(f, jnp.float32), w.shape)
    fi = _f_effective(jnp.floor(f + 0.5), w)
    mf = jnp.abs(jnp.round(jnp.asarray(w, jnp.float32) * _exp2i(fi)))
    m24, ex = _mantissa24(mf)
    # msb index of mf is ex-1; its trailing zeros are tz(m24) - (24 - ex);
    # rebasing by the same (effective) fi keeps positions absolute
    msb = jnp.where(m24 > 0, (ex - 1.0) - fi, jnp.float32(_NEG_LARGE))
    lsb = jnp.where(m24 > 0, (_trailing_zeros(m24) + ex - 24.0) - fi,
                    jnp.float32(-_NEG_LARGE))
    axes = _reduce_axes(w.shape, f_sh)
    if axes:
        msb = jnp.max(msb, axis=axes, keepdims=True)
        lsb = jnp.min(lsb, axis=axes, keepdims=True)
    bits = msb - lsb + 1.0
    return jnp.where(msb >= lsb, bits, 0.0).reshape(f_sh if f_sh else ())


def _reduce_axes(value_shape: Sequence[int], f_sh: Sequence[int]):
    value_shape = tuple(value_shape)
    f_sh = tuple(f_sh)
    if not f_sh:
        return tuple(range(len(value_shape)))
    assert len(f_sh) == len(value_shape), (f_sh, value_shape)
    return tuple(i for i, (v, g) in enumerate(zip(value_shape, f_sh))
                 if g == 1 and v != 1)
