"""HGQ fixed-point quantizer with gradient-trainable fractional bitwidths.

Implements Algorithm 1 of the paper:

    f   <- ste(f_fp)                          # STE on the (float) bitwidth
    x_q <- sg(round(x * 2^f) * 2^-f)          # Eq. (4) quantization
    d   <- sg(x - x_q)                        # quantization error delta_f
    d   <- sg(d + ln2 * f * d) - ln2 * f * d  # surrogate grad  d(delta)/df = -ln2*delta
    x_q <- x - d                              # STE in x, surrogate grad in f

so that  d(x_q)/dx = 1  (straight-through) and  d(x_q)/df_fp = +ln2 * delta
(Eq. (15)).  Integer bits are *not* tracked during training (Eq. 4); they are
fixed post-hoc by calibration (see `repro.core.calibrate`).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

LN2 = 0.6931471805599453

sg = jax.lax.stop_gradient


def ste_round(x: jax.Array, epsilon: float = 0.5) -> jax.Array:
    """Round-to-integer with a straight-through gradient (QKeras convention).

    ``[x] = floor(x + eps)`` with midpoint round-up at eps=1/2 (Eq. 1 footnote).
    """
    return x + sg(jnp.floor(x + epsilon) - x)


def grad_scale(x: jax.Array, scale) -> jax.Array:
    """Identity in the forward pass; multiplies the gradient by ``scale``.

    Used for the 1/sqrt(||g||) normalization of the regularizer gradient on
    shared bitwidths (paper SSec. III.D.3).
    """
    return x * scale + sg(x * (1.0 - scale))


def quantize(x: jax.Array, f: jax.Array, epsilon: float = 0.5) -> jax.Array:
    """HGQ Algorithm-1 quantizer. Differentiable in ``x`` (STE) and ``f``.

    ``f`` broadcasts against ``x`` (per-tensor scalar, per-channel, or full
    per-parameter shape).  Math is done in float32 regardless of x dtype so
    the fixed-point grid is exact, and cast back at the end.
    """
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    fi = ste_round(f.astype(jnp.float32))
    scale = jnp.exp2(fi)  # exact for integer fi
    xq = sg(jnp.floor(x32 * scale + epsilon) / scale)
    delta = sg(x32 - xq)
    delta = sg(delta + LN2 * fi * delta) - LN2 * fi * delta
    return (x32 - delta).astype(dtype)


def quantize_inference(x: jax.Array, f: jax.Array, epsilon: float = 0.5) -> jax.Array:
    """Pure (non-differentiable) Eq.-(4) quantization: round(x*2^f)*2^-f."""
    x32 = x.astype(jnp.float32)
    fi = jnp.floor(f.astype(jnp.float32) + 0.5)
    scale = jnp.exp2(fi)
    return (jnp.floor(x32 * scale + epsilon) / scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# Granularity / parameter groups
# ---------------------------------------------------------------------------

Granularity = str  # 'per_tensor' | 'per_channel' | 'per_parameter'

_GRANULARITIES = ("per_tensor", "per_channel", "per_parameter")


def f_shape_for(shape: Sequence[int], granularity: Granularity,
                channel_axis: int = -1) -> Tuple[int, ...]:
    """Shape of the trainable fractional-bit tensor for a value of ``shape``.

    per_tensor    -> ()            one shared bitwidth
    per_channel   -> broadcastable along ``channel_axis`` only
    per_parameter -> same shape as the value (maximum granularity)
    """
    if granularity not in _GRANULARITIES:
        raise ValueError(f"unknown granularity {granularity!r}")
    shape = tuple(shape)
    if granularity == "per_tensor" or not shape:
        return ()
    if granularity == "per_parameter":
        return shape
    ax = channel_axis % len(shape)
    return tuple(d if i == ax else 1 for i, d in enumerate(shape))


def group_size(value_shape: Sequence[int], f_sh: Sequence[int]) -> float:
    """Number of parameters sharing one bitwidth, ``||g||`` in the paper."""
    import math
    n_val = math.prod(value_shape) if value_shape else 1
    n_f = math.prod(f_sh) if f_sh else 1
    return float(n_val) / float(n_f)


@dataclasses.dataclass(frozen=True)
class QuantizerSpec:
    """Static configuration of one HGQ quantizer."""
    granularity: Granularity = "per_parameter"
    init_frac_bits: float = 2.0
    channel_axis: int = -1
    trainable: bool = True
    # extra margin (in powers of two) added during calibration for outliers
    calib_margin_bits: float = 0.0

    def init_f(self, value_shape: Sequence[int]) -> jax.Array:
        return jnp.full(f_shape_for(value_shape, self.granularity,
                                    self.channel_axis),
                        self.init_frac_bits, dtype=jnp.float32)


# ---------------------------------------------------------------------------
# Integer-bit estimation (Eq. 3) — used by the ~EBOPs regularizer and by
# post-training calibration.
# ---------------------------------------------------------------------------

_NEG_LARGE = -127.0  # "no integer bits needed" sentinel (value is ~0)


def int_bits_from_range(vmin: jax.Array, vmax: jax.Array) -> jax.Array:
    """Eq. (3): integer bits i' (sign bit excluded) needed to cover [vmin, vmax].

    i' = max( floor(log2|vmax|) + 1, ceil(log2|vmin|) )

    Zero-range values get a large negative i' so that relu(i' + f) == 0 and
    the parameter contributes nothing to ~EBOPs (it is effectively pruned).
    """
    vmin = sg(jnp.asarray(vmin, jnp.float32))
    vmax = sg(jnp.asarray(vmax, jnp.float32))
    hi = jnp.where(vmax > 0, jnp.floor(_safe_log2(vmax)) + 1.0, _NEG_LARGE)
    lo = jnp.where(vmin < 0, jnp.ceil(_safe_log2(-vmin)), _NEG_LARGE)
    return jnp.maximum(hi, lo)


def _safe_log2(x: jax.Array) -> jax.Array:
    x = jnp.asarray(x, jnp.float32)
    return jnp.log2(jnp.maximum(x, jnp.float32(2.0 ** _NEG_LARGE)))


def train_bits(f: jax.Array, vmin: jax.Array, vmax: jax.Array,
               signed_bit: bool = True) -> jax.Array:
    """Differentiable (in f) bitwidth estimate ``max(i' + f, 0)`` used by ~EBOPs.

    ``signed_bit`` adds one bit when the observed range goes negative
    (variable operands carry their sign bit on-chip).
    """
    ip = int_bits_from_range(vmin, vmax)
    bits = jax.nn.relu(ip + f)
    if signed_bit:
        bits = bits + sg((jnp.asarray(vmin) < 0).astype(jnp.float32)) * (bits > 0)
    return bits


# ---------------------------------------------------------------------------
# Exact occupied-bit counting (EBOPs, SSec. III.C) — post-training, on
# quantized constants.  "bits enclosed by the most and least significant
# non-zero bits": e.g. 001xx1000 counts 4 bits.
# ---------------------------------------------------------------------------

def occupied_bits(w: jax.Array, f: jax.Array) -> jax.Array:
    """Exact per-element occupied bits of quantized constants ``w``.

    Represent |w_q| = m * 2^-f with integer m; occupied bits =
    floor(log2 m) - trailing_zeros(m) + 1, and 0 when m == 0.
    """
    f = jnp.floor(jnp.asarray(f, jnp.float32) + 0.5)
    m = jnp.abs(jnp.round(jnp.asarray(w, jnp.float32) * jnp.exp2(f)))
    m = m.astype(jnp.int32)
    msb = jnp.where(m > 0, jnp.floor(_safe_log2(m.astype(jnp.float32))), -1.0)
    tz = _trailing_zeros(m)
    return jnp.where(m > 0, msb - tz + 1.0, 0.0)


def _trailing_zeros(m: jax.Array) -> jax.Array:
    """Trailing zero count of non-negative int32 (0 -> 0)."""
    m = m.astype(jnp.uint32)
    lowbit = jnp.bitwise_and(m, (~m + jnp.uint32(1)))  # isolate lowest set bit
    return jnp.where(m > 0,
                     jnp.floor(_safe_log2(lowbit.astype(jnp.float32))),
                     0.0)


def group_occupied_bits(w: jax.Array, f: jax.Array,
                        f_sh: Sequence[int]) -> jax.Array:
    """Occupied bits when a *group* of weights shares one multiplier.

    The group bitwidth spans the most-significant non-zero bit to the
    least-significant non-zero bit across the whole group (paper SSec. III.C).
    Reduction axes are those where f is broadcast (size 1 or missing).
    """
    f = jnp.broadcast_to(jnp.asarray(f, jnp.float32), w.shape)
    fi = jnp.floor(f + 0.5)
    m = jnp.abs(jnp.round(jnp.asarray(w, jnp.float32) * jnp.exp2(fi)))
    m = m.astype(jnp.int32)
    msb = jnp.where(m > 0, jnp.floor(_safe_log2(m.astype(jnp.float32))) - fi,
                    jnp.float32(_NEG_LARGE))
    lsb = jnp.where(m > 0, _trailing_zeros(m) - fi, jnp.float32(-_NEG_LARGE))
    axes = _reduce_axes(w.shape, f_sh)
    if axes:
        msb = jnp.max(msb, axis=axes, keepdims=True)
        lsb = jnp.min(lsb, axis=axes, keepdims=True)
    bits = msb - lsb + 1.0
    return jnp.where(msb >= lsb, bits, 0.0).reshape(f_sh if f_sh else ())


def _reduce_axes(value_shape: Sequence[int], f_sh: Sequence[int]):
    value_shape = tuple(value_shape)
    f_sh = tuple(f_sh)
    if not f_sh:
        return tuple(range(len(value_shape)))
    assert len(f_sh) == len(value_shape), (f_sh, value_shape)
    return tuple(i for i, (v, g) in enumerate(zip(value_shape, f_sh))
                 if g == 1 and v != 1)
