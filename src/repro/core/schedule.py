"""beta / learning-rate schedules.

The paper sweeps the resource-regularization strength beta along a log ramp
within a single training run (e.g. 1e-6 -> 1e-4 for jet tagging), recovering
the Pareto front from one run.  gamma (the L1 term) stays fixed (2e-6).
"""
from __future__ import annotations

import math
from typing import Callable

import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]  # step -> value


def constant(v: float) -> Schedule:
    def fn(step):
        return jnp.float32(v)
    return fn


def log_ramp(v0: float, v1: float, total_steps: int) -> Schedule:
    """beta(t) = v0 * (v1/v0)^(t / T), clamped at v1 (paper SSec. V.B-D)."""
    lv0, lv1 = math.log(v0), math.log(v1)

    def fn(step):
        t = jnp.clip(step / float(max(total_steps, 1)), 0.0, 1.0)
        return jnp.exp(jnp.float32(lv0) + t * jnp.float32(lv1 - lv0))
    return fn


def linear_warmup_cosine(peak: float, warmup: int, total: int,
                         floor: float = 0.0) -> Schedule:
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak * step / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + 0.5 * (peak - floor) * (1.0 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos).astype(jnp.float32)
    return fn
