"""RunSpec: the one declarative, serializable configuration surface.

Every scenario this repo runs — CPU smoke training, FSDP x TP host
meshes, the 512-chip production dry-run, int8-wire gradient compression,
bf16 compute, packed int8 serving — is a *value* of :class:`RunSpec`, a
frozen composition of:

* :class:`MeshSpec` — mesh topology (data/model/pod axes; host vs
  production devices);
* :class:`PrecisionSpec` — matmul compute dtype, int8 serving-weight
  packing, packed-kernel routing;
* :class:`CompressionSpec` — gradient compression kind, wire exchange
  layout, error-feedback residual layout;
* :class:`ServingSpec` — continuous-batching slot count, ring-buffer
  slack, packed-weight serving, KV-cache storage mode, prefix reuse,
  admitted workloads (LM and/or streaming ASR audio);
* the existing :class:`repro.train.TrainConfig` and
  :class:`repro.data.DataSpec`.

``RunSpec.to_json`` / ``from_json`` round-trip exactly
(``RunSpec.from_json(s.to_json()) == s``), so the config a CI bench-gate
measures can be byte-identical to the config a launcher trains.
``RunSpec.from_args`` is the single CLI parser the launchers share:
``--spec run.json`` loads a spec file, and the classic flags
(``--mesh 2x4``, ``--grad-compression int8-wire`` ...) are overrides on
top of it.  :func:`repro.api.build` turns a spec into a
:class:`repro.api.RunContext` — mesh, axis registry, shardings, train
step, serving engine — with no module-level mutable state.

This module is pure configuration: no jax import, no device state.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
from typing import Any, Dict, List, Optional, Sequence, Tuple

from ..core.plan import PrecisionPlan
from ..data.synthetic import DataSpec
from ..train.loop import TrainConfig

GRAD_COMPRESSION_KINDS = ("none", "bf16", "int8", "int8-wire",
                          "int8-wire-2d")
WIRE_LAYOUTS = ("auto", "1d", "2d")
COMPUTE_DTYPES = (None, "bfloat16", "float32")
# mirrors serving.kvcache.KV_CACHE_MODES (this module stays jax-free)
KV_CACHE_MODES = ("fp", "int8", "plan")
SERVING_WORKLOADS = ("lm", "asr")


def _check(cond: bool, msg: str) -> None:
    if not cond:
        raise ValueError(msg)


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    """Mesh topology as data: replaces per-launcher mesh wiring.

    ``kind="host"`` builds a ``data x model`` mesh over (forced) host
    devices — ``MeshSpec()`` is the 1x1 smoke mesh, ``MeshSpec(data=2,
    model=4)`` the 2x4 FSDP x TP mesh (needs
    ``XLA_FLAGS=--xla_force_host_platform_device_count>=8``).
    ``kind="production"`` is the 16x16 pod slice; ``pods=2`` adds the
    outer ``pod`` data axis (2x16x16, the multi-pod dry-run mesh).
    """
    kind: str = "host"          # "host" | "production"
    data: int = 1
    model: int = 1
    pods: int = 1

    def __post_init__(self):
        _check(self.kind in ("host", "production"),
               f"MeshSpec.kind must be 'host' or 'production', "
               f"got {self.kind!r}")
        _check(self.data >= 1 and self.model >= 1 and self.pods >= 1,
               f"MeshSpec sizes must be >= 1, got {self}")
        _check(self.kind == "production" or self.pods == 1,
               "multi-pod meshes are production meshes (pods > 1 needs "
               "kind='production')")

    @classmethod
    def host(cls, data: int = 1, model: int = 1) -> "MeshSpec":
        return cls(kind="host", data=data, model=model)

    @classmethod
    def production(cls, multi_pod: bool = False) -> "MeshSpec":
        """The 16x16 = 256-chip pod slice (2 pods = 512 chips)."""
        return cls(kind="production", data=16, model=16,
                   pods=2 if multi_pod else 1)

    @property
    def axis_names(self) -> Tuple[str, ...]:
        return (("pod", "data", "model") if self.pods > 1
                else ("data", "model"))

    @property
    def shape(self) -> Tuple[int, ...]:
        return ((self.pods, self.data, self.model) if self.pods > 1
                else (self.data, self.model))

    @property
    def device_count(self) -> int:
        return self.pods * self.data * self.model

    @property
    def data_size(self) -> int:
        """Total data-parallel degree (pod is outer data parallelism)."""
        return self.pods * self.data


@dataclasses.dataclass(frozen=True)
class PrecisionSpec:
    """Compute/serving precision as data: replaces ``set_compute_dtype``
    and the ad-hoc ``Engine(packed=...)`` / ``set_packed_matmul`` wiring.

    * ``compute_dtype`` — matmul operand cast (``dist.perf
      .cast_for_matmul``): ``None`` (no cast), ``"bfloat16"`` or
      ``"float32"``;
    * ``packed_serving`` — serve from the HGQ int8-packed weight tree
      (``serving/packed.py``);
    * ``packed_matmul`` — route packed weights onto the fused Pallas
      dequant-matmul kernel; ``None`` follows ``packed_serving``.
    """
    compute_dtype: Optional[str] = None
    packed_serving: bool = False
    packed_matmul: Optional[bool] = None

    def __post_init__(self):
        _check(self.compute_dtype in COMPUTE_DTYPES,
               f"PrecisionSpec.compute_dtype must be one of "
               f"{COMPUTE_DTYPES}, got {self.compute_dtype!r}")

    @property
    def packed_kernels(self) -> bool:
        """The resolved packed-kernel routing flag."""
        return (self.packed_serving if self.packed_matmul is None
                else self.packed_matmul)


@dataclasses.dataclass(frozen=True)
class CompressionSpec:
    """Gradient-compression configuration as data.

    * ``kind`` — ``none`` | post-reduce error feedback (``bf16``,
      ``int8``) | in-reduction wire compression (``int8-wire``,
      ``int8-wire-2d``);
    * ``wire_layout`` — exchange topology for the wire kinds: ``1d``
      (data axes only), ``2d`` (sliced over data x model), or ``auto``
      (2d whenever the mesh has a model axis of size > 1 — the strictly
      better choice there);
    * ``residual_layout`` — error-feedback residual placement;
      ``auto`` follows the resolved wire layout (``sharding
      .ef_residual_sharding``'s ``[n_data, ...]`` stack vs the sliced
      ``[n_data, n_model, C]`` tree);
    * ``fused`` — the wire fast path: quantize/pack through the
      ``kernels.wire_pack`` fused kernels with leaves exchanged in
      size-bucketed pipelined buffers (bit-for-bit the per-leaf trace;
      ``False`` keeps the one-collective-set-per-leaf reference).
    """
    kind: str = "none"
    wire_layout: str = "auto"
    residual_layout: str = "auto"
    fused: bool = True

    def __post_init__(self):
        _check(self.kind in GRAD_COMPRESSION_KINDS,
               f"CompressionSpec.kind must be one of "
               f"{GRAD_COMPRESSION_KINDS}, got {self.kind!r}")
        _check(isinstance(self.fused, bool),
               f"CompressionSpec.fused must be a bool, got {self.fused!r}")
        _check(self.wire_layout in WIRE_LAYOUTS,
               f"CompressionSpec.wire_layout must be one of "
               f"{WIRE_LAYOUTS}, got {self.wire_layout!r}")
        _check(self.residual_layout in WIRE_LAYOUTS,
               f"CompressionSpec.residual_layout must be one of "
               f"{WIRE_LAYOUTS}, got {self.residual_layout!r}")
        _check(not (self.kind == "int8-wire-2d"
                    and self.wire_layout == "1d"),
               "int8-wire-2d IS the 2D layout; wire_layout='1d' "
               "contradicts it")

    @property
    def is_wire(self) -> bool:
        return self.kind in ("int8-wire", "int8-wire-2d")

    @property
    def wire_kind(self) -> str:
        """Payload dtype of the wire collective (int8 unless bf16)."""
        return "bf16" if self.kind == "bf16" else "int8"

    def resolved_wire_layout(self, model_size: int) -> str:
        """The concrete exchange layout on a mesh with ``model_size`` TP
        shards: the 2D sliced exchange is strictly better whenever the
        mesh has a model axis (int8 instead of fp32 crosses it)."""
        if self.kind == "int8-wire-2d":
            return "2d"
        if self.wire_layout != "auto":
            return self.wire_layout
        return "2d" if model_size > 1 else "1d"

    def resolved_residual_layout(self, model_size: int) -> str:
        if self.residual_layout != "auto":
            return self.residual_layout
        return self.resolved_wire_layout(model_size)


@dataclasses.dataclass(frozen=True)
class AudioSpec:
    """Streaming-audio admission parameters for the ``"asr"`` serving
    workload (``serving/streaming.py``).

    * ``chunk_frames`` — default arrival granularity, in encoder frames:
      each engine tick delivers one ``chunk_frames``-frame block per
      in-flight stream; ``0`` = whole audio arrives at once (offline
      admission through the streaming path);
    * ``max_frames`` — admission cap on total frames per request; ``0``
      resolves to the architecture's ``enc_seq`` at build time.
    """
    chunk_frames: int = 0
    max_frames: int = 0

    def __post_init__(self):
        _check(self.chunk_frames >= 0,
               f"AudioSpec.chunk_frames must be >= 0, "
               f"got {self.chunk_frames}")
        _check(self.max_frames >= 0,
               f"AudioSpec.max_frames must be >= 0, "
               f"got {self.max_frames}")


@dataclasses.dataclass(frozen=True)
class ServingSpec:
    """Serving configuration as data — the whole ``RunContext
    .make_engine`` surface.

    * ``slots`` — continuous-batching slot count (``Engine`` batch rows);
    * ``ring_slack`` — extra ring-buffer slots beyond the attention
      window; ``0`` = auto (follow the prefill chunk, the exactness
      floor for chunked prefill);
    * ``packed`` — serve from the HGQ int8-packed weight tree; ``None``
      follows ``PrecisionSpec.packed_serving``;
    * ``kv_cache`` — KV ring-buffer storage: ``"fp"`` (the exact legacy
      bf16 cache, byte-identical HLO), ``"int8"`` (8-bit mantissas on
      per-row 2^-f grids), or ``"plan"`` (the narrowest ``kv_bits`` the
      run's :class:`core.plan.PrecisionPlan` resolves — nibble-packed
      two-per-byte at <= 4 bits);
    * ``prefix_reuse`` — cache prefilled prompt slices keyed by the
      exact prompt, so re-submitting an identical prompt skips prefill;
    * ``workloads`` — request types the engine admits: ``("lm",)`` is
      the classic text engine; adding ``"asr"`` routes ``make_engine``
      to :class:`serving.StreamingEngine`, which also accepts streaming
      audio-chunk requests (needs an encoder-decoder arch);
    * ``audio`` — :class:`AudioSpec` admission parameters; auto-filled
      with defaults when ``"asr"`` is enabled.
    """
    slots: int = 8
    ring_slack: int = 0
    packed: Optional[bool] = None
    kv_cache: str = "fp"
    prefix_reuse: bool = False
    workloads: Tuple[str, ...] = ("lm",)
    audio: Optional[AudioSpec] = None

    def __post_init__(self):
        _check(self.slots >= 1,
               f"ServingSpec.slots must be >= 1, got {self.slots}")
        _check(self.ring_slack >= 0,
               f"ServingSpec.ring_slack must be >= 0, "
               f"got {self.ring_slack}")
        _check(self.kv_cache in KV_CACHE_MODES,
               f"ServingSpec.kv_cache must be one of {KV_CACHE_MODES}, "
               f"got {self.kv_cache!r}")
        _check(self.packed is None or isinstance(self.packed, bool),
               f"ServingSpec.packed must be None or a bool, "
               f"got {self.packed!r}")
        # JSON round-trip coercion: lists arrive from from_json, dicts
        # from the nested-spec loader (RunSpec.from_dict only constructs
        # the top-level parts)
        if isinstance(self.workloads, list):
            object.__setattr__(self, "workloads", tuple(self.workloads))
        if isinstance(self.audio, dict):
            known = {f.name for f in dataclasses.fields(AudioSpec)}
            unknown = set(self.audio) - known
            _check(not unknown,
                   f"unknown AudioSpec fields: {sorted(unknown)}")
            object.__setattr__(self, "audio", AudioSpec(**self.audio))
        _check(len(self.workloads) >= 1,
               "ServingSpec.workloads must name at least one workload")
        bad = [w for w in self.workloads if w not in SERVING_WORKLOADS]
        _check(not bad,
               f"ServingSpec.workloads must be drawn from "
               f"{SERVING_WORKLOADS}, got {bad}")
        _check(len(set(self.workloads)) == len(self.workloads),
               f"duplicate ServingSpec.workloads: {self.workloads}")
        _check(self.audio is None or "asr" in self.workloads,
               "ServingSpec.audio is set but 'asr' is not in workloads")
        if "asr" in self.workloads and self.audio is None:
            object.__setattr__(self, "audio", AudioSpec())

    def resolved_packed(self, precision: PrecisionSpec) -> bool:
        """The concrete packed-weight flag (``None`` follows
        ``PrecisionSpec.packed_serving``)."""
        return (precision.packed_serving if self.packed is None
                else self.packed)


def _default_train() -> TrainConfig:
    # the launcher's classic training hyperparameters (launch.train)
    return TrainConfig(steps=20, lr=1e-3, beta0=1e-9, beta1=1e-7)


def _default_data() -> DataSpec:
    # vocab=0 resolves to the architecture's vocab at build time
    return DataSpec(kind="lm", batch=4, seq=32, vocab=0, seed=0)


@dataclasses.dataclass(frozen=True)
class RunSpec:
    """One run, fully specified: arch + mesh + precision + compression +
    train/data config + seed + optional per-layer width plan.  See the
    module docstring."""
    arch: str = "qwen2-0.5b"
    full: bool = False
    seed: int = 0
    mesh: MeshSpec = dataclasses.field(default_factory=MeshSpec)
    precision: PrecisionSpec = dataclasses.field(
        default_factory=PrecisionSpec)
    compression: CompressionSpec = dataclasses.field(
        default_factory=CompressionSpec)
    train: TrainConfig = dataclasses.field(default_factory=_default_train)
    data: DataSpec = dataclasses.field(default_factory=_default_data)
    serving: ServingSpec = dataclasses.field(default_factory=ServingSpec)
    # learned per-layer precision (core.plan.PrecisionPlan): wire widths
    # for the compressed gradient collective + pack widths for serving.
    # None (and any uniform-int8 plan) is byte-identical to the pre-plan
    # behavior — build() normalizes both to the exact legacy trace.
    plan: Optional[PrecisionPlan] = None

    # ------------------------- serialization --------------------------

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True) + "\n"

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "RunSpec":
        parts = {"mesh": MeshSpec, "precision": PrecisionSpec,
                 "compression": CompressionSpec, "train": TrainConfig,
                 "data": DataSpec, "serving": ServingSpec}
        d = dict(d)
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        _check(not unknown, f"unknown RunSpec fields: {sorted(unknown)}")
        for name, sub in parts.items():
            if isinstance(d.get(name), dict):
                sub_known = {f.name for f in dataclasses.fields(sub)}
                sub_unknown = set(d[name]) - sub_known
                _check(not sub_unknown,
                       f"unknown {sub.__name__} fields: "
                       f"{sorted(sub_unknown)}")
                d[name] = sub(**d[name])
        if isinstance(d.get("plan"), dict):
            # PrecisionPlan has its own strict loader (rejects unknown
            # fields, validates widths) — reuse it
            d["plan"] = PrecisionPlan.from_dict(d["plan"])
        return cls(**d)

    @classmethod
    def from_json(cls, s: str) -> "RunSpec":
        return cls.from_dict(json.loads(s))

    @classmethod
    def from_file(cls, path: str) -> "RunSpec":
        with open(path) as f:
            return cls.from_json(f.read())

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    # ----------------------------- CLI --------------------------------

    @classmethod
    def parser(cls, **kwargs) -> argparse.ArgumentParser:
        """The shared launcher argument parser: ``--spec run.json`` plus
        the classic flags as overrides (every flag maps to one spec
        field; see the README migration table)."""
        ap = argparse.ArgumentParser(**kwargs)
        ap.add_argument("--spec", default=None, metavar="RUN_JSON",
                        help="RunSpec JSON file; other flags override "
                             "individual fields of it")
        ap.add_argument("--arch", default=None)
        ap.add_argument("--full", action="store_true", default=None,
                        help="use the full (published) config, not smoke")
        ap.add_argument("--steps", type=int, default=None)
        ap.add_argument("--batch", type=int, default=None)
        ap.add_argument("--seq", type=int, default=None)
        ap.add_argument("--seed", type=int, default=None,
                        help="PRNG seed for init AND the data pipeline")
        ap.add_argument("--production-mesh", action="store_true",
                        default=None)
        ap.add_argument("--multi-pod", action="store_true", default=None)
        ap.add_argument("--mesh", default=None,
                        help="host mesh DATAxMODEL (e.g. 4x2) for "
                             "multi-device smoke runs; needs XLA_FLAGS="
                             "--xla_force_host_platform_device_count>=D*M")
        ap.add_argument("--ckpt-dir", default=None)
        ap.add_argument("--ckpt-every", type=int, default=None,
                        help="checkpoint every N steps (makes the "
                             "EF-residual resume path drivable in short "
                             "runs)")
        ap.add_argument("--plan", default=None, metavar="PLAN_JSON",
                        help="PrecisionPlan JSON file (core.plan): "
                             "per-layer wire + pack widths learned from a "
                             "trained HGQ run; omitted = uniform int8, "
                             "byte-identical to not passing a plan")
        ap.add_argument("--compute-dtype", default=None,
                        choices=["none", "bfloat16", "float32"],
                        help="matmul compute dtype "
                             "(PrecisionSpec.compute_dtype)")
        ap.add_argument("--kv-cache", default=None,
                        choices=list(KV_CACHE_MODES),
                        help="serving KV ring-buffer storage "
                             "(ServingSpec.kv_cache): fp keeps the exact "
                             "legacy cache; int8/plan store 2^-f "
                             "quantized mantissas at 8 / plan bits")
        ap.add_argument("--slots", type=int, default=None,
                        help="continuous-batching slot count "
                             "(ServingSpec.slots)")
        ap.add_argument("--grad-compression",
                        choices=list(GRAD_COMPRESSION_KINDS), default=None,
                        help="bf16/int8 quantize the synchronized "
                             "gradient (post-reduce); int8-wire "
                             "compresses inside the reduction — int8 "
                             "bytes on the wire via dist.collectives; "
                             "int8-wire-2d additionally slices the "
                             "exchange over the model (TP) axis — "
                             "auto-selected for int8-wire when the mesh "
                             "has M>1 (single-device runs fall back to "
                             "the post-reduce int8 path)")
        return ap

    @classmethod
    def from_args(cls, argv: Optional[Sequence[str]] = None,
                  **parser_kwargs) -> "RunSpec":
        """Parse CLI flags into a spec: ``--spec`` loads a JSON file,
        explicit flags override its fields, and with no ``--spec`` the
        flags override the defaults (classic launcher behavior)."""
        args = cls.parser(**parser_kwargs).parse_args(argv)
        return cls.from_parsed(args)

    @classmethod
    def from_parsed(cls, args: argparse.Namespace,
                    base: Optional["RunSpec"] = None) -> "RunSpec":
        """Apply explicitly-passed flags as overrides on ``--spec``'s
        file, or on ``base`` (an entry point's own defaults — e.g. the
        examples ship different default arch/steps than the launcher),
        or on the class defaults."""
        spec = (cls.from_file(args.spec) if getattr(args, "spec", None)
                else (base if base is not None else cls()))
        rep: Dict[str, Any] = {}
        if args.arch is not None:
            rep["arch"] = args.arch
        if args.full:
            rep["full"] = True
        if args.seed is not None:
            rep["seed"] = args.seed
        if args.production_mesh or args.multi_pod:
            rep["mesh"] = MeshSpec.production(
                multi_pod=bool(args.multi_pod))
        elif args.mesh is not None:
            d, m = (int(v) for v in args.mesh.lower().split("x"))
            rep["mesh"] = MeshSpec.host(d, m)
        if getattr(args, "plan", None) is not None:
            rep["plan"] = PrecisionPlan.from_file(args.plan)
        if args.compute_dtype is not None:
            rep["precision"] = dataclasses.replace(
                spec.precision,
                compute_dtype=(None if args.compute_dtype == "none"
                               else args.compute_dtype))
        if args.grad_compression is not None:
            rep["compression"] = dataclasses.replace(
                spec.compression, kind=args.grad_compression)
        sv: Dict[str, Any] = {}
        if getattr(args, "kv_cache", None) is not None:
            sv["kv_cache"] = args.kv_cache
        if getattr(args, "slots", None) is not None:
            sv["slots"] = args.slots
        if sv:
            rep["serving"] = dataclasses.replace(spec.serving, **sv)
        tr: Dict[str, Any] = {}
        if args.steps is not None:
            tr["steps"] = args.steps
        if args.ckpt_dir is not None:
            tr["ckpt_dir"] = args.ckpt_dir
        if args.ckpt_every is not None:
            tr["ckpt_every"] = args.ckpt_every
        if tr:
            rep["train"] = dataclasses.replace(spec.train, **tr)
        da: Dict[str, Any] = {}
        if args.batch is not None:
            da["batch"] = args.batch
        if args.seq is not None:
            da["seq"] = args.seq
        if args.seed is not None:
            da["seed"] = args.seed
        if da:
            rep["data"] = dataclasses.replace(spec.data, **da)
        return dataclasses.replace(spec, **rep) if rep else spec


def emit_pareto_specs(front, base: RunSpec, out_dir: str) -> List[str]:
    """Turn a trained run's Pareto front into ready-to-run spec files.

    For every front point carrying a :class:`core.plan.PrecisionPlan`
    payload (the sweep's per-point width tables), writes
    ``out_dir/pareto_<i>_step<step>.json`` — ``base`` with that plan
    embedded — plus ``out_dir/front.json`` (the serialized front, metric
    vs EBOPs per point).  Each emitted spec is directly loadable with
    ``--spec`` (or the plan alone with ``--plan`` after extracting it);
    points without a plan payload are skipped.  Returns the spec paths,
    cheapest point first."""
    os.makedirs(out_dir, exist_ok=True)
    paths: List[str] = []
    for i, p in enumerate(front.points):
        if not isinstance(p.payload, PrecisionPlan):
            continue
        spec = dataclasses.replace(base, plan=p.payload)
        path = os.path.join(out_dir, f"pareto_{i:02d}_step{p.step}.json")
        spec.save(path)
        paths.append(path)
    with open(os.path.join(out_dir, "front.json"), "w") as f:
        f.write(front.to_json())
    return paths
