"""``repro.api`` — the declarative run-configuration surface.

One frozen, JSON-serializable :class:`RunSpec` (mesh + precision +
compression + train/data config + seed) replaces the old trace-time
globals (``dist.axes.set_axes`` / ``dist.perf.set_compute_dtype``,
both since removed) and
the per-launcher argparse/setup blocks; :func:`build` turns a spec into
a :class:`RunContext` that constructs the mesh, axis registry,
shardings, train step, and serving engine from the spec alone, with no
module-level mutable state.

    from repro.api import RunSpec, build
    spec = RunSpec.from_file("examples/specs/host_2x4_int8wire2d.json")
    ctx = build(spec)
    setup = ctx.init_training()
    with ctx.mesh:
        metrics = setup.step(0)
"""
from ..core.plan import LayerPlan, PrecisionPlan  # noqa: F401
from .spec import (AudioSpec, CompressionSpec,  # noqa: F401
                   GRAD_COMPRESSION_KINDS, KV_CACHE_MODES, MeshSpec,
                   PrecisionSpec, RunSpec, SERVING_WORKLOADS, ServingSpec,
                   emit_pareto_specs)
from .context import (GradCompression, RunContext,  # noqa: F401
                      TrainSetup, build, build_mesh)
