"""RunContext: build a whole run — mesh, axis registry, shardings, train
step, serving engine — from a :class:`repro.api.RunSpec`, with **no
module-level mutable state**.

The old flow was ``set_axes(...)``; ``set_compute_dtype(...)``; build a
mesh by hand; wire ``make_train_step``/``Engine`` per launcher.  Every
jitted program silently depended on whatever those globals held when it
traced.  A :class:`RunContext` instead *carries* its configuration and
activates it as a dynamic scope (``dist.scope``) around every trace it
owns:

    ctx = repro.api.build(spec)
    setup = ctx.init_training()        # params/opt/EF state + jitted step
    with ctx.mesh:
        ... setup.step(...) ...

    eng = ctx.make_engine(params, qstate)   # serving, same spec surface

Because nothing global is touched, two contexts with different
precision/axes coexist in one process — each keeps its own jit caches,
neither retraces nor perturbs the other (see ``tests/test_api.py``) —
which is what makes multi-tenant serving and side-by-side scenario
sweeps possible at all.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs import get as get_config
from ..data import make_pipeline
from ..data.synthetic import DataSpec
from ..dist import EFState, collectives, ef_compress, ef_init
from ..dist.axes import AxisRegistry, axis_scope, registry_for_mesh
from ..dist.perf import compute_dtype_scope, packed_matmul
from ..dist.sharding import (batch_sharding, ef_residual_sharding,
                             replicated, shard_tree)
from ..models import model_for
from ..optim import adamw_init
from ..train import lm_loss, make_train_step
from ..train import checkpoint as ckpt_lib
from .spec import MeshSpec, RunSpec

_DTYPES = {None: None, "bfloat16": jnp.bfloat16, "float32": jnp.float32}


def build_mesh(mspec: MeshSpec):
    """Concrete ``jax.sharding.Mesh`` for a :class:`MeshSpec`.

    A function (never a module-level constant) so importing this module
    touches no jax device state — production meshes need the forced
    host-device XLA flag set before first jax init (``launch.dryrun``).
    """
    return jax.make_mesh(mspec.shape, mspec.axis_names)


@dataclasses.dataclass(frozen=True)
class GradCompression:
    """The resolved gradient-compression plan for one (spec, mesh) pair:
    either a post-reduce ``grad_tx`` transform or the in-reduction wire
    collective (``wire=True``), plus the initial EF state."""
    wire: bool
    wire_layout: str                  # "1d" | "2d" (resolved; wire only)
    reduce: str                       # "full" | "compressed"
    grad_tx: Optional[Callable]
    kind: str

    def init_state(self, params, n_data: int, n_model: int
                   ) -> Optional[EFState]:
        if self.kind == "none":
            return None
        if self.wire and self.wire_layout == "2d":
            return EFState(residual=collectives.ef_wire2d_init(
                params, n_data, n_model))
        if self.wire:
            return EFState(residual=collectives.ef_wire_init(
                params, n_data))
        return ef_init(params)


class TrainSetup:
    """Everything ``RunContext.init_training`` builds: state trees plus
    the jitted, sharding-annotated step.  ``step`` threads the optimizer
    and (when compression is on) EF residual state through itself."""

    def __init__(self, ctx: "RunContext", params, qstate, opt, ef_state,
                 jitted, pipeline):
        self.ctx = ctx
        self.params = params
        self.qstate = qstate
        self.opt = opt
        self.ef_state = ef_state
        self.jitted = jitted
        self.pipeline = pipeline
        self.start_step = 0

    def step(self, step: int) -> Dict[str, jax.Array]:
        batch = self.pipeline(step)
        if self.ef_state is not None:
            (self.params, self.qstate, self.opt, metrics,
             self.ef_state) = self.jitted(self.params, self.qstate,
                                          self.opt, batch,
                                          jnp.int32(step), self.ef_state)
        else:
            self.params, self.qstate, self.opt, metrics = self.jitted(
                self.params, self.qstate, self.opt, batch,
                jnp.int32(step))
        return metrics

    # --------------------- checkpointing / resume ----------------------

    def maybe_resume(self, log=print) -> bool:
        """Resume params/qstate/opt (and the EF residual, when present
        and shape-compatible) from the newest checkpoint."""
        ckpt_dir = self.ctx.spec.train.ckpt_dir
        if not ckpt_dir:
            return False
        last = ckpt_lib.latest_step(ckpt_dir)
        if last is None:
            return False
        tmpl = {"params": self.params, "qstate": self.qstate,
                "opt": self.opt}
        start, trees = ckpt_lib.restore(ckpt_dir, last, tmpl)
        self.params, self.qstate, self.opt = (
            trees["params"], trees["qstate"], trees["opt"])
        # EF residual resumes rather than resetting — but only when the
        # checkpoint has a shape-compatible one (a run may turn
        # compression on mid-stream, change kind, or rescale the mesh:
        # the 1D wire residual is [n_data, ...] and the 2D one
        # [n_data, n_model, C], so a rescale — or a 1d<->2d layout
        # switch — cannot re-chunk it: warn, restart it at zero, and eat
        # one biased window instead of dying)
        if self.ef_state is not None and ckpt_lib.has_tree(
                ckpt_dir, last, "ef"):
            try:
                _, eft = ckpt_lib.restore(ckpt_dir, last,
                                          {"ef": self.ef_state})
                self.ef_state = eft["ef"]
            except (AssertionError, KeyError):
                log("warning: checkpointed EF residual does not match "
                    "the current mesh/compression kind; restarting it "
                    "at zero")
        self.start_step = start
        return True

    def checkpoint(self, steps_applied: int) -> None:
        """Save under the 'steps applied' label (= next step to run)."""
        trees = {"params": self.params, "qstate": self.qstate,
                 "opt": self.opt}
        if self.ef_state is not None:
            trees["ef"] = self.ef_state
        ckpt_lib.save(self.ctx.spec.train.ckpt_dir, steps_applied, trees)


class RunContext:
    """A built run: the spec plus mesh, axis registry, resolved
    precision, and constructors for every derived object.  Cheap to
    build (no params are materialized until ``init_state`` /
    ``init_training``)."""

    def __init__(self, spec: RunSpec):
        self.spec = spec
        self.cfg = get_config(spec.arch, smoke=not spec.full)
        self.model = model_for(self.cfg)
        self.mesh = build_mesh(spec.mesh)
        self.axes: AxisRegistry = registry_for_mesh(self.mesh)
        self.compute_dtype = _DTYPES[spec.precision.compute_dtype]
        self.n_data = collectives.data_axis_size(self.mesh)
        self.n_model = collectives.model_axis_size(self.mesh)
        # effective precision plan: a missing plan and an explicit
        # uniform-int8 plan both resolve to None, so every consumer takes
        # the exact legacy (int8-everywhere) trace — spec files without
        # a plan stay HLO-byte-identical (tests/test_api.py)
        plan = spec.plan
        self.plan = None if plan is None or plan.is_uniform_int8 else plan
        # the un-normalized plan: kv_bits resolution must see every entry
        # (a plan that is uniform-int8 for wire/pack may still carry
        # narrow KV widths, and normalizing it away would drop them)
        self._full_plan = spec.plan

    # --------------------------- activation ----------------------------

    @contextlib.contextmanager
    def activate(self, packed: Optional[bool] = None):
        """Bind this context's trace-time configuration (axis registry,
        compute dtype, packed-kernel routing) for the enclosed block.
        Re-entrant and nestable across contexts; nothing global moves."""
        if packed is None:
            packed = self.spec.precision.packed_kernels
        with axis_scope(self.axes), \
                compute_dtype_scope(self.compute_dtype), \
                packed_matmul(packed):
            yield self

    def wrap(self, fn: Callable, packed: Optional[bool] = None) -> Callable:
        """Wrap ``fn`` so its *trace* runs under :meth:`activate` — the
        way every jitted function owned by this context is built.  (jit
        invokes the Python callable only on cache miss, so the scope is
        active exactly when trace-time flags are read.)"""
        @functools.wraps(fn)
        def traced(*args, **kwargs):
            with self.activate(packed=packed):
                return fn(*args, **kwargs)
        return traced

    # ------------------------- derived objects -------------------------

    @property
    def forward(self) -> Callable:
        cfg = self.cfg
        model = self.model
        return lambda p, q, b, mode: model.forward(p, q, b, cfg, mode)

    def data_spec(self) -> DataSpec:
        """The run's :class:`DataSpec` with vocab resolved from the
        architecture (a spec file may leave ``vocab=0``)."""
        ds = self.spec.data
        if ds.kind in ("lm", "asr") and ds.vocab == 0:
            ds = dataclasses.replace(ds, vocab=self.cfg.vocab)
        return ds

    def make_pipeline(self) -> Callable[[int], Dict[str, jax.Array]]:
        ds = self.data_spec()
        if ds.kind == "asr":
            return make_pipeline(ds, d_model=self.cfg.d_model,
                                 enc_seq=self.cfg.enc_seq)
        return make_pipeline(ds)

    def init_state(self) -> Tuple[Any, Any]:
        """Seeded model init (``RunSpec.seed``) under this context."""
        with self.activate():
            return self.model.init(jax.random.PRNGKey(self.spec.seed),
                                   self.cfg)

    # ---------------------- gradient compression -----------------------

    def grad_compression(self) -> GradCompression:
        """Resolve ``CompressionSpec`` against this mesh (the logic the
        launcher used to inline): wire kinds run the in-reduction
        collective whenever the mesh can carry it, and degenerate to the
        post-reduce int8 path on a single device, token-for-token."""
        comp = self.spec.compression
        kind = comp.kind
        if kind == "none":
            return GradCompression(False, "1d", "full", None, kind)
        if comp.is_wire:
            layout = comp.resolved_wire_layout(self.n_model)
            wire = self.n_data > 1 or (layout == "2d" and self.n_model > 1)
            if wire:
                return GradCompression(True, layout, "compressed", None,
                                       kind)
            # single device: the wire is a no-op — post-reduce int8 EF IS
            # the compressed path here, token-for-token
            return GradCompression(
                False, layout, "full",
                lambda g, s: ef_compress(g, s, kind="int8"), kind)
        return GradCompression(
            False, "1d", "full",
            lambda g, s: ef_compress(g, s, kind=kind), kind)

    # --------------------------- training ------------------------------

    def make_train_step(self, loss_fn: Optional[Callable] = None,
                        comp: Optional[GradCompression] = None) -> Callable:
        """The pure (pjit-able) train step for this spec, tracing under
        this context.  ``loss_fn`` defaults to the LM loss."""
        comp = comp or self.grad_compression()
        loss_fn = loss_fn or (lambda out, b: lm_loss(out, b["tokens"]))
        step = make_train_step(
            self.forward, loss_fn, self.spec.train, grad_tx=comp.grad_tx,
            reduce=comp.reduce, mesh=self.mesh if comp.wire else None,
            wire_kind=self.spec.compression.wire_kind,
            wire_layout=comp.wire_layout if comp.wire else "auto",
            wire_widths=self.plan,
            wire_fused=self.spec.compression.fused)
        return self.wrap(step)

    def _batch_shardings(self, mesh) -> Dict[str, Any]:
        """Batch-dim shardings for the pipeline's batch dict (tokens are
        ``[B, S]``; ASR batches add ``[B, T, d]`` frame embeddings)."""
        b = self.spec.data.batch
        sh = {"tokens": batch_sharding(mesh, b, 2)}
        if self.spec.data.kind == "asr":
            sh["frame_embeds"] = batch_sharding(mesh, b, 3)
        return sh

    def train_shardings(self, params, qstate, opt,
                        ef_state: Optional[EFState],
                        comp: GradCompression) -> Tuple[tuple, tuple]:
        """(in_shardings, donate_argnums) for the jitted train step."""
        mesh = self.mesh
        in_shardings = (shard_tree(params, mesh, "train"),
                        shard_tree(qstate, mesh, "train"),
                        type(opt)(step=replicated(mesh),
                                  mu=shard_tree(opt.mu, mesh, "train"),
                                  nu=shard_tree(opt.nu, mesh, "train")),
                        self._batch_shardings(mesh),
                        replicated(mesh))
        donate = (0, 2)
        if ef_state is not None:
            layout = self.spec.compression.resolved_residual_layout(
                self.n_model)
            res_sh = (ef_residual_sharding(ef_state.residual, mesh,
                                           layout=layout) if comp.wire
                      else shard_tree(ef_state.residual, mesh, "train"))
            in_shardings += (EFState(residual=res_sh),)
            donate += (5,)  # the residual threads step-to-step like opt
        return in_shardings, donate

    def init_training(self, loss_fn: Optional[Callable] = None
                      ) -> TrainSetup:
        """Params/opt/EF state + the jitted sharded step + pipeline, all
        from the spec alone."""
        params, qstate = self.init_state()
        opt = adamw_init(params)
        comp = self.grad_compression()
        ef_state = comp.init_state(params, self.n_data, self.n_model)
        step_fn = self.make_train_step(loss_fn, comp)
        with self.mesh:
            in_shardings, donate = self.train_shardings(
                params, qstate, opt, ef_state, comp)
            jitted = jax.jit(step_fn, in_shardings=in_shardings,
                             donate_argnums=donate)
        return TrainSetup(self, params, qstate, opt, ef_state, jitted,
                          self.make_pipeline())

    # --------------------------- serving -------------------------------

    def pack_params(self, params: Any) -> Any:
        """The HGQ packed serving tree (``serving/packed.py``), traced
        under this context: int8 per layer by default, nibble-packed int4
        where the spec's :class:`PrecisionPlan` says so (safe on abstract
        trees via eval_shape)."""
        from ..serving.packed import pack_tree
        with self.activate():
            return pack_tree(params, self.plan)

    def make_engine(self, params, qstate, **kwargs):
        """A continuous-batching ``serving.Engine`` serving this spec:
        slot count, packing, KV-cache storage, prefix reuse and admitted
        workloads all come from ``spec.serving`` (plus the spec's
        precision plan), and the engine snapshots this context's trace
        flags, so engines from different contexts coexist in one
        process.  When ``spec.serving.workloads`` includes ``"asr"``
        this builds a :class:`serving.StreamingEngine` — audio-chunk
        requests admitted beside LM traffic, with ``spec.serving.audio``
        setting the arrival chunk and admission cap.  Workload knobs the
        spec does not own (``max_len``, ``eos_id``, ``prefill_chunk``,
        ``seed``) pass through."""
        from ..serving import Engine, StreamingEngine, resolve_kv_bits
        sv = self.spec.serving
        removed = {"batch_slots": "serving.slots",
                   "packed": "serving.packed", "plan": "RunSpec.plan"}
        for kw in removed:
            if kw in kwargs:
                raise TypeError(f"make_engine({kw}=...) was removed: set "
                                f"RunSpec.{removed[kw]} in the spec "
                                f"instead")
        kwargs.setdefault("kv_bits",
                          resolve_kv_bits(sv.kv_cache, self._full_plan))
        kwargs.setdefault("ring_slack", sv.ring_slack or None)
        kwargs.setdefault("prefix_reuse", sv.prefix_reuse)
        cls = Engine
        if "asr" in sv.workloads:
            cls = StreamingEngine
            kwargs.setdefault("audio_chunk", sv.audio.chunk_frames)
            kwargs.setdefault("max_frames", sv.audio.max_frames or None)
        with self.activate(packed=False):
            return cls(self.model, params, qstate, self.cfg,
                       batch_slots=sv.slots,
                       packed=sv.resolved_packed(self.spec.precision),
                       plan=self.plan, **kwargs)

    def plan_summary(self) -> Optional[Dict[str, Any]]:
        """Reporting view of the effective plan (None == uniform int8):
        what dry-run cells and bench JSONs embed."""
        return None if self.plan is None else self.plan.summary()


def build(spec: RunSpec) -> RunContext:
    """``RunSpec -> RunContext``: the one entry point every launcher,
    example, and benchmark shares."""
    return RunContext(spec)
