"""Architecture registry + assigned input shapes.

Each ``src/repro/configs/<id>.py`` defines ``FULL`` (the exact published
config) and ``SMOKE`` (a reduced same-family config for CPU tests).
``--arch <id>`` resolves through :func:`get`.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Tuple

from ..models.config import ModelConfig

ARCHS = (
    "llama3_2_3b", "qwen2_0_5b", "deepseek_67b", "qwen1_5_110b",
    "pixtral_12b", "rwkv6_1_6b", "moonshot_v1_16b_a3b",
    "granite_moe_3b_a800m", "recurrentgemma_2b", "whisper_large_v3",
)

# public ids (hyphenated) -> module names
ALIASES = {a.replace("_", "-").replace("-v1-", "-v1-"): a for a in ARCHS}
ALIASES.update({
    "llama3.2-3b": "llama3_2_3b", "qwen2-0.5b": "qwen2_0_5b",
    "deepseek-67b": "deepseek_67b", "qwen1.5-110b": "qwen1_5_110b",
    "pixtral-12b": "pixtral_12b", "rwkv6-1.6b": "rwkv6_1_6b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "whisper-large-v3": "whisper_large_v3",
})


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def get(arch: str, smoke: bool = False) -> ModelConfig:
    mod_name = ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.SMOKE if smoke else mod.FULL


def cells() -> Tuple[Tuple[str, str], ...]:
    """All 40 (arch x shape) cells; `skip` cells are resolved by the caller
    via ModelConfig.sub_quadratic (see DESIGN.md SS4)."""
    return tuple((a, s) for a in ARCHS for s in SHAPES)
