"""granite-moe-3b-a800m [moe] 32L d1536 24H GQA kv=8 ff512/expert v49155 MoE 40e top-8 (hf:ibm-granite)"""
from ..models.config import ModelConfig
from ..nn.common import HGQConfig

_HGQ = HGQConfig(weight_gran="per_channel", act_gran="per_tensor",
                 init_weight_f=6.0, init_act_f=6.0)

FULL = ModelConfig(
    name="granite-moe-3b-a800m", family="moe", n_layers=32, d_model=1536,
    n_heads=24, n_kv=8, d_ff=512, vocab=49155, moe_experts=40,
    moe_top_k=8, rope_theta=10000.0,
    hgq=_HGQ)

SMOKE = ModelConfig(
    name="granite-smoke", family="moe", n_layers=2, d_model=48,
    n_heads=4, n_kv=2, d_ff=16, vocab=256, moe_experts=5, moe_top_k=2,
    q_chunk=32, k_chunk=32,
    hgq=_HGQ)
