"""rwkv6-1.6b [ssm] 24L d2048 attn-free ff7168 v65536 — Finch data-dependent decay (arXiv:2404.05892)"""
from ..models.config import ModelConfig
from ..nn.common import HGQConfig

_HGQ = HGQConfig(weight_gran="per_channel", act_gran="per_tensor",
                 init_weight_f=6.0, init_act_f=6.0)

FULL = ModelConfig(
    name="rwkv6-1.6b", family="ssm", n_layers=24, d_model=2048,
    n_heads=32, n_kv=32, d_ff=7168, vocab=65536, norm="ln",
    hgq=_HGQ)

SMOKE = ModelConfig(
    name="rwkv6-1.6b-smoke", family="ssm", n_layers=2, d_model=128,
    n_heads=2, n_kv=2, d_ff=256, vocab=256, norm="ln", rwkv_chunk=8,
    hgq=_HGQ)
