"""qwen1.5-110b [dense] 80L d8192 64H GQA kv=8 ff49152 v152064, QKV bias (hf:Qwen/Qwen1.5-0.5B)"""
from ..models.config import ModelConfig
from ..nn.common import HGQConfig

_HGQ = HGQConfig(weight_gran="per_channel", act_gran="per_tensor",
                 init_weight_f=6.0, init_act_f=6.0)

FULL = ModelConfig(
    name="qwen1.5-110b", family="dense", n_layers=80, d_model=8192,
    n_heads=64, n_kv=8, d_ff=49152, vocab=152064, qkv_bias=True,
    rope_theta=1000000.0,
    hgq=_HGQ)

SMOKE = ModelConfig(
    name="qwen1.5-110b-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=8, n_kv=2, d_ff=384, vocab=256, qkv_bias=True,
    q_chunk=32, k_chunk=32,
    hgq=_HGQ)
