"""moonshot-v1-16b-a3b [moe] 48L d2048 16H GQA kv=16 ff1408/expert v163840 MoE 64e top-6 (hf:moonshotai/Moonlight-16B-A3B)"""
from ..models.config import ModelConfig
from ..nn.common import HGQConfig

_HGQ = HGQConfig(weight_gran="per_channel", act_gran="per_tensor",
                 init_weight_f=6.0, init_act_f=6.0)

FULL = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe", n_layers=48, d_model=2048,
    n_heads=16, n_kv=16, d_ff=1408, vocab=163840, moe_experts=64,
    moe_top_k=6, rope_theta=50000.0,
    hgq=_HGQ)

SMOKE = ModelConfig(
    name="moonshot-smoke", family="moe", n_layers=2, d_model=64,
    n_heads=4, n_kv=4, d_ff=32, vocab=256, moe_experts=8, moe_top_k=2,
    q_chunk=32, k_chunk=32,
    hgq=_HGQ)
