"""deepseek-67b [dense] 95L d8192 64H GQA kv=8 ff22016 v102400, llama-arch (arXiv:2401.02954)"""
from ..models.config import ModelConfig
from ..nn.common import HGQConfig

_HGQ = HGQConfig(weight_gran="per_channel", act_gran="per_tensor",
                 init_weight_f=6.0, init_act_f=6.0)

FULL = ModelConfig(
    name="deepseek-67b", family="dense", n_layers=95, d_model=8192,
    n_heads=64, n_kv=8, d_ff=22016, vocab=102400, rope_theta=10000.0,
    hgq=_HGQ)

SMOKE = ModelConfig(
    name="deepseek-67b-smoke", family="dense", n_layers=3, d_model=64,
    n_heads=8, n_kv=2, d_ff=160, vocab=256, q_chunk=32, k_chunk=32,
    hgq=_HGQ)
