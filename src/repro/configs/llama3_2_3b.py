"""llama3.2-3b [dense] 28L d3072 24H GQA kv=8 ff8192 v128256 (hf:meta-llama/Llama-3.2-1B; unverified)"""
from ..models.config import ModelConfig
from ..nn.common import HGQConfig

_HGQ = HGQConfig(weight_gran="per_channel", act_gran="per_tensor",
                 init_weight_f=6.0, init_act_f=6.0)

FULL = ModelConfig(
    name="llama3.2-3b", family="dense", n_layers=28, d_model=3072,
    n_heads=24, n_kv=8, d_ff=8192, vocab=128256, rope_theta=500000.0,
    tie_embeddings=True,
    hgq=_HGQ)

SMOKE = ModelConfig(
    name="llama3.2-3b-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv=2, d_ff=128, vocab=256, rope_theta=500000.0,
    tie_embeddings=True, q_chunk=32, k_chunk=32,
    hgq=_HGQ)
