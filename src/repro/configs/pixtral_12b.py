"""pixtral-12b [vlm] 40L d5120 32H GQA kv=8 ff14336 v131072 — ViT frontend STUB (hf:mistralai/Pixtral-12B-2409)"""
from ..models.config import ModelConfig
from ..nn.common import HGQConfig

_HGQ = HGQConfig(weight_gran="per_channel", act_gran="per_tensor",
                 init_weight_f=6.0, init_act_f=6.0)

FULL = ModelConfig(
    name="pixtral-12b", family="vlm", n_layers=40, d_model=5120,
    n_heads=32, n_kv=8, d_ff=14336, vocab=131072, head_dim=128,
    rope_theta=1000000.0, n_patches=256,
    hgq=_HGQ)

SMOKE = ModelConfig(
    name="pixtral-12b-smoke", family="vlm", n_layers=2, d_model=64,
    n_heads=4, n_kv=2, d_ff=128, vocab=256, n_patches=8,
    q_chunk=32, k_chunk=32,
    hgq=_HGQ)
