from .base import ARCHS, ALIASES, SHAPES, ShapeSpec, get, cells
