"""recurrentgemma-2b [hybrid] 26L d2560 10H MQA kv=1 ff7680 v256000 — RG-LRU + local attn 1:2 (arXiv:2402.19427)"""
from ..models.config import ModelConfig
from ..nn.common import HGQConfig

_HGQ = HGQConfig(weight_gran="per_channel", act_gran="per_tensor",
                 init_weight_f=6.0, init_act_f=6.0)

FULL = ModelConfig(
    name="recurrentgemma-2b", family="hybrid", n_layers=26, d_model=2560,
    n_heads=10, n_kv=1, d_ff=7680, vocab=256000, window=2048,
    act="gelu",
    hgq=_HGQ)

SMOKE = ModelConfig(
    name="recurrentgemma-smoke", family="hybrid", n_layers=5, d_model=40,
    n_heads=4, n_kv=1, d_ff=80, vocab=256, window=16, act="gelu",
    q_chunk=16, k_chunk=16,
    hgq=_HGQ)
