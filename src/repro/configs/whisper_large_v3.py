"""whisper-large-v3 [audio] enc-dec 32L d1280 20H MHA kv=20 ff5120 v51866 — conv frontend STUB (arXiv:2212.04356)"""
from ..models.config import ModelConfig
from ..nn.common import HGQConfig

_HGQ = HGQConfig(weight_gran="per_channel", act_gran="per_tensor",
                 init_weight_f=6.0, init_act_f=6.0)

FULL = ModelConfig(
    name="whisper-large-v3", family="audio", n_layers=32, enc_layers=32,
    enc_seq=1500, d_model=1280, n_heads=20, n_kv=20, d_ff=5120,
    vocab=51866, norm="ln", act="gelu",
    hgq=_HGQ)

SMOKE = ModelConfig(
    name="whisper-smoke", family="audio", n_layers=2, enc_layers=2,
    enc_seq=16, d_model=64, n_heads=4, n_kv=4, d_ff=128, vocab=256,
    norm="ln", act="gelu", q_chunk=32, k_chunk=32,
    hgq=_HGQ)
