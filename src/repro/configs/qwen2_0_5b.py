"""qwen2-0.5b [dense] 24L d896 14H GQA kv=2 ff4864 v151936, QKV bias (arXiv:2407.10671)"""
from ..models.config import ModelConfig
from ..nn.common import HGQConfig

_HGQ = HGQConfig(weight_gran="per_channel", act_gran="per_tensor",
                 init_weight_f=6.0, init_act_f=6.0)

FULL = ModelConfig(
    name="qwen2-0.5b", family="dense", n_layers=24, d_model=896,
    n_heads=14, n_kv=2, d_ff=4864, vocab=151936, qkv_bias=True,
    rope_theta=1000000.0, tie_embeddings=True,
    hgq=_HGQ)

SMOKE = ModelConfig(
    name="qwen2-0.5b-smoke", family="dense", n_layers=2, d_model=56,
    n_heads=14, n_kv=2, d_ff=112, vocab=256, qkv_bias=True,
    tie_embeddings=True, q_chunk=32, k_chunk=32,
    hgq=_HGQ)
