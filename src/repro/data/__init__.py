from .synthetic import (jet_batch, svhn_batch, muon_batch, lm_batch,
                        DataSpec, make_pipeline)
