"""Deterministic, resumable synthetic data pipelines.

Every batch is a pure function of (seed, step) — restart at step k
reproduces byte-identical data with *no iterator state* in checkpoints,
which is the fault-tolerance property the brief asks for (a preempted node
rejoins and replays exactly).  Real datasets would slot in behind the same
``make_pipeline`` signature (the container is offline; see DESIGN.md SS8).

The generators are *learnable*: targets are deterministic functions of the
inputs plus noise, so train-loss-decreases integration tests are meaningful.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict

import jax
import jax.numpy as jnp


def _key(seed: int, step) -> jax.Array:
    return jax.random.fold_in(jax.random.PRNGKey(seed), step)


# ------------------------- paper-task generators --------------------------

def jet_batch(seed: int, step, batch: int = 1024, d: int = 16,
              n_classes: int = 5) -> Dict[str, jax.Array]:
    """5 Gaussian class clusters in 16-d (jet-tagging shaped)."""
    k1, k2, k3 = jax.random.split(_key(seed, step), 3)
    y = jax.random.randint(k1, (batch,), 0, n_classes)
    centers = jax.random.normal(jax.random.PRNGKey(7), (n_classes, d)) * 1.5
    x = centers[y] + jax.random.normal(k2, (batch, d))
    return {"x": x, "y": y}


def svhn_batch(seed: int, step, batch: int = 256) -> Dict[str, jax.Array]:
    """32x32x3 images whose class is encoded as a localized bright blob."""
    k1, k2 = jax.random.split(_key(seed, step))
    y = jax.random.randint(k1, (batch,), 0, 10)
    x = jax.random.uniform(k2, (batch, 32, 32, 3))
    cx = 4 + 3 * (y % 5)
    cy = 8 + 12 * (y // 5)
    ii = jnp.arange(32)
    blob = jnp.exp(-((ii[None, :, None] - cx[:, None, None]) ** 2
                     + (ii[None, None, :] - cy[:, None, None]) ** 2) / 8.0)
    x = x + 2.0 * blob[..., None]
    return {"x": x, "y": y}


def muon_batch(seed: int, step, batch: int = 1024) -> Dict[str, jax.Array]:
    """Three 3x50 binary hit maps of a straight track; target = angle (mrad).
    Station s fires strip round(25 + angle * z_s) in each of 3 layers."""
    k1, k2, k3 = jax.random.split(_key(seed, step), 3)
    angle = jax.random.uniform(k1, (batch,), minval=-0.25, maxval=0.25)
    z = jnp.array([0.3, 0.5, 0.7])          # station lever arms
    strips = jnp.clip(jnp.round(25.0 + 80.0 * angle[:, None] * z[None, :]),
                      0, 49).astype(jnp.int32)          # [B, 3]
    noise = jax.random.bernoulli(k2, 0.005, (batch, 3, 3, 50))
    hits = jax.nn.one_hot(strips[:, :, None].repeat(3, 2), 50)  # [B,3,3,50]
    jitter = jax.random.randint(k3, (batch, 3, 3), -1, 2)
    hits = jax.vmap(jax.vmap(jax.vmap(jnp.roll)))(hits, jitter)
    x = jnp.clip(hits + noise, 0, 1).reshape(batch, 3, 150)
    return {"stations": x, "target": angle * 1000.0}    # mrad


# ----------------------------- LM generator -------------------------------

def lm_batch(seed: int, step, batch: int, seq: int, vocab: int
             ) -> Dict[str, jax.Array]:
    """Markov-ish token stream: next token depends on the current one, so a
    model can actually reduce the loss below log(vocab)."""
    k1, k2 = jax.random.split(_key(seed, step))
    base = jax.random.randint(k1, (batch, seq), 0, vocab)
    shifted = jnp.roll(base, 1, axis=1) * 31 % vocab
    use_rule = jax.random.bernoulli(k2, 0.7, (batch, seq))
    tokens = jnp.where(use_rule, shifted, base)
    return {"tokens": tokens}


# ----------------------------- ASR generator -------------------------------

def asr_batch(seed: int, step, batch: int, seq: int, vocab: int,
              d_model: int, frames: int) -> Dict[str, jax.Array]:
    """Paired (frame_embeds, tokens) for encoder-decoder ASR smoke runs:
    the transcript is the same Markov-ish stream :func:`lm_batch` emits,
    and each of the ``frames`` frame embeddings is a fixed per-token code
    (of the token whose window covers that frame) plus noise — so the
    audio genuinely *encodes* the transcript and cross-attention has
    something to learn."""
    k1, k2, k3 = jax.random.split(_key(seed, step), 3)
    base = jax.random.randint(k1, (batch, seq), 0, vocab)
    shifted = jnp.roll(base, 1, axis=1) * 31 % vocab
    tokens = jnp.where(jax.random.bernoulli(k2, 0.7, (batch, seq)),
                       shifted, base)
    codes = jax.random.normal(jax.random.PRNGKey(13), (vocab, d_model))
    tok_at = tokens[:, (jnp.arange(frames) * seq) // frames]
    x = codes[tok_at] + 0.1 * jax.random.normal(k3,
                                                (batch, frames, d_model))
    return {"frame_embeds": x, "tokens": tokens}


# ----------------------------- pipeline API --------------------------------

@dataclasses.dataclass(frozen=True)
class DataSpec:
    kind: str           # jet | svhn | muon | lm | asr
    batch: int
    seq: int = 0
    vocab: int = 0
    seed: int = 0


def make_pipeline(spec: DataSpec, *, d_model: int = 0,
                  enc_seq: int = 0) -> Callable[[int], Dict[str, jax.Array]]:
    """step -> batch dict.  jit-able; resumable by construction.

    ``kind="asr"`` additionally needs the architecture's frame embedding
    dims (``d_model``, ``enc_seq``) — model facts, not data facts, so
    they ride in as kwargs (``RunContext.make_pipeline`` fills them)
    rather than as :class:`DataSpec` fields."""
    if spec.kind == "jet":
        return lambda step: jet_batch(spec.seed, step, spec.batch)
    if spec.kind == "svhn":
        return lambda step: svhn_batch(spec.seed, step, spec.batch)
    if spec.kind == "muon":
        return lambda step: muon_batch(spec.seed, step, spec.batch)
    if spec.kind == "lm":
        return lambda step: lm_batch(spec.seed, step, spec.batch, spec.seq,
                                     spec.vocab)
    if spec.kind == "asr":
        if d_model < 1 or enc_seq < 1:
            raise ValueError("kind='asr' needs the architecture dims: "
                             "make_pipeline(spec, d_model=..., enc_seq=...)")
        return lambda step: asr_batch(spec.seed, step, spec.batch, spec.seq,
                                      spec.vocab, d_model, enc_seq)
    raise ValueError(spec.kind)
