"""Attention-free sequence mixers: RWKV-6 (Finch) and RG-LRU (Griffin /
RecurrentGemma).

TPU adaptation notes (DESIGN.md SS2):
* RG-LRU is a *linear* recurrence h_t = a_t h_{t-1} + b_t — implemented with
  ``jax.lax.associative_scan`` (log-depth, MXU-friendly), not a sequential
  loop.  Decode carries (conv buffer, h) state — O(1) per token, which is
  why these archs run the 500k-token cell.
* RWKV-6's WKV recurrence has data-dependent per-channel decay; it is
  evaluated in fixed-size time chunks: within a chunk the quadratic
  (intra-chunk) part is a batched matmul, across chunks the state is carried
  by a short scan — the standard chunked-parallel linear-attention form.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..core import hgq
from ..core.hgq import Aux, QTensor
from ..dist.axes import constrain
from .basic import HDense
from .common import HGQConfig, qweight_init, get_qw


# ===========================================================================
# RWKV-6 time mix + channel mix
# ===========================================================================

@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    d_model: int
    n_heads: int          # head dim = d_model // n_heads
    d_ff: int
    decay_lora: int = 64
    time_chunk: int = 64
    wkv_impl: str = "chunked"  # 'chunked' (fast) | 'sequential' (exact oracle)


class RWKVState(NamedTuple):
    shift_a: jax.Array    # [B, d]  last token (time-mix shift)
    shift_f: jax.Array    # [B, d]  last token (channel-mix shift)
    wkv: jax.Array        # [B, H, N, N] recurrent state


class RWKVTimeMix:
    @staticmethod
    def init(key, cfg: RWKVConfig, qcfg: HGQConfig, dtype=jnp.float32):
        d = cfg.d_model
        H = cfg.n_heads
        N = d // H
        ks = jax.random.split(key, 8)
        p: Dict[str, Any] = {"mu": jnp.full((5, d), 0.5, dtype)}  # r,k,v,g,w
        q: Dict[str, Any] = {}
        for i, name in enumerate(("wr", "wk", "wv", "wg")):
            p[name], q[name] = HDense.init(ks[i], d, d, qcfg, bias=False,
                                           dtype=dtype)
        p["wo"], q["wo"] = HDense.init(ks[4], d, d, qcfg, bias=False,
                                       out_q=False, dtype=dtype)
        # data-dependent decay: w_t = exp(-exp(w0 + (x_w @ A) @ B))
        p["decay_w0"] = jnp.full((d,), -4.0, dtype)
        p["decay_a"] = qweight_init(ks[5], (d, cfg.decay_lora), qcfg,
                                    dtype=dtype)
        p["decay_b"] = qweight_init(ks[6], (cfg.decay_lora, d), qcfg,
                                    dtype=dtype)
        p["bonus_u"] = jnp.zeros((H, N), dtype)
        p["ln_scale"] = jnp.ones((d,), dtype)
        return p, q

    @staticmethod
    def apply(p, q, x: QTensor, state: Optional[RWKVState], *,
              cfg: RWKVConfig, mode: str, aux: Aux):
        B, S, d = x.q.shape
        H = cfg.n_heads
        N = d // H
        newq: Dict[str, Any] = {}
        prev = jnp.concatenate(
            [state.shift_a[:, None] if state is not None
             else jnp.zeros((B, 1, d), x.q.dtype), x.q[:, :-1]], axis=1)
        mu = p["mu"]
        xz = [x.q + (prev - x.q) * mu[i] for i in range(5)]  # r,k,v,g,w

        def proj(name, xi, act=None):
            t, newq[name] = HDense.apply(p[name], q[name],
                                         QTensor(xi, x.bits), mode=mode,
                                         aux=aux)
            return t.q if act is None else act(t.q)

        r = constrain(proj("wr", xz[0]).reshape(B, S, H, N), "b.m.")
        k = constrain(proj("wk", xz[1]).reshape(B, S, H, N), "b.m.")
        v = constrain(proj("wv", xz[2]).reshape(B, S, H, N), "b.m.")
        g = proj("wg", xz[3], jax.nn.silu)
        lw = jnp.tanh(xz[4] @ get_qw(p["decay_a"], mode).q)
        hgq.matmul_ebops(aux, x.bits, get_qw(p["decay_a"], mode).bits,
                         d, cfg.decay_lora)
        lw = lw @ get_qw(p["decay_b"], mode).q
        hgq.matmul_ebops(aux, None if x.bits is None else jnp.float32(8.0),
                         get_qw(p["decay_b"], mode).bits, cfg.decay_lora, d)
        w = jnp.exp(-jnp.exp(p["decay_w0"] + lw))  # (0,1) decay, [B,S,d]
        w = w.reshape(B, S, H, N)
        u = p["bonus_u"]

        wkv0 = state.wkv if state is not None \
            else jnp.zeros((B, H, N, N), jnp.float32)
        if cfg.wkv_impl == "sequential":
            y, wkv_out = _wkv_sequential(r, k, v, w, u, wkv0)
        else:
            y, wkv_out = _wkv_chunked(r, k, v, w, u, wkv0, cfg.time_chunk)
        y = y.reshape(B, S, d)
        # per-head group norm
        yh = y.reshape(B, S, H, N).astype(jnp.float32)
        yh = yh * jax.lax.rsqrt(jnp.mean(yh * yh, -1, keepdims=True) + 1e-6)
        y = (yh.reshape(B, S, d) * p["ln_scale"]).astype(x.q.dtype) * g
        out, newq["wo"] = HDense.apply(p["wo"], q["wo"], QTensor(y, x.bits),
                                       mode=mode, aux=aux)
        new_state = (x.q[:, -1], wkv_out)
        return out, newq, new_state


def _wkv_chunked(r, k, v, w, u, wkv0, chunk: int):
    """Chunked WKV:  S_t = diag(w_t) S_{t-1} + k_t v_t^T ;
    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T).

    r/k/v/w: [B, S, H, N]; u: [H, N]; wkv0: [B, H, N, N] (k-dim x v-dim).
    Returns y [B, S, H, N], final state.
    """
    B, S, H, N = r.shape
    c = min(chunk, S)
    nc = -(-S // c)
    pad = nc * c - S
    if pad:
        z = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = z(r), z(k), z(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)),
                    constant_values=1.0)
    # [nc, B, H, c, N]
    resh = lambda t: t.reshape(B, nc, c, H, N).transpose(1, 0, 3, 2, 4)
    rc, kc, vc, wc = resh(r), resh(k), resh(v), resh(w)
    logw = jnp.log(jnp.maximum(wc, 1e-38))
    cum = jnp.cumsum(logw, axis=3)                    # inclusive within chunk
    tot = cum[:, :, :, -1:, :]                        # chunk total decay

    def step(S_in, xs):
        rc_, kc_, vc_, cum_, tot_ = xs
        # state contribution at position t decays by exp(cum_{t-1}) (exclusive)
        excl = jnp.concatenate(
            [jnp.zeros_like(cum_[:, :, :1]), cum_[:, :, :-1]], axis=2)
        pre = jnp.exp(excl)
        # y_state_t = (r_t * pre_t) @ S_in
        y_state = jnp.einsum("bhtn,bhnm->bhtm", rc_ * pre, S_in)
        # intra-chunk: A[t,s] = sum_n r_t[n] k_s[n] exp(cum_{t-1,n} - cum_{s,n})
        # for s < t, computed as (r_t exp(cum_{t-1})) . (k_s exp(-cum_s)).
        # exp(-cum_s) is clipped for stability: only matters for channels that
        # decayed below e^-60 inside one chunk, whose contribution is ~0.
        kd = kc_ * jnp.exp(jnp.minimum(-cum_, 60.0))
        A = jnp.einsum("bhtn,bhsn->bhts", rc_ * pre, kd)
        tri = jnp.tril(jnp.ones((A.shape[-2], A.shape[-1])), -1)
        A = A * tri
        # bonus (current token) term: r_t . (u * k_t) v_t
        bonus = jnp.einsum("bhtn,bhtn->bht", rc_, u[None, :, None] * kc_)
        y = y_state + jnp.einsum("bhts,bhsm->bhtm", A, vc_) \
            + bonus[..., None] * vc_
        # state update: S_out = diag(exp(tot)) S_in + sum_s exp(tot - cum_s) k_s v_s
        S_out = jnp.exp(tot_)[:, :, 0, :, None] * S_in + jnp.einsum(
            "bhsn,bhsm->bhnm", kc_ * jnp.exp(tot_ - cum_), vc_)
        return S_out, y

    S_fin, ys = jax.lax.scan(step, wkv0.astype(jnp.float32),
                             (rc, kc, vc, cum, tot))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(B, nc * c, H, N)[:, :S]
    return y.astype(r.dtype), S_fin


def _wkv_sequential(r, k, v, w, u, wkv0):
    """Exact sequential WKV (oracle / harsh-decay fallback).

    Same contract as :func:`_wkv_chunked`.
    """
    B, S, H, N = r.shape

    def step(S_in, xs):
        rt, kt, vt, wt = xs                           # [B, H, N]
        kv = kt[..., :, None] * vt[..., None, :]      # [B, H, N, N]
        y = jnp.einsum("bhn,bhnm->bhm", rt, S_in + u[None, :, :, None] * kv)
        S_out = wt[..., :, None] * S_in + kv
        return S_out, y

    seq = lambda t: t.transpose(1, 0, 2, 3)           # [S, B, H, N]
    S_fin, ys = jax.lax.scan(
        step, wkv0.astype(jnp.float32),
        (seq(r.astype(jnp.float32)), seq(k.astype(jnp.float32)),
         seq(v.astype(jnp.float32)), seq(w.astype(jnp.float32))))
    return ys.transpose(1, 0, 2, 3).astype(r.dtype), S_fin


class RWKVChannelMix:
    @staticmethod
    def init(key, cfg: RWKVConfig, qcfg: HGQConfig, dtype=jnp.float32):
        k1, k2, k3 = jax.random.split(key, 3)
        d = cfg.d_model
        p: Dict[str, Any] = {"mu": jnp.full((2, d), 0.5, dtype)}
        q: Dict[str, Any] = {}
        p["wk"], q["wk"] = HDense.init(k1, d, cfg.d_ff, qcfg, bias=False,
                                       act="relu", dtype=dtype)
        p["wv"], q["wv"] = HDense.init(k2, cfg.d_ff, d, qcfg, bias=False,
                                       out_q=False, dtype=dtype)
        p["wr"], q["wr"] = HDense.init(k3, d, d, qcfg, bias=False,
                                       act="sigmoid", dtype=dtype)
        return p, q

    @staticmethod
    def apply(p, q, x: QTensor, shift: Optional[jax.Array], *, mode: str,
              aux: Aux):
        B, S, d = x.q.shape
        newq: Dict[str, Any] = {}
        prev = jnp.concatenate(
            [shift[:, None] if shift is not None
             else jnp.zeros((B, 1, d), x.q.dtype), x.q[:, :-1]], axis=1)
        xk = x.q + (prev - x.q) * p["mu"][0]
        xr = x.q + (prev - x.q) * p["mu"][1]
        kq, newq["wk"] = HDense.apply(p["wk"], q["wk"], QTensor(xk, x.bits),
                                      mode=mode, aux=aux, act="relu")
        k2 = QTensor(kq.q * kq.q,
                     None if kq.bits is None else 2.0 * kq.bits)
        vq, newq["wv"] = HDense.apply(p["wv"], q["wv"], k2, mode=mode, aux=aux)
        rq, newq["wr"] = HDense.apply(p["wr"], q["wr"], QTensor(xr, x.bits),
                                      mode=mode, aux=aux, act="sigmoid")
        return QTensor(rq.q * vq.q, None), newq, x.q[:, -1]


# ===========================================================================
# RG-LRU (Griffin / RecurrentGemma) recurrent block
# ===========================================================================

@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    d_model: int
    d_rnn: int
    conv_width: int = 4
    c_const: float = 8.0


class GriffinState(NamedTuple):
    conv: jax.Array   # [B, conv_width-1, d_rnn]
    h: jax.Array      # [B, d_rnn]


class RecurrentBlock:
    """Griffin recurrent block: (gelu branch) * (conv -> RG-LRU branch)."""

    @staticmethod
    def init(key, cfg: RGLRUConfig, qcfg: HGQConfig, dtype=jnp.float32):
        ks = jax.random.split(key, 6)
        d, dr = cfg.d_model, cfg.d_rnn
        p: Dict[str, Any] = {}
        q: Dict[str, Any] = {}
        p["in_gelu"], q["in_gelu"] = HDense.init(ks[0], d, dr, qcfg,
                                                 bias=False, act="gelu",
                                                 dtype=dtype)
        p["in_rnn"], q["in_rnn"] = HDense.init(ks[1], d, dr, qcfg, bias=False,
                                               dtype=dtype)
        p["conv_w"] = qweight_init(ks[2], (cfg.conv_width, dr), qcfg,
                                   dtype=dtype)
        p["gate_a"], q["gate_a"] = HDense.init(ks[3], dr, dr, qcfg,
                                               bias=True, dtype=dtype)
        p["gate_x"], q["gate_x"] = HDense.init(ks[4], dr, dr, qcfg,
                                               bias=True, dtype=dtype)
        p["lambda"] = jnp.full((dr,), 2.2, dtype)  # sigmoid ~ 0.9
        p["out"], q["out"] = HDense.init(ks[5], dr, d, qcfg, bias=False,
                                         out_q=False, dtype=dtype)
        return p, q

    @staticmethod
    def apply(p, q, x: QTensor, state: Optional[GriffinState], *,
              cfg: RGLRUConfig, mode: str, aux: Aux):
        B, S, d = x.q.shape
        dr = cfg.d_rnn
        cw = cfg.conv_width
        newq: Dict[str, Any] = {}
        gelu_b, newq["in_gelu"] = HDense.apply(p["in_gelu"], q["in_gelu"], x,
                                               mode=mode, aux=aux, act="gelu")
        rnn_b, newq["in_rnn"] = HDense.apply(p["in_rnn"], q["in_rnn"], x,
                                             mode=mode, aux=aux)
        # causal depthwise conv1d (width cw)
        prev = state.conv if state is not None \
            else jnp.zeros((B, cw - 1, dr), rnn_b.q.dtype)
        xc = jnp.concatenate([prev, rnn_b.q], axis=1)
        wq = get_qw(p["conv_w"], mode)
        u = constrain(sum(xc[:, i:i + S] * wq.q[i] for i in range(cw)),
                      "b.m")
        if rnn_b.bits is not None and wq.bits is not None:
            aux.add(ebops=jnp.max(rnn_b.bits) * jnp.sum(
                jnp.broadcast_to(wq.bits, (cw, dr))))
        uq = QTensor(u, rnn_b.bits)
        # RG-LRU gates
        ra, newq["gate_a"] = HDense.apply(p["gate_a"], q["gate_a"], uq,
                                          mode=mode, aux=aux)
        rx, newq["gate_x"] = HDense.apply(p["gate_x"], q["gate_x"], uq,
                                          mode=mode, aux=aux)
        r_a = jax.nn.sigmoid(ra.q.astype(jnp.float32))
        i_x = jax.nn.sigmoid(rx.q.astype(jnp.float32))
        log_a0 = -cfg.c_const * jax.nn.softplus(p["lambda"]).astype(jnp.float32)
        log_a = log_a0 * r_a                              # [B, S, dr], <= 0
        a = jnp.exp(log_a)
        gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
            * i_x * u.astype(jnp.float32)
        h0 = state.h if state is not None else jnp.zeros((B, dr), jnp.float32)
        h = constrain(_linear_scan(a, gated, h0), "b.m")  # associative scan
        y = (gelu_b.q.astype(jnp.float32) * h).astype(x.q.dtype)
        out, newq["out"] = HDense.apply(p["out"], q["out"],
                                        QTensor(y, gelu_b.bits), mode=mode,
                                        aux=aux)
        new_state = GriffinState(conv=xc[:, -(cw - 1):], h=h[:, -1])
        return out, newq, new_state


def _linear_scan(a: jax.Array, b: jax.Array, h0: jax.Array) -> jax.Array:
    """h_t = a_t * h_{t-1} + b_t via associative_scan along axis 1."""
    b = b.at[:, 0].add(a[:, 0] * h0) if h0 is not None else b

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h
