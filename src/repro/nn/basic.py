"""Basic quantized layers: dense, conv2d, embedding, norms, activations.

Static layer attributes (activation name, conv stride/padding) are passed at
apply time, NOT stored in params — params must stay a pure-array pytree so
layer stacks can be vmap-initialized and lax.scan'ed.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core import ebops as ebops_lib
from ..core import hgq
from ..core.hgq import Aux, QTensor
from ..core.quantizer import f_shape_for
from .common import HGQConfig, act_q_init, apply_act_q, get_qw, qweight_init


def activation(name: str, x: jax.Array) -> jax.Array:
    if not name or name == "linear":
        return x
    return {"relu": jax.nn.relu, "silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "tanh": jnp.tanh, "sigmoid": jax.nn.sigmoid,
            "softmax": lambda v: jax.nn.softmax(v, axis=-1)}[name](x)


class HDense:
    """The paper's HDense: quantized kernel (+bias), EBOPs on x@W, optional
    fused activation + output activation quantizer."""

    @staticmethod
    def init(key, d_in: int, d_out: int, cfg: HGQConfig, *, bias: bool = True,
             act: Optional[str] = None, out_q: bool = True,
             dtype=jnp.float32):
        del act  # static; passed at apply time
        kk, _ = jax.random.split(key)
        p: Dict[str, Any] = {"kernel": qweight_init(kk, (d_in, d_out), cfg,
                                                    dtype=dtype)}
        q: Dict[str, Any] = {}
        if bias:
            p["bias"] = {"w": jnp.zeros((d_out,), dtype)}
            if cfg.enabled:
                p["bias"]["f"] = jnp.full(
                    f_shape_for((d_out,), cfg.weight_gran),
                    cfg.init_weight_f, jnp.float32)
        if out_q:
            f, st = act_q_init(cfg)
            if f is not None:
                p["out_f"] = f
                q["out"] = st
        return p, q

    @staticmethod
    def apply(p, q, x: QTensor, *, mode: str, aux: Aux, act: str = ""
              ) -> Tuple[QTensor, Dict[str, Any]]:
        from ..dist.perf import (cast_for_matmul, get_compute_dtype,
                                 get_packed_matmul, is_packed,
                                 packed_mantissas)
        if is_packed(p["kernel"]) and get_packed_matmul():
            # serving hot path (serving/packed.py): the packed mantissas
            # stream straight into the fused dequant-matmul Pallas kernel
            # (nibble-stored layers sign-extend to int8 first) — the
            # weight bytes moved from HBM are the packed ones
            from ..kernels.qmatmul.ops import qmatmul_any
            ki = packed_mantissas(p["kernel"])
            y = qmatmul_any(x.q.astype(jnp.float32), ki,
                            p["kernel"]["scale"].reshape(ki.shape[-1])
                            ).astype(x.q.dtype)
        else:
            wq = get_qw(p["kernel"], mode)
            d_in, d_out = wq.q.shape
            xq = cast_for_matmul(x.q).astype(wq.q.dtype)
            # under bf16-compute the cross-shard partial-sum all-reduce runs
            # on the bf16 output (Megatron convention) — halves the TP
            # collective; otherwise accumulate/reduce in f32
            pet = jnp.float32 if get_compute_dtype() is None else None
            y = jnp.matmul(xq, wq.q,
                           preferred_element_type=pet).astype(x.q.dtype)
            hgq.matmul_ebops(aux, x.bits, wq.bits, d_in, d_out)
        if "bias" in p:
            y = y + get_qw(p["bias"], mode).q
        y = activation(act, y)
        newq = dict(q) if q else {}
        if "out_f" in p:
            yq, st = apply_act_q(y, p["out_f"], q.get("out"), mode, aux)
            if st is not None:
                newq["out"] = st
            return yq, newq
        return QTensor(y, None), newq


class HConv2D:
    """SAME/VALID conv with stream-IO EBOPs counting (DESIGN.md SS2)."""

    @staticmethod
    def init(key, kh: int, kw: int, cin: int, cout: int, cfg: HGQConfig, *,
             act: Optional[str] = None, bias: bool = True, out_q: bool = True,
             dtype=jnp.float32):
        del act
        kk, _ = jax.random.split(key)
        p = {"kernel": qweight_init(kk, (kh, kw, cin, cout), cfg,
                                    dtype=dtype)}
        q: Dict[str, Any] = {}
        if bias:
            p["bias"] = {"w": jnp.zeros((cout,), dtype)}
            if cfg.enabled:
                p["bias"]["f"] = jnp.full(f_shape_for((cout,),
                                                      cfg.weight_gran),
                                          cfg.init_weight_f, jnp.float32)
        if out_q:
            f, st = act_q_init(cfg)
            if f is not None:
                p["out_f"] = f
                q["out"] = st
        return p, q

    @staticmethod
    def apply(p, q, x: QTensor, *, mode: str, aux: Aux, act: str = "",
              stride: int = 1, padding: str = "VALID"):
        wq = get_qw(p["kernel"], mode)
        w_shape = p["kernel"]["w"].shape
        y = jax.lax.conv_general_dilated(
            x.q, wq.q, window_strides=(stride, stride), padding=padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        if x.bits is not None and wq.bits is not None:
            aux.add(ebops=ebops_lib.ebops_conv2d(
                _chan_bits(x.bits, w_shape[2]), wq.bits, w_shape))
        if "bias" in p:
            y = y + get_qw(p["bias"], mode).q
        y = activation(act, y)
        newq = dict(q) if q else {}
        if "out_f" in p:
            yq, st = apply_act_q(y, p["out_f"], q.get("out"), mode, aux)
            if st is not None:
                newq["out"] = st
            return yq, newq
        return QTensor(y, None), newq


def _chan_bits(bits: jax.Array, cin: int) -> jax.Array:
    """Collapse activation bits to per-input-channel for conv EBOPs."""
    b = jnp.asarray(bits, jnp.float32)
    if b.ndim == 0:
        return b
    return jnp.max(b.reshape(-1, b.shape[-1]), axis=0) if b.shape[-1] == cin \
        else jnp.max(b) * jnp.ones((1,), jnp.float32)


class HEmbedding:
    """Lookup = no multipliers => no EBOPs; the table is still quantized (its
    bits feed the packed-bytes TPU cost and the L1 term)."""

    @staticmethod
    def init(key, vocab: int, d: int, cfg: HGQConfig, dtype=jnp.float32):
        p = {"table": qweight_init(key, (vocab, d), cfg, channel_axis=-1,
                                   scale=0.02, dtype=dtype)}
        return p, {}

    @staticmethod
    def apply(p, q, ids: jax.Array, *, mode: str, aux: Aux):
        wq = get_qw(p["table"], mode)
        y = jnp.take(wq.q, ids, axis=0)
        return QTensor(y, None), (dict(q) if q else {})


class RMSNorm:
    @staticmethod
    def init(key, d: int, cfg: HGQConfig, *, out_q: bool = True,
             dtype=jnp.float32):
        p = {"scale": jnp.ones((d,), dtype)}
        q: Dict[str, Any] = {}
        if out_q:
            f, st = act_q_init(cfg)
            if f is not None:
                p["out_f"] = f
                q["out"] = st
        return p, q

    @staticmethod
    def apply(p, q, x: jax.Array, *, mode: str, aux: Aux, eps: float = 1e-6):
        xf = x.astype(jnp.float32)
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        y = (y * p["scale"]).astype(x.dtype)
        newq = dict(q) if q else {}
        if "out_f" in p:
            yq, st = apply_act_q(y, p["out_f"], q.get("out"), mode, aux)
            if st is not None:
                newq["out"] = st
            return yq, newq
        return QTensor(y, None), newq


class LayerNorm:
    @staticmethod
    def init(key, d: int, cfg: HGQConfig, *, out_q: bool = True,
             dtype=jnp.float32):
        p = {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
        q: Dict[str, Any] = {}
        if out_q:
            f, st = act_q_init(cfg)
            if f is not None:
                p["out_f"] = f
                q["out"] = st
        return p, q

    @staticmethod
    def apply(p, q, x: jax.Array, *, mode: str, aux: Aux, eps: float = 1e-5):
        xf = x.astype(jnp.float32)
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = (y * p["scale"] + p["bias"]).astype(x.dtype)
        newq = dict(q) if q else {}
        if "out_f" in p:
            yq, st = apply_act_q(y, p["out_f"], q.get("out"), mode, aux)
            if st is not None:
                newq["out"] = st
            return yq, newq
        return QTensor(y, None), newq
