"""Shared plumbing for the quantized functional layer library.

Layers are pure functions over nested-dict params.  Every quantizable
weight is stored as ``{'w': array, 'f': frac-bit array}``; every quantized
activation has a trainable ``f`` in params and an (vmin, vmax) ActState in
the separate ``qstate`` tree (same tree structure as params, only at
activation-quantizer leaves).

Convention:  ``Layer.init(key, ...) -> (params, qstate)`` and
``Layer.apply(params, qstate, x, *, cfg, mode, aux) -> (y, new_qstate)``.
With HGQ disabled (cfg.hgq.enabled = False) params carry no ``f`` leaves and
apply() degenerates to the float baseline — this is how the paper's BF/BP
baselines are expressed.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core import hgq
from ..core.hgq import ActState, Aux, QTensor
from ..core.quantizer import f_shape_for


@dataclasses.dataclass(frozen=True)
class HGQConfig:
    """Per-model quantization policy."""
    enabled: bool = True
    weight_gran: str = "per_parameter"   # paper tasks; LLMs use per_channel
    act_gran: str = "per_tensor"
    init_weight_f: float = 2.0           # paper: jet=2, svhn/muon=6
    init_act_f: float = 2.0
    # beta/gamma live in the training loop (Eq. 16), not in the layers

    def off(self) -> "HGQConfig":
        return dataclasses.replace(self, enabled=False)


FP_BASELINE = HGQConfig(enabled=False)


def uniform_init(key, shape, scale=None, dtype=jnp.float32):
    """LeCun-uniform (matches Keras defaults used by the paper's library)."""
    fan_in = shape[0] if len(shape) > 1 else max(shape[-1], 1)
    if len(shape) == 4:  # conv kernel [kh, kw, cin, cout]
        fan_in = shape[0] * shape[1] * shape[2]
    limit = scale if scale is not None else (3.0 / fan_in) ** 0.5
    return jax.random.uniform(key, shape, dtype, -limit, limit)


def qweight_init(key, shape, cfg: HGQConfig, channel_axis: int = -1,
                 scale: float = None, dtype=jnp.float32) -> Dict[str, Any]:
    p = {"w": uniform_init(key, shape, scale, dtype)}
    if cfg.enabled:
        p["f"] = jnp.full(f_shape_for(shape, cfg.weight_gran, channel_axis),
                          cfg.init_weight_f, jnp.float32)
    return p


def act_q_init(cfg: HGQConfig, feature_shape=()) -> Tuple[Optional[jax.Array],
                                                          Optional[ActState]]:
    """Returns (f param or None, range state or None) for one activation
    quantizer."""
    if not cfg.enabled:
        return None, None
    f_sh = f_shape_for(feature_shape, cfg.act_gran) if feature_shape else ()
    f = jnp.full(f_sh, cfg.init_act_f, jnp.float32)
    return f, hgq.init_act_state(f_sh)


def get_qw(p: Dict[str, Any], mode: str) -> QTensor:
    """Quantize (or pass through) a stored weight.

    Packed serving path (dist.perf.pack_params_for_serving): the kernel is
    stored int8 + per-channel scale; dequantize at use — XLA fuses this into
    the consuming matmul, exactly the structure of kernels/qmatmul.
    """
    if "w_int8" in p or "w_nib" in p:
        from ..dist.perf import unpack_weight
        w = unpack_weight(p)
        return QTensor(w, None if p.get("f") is None else
                       jax.nn.relu(jnp.asarray(p["f"], jnp.float32)) + 1.0)
    qt = hgq.quant_weight(p["w"], p.get("f"), mode)
    from ..dist.perf import cast_for_matmul
    return QTensor(cast_for_matmul(qt.q), qt.bits)


def apply_act_q(x: jax.Array, f: Optional[jax.Array],
                state: Optional[ActState], mode: str, aux: Aux
                ) -> Tuple[QTensor, Optional[ActState]]:
    return hgq.quant_act(x, f, state, mode, aux)
