from .common import HGQConfig, FP_BASELINE
from .basic import HDense, HConv2D, HEmbedding, RMSNorm, LayerNorm, activation
from .attention import AttnConfig, GQAAttention, KVCache, rope
from .mlp import GLUMLP, MLP
from .moe import MoE, MoEConfig
from .recurrent import (RWKVConfig, RWKVTimeMix, RWKVChannelMix, RWKVState,
                        RGLRUConfig, RecurrentBlock, GriffinState)
