"""Quantized Mixture-of-Experts block (top-k routing, GLU experts).

Dispatch is *per batch row* (vmap over B): each row sorts its own S*k
(token, expert) pairs and packs them into a fixed [E, C, d] buffer.  Because
rows are sharded over the ``data`` mesh axis, the sort/scatter never crosses
shards — no all-to-all is induced at 512 chips (DESIGN.md SS5); expert weights
are replicated/TP-sharded on ``model`` (EP=1 — assigned MoEs have tiny
per-expert d_ff but many experts, so expert-parallel dispatch would be
collective-dominant instead).

~EBOPs counts *active* compute only (top_k/E of each expert's multipliers),
matching the paper's "count only ops executed in parallel" rule and the
6*N_active*D MoE FLOPs convention.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..core.hgq import Aux, QTensor
from ..dist.axes import constrain
from .basic import HDense, activation
from .common import (HGQConfig, act_q_init, apply_act_q, get_qw,
                     uniform_init)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int            # per-expert hidden
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    act: str = "silu"


def _expert_weight(key, e: int, din: int, dout: int, cfg: HGQConfig,
                   dtype=jnp.float32) -> Dict[str, Any]:
    w = uniform_init(key, (e, din, dout), dtype=dtype)
    p = {"w": w}
    if cfg.enabled:
        if cfg.weight_gran == "per_parameter":
            f_sh = (e, din, dout)
        elif cfg.weight_gran == "per_channel":
            f_sh = (e, 1, dout)            # per-expert, per-out-channel
        else:
            f_sh = (e, 1, 1)               # per-expert tensor
        p["f"] = jnp.full(f_sh, cfg.init_weight_f, jnp.float32)
    return p


class MoE:
    @staticmethod
    def init(key, cfg: MoEConfig, qcfg: HGQConfig, dtype=jnp.float32):
        kr, kg, ku, kd = jax.random.split(key, 4)
        d, dff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
        p: Dict[str, Any] = {}
        q: Dict[str, Any] = {}
        p["router"], q["router"] = HDense.init(kr, d, E, qcfg, bias=False,
                                               out_q=False, dtype=dtype)
        p["gate"] = _expert_weight(kg, E, d, dff, qcfg, dtype)
        p["up"] = _expert_weight(ku, E, d, dff, qcfg, dtype)
        p["down"] = _expert_weight(kd, E, dff, d, qcfg, dtype)
        if qcfg.enabled:
            f, st = act_q_init(qcfg)
            p["h_f"] = f
            q["h"] = st
        return p, q

    @staticmethod
    def apply(p, q, x: QTensor, *, cfg: MoEConfig, mode: str, aux: Aux
              ) -> Tuple[QTensor, Dict[str, Any]]:
        B, S, d = x.q.shape
        E, k, dff = cfg.n_experts, cfg.top_k, cfg.d_ff
        newq: Dict[str, Any] = {}
        logits, newq["router"] = HDense.apply(p["router"], q["router"], x,
                                              mode=mode, aux=aux)
        probs = jax.nn.softmax(logits.q.astype(jnp.float32), axis=-1)
        gates, eidx = jax.lax.top_k(probs, k)            # [B, S, k]
        gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)

        wg = get_qw(p["gate"], mode)
        wu = get_qw(p["up"], mode)
        wd = get_qw(p["down"], mode)

        C = max(1, math.ceil(S * k / E * cfg.capacity_factor))

        def row_dispatch(xr, er, gr):
            """xr [S, d]; er/gr [S, k] -> MoE output [S, d] for one row."""
            Tk = S * k
            e_flat = er.reshape(Tk)
            tok_flat = jnp.repeat(jnp.arange(S), k)
            g_flat = gr.reshape(Tk)
            order = jnp.argsort(e_flat, stable=True)
            se, st_, sg_ = e_flat[order], tok_flat[order], g_flat[order]
            counts = jnp.bincount(e_flat, length=E)
            starts = jnp.cumsum(counts) - counts
            pos = jnp.arange(Tk) - starts[se]
            valid = pos < C
            slot = jnp.where(valid, se * C + pos, E * C)   # E*C = dump slot
            buf = jnp.zeros((E * C + 1, d), xr.dtype).at[slot].set(xr[st_])
            xe = buf[:E * C].reshape(E, C, d)
            g_h = jnp.einsum("ecd,edf->ecf", xe, wg.q,
                             preferred_element_type=jnp.float32)
            u_h = jnp.einsum("ecd,edf->ecf", xe, wu.q,
                             preferred_element_type=jnp.float32)
            h = activation(cfg.act, g_h) * u_h
            return h.astype(xr.dtype), (slot, st_, sg_)

        def row_combine(h, wdq, meta):
            slot, st_, sg_ = meta
            y_e = jnp.einsum("ecf,efd->ecd", h, wdq,
                             preferred_element_type=jnp.float32)
            y_flat = jnp.concatenate(
                [y_e.reshape(E * C, d), jnp.zeros((1, d), y_e.dtype)], axis=0)
            contrib = y_flat[slot] * sg_[:, None]
            return jnp.zeros((S, d), jnp.float32).at[st_].add(contrib)

        h_all, meta = jax.vmap(row_dispatch)(x.q, eidx, gates)
        h_all = constrain(h_all, "b..m")
        # quantize the expert hidden activation (per-tensor) before down-proj
        if p.get("h_f") is not None:
            hq, newq["h"] = apply_act_q(h_all, p["h_f"], q.get("h"), mode, aux)
            h_all = hq.q
            h_bits = hq.bits
        else:
            h_bits = None
        y = jax.vmap(row_combine, in_axes=(0, None, 0))(h_all, wd.q, meta)
        y = constrain(y.astype(x.q.dtype), "b..")

        # ---- active-compute ~EBOPs (analytic, scaled by k/E) ----
        if x.bits is not None and wg.bits is not None:
            frac = float(k) / float(E)

            def _wsum(bits, full_shape):
                mult = math.prod(full_shape) / math.prod(bits.shape)
                return jnp.sum(bits) * mult

            e_in = jnp.max(x.bits) * (_wsum(wg.bits, (E, d, dff))
                                      + _wsum(wu.bits, (E, d, dff)))
            aux.add(ebops=frac * e_in)
            if h_bits is not None and wd.bits is not None:
                aux.add(ebops=frac * jnp.max(h_bits)
                        * _wsum(wd.bits, (E, dff, d)))
        return QTensor(y, None), newq
