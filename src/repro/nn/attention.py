"""Quantized GQA attention: chunked (flash-style) train/prefill, KV-cache
decode, optional local window (RecurrentGemma), RoPE.

Memory: full S x S score tensors are never materialized — a two-level
``lax.scan`` over query/key chunks with online softmax keeps the working set
O(chunk^2), which is what lets the 32k-prefill cells compile within HBM on
the production mesh (and is the natural chunking a TPU flash kernel uses).

EBOPs: the dynamic QK^T / PV matmuls use per-tensor activation bits, so
their ~EBOPs terms are computed *analytically* from the static shapes —
no extra tensor work inside the scan (DESIGN.md SS2).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core import hgq
from ..core.hgq import Aux, QTensor
from ..core.quantizer import quantize, quantize_inference
from ..dist.axes import constrain, get_model_size
from .basic import HDense
from .common import HGQConfig, act_q_init, apply_act_q

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    window: Optional[int] = None      # local attention window (RG / pixtral)
    causal: bool = True
    q_chunk: int = 1024
    k_chunk: int = 1024


class KVCache(NamedTuple):
    k: jax.Array  # [B, S_max, KV, hd]
    v: jax.Array


class QKVCache(NamedTuple):
    """Plan-width quantized ring cache: int8 mantissas on a per-row
    (token x kv-head) 2^-f grid, the grid exponents riding alongside in
    ring-indexed buffers scatter-written at the same slots.  ``k``/``v``
    are ``[B, W, KV, hd]`` (or ``[B, W, KV, hd // 2]`` nibble-packed when
    ``kv_bits <= 4``); ``kf``/``vf`` are ``[B, W, KV]`` int8 exponents.
    Built by ``serving/kvcache.py``; read by the fused dequant-attention
    kernel (``kernels/kv_dequant``)."""
    k: jax.Array
    v: jax.Array
    kf: jax.Array
    vf: jax.Array


# int8 KV cache (beyond-paper, HGQ-machinery): k/v stored as round(x * 2^4)
# — halves cache HBM traffic vs bf16 at decode.  Static scale: post-HGQ
# activations are range-calibrated, |k|,|v| < 8 by construction.
KV_INT8_SCALE = 16.0


def _cache_store(x: jax.Array, cache_dtype) -> jax.Array:
    if cache_dtype == jnp.int8:
        return jnp.clip(jnp.round(x.astype(jnp.float32) * KV_INT8_SCALE),
                        -127, 127).astype(jnp.int8)
    return x.astype(cache_dtype)


def _cache_load(x: jax.Array) -> jax.Array:
    if x.dtype == jnp.int8:
        return x.astype(jnp.float32) * (1.0 / KV_INT8_SCALE)
    return x


def decode_positions(cache_pos: jax.Array, S: int) -> jax.Array:
    """Token positions for a decode chunk of S new tokens.

    ``cache_pos`` is either a scalar (all batch rows aligned) or a per-slot
    vector ``[B]`` (continuous batching: every row at its own offset).
    Returns ``[S]`` or ``[B, S]`` accordingly.
    """
    cp = jnp.asarray(cache_pos, jnp.int32)
    ar = jnp.arange(S, dtype=jnp.int32)
    return cp[:, None] + ar[None, :] if cp.ndim == 1 else cp + ar


def memory_tpos(mem_len: jax.Array, T: int) -> jax.Array:
    """Slot positions for a linearly-filled (non-ring) memory buffer of
    width ``T`` holding ``mem_len[b]`` valid rows: slot t carries global
    position t while t < mem_len, else -1 (the empty-slot sentinel the
    decode masks share).  This is how encoder-decoder cross-attention
    reads a partially-streamed memory through the same ``tpos``-masked
    decode kernels the ring caches use — ``mem_len == 0`` masks every
    slot, so rows with no memory (e.g. LM traffic sharing a batch with
    ASR) get an exactly-zero attention read."""
    ar = jnp.arange(T, dtype=jnp.int32)
    mem = jnp.asarray(mem_len, jnp.int32)
    return jnp.where(ar[None, :] < mem[:, None], ar[None, :], -1)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, H, hd]; positions: [B, S] or [S]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.log(theta) * (jnp.arange(half, dtype=jnp.float32)
                                       / half))
    pos = positions.astype(jnp.float32)
    ang = pos[..., None] * freqs  # [B?, S, half]
    while ang.ndim < x.ndim:
        ang = ang[..., None, :] if ang.ndim == x.ndim - 1 else ang[None]
    # ang now [B, S, 1, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


class GQAAttention:
    @staticmethod
    def init(key, cfg: AttnConfig, qcfg: HGQConfig, dtype=jnp.float32):
        ks = jax.random.split(key, 4)
        d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim
        p: Dict[str, Any] = {}
        q: Dict[str, Any] = {}
        for name, dout, kk in (("wq", H * hd, ks[0]), ("wk", KV * hd, ks[1]),
                               ("wv", KV * hd, ks[2])):
            p[name], q[name] = HDense.init(kk, d, dout, qcfg,
                                           bias=cfg.qkv_bias, dtype=dtype)
        p["wo"], q["wo"] = HDense.init(ks[3], H * hd, d, qcfg, bias=False,
                                       out_q=False, dtype=dtype)
        if qcfg.enabled:
            p["probs_f"] = jnp.full((), qcfg.init_act_f, jnp.float32)
            f, st = act_q_init(qcfg)
            p["attnout_f"] = f
            q["attnout"] = st
        return p, q

    @staticmethod
    def apply(p, q, x: QTensor, *, cfg: AttnConfig, mode: str, aux: Aux,
              positions: jax.Array, cache: Optional[KVCache] = None,
              cache_pos: Optional[jax.Array] = None,
              kv_bits: Optional[int] = None
              ) -> Tuple[QTensor, Dict[str, Any], Optional[KVCache]]:
        B, S, _ = x.q.shape
        H, KV, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
        newq: Dict[str, Any] = {}
        qt, newq["wq"] = HDense.apply(p["wq"], q["wq"], x, mode=mode, aux=aux)
        kt, newq["wk"] = HDense.apply(p["wk"], q["wk"], x, mode=mode, aux=aux)
        vt, newq["wv"] = HDense.apply(p["wv"], q["wv"], x, mode=mode, aux=aux)
        qh = constrain(qt.q.reshape(B, S, H, hd), "b.m.")
        # under head-TP (H %% TP == 0) k/v get repeated to full heads inside
        # the chunked path; keep the small KV-head tensors replicated over
        # `model` here so the repeat is a local broadcast, not an all-to-all
        # (observed: 344 GB of all-to-all at qwen110 prefill)
        kv_pat = "b..." if (get_model_size() > 1
                            and H % get_model_size() == 0) else "b.m."
        kh = constrain(kt.q.reshape(B, S, KV, hd), kv_pat)
        vh = constrain(vt.q.reshape(B, S, KV, hd), kv_pat)
        qh = rope(qh, positions, cfg.rope_theta)
        kh = rope(kh, positions, cfg.rope_theta)

        probs_f = p.get("probs_f")
        new_cache = None
        if cache is not None:
            # decode: append new k/v, attend over the cache.  Windowed caches
            # are ring buffers of size W: global position g lives in slot g%W.
            # cache_pos is scalar (aligned batch) or [B] (ragged continuous
            # batching — every row writes/reads at its own offset).
            W = cache.k.shape[1]
            cpb = jnp.broadcast_to(jnp.asarray(cache_pos, jnp.int32), (B,))
            qpos = cpb[:, None] + jnp.arange(S)        # [B, S] global q pos
            if cfg.window is not None:
                # ring write: if a chunk longer than the ring ever aliases
                # two positions onto one slot, keep only the newest (scatter
                # order with duplicate indices is otherwise unspecified) —
                # stale writes get an out-of-bounds slot and are dropped
                last = cpb + (S - 1)
                slot = jnp.where(qpos > last[:, None] - W, qpos % W, W)
            else:
                slot = qpos
            bidx = jnp.arange(B)[:, None]
            quantized = isinstance(cache, QKVCache)
            if quantized:
                # plan-width store: per-row 2^-f grid mantissas + the grid
                # exponents, scatter-written at the same newest-wins slots
                from ..kernels.kv_dequant.ops import (kv_attention_decode,
                                                      kv_pack, kv_quantize)
                km_new, kf_new = kv_quantize(kh, kv_bits or 8)
                vm_new, vf_new = kv_quantize(vh, kv_bits or 8)
                if cache.k.shape[-1] != kh.shape[-1]:
                    km_new, vm_new = kv_pack(km_new), kv_pack(vm_new)
                k_all = cache.k.at[bidx, slot].set(km_new, mode="drop")
                v_all = cache.v.at[bidx, slot].set(vm_new, mode="drop")
                kf_all = cache.kf.at[bidx, slot].set(kf_new, mode="drop")
                vf_all = cache.vf.at[bidx, slot].set(vf_new, mode="drop")
                new_cache = QKVCache(k_all, v_all, kf_all, vf_all)
            else:
                k_all = cache.k.at[bidx, slot].set(
                    _cache_store(kh, cache.k.dtype), mode="drop")
                v_all = cache.v.at[bidx, slot].set(
                    _cache_store(vh, cache.v.dtype), mode="drop")
                new_cache = KVCache(k_all, v_all)
            if cfg.window is not None:
                # slot s holds global position last - ((last - s) % W) where
                # last is the row's newest written position; never-written
                # slots resolve to negative tpos and are masked below
                spos = jnp.arange(W)
                tpos = last[:, None] - jnp.mod(last[:, None] - spos[None], W)
            else:
                tpos = jnp.broadcast_to(jnp.arange(W), (B, W))
            if quantized:
                out = kv_attention_decode(
                    qh, k_all, kf_all, v_all, vf_all, qpos, tpos,
                    window=cfg.window, n_kv=KV, probs_f=probs_f)
            else:
                out = _decode_attention(qh, _cache_load(k_all),
                                        _cache_load(v_all), qpos, cfg,
                                        probs_f, mode, tpos=tpos)
            kv_len = W
        else:
            out = _chunked_attention(qh, kh, vh, positions, cfg, probs_f, mode)
            kv_len = S
        # analytic ~EBOPs for the dynamic matmuls (per-tensor bits)
        if qt.bits is not None and probs_f is not None:
            n_qk = float(B * H * S) * float(kv_len) * hd
            b_p = jax.nn.relu(1.0 + p["probs_f"])  # p~ in [0, 1] => i' = 1
            aux.add(ebops=jnp.max(qt.bits) * jnp.max(kt.bits) * n_qk
                    + b_p * jnp.max(vt.bits) * n_qk)
            aux.add(l1=jax.nn.relu(p["probs_f"]))
        o = constrain(out.reshape(B, S, H * hd), "b.m")
        if p.get("attnout_f") is not None:
            oq, st = apply_act_q(o, p["attnout_f"], q.get("attnout"), mode, aux)
            newq["attnout"] = st
        else:
            oq = QTensor(o, None)
        yo, newq["wo"] = HDense.apply(p["wo"], q["wo"], oq, mode=mode, aux=aux)
        return yo, newq, new_cache


def _quant_probs(pt: jax.Array, probs_f, mode: str) -> jax.Array:
    if probs_f is None:
        return pt
    fn = quantize if mode == hgq.TRAIN else quantize_inference
    return fn(pt, probs_f)


def _group_heads(qh, KV):
    """[B, S, H, hd] -> [B, KV, G, S, hd]."""
    B, S, H, hd = qh.shape
    G = H // KV
    return qh.reshape(B, S, KV, G, hd).transpose(0, 2, 3, 1, 4)


def _chunked_attention(qh, kh, vh, positions, cfg: AttnConfig, probs_f,
                       mode) -> jax.Array:
    """Online-softmax attention, scanned over query and key chunks.

    TP strategy (EXPERIMENTS.md SSPerf, iteration log):
    * H %% TP == 0: repeat k/v to full heads (the standard TPU prefill
      trick — GQA bandwidth savings matter at decode, not prefill) and
      shard the head axis.  Without the repeat, GSPMD composite-shards
      (KV, G) and any other constraint forces a full reshard per layer
      (observed: 343 GB/layer "involuntary full rematerialization").
    * otherwise (e.g. qwen2: H=14): sequence-parallel — shard the q-chunk
      axis; q rows are independent so the score/AV matmuls split with no
      extra collectives (k/v chunks replicated across `model`).
    """
    B, S, H, hd = qh.shape
    msize = get_model_size()
    head_tp = msize > 1 and H % msize == 0
    if head_tp:
        G = H // cfg.n_kv
        kh = jnp.repeat(kh, G, axis=2)              # [B, S, H, hd]
        vh = jnp.repeat(vh, G, axis=2)
        KV, G = H, 1
    else:
        KV = cfg.n_kv
        G = H // KV
    scale = hd ** -0.5
    cq = min(cfg.q_chunk, S)
    ck = min(cfg.k_chunk, S)
    nq, nk = -(-S // cq), -(-S // ck)
    pad_q, pad_k = nq * cq - S, nk * ck - S
    qg = _group_heads(qh, KV)                       # [B, KV, G, S, hd]
    kg = kh.transpose(0, 2, 1, 3)                   # [B, KV, S, hd]
    vg = vh.transpose(0, 2, 1, 3)
    if pad_q:
        qg = jnp.pad(qg, ((0, 0),) * 3 + ((0, pad_q), (0, 0)))
    if pad_k:
        kg = jnp.pad(kg, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vg = jnp.pad(vg, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    q_pat = ".bm..." if head_tp else ".b..m."
    kv_pat = ".bm.." if head_tp else ".b..."
    qs = constrain(
        qg.reshape(B, KV, G, nq, cq, hd).transpose(3, 0, 1, 2, 4, 5), q_pat)
    ks = constrain(kg.reshape(B, KV, nk, ck, hd).transpose(2, 0, 1, 3, 4),
                   kv_pat)
    vs = constrain(vg.reshape(B, KV, nk, ck, hd).transpose(2, 0, 1, 3, 4),
                   kv_pat)
    kpos_all = jnp.arange(nk * ck)

    def q_step(_, qi):
        qi_idx, qc = qi                                  # qc [B,KV,G,cq,hd]
        qpos = qi_idx * cq + jnp.arange(cq)

        @jax.checkpoint
        def k_step(carry, ki):
            m, l, o = carry
            ki_idx, kc, vc = ki
            kpos = ki_idx * ck + jnp.arange(ck)
            s = constrain(
                jnp.einsum("bkgqh,bkch->bkgqc", qc, kc,
                           preferred_element_type=jnp.float32), "b..m.") \
                * scale
            mask = jnp.ones((cq, ck), bool)
            if cfg.causal:
                mask &= qpos[:, None] >= kpos[None, :]
            if cfg.window is not None:
                mask &= (qpos[:, None] - kpos[None, :]) < cfg.window
            mask &= (kpos < S)[None, :]  # mask key padding
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            pt = jnp.exp(s - m_new[..., None])
            pt = jnp.where(mask, pt, 0.0)
            pt = _quant_probs(pt, probs_f, mode)
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(pt, axis=-1)
            o_new = o * corr[..., None] + jnp.einsum(
                "bkgqc,bkch->bkgqh", pt, vc,
                preferred_element_type=jnp.float32)
            # keep the online-softmax carries sharded like q: an unsharded
            # carry would make XLA all-gather the sharded probs on EVERY
            # inner step (observed: 44 TB of all-gathers at qwen110 prefill)
            c_pat = "bm.." if head_tp else "b..m"
            return (constrain(m_new, c_pat), constrain(l_new, c_pat),
                    constrain(o_new, c_pat + ".")), None

        c_pat = "bm.." if head_tp else "b..m"
        m0 = constrain(jnp.full((B, KV, G, cq), NEG_INF, jnp.float32), c_pat)
        l0 = constrain(jnp.zeros((B, KV, G, cq), jnp.float32), c_pat)
        o0 = constrain(jnp.zeros((B, KV, G, cq, hd), jnp.float32),
                       c_pat + ".")
        (m, l, o), _ = jax.lax.scan(
            k_step, (m0, l0, o0), (jnp.arange(nk), ks, vs))
        o = o / jnp.maximum(l, 1e-20)[..., None]
        # cast BEFORE the chunk->token layout transition: the boundary
        # reshard otherwise moves fp32
        return None, constrain(o.astype(qh.dtype), c_pat + ".")

    # remat both scan levels: the backward pass recomputes the score chunks
    # (flash-attention backward) instead of storing [nq, nk, cq, ck] score
    # tensors — without this, autodiff materializes full S x S scores.
    q_step = jax.checkpoint(q_step,
                            policy=jax.checkpoint_policies.nothing_saveable)
    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), qs))
    # outs: [nq, B, KV, G, cq, hd] -> [B, S, H, hd]
    out = outs.transpose(1, 2, 3, 0, 4, 5).reshape(B, KV * G, nq * cq, hd)
    out = out[:, :, :S].transpose(0, 2, 1, 3)
    return out.astype(qh.dtype)


def _decode_attention(qh, k_all, v_all, qpos, cfg: AttnConfig, probs_f,
                      mode, tpos=None) -> jax.Array:
    """Chunk (S small) attention over the full cache, per-row positions.

    ``qpos`` [B, S]: global position of each new query row; ``tpos`` [B, T]:
    global position currently held by each cache slot (negative = empty).
    """
    B, S, H, hd = qh.shape
    KV = cfg.n_kv
    G = H // KV
    scale = hd ** -0.5
    qg = qh.reshape(B, S, KV, G, hd)
    s = constrain(jnp.einsum("bskgh,btkh->bkgst", qg, k_all,
                             preferred_element_type=jnp.float32),
                  "b...m") * scale
    if tpos is None:
        tpos = jnp.broadcast_to(jnp.arange(k_all.shape[1]),
                                (B, k_all.shape[1]))
    mask = (tpos[:, None, :] <= qpos[:, :, None]) & (tpos[:, None, :] >= 0)
    if cfg.window is not None:
        mask &= (qpos[:, :, None] - tpos[:, None, :]) < cfg.window
    mask = mask[:, None, None]                    # [B, 1, 1, S, T]
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    pt = jnp.exp(s - m)
    pt = jnp.where(mask, pt, 0.0)
    pt = _quant_probs(pt, probs_f, mode)
    l = jnp.sum(pt, axis=-1, keepdims=True)
    o = jnp.einsum("bkgst,btkh->bskgh", pt / jnp.maximum(l, 1e-20), v_all,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, S, H, hd).astype(qh.dtype)
