"""Quantized MLP blocks: GLU (llama-style) and plain two-layer."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..core.hgq import Aux, QTensor
from ..dist.axes import constrain
from .basic import HDense
from .common import HGQConfig


class GLUMLP:
    """gate/up/down with silu (SwiGLU) — llama/qwen/deepseek/pixtral/moe-expert."""

    @staticmethod
    def init(key, d: int, d_ff: int, qcfg: HGQConfig, *, act: str = "silu",
             dtype=jnp.float32):
        del act
        k1, k2, k3 = jax.random.split(key, 3)
        p: Dict[str, Any] = {}
        q: Dict[str, Any] = {}
        p["gate"], q["gate"] = HDense.init(k1, d, d_ff, qcfg, bias=False,
                                           dtype=dtype)
        p["up"], q["up"] = HDense.init(k2, d, d_ff, qcfg, bias=False,
                                       dtype=dtype)
        p["down"], q["down"] = HDense.init(k3, d_ff, d, qcfg, bias=False,
                                           out_q=False, dtype=dtype)
        return p, q

    @staticmethod
    def apply(p, q, x: QTensor, *, mode: str, aux: Aux, act: str = "silu"
              ) -> Tuple[QTensor, Dict[str, Any]]:
        newq: Dict[str, Any] = {}
        g, newq["gate"] = HDense.apply(p["gate"], q["gate"], x, mode=mode,
                                       aux=aux, act=act)
        u, newq["up"] = HDense.apply(p["up"], q["up"], x, mode=mode, aux=aux)
        # product of two quantized values: bits add (fixed-point multiply)
        h = constrain(g.q * u.q, "b.m")
        bits = None
        if g.bits is not None and u.bits is not None:
            bits = g.bits + u.bits
        y, newq["down"] = HDense.apply(p["down"], q["down"], QTensor(h, bits),
                                       mode=mode, aux=aux)
        return y, newq


class MLP:
    """Plain act(x W1 + b) W2 + b (whisper / paper-task models)."""

    @staticmethod
    def init(key, d: int, d_ff: int, qcfg: HGQConfig, *, act: str = "gelu",
             bias: bool = True, dtype=jnp.float32):
        k1, k2 = jax.random.split(key)
        p: Dict[str, Any] = {}
        q: Dict[str, Any] = {}
        del act
        p["fc1"], q["fc1"] = HDense.init(k1, d, d_ff, qcfg, bias=bias,
                                         dtype=dtype)
        p["fc2"], q["fc2"] = HDense.init(k2, d_ff, d, qcfg, bias=bias,
                                         out_q=False, dtype=dtype)
        return p, q

    @staticmethod
    def apply(p, q, x: QTensor, *, mode: str, aux: Aux, act: str = "gelu"):
        newq: Dict[str, Any] = {}
        h, newq["fc1"] = HDense.apply(p["fc1"], q["fc1"], x, mode=mode,
                                      aux=aux, act=act)
        y, newq["fc2"] = HDense.apply(p["fc2"], q["fc2"], h, mode=mode, aux=aux)
        return y, newq
