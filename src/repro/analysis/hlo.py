"""Parsers over compiled HLO text: collectives, replica groups, aliases.

One shared parser for everything in the repo that inspects
``jitted.lower(...).compile().as_text()`` — the program linter
(``analysis.rules`` / ``tools/lint_programs.py``) and the HLO assertions
in ``tests/test_wire2d.py`` / ``tests/test_collectives.py`` /
``tests/test_api.py``, which previously each hand-rolled their own
regex line scans.

The unit of analysis is the :class:`Collective`: one cross-device HLO
instruction with its result dtype/shape and its concrete device
grouping.  Replica groups come in two textual forms and both are
materialized to explicit device-id lists:

* brace lists — ``replica_groups={{0,4},{1,5}}``;
* iota lists — ``replica_groups=[4,2]<=[2,4]T(1,0)``: reshape
  ``iota(prod)`` to the source dims, transpose by the permutation, then
  reshape to ``[n_groups, group_size]`` rows.

``crosses_data_axis`` classifies a grouping against the repo's row-major
``(data, model)`` meshes (``jax.make_mesh((D, M))`` assigns device id
``d * M + m`` — asserted in ``tests/test_analysis.py``).
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import List, Optional, Sequence, Tuple

# HLO ops that move data across devices.  "-start" covers the async
# forms ("-done" carries no shape/groups of its own and is not counted —
# one launch, one entry).
COLLECTIVE_OPS = ("all-reduce", "all-gather", "all-to-all",
                  "reduce-scatter", "collective-permute",
                  "collective-broadcast")

# Results smaller than this are treated as scalar-class traffic (loss /
# gnorm scalars, per-leaf amax grids, feature extremes) by the dtype-flow
# rules; a gradient-sized leaf is always far above it.
SCALAR_MAX = 256

# result is either `dtype[dims]{layout}` or a tuple `(dtype[..]{..}, ...)`
# (async pairs, multi-operand all-to-all): skip lazily to the op name
_COLLECTIVE_RE = re.compile(
    r"=\s+\(?(\w+)\[([\d,]*)\][^)]*?\)?\s+("
    + "|".join(COLLECTIVE_OPS) + r")(-start)?\(")
_BRACE_GROUPS_RE = re.compile(r"replica_groups=\{(\{[\d{},]*\})\}")
_IOTA_GROUPS_RE = re.compile(
    r"replica_groups=\[([\d,]+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")
_SOURCE_TARGET_RE = re.compile(r"source_target_pairs=\{([\d{},]*)\}")


def strip_metadata(hlo: str) -> str:
    """Strip source-location noise from compiled HLO text, for
    program-identity comparisons: ``metadata={...}`` blocks and every
    quoted string (op names embed auto-numbered trace paths that are not
    the program)."""
    hlo = re.sub(r"metadata=\{[^}]*\}", "", hlo)
    return re.sub(r'"[^"]*"', '""', hlo)


def _transpose_reshape_iota(dims: Sequence[int], reshape: Sequence[int],
                            perm: Optional[Sequence[int]]
                            ) -> List[List[int]]:
    """Materialize an iota replica-group list without numpy: iota over
    ``prod(reshape)``, laid out in ``reshape`` order, transposed by
    ``perm``, re-read as ``dims`` = [n_groups, group_size...]."""
    total = math.prod(reshape)
    ids = list(range(total))
    if perm:
        # strides of the source layout, then walk the transposed order
        strides = [0] * len(reshape)
        acc = 1
        for i in range(len(reshape) - 1, -1, -1):
            strides[i] = acc
            acc *= reshape[i]
        tdims = [reshape[p] for p in perm]
        tstrides = [strides[p] for p in perm]
        out = []
        idx = [0] * len(tdims)
        for _ in range(total):
            out.append(sum(i * s for i, s in zip(idx, tstrides)))
            for d in range(len(tdims) - 1, -1, -1):
                idx[d] += 1
                if idx[d] < tdims[d]:
                    break
                idx[d] = 0
        ids = out
    group_size = total // dims[0]
    return [ids[g * group_size:(g + 1) * group_size]
            for g in range(dims[0])]


def parse_replica_groups(line: str) -> Optional[List[List[int]]]:
    """Concrete device-id groups of one HLO line, or None when the line
    carries no grouping (callers decide whether that means "global")."""
    m = _BRACE_GROUPS_RE.search(line)
    if m:
        return [[int(x) for x in grp.split(",")]
                for grp in re.findall(r"\{([\d,]+)\}", m.group(1))]
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        dims = [int(x) for x in m.group(1).split(",")]
        reshape = [int(x) for x in m.group(2).split(",")]
        perm = ([int(x) for x in m.group(3).split(",")]
                if m.group(3) else None)
        return _transpose_reshape_iota(dims, reshape, perm)
    m = _SOURCE_TARGET_RE.search(line)
    if m:
        # collective-permute: each {src,dst} pair is a 2-device group
        return [[int(x) for x in pair.split(",")]
                for pair in re.findall(r"\{([\d,]+)\}", m.group(1))]
    return None


@dataclasses.dataclass(frozen=True)
class Collective:
    """One cross-device instruction in a compiled module."""
    kind: str                 # "all-reduce", "all-gather", ...
    dtype: str                # HLO dtype of the result ("f32", "s8", ...)
    dims: Tuple[int, ...]
    groups: Optional[Tuple[Tuple[int, ...], ...]]  # None = unknown/global
    line: str                 # the stripped source line (diagnostics)

    @property
    def numel(self) -> int:
        return math.prod(self.dims) if self.dims else 1

    def crosses_data_axis(self, model_size: int) -> bool:
        """Does this collective move bytes between data-axis rows of a
        row-major ``(data, model)`` mesh?  Unknown grouping counts as
        crossing — the conservative reading every rule wants."""
        if self.groups is None:
            return True
        return any(len({i // model_size for i in grp}) > 1
                   for grp in self.groups)


def parse_collectives(hlo: str) -> List[Collective]:
    """Every collective instruction of a compiled module, in program
    order.  Tuple-shaped results (async pairs, multi-operand all-to-all)
    report the first element's dtype/shape — one launch, one entry."""
    out = []
    for raw in hlo.splitlines():
        line = raw.strip()
        m = _COLLECTIVE_RE.search(line)
        if m is None:
            continue
        dims = tuple(int(x) for x in m.group(2).split(",") if x)
        groups = parse_replica_groups(line)
        out.append(Collective(
            kind=m.group(3), dtype=m.group(1), dims=dims,
            groups=None if groups is None else
            tuple(tuple(g) for g in groups),
            line=line[:200]))
    return out


def input_output_aliases(hlo: str) -> List[Tuple[int, int]]:
    """The compiled module's donation result: ``(output_index,
    parameter_index)`` pairs from the ``input_output_alias={...}`` header
    (empty list = nothing aliased, every donated buffer was dropped)."""
    start = hlo.find("input_output_alias={")
    if start < 0:
        return []
    # the map nests braces ({ {0}: (0, {}, may-alias), ... }): scan to
    # the matching close instead of regexing over nesting
    i = hlo.index("{", start)
    depth = 0
    for j in range(i, len(hlo)):
        if hlo[j] == "{":
            depth += 1
        elif hlo[j] == "}":
            depth -= 1
            if depth == 0:
                break
    body = hlo[i:j + 1]
    return [(int(o), int(p)) for o, p in
            re.findall(r"\{(\d+)\}:\s*\((\d+),", body)]
