"""Walkers over jaxprs: the *explicit* collectives a program asked for.

GSPMD inserts collectives of its own at compile time (FSDP weight
gathers, TP partial-sum reductions) — those live in the HLO and are
census'd by ``analysis.hlo``.  The jaxpr level sees only the exchanges
the repo's code wrote explicitly (the ``shard_map`` wire collective, the
scale pmax), each tagged with the logical *axis names* it runs over —
which is exactly the information the dtype-flow rules need: "does this
cross the data axis" is a name lookup here, not a device-id
reconstruction.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Iterator, List, Tuple

# primitives that exchange bytes between devices when bound inside
# shard_map / pmap.  psum2 is what shard_map rebinds psum to; axis_index
# and pvary are excluded: they read/adjust replication, nothing moves.
COLLECTIVE_PRIMITIVES = frozenset({
    "psum", "psum2", "pmax", "pmin", "ppermute", "pbroadcast",
    "all_gather", "all_to_all", "psum_scatter", "reduce_scatter",
})


def _subjaxprs(params: dict) -> Iterator[Any]:
    """Every jaxpr nested in an equation's params (call_jaxpr, branches,
    scan/while bodies, custom_vjp closures, shard_map bodies, ...)."""
    for v in params.values():
        vs = v if isinstance(v, (list, tuple)) else (v,)
        for x in vs:
            if hasattr(x, "eqns"):                   # Jaxpr
                yield x
            elif hasattr(x, "jaxpr") and hasattr(
                    getattr(x, "jaxpr", None), "eqns"):  # ClosedJaxpr
                yield x.jaxpr


def iter_eqns(jaxpr) -> Iterator[Any]:
    """All equations of ``jaxpr`` (a Jaxpr or ClosedJaxpr), recursively
    through every nested call/control-flow/shard_map body."""
    if hasattr(jaxpr, "jaxpr"):
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _subjaxprs(eqn.params):
            yield from iter_eqns(sub)


def _axis_names(params: dict) -> Tuple[str, ...]:
    """The logical mesh axes a collective equation runs over, whatever
    the primitive calls its parameter (``axes``, ``axis_name``)."""
    for key in ("axes", "axis_name"):
        if key in params:
            v = params[key]
            if isinstance(v, (list, tuple)):
                return tuple(str(a) for a in v)
            return (str(v),)
    return ()


@dataclasses.dataclass(frozen=True)
class ExplicitCollective:
    """One explicitly-written collective equation in a traced program."""
    primitive: str            # "psum", "all_to_all", ...
    axes: Tuple[str, ...]     # logical axis names it exchanges over
    dtype: str                # canonical dtype name ("float32", "int8")
    dims: Tuple[int, ...]     # result shape (first output)

    @property
    def numel(self) -> int:
        return math.prod(self.dims) if self.dims else 1

    def over(self, axis: str) -> bool:
        return axis in self.axes


def explicit_collectives(jaxpr) -> List[ExplicitCollective]:
    """Every collective primitive bound anywhere in ``jaxpr`` (a Jaxpr or
    ClosedJaxpr), in trace order."""
    out = []
    for eqn in iter_eqns(jaxpr):
        name = eqn.primitive.name
        if name not in COLLECTIVE_PRIMITIVES:
            continue
        aval = eqn.outvars[0].aval
        dtype = getattr(aval, "dtype", None)
        shape = tuple(getattr(aval, "shape", ()) or ())
        out.append(ExplicitCollective(
            # psum2 is jax-internal for psum-under-shard_map: report the
            # name the user wrote
            primitive="psum" if name == "psum2" else name,
            axes=_axis_names(eqn.params),
            dtype="" if dtype is None else str(dtype),
            dims=shape))
    return out
