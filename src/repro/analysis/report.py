"""Program reports: the collective census, serialized and baseline-gated.

``program_report`` turns one :class:`ProgramArtifacts` into a plain-JSON
dict; ``collect`` bundles every analyzed program of a repo checkout into
the report ``tools/lint_programs.py`` writes and CI diffs against the
committed golden (``benchmarks/baselines/PROGRAMS.json``).

The census is the load-bearing part: ``launches`` (explicit collectives
in the traced program — the ROADMAP's "3 serialized wire launches vs
fp32's 1" tail, now a number a PR must visibly move) and the HLO-level
per-kind/per-dtype counts (which include GSPMD-inserted traffic and so
catch a *new* f32 all-reduce appearing even when every hard rule still
passes).

``compare`` mirrors ``benchmarks/check_regression.py`` semantics —
direction-aware metrics, fnmatch overrides with last-match-wins, exit
codes 0/1/2 — but with a default tolerance of **zero**: program shapes
are deterministic counts, so any drift is a real change that either gets
fixed or deliberately re-baselined with ``--update``.
"""
from __future__ import annotations

import fnmatch
import json
from typing import Dict, List, Sequence, Tuple

from .hlo import SCALAR_MAX
from .program import ProgramArtifacts
from .rules import PROGRAM_RULES, run_rules

# metric-name suffix -> direction ("lower" is better / "higher" is
# better).  Counts of launches and collectives want to go down; aliased
# buffers (donations that actually landed) want to go up.
METRIC_DIRECTIONS = (
    ("aliased_buffers", "higher"),
    ("launches", "lower"),
    ("collectives.", "lower"),
    ("crossing.", "lower"),
)


def direction_for(name: str) -> str:
    for frag, direction in METRIC_DIRECTIONS:
        if frag in name:
            return direction
    return "lower"


def program_report(art: ProgramArtifacts) -> Dict:
    """One program's census + rule verdicts as a plain-JSON dict."""
    explicit: Dict[str, int] = {}
    for c in art.explicit_collectives():
        key = f"{c.primitive}[{','.join(c.axes)}]"
        explicit[key] = explicit.get(key, 0) + 1
    hlo_census: Dict[str, int] = {}
    crossing: Dict[str, int] = {}
    model = art.mesh_shape[1]
    for c in art.hlo_collectives():
        key = f"{c.kind}.{c.dtype}"
        hlo_census[key] = hlo_census.get(key, 0) + 1
        if c.numel >= SCALAR_MAX and c.crosses_data_axis(model):
            crossing[key] = crossing.get(key, 0) + 1
    return {
        "kind": art.kind,
        "spec": art.spec_path,
        "mesh": list(art.mesh_shape),
        "launches": sum(explicit.values()),
        "explicit": dict(sorted(explicit.items())),
        "collectives": dict(sorted(hlo_census.items())),
        "crossing": dict(sorted(crossing.items())),
        "aliased_buffers": art.aliased_buffers(),
        "violations": [str(v) for v in run_rules(art, PROGRAM_RULES)],
    }


def collect(arts: Sequence[ProgramArtifacts]) -> Dict:
    return {"report": "programs",
            "programs": {a.name: program_report(a) for a in arts}}


def dumps(report: Dict) -> str:
    return json.dumps(report, indent=2, sort_keys=True) + "\n"


def extract_metrics(report: Dict) -> Dict[str, float]:
    """Flatten a collected report to gateable ``name -> value`` pairs.
    Violations are deliberately NOT metrics: they fail the run outright
    regardless of what any baseline says."""
    out: Dict[str, float] = {}
    for prog, rep in sorted(report.get("programs", {}).items()):
        out[f"{prog}.launches"] = float(rep.get("launches", 0))
        out[f"{prog}.aliased_buffers"] = float(rep.get("aliased_buffers", 0))
        for k, v in sorted(rep.get("explicit", {}).items()):
            out[f"{prog}.explicit.{k}"] = float(v)
        for k, v in sorted(rep.get("collectives", {}).items()):
            out[f"{prog}.collectives.{k}"] = float(v)
        for k, v in sorted(rep.get("crossing", {}).items()):
            out[f"{prog}.crossing.{k}"] = float(v)
    return out


def tolerance_for(name: str,
                  overrides: Sequence[Tuple[str, float]]) -> float:
    """Relative slack for one metric: default 0 (exact counts), widened
    by ``--override 'PATTERN=TOL'`` entries — fnmatch patterns, last
    match wins, same contract as check_regression.py."""
    tol = 0.0
    for pattern, value in overrides:
        if fnmatch.fnmatch(name, pattern):
            tol = value
    return tol


def compare(baseline: Dict, fresh: Dict,
            overrides: Sequence[Tuple[str, float]] = ()
            ) -> Tuple[List[str], List[str]]:
    """(failures, notes) of fresh vs baseline metrics.  A metric moving
    in its bad direction past tolerance is a failure; moving in its good
    direction, or appearing/disappearing, is a note (re-baseline with
    --update when intentional)."""
    base = extract_metrics(baseline)
    new = extract_metrics(fresh)
    failures, notes = [], []
    for name in sorted(set(base) | set(new)):
        if name not in new:
            notes.append(f"{name}: in baseline only "
                         f"(baseline={base[name]:g}) — gone from report")
            continue
        if name not in base:
            notes.append(f"{name}: new metric (value={new[name]:g}) — "
                         f"not in baseline, re-baseline to gate it")
            continue
        b, f = base[name], new[name]
        if b == f:
            continue
        tol = tolerance_for(name, overrides)
        worse = f > b if direction_for(name) == "lower" else f < b
        limit = abs(b) * tol
        if worse and abs(f - b) > limit:
            failures.append(
                f"{name}: {b:g} -> {f:g} "
                f"({'+' if f > b else ''}{f - b:g}, tol {tol:g})")
        else:
            notes.append(f"{name}: {b:g} -> {f:g} (ok)")
    return failures, notes
