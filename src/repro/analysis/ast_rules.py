"""Source-level AST rules over ``src/repro`` — the registry behind
``tools/check_no_globals.py``.

Same shape as the program rules: a :class:`SourceRule` is (name, doc,
check); ``SOURCE_RULES`` is the immutable registry; ``check_source``
walks a tree and runs every rule.  Rules:

* ``no-global`` — any ``global`` statement: mutating module state from
  a function is the pattern that made jitted programs depend on ambient
  configuration (use ``repro.dist.scope.Scoped``).
* ``module-mutable`` — module-level bindings of mutable container
  literals (``= []`` / ``= {}`` / ``= dict()`` ...), including through
  tuple-unpack targets (``a, b = [], {}``) and starred targets
  (``a, *rest = ...`` — a starred target *always* binds a fresh list).
* ``inexact-bit-arith`` — traced ``jnp.exp2`` / ``jnp.log2`` /
  ``power`` calls inside the bit-exact modules (quantizer grids, wire
  packing, fixed-point wrap): XLA's transcendental approximations are an
  ulp off at e.g. ``2^13``, which silently shifts the quantization grid
  (the PR-1 bug class).  Use the frexp/ldexp-exact helpers
  (``core.quantizer._exp2i`` / ``floor_log2``).  Python-level
  ``2.0 ** k`` is exact and allowed.
* ``fixed-prngkey`` — literal ``PRNGKey(0)`` in library code: the
  all-zeros threefry key silently correlates streams that were meant to
  be independent; thread a key in, or take a seed argument.
* ``deprecated-shim-call`` — calls to the removed-next-release
  ``set_axes`` / ``set_compute_dtype`` / ``set_packed_matmul`` shims:
  library code must use the RunSpec surface.

Suppression: a ``# lint: allow(<rule>)`` comment on the offending line,
or an allowlist entry — ``path::name`` (one binding) or ``path::*``
(whole file, any rule), paths relative to the repo root.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from typing import Callable, FrozenSet, List, Tuple

MUTABLE_CALLS = frozenset({"dict", "list", "set", "defaultdict",
                           "OrderedDict", "deque", "Counter"})

# modules whose arithmetic must stay bit-exact: quantization grids, wire
# packing, fixed-point wrap/overflow.  Relative-path prefixes.
BIT_EXACT_PREFIXES = (
    "src/repro/core/quantizer.py",
    "src/repro/core/fixedpoint.py",
    "src/repro/core/calibrate.py",
    "src/repro/core/plan.py",
    "src/repro/kernels/",
)

INEXACT_CALLS = frozenset({"exp2", "log2", "power", "pow"})

DEPRECATED_SHIMS = frozenset({"set_axes", "set_compute_dtype",
                              "set_packed_matmul"})

_ALLOW_RE = re.compile(r"#\s*lint:\s*allow\(([\w-]+)\)")


@dataclasses.dataclass(frozen=True)
class SourceFile:
    """One parsed module plus everything a rule needs to judge it."""
    rel: str                    # path relative to the repo root
    tree: ast.Module
    lines: Tuple[str, ...]      # source lines, for pragma lookups
    allow: FrozenSet[str]       # path::name / path::* allowlist

    def allowed(self, rule: str, lineno: int, name: str = "") -> bool:
        if f"{self.rel}::*" in self.allow:
            return True
        if name and f"{self.rel}::{name}" in self.allow:
            return True
        if 1 <= lineno <= len(self.lines):
            m = _ALLOW_RE.search(self.lines[lineno - 1])
            if m and m.group(1) == rule:
                return True
        return False


@dataclasses.dataclass(frozen=True)
class SourceRule:
    name: str
    doc: str
    check: Callable[[SourceFile], List[str]]


def _call_name(node: ast.Call) -> str:
    fn = node.func
    if isinstance(fn, ast.Name):
        return fn.id
    if isinstance(fn, ast.Attribute):
        return fn.attr
    return ""


def _fail(src: SourceFile, rule: str, lineno: int, msg: str,
          name: str = "") -> List[str]:
    if src.allowed(rule, lineno, name):
        return []
    return [f"{src.rel}:{lineno}: [{rule}] {msg}"]


# -- no-global -----------------------------------------------------------

def _check_no_global(src: SourceFile) -> List[str]:
    out = []
    for node in ast.walk(src.tree):
        if isinstance(node, ast.Global):
            out += _fail(
                src, "no-global", node.lineno,
                f"`global {', '.join(node.names)}` — module-level mutable "
                f"trace-time state; use repro.dist.scope.Scoped")
    return out


# -- module-mutable ------------------------------------------------------

def _is_mutable_literal(node: ast.AST) -> bool:
    if isinstance(node, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                         ast.DictComp, ast.SetComp)):
        return True
    if isinstance(node, ast.Call):
        return _call_name(node) in MUTABLE_CALLS
    return False


def _mutable_bindings(target: ast.AST, value: ast.AST
                      ) -> List[Tuple[str, bool]]:
    """``(name, is_starred)`` pairs bound to a mutable value by one
    (possibly nested tuple-unpack) assignment target.  A starred target
    binds a fresh list regardless of the value's type."""
    if isinstance(target, ast.Starred):
        inner = _mutable_bindings(target.value, value)
        return [(n, True) for n, _ in inner] or (
            [(ast.unparse(target.value), True)])
    if isinstance(target, ast.Name):
        return [(target.id, False)] if _is_mutable_literal(value) else []
    if isinstance(target, (ast.Tuple, ast.List)):
        elts = target.elts
        # element-wise when the value is a matching literal tuple/list
        # (`a, b = [], 3` flags only a); otherwise judge the whole value
        # against every name (`a, b = make_pair()` with a mutable call)
        if isinstance(value, (ast.Tuple, ast.List)) \
                and len(value.elts) == len([e for e in elts
                                            if not isinstance(e, ast.Starred)]):
            vals = list(value.elts)
            out = []
            vi = 0
            for e in elts:
                if isinstance(e, ast.Starred):
                    out += _mutable_bindings(e, value)
                else:
                    out += _mutable_bindings(e, vals[vi])
                    vi += 1
            return out
        return [b for e in elts for b in _mutable_bindings(e, value)]
    return []


def _check_module_mutable(src: SourceFile) -> List[str]:
    out = []
    for node in src.tree.body:
        if not isinstance(node, (ast.Assign, ast.AnnAssign)):
            continue
        value = node.value
        if value is None:
            continue
        targets = (node.targets if isinstance(node, ast.Assign)
                   else [node.target])
        for t in targets:
            for name, starred in _mutable_bindings(t, value):
                if name.startswith("__") and name.endswith("__"):
                    continue   # dunder module attrs (__all__) are constants
                why = ("a starred target always binds a fresh list"
                       if starred else
                       "bind it in a class or a Scoped default")
                out += _fail(
                    src, "module-mutable", node.lineno,
                    f"module-level mutable binding `{name}` — {why}",
                    name=name)
    return out


# -- inexact-bit-arith ---------------------------------------------------

def _check_inexact_bit_arith(src: SourceFile) -> List[str]:
    if not any(src.rel.startswith(p) for p in BIT_EXACT_PREFIXES):
        return []
    out = []
    for node in ast.walk(src.tree):
        if not isinstance(node, ast.Call):
            continue
        name = _call_name(node)
        if name not in INEXACT_CALLS:
            continue
        # only traced math (attribute calls like jnp.exp2 / lax.pow);
        # plain `pow(2, k)` / `2.0 ** k` run in Python and are exact
        if not isinstance(node.func, ast.Attribute):
            continue
        out += _fail(
            src, "inexact-bit-arith", node.lineno,
            f"`{ast.unparse(node.func)}` in a bit-exact module — XLA's "
            f"{name} is an ulp off at e.g. 2^13 and shifts the "
            f"quantization grid; use core.quantizer._exp2i / floor_log2 "
            f"(frexp/ldexp-exact)")
    return out


# -- fixed-prngkey -------------------------------------------------------

def _check_fixed_prngkey(src: SourceFile) -> List[str]:
    out = []
    for node in ast.walk(src.tree):
        if not isinstance(node, ast.Call) or _call_name(node) != "PRNGKey":
            continue
        if node.args and isinstance(node.args[0], ast.Constant) \
                and node.args[0].value == 0:
            out += _fail(
                src, "fixed-prngkey", node.lineno,
                "hardcoded PRNGKey(0) — the all-zeros key correlates "
                "streams meant to be independent; thread a key or seed "
                "argument through instead")
    return out


# -- deprecated-shim-call ------------------------------------------------

def _check_deprecated_shims(src: SourceFile) -> List[str]:
    out = []
    for node in ast.walk(src.tree):
        if isinstance(node, ast.Call) and _call_name(node) in DEPRECATED_SHIMS:
            out += _fail(
                src, "deprecated-shim-call", node.lineno,
                f"call to deprecated `{_call_name(node)}` — library code "
                f"must configure through repro.api.RunSpec, not the "
                f"one-release compatibility shims")
    return out


SOURCE_RULES: Tuple[SourceRule, ...] = (
    SourceRule("no-global",
               "no `global` statements anywhere in src/repro",
               _check_no_global),
    SourceRule("module-mutable",
               "no module-level mutable-container bindings (incl. "
               "tuple-unpack and starred targets)",
               _check_module_mutable),
    SourceRule("inexact-bit-arith",
               "no jnp.exp2/log2/pow in bit-exact modules — "
               "frexp/ldexp-exact helpers only",
               _check_inexact_bit_arith),
    SourceRule("fixed-prngkey",
               "no hardcoded PRNGKey(0) in library code",
               _check_fixed_prngkey),
    SourceRule("deprecated-shim-call",
               "no calls to the deprecated set_* shims",
               _check_deprecated_shims),
)


def check_source(rel: str, text: str, allow: FrozenSet[str] = frozenset(),
                 rules: Tuple[SourceRule, ...] = SOURCE_RULES
                 ) -> List[str]:
    """All rule findings for one module's source text.  ``rel`` is the
    repo-root-relative path used in messages and allowlist keys."""
    src = SourceFile(rel=rel, tree=ast.parse(text, filename=rel),
                     lines=tuple(text.splitlines()), allow=allow)
    out = []
    for rule in rules:
        out.extend(rule.check(src))
    return out
