"""Declarative invariant rules over compiled program artifacts.

Each :class:`Rule` is (name, doc, applies?, check) — ``check`` returns
:class:`Violation`\\ s, an empty list means the invariant holds.  The
registry ``PROGRAM_RULES`` is an immutable tuple so the module passes
the repo's own no-module-level-mutable-state gate without allowlisting.

The dtype-flow invariants run over the *explicit* collectives of the
traced jaxpr (``analysis.jaxpr``): those are exactly the exchanges the
repo wrote — the wire all_to_all/all_gather, the scale pmax — with
logical axis names attached.  The compiled HLO additionally contains
GSPMD-inserted collectives (FSDP weight gathers, TP partial-sum
reductions); those are legitimate f32 traffic and are gated by the
census baselines in ``analysis.report``, not by hard rules here.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Tuple

from .hlo import SCALAR_MAX
from .program import ProgramArtifacts

def _wire_payload(kind: str) -> Tuple[str, ...]:
    """jaxpr-level dtype names a wire payload may travel as: s8 grads /
    nibble-packed u8 pairs for the int8 kinds, bf16 for the bf16 wire."""
    return ("bfloat16",) if kind == "bf16" else ("int8", "uint8")


@dataclasses.dataclass(frozen=True)
class Violation:
    rule: str
    program: str
    message: str

    def __str__(self) -> str:
        return f"{self.program}: [{self.rule}] {self.message}"


@dataclasses.dataclass(frozen=True)
class Rule:
    name: str
    doc: str
    applies: Callable[[ProgramArtifacts], bool]
    check: Callable[[ProgramArtifacts], List[Violation]]

    def run(self, art: ProgramArtifacts) -> List[Violation]:
        return self.check(art) if self.applies(art) else []


def _is_wire_train(art: ProgramArtifacts) -> bool:
    return (art.kind == "train" and bool(art.meta.get("wire"))
            and art.mesh_shape[0] * art.mesh_shape[1] > 1)


# -- dtype-flow ----------------------------------------------------------

def _check_wire_dtypes(art: ProgramArtifacts) -> List[Violation]:
    """Every explicit collective moving more than scalar-class traffic
    must carry the plan's wire payload dtype — a gradient-sized f32
    exchange is exactly the silent-upcast leak HGQ exists to prevent.
    Scalar-class traffic (the amax pmax, loss/gnorm scalars) may stay
    f32: it is O(layers), not O(params)."""
    allowed = _wire_payload(art.meta.get("wire_payload", "int8"))
    out = []
    for c in art.explicit_collectives():
        if c.numel < SCALAR_MAX:
            continue
        if c.dtype not in allowed:
            out.append(Violation(
                "wire-dtype", art.name,
                f"{c.primitive} over {c.axes} moves {c.dtype}"
                f"{list(c.dims)} ({c.numel} elems) — wire payload must "
                f"be one of {allowed}; scalar-class f32 is only allowed "
                f"under {SCALAR_MAX} elems"))
    return out


def _check_wire_present(art: ProgramArtifacts) -> List[Violation]:
    """A wire-compressed step with no explicit payload collective over
    the data axis means the exchange silently fell back to the dense
    GSPMD path — the compression did nothing."""
    allowed = _wire_payload(art.meta.get("wire_payload", "int8"))
    if art.mesh_shape[0] == 1:
        return []          # no data axis to exchange over
    for c in art.explicit_collectives():
        if c.over("data") and c.dtype in allowed and c.numel >= SCALAR_MAX:
            return []
    return [Violation(
        "wire-present", art.name,
        f"no explicit {allowed} collective over the data axis — the "
        f"compressed wire exchange is missing from the traced program")]


def _check_no_f64(art: ProgramArtifacts) -> List[Violation]:
    """f64 anywhere in a compiled module means x64 leaked in — every
    HGQ width fits in f32/bf16/intN and doubles would silently halve
    matmul throughput."""
    if " f64[" in art.hlo or "=f64[" in art.hlo or "(f64[" in art.hlo:
        return [Violation("no-f64", art.name,
                          "compiled module contains f64 values")]
    return []


# -- donation / aliasing -------------------------------------------------

def _check_donation(art: ProgramArtifacts) -> List[Violation]:
    """Donated buffers (params, optimizer mu/nu, EF residual) must come
    back as input-output aliases in the compiled module; a dropped
    donation doubles peak memory for that tree silently."""
    want = art.meta.get("donated_leaves", 0)
    got = art.aliased_buffers()
    if got < want:
        return [Violation(
            "donation", art.name,
            f"compiled module aliases {got} buffers, but at least {want} "
            f"donated leaves (params + opt.mu/nu + EF residual) must "
            f"round-trip in place")]
    return []


def _check_decode_donation(art: ProgramArtifacts) -> List[Violation]:
    """The decode tick donates its KV/state cache tree; zero aliases
    means every token copies the full cache."""
    if art.aliased_buffers() == 0:
        return [Violation(
            "decode-donation", art.name,
            "decode step has no input-output aliases — the donated "
            "KV/state cache is being copied every token")]
    return []


# -- packed serving ------------------------------------------------------

def _entry_params(hlo: str):
    """``[(dtype, numel), ...]`` of the compiled module's entry
    parameters, from the ``entry_computation_layout`` header — the
    ground truth for what the program stores vs rematerializes.  None
    when the header cannot be located."""
    import re
    header = hlo.split("\n\n", 1)[0]
    m = re.search(r"entry_computation_layout=\{\((.*?)\)->", header)
    if not m:
        return None
    out = []
    for dtype, dims in re.findall(r"(\w+)\[([\d,]*)\]", m.group(1)):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out.append((dtype, n))
    return out


def _check_packed_weights(art: ProgramArtifacts) -> List[Violation]:
    """A packed-serving program must actually take its weights as
    integer parameters: f32 parameter bytes at or above the unpacked
    tree size mean the pack was dropped before compilation."""
    params = _entry_params(art.hlo)
    if params is None:
        return [Violation("packed-weights", art.name,
                          "could not locate entry_computation_layout")]
    int_bytes = f32_bytes = 0
    for dtype, n in params:
        if dtype in ("s8", "u8", "s4", "u4"):
            int_bytes += n
        elif dtype == "f32":
            f32_bytes += 4 * n
    out = []
    if int_bytes == 0:
        out.append(Violation(
            "packed-weights", art.name,
            "packed_serving spec but the decode program has no integer "
            "weight parameters"))
    unpacked = art.meta.get("unpacked_param_bytes", 0)
    if unpacked and f32_bytes >= unpacked:
        out.append(Violation(
            "packed-weights", art.name,
            f"f32 parameter bytes ({f32_bytes}) >= unpacked tree size "
            f"({unpacked}) — weights are not being served packed"))
    return out


# -- quantized KV cache --------------------------------------------------

def _check_quantized_kv(art: ProgramArtifacts) -> List[Violation]:
    """A quantized-KV decode program must store its ring buffer at the
    plan's KV byte widths: the int8 mantissa/exponent buffers account
    for the cache bytes the engine allocated, and no cache-class
    (>= SCALAR_MAX elems) bf16 parameter exists — a bf16 entry buffer
    here is exactly the hidden fp spill the spec was meant to remove
    (weights stay f32/s8; only the fp cache was ever bf16)."""
    params = _entry_params(art.hlo)
    if params is None:
        return [Violation("quantized-kv", art.name,
                          "could not locate entry_computation_layout")]
    want = art.meta.get("kv_cache_int_bytes", 0)
    int_bytes = sum(n for dtype, n in params
                    if dtype in ("s8", "u8", "s4", "u4"))
    out = []
    if int_bytes < want:
        out.append(Violation(
            "quantized-kv", art.name,
            f"integer entry-parameter bytes ({int_bytes}) < the engine's "
            f"quantized cache allocation ({want}) — the KV ring buffer "
            f"is not stored at plan widths"))
    spilled = [n for dtype, n in params
               if dtype == "bf16" and n >= SCALAR_MAX]
    if spilled:
        out.append(Violation(
            "quantized-kv", art.name,
            f"bf16 entry parameters of cache class remain "
            f"({sorted(spilled, reverse=True)[:4]} elems) — the fp KV "
            f"cache is spilling alongside the quantized one"))
    return out


PROGRAM_RULES: Tuple[Rule, ...] = (
    Rule("wire-dtype",
         "explicit collectives >= SCALAR_MAX elems carry the wire "
         "payload dtype (s8 / nibble-packed u8, or bf16), never f32",
         _is_wire_train, _check_wire_dtypes),
    Rule("wire-present",
         "a wire-compressed step has an explicit payload collective "
         "over the data axis (no silent dense fallback)",
         _is_wire_train, _check_wire_present),
    Rule("no-f64",
         "no f64 values anywhere in a compiled module",
         lambda art: True, _check_no_f64),
    Rule("donation",
         "donated train buffers (params, opt.mu/nu, EF residual) are "
         "input-output aliased in the compiled module",
         lambda art: art.kind == "train", _check_donation),
    Rule("decode-donation",
         "the decode tick's donated cache tree is aliased in place",
         lambda art: art.kind == "decode", _check_decode_donation),
    Rule("packed-weights",
         "packed-serving programs take integer weight parameters and "
         "never rematerialize the f32 tree",
         lambda art: art.kind == "decode" and art.meta.get("packed"),
         _check_packed_weights),
    Rule("quantized-kv",
         "quantized-KV decode programs store the ring buffer at plan "
         "KV byte widths with no cache-class bf16 spill",
         lambda art: art.kind == "decode" and art.meta.get("kv_bits"),
         _check_quantized_kv),
)


def run_rules(art: ProgramArtifacts,
              rules: Tuple[Rule, ...] = PROGRAM_RULES) -> List[Violation]:
    out = []
    for rule in rules:
        out.extend(rule.run(art))
    return out
