"""Spec -> compiled program artifacts: the linter's unit of analysis.

For each ``RunSpec`` the repo ships (``examples/specs/*.json``) two
programs matter:

* the **train step** — ``repro.api.build(spec).init_training()``'s
  jitted function, exactly as the launcher runs it (shardings, donation,
  wire collective and all); and
* the **serving decode step** — the ``serving.Engine``'s ragged decode
  tick, built on a 1x1 mesh with the spec's packing flags.

``artifacts_for_spec`` traces both (where the mesh fits the host) and
captures the jaxpr plus the compiled HLO text; the declarative rules in
``analysis.rules`` and the census in ``analysis.report`` run over these
:class:`ProgramArtifacts` — never over re-derived, subtly-different
lowerings.  ``tests/test_api.py`` shares :func:`train_traced` /
:func:`train_step_hlo` for its HLO-identity assertions, so the program
the tests pin and the program the linter gates are the same object.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..api import RunSpec, build
from .hlo import input_output_aliases, parse_collectives
from .jaxpr import explicit_collectives


@dataclasses.dataclass
class ProgramArtifacts:
    """One compiled program plus everything the rules need to judge it."""
    # "train:host_1x1", "decode:serving_packed" — colon, not brackets:
    # these names feed fnmatch override patterns, where [..] is a class
    name: str
    kind: str                     # "train" | "decode"
    spec: RunSpec
    spec_path: str                # "" when built from an in-memory spec
    mesh_shape: Tuple[int, int]   # (data, model)
    jaxpr: Any                    # ClosedJaxpr of the traced program
    hlo: str                      # compiled StableHLO/HLO text
    meta: Dict[str, Any]          # kind-specific facts (see builders)

    # cached derived views -------------------------------------------------
    def explicit_collectives(self):
        return explicit_collectives(self.jaxpr)

    def hlo_collectives(self):
        return parse_collectives(self.hlo)

    def aliased_buffers(self) -> int:
        return len(input_output_aliases(self.hlo))


def _spec_name(spec_path: str, spec: RunSpec) -> str:
    import os
    if spec_path:
        return os.path.splitext(os.path.basename(spec_path))[0]
    return f"{spec.arch}_{spec.mesh.data}x{spec.mesh.model}"


def train_traced(spec: RunSpec):
    """(ctx, setup, traced) for the spec's jitted train step — the one
    ``init_training`` builds, traced on its own representative args."""
    ctx = build(spec)
    setup = ctx.init_training()
    with ctx.mesh:
        args = [setup.params, setup.qstate, setup.opt,
                setup.pipeline(0), jnp.int32(0)]
        if setup.ef_state is not None:
            args.append(setup.ef_state)
        traced = setup.jitted.trace(*args)
    return ctx, setup, traced


def train_step_hlo(spec_or_argv) -> str:
    """Compiled HLO text of the spec-built train step.  Accepts a
    ``RunSpec`` or a CLI argv list (``["--mesh", "2x4", ...]``) — the
    helper ``tests/test_api.py`` builds its HLO-identity pins on."""
    spec = (spec_or_argv if isinstance(spec_or_argv, RunSpec)
            else RunSpec.from_args(list(spec_or_argv)))
    _, _, traced = train_traced(spec)
    return traced.lower().compile().as_text()


def train_artifacts(spec: RunSpec, spec_path: str = "") -> ProgramArtifacts:
    ctx, setup, traced = train_traced(spec)
    comp = ctx.grad_compression()
    n_leaves = len(jax.tree.leaves(setup.params))
    donated = 2 * n_leaves + len(jax.tree.leaves(setup.opt.mu)) \
        + len(jax.tree.leaves(setup.opt.nu)) - n_leaves
    # donated buffers that must come back aliased: params + opt.mu/nu
    # (all round-trip the step with unchanged shapes); the EF residual
    # rides on top when compression is on
    if setup.ef_state is not None:
        donated += len(jax.tree.leaves(setup.ef_state.residual))
    return ProgramArtifacts(
        name=f"train:{_spec_name(spec_path, spec)}",
        kind="train", spec=spec, spec_path=spec_path,
        mesh_shape=(ctx.n_data, ctx.n_model),
        jaxpr=traced.jaxpr,
        hlo=traced.lower().compile().as_text(),
        meta={
            "wire": comp.wire,
            "wire_layout": comp.wire_layout,
            "compression": spec.compression.kind,
            "wire_payload": spec.compression.wire_kind,
            "donated_leaves": donated,
            "param_leaves": n_leaves,
        })


def decode_artifacts(spec: RunSpec, spec_path: str = "") -> ProgramArtifacts:
    """The serving decode-step program for a (1x1-mesh) spec: the
    Engine's jitted ragged tick with the spec's serving/packing flags.
    The census engine is built small (2 slots, 32-token cache) but
    otherwise exactly as ``make_engine`` would serve the spec."""
    spec = dataclasses.replace(
        spec, serving=dataclasses.replace(spec.serving, slots=2))
    ctx = build(spec)
    params, qstate = ctx.init_state()
    unpacked_bytes = sum(
        a.size * a.dtype.itemsize for a in jax.tree.leaves(params))
    eng = ctx.make_engine(params, qstate, max_len=32)
    jaxpr, hlo = eng.decode_program()
    return ProgramArtifacts(
        name=f"decode:{_spec_name(spec_path, spec)}",
        kind="decode", spec=spec, spec_path=spec_path,
        mesh_shape=(1, 1), jaxpr=jaxpr, hlo=hlo,
        meta={
            "packed": bool(eng.packed),
            "unpacked_param_bytes": int(unpacked_bytes),
            "kv_cache": spec.serving.kv_cache,
            "kv_bits": eng.kv_bits,
            # quantized cache trees are all-int8; what the quantized-kv
            # rule requires the entry layout to store as integer bytes
            "kv_cache_int_bytes": (0 if eng.kv_bits is None else sum(
                a.size for a in jax.tree.leaves(eng.caches)
                if a.dtype == jnp.int8)),
        })


def artifacts_for_spec(spec: RunSpec, spec_path: str = "",
                       kinds: Optional[Tuple[str, ...]] = None
                       ) -> List[ProgramArtifacts]:
    """Every analyzable program of one spec.  The train step needs the
    spec's full mesh; the decode engine is a single-replica object, so it
    is built only for 1x1-mesh specs (a sharded-serving spec would need
    its own engine-per-replica story first)."""
    need = spec.mesh.device_count
    if need > jax.device_count():
        raise RuntimeError(
            f"spec {spec_path or spec.arch} needs {need} devices, host "
            f"has {jax.device_count()} (force more with XLA_FLAGS="
            f"--xla_force_host_platform_device_count={need}, or let "
            f"tools/lint_programs.py --devices do it)")
    out = []
    if kinds is None or "train" in kinds:
        out.append(train_artifacts(spec, spec_path))
    if (kinds is None or "decode" in kinds) and need == 1:
        out.append(decode_artifacts(spec, spec_path))
    return out
