"""Static analysis over compiled programs and library source.

Two rule registries, one shape:

* :data:`PROGRAM_RULES` (``analysis.rules``) run over
  :class:`ProgramArtifacts` — the traced jaxpr + compiled HLO of each
  spec-built train step and serving decode tick (``analysis.program``).
  Parsers live in ``analysis.hlo`` (collectives, replica groups,
  input-output aliases) and ``analysis.jaxpr`` (explicit collectives
  with logical axis names).  ``analysis.report`` serializes the census
  and diffs it against the committed golden
  (``benchmarks/baselines/PROGRAMS.json``) — see
  ``tools/lint_programs.py``.
* :data:`SOURCE_RULES` (``analysis.ast_rules``) run over ``src/repro``
  source text — see ``tools/check_no_globals.py``.

Tests share these parsers (``strip_metadata``, ``parse_collectives``,
``train_step_hlo``) instead of hand-rolling HLO regexes.
"""
from .ast_rules import (SOURCE_RULES, SourceFile, SourceRule,  # noqa: F401
                        check_source)
from .hlo import (COLLECTIVE_OPS, SCALAR_MAX, Collective,  # noqa: F401
                  input_output_aliases, parse_collectives,
                  parse_replica_groups, strip_metadata)
from .jaxpr import (COLLECTIVE_PRIMITIVES, ExplicitCollective,  # noqa: F401
                    explicit_collectives, iter_eqns)
from .program import (ProgramArtifacts, artifacts_for_spec,  # noqa: F401
                      decode_artifacts, train_artifacts, train_step_hlo,
                      train_traced)
from .report import (collect, compare, direction_for, dumps,  # noqa: F401
                     extract_metrics, program_report, tolerance_for)
from .rules import (PROGRAM_RULES, Rule, Violation, run_rules)  # noqa: F401
