"""Decoder-only transformer LM (dense / MoE / VLM-backbone variants).

Layers are homogeneous and stacked; the forward pass is a single
``jax.lax.scan`` over the layer axis (small HLO, fast multi-arch compiles,
remat-friendly) — mandatory for the 95-layer deepseek-67b cell.

The residual stream is kept unquantized (it is an *accumulator*, paper
SSec. III.C folds accumulation EBOPs into the feeding multiplications);
activation quantizers sit at the norm outputs and projection outputs, so
every matmul sees quantized operands.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..core import hgq
from ..core.hgq import Aux, QTensor
from ..dist.axes import constrain
from ..nn.attention import (AttnConfig, GQAAttention, KVCache,
                            decode_positions)
from ..nn.basic import HDense, HEmbedding, LayerNorm, RMSNorm
from ..nn.mlp import GLUMLP
from ..nn.moe import MoE, MoEConfig
from .config import ModelConfig


def _norm_cls(cfg: ModelConfig):
    return RMSNorm if cfg.norm == "rms" else LayerNorm


def _attn_cfg(cfg: ModelConfig) -> AttnConfig:
    return AttnConfig(d_model=cfg.d_model, n_heads=cfg.n_heads,
                      n_kv=cfg.n_kv, head_dim=cfg.hd, qkv_bias=cfg.qkv_bias,
                      rope_theta=cfg.rope_theta, window=cfg.window,
                      causal=True, q_chunk=cfg.q_chunk, k_chunk=cfg.k_chunk)


def _moe_cfg(cfg: ModelConfig) -> MoEConfig:
    return MoEConfig(d_model=cfg.d_model, d_ff=cfg.d_ff,
                     n_experts=cfg.moe_experts, top_k=cfg.moe_top_k,
                     act=cfg.act)


class TransformerLM:
    # ---------------------------- init ----------------------------------
    @staticmethod
    def init(key, cfg: ModelConfig):
        dtype = cfg.np_dtype
        ke, kl, kf, kh = jax.random.split(key, 4)
        Norm = _norm_cls(cfg)
        p: Dict[str, Any] = {}
        q: Dict[str, Any] = {}
        p["embed"], q["embed"] = HEmbedding.init(ke, cfg.vocab, cfg.d_model,
                                                 cfg.hgq, dtype)

        def layer_init(k):
            k1, k2, k3, k4 = jax.random.split(k, 4)
            lp: Dict[str, Any] = {}
            lq: Dict[str, Any] = {}
            lp["ln1"], lq["ln1"] = Norm.init(k1, cfg.d_model, cfg.hgq,
                                             dtype=dtype)
            lp["attn"], lq["attn"] = GQAAttention.init(k2, _attn_cfg(cfg),
                                                       cfg.hgq, dtype)
            lp["ln2"], lq["ln2"] = Norm.init(k3, cfg.d_model, cfg.hgq,
                                             dtype=dtype)
            if cfg.moe_experts:
                lp["moe"], lq["moe"] = MoE.init(k4, _moe_cfg(cfg), cfg.hgq,
                                                dtype)
            else:
                lp["mlp"], lq["mlp"] = GLUMLP.init(k4, cfg.d_model, cfg.d_ff,
                                                   cfg.hgq, act=cfg.act,
                                                   dtype=dtype)
            return lp, lq

        lkeys = jax.random.split(kl, cfg.n_layers)
        p["layers"], q["layers"] = jax.vmap(layer_init)(lkeys)
        p["final_norm"], q["final_norm"] = Norm.init(kf, cfg.d_model, cfg.hgq,
                                                     dtype=dtype)
        if not cfg.tie_embeddings:
            p["lm_head"], q["lm_head"] = HDense.init(
                kh, cfg.d_model, cfg.vocab, cfg.hgq, bias=False, out_q=False,
                dtype=dtype)
        return p, q

    # -------------------------- layer body ------------------------------
    @staticmethod
    def _layer(lp, lq, x, positions, cache, cache_pos, cfg: ModelConfig,
               mode: str, kv_bits: Optional[int] = None):
        Norm = _norm_cls(cfg)
        aux = Aux.zero()
        newq: Dict[str, Any] = {}
        h, newq["ln1"] = Norm.apply(lp["ln1"], lq["ln1"], x, mode=mode,
                                    aux=aux)
        a, newq["attn"], new_cache = GQAAttention.apply(
            lp["attn"], lq["attn"], h, cfg=_attn_cfg(cfg), mode=mode, aux=aux,
            positions=positions, cache=cache, cache_pos=cache_pos,
            kv_bits=kv_bits)
        x = constrain(x + a.q, "b..")
        h, newq["ln2"] = Norm.apply(lp["ln2"], lq["ln2"], x, mode=mode,
                                    aux=aux)
        if cfg.moe_experts:
            m, newq["moe"] = MoE.apply(lp["moe"], lq["moe"], h,
                                       cfg=_moe_cfg(cfg), mode=mode, aux=aux)
        else:
            m, newq["mlp"] = GLUMLP.apply(lp["mlp"], lq["mlp"], h, mode=mode,
                                          aux=aux, act=cfg.act)
        x = constrain(x + m.q, "b..")
        return x, newq, new_cache, aux.as_tuple()

    # ------------------------- scan driver ------------------------------
    @staticmethod
    def _stack_forward(p, q, x, positions, cfg: ModelConfig, mode: str,
                       caches: Optional[KVCache] = None,
                       cache_pos=None, kv_bits: Optional[int] = None):
        def body(carry, xs):
            h, ebops, l1 = carry
            if caches is not None:
                lp, lq, cache_l = xs
            else:
                lp, lq = xs
                cache_l = None
            h2, newlq, new_cache, (e, l) = TransformerLM._layer(
                lp, lq, h, positions, cache_l, cache_pos, cfg, mode,
                kv_bits=kv_bits)
            out = (newlq, new_cache) if caches is not None else newlq
            return (h2.astype(h.dtype), ebops + e, l1 + l), out

        if cfg.remat:
            body = jax.checkpoint(body,
                                  policy=jax.checkpoint_policies.nothing_saveable)
        xs = (p["layers"], q["layers"]) if caches is None \
            else (p["layers"], q["layers"], caches)
        (x, ebops, l1), out = jax.lax.scan(body, (x, jnp.float32(0.0),
                                                  jnp.float32(0.0)), xs)
        if caches is None:
            return x, out, None, (ebops, l1)
        return x, out[0], out[1], (ebops, l1)

    # --------------------------- forward --------------------------------
    @staticmethod
    def forward(p, q, batch: Dict[str, jax.Array], cfg: ModelConfig,
                mode: str = hgq.TRAIN):
        """Training / prefill forward.  batch: tokens [B,S]
        (+ patch_embeds [B,P,d] for vlm).  Returns (logits, new_qstate, aux).
        """
        tokens = batch["tokens"]
        B, S = tokens.shape
        aux = Aux.zero()
        newq: Dict[str, Any] = {}
        e, newq["embed"] = HEmbedding.apply(p["embed"], q["embed"], tokens,
                                            mode=mode, aux=aux)
        from ..dist.perf import cast_for_matmul
        x = constrain(cast_for_matmul(e.q), "b..")
        if cfg.n_patches and "patch_embeds" in batch:
            pe = batch["patch_embeds"].astype(x.dtype)
            x = jax.lax.dynamic_update_slice(x, pe, (0, 0, 0))
        positions = jnp.arange(S)
        x, newq["layers"], _, (ebops, l1) = TransformerLM._stack_forward(
            p, q, x, positions, cfg, mode)
        aux.add(ebops=ebops, l1=l1)
        Norm = _norm_cls(cfg)
        h, newq["final_norm"] = Norm.apply(p["final_norm"], q["final_norm"],
                                           x, mode=mode, aux=aux)
        logits = TransformerLM._logits(p, q, newq, h, cfg, mode, aux)
        return logits, newq, aux

    @staticmethod
    def _logits(p, q, newq, h: QTensor, cfg: ModelConfig, mode, aux):
        if cfg.tie_embeddings:
            from ..dist.perf import (get_packed_matmul, is_packed,
                                     packed_mantissas)
            tbl = p["embed"]["table"]
            if is_packed(tbl) and get_packed_matmul():
                # tied head with a packed table: scales are per-embedding-
                # column (axis d), so they fold into the activation —
                # h @ (m * s[None]).T == (h * s) @ m.T — leaving a unit
                # per-output scale for the kernel
                from ..kernels.qmatmul.ops import qmatmul_any
                s_d = tbl["scale"].reshape(cfg.d_model)
                logits = qmatmul_any(h.q.astype(jnp.float32) * s_d,
                                     packed_mantissas(tbl).T,
                                     jnp.ones((cfg.vocab,), jnp.float32))
                return constrain(logits, "b.m")
            from ..nn.common import get_qw
            wq = get_qw(tbl, mode)
            logits = jnp.matmul(h.q.astype(wq.q.dtype), wq.q.T)
            hgq.matmul_ebops(aux, h.bits,
                             None if wq.bits is None else wq.bits.T,
                             cfg.d_model, cfg.vocab)
            return constrain(logits, "b.m")
        lt, newq["lm_head"] = HDense.apply(p["lm_head"], q["lm_head"], h,
                                           mode=mode, aux=aux)
        return constrain(lt.q, "b.m")

    # ---------------------------- decode --------------------------------
    @staticmethod
    def init_cache(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16, ring_slack: int = 0,
                   kv_bits: Optional[int] = None) -> KVCache:
        """``ring_slack``: extra ring-buffer slots beyond the attention
        window — writing a decode/prefill chunk of S <= ring_slack + 1
        tokens then never evicts history still inside the oldest chunk
        query's window, keeping multi-token decode_step calls exact.
        ``kv_bits``: plan-width quantized storage (``serving/kvcache.py``);
        None keeps the exact legacy fp cache."""
        kv_len = min(max_len, cfg.window + ring_slack) if cfg.window \
            else max_len
        shape = (cfg.n_layers, batch, kv_len, cfg.n_kv, cfg.hd)
        if kv_bits is not None:
            from ..serving.kvcache import quantized_cache
            return quantized_cache(shape, kv_bits)
        return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))

    @staticmethod
    def decode_step(p, q, caches: KVCache, tokens: jax.Array,
                    cache_pos: jax.Array, cfg: ModelConfig,
                    mode: str = hgq.EVAL, kv_bits: Optional[int] = None):
        """One decode step. tokens [B, S_new]; cache_pos scalar or per-slot
        [B] (ragged continuous batching). Returns (logits, new_caches)."""
        B, S = tokens.shape
        aux = Aux.zero()
        newq: Dict[str, Any] = {}
        e, newq["embed"] = HEmbedding.apply(p["embed"], q["embed"], tokens,
                                            mode=mode, aux=aux)
        positions = decode_positions(cache_pos, S)
        x, newq["layers"], new_caches, (ebops, l1) = \
            TransformerLM._stack_forward(p, q, e.q, positions, cfg, mode,
                                         caches=caches, cache_pos=cache_pos,
                                         kv_bits=kv_bits)
        aux.add(ebops=ebops, l1=l1)
        Norm = _norm_cls(cfg)
        h, newq["final_norm"] = Norm.apply(p["final_norm"], q["final_norm"],
                                           x, mode=mode, aux=aux)
        logits = TransformerLM._logits(p, q, newq, h, cfg, mode, aux)
        return logits, new_caches
