"""Whisper-large-v3 backbone: encoder-decoder transformer.

The conv/mel frontend is a STUB per the assignment brief — ``input_specs``
provides precomputed frame embeddings [B, enc_seq, d] (enc_seq = 1500).
Full MHA (n_kv == n_heads), LayerNorm + biases, gelu MLP, learned positions.

Serving shape: the decoder runs through the Engine's ragged decode path
(``decode_step`` with per-slot ``cache_pos``), and the encoder memory is
*streamable* — ``append_cross`` encodes one audio chunk block-locally at
the cache's absolute frame offset and appends its cross-attention K/V
rows, advancing the per-slot fill level ``mem_len``.  Decode-path
cross-attention reads through the same ``tpos``-masked kernels the ring
caches use (``nn.attention.memory_tpos``), so partially-streamed memory
is masked exactly and rows with ``mem_len == 0`` (LM traffic sharing the
batch) get a zero attention read.  Under a quantized KV plan the cross
rows are stored on the same int8 2^-f grids as the self-attention ring
(``cross_kf``/``cross_vf``), read through ``kernels.kv_dequant``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..core import hgq
from ..core.hgq import Aux, QTensor
from ..dist.axes import constrain
from ..nn.attention import (AttnConfig, GQAAttention, KVCache, QKVCache,
                            _decode_attention, decode_positions, memory_tpos)
from ..nn.basic import HDense, HEmbedding, LayerNorm
from ..nn.mlp import MLP
from .config import ModelConfig


class WhisperCaches(NamedTuple):
    self_k: jax.Array    # [L, B, S_max, H, hd] (int8 mantissas quantized)
    self_v: jax.Array
    cross_k: jax.Array   # [L, B, enc_seq, H, hd] (int8 mantissas quantized)
    cross_v: jax.Array
    mem_len: jax.Array   # [1, B] int32 — encoder frames written per slot
    self_kf: Optional[jax.Array] = None  # [L, B, S_max, H] grid exponents
    self_vf: Optional[jax.Array] = None  # (None = legacy fp self cache)
    cross_kf: Optional[jax.Array] = None  # [L, B, enc_seq, H] exponents
    cross_vf: Optional[jax.Array] = None  # (None = fp cross memory)


def _attn_cfg(cfg: ModelConfig, causal: bool) -> AttnConfig:
    return AttnConfig(d_model=cfg.d_model, n_heads=cfg.n_heads,
                      n_kv=cfg.n_kv, head_dim=cfg.hd, qkv_bias=True,
                      causal=causal, q_chunk=cfg.q_chunk, k_chunk=cfg.k_chunk)


class CrossAttention:
    """q from decoder stream, k/v from (fixed) encoder memory."""

    @staticmethod
    def init(key, cfg: ModelConfig, dtype=jnp.float32):
        ks = jax.random.split(key, 4)
        d, H, hd = cfg.d_model, cfg.n_heads, cfg.hd
        p, q = {}, {}
        p["wq"], q["wq"] = HDense.init(ks[0], d, H * hd, cfg.hgq, bias=True,
                                       dtype=dtype)
        p["wk"], q["wk"] = HDense.init(ks[1], d, H * hd, cfg.hgq, bias=False,
                                       dtype=dtype)
        p["wv"], q["wv"] = HDense.init(ks[2], d, H * hd, cfg.hgq, bias=True,
                                       dtype=dtype)
        p["wo"], q["wo"] = HDense.init(ks[3], H * hd, d, cfg.hgq, bias=True,
                                       out_q=False, dtype=dtype)
        if cfg.hgq.enabled:
            p["probs_f"] = jnp.full((), cfg.hgq.init_act_f, jnp.float32)
        return p, q

    @staticmethod
    def kv(p, q, memory: QTensor, cfg: ModelConfig, mode, aux):
        B, T, _ = memory.q.shape
        kt, nk = HDense.apply(p["wk"], q["wk"], memory, mode=mode, aux=aux)
        vt, nv = HDense.apply(p["wv"], q["wv"], memory, mode=mode, aux=aux)
        H, hd = cfg.n_heads, cfg.hd
        return (kt.q.reshape(B, T, H, hd), vt.q.reshape(B, T, H, hd),
                {"wk": nk, "wv": nv})

    @staticmethod
    def apply(p, q, x: QTensor, kh, vh, cfg: ModelConfig, mode, aux):
        B, S, _ = x.q.shape
        H, hd = cfg.n_heads, cfg.hd
        newq: Dict[str, Any] = {}
        qt, newq["wq"] = HDense.apply(p["wq"], q["wq"], x, mode=mode, aux=aux)
        qh = qt.q.reshape(B, S, H, hd)
        T = kh.shape[1]
        scale = hd ** -0.5
        cq = min(cfg.q_chunk, S)
        nq = -(-S // cq)
        pad = nq * cq - S
        qp = jnp.pad(qh, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else qh
        qs = qp.reshape(B, nq, cq, H, hd).transpose(1, 0, 3, 2, 4)

        def q_step(_, qc):
            s = constrain(jnp.einsum("bhqd,bthd->bhqt", qc, kh,
                                     preferred_element_type=jnp.float32),
                          "bm..") * scale
            pt = jax.nn.softmax(s, axis=-1)
            if p.get("probs_f") is not None:
                fn = (hgq.quantize if mode == hgq.TRAIN
                      else hgq.quantize_inference)
                pt = fn(pt, p["probs_f"])
            o = jnp.einsum("bhqt,bthd->bhqd", pt, vh,
                           preferred_element_type=jnp.float32)
            return None, o

        _, outs = jax.lax.scan(q_step, None, qs)
        o = outs.transpose(1, 0, 3, 2, 4).reshape(B, nq * cq, H * hd)[:, :S]
        o = o.astype(x.q.dtype)
        yo, newq["wo"] = HDense.apply(p["wo"], q["wo"], QTensor(o, None),
                                      mode=mode, aux=aux)
        if p.get("probs_f") is not None:
            aux.add(l1=jax.nn.relu(p["probs_f"]))
        return yo, newq

    @staticmethod
    def decode(p, q, x: QTensor, ck, cv, mem, cfg: ModelConfig, mode, aux,
               ckf=None, cvf=None):
        """Decode-path cross read over the (possibly partially-streamed,
        possibly quantized) memory cache: only the ``mem[b]`` written
        rows are visible — empty slots are masked via ``memory_tpos``,
        and a row with ``mem == 0`` gets an exactly-zero attention read
        (how LM slots ride a shared batch without touching the memory
        buffer).  ``ckf``/``cvf`` select the fused dequant-attention
        kernel path over int8 2^-f mantissas (``kernels.kv_dequant``)."""
        B, S, _ = x.q.shape
        H, hd = cfg.n_heads, cfg.hd
        newq: Dict[str, Any] = {}
        qt, newq["wq"] = HDense.apply(p["wq"], q["wq"], x, mode=mode, aux=aux)
        qh = qt.q.reshape(B, S, H, hd)
        T = ck.shape[1]
        tpos = memory_tpos(mem, T)
        # every valid memory row is visible to every query position
        qpos = jnp.full((B, S), T, jnp.int32)
        probs_f = p.get("probs_f")
        if ckf is not None:
            from ..kernels.kv_dequant.ops import kv_attention_decode
            out = kv_attention_decode(qh, ck, ckf, cv, cvf, qpos, tpos,
                                      window=None, n_kv=H, probs_f=probs_f)
        else:
            acfg = dataclasses.replace(_attn_cfg(cfg, causal=False), n_kv=H)
            out = _decode_attention(qh, ck, cv, qpos, acfg, probs_f, mode,
                                    tpos=tpos)
        o = out.reshape(B, S, H * hd).astype(x.q.dtype)
        yo, newq["wo"] = HDense.apply(p["wo"], q["wo"], QTensor(o, None),
                                      mode=mode, aux=aux)
        if probs_f is not None:
            aux.add(l1=jax.nn.relu(p["probs_f"]))
        return yo, newq


class WhisperModel:
    @staticmethod
    def init(key, cfg: ModelConfig):
        dtype = cfg.np_dtype
        ks = jax.random.split(key, 8)
        p: Dict[str, Any] = {}
        q: Dict[str, Any] = {}
        d = cfg.d_model
        # encoder (frame embeddings come precomputed — frontend stub)
        p["enc_pos"] = 0.02 * jax.random.normal(ks[0], (cfg.enc_seq, d),
                                                dtype)

        def enc_layer(k):
            k1, k2, k3, k4 = jax.random.split(k, 4)
            lp, lq = {}, {}
            lp["ln1"], lq["ln1"] = LayerNorm.init(k1, d, cfg.hgq, dtype=dtype)
            lp["attn"], lq["attn"] = GQAAttention.init(
                k2, _attn_cfg(cfg, causal=False), cfg.hgq, dtype)
            lp["ln2"], lq["ln2"] = LayerNorm.init(k3, d, cfg.hgq, dtype=dtype)
            lp["mlp"], lq["mlp"] = MLP.init(k4, d, cfg.d_ff, cfg.hgq,
                                            act="gelu", dtype=dtype)
            return lp, lq

        p["enc_layers"], q["enc_layers"] = jax.vmap(enc_layer)(
            jax.random.split(ks[1], cfg.enc_layers))
        p["enc_norm"], q["enc_norm"] = LayerNorm.init(ks[2], d, cfg.hgq,
                                                      dtype=dtype)
        # decoder
        p["embed"], q["embed"] = HEmbedding.init(ks[3], cfg.vocab, d,
                                                 cfg.hgq, dtype)
        p["dec_pos"] = 0.02 * jax.random.normal(ks[4], (4096, d), dtype)

        def dec_layer(k):
            k1, k2, k3, k4, k5, k6 = jax.random.split(k, 6)
            lp, lq = {}, {}
            lp["ln1"], lq["ln1"] = LayerNorm.init(k1, d, cfg.hgq, dtype=dtype)
            lp["attn"], lq["attn"] = GQAAttention.init(
                k2, _attn_cfg(cfg, causal=True), cfg.hgq, dtype)
            lp["ln_x"], lq["ln_x"] = LayerNorm.init(k3, d, cfg.hgq,
                                                    dtype=dtype)
            lp["xattn"], lq["xattn"] = CrossAttention.init(k4, cfg, dtype)
            lp["ln2"], lq["ln2"] = LayerNorm.init(k5, d, cfg.hgq, dtype=dtype)
            lp["mlp"], lq["mlp"] = MLP.init(k6, d, cfg.d_ff, cfg.hgq,
                                            act="gelu", dtype=dtype)
            return lp, lq

        p["dec_layers"], q["dec_layers"] = jax.vmap(dec_layer)(
            jax.random.split(ks[5], cfg.n_layers))
        p["dec_norm"], q["dec_norm"] = LayerNorm.init(ks[6], d, cfg.hgq,
                                                      dtype=dtype)
        return p, q

    # ------------------------------------------------------------------
    @staticmethod
    def encode(p, q, frame_embeds: jax.Array, cfg: ModelConfig, mode, aux,
               offset=0):
        """Encode a block of frames at absolute frame position
        ``offset``: learned positions are sliced there and RoPE phases
        start there, so streaming (one call per arriving chunk,
        block-local self-attention) and whole-audio encoding agree on
        any block they both encode.  ``offset=0`` with the full audio is
        the classic offline encoder."""
        T = frame_embeds.shape[1]
        if isinstance(offset, int) and offset == 0:
            pe = p["enc_pos"][None, :T]
            positions = jnp.arange(T)
        else:
            off = jnp.asarray(offset, jnp.int32)
            pe = jax.lax.dynamic_slice_in_dim(p["enc_pos"], off, T,
                                              axis=0)[None]
            positions = off + jnp.arange(T)
        x = constrain(frame_embeds + pe, "b..")

        def body(carry, xs):
            h, eb, l1 = carry
            lp, lq = xs
            a = Aux.zero()
            nq = {}
            n1, nq["ln1"] = LayerNorm.apply(lp["ln1"], lq["ln1"], h,
                                            mode=mode, aux=a)
            at, nq["attn"], _ = GQAAttention.apply(
                lp["attn"], lq["attn"], n1, cfg=_attn_cfg(cfg, causal=False),
                mode=mode, aux=a, positions=positions)
            h = h + at.q
            n2, nq["ln2"] = LayerNorm.apply(lp["ln2"], lq["ln2"], h,
                                            mode=mode, aux=a)
            mt, nq["mlp"] = MLP.apply(lp["mlp"], lq["mlp"], n2, mode=mode,
                                      aux=a)
            e, l = a.as_tuple()
            return ((h + mt.q).astype(carry[0].dtype), eb + e, l1 + l), nq

        if cfg.remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        (x, eb, l1), newq = jax.lax.scan(
            body, (x, jnp.float32(0.0), jnp.float32(0.0)),
            (p["enc_layers"], q["enc_layers"]))
        aux.add(ebops=eb, l1=l1)
        n, nq_n = LayerNorm.apply(p["enc_norm"], q["enc_norm"], x, mode=mode,
                                  aux=aux)
        return n, {"enc_layers": newq, "enc_norm": nq_n}

    @staticmethod
    def _decode_stack(p, q, x, memory: Optional[QTensor], positions, cfg,
                      mode, aux, caches=None, cache_pos=None, kv_bits=None):
        decode = caches is not None
        quant = decode and caches.self_kf is not None
        # per-slot memory fill level [B]: not scanned over layers
        mem = caches.mem_len[0] if decode else None

        def body(carry, xs):
            h, eb, l1 = carry
            ckf = cvf = None
            if quant:
                lp, lq, (sk, sv, skf, svf, ck, cv, ckf, cvf) = xs
                kvc = QKVCache(sk, sv, skf, svf)
            elif decode:
                lp, lq, (sk, sv, ck, cv) = xs
                kvc = KVCache(sk, sv)
            else:
                lp, lq = xs
                kvc = None
            a = Aux.zero()
            nq = {}
            n1, nq["ln1"] = LayerNorm.apply(lp["ln1"], lq["ln1"], h,
                                            mode=mode, aux=a)
            at, nq["attn"], nkv = GQAAttention.apply(
                lp["attn"], lq["attn"], n1, cfg=_attn_cfg(cfg, causal=True),
                mode=mode, aux=a, positions=positions, cache=kvc,
                cache_pos=cache_pos, kv_bits=kv_bits)
            h = h + at.q
            nx, nq["ln_x"] = LayerNorm.apply(lp["ln_x"], lq["ln_x"], h,
                                             mode=mode, aux=a)
            if decode:
                nq["xattn_kv"] = {}
                xt, nq["xattn"] = CrossAttention.decode(
                    lp["xattn"], lq["xattn"], nx, ck, cv, mem, cfg, mode,
                    a, ckf=ckf, cvf=cvf)
            else:
                kh, vh, nq["xattn_kv"] = CrossAttention.kv(
                    lp["xattn"], lq["xattn"], memory, cfg, mode, a)
                xt, nq["xattn"] = CrossAttention.apply(
                    lp["xattn"], lq["xattn"], nx, kh, vh, cfg, mode, a)
            h = h + xt.q
            n2, nq["ln2"] = LayerNorm.apply(lp["ln2"], lq["ln2"], h,
                                            mode=mode, aux=a)
            mt, nq["mlp"] = MLP.apply(lp["mlp"], lq["mlp"], n2, mode=mode,
                                      aux=a)
            e, l = a.as_tuple()
            if quant:
                out = (nq, (nkv.k, nkv.v, nkv.kf, nkv.vf))
            elif decode:
                out = (nq, (nkv.k, nkv.v))
            else:
                out = nq
            return ((h + mt.q).astype(carry[0].dtype), eb + e, l1 + l), out

        if cfg.remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        if quant:
            xs = (p["dec_layers"], q["dec_layers"],
                  (caches.self_k, caches.self_v, caches.self_kf,
                   caches.self_vf, caches.cross_k, caches.cross_v,
                   caches.cross_kf, caches.cross_vf))
        elif decode:
            xs = (p["dec_layers"], q["dec_layers"],
                  (caches.self_k, caches.self_v, caches.cross_k,
                   caches.cross_v))
        else:
            xs = (p["dec_layers"], q["dec_layers"])
        (x, eb, l1), out = jax.lax.scan(
            body, (x, jnp.float32(0.0), jnp.float32(0.0)), xs)
        aux.add(ebops=eb, l1=l1)
        if decode:
            return x, out[0], out[1]
        return x, out, None

    # ------------------------------------------------------------------
    @staticmethod
    def forward(p, q, batch, cfg: ModelConfig, mode: str = hgq.TRAIN):
        """batch: frame_embeds [B, enc_seq, d], tokens [B, S_dec]."""
        aux = Aux.zero()
        newq: Dict[str, Any] = {}
        mem, nq_enc = WhisperModel.encode(p, q, batch["frame_embeds"], cfg,
                                          mode, aux)
        newq.update(nq_enc)
        tokens = batch["tokens"]
        B, S = tokens.shape
        e, newq["embed"] = HEmbedding.apply(p["embed"], q["embed"], tokens,
                                            mode=mode, aux=aux)
        pos_table = p["dec_pos"]
        x = e.q + jnp.take(pos_table, jnp.arange(S) % pos_table.shape[0],
                           axis=0)[None]
        x, newq["dec_layers"], _ = WhisperModel._decode_stack(
            p, q, x, mem, jnp.arange(S), cfg, mode, aux)
        h, newq["dec_norm"] = LayerNorm.apply(p["dec_norm"], q["dec_norm"],
                                              x, mode=mode, aux=aux)
        # whisper ties decoder embedding for logits
        from ..nn.common import get_qw
        wq = get_qw(p["embed"]["table"], mode)
        logits = constrain(jnp.matmul(h.q.astype(wq.q.dtype), wq.q.T), "b.m")
        hgq.matmul_ebops(aux, h.bits,
                         None if wq.bits is None else wq.bits.T,
                         cfg.d_model, cfg.vocab)
        return logits, newq, aux

    @staticmethod
    def init_cache(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16, ring_slack: int = 0,
                   kv_bits=None) -> WhisperCaches:
        del ring_slack  # decoder self-attn cache is not windowed
        L, H, hd = cfg.n_layers, cfg.n_heads, cfg.hd
        self_shape = (L, batch, max_len, H, hd)
        cross_shape = (L, batch, cfg.enc_seq, H, hd)
        if kv_bits is not None:
            # the cross memory rides the same quantized-cache machinery
            # as the self-attention ring: int8 mantissas on per-row 2^-f
            # grids (nibble-packed at kv_bits <= 4), exponents alongside
            from ..serving.kvcache import quantized_cache
            qkv = quantized_cache(self_shape, kv_bits)
            selfkv = dict(self_k=qkv.k, self_v=qkv.v,
                          self_kf=qkv.kf, self_vf=qkv.vf)
            qx = quantized_cache(cross_shape, kv_bits)
            cross = dict(cross_k=qx.k, cross_v=qx.v,
                         cross_kf=qx.kf, cross_vf=qx.vf)
        else:
            selfkv = dict(self_k=jnp.zeros(self_shape, dtype),
                          self_v=jnp.zeros(self_shape, dtype))
            cross = dict(cross_k=jnp.zeros(cross_shape, dtype),
                         cross_v=jnp.zeros(cross_shape, dtype))
        return WhisperCaches(
            mem_len=jnp.zeros((1, batch), jnp.int32), **selfkv, **cross)

    @staticmethod
    def append_cross(p, q, caches: WhisperCaches, frame_chunk, cfg,
                     mode: str = hgq.EVAL, kv_bits=None) -> WhisperCaches:
        """Encode one audio chunk block-locally at the cache's current
        memory offset and append its cross-attention K/V rows,
        advancing ``mem_len``.

        Streaming contract: chunks are self-attended only within their
        own block (at absolute positions — ``encode(offset=...)``), so
        feeding N chunks one call at a time writes bit-for-bit the rows
        that one call per chunk over the whole audio would — the
        chunk *decomposition* is the semantic unit, not the arrival
        schedule.  All batch rows advance together (the Engine appends
        on single-slot cache slices; ``serving.streaming.generate_asr``
        is the B=1 offline reference)."""
        aux = Aux.zero()
        off = caches.mem_len[0, 0]
        mem, _ = WhisperModel.encode(p, q, frame_chunk, cfg, mode, aux,
                                     offset=off)

        def one_layer(lp, lq):
            kh, vh, _ = CrossAttention.kv(lp["xattn"], lq["xattn"], mem, cfg,
                                          mode, Aux.zero())
            return kh, vh

        ck, cv = jax.vmap(one_layer)(p["dec_layers"], q["dec_layers"])

        def upd(a, u):
            return jax.lax.dynamic_update_slice_in_dim(a, u, off, axis=2)

        if caches.cross_kf is not None:
            from ..kernels.kv_dequant.ops import kv_pack, kv_quantize
            km, kf = kv_quantize(ck, kv_bits or 8)
            vm, vf = kv_quantize(cv, kv_bits or 8)
            if caches.cross_k.shape[-1] != ck.shape[-1]:
                km, vm = kv_pack(km), kv_pack(vm)
            new = dict(cross_k=upd(caches.cross_k, km),
                       cross_v=upd(caches.cross_v, vm),
                       cross_kf=upd(caches.cross_kf, kf),
                       cross_vf=upd(caches.cross_vf, vf))
        else:
            new = dict(
                cross_k=upd(caches.cross_k,
                            ck.astype(caches.cross_k.dtype)),
                cross_v=upd(caches.cross_v,
                            cv.astype(caches.cross_v.dtype)))
        n = jnp.int32(frame_chunk.shape[1])
        return caches._replace(mem_len=caches.mem_len + n, **new)

    @staticmethod
    def prefill_cross(p, q, caches: WhisperCaches, frame_embeds, cfg,
                      mode: str = hgq.EVAL, kv_bits=None) -> WhisperCaches:
        """Whole-audio memory prefill: one block-local ``append_cross``
        covering the full audio on a fresh cache — the offline encoder."""
        return WhisperModel.append_cross(p, q, caches, frame_embeds, cfg,
                                         mode=mode, kv_bits=kv_bits)

    @staticmethod
    def decode_step(p, q, caches: WhisperCaches, tokens, cache_pos,
                    cfg: ModelConfig, mode: str = hgq.EVAL, kv_bits=None):
        aux = Aux.zero()
        newq: Dict[str, Any] = {}
        B, S = tokens.shape
        e, newq["embed"] = HEmbedding.apply(p["embed"], q["embed"], tokens,
                                            mode=mode, aux=aux)
        pos_table = p["dec_pos"]
        positions = decode_positions(cache_pos, S)
        pe = jnp.take(pos_table, positions % pos_table.shape[0], axis=0)
        x = e.q + (pe if positions.ndim == 2 else pe[None])
        x, _, new_kv = WhisperModel._decode_stack(
            p, q, x, None, positions, cfg, mode, aux, caches=caches,
            cache_pos=cache_pos, kv_bits=kv_bits)
        h, _ = LayerNorm.apply(p["dec_norm"], q["dec_norm"], x, mode=mode,
                               aux=aux)
        from ..nn.common import get_qw
        wq = get_qw(p["embed"]["table"], mode)
        logits = constrain(jnp.matmul(h.q.astype(wq.q.dtype), wq.q.T), "b.m")
        if caches.self_kf is not None:
            nk, nv, nkf, nvf = new_kv
            return logits, caches._replace(self_k=nk, self_v=nv,
                                           self_kf=nkf, self_vf=nvf)
        nk, nv = new_kv
        return logits, caches._replace(self_k=nk, self_v=nv)
