"""Griffin / RecurrentGemma: RG-LRU recurrent blocks + local attention, 1:2.

Pattern: (recurrent, recurrent, attention) repeating; trailing remainder
layers are recurrent.  The stack scans over *pattern units* (homogeneous),
with the remainder unrolled — keeps the HLO small while supporting L % 3 != 0
(recurrentgemma-2b has 26 layers = 8 units + 2 remainder).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core import hgq
from ..core.hgq import Aux
from ..dist.axes import constrain
from ..nn.attention import (AttnConfig, GQAAttention, KVCache, QKVCache,
                            decode_positions)
from ..nn.basic import HDense, HEmbedding, RMSNorm
from ..nn.mlp import GLUMLP
from ..nn.recurrent import GriffinState, RecurrentBlock, RGLRUConfig
from .config import ModelConfig


class GriffinCaches(NamedTuple):
    conv: jax.Array      # [n_rec, B, cw-1, d_rnn]
    h: jax.Array         # [n_rec, B, d_rnn]
    k: jax.Array         # [n_att, B, W, KV, hd] (int8 mantissas when
    v: jax.Array         # quantized; [.., hd//2] nibble-packed <= 4 bits)
    kf: Optional[jax.Array] = None   # [n_att, B, W, KV] grid exponents
    vf: Optional[jax.Array] = None   # (None = legacy fp cache)


def _rg_cfg(cfg: ModelConfig) -> RGLRUConfig:
    return RGLRUConfig(d_model=cfg.d_model, d_rnn=cfg.d_model)


def _attn_cfg(cfg: ModelConfig) -> AttnConfig:
    return AttnConfig(d_model=cfg.d_model, n_heads=cfg.n_heads,
                      n_kv=cfg.n_kv, head_dim=cfg.hd, rope_theta=10000.0,
                      window=cfg.window, causal=True,
                      q_chunk=cfg.q_chunk, k_chunk=cfg.k_chunk)


def _layer_counts(cfg: ModelConfig) -> Tuple[int, int, int]:
    """(#pattern units, #remainder recurrent layers, #attention layers)."""
    units = cfg.n_layers // 3
    rem = cfg.n_layers - units * 3
    return units, rem, units


class GriffinLM:
    @staticmethod
    def init(key, cfg: ModelConfig):
        dtype = cfg.np_dtype
        rg, ac = _rg_cfg(cfg), _attn_cfg(cfg)
        units, rem, _ = _layer_counts(cfg)
        ke, ku, kr, kf, kh = jax.random.split(key, 5)
        p: Dict[str, Any] = {}
        q: Dict[str, Any] = {}
        p["embed"], q["embed"] = HEmbedding.init(ke, cfg.vocab, cfg.d_model,
                                                 cfg.hgq, dtype)

        def block_init(k, kind: str):
            k1, k2, k3, k4 = jax.random.split(k, 4)
            lp, lq = {}, {}
            lp["ln1"], lq["ln1"] = RMSNorm.init(k1, cfg.d_model, cfg.hgq,
                                                dtype=dtype)
            if kind == "rec":
                lp["mix"], lq["mix"] = RecurrentBlock.init(k2, rg, cfg.hgq,
                                                           dtype)
            else:
                lp["mix"], lq["mix"] = GQAAttention.init(k2, ac, cfg.hgq,
                                                         dtype)
            lp["ln2"], lq["ln2"] = RMSNorm.init(k3, cfg.d_model, cfg.hgq,
                                                dtype=dtype)
            lp["mlp"], lq["mlp"] = GLUMLP.init(k4, cfg.d_model, cfg.d_ff,
                                               cfg.hgq, act="gelu",
                                               dtype=dtype)
            return lp, lq

        def unit_init(k):
            ka, kb, kc = jax.random.split(k, 3)
            r1 = block_init(ka, "rec")
            r2 = block_init(kb, "rec")
            at = block_init(kc, "att")
            return {"rec1": r1[0], "rec2": r2[0], "att": at[0]}, \
                   {"rec1": r1[1], "rec2": r2[1], "att": at[1]}

        p["units"], q["units"] = jax.vmap(unit_init)(
            jax.random.split(ku, units))
        p["rem"], q["rem"] = [], []
        rem_p, rem_q = [], []
        for k in jax.random.split(kr, max(rem, 1))[:rem]:
            bp, bq = block_init(k, "rec")
            rem_p.append(bp)
            rem_q.append(bq)
        p["rem"], q["rem"] = rem_p, rem_q
        p["final_norm"], q["final_norm"] = RMSNorm.init(kf, cfg.d_model,
                                                        cfg.hgq, dtype=dtype)
        p["lm_head"], q["lm_head"] = HDense.init(kh, cfg.d_model, cfg.vocab,
                                                 cfg.hgq, bias=False,
                                                 out_q=False, dtype=dtype)
        return p, q

    # ------------------------------------------------------------------
    @staticmethod
    def _block(lp, lq, x, kind, cfg, mode, aux, positions, rec_state=None,
               kv_cache=None, cache_pos=None, kv_bits=None):
        newq: Dict[str, Any] = {}
        h, newq["ln1"] = RMSNorm.apply(lp["ln1"], lq["ln1"], x, mode=mode,
                                       aux=aux)
        new_state = None
        new_cache = None
        if kind == "rec":
            m, newq["mix"], new_state = RecurrentBlock.apply(
                lp["mix"], lq["mix"], h, rec_state, cfg=_rg_cfg(cfg),
                mode=mode, aux=aux)
        else:
            m, newq["mix"], new_cache = GQAAttention.apply(
                lp["mix"], lq["mix"], h, cfg=_attn_cfg(cfg), mode=mode,
                aux=aux, positions=positions, cache=kv_cache,
                cache_pos=cache_pos, kv_bits=kv_bits)
        x = x + m.q
        h, newq["ln2"] = RMSNorm.apply(lp["ln2"], lq["ln2"], x, mode=mode,
                                       aux=aux)
        m, newq["mlp"] = GLUMLP.apply(lp["mlp"], lq["mlp"], h, mode=mode,
                                      aux=aux, act="gelu")
        return x + m.q, newq, new_state, new_cache

    @staticmethod
    def _stack(p, q, x, positions, cfg: ModelConfig, mode,
               caches: Optional[GriffinCaches], cache_pos, kv_bits=None):
        units, rem, _ = _layer_counts(cfg)
        decode = caches is not None
        quant = decode and caches.kf is not None

        def unit_body(carry, xs):
            h, ebops, l1 = carry
            carry = (h, ebops, l1)
            if quant:
                up, uq, (c1, h1, c2, h2, kc, vc, kcf, vcf) = xs
                s1, s2 = GriffinState(c1, h1), GriffinState(c2, h2)
                kvc = QKVCache(kc, vc, kcf, vcf)
            elif decode:
                up, uq, (c1, h1, c2, h2, kc, vc) = xs
                s1, s2 = GriffinState(c1, h1), GriffinState(c2, h2)
                kvc = KVCache(kc, vc)
            else:
                up, uq = xs
                s1 = s2 = kvc = None
            aux = Aux.zero()
            nq: Dict[str, Any] = {}
            h, nq["rec1"], ns1, _ = GriffinLM._block(
                up["rec1"], uq["rec1"], h, "rec", cfg, mode, aux, positions,
                rec_state=s1)
            h, nq["rec2"], ns2, _ = GriffinLM._block(
                up["rec2"], uq["rec2"], h, "rec", cfg, mode, aux, positions,
                rec_state=s2)
            h, nq["att"], _, nkv = GriffinLM._block(
                up["att"], uq["att"], h, "att", cfg, mode, aux, positions,
                kv_cache=kvc, cache_pos=cache_pos, kv_bits=kv_bits)
            e, l = aux.as_tuple()
            if quant:
                out = (nq, (ns1.conv, ns1.h, ns2.conv, ns2.h,
                            nkv.k, nkv.v, nkv.kf, nkv.vf))
            elif decode:
                out = (nq, (ns1.conv, ns1.h, ns2.conv, ns2.h, nkv.k, nkv.v))
            else:
                out = nq
            return (h.astype(carry[0].dtype), ebops + e, l1 + l), out

        if cfg.remat:
            unit_body = jax.checkpoint(
                unit_body, policy=jax.checkpoint_policies.nothing_saveable)
        if decode:
            nrec = 2 * units
            kv_xs = (caches.k, caches.v) if not quant else \
                (caches.k, caches.v, caches.kf, caches.vf)
            xs = (p["units"], q["units"],
                  (caches.conv[:nrec:2], caches.h[:nrec:2],
                   caches.conv[1:nrec:2], caches.h[1:nrec:2]) + kv_xs)
        else:
            xs = (p["units"], q["units"])
        (x, ebops, l1), out = jax.lax.scan(
            unit_body, (x, jnp.float32(0.0), jnp.float32(0.0)), xs)
        aux_tot = Aux(ebops, l1)
        newq = {"units": out[0] if decode else out}
        new_caches = None
        rem_states = []
        # remainder recurrent layers (unrolled)
        rem_newq = []
        for i in range(rem):
            aux = Aux.zero()
            st = GriffinState(caches.conv[2 * units + i],
                              caches.h[2 * units + i]) if decode else None
            x, nq, ns, _ = GriffinLM._block(p["rem"][i], q["rem"][i], x,
                                            "rec", cfg, mode, aux, positions,
                                            rec_state=st)
            rem_newq.append(nq)
            rem_states.append(ns)
            aux_tot.merge(aux)
        newq["rem"] = rem_newq
        if decode:
            if quant:
                c1, h1, c2, h2, kc, vc, kcf, vcf = out[1]
            else:
                c1, h1, c2, h2, kc, vc = out[1]
                kcf = vcf = None
            conv_u = jnp.stack([c1, c2], axis=1).reshape(
                (2 * units,) + c1.shape[1:])
            h_u = jnp.stack([h1, h2], axis=1).reshape(
                (2 * units,) + h1.shape[1:])
            if rem:
                conv_u = jnp.concatenate(
                    [conv_u, jnp.stack([s.conv for s in rem_states])], 0)
                h_u = jnp.concatenate(
                    [h_u, jnp.stack([s.h for s in rem_states])], 0)
            new_caches = GriffinCaches(conv=conv_u, h=h_u, k=kc, v=vc,
                                       kf=kcf, vf=vcf)
        return x, newq, new_caches, aux_tot

    # ------------------------------------------------------------------
    @staticmethod
    def forward(p, q, batch, cfg: ModelConfig, mode: str = hgq.TRAIN):
        tokens = batch["tokens"]
        B, S = tokens.shape
        aux = Aux.zero()
        newq: Dict[str, Any] = {}
        e, newq["embed"] = HEmbedding.apply(p["embed"], q["embed"], tokens,
                                            mode=mode, aux=aux)
        x, nq, _, aux2 = GriffinLM._stack(p, q, constrain(e.q, "b.."),
                                          jnp.arange(S), cfg, mode,
                                          None, None)
        newq.update(nq)
        aux.merge(aux2)
        h, newq["final_norm"] = RMSNorm.apply(p["final_norm"],
                                              q["final_norm"], x, mode=mode,
                                              aux=aux)
        lt, newq["lm_head"] = HDense.apply(p["lm_head"], q["lm_head"], h,
                                           mode=mode, aux=aux)
        return constrain(lt.q, "b.m"), newq, aux

    @staticmethod
    def init_cache(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16, ring_slack: int = 0,
                   kv_bits=None) -> GriffinCaches:
        units, rem, natt = _layer_counts(cfg)
        nrec = 2 * units + rem
        # ring_slack: see TransformerLM.init_cache — keeps multi-token
        # chunks exact on the local-attention ring buffers
        W = min(max_len, (cfg.window + ring_slack) if cfg.window
                else max_len)
        rg = _rg_cfg(cfg)
        kv_shape = (natt, batch, W, cfg.n_kv, cfg.hd)
        if kv_bits is not None:
            from ..serving.kvcache import quantized_cache
            qkv = quantized_cache(kv_shape, kv_bits)
            kv = dict(k=qkv.k, v=qkv.v, kf=qkv.kf, vf=qkv.vf)
        else:
            kv = dict(k=jnp.zeros(kv_shape, dtype),
                      v=jnp.zeros(kv_shape, dtype))
        return GriffinCaches(
            conv=jnp.zeros((nrec, batch, rg.conv_width - 1, rg.d_rnn),
                           jnp.float32),
            h=jnp.zeros((nrec, batch, rg.d_rnn), jnp.float32), **kv)

    @staticmethod
    def decode_step(p, q, caches: GriffinCaches, tokens, cache_pos,
                    cfg: ModelConfig, mode: str = hgq.EVAL, kv_bits=None):
        B, S = tokens.shape
        aux = Aux.zero()
        newq: Dict[str, Any] = {}
        e, newq["embed"] = HEmbedding.apply(p["embed"], q["embed"], tokens,
                                            mode=mode, aux=aux)
        positions = decode_positions(cache_pos, S)
        x, nq, new_caches, _ = GriffinLM._stack(p, q, e.q, positions, cfg,
                                                mode, caches, cache_pos,
                                                kv_bits=kv_bits)
        h, _ = RMSNorm.apply(p["final_norm"], q["final_norm"], x, mode=mode,
                             aux=aux)
        lt, _ = HDense.apply(p["lm_head"], q["lm_head"], h, mode=mode,
                             aux=aux)
        return constrain(lt.q, "b.m"), new_caches
