"""The paper's three benchmark models (Tables I-III), built from H-layers
with per-parameter granularity — the exact regime HGQ targets on FPGAs.

* JetTagger   — 16 -> 64 -> 32 -> 32 -> 5 MLP (jet tagging, Table I)
* SVHNNet     — LeNet-like conv net from [64] (SVHN classifier, Table II)
* MuonTracker — multistage dense regression from [65] (Table III)

Each model starts with an input quantizer (the paper's ``HQuantize`` layer,
Listing 2).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..core import hgq
from ..core.hgq import Aux, QTensor
from ..nn.basic import HConv2D, HDense
from ..nn.common import HGQConfig, act_q_init, apply_act_q


def _input_q(cfg: HGQConfig, feature_shape=()):
    f, st = act_q_init(cfg, feature_shape)
    return f, st


class JetTagger:
    WIDTHS = (64, 32, 32, 5)

    @staticmethod
    def init(key, cfg: HGQConfig, d_in: int = 16):
        ks = jax.random.split(key, len(JetTagger.WIDTHS))
        p: Dict[str, Any] = {}
        q: Dict[str, Any] = {}
        f, st = _input_q(cfg, (d_in,) if cfg.act_gran != "per_tensor" else ())
        if f is not None:
            p["inp_f"] = f
            q["inp"] = st
        din = d_in
        for i, (w, k) in enumerate(zip(JetTagger.WIDTHS, ks)):
            act = "relu" if i < len(JetTagger.WIDTHS) - 1 else None
            out_q = i < len(JetTagger.WIDTHS) - 1
            p[f"d{i}"], q[f"d{i}"] = HDense.init(k, din, w, cfg, act=act,
                                                 out_q=out_q)
            din = w
        return p, q

    @staticmethod
    def forward(p, q, batch, mode: str = hgq.TRAIN):
        x = batch["x"]
        aux = Aux.zero()
        newq: Dict[str, Any] = {}
        if "inp_f" in p:
            xq, newq["inp"] = apply_act_q(x, p["inp_f"], q.get("inp"), mode,
                                          aux)
        else:
            xq = QTensor(x, None)
        h = xq
        for i in range(len(JetTagger.WIDTHS)):
            act = "relu" if i < len(JetTagger.WIDTHS) - 1 else ""
            h, newq[f"d{i}"] = HDense.apply(p[f"d{i}"], q[f"d{i}"], h,
                                            mode=mode, aux=aux, act=act)
        return h.q, newq, aux


class SVHNNet:
    """conv16-conv16-conv24 (each + maxpool) -> dense42 -> dense64 -> 10."""

    @staticmethod
    def init(key, cfg: HGQConfig, img: int = 32, cin: int = 3):
        ks = jax.random.split(key, 6)
        p: Dict[str, Any] = {}
        q: Dict[str, Any] = {}
        f, st = _input_q(cfg)
        if f is not None:
            p["inp_f"] = f
            q["inp"] = st
        p["c0"], q["c0"] = HConv2D.init(ks[0], 3, 3, cin, 16, cfg, act="relu")
        p["c1"], q["c1"] = HConv2D.init(ks[1], 3, 3, 16, 16, cfg, act="relu")
        p["c2"], q["c2"] = HConv2D.init(ks[2], 3, 3, 16, 24, cfg, act="relu")
        # 32x32 -> conv(30) pool(15) -> conv(13) pool(6) -> conv(4) pool(2)
        flat = 2 * 2 * 24
        p["d0"], q["d0"] = HDense.init(ks[3], flat, 42, cfg, act="relu")
        p["d1"], q["d1"] = HDense.init(ks[4], 42, 64, cfg, act="relu")
        p["d2"], q["d2"] = HDense.init(ks[5], 64, 10, cfg, out_q=False)
        return p, q

    @staticmethod
    def forward(p, q, batch, mode: str = hgq.TRAIN):
        x = batch["x"]  # [B, 32, 32, 3]
        aux = Aux.zero()
        newq: Dict[str, Any] = {}
        if "inp_f" in p:
            xq, newq["inp"] = apply_act_q(x, p["inp_f"], q.get("inp"), mode,
                                          aux)
        else:
            xq = QTensor(x, None)
        h = xq
        for name in ("c0", "c1", "c2"):
            h, newq[name] = HConv2D.apply(p[name], q[name], h, mode=mode,
                                          aux=aux, act="relu")
            pooled = jax.lax.reduce_window(
                h.q, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1),
                "VALID")
            h = QTensor(pooled, h.bits)
        B = h.q.shape[0]
        h = QTensor(h.q.reshape(B, -1),
                    None if h.bits is None else jnp.max(h.bits))
        for name in ("d0", "d1", "d2"):
            h, newq[name] = HDense.apply(p[name], q[name], h, mode=mode,
                                         aux=aux,
                                         act="relu" if name != "d2" else "")
        return h.q, newq, aux


class MuonTracker:
    """Three detector stations (3x50 binary hit maps) -> per-station dense
    encoders -> concatenated trunk -> angle (mrad) regression."""

    STATION_WIDTH = 32
    TRUNK = (64, 32)

    @staticmethod
    def init(key, cfg: HGQConfig):
        ks = jax.random.split(key, 6)
        p: Dict[str, Any] = {}
        q: Dict[str, Any] = {}
        f, st = _input_q(cfg)
        if f is not None:
            p["inp_f"] = f
            q["inp"] = st
        for i in range(3):
            p[f"s{i}"], q[f"s{i}"] = HDense.init(
                ks[i], 150, MuonTracker.STATION_WIDTH, cfg, act="relu")
        din = 3 * MuonTracker.STATION_WIDTH
        for j, w in enumerate(MuonTracker.TRUNK):
            p[f"t{j}"], q[f"t{j}"] = HDense.init(ks[3 + j], din, w, cfg,
                                                 act="relu")
            din = w
        p["out"], q["out"] = HDense.init(ks[5], din, 1, cfg, out_q=False)
        return p, q

    @staticmethod
    def forward(p, q, batch, mode: str = hgq.TRAIN):
        """batch['stations']: [B, 3, 150] (flattened 3x50 hit maps)."""
        x = batch["stations"]
        aux = Aux.zero()
        newq: Dict[str, Any] = {}
        if "inp_f" in p:
            xq, newq["inp"] = apply_act_q(x, p["inp_f"], q.get("inp"), mode,
                                          aux)
        else:
            xq = QTensor(x, None)
        outs = []
        for i in range(3):
            hi, newq[f"s{i}"] = HDense.apply(
                p[f"s{i}"], q[f"s{i}"],
                QTensor(xq.q[:, i], xq.bits), mode=mode, aux=aux, act="relu")
            outs.append(hi)
        bits = None
        if outs[0].bits is not None:
            bits = jnp.max(jnp.stack([jnp.max(o.bits) for o in outs]))
        h = QTensor(jnp.concatenate([o.q for o in outs], axis=-1), bits)
        for j in range(len(MuonTracker.TRUNK)):
            h, newq[f"t{j}"] = HDense.apply(p[f"t{j}"], q[f"t{j}"], h,
                                            mode=mode, aux=aux, act="relu")
        h, newq["out"] = HDense.apply(p["out"], q["out"], h, mode=mode,
                                      aux=aux)
        return h.q[..., 0], newq, aux
