"""RWKV-6 (Finch) language model — attention-free, O(1)-state decode."""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..core import hgq
from ..core.hgq import Aux
from ..dist.axes import constrain
from ..nn.basic import HDense, HEmbedding, LayerNorm
from ..nn.recurrent import (RWKVChannelMix, RWKVConfig, RWKVState,
                            RWKVTimeMix)
from .config import ModelConfig


class RWKVCaches(NamedTuple):
    shift_a: jax.Array   # [L, B, d]
    shift_f: jax.Array   # [L, B, d]
    wkv: jax.Array       # [L, B, H, N, N]


def _rwkv_cfg(cfg: ModelConfig) -> RWKVConfig:
    return RWKVConfig(d_model=cfg.d_model,
                      n_heads=cfg.d_model // 64,
                      d_ff=cfg.d_ff, time_chunk=cfg.rwkv_chunk)


class RWKVLM:
    @staticmethod
    def init(key, cfg: ModelConfig):
        dtype = cfg.np_dtype
        rc = _rwkv_cfg(cfg)
        ke, kl, kf, kh = jax.random.split(key, 4)
        p: Dict[str, Any] = {}
        q: Dict[str, Any] = {}
        p["embed"], q["embed"] = HEmbedding.init(ke, cfg.vocab, cfg.d_model,
                                                 cfg.hgq, dtype)

        def layer_init(k):
            k1, k2, k3, k4 = jax.random.split(k, 4)
            lp, lq = {}, {}
            lp["ln1"], lq["ln1"] = LayerNorm.init(k1, cfg.d_model, cfg.hgq,
                                                  dtype=dtype)
            lp["att"], lq["att"] = RWKVTimeMix.init(k2, rc, cfg.hgq, dtype)
            lp["ln2"], lq["ln2"] = LayerNorm.init(k3, cfg.d_model, cfg.hgq,
                                                  dtype=dtype)
            lp["ffn"], lq["ffn"] = RWKVChannelMix.init(k4, rc, cfg.hgq, dtype)
            return lp, lq

        p["layers"], q["layers"] = jax.vmap(layer_init)(
            jax.random.split(kl, cfg.n_layers))
        p["final_norm"], q["final_norm"] = LayerNorm.init(
            kf, cfg.d_model, cfg.hgq, dtype=dtype)
        p["lm_head"], q["lm_head"] = HDense.init(kh, cfg.d_model, cfg.vocab,
                                                 cfg.hgq, bias=False,
                                                 out_q=False, dtype=dtype)
        return p, q

    @staticmethod
    def _stack(p, q, x, cfg: ModelConfig, mode: str,
               caches: Optional[RWKVCaches]):
        rc = _rwkv_cfg(cfg)

        def body(carry, xs):
            h, ebops, l1 = carry
            carry = (h, ebops, l1)
            if caches is not None:
                lp, lq, (sa, sf, wkv) = xs
                st = RWKVState(sa, sf, wkv)
            else:
                lp, lq = xs
                st = None
            aux = Aux.zero()
            newq: Dict[str, Any] = {}
            n1, newq["ln1"] = LayerNorm.apply(lp["ln1"], lq["ln1"], h,
                                              mode=mode, aux=aux)
            a, newq["att"], (sa_n, wkv_n) = RWKVTimeMix.apply(
                lp["att"], lq["att"], n1,
                st if st is not None else None, cfg=rc, mode=mode, aux=aux)
            h = h + a.q
            n2, newq["ln2"] = LayerNorm.apply(lp["ln2"], lq["ln2"], h,
                                              mode=mode, aux=aux)
            f, newq["ffn"], sf_n = RWKVChannelMix.apply(
                lp["ffn"], lq["ffn"], n2,
                st.shift_f if st is not None else None, mode=mode, aux=aux)
            h = (h + f.q).astype(carry[0].dtype)
            e, l = aux.as_tuple()
            out = (newq, (sa_n, sf_n, wkv_n)) if caches is not None else newq
            return (h, ebops + e, l1 + l), out

        if cfg.remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        xs = (p["layers"], q["layers"]) if caches is None else \
            (p["layers"], q["layers"],
             (caches.shift_a, caches.shift_f, caches.wkv))
        (x, ebops, l1), out = jax.lax.scan(
            body, (x, jnp.float32(0.0), jnp.float32(0.0)), xs)
        if caches is None:
            return x, out, None, (ebops, l1)
        newq, (sa, sf, wkv) = out
        return x, newq, RWKVCaches(sa, sf, wkv), (ebops, l1)

    @staticmethod
    def forward(p, q, batch, cfg: ModelConfig, mode: str = hgq.TRAIN):
        tokens = batch["tokens"]
        aux = Aux.zero()
        newq: Dict[str, Any] = {}
        e, newq["embed"] = HEmbedding.apply(p["embed"], q["embed"], tokens,
                                            mode=mode, aux=aux)
        x, newq["layers"], _, (eb, l1) = RWKVLM._stack(
            p, q, constrain(e.q, "b.."), cfg, mode, None)
        aux.add(ebops=eb, l1=l1)
        h, newq["final_norm"] = LayerNorm.apply(p["final_norm"],
                                                q["final_norm"], x, mode=mode,
                                                aux=aux)
        lt, newq["lm_head"] = HDense.apply(p["lm_head"], q["lm_head"], h,
                                           mode=mode, aux=aux)
        return constrain(lt.q, "b.m"), newq, aux

    @staticmethod
    def init_cache(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=jnp.float32, ring_slack: int = 0) -> RWKVCaches:
        del ring_slack  # recurrent state, no attention ring buffer
        d = cfg.d_model
        H = d // 64
        L = cfg.n_layers
        return RWKVCaches(
            shift_a=jnp.zeros((L, batch, d), dtype),
            shift_f=jnp.zeros((L, batch, d), dtype),
            wkv=jnp.zeros((L, batch, H, 64, 64), jnp.float32))

    @staticmethod
    def decode_step(p, q, caches: RWKVCaches, tokens, cache_pos,
                    cfg: ModelConfig, mode: str = hgq.EVAL):
        aux = Aux.zero()
        newq: Dict[str, Any] = {}
        e, newq["embed"] = HEmbedding.apply(p["embed"], q["embed"], tokens,
                                            mode=mode, aux=aux)
        x, newq["layers"], new_caches, _ = RWKVLM._stack(p, q, e.q, cfg, mode,
                                                         caches)
        h, newq["final_norm"] = LayerNorm.apply(p["final_norm"],
                                                q["final_norm"], x, mode=mode,
                                                aux=aux)
        lt, _ = HDense.apply(p["lm_head"], q["lm_head"], h, mode=mode,
                             aux=aux)
        return constrain(lt.q, "b.m"), new_caches
