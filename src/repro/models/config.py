"""Model / architecture configuration schema (one instance per assigned arch)."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

from ..nn.common import HGQConfig


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 => d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    window: Optional[int] = None         # local-attention window
    attn_pattern: Tuple[str, ...] = ()   # hybrid: e.g. ('rec','rec','attn')
    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    # enc-dec (whisper)
    enc_layers: int = 0
    enc_seq: int = 1500
    # vlm
    n_patches: int = 0
    # misc
    act: str = "silu"
    norm: str = "rms"            # rms | ln
    tie_embeddings: bool = False
    dtype: str = "float32"
    remat: bool = True
    q_chunk: int = 1024
    k_chunk: int = 1024
    rwkv_chunk: int = 64
    hgq: HGQConfig = dataclasses.field(
        default_factory=lambda: HGQConfig(weight_gran="per_channel",
                                          act_gran="per_tensor",
                                          init_weight_f=6.0, init_act_f=6.0))

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def np_dtype(self):
        return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[self.dtype]

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch serve 500k-token contexts? (SSM/hybrid: O(1)/O(window)
        state; full-attention archs cannot — see DESIGN.md SS4.)"""
        return self.family in ("ssm", "hybrid")

    def n_params(self) -> int:
        """Analytic parameter count (embedding + layers [+ encoder])."""
        d, ff, V = self.d_model, self.d_ff, self.vocab
        hd = self.hd
        attn = d * self.n_heads * hd + 2 * d * self.n_kv * hd \
            + self.n_heads * hd * d
        if self.family == "ssm":  # rwkv6: r,k,v,g,o (d*d) + ffn + decay lora
            layer = 5 * d * d + 2 * d * ff + d * ff + 2 * d * 64
        elif self.moe_experts:
            layer = attn + self.moe_experts * 3 * d * ff + d * self.moe_experts
        else:
            layer = attn + 3 * d * ff if self.act == "silu" \
                else attn + 2 * d * ff
        if self.family == "hybrid":
            # 2/3 recurrent blocks (~(3 d*dr + 2 dr^2 + conv) with dr = d)
            rec = 3 * d * d + 2 * d * d
            layer = (2 * rec + attn) / 3 + 3 * d * ff
        total = self.n_layers * layer + V * d * (1 if self.tie_embeddings else 2)
        if self.enc_layers:
            total += self.enc_layers * (4 * d * d + 2 * d * ff)
            total += self.n_layers * 2 * d * d  # cross-attention extra
        return int(total)

    def n_active_params(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if not self.moe_experts:
            return self.n_params()
        d, ff = self.d_model, self.d_ff
        dense_share = self.n_params() - self.n_layers * self.moe_experts * 3 * d * ff
        return int(dense_share + self.n_layers * self.moe_top_k * 3 * d * ff)
