from .config import ModelConfig
from .lm import TransformerLM
from .rwkv import RWKVLM, RWKVCaches
from .griffin import GriffinLM, GriffinCaches
from .whisper import WhisperModel, WhisperCaches
from .tasks import JetTagger, SVHNNet, MuonTracker


def model_for(cfg: ModelConfig):
    """Dispatch an arch config to its model implementation."""
    if cfg.family == "ssm":
        return RWKVLM
    if cfg.family == "hybrid":
        return GriffinLM
    if cfg.family == "audio":
        return WhisperModel
    return TransformerLM  # dense | moe | vlm
