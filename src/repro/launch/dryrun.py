"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh and extract roofline terms.  No arrays are materialized —
params/state are ShapeDtypeStructs, the compile proves the sharding config
is coherent and the memory/cost analysis feeds EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --all
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-3b \
      --shape train_4k [--multi-pod]
"""
# The 512 placeholder devices MUST be requested before any jax init:
import os
os.environ["XLA_FLAGS"] = (os.environ.get("_DRYRUN_BASE_XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..api import MeshSpec, PrecisionSpec, RunSpec, build
from ..configs import ARCHS, SHAPES, ShapeSpec, get
from ..core import hgq
from ..dist.sharding import (batch_sharding, cache_sharding, replicated,
                             shard_tree)
from ..models import (GriffinCaches, ModelConfig, RWKVCaches,
                      WhisperCaches, model_for)
from ..nn.attention import KVCache
from ..train import TrainConfig, lm_loss, make_train_step
from .roofline import mfu

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


# --------------------------------------------------------------------------
# input specs (ShapeDtypeStructs — never allocated)
# --------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Abstract model inputs for one cell (weak-type-correct, shardable)."""
    B = shape.global_batch
    S = shape.seq_len if shape.kind != "decode" else 1
    f32 = jnp.float32 if cfg.dtype == "float32" else jnp.bfloat16
    specs: Dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if cfg.family == "vlm" and shape.kind != "decode":
        specs["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_patches, cfg.d_model), f32)
    if cfg.family == "audio" and shape.kind != "decode":
        specs["frame_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.enc_seq, cfg.d_model), f32)
    return specs


def abstract_model_state(M, cfg: ModelConfig):
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(lambda k: M.init(k, cfg),
                          jax.eval_shape(jax.random.PRNGKey, 0))


def abstract_cache(M, cfg: ModelConfig, batch: int, max_len: int):
    return jax.eval_shape(lambda: M.init_cache(cfg, batch, max_len))


def cache_shardings(caches, mesh, cfg: ModelConfig):
    """Family-aware cache sharding (DESIGN.md SS5)."""
    if isinstance(caches, KVCache):
        sh = cache_sharding(mesh, caches.k.shape, batch_axis=1, seq_axis=2)
        return KVCache(sh, sh)
    if isinstance(caches, RWKVCaches):
        shift = cache_sharding(mesh, caches.shift_a.shape, batch_axis=1,
                               head_axis=2)
        wkv = cache_sharding(mesh, caches.wkv.shape, batch_axis=1,
                             head_axis=2)
        return RWKVCaches(shift, shift, wkv)
    if isinstance(caches, GriffinCaches):
        conv = cache_sharding(mesh, caches.conv.shape, batch_axis=1,
                              head_axis=3)
        h = cache_sharding(mesh, caches.h.shape, batch_axis=1, head_axis=2)
        kv = cache_sharding(mesh, caches.k.shape, batch_axis=1, seq_axis=2)
        return GriffinCaches(conv, h, kv, kv)
    if isinstance(caches, WhisperCaches):
        s = cache_sharding(mesh, caches.self_k.shape, batch_axis=1,
                           seq_axis=2)
        c = cache_sharding(mesh, caches.cross_k.shape, batch_axis=1,
                           head_axis=4)
        return WhisperCaches(s, s, c, c, replicated(mesh))
    raise TypeError(type(caches))


# --------------------------------------------------------------------------
# cell builders
# --------------------------------------------------------------------------

def cell_spec(arch: str, shape_name: str, multi_pod: bool,
              variant: str, plan=None) -> RunSpec:
    """The declarative config of one dry-run cell — the same RunSpec
    surface the training launcher parses, so a dry-run cell and a real
    run describe their mesh/precision identically.  ``plan`` embeds a
    learned :class:`core.plan.PrecisionPlan` (per-layer wire/pack
    widths); the cell then reports them under ``plan_widths``."""
    return RunSpec(
        arch=arch, full=True, plan=plan,
        mesh=MeshSpec.production(multi_pod=multi_pod),
        precision=PrecisionSpec(
            # bf16 compute-cast everywhere: fp32-master FSDP gathers and
            # the TP partial-sum all-reduces run on bf16 values
            compute_dtype="bfloat16" if variant == "opt" else None,
            packed_serving=(variant == "opt"
                            and SHAPES[shape_name].kind == "decode"),
            # the compile-only dry-run keeps packed weights on the
            # XLA-fused dequant path (no Pallas kernel in the lowering)
            packed_matmul=False))


def build_cell(arch: str, shape_name: str, multi_pod: bool = False,
               variant: str = "base", plan=None) -> Dict[str, Any]:
    """variant='opt' enables the beyond-paper knobs (dist.perf):
    train -> bf16 compute-cast (halves FSDP gather volume);
    decode -> HGQ-packed int8 weights + int8 KV cache."""
    shape = SHAPES[shape_name]
    # applicability check BEFORE building the context: a skipped cell
    # must not pay the 256/512-device mesh construction
    if shape_name == "long_500k" and not get(arch).sub_quadratic:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": "full quadratic attention at 524288 tokens "
                          "(see DESIGN.md SS4 Arch-applicability)"}
    ctx = build(cell_spec(arch, shape_name, multi_pod, variant, plan))
    cfg = ctx.cfg
    if shape.kind != "train":
        cfg = dataclasses.replace(cfg, dtype="bfloat16", remat=False)
    M = model_for(cfg)
    mesh = ctx.mesh
    chips = mesh.devices.size
    params_abs, qstate_abs = abstract_model_state(M, cfg)
    if ctx.spec.precision.packed_serving:
        from ..dist.perf import pack_params_for_serving
        params_abs = jax.eval_shape(pack_params_for_serving, params_abs)
    batch_abs = input_specs(cfg, shape)
    mode = "train" if shape.kind == "train" else "serve"
    params_sh = shard_tree(params_abs, mesh, mode)
    qstate_sh = shard_tree(qstate_abs, mesh, mode)
    batch_sh = {k: batch_sharding(mesh, v.shape[0], len(v.shape))
                for k, v in batch_abs.items()}
    t0 = time.time()

    if shape.kind == "train":
        from ..optim import adamw_init
        opt_abs = jax.eval_shape(adamw_init, params_abs)
        opt_sh = type(opt_abs)(step=replicated(mesh),
                               mu=shard_tree(opt_abs.mu, mesh, "train"),
                               nu=shard_tree(opt_abs.nu, mesh, "train"))
        fwd = lambda p, q, b, mode: M.forward(p, q, b, cfg, mode)
        step_fn = ctx.wrap(make_train_step(
            fwd, lambda out, b: lm_loss(out, b["tokens"]),
            TrainConfig(steps=1000)))
        with mesh:
            jitted = jax.jit(step_fn,
                             in_shardings=(params_sh, qstate_sh, opt_sh,
                                           batch_sh, replicated(mesh)))
            lowered = jitted.lower(params_abs, qstate_abs, opt_abs,
                                   batch_abs,
                                   jax.ShapeDtypeStruct((), jnp.int32))
            compiled = lowered.compile()
    elif shape.kind == "prefill":
        @ctx.wrap
        def prefill(p, q, b):
            logits, _, _ = M.forward(p, q, b, cfg, mode=hgq.EVAL)
            return logits
        with mesh:
            jitted = jax.jit(prefill, in_shardings=(params_sh, qstate_sh,
                                                    batch_sh))
            lowered = jitted.lower(params_abs, qstate_abs, batch_abs)
            compiled = lowered.compile()
    else:  # decode
        max_len = shape.seq_len
        if variant == "opt" and cfg.family not in ("ssm",):
            caches_abs = jax.eval_shape(
                lambda: M.init_cache(cfg, shape.global_batch, max_len,
                                     dtype=jnp.int8))
        else:
            caches_abs = abstract_cache(M, cfg, shape.global_batch, max_len)
        caches_sh = cache_shardings(caches_abs, mesh, cfg)

        @ctx.wrap
        def serve_step(p, q, c, tokens, pos):
            return M.decode_step(p, q, c, tokens, pos, cfg)

        with mesh:
            # per-slot position vector [B]: the continuous-batching ragged
            # decode step (serving/engine.py) — every slot at its own offset
            jitted = jax.jit(serve_step,
                             in_shardings=(params_sh, qstate_sh, caches_sh,
                                           batch_sh["tokens"],
                                           replicated(mesh)))
            lowered = jitted.lower(params_abs, qstate_abs, caches_abs,
                                   batch_abs["tokens"],
                                   jax.ShapeDtypeStruct(
                                       (shape.global_batch,), jnp.int32))
            compiled = lowered.compile()

    compile_s = time.time() - t0
    hlo = compiled.as_text()
    from .analytic import analytic_flops_total, hbm_bytes_per_chip
    from .roofline import RooflineTerms, parse_collective_bytes, \
        parse_dot_flops
    flops_dev = parse_dot_flops(hlo)           # trip-count-scaled, per device
    coll = parse_collective_bytes(hlo)
    opt_decode = variant == "opt" and shape.kind == "decode"
    mem_model = hbm_bytes_per_chip(
        cfg, shape, chips, weight_bits=8.0 if opt_decode else 16.0,
        cache_bytes=1.0 if opt_decode else 2.0)
    terms = RooflineTerms(flops=flops_dev,
                          hbm_bytes=mem_model["total"],
                          coll_bytes=sum(coll.values()),
                          coll_breakdown=coll, chips=chips)
    # raw cost_analysis for reference (known loop-body undercount)
    raw = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        raw = {"flops": float(ca.get("flops", 0.0)),
               "bytes_accessed": float(ca.get("bytes accessed", 0.0))}
    except Exception:
        pass
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                mem[k] = int(v)
    except Exception as e:  # pragma: no cover
        mem["error"] = str(e)

    # useful-model-FLOPs ratio
    n_act = cfg.n_active_params()
    tokens_processed = shape.global_batch * (shape.seq_len
                                             if shape.kind != "decode" else 1)
    flops_factor = 6.0 if shape.kind == "train" else 2.0
    model_flops = flops_factor * n_act * tokens_processed
    hlo_total = terms.flops * chips
    result = {
        "arch": arch, "shape": shape_name, "variant": variant,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "ok", "compile_s": round(compile_s, 1),
        "kind": shape.kind,
        **terms.as_dict(),
        "hbm_model_breakdown": mem_model,
        "analytic_flops_total": analytic_flops_total(cfg, shape),
        "raw_cost_analysis": raw,
        "memory_analysis": mem,
        "model_flops_total": model_flops,
        "useful_flops_ratio": (model_flops / hlo_total) if hlo_total else 0.0,
        "roofline_fraction": mfu(model_flops, terms),
        # per-layer wire/pack widths of the cell's precision plan
        # (None == uniform int8, the plan-free default)
        "plan_widths": ctx.plan_summary(),
    }
    return result


def run_cells(archs, shapes, multi_pod: bool, out_dir: str,
              variant: str = "base") -> None:
    os.makedirs(out_dir, exist_ok=True)
    for arch in archs:
        for shape in shapes:
            vtag = "" if variant == "base" else f"_{variant}"
            tag = f"{arch}_{shape}_{'2x16x16' if multi_pod else '16x16'}"                 + vtag
            path = os.path.join(out_dir, tag + ".json")
            if os.path.exists(path):
                print(f"[skip existing] {tag}")
                continue
            print(f"[dryrun] {tag} ...", flush=True)
            try:
                res = build_cell(arch, shape, multi_pod, variant=variant)
            except Exception as e:
                res = {"arch": arch, "shape": shape, "status": "FAILED",
                       "mesh": "2x16x16" if multi_pod else "16x16",
                       "error": f"{type(e).__name__}: {e}",
                       "trace": traceback.format_exc()[-2000:]}
            with open(path, "w") as f:
                json.dump(res, f, indent=1, default=str)
            stat = res["status"]
            extra = ""
            if stat == "ok":
                extra = (f" bottleneck={res['bottleneck']}"
                         f" t=({res['t_compute_s']:.2e},"
                         f"{res['t_memory_s']:.2e},"
                         f"{res['t_collective_s']:.2e})s"
                         f" compile={res['compile_s']}s")
            print(f"[dryrun] {tag}: {stat}{extra}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(OUT_DIR))
    ap.add_argument("--variant", default="base", choices=["base", "opt"])
    args = ap.parse_args()
    archs = ARCHS if args.arch is None else [args.arch]
    shapes = list(SHAPES) if args.shape is None else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for mp in meshes:
        run_cells(archs, shapes, mp, args.out, variant=args.variant)


if __name__ == "__main__":
    main()
