"""Analytic HBM-traffic model per (arch x shape x mesh) cell.

``cost_analysis()['bytes accessed']`` is not HBM traffic: it sums operand
bytes at every HLO op (fused/VMEM-resident values included) and counts loop
bodies once.  For the roofline memory term we model the real traffic:

TRAIN (fp32 master, FSDP(dp) x TP(tp), full remat):
  weights   : gathered TP shard read 3x (fwd, remat-fwd, bwd)    3*4N/tp
  grads     : reduce-scattered shard written once                 4N/(dp*tp)
  optimizer : adam m,v read+write, params read+write         5*4N/(dp*tp)
  activations: remat saves layer inputs (write+read)       2*L*Bl*S*d*4
               + per-layer working set streamed               ~c_act*L*Bl*S*(d+ff')*4

PREFILL (bf16, TP):  weights once 2N/tp + activation stream
DECODE  (bf16, TP):  weights once per token 2N/tp + KV-cache shard read
                     (the canonical HBM-bound regime)

N is *active* params (MoE: top-k experts; the packed-bits serving path
scales the weight term by mean_bits/16 — that is the HGQ TPU win, see
EXPERIMENTS.md SSPerf).
"""
from __future__ import annotations

from typing import Dict

from ..configs.base import ShapeSpec
from ..models.config import ModelConfig


def hbm_bytes_per_chip(cfg: ModelConfig, shape: ShapeSpec, chips: int,
                       tp: int = 16, *, weight_bits: float = 16.0,
                       cache_bytes: float = 2.0,
                       fsdp_gather: int = 3) -> Dict[str, float]:
    N = cfg.n_active_params()
    dp = max(chips // tp, 1)
    B = shape.global_batch
    Bl = max(B // dp, 1)
    S = shape.seq_len
    d, ff, L = cfg.d_model, cfg.d_ff, cfg.n_layers
    out: Dict[str, float] = {}
    if shape.kind == "train":
        wb = 4.0  # fp32 master
        out["weights"] = fsdp_gather * wb * N / tp
        out["grads"] = wb * N / (dp * tp)
        out["optimizer"] = 5.0 * wb * N / (dp * tp)
        act_ff = ff if not cfg.moe_experts else cfg.moe_top_k * ff
        out["act_saved"] = 2.0 * L * Bl * S * d * 4.0
        out["act_stream"] = 3.0 * L * Bl * S * (2 * d + 2 * act_ff) * 4.0
    else:
        wbytes = weight_bits / 8.0
        out["weights"] = wbytes * N / tp
        if shape.kind == "prefill":
            out["act_stream"] = 2.0 * L * Bl * S * 2 * d * 2.0
        else:  # decode: read the whole local cache shard every token
            out["cache"] = _cache_bytes_total(cfg, shape, cache_bytes) / chips
            out["act_stream"] = L * Bl * 4 * d * 2.0
    out["total"] = sum(out.values())
    return out


def _cache_bytes_total(cfg: ModelConfig, shape: ShapeSpec,
                       kv_bytes: float = 2.0) -> float:
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "ssm":
        H = cfg.d_model // 64
        return cfg.n_layers * B * (2 * cfg.d_model + H * 64 * 64) * 4.0
    if cfg.family == "hybrid":
        units = cfg.n_layers // 3
        nrec = cfg.n_layers - units
        W = min(S, cfg.window or S)
        rec = nrec * B * (3 * cfg.d_model + cfg.d_model) * 4.0
        att = units * B * W * cfg.n_kv * cfg.hd * 2 * kv_bytes
        return rec + att
    if cfg.family == "audio":
        self_c = cfg.n_layers * B * S * cfg.n_heads * cfg.hd * 2 * kv_bytes
        cross = cfg.n_layers * B * cfg.enc_seq * cfg.n_heads * cfg.hd * 2 \
            * kv_bytes
        return self_c + cross
    W = min(S, cfg.window or S)
    return cfg.n_layers * B * W * cfg.n_kv * cfg.hd * 2 * kv_bytes


def analytic_flops_total(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """Global matmul FLOPs of one step (cross-check for the HLO parse)."""
    N = cfg.n_active_params()
    # embedding lookup contributes no matmul flops; tied head reuses table
    N_mm = N if cfg.tie_embeddings else N - cfg.vocab * cfg.d_model
    B, S = shape.global_batch, shape.seq_len
    T = B * (S if shape.kind != "decode" else 1)
    factor = 8.0 if shape.kind == "train" else 2.0  # fwd+bwd+remat vs fwd
    flops = factor / 2.0 * 2.0 * N_mm * T
    # attention score/value matmuls
    L, H, hd = cfg.n_layers, cfg.n_heads, cfg.hd
    if cfg.family == "ssm":
        Nh = cfg.d_model // 64
        flops += factor / 2.0 * 4.0 * B * (S if shape.kind != "decode" else 1) \
            * Nh * 64 * 64
    else:
        att_layers = L // 3 if cfg.family == "hybrid" else L
        if shape.kind == "decode":
            kv = min(S, cfg.window or S)
            flops += 4.0 * att_layers * B * kv * hd * H
        else:
            kv = min(S, cfg.window or S)
            causal = 0.5 if (cfg.window is None or S <= cfg.window) else 1.0
            flops += factor / 2.0 * 4.0 * att_layers * B * S * kv * hd * H \
                * causal
        if cfg.family == "audio":
            flops += factor / 2.0 * 4.0 * cfg.enc_layers * B \
                * cfg.enc_seq ** 2 * hd * H
            dec_T = B * (S if shape.kind != "decode" else 1)
            flops += factor / 2.0 * 4.0 * L * dec_T * cfg.enc_seq * hd * H
    return flops
