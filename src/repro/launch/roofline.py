"""Roofline-term extraction from compiled XLA artifacts (no hardware).

    compute term    = HLO_FLOPs / (chips * peak_FLOPs)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``.
collective_bytes is parsed out of the post-SPMD HLO text: the summed output
sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops (per-device module, so the value is already
per-chip; for ops inside ``while`` loop bodies the trip count multiplies).

Hardware constants: TPU v5e-class — 197 TFLOP/s bf16 per chip, 819 GB/s
HBM, ~50 GB/s/link ICI (brief SSRoofline).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s / chip
ICI_BW = 50e9             # bytes/s / link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "s4": 0.5, "u4": 0.5,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> float:
    """Bytes of one HLO shape string like 'f32[128,1024]' or a tuple."""
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _computation_multipliers(hlo_text: str) -> Dict[str, float]:
    """Execution-count multiplier per computation, from the while-loop graph.

    Every ``while`` op names its body computation and (usually) carries
    ``known_trip_count`` in backend_config.  Loop bodies execute trip_count
    times the count of the computation containing the while; nesting
    composes multiplicatively (layer scan x q-chunk scan x k-chunk scan).
    Called computations (fusions etc.) inherit their caller's multiplier —
    we conservatively propagate only through while bodies/conditions, which
    is where the collectives of interest live.
    """
    comp_re = re.compile(r"^(?:ENTRY )?%?([\w.\-]+) \(")
    # which computation does each line belong to
    current = None
    # body name -> (parent computation, trip count)
    parent: Dict[str, Tuple[str, float]] = {}
    while_re = re.compile(
        r" while\(%?[\w.\-]+\), condition=%?([\w.\-]+), body=%?([\w.\-]+)"
        r"([^\n]*)")
    trip_re = re.compile(r"known_trip_count[^0-9]*(\d+)")
    for raw in hlo_text.splitlines():
        line = raw.strip()
        if line.endswith("{") and "->" in line:
            mm = comp_re.match(line)
            if mm:
                current = mm.group(1)
                continue
        wm = while_re.search(line)
        if wm and current:
            tm = trip_re.search(wm.group(3))
            n = float(tm.group(1)) if tm else 1.0
            cond, body = wm.group(1), wm.group(2)
            parent[body] = (current, n)
            parent[cond] = (current, n)
    mult: Dict[str, float] = {}

    def resolve(comp: str, depth: int = 0) -> float:
        if comp in mult:
            return mult[comp]
        if depth > 20 or comp not in parent:
            return 1.0
        pcomp, n = parent[comp]
        m = n * resolve(pcomp, depth + 1)
        mult[comp] = m
        return m

    for comp in list(parent):
        resolve(comp)
    return mult


def parse_collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum result bytes per collective kind from post-SPMD HLO text, scaling
    ops inside while bodies by their loop trip counts.  For async
    ``*-start`` ops with tuple results, only the final (result) shape is
    counted — the tuple repeats the operand."""
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    mult = _computation_multipliers(hlo_text)
    comp_re = re.compile(r"^(?:ENTRY )?%?([\w.\-]+) \(")
    current = None
    for raw in hlo_text.splitlines():
        line = raw.strip()
        if line.endswith("{") and "->" in line:
            mm = comp_re.match(line)
            if mm:
                current = mm.group(1)
                continue
        for kind in _COLLECTIVES:
            if f" {kind}(" not in line and f" {kind}-start(" not in line:
                continue
            eq = line.split("=", 1)
            if len(eq) != 2:
                continue
            shape_part = eq[1].split(kind)[0]
            shapes = _SHAPE_RE.findall(shape_part)
            if not shapes:
                continue
            # tuple result (async start): last element is the output
            dtype, dims = shapes[-1]
            if dtype not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            b = n * _DTYPE_BYTES[dtype]
            out[kind] += b * mult.get(current, 1.0)
            break
    return out


_OP_RE = re.compile(r"^%?([\w.\-]+) = (\w+)\[([\d,]*)\]")


def parse_dot_flops(hlo_text: str) -> float:
    """Trip-count-scaled matmul FLOPs per device, parsed from the compiled
    HLO.  ``cost_analysis()`` counts while-loop bodies ONCE — at 24-95
    scanned layers that is a 20-90x undercount — so we walk the HLO
    ourselves: every ``dot`` contributes 2 * prod(result) * prod(contract)
    FLOPs, multiplied by its computation's execution count from the
    while-loop graph.  Elementwise FLOPs are ignored (<2% for these models).
    """
    mult = _computation_multipliers(hlo_text)
    comp_re = re.compile(r"^(?:ENTRY )?%?([\w.\-]+) \(")
    # pass 1: symbol table  op name -> dims
    shapes: Dict[str, Tuple[int, ...]] = {}
    for raw in hlo_text.splitlines():
        m = _OP_RE.match(raw.strip())
        if m:
            dims = tuple(int(d) for d in m.group(3).split(",") if d)
            shapes[m.group(1)] = dims
    total = 0.0
    current = None
    dot_re = re.compile(
        r"^%?[\w.\-]+ = \w+\[([\d,]*)\]\S* dot\(%?([\w.\-]+),")
    lhs_c_re = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
    for raw in hlo_text.splitlines():
        line = raw.strip()
        if line.endswith("{") and "->" in line:
            cm = comp_re.match(line)
            if cm:
                current = cm.group(1)
                continue
        dm = dot_re.match(line)
        if not dm:
            continue
        res_dims = tuple(int(d) for d in dm.group(1).split(",") if d)
        lhs = shapes.get(dm.group(2), ())
        cm2 = lhs_c_re.search(line)
        contract = 1
        if cm2 and lhs:
            for idx in cm2.group(1).split(","):
                if idx and int(idx) < len(lhs):
                    contract *= lhs[int(idx)]
        n = 1
        for d in res_dims:
            n *= d
        total += 2.0 * n * contract * mult.get(current, 1.0)
    return total


@dataclasses.dataclass
class RooflineTerms:
    flops: float               # per-device HLO flops
    hbm_bytes: float           # per-device bytes accessed
    coll_bytes: float          # per-device collective bytes
    coll_breakdown: Dict[str, float]
    chips: int

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    def as_dict(self) -> Dict:
        return {
            "flops_per_chip": self.flops,
            "hbm_bytes_per_chip": self.hbm_bytes,
            "coll_bytes_per_chip": self.coll_bytes,
            "coll_breakdown": self.coll_breakdown,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "chips": self.chips,
        }


def terms_from_compiled(compiled, mesh_size: int,
                        hlo_text: Optional[str] = None) -> RooflineTerms:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = parse_collective_bytes(text)
    # cost_analysis on a partitioned module is per-device already
    return RooflineTerms(flops=flops, hbm_bytes=byts,
                         coll_bytes=sum(coll.values()),
                         coll_breakdown=coll, chips=mesh_size)


def mfu(model_flops_total: float, terms: RooflineTerms) -> float:
    """MODEL_FLOPS / (chips * peak * t_dominant) — roofline fraction."""
    t = max(terms.t_compute, terms.t_memory, terms.t_collective)
    if t <= 0:
        return 0.0
    return model_flops_total / (terms.chips * PEAK_FLOPS * t)
