"""Pod-scale LM training launcher: pjit'd train step under the production
mesh with the full sharding rules.

The launcher is a thin shell over ``repro.api``: CLI flags (or a
``--spec run.json`` file — see ``examples/specs/``) parse into one
declarative :class:`repro.api.RunSpec`, and :func:`repro.api.build`
constructs the mesh, axis registry, shardings, compressed train step,
and checkpoint/resume flow from the spec alone — the exact config that
ran is reprintable as JSON, and no module-level globals are touched.

On this CPU container it runs the smoke config on a 1x1 mesh; on
hardware, ``--multi-pod`` builds the (2, 16, 16) mesh and the same code
paths shard per repro.dist.sharding (exactly what launch/dryrun.py
proves compiles).

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --steps 20 --batch 4 --seq 32
    PYTHONPATH=src python -m repro.launch.train \
        --spec examples/specs/host_2x4_int8wire2d.json
"""
from __future__ import annotations

import time

from ..api import RunSpec, build


def main() -> None:
    spec = RunSpec.from_args()
    ctx = build(spec)
    comp = ctx.grad_compression()
    if (spec.compression.kind == "int8-wire" and comp.wire
            and comp.wire_layout == "2d"):
        print(f"mesh has model axis of size {ctx.n_model}: upgrading "
              f"int8-wire to the 2D-sliced exchange (int8-wire-2d)")
    setup = ctx.init_training()
    tcfg = spec.train
    with ctx.mesh:
        if tcfg.ckpt_dir and setup.maybe_resume():
            print(f"resumed from step {setup.start_step}")
        start = setup.start_step
        t0 = time.time()
        for step in range(start, tcfg.steps):
            m = setup.step(step)
            if step % max(tcfg.steps // 10, 1) == 0:
                print(f"step {step}: loss={float(m['loss']):.4f} "
                      f"ebops={float(m['ebops']):.3g}")
            if tcfg.ckpt_dir and step and step % tcfg.ckpt_every == 0:
                # label = steps applied = next step to run; labelling
                # with `step` would replay an already-applied batch
                setup.checkpoint(step + 1)
        print(f"done: {tcfg.steps - start} steps in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
