"""Pod-scale LM training launcher: pjit'd train step under the production
mesh with the full sharding rules.

On this CPU container it runs the smoke config on a 1x1 mesh; on hardware,
``--multi-pod`` builds the (2, 16, 16) mesh and the same code paths shard
per repro.dist.sharding (exactly what launch/dryrun.py proves compiles).

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --steps 20 --batch 4 --seq 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get
from ..data import DataSpec, make_pipeline
from ..dist import EFState, ef_compress, ef_init
from ..dist import collectives
from ..dist.axes import set_axes
from ..dist.sharding import (batch_sharding, ef_residual_sharding,
                             replicated, shard_tree)
from ..models import model_for
from ..optim import adamw_init
from ..train import TrainConfig, lm_loss, make_train_step
from ..train import checkpoint as ckpt_lib
from .mesh import make_host_mesh, make_production_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mesh", default="",
                    help="host mesh DATAxMODEL (e.g. 4x2) for multi-device "
                         "smoke runs; needs XLA_FLAGS="
                         "--xla_force_host_platform_device_count>=D*M")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=200,
                    help="checkpoint every N steps (makes the EF-residual "
                         "resume path drivable in short runs)")
    ap.add_argument("--grad-compression",
                    choices=["none", "bf16", "int8", "int8-wire",
                             "int8-wire-2d"],
                    default="none",
                    help="bf16/int8 quantize the synchronized gradient "
                         "(post-reduce); int8-wire compresses inside the "
                         "reduction — int8 bytes on the wire via "
                         "dist.collectives; int8-wire-2d additionally "
                         "slices the exchange over the model (TP) axis — "
                         "auto-selected for int8-wire when --mesh DxM has "
                         "M>1 (single-device runs fall back to the "
                         "post-reduce int8 path)")
    args = ap.parse_args()

    cfg = get(args.arch, smoke=not args.full)
    M = model_for(cfg)
    if args.production_mesh or args.multi_pod:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        daxes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
        dsize = 1
        for a in daxes:
            dsize *= sizes[a]
        set_axes(daxes, "model", data_size=dsize, model_size=sizes["model"])
    elif args.mesh:
        d, m = (int(v) for v in args.mesh.lower().split("x"))
        mesh = jax.make_mesh((d, m), ("data", "model"))
        set_axes(("data",), "model", data_size=d, model_size=m)
    else:
        mesh = make_host_mesh()

    params, qstate = M.init(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    pipe = make_pipeline(DataSpec(kind="lm", batch=args.batch, seq=args.seq,
                                  vocab=cfg.vocab))
    tcfg = TrainConfig(steps=args.steps, lr=1e-3, beta0=1e-9, beta1=1e-7,
                       ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every)
    fwd = lambda p, q, b, mode: M.forward(p, q, b, cfg, mode)
    # int8/bf16 error-feedback quantization of the gradient (the residual
    # carries the quantization error so the time-averaged update stays
    # unbiased).  bf16/int8 quantize the *synchronized* gradient — they
    # bound update noise but fp32 still crosses the wire; int8-wire moves
    # the compression inside the reduction (dist.collectives: shard_map
    # two-phase int8 exchange, custom-vjp psum), so the gradient collective
    # itself is ~4x smaller.
    dsize = collectives.data_axis_size(mesh)
    msize = collectives.model_axis_size(mesh)
    wire_kinds = ("int8-wire", "int8-wire-2d")
    # the 2D sliced exchange is strictly better whenever the mesh has a
    # model axis (int8 instead of fp32 crosses it) — auto-upgrade int8-wire
    wire_layout = ("2d" if (args.grad_compression == "int8-wire-2d"
                            or msize > 1) else "1d")
    wire = (args.grad_compression in wire_kinds
            and (dsize > 1 or (wire_layout == "2d" and msize > 1)))
    if args.grad_compression == "int8-wire" and wire and wire_layout == "2d":
        print(f"mesh has model axis of size {msize}: upgrading int8-wire "
              f"to the 2D-sliced exchange (int8-wire-2d)")
    grad_tx = None
    ef_state = None
    if args.grad_compression in wire_kinds:
        if wire and wire_layout == "2d":
            ef_state = EFState(
                residual=collectives.ef_wire2d_init(params, dsize, msize))
        elif wire:
            ef_state = EFState(
                residual=collectives.ef_wire_init(params, dsize))
        else:
            # single device: the wire is a no-op — post-reduce int8 EF IS
            # the compressed path here, token-for-token
            grad_tx = lambda g, s: ef_compress(g, s, kind="int8")
            ef_state = ef_init(params)
    elif args.grad_compression != "none":
        grad_tx = lambda g, s: ef_compress(g, s, kind=args.grad_compression)
        ef_state = ef_init(params)
    step_fn = make_train_step(fwd, lambda out, b: lm_loss(out, b["tokens"]),
                              tcfg, grad_tx=grad_tx,
                              reduce="compressed" if wire else "full",
                              mesh=mesh if wire else None,
                              wire_layout=wire_layout if wire else "auto")
    with mesh:
        in_shardings = (shard_tree(params, mesh, "train"),
                        shard_tree(qstate, mesh, "train"),
                        type(opt)(step=replicated(mesh),
                                  mu=shard_tree(opt.mu, mesh, "train"),
                                  nu=shard_tree(opt.nu, mesh, "train")),
                        {"tokens": batch_sharding(mesh, args.batch, 2)},
                        replicated(mesh))
        donate = (0, 2)
        if ef_state is not None:
            res_sh = (ef_residual_sharding(ef_state.residual, mesh,
                                           layout=wire_layout) if wire
                      else shard_tree(ef_state.residual, mesh, "train"))
            in_shardings += (EFState(residual=res_sh),)
            donate += (5,)  # the residual threads step-to-step like opt
        jitted = jax.jit(step_fn, in_shardings=in_shardings,
                         donate_argnums=donate)
        start = 0
        if args.ckpt_dir:
            last = ckpt_lib.latest_step(args.ckpt_dir)
            if last is not None:
                tmpl = {"params": params, "qstate": qstate, "opt": opt}
                start, trees = ckpt_lib.restore(args.ckpt_dir, last, tmpl)
                params, qstate, opt = (trees["params"], trees["qstate"],
                                       trees["opt"])
                # EF residual resumes rather than resetting — but only when
                # the checkpoint has a shape-compatible one (a run may turn
                # compression on mid-stream, change kind, or rescale the
                # mesh: the 1D wire residual is [n_data, ...] and the 2D
                # one [n_data, n_model, C], so a rescale — or a 1d<->2d
                # layout switch — cannot re-chunk it: warn, restart it at
                # zero, and eat one biased window instead of dying)
                if ef_state is not None and ckpt_lib.has_tree(
                        args.ckpt_dir, last, "ef"):
                    try:
                        _, eft = ckpt_lib.restore(args.ckpt_dir, last,
                                                  {"ef": ef_state})
                        ef_state = eft["ef"]
                    except (AssertionError, KeyError):
                        print("warning: checkpointed EF residual does not "
                              "match the current mesh/compression kind; "
                              "restarting it at zero")
                print(f"resumed from step {start}")
        t0 = time.time()
        for step in range(start, args.steps):
            if ef_state is not None:
                params, qstate, opt, m, ef_state = jitted(
                    params, qstate, opt, pipe(step), jnp.int32(step),
                    ef_state)
            else:
                params, qstate, opt, m = jitted(params, qstate, opt,
                                                pipe(step), jnp.int32(step))
            if step % max(args.steps // 10, 1) == 0:
                print(f"step {step}: loss={float(m['loss']):.4f} "
                      f"ebops={float(m['ebops']):.3g}")
            if args.ckpt_dir and step and step % tcfg.ckpt_every == 0:
                trees = {"params": params, "qstate": qstate, "opt": opt}
                if ef_state is not None:
                    trees["ef"] = ef_state
                # label = steps applied = next step to run; labelling with
                # `step` would replay an already-applied batch on resume
                ckpt_lib.save(args.ckpt_dir, step + 1, trees)
        print(f"done: {args.steps - start} steps in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
