"""Production mesh builders.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init, smoke tests keep 1 device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod (v5e-class pod slice); 2 pods = 512 chips.

    ``pod`` is an outer data axis: the gradient all-reduce crosses the
    (slower) inter-pod links once per step; TP traffic stays inside a pod.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU smoke tests/examples."""
    return jax.make_mesh((1, 1), ("data", "model"))


def data_axes(mesh) -> tuple:
    """All data-parallel axes of a mesh (pod is outer-DP)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
