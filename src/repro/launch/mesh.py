"""Mesh builders — thin wrappers over ``repro.api``'s declarative
:class:`MeshSpec` (the one place mesh topology is described as data).

FUNCTIONS (not module-level constants) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init, smoke tests keep 1 device.
"""
from __future__ import annotations

from ..api.spec import MeshSpec
from ..api.context import build_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod (v5e-class pod slice); 2 pods = 512 chips.

    ``pod`` is an outer data axis: the gradient all-reduce crosses the
    (slower) inter-pod links once per step; TP traffic stays inside a pod.
    """
    return build_mesh(MeshSpec.production(multi_pod=multi_pod))


def make_host_mesh(data: int = 1, model: int = 1):
    """Host ``data x model`` mesh (default 1x1 for CPU smoke tests)."""
    return build_mesh(MeshSpec.host(data, model))


def data_axes(mesh) -> tuple:
    """All data-parallel axes of a mesh (pod is outer-DP)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
